//! Serving-style driver: a stream of inference requests on the WIENNA
//! package, with inter-layer pipelining (double-buffered preloads) and
//! per-request latency/throughput statistics — the deployment mode the
//! paper's real-time-inference motivation implies.
//!
//! Run with: `cargo run --release --example serving`

use wienna::config::{DesignPoint, SystemConfig, CLOCK_HZ};
use wienna::coordinator::pipeline::pipeline_makespan;
use wienna::cost::{evaluate_model, CostEngine};
use wienna::report::Table;
use wienna::workload::resnet50::resnet50;

fn main() {
    let sys = SystemConfig::default();
    // Request = one image (batch-1 model); the package serves a stream.
    let model = resnet50(1);

    let mut t = Table::new(
        "request-serving on the 256-chiplet package (ResNet-50, batch 1/request)",
        &["design", "latency/request (ms)", "pipelined (ms)", "throughput (req/s)", "speedup"],
    );
    for dp in DesignPoint::ALL {
        let e = CostEngine::for_design_point(&sys, dp);
        let cost = evaluate_model(&e, &model, None);
        let seq_ms = cost.total_latency / CLOCK_HZ * 1e3;
        let pipelined = pipeline_makespan(&cost.layers, 512 * 1024);
        let pipe_ms = pipelined.pipelined_cycles / CLOCK_HZ * 1e3;
        // Steady-state: back-to-back requests pipeline across the stream;
        // the bottleneck phase of the whole network gates issue rate.
        let steady_cycles: f64 = cost
            .layers
            .iter()
            .map(|l| l.timeline.stream.max(l.timeline.compute).max(l.timeline.collect))
            .sum();
        let req_per_s = CLOCK_HZ / steady_cycles;
        t.row(vec![
            dp.label(),
            format!("{seq_ms:.3}"),
            format!("{pipe_ms:.3}"),
            format!("{req_per_s:.0}"),
            format!("{:.3}x", pipelined.speedup()),
        ]);
    }
    print!("{}", t.render());

    // Burst behaviour: how many in-flight requests before the
    // distribution plane saturates (little's-law style estimate).
    let e = CostEngine::for_design_point(&sys, DesignPoint::WIENNA_C);
    let cost = evaluate_model(&e, &model, None);
    let dist: f64 = cost.layers.iter().map(|l| l.timeline.preload + l.timeline.stream).sum();
    let compute: f64 = cost.layers.iter().map(|l| l.timeline.compute).sum();
    println!(
        "\nWIENNA-C: distribution occupies {:.1}% of a request's cycles; \
         the wireless plane sustains ~{:.1} overlapped requests before it saturates",
        dist / (dist + compute) * 100.0,
        (dist + compute) / dist
    );
}
