//! Request serving on WIENNA package fleets — the deployment mode the
//! paper's real-time-inference motivation implies, now as a discrete-event
//! simulation (`wienna::serve`) instead of a steady-state estimate.
//!
//! Three scenarios:
//!
//! 1. an offered-load sweep over design points: open-loop Poisson traffic
//!    of a ResNet-50 / UNet / BERT mix on four-package fleets, showing the
//!    dynamic batcher growing the batch as load rises and the SLO
//!    violation rate exploding past the saturation knee;
//! 2. a routing-policy comparison on a *heterogeneous* fleet (two
//!    aggressive wireless packages + two conservative interposer ones);
//! 3. a closed-loop client pool (completions gate new arrivals).
//!
//! Run with: `cargo run --release --example serving`

use wienna::config::DesignPoint;
use wienna::report::Table;
use wienna::serve::{
    cycles_to_ms, ms_to_cycles, Fleet, PackageSpec, RoutePolicy, ServeStats, Source, WorkloadMix,
};

/// The crate's canonical ResNet-50 / UNet / BERT serving mix.
fn mix() -> WorkloadMix {
    WorkloadMix::cnn_transformer_default()
}

const HORIZON_MS: f64 = 100.0;

fn run(fleet: &mut Fleet, load: f64, seed: u64) -> (ServeStats, f64, f64) {
    let capacity = fleet.estimate_capacity_rps(&mix(), 8);
    let rate = capacity * load;
    let mut source = Source::poisson(mix(), rate, seed);
    let mut stats = ServeStats::new();
    let end = fleet.run(&mut source, ms_to_cycles(HORIZON_MS), &mut stats);
    (stats, rate, end)
}

fn main() {
    // ---- 1. Offered-load sweep per design point ----------------------
    let mut t = Table::new(
        "CNN+transformer mix on 4-package fleets (EDF routing, 100 ms of Poisson traffic)",
        &[
            "design",
            "load",
            "offered req/s",
            "p50 ms",
            "p99 ms",
            "goodput req/s",
            "SLO viol %",
            "mean batch",
            "max batch",
            "dist-plane util %",
        ],
    );
    for dp in [DesignPoint::INTERPOSER_A, DesignPoint::WIENNA_C, DesignPoint::WIENNA_A] {
        for load in [0.3, 0.8, 1.5] {
            let mut fleet =
                Fleet::new(PackageSpec::homogeneous(4, dp), RoutePolicy::EarliestDeadline);
            let (stats, rate, end) = run(&mut fleet, load, 42);
            let n = fleet.packages.len() as f64;
            let dist_util =
                fleet.packages.iter().map(|p| p.dist_plane_utilization(end)).sum::<f64>() / n;
            t.row(vec![
                dp.label(),
                format!("{load:.1}"),
                format!("{rate:.0}"),
                format!("{:.2}", stats.latency_ms(50.0)),
                format!("{:.2}", stats.latency_ms(99.0)),
                format!("{:.0}", stats.goodput_rps()),
                format!("{:.1}", stats.violation_rate() * 100.0),
                format!("{:.2}", stats.mean_batch()),
                stats.max_batch().to_string(),
                format!("{:.1}", dist_util * 100.0),
            ]);
        }
    }
    print!("{}", t.render());
    println!(
        "-> the batcher serves batch ~1 at light load and grows the batch under backlog;\n\
         -> past the knee (load > 1) goodput flattens and the SLO violation rate explodes.\n"
    );

    // ---- 2. Routing policies on a heterogeneous fleet ----------------
    let hetero = || -> Vec<PackageSpec> {
        let mut v = PackageSpec::homogeneous(2, DesignPoint::WIENNA_A);
        v.extend(PackageSpec::homogeneous(2, DesignPoint::INTERPOSER_C));
        v
    };
    let mut t = Table::new(
        "routing policies on a heterogeneous fleet (2x WIENNA-A + 2x Interposer-C, load 0.9)",
        &["policy", "p50 ms", "p99 ms", "goodput req/s", "SLO viol %", "fast-pkg share %"],
    );
    for policy in RoutePolicy::ALL {
        let mut fleet = Fleet::new(hetero(), policy);
        let (stats, _, _) = run(&mut fleet, 0.9, 7);
        let fast: u64 = fleet.packages[..2].iter().map(|p| p.requests_completed).sum();
        let total: u64 = fleet.packages.iter().map(|p| p.requests_completed).sum();
        t.row(vec![
            policy.label().to_string(),
            format!("{:.2}", stats.latency_ms(50.0)),
            format!("{:.2}", stats.latency_ms(99.0)),
            format!("{:.0}", stats.goodput_rps()),
            format!("{:.1}", stats.violation_rate() * 100.0),
            format!("{:.1}", fast as f64 / total.max(1) as f64 * 100.0),
        ]);
    }
    print!("{}", t.render());
    println!("-> load- and SLO-aware routing shifts traffic onto the wireless packages.\n");

    // ---- 3. Closed-loop clients --------------------------------------
    let mut fleet =
        Fleet::new(PackageSpec::homogeneous(4, DesignPoint::WIENNA_C), RoutePolicy::LeastLoaded);
    let mut source = Source::closed_loop(mix(), 64, 2.0, 16, 3);
    let mut stats = ServeStats::new();
    let end = fleet.run(&mut source, f64::INFINITY, &mut stats);
    println!(
        "closed loop: 64 clients x 16 requests, 2 ms think time on 4x WIENNA-C -> \
         {} served in {:.1} ms, p50 {:.2} ms, p99 {:.2} ms, {:.1}% SLO violations",
        stats.completed(),
        cycles_to_ms(end),
        stats.latency_ms(50.0),
        stats.latency_ms(99.0),
        stats.violation_rate() * 100.0
    );
    println!(
        "cost cache after the closed-loop run: {} entries, {} hits / {} misses",
        fleet.cache.len(),
        fleet.cache.hits,
        fleet.cache.misses
    );
}
