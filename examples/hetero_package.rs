//! Heterogeneous chiplet packages (paper §4's "no assumptions about the
//! chiplet architecture" claim, exercised): mixes of big and small
//! chiplets, capability-proportional vs naive-uniform work splits.
//!
//! Run with: `cargo run --release --example hetero_package`

use wienna::coordinator::hetero::{partition_hetero, partition_uniform, ChipletClass, HeteroPackage};
use wienna::dataflow::{ChipletArch, Strategy};
use wienna::report::Table;
use wienna::workload::resnet50::resnet50;

fn main() {
    // 16384 PEs, three ways: uniform small, uniform big, 50/50 mix.
    let packages = [
        ("256 x 64-PE", HeteroPackage::homogeneous(256, 64, ChipletArch::NvdlaLike)),
        ("64 x 256-PE", HeteroPackage::homogeneous(64, 256, ChipletArch::NvdlaLike)),
        (
            "mix 32x256 + 128x64",
            HeteroPackage {
                classes: vec![
                    ChipletClass { name: "big".into(), count: 32, pes: 256, arch: ChipletArch::NvdlaLike },
                    ChipletClass { name: "small".into(), count: 128, pes: 64, arch: ChipletArch::NvdlaLike },
                ],
            },
        ),
    ];

    let model = resnet50(8);
    for (name, pkg) in &packages {
        println!(
            "### {} ({} chiplets, {} PEs)",
            name,
            pkg.total_chiplets(),
            pkg.total_pes()
        );
        let mut t = Table::new(
            "per-layer makespan, KP-CP (first 8 conv layers)",
            &["layer", "proportional (cyc)", "uniform (cyc)", "gain", "imbalance"],
        );
        for l in model.layers.iter().filter(|l| l.weight_elems() > 0).take(8) {
            let prop = partition_hetero(l, Strategy::KpCp, pkg, 1);
            let unif = partition_uniform(l, Strategy::KpCp, pkg, 1);
            t.row(vec![
                l.name.to_string(),
                format!("{}", prop.makespan),
                format!("{}", unif.makespan),
                format!("{:.2}x", unif.makespan as f64 / prop.makespan.max(1) as f64),
                format!("{:.2}", prop.imbalance),
            ]);
        }
        print!("{}\n", t.render());
    }
    println!("capability-proportional splitting recovers the loss a naive uniform split pays on mixed packages.");
}
