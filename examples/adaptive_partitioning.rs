//! Adaptive vs fixed partitioning — the paper's co-design headline
//! (§5.2: adaptive beats all-KP-CP by 4.7% on ResNet50 and 9.1% on UNet).
//!
//! Prints the per-layer-type strategy histogram the coordinator settles
//! on, and the end-to-end gain of adaptive over each fixed strategy.
//!
//! Run with: `cargo run --release --example adaptive_partitioning`

use wienna::config::{DesignPoint, SystemConfig};
use wienna::coordinator::{Coordinator, StrategyPolicy};
use wienna::cost::{evaluate_model, CostEngine};
use wienna::dataflow::Strategy;
use wienna::report::Table;
use wienna::workload::{resnet50::resnet50, unet::unet};

fn main() {
    let sys = SystemConfig::default();

    for model in [resnet50(64), unet(64)] {
        println!("### {} on WIENNA-C\n", model.name);
        let engine = CostEngine::for_design_point(&sys, DesignPoint::WIENNA_C);

        // Fixed-strategy baselines vs adaptive.
        let adaptive = evaluate_model(&engine, &model, None);
        let mut t = Table::new("policy comparison", &["policy", "MACs/cycle", "gain of adaptive"]);
        for s in Strategy::ALL {
            let fixed = evaluate_model(&engine, &model, Some(s));
            t.row(vec![
                s.label().to_string(),
                format!("{:.0}", fixed.macs_per_cycle),
                format!("+{:.1}%", (adaptive.macs_per_cycle / fixed.macs_per_cycle - 1.0) * 100.0),
            ]);
        }
        t.row(vec!["Adaptive".into(), format!("{:.0}", adaptive.macs_per_cycle), "-".into()]);
        print!("{}", t.render());

        // What the coordinator actually picks, per layer type.
        let coord = Coordinator::new(sys.clone(), DesignPoint::WIENNA_C, StrategyPolicy::Adaptive);
        let (_, sum) = coord.run_model(&model);
        let mut h = Table::new("strategy histogram (layer type x strategy -> #layers)", &["layer type", "strategy", "layers"]);
        for (ty, s, n) in &sum.strategy_histogram {
            h.row(vec![ty.clone(), s.clone(), n.to_string()]);
        }
        print!("{}\n", h.render());
    }
}
