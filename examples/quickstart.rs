//! Quickstart: evaluate one DNN on the four design points of the paper
//! and print the throughput/energy comparison (the Fig-7 headline in
//! miniature).
//!
//! Run with: `cargo run --release --example quickstart`

use wienna::config::{DesignPoint, SystemConfig};
use wienna::cost::{evaluate_model, CostEngine};
use wienna::report::Table;
use wienna::workload::resnet50::resnet50;

fn main() {
    // The paper's default package: 256 chiplets x 64 PEs, 13 MiB global
    // SRAM, 500 MHz (Table 4).
    let sys = SystemConfig::default();
    let model = resnet50(64);
    println!(
        "{}: {} layers, {:.1} GMACs\n",
        model.name,
        model.layers.len(),
        model.total_macs() as f64 / 1e9
    );

    let mut t = Table::new(
        "ResNet-50, adaptive partitioning, four design points",
        &["design", "MACs/cycle", "latency (ms)", "dist energy (mJ)", "vs Interposer-C"],
    );
    let base = {
        let e = CostEngine::for_design_point(&sys, DesignPoint::INTERPOSER_C);
        evaluate_model(&e, &model, None).macs_per_cycle
    };
    for dp in DesignPoint::ALL {
        let engine = CostEngine::for_design_point(&sys, dp);
        let cost = evaluate_model(&engine, &model, None);
        t.row(vec![
            dp.label(),
            format!("{:.0}", cost.macs_per_cycle),
            format!("{:.2}", cost.total_latency / wienna::config::CLOCK_HZ * 1e3),
            format!("{:.1}", cost.total_dist_energy_pj * 1e-9),
            format!("{:.2}x", cost.macs_per_cycle / base),
        ]);
    }
    print!("{}", t.render());

    println!("\nPer-layer strategy choices (first 10 layers, WIENNA-C):");
    let engine = CostEngine::for_design_point(&sys, DesignPoint::WIENNA_C);
    for layer in model.layers.iter().take(10) {
        let (s, c) = wienna::cost::best_strategy(&engine, layer);
        println!(
            "  {:<16} {:<9} -> {:<6} ({} chiplets, {:.0} MACs/cyc, {})",
            layer.name,
            c.layer_type.label(),
            s.label(),
            c.used_chiplets,
            c.macs_per_cycle,
            c.bottleneck().label()
        );
    }
}
