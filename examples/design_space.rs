//! Design-space exploration: the workload the paper's §5.2 Fig-8 study
//! motivates — how should a 16384-PE budget be chipletized, and how much
//! distribution bandwidth does each configuration need?
//!
//! Sweeps (a) chiplet count at fixed total PEs and (b) SRAM read
//! bandwidth, for both DNNs and all three partitioning strategies, and
//! reports the throughput-optimal configuration per workload.
//!
//! Run with: `cargo run --release --example design_space`

use wienna::config::{DesignPoint, SystemConfig};
use wienna::cost::{evaluate_model, CostEngine};
use wienna::dataflow::Strategy;
use wienna::report::Table;
use wienna::workload::{resnet50::resnet50, unet::unet};

fn main() {
    for model in [resnet50(64), unet(64)] {
        println!("### {}\n", model.name);

        // (a) Chiplet-count sweep at fixed 16384 PEs (Fig 8).
        let mut t = Table::new(
            "cluster-size sweep on WIENNA-C (MACs/cycle)",
            &["chiplets", "PEs/chiplet", "KP-CP", "NP-CP", "YP-XP", "adaptive"],
        );
        let mut best: (f64, u64) = (0.0, 0);
        for nc in [32u64, 64, 128, 256, 512, 1024] {
            let sys = SystemConfig::with_chiplets(nc);
            let e = CostEngine::for_design_point(&sys, DesignPoint::WIENNA_C);
            let per: Vec<f64> = Strategy::ALL
                .iter()
                .map(|&s| evaluate_model(&e, &model, Some(s)).macs_per_cycle)
                .collect();
            let adaptive = evaluate_model(&e, &model, None).macs_per_cycle;
            if adaptive > best.0 {
                best = (adaptive, nc);
            }
            t.row(vec![
                nc.to_string(),
                sys.pes_per_chiplet.to_string(),
                format!("{:.0}", per[0]),
                format!("{:.0}", per[1]),
                format!("{:.0}", per[2]),
                format!("{:.0}", adaptive),
            ]);
        }
        print!("{}", t.render());
        println!("best configuration: {} chiplets ({:.0} MACs/cycle)\n", best.1, best.0);

        // (b) Bandwidth requirement: smallest ideal-fabric BW reaching 95%
        // of the saturated throughput (the Fig-3 takeaway, condensed).
        let sys = SystemConfig::default();
        let saturated = evaluate_model(&CostEngine::ideal(&sys, 1048576.0), &model, None).macs_per_cycle;
        let mut need = None;
        for bw in [4.0, 8.0, 16.0, 32.0, 64.0, 128.0, 256.0, 512.0] {
            let th = evaluate_model(&CostEngine::ideal(&sys, bw), &model, None).macs_per_cycle;
            if th >= 0.95 * saturated {
                need = Some((bw, th));
                break;
            }
        }
        match need {
            Some((bw, th)) => println!(
                "bandwidth to saturate (95% of {:.0} MACs/cyc): {bw} B/cycle ({th:.0} MACs/cyc)\n",
                saturated
            ),
            None => println!("does not saturate below 512 B/cycle\n"),
        }
    }
}
