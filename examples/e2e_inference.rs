//! End-to-end driver: real inference through the full three-layer stack.
//!
//! This is the example that proves all layers compose:
//!
//! 1. `make artifacts` lowered the L1 Pallas kernels (inside the L2 JAX
//!    chiplet graph) to HLO text;
//! 2. the Rust runtime compiles them once on the PJRT CPU client;
//! 3. the coordinator partitions every layer of a small ResNet-style CNN
//!    across a simulated 16-chiplet package (adaptive strategy), streams
//!    the distribution schedule through the NoP models, dispatches the
//!    chiplets' GEMM tiles to the XLA executables, and collects outputs;
//! 4. the final activations are checked against an independent naive
//!    Rust convolution oracle.
//!
//! Run with: `make artifacts && cargo run --release --example e2e_inference`

use wienna::anyhow;
use wienna::config::{DesignPoint, SystemConfig};
use wienna::coordinator::{Coordinator, PackageExecutor, StrategyPolicy};
use wienna::coordinator::exec::Tensor;
use wienna::runtime::ExecutableCache;
use wienna::workload::tiny::tiny_cnn;

fn main() -> anyhow::Result<()> {
    let artifacts = std::env::args().nth(1).unwrap_or_else(|| "artifacts".to_string());
    let sys = SystemConfig { num_chiplets: 16, pes_per_chiplet: 64, ..Default::default() };

    let cache = std::sync::Arc::new(ExecutableCache::new(std::path::Path::new(&artifacts))?);
    println!("PJRT platform: {}", cache.platform());
    let n = cache.warm_up()?;
    println!("compiled {n} artifacts\n");

    let batch = 1u64;
    let model = tiny_cnn(batch);
    let coord = Coordinator::new(sys, DesignPoint::WIENNA_C, StrategyPolicy::Adaptive);
    let mut exec = PackageExecutor::new(coord, cache);

    let input = Tensor::from_fn(batch as usize, 16, 32, 32, |n, c, y, x| {
        ((n * 7 + c * 5 + y * 3 + x) % 17) as f32 * 0.05 - 0.4
    });
    let report = exec.run_model(&model, &input)?;

    println!("{:<12} {:<7} {:>6} {:>9} {:>14} {:>10}", "layer", "strat", "tiles", "chiplets", "model cycles", "wall (us)");
    for l in &report.layers {
        println!(
            "{:<12} {:<7} {:>6} {:>9} {:>14.0} {:>10.0}",
            l.layer_name, l.strategy, l.tiles_dispatched, l.chiplets_used, l.model_cycles, l.wall_us
        );
    }
    println!(
        "\n{}: {} outputs | {:.0} simulated cycles ({:.3} ms @500MHz) | {:.1} ms wall",
        report.model_name,
        report.output_len,
        report.total_model_cycles,
        report.total_model_cycles / wienna::config::CLOCK_HZ * 1e3,
        report.total_wall_ms
    );
    println!("max |XLA - oracle| = {:.3e}", report.max_abs_err);
    anyhow::ensure!(report.max_abs_err < 1e-3, "numerics mismatch");
    println!("NUMERICS OK — Pallas/JAX/XLA path agrees with the naive Rust oracle");
    Ok(())
}
