//! Integration tests for the fast-path cost engine (memo + worker pool)
//! and the `search::autosize` fleet auto-sizer.
//!
//! The memo/parallel properties are the load-bearing guarantees of this
//! crate's hot-path rework: caching and threading must change *nothing*
//! about the numbers, only how fast they arrive. The search tests close
//! the loop the ISSUE asks for: the fleet the auto-sizer returns is
//! re-verified by an independent `serve` replay.

use wienna::config::{DesignPoint, SystemConfig};
use wienna::cost::{
    evaluate_grid, evaluate_layer, evaluate_layer_uncached, evaluate_model, evaluate_model_par,
    CostEngine,
};
use wienna::dataflow::Strategy;
use wienna::search::{autosize, AutosizeConfig, CostModel, SearchSpace};
use wienna::serve::{
    ms_to_cycles, Fleet, MixEntry, ModelKind, RoutePolicy, ServeStats, Source, WorkloadMix,
};
use wienna::testutil::Rng;
use wienna::workload::{Layer, Model};

/// Draw a random but well-formed layer (mirrors `proptest_coordinator`).
fn arb_layer(rng: &mut Rng) -> Layer {
    match rng.range_u64(0, 2) {
        0 => {
            let r = *rng.pick(&[1u64, 3, 5]);
            let stride = *rng.pick(&[1u64, 2]);
            let yo = rng.range_u64(1, 28);
            let y = (yo - 1) * stride + r;
            Layer::conv(
                "p_conv",
                rng.range_u64(1, 16),
                rng.range_u64(1, 256),
                rng.range_u64(1, 256),
                y,
                y,
                r,
                r,
                stride,
            )
        }
        1 => Layer::fc("p_fc", rng.range_u64(1, 32), rng.range_u64(1, 2048), rng.range_u64(1, 2048)),
        _ => Layer::residual("p_res", rng.range_u64(1, 32), rng.range_u64(1, 256), rng.range_u64(1, 28), rng.range_u64(1, 28)),
    }
}

fn arb_sys(rng: &mut Rng) -> SystemConfig {
    SystemConfig {
        num_chiplets: *rng.pick(&[16u64, 64, 256]),
        pes_per_chiplet: *rng.pick(&[16u64, 64]),
        ..Default::default()
    }
}

/// Property: for random layers, strategies and packages, the memoized
/// path (first call populates, second call hits) returns bit-identical
/// numbers to a direct uncached evaluation.
#[test]
fn prop_memoized_layer_eval_is_exact() {
    let mut rng = Rng::new(0xC057);
    for iter in 0..200 {
        let layer = arb_layer(&mut rng);
        let sys = arb_sys(&mut rng);
        let dp = *rng.pick(&DesignPoint::ALL);
        let s = *rng.pick(&Strategy::ALL);
        let engine = CostEngine::for_design_point(&sys, dp);
        let direct = evaluate_layer_uncached(&engine, &layer, s);
        let first = evaluate_layer(&engine, &layer, s); // may populate
        let second = evaluate_layer(&engine, &layer, s); // must hit
        for (label, got) in [("first", &first), ("second", &second)] {
            assert_eq!(direct.latency, got.latency, "iter {iter} {label}");
            assert_eq!(direct.timeline, got.timeline, "iter {iter} {label}");
            assert_eq!(direct.macs, got.macs, "iter {iter} {label}");
            assert_eq!(direct.used_chiplets, got.used_chiplets, "iter {iter} {label}");
            assert_eq!(direct.dist_energy_pj, got.dist_energy_pj, "iter {iter} {label}");
            assert_eq!(direct.local_buffer_bytes, got.local_buffer_bytes, "iter {iter} {label}");
            assert_eq!(direct.layer_name, got.layer_name, "iter {iter} {label}");
        }
    }
}

/// Property: multi-threaded, memo-backed whole-model evaluation matches
/// the direct single-threaded, uncached result exactly — per layer, in
/// order, across random models and thread counts.
#[test]
fn prop_parallel_model_eval_is_exact() {
    let mut rng = Rng::new(0xBEEF);
    for iter in 0..25 {
        let layers: Vec<Layer> = (0..rng.range_u64(1, 12)).map(|_| arb_layer(&mut rng)).collect();
        let model = Model { name: format!("fuzz{iter}"), layers };
        let sys = arb_sys(&mut rng);
        let dp = *rng.pick(&DesignPoint::ALL);
        let engine = CostEngine::for_design_point(&sys, dp);
        // Uncached single-threaded reference, layer by layer (adaptive).
        let reference: Vec<f64> = model
            .layers
            .iter()
            .map(|l| {
                Strategy::ALL
                    .iter()
                    .map(|&s| evaluate_layer_uncached(&engine, l, s).latency)
                    .fold(f64::INFINITY, f64::min)
            })
            .collect();
        let threads = *rng.pick(&[1usize, 2, 4]);
        let par = evaluate_model_par(&engine, &model, None, threads);
        let seq = evaluate_model(&engine, &model, None);
        assert_eq!(seq.total_latency, par.total_latency, "iter {iter}");
        assert_eq!(par.layers.len(), model.layers.len());
        for (i, lc) in par.layers.iter().enumerate() {
            assert_eq!(lc.latency, reference[i], "iter {iter} layer {i}");
            assert_eq!(lc.layer_name, model.layers[i].name, "iter {iter} layer {i}");
        }
    }
}

/// The Fig-7 grid evaluated through the pool equals cell-by-cell direct
/// evaluation.
#[test]
fn grid_equals_direct_cells() {
    let sys = SystemConfig::default();
    let models = [wienna::workload::tiny::tiny_cnn(8)];
    let grid = evaluate_grid(&sys, &DesignPoint::ALL, &models, None, 4);
    for (i, dp) in DesignPoint::ALL.iter().enumerate() {
        let direct = evaluate_model(&CostEngine::for_design_point(&sys, *dp), &models[0], None);
        assert_eq!(grid[i].total_latency, direct.total_latency, "{}", dp.label());
        assert_eq!(grid[i].macs_per_cycle, direct.macs_per_cycle, "{}", dp.label());
    }
}

fn tiny_mix(slo_ms: f64) -> WorkloadMix {
    WorkloadMix::new(vec![MixEntry {
        kind: ModelKind::TinyCnn,
        weight: 1.0,
        slo_cycles: ms_to_cycles(slo_ms),
    }])
}

/// Small 8-point grid for search tests: 2 chiplet counts × 2 PE counts ×
/// 2 design points.
fn small_space() -> SearchSpace {
    SearchSpace {
        chiplet_counts: vec![64, 256],
        pes_per_chiplet: vec![32, 64],
        buffer_bytes: vec![512 * 1024],
        design_points: vec![DesignPoint::WIENNA_C, DesignPoint::INTERPOSER_C],
        max_width: 8,
    }
}

/// Pruned and exhaustive searches must agree on the optimum.
#[test]
fn pruned_search_equals_exhaustive_on_small_grid() {
    let mut cfg = AutosizeConfig::new(20.0, 2500.0, tiny_mix(20.0));
    cfg.horizon_ms = 15.0;
    cfg.threads = 2;
    let costs = CostModel::default();
    let pruned = autosize(&cfg, &small_space(), &costs);
    let exhaustive = autosize(&AutosizeConfig { prune: false, ..cfg }, &small_space(), &costs);
    let p = pruned.best.expect("pruned search found a fleet");
    let e = exhaustive.best.expect("exhaustive search found a fleet");
    assert_eq!(p.fleet_cost, e.fleet_cost, "pruning changed the optimal cost");
    assert_eq!(p.width, e.width, "pruning changed the optimal width");
    assert_eq!(pruned.explored, exhaustive.explored);
}

/// The acceptance loop: the auto-sized fleet, rebuilt from its returned
/// plan and driven by an independent trace *replay* at the target load,
/// meets the SLO it was sized for.
#[test]
fn autosized_fleet_survives_replay_verification() {
    let slo_ms = 20.0;
    let load_rps = 2500.0;
    let mut cfg = AutosizeConfig::new(slo_ms, load_rps, tiny_mix(slo_ms));
    cfg.horizon_ms = 15.0;
    cfg.threads = 2;
    let result = autosize(&cfg, &small_space(), &CostModel::default());
    assert!(result.explored >= 8);
    let best = result.best.expect("search must find a feasible fleet");
    assert!(best.p99_ms <= slo_ms);

    // Independent verification: a uniform-gap replay at the same offered
    // rate (different arrival process AND different seed than the search
    // probes used).
    let n_requests = 400;
    let gap_ms = 1000.0 / load_rps;
    let gaps: Vec<f64> = vec![gap_ms; n_requests];
    let mut fleet = Fleet::new(best.point.fleet(best.width), RoutePolicy::EarliestDeadline);
    let mut source = Source::replay(tiny_mix(slo_ms), &gaps, 7);
    let mut stats = ServeStats::new();
    fleet.run(&mut source, f64::INFINITY, &mut stats);
    assert_eq!(stats.completed(), n_requests as u64);
    assert!(
        stats.latency_ms(99.0) <= slo_ms,
        "replayed p99 {:.2} ms exceeds the {slo_ms} ms SLO the fleet was sized for",
        stats.latency_ms(99.0)
    );
}

/// Analytic sanity on the monotonicity motivating the pruner: on the
/// wireless designs, more chiplets never raises a model's (adaptive)
/// per-batch latency — broadcasts cost one transmission regardless of
/// fan-out, so growing the package only shrinks compute and collection.
/// (The interposer's replicated-unicast broadcasts amplify with fan-out,
/// which is why the pruner compares *measured* latency curves instead of
/// assuming monotonicity across the board.)
#[test]
fn more_chiplets_never_raise_batch_latency() {
    let model = wienna::workload::tiny::tiny_cnn(8);
    for dp in [DesignPoint::WIENNA_C, DesignPoint::WIENNA_A] {
        let mut prev = f64::INFINITY;
        for nc in [16u64, 64, 256] {
            let sys = SystemConfig { num_chiplets: nc, ..Default::default() };
            let engine = CostEngine::for_design_point(&sys, dp);
            let lat = evaluate_model(&engine, &model, None).total_latency;
            assert!(
                lat <= prev + 1e-6,
                "{}: latency rose from {prev:.0} to {lat:.0} cycles at {nc} chiplets",
                dp.label()
            );
            prev = lat;
        }
    }
}
