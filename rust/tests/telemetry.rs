//! Integration tests for `wienna::telemetry`:
//!
//! 1. **Span conservation**: every completed request carries a phase
//!    breakdown whose parts are non-negative and sum exactly (1e-9
//!    relative) to its end-to-end latency — including requests that were
//!    preempted-then-resumed and requests moved across shards by the
//!    work-stealing pass. Shed and preemption instants match the stats
//!    counters one for one.
//! 2. **Schema stability**: the metrics-JSON and Chrome-trace field
//!    names and order are pinned against a golden fixture (the
//!    determinism gate diffs runs of the same binary, so a renamed or
//!    reordered field would sail through it).

use wienna::assert_close;
use wienna::cluster::{
    AdmissionConfig, ClassMix, ClassSpec, Cluster, ClusterConfig, ClusterStats, ShedReason,
    SyncConfig, TrafficClass, NUM_CLASSES,
};
use wienna::config::DesignPoint;
use wienna::cost::MemoStats;
use wienna::serve::{
    ms_to_cycles, BatcherConfig, Fleet, MixEntry, ModelKind, PackageSpec, RoutePolicy, ServeStats,
    Source, WorkloadMix,
};
use wienna::telemetry::{
    chrome_trace, metrics_json, metrics_json_with, EpochSample, FlowRecord, PhaseBreakdown,
    PhaseTotals, PreemptSpan, QuantileSketch, Recorder, ShedSpan, SloEvent, SloEventKind,
    SloWindow, SpanRecord, Telemetry, TelemetryConfig, PHASES,
};
use wienna::workload::trace::synthetic_arrivals;

fn tiny_mix(slo_ms: f64) -> WorkloadMix {
    WorkloadMix::new(vec![MixEntry {
        kind: ModelKind::TinyCnn,
        weight: 1.0,
        slo_cycles: ms_to_cycles(slo_ms),
    }])
}

fn two_model_mix() -> WorkloadMix {
    WorkloadMix::new(vec![
        MixEntry { kind: ModelKind::TinyCnn, weight: 3.0, slo_cycles: ms_to_cycles(25.0) },
        MixEntry { kind: ModelKind::Mlp, weight: 1.0, slo_cycles: ms_to_cycles(50.0) },
    ])
}

/// The span-conservation property over one telemetry-enabled cluster run:
/// one span per completion (chronological), all phases non-negative and
/// summing to the end-to-end latency; shed/preempt instants match the
/// counters; the attribution sums and the registry agree with the stats.
fn check_cluster_telemetry(stats: &ClusterStats, label: &str) {
    let t = stats.telemetry.as_ref().expect("run had telemetry enabled");
    assert_eq!(t.log.spans.len() as u64, stats.serve.completed(), "{label}: one span per completion");
    assert_eq!(t.log.sheds.len() as u64, stats.serve.shed(), "{label}: one instant per shed");
    assert_eq!(
        t.log.preemptions.len() as u64,
        stats.preemptions,
        "{label}: one instant per preemption"
    );

    let mut prev = f64::NEG_INFINITY;
    for s in &t.log.spans {
        let p = &s.phases;
        for (phase, v) in PHASES.iter().zip([p.queue, p.dist, p.compute, p.collect, p.throttle]) {
            assert!(v >= 0.0, "{label}: negative {phase} phase on request {}", s.id);
        }
        assert!(
            s.arrival <= s.dispatched && s.dispatched <= s.completed,
            "{label}: span timestamps out of order on request {}",
            s.id
        );
        // The heart of the property: the five phases reconstruct the
        // end-to-end latency exactly, preempted/stolen or not.
        assert_close!(p.total(), s.completed - s.arrival);
        assert!(s.class.is_some(), "{label}: cluster spans carry their traffic class");
        assert!(s.completed >= prev, "{label}: span log is not chronological");
        prev = s.completed;
    }

    // Always-on attribution agrees with the opt-in span log.
    assert_eq!(
        stats.serve.attr.requests,
        stats.serve.completed(),
        "{label}: attribution folds every completion"
    );
    if stats.serve.completed() > 0 {
        let f = stats.serve.attr.fractions();
        assert_close!(f.iter().sum::<f64>(), 1.0);
    }
    let class_requests: u64 = stats.class_attr.iter().map(|a| a.requests).sum();
    assert_eq!(class_requests, stats.serve.completed(), "{label}: per-class attribution covers all");
    let class_total: f64 = stats.class_attr.iter().map(|a| a.total()).sum();
    assert_close!(class_total, stats.serve.attr.total());

    // The registry was filled at finalize / the epoch barriers.
    assert_eq!(t.metrics.latency_ms.count, stats.serve.completed(), "{label}: latency histogram");
    assert_eq!(t.metrics.batch_size.count, stats.serve.completed(), "{label}: batch histogram");
    assert_eq!(t.metrics.epochs.len() as u64, stats.epochs, "{label}: one sample per epoch");
    let last = t.metrics.epochs.last().expect("at least one epoch sample");
    assert_eq!(last.completed, stats.serve.completed(), "{label}: final sample sees the drain");
    assert_eq!(last.steals, stats.steals, "{label}: final sample sees every steal");
}

/// Preemption regime: one package, best-effort-dominant traffic with a
/// sliver of tight-deadline interactive arrivals, deep overload. Swept
/// over seeds and SLO widths so at least one run lands in the window
/// where preempting rescues the deadline — the conservation property
/// must then hold for the preempted-then-resumed spans (their queue
/// phase absorbs the aborted service).
#[test]
fn preempted_spans_conserve_latency() {
    let mut total_preemptions = 0u64;
    let mut total_completed = 0u64;
    for seed in [1u64, 2, 3] {
        for slo_ms in [1.0f64, 3.0, 8.0] {
            let cluster = Cluster::new(
                PackageSpec::homogeneous(1, DesignPoint::WIENNA_C),
                ClusterConfig {
                    shards: 1,
                    threads: 2,
                    classes: ClassMix::new(vec![
                        ClassSpec {
                            class: TrafficClass::BestEffort,
                            weight: 20.0,
                            slo_scale: f64::INFINITY,
                            deadline_shed: false,
                        },
                        ClassSpec {
                            class: TrafficClass::Interactive,
                            weight: 1.0,
                            slo_scale: 1.0,
                            deadline_shed: false,
                        },
                    ]),
                    admission: AdmissionConfig::admit_all(),
                    preemption: true,
                    telemetry: TelemetryConfig::enabled(),
                    ..Default::default()
                },
            );
            let mut source = Source::poisson(tiny_mix(slo_ms), 12_000.0, seed);
            let stats = cluster.run(&mut source, ms_to_cycles(10.0));
            check_cluster_telemetry(&stats, &format!("preempt regime seed {seed} slo {slo_ms}"));
            total_preemptions += stats.preemptions;
            total_completed += stats.serve.completed();
        }
    }
    assert!(total_completed > 0, "the sweep served traffic");
    assert!(
        total_preemptions > 0,
        "no sweep point preempted — the preempted-span property went unexercised"
    );
}

/// Steal regime (mirrors the hot-stripe integration test, which proves
/// this exact configuration steals): stolen spans — whose queue phase
/// includes the barrier hand-off wait — still conserve latency, and the
/// final epoch sample accounts for every move.
#[test]
fn stolen_spans_conserve_latency() {
    let cluster = Cluster::new(
        PackageSpec::homogeneous(4, DesignPoint::WIENNA_C),
        ClusterConfig {
            shards: 4,
            threads: 2,
            classes: ClassMix::single(TrafficClass::Interactive, 1.0, false),
            admission: AdmissionConfig::admit_all(),
            preemption: false,
            batcher: BatcherConfig { max_batch: 8, candidates: vec![1, 2, 4, 8] },
            sync: SyncConfig { steal: true, epoch_cycles: ms_to_cycles(0.1), ..Default::default() },
            telemetry: TelemetryConfig::enabled(),
            ..Default::default()
        },
    );
    let counts: Vec<usize> = (0..64).map(|i| if i % 4 == 0 { 40 } else { 1 }).collect();
    let traces = synthetic_arrivals(&counts, 0.02, 0.5, 9);
    let mut source = Source::client_trace(tiny_mix(25.0), &traces, 9);
    let stats = cluster.run(&mut source, f64::INFINITY);
    assert!(stats.steals > 0, "the hot stripe must donate work");
    check_cluster_telemetry(&stats, "steal regime");
}

/// Shed regime: overload against a cap-4 queue. Every shed leaves an
/// instant whose reason tallies with the stats counters.
#[test]
fn shed_instants_match_the_shed_counters() {
    let cluster = Cluster::new(
        PackageSpec::homogeneous(2, DesignPoint::WIENNA_C),
        ClusterConfig {
            shards: 2,
            threads: 2,
            admission: AdmissionConfig { queue_cap: Some(4), shed_late: true },
            telemetry: TelemetryConfig::enabled(),
            ..Default::default()
        },
    );
    let mut source = Source::poisson(two_model_mix(), 20_000.0, 5);
    let stats = cluster.run(&mut source, ms_to_cycles(10.0));
    check_cluster_telemetry(&stats, "shed regime");
    assert!(stats.serve.shed() > 0, "overload against a cap-4 queue must shed");
    let t = stats.telemetry.as_ref().unwrap();
    let queue_full = t
        .log
        .sheds
        .iter()
        .filter(|s| matches!(s.reason, ShedReason::QueueFull))
        .count() as u64;
    let deadline = t
        .log
        .sheds
        .iter()
        .filter(|s| matches!(s.reason, ShedReason::DeadlineHopeless))
        .count() as u64;
    assert_eq!(queue_full, stats.shed_queue_full, "queue-full instants tally");
    assert_eq!(deadline, stats.shed_deadline, "deadline instants tally");
    for s in &t.log.sheds {
        assert!(s.cycle >= s.arrival, "shed instant precedes the request's arrival");
    }
}

/// The plain serve fleet records the same property through its own
/// recorder hook — no classes, shard 0, and the per-package attribution
/// sums to the fleet total.
#[test]
fn serve_fleet_spans_conserve_latency() {
    let mut fleet = Fleet::new(
        PackageSpec::homogeneous(2, DesignPoint::WIENNA_C),
        RoutePolicy::EarliestDeadline,
    );
    fleet.recorder = Recorder::new(true);
    let mut stats = ServeStats::new();
    let mut source = Source::poisson(two_model_mix(), 3000.0, 11);
    fleet.run(&mut source, ms_to_cycles(20.0), &mut stats);
    assert!(stats.completed() > 0, "the run served traffic");

    let mut tele = Telemetry { log: fleet.recorder.take_log(), ..Default::default() };
    tele.finish();
    assert_eq!(tele.log.spans.len() as u64, stats.completed(), "one span per completion");
    for s in &tele.log.spans {
        assert!(s.class.is_none(), "plain serve spans carry no traffic class");
        let p = &s.phases;
        for v in [p.queue, p.dist, p.compute, p.collect, p.throttle] {
            assert!(v >= 0.0, "negative phase on request {}", s.id);
        }
        assert_close!(p.total(), s.completed - s.arrival);
    }
    assert_eq!(stats.attr.requests, stats.completed());
    let f = stats.attr.fractions();
    assert_close!(f.iter().sum::<f64>(), 1.0);
    assert_eq!(tele.metrics.latency_ms.count, stats.completed());
    assert_eq!(tele.metrics.batch_size.count, stats.completed());
    let package_total: f64 = fleet.packages.iter().map(|p| p.attr.total()).sum();
    assert_close!(package_total, stats.attr.total());
}

/// Golden-file regression (schema satellite): the metrics-JSON and
/// Chrome-trace field names and order match the checked-in fixture,
/// mirroring `cluster_stats_schema.golden`. Built from a synthetic
/// `Telemetry` so every event kind (span, shed, preemption, epoch
/// counter, memo block) is guaranteed present. If the schema changes on
/// purpose, regenerate the fixture to match the serializers.
#[test]
fn telemetry_schema_matches_the_golden_fixture() {
    // Keys of one single-line JSON object: the `"`-delimited segments
    // immediately followed by a `:`, first occurrence only (nested args
    // repeat keys like "name"/"count").
    fn object_keys(line: &str) -> Vec<String> {
        let parts: Vec<&str> = line.split('"').collect();
        let mut keys = Vec::new();
        let mut i = 1;
        while i < parts.len() {
            if parts.get(i + 1).is_some_and(|s| s.trim_start().starts_with(':')) {
                let key = parts[i].to_string();
                if !keys.contains(&key) {
                    keys.push(key);
                }
            }
            i += 2;
        }
        keys
    }
    fn keys_of_first(hay: &str, needle: &str) -> Vec<String> {
        let line = hay
            .lines()
            .find(|l| l.contains(needle))
            .unwrap_or_else(|| panic!("no line containing {needle:?}"));
        object_keys(line)
    }

    let mut t = Telemetry::default();
    t.log.spans.push(SpanRecord {
        id: 7,
        kind: ModelKind::TinyCnn,
        class: Some(TrafficClass::Interactive),
        shard: 0,
        package: 0,
        batch: 2,
        arrival: 0.0,
        dispatched: 1000.0,
        completed: 3000.0,
        phases: PhaseBreakdown { queue: 1000.0, compute: 2000.0, ..Default::default() },
    });
    t.log.sheds.push(ShedSpan {
        id: 9,
        kind: ModelKind::Mlp,
        class: Some(TrafficClass::Batch),
        shard: 0,
        arrival: 10.0,
        cycle: 20.0,
        reason: ShedReason::QueueFull,
    });
    t.log.preemptions.push(PreemptSpan { cycle: 50.0, shard: 0, package: 1, batch: 4 });
    t.log.flows.push(FlowRecord {
        id: 13,
        class: TrafficClass::BestEffort,
        from_shard: 0,
        to_shard: 1,
        cycle: 60.0,
    });
    t.metrics.epochs.push(EpochSample {
        epoch: 0,
        cycle: 4000.0,
        queued: 3,
        mac_occupancy_by_pkg: vec![0.5],
        token_wait_by_pkg: vec![7.0],
        ..Default::default()
    });
    t.metrics.slo_events.push(SloEvent {
        epoch: 0,
        cycle: 4000.0,
        class: TrafficClass::Interactive,
        window: SloWindow::Fast,
        kind: SloEventKind::Raise,
        burn_rate: 8.5,
    });
    t.finish();
    let mut attr = PhaseTotals::default();
    attr.record(&t.log.spans[0].phases);
    let class_attr = [attr; NUM_CLASSES];
    let memo = MemoStats { hits: 4, misses: 1, entries: 1, evictions: 0, capacity: 64 };
    // A bounded-stats artifact also carries ε-bounded quantile sketches;
    // pin that object's shape too.
    let mut sk = QuantileSketch::new(0.01);
    sk.record(2000.0);
    let sketches = vec![("latency_ms".to_string(), &sk)];

    let metrics = metrics_json_with(&t, &attr, Some(&class_attr), Some(memo), &sketches);
    let trace = chrome_trace(&t);

    let mut schema = String::new();
    for line in metrics.lines() {
        if let Some(rest) = line.strip_prefix("  \"") {
            let key = rest.split('"').next().expect("top-level key closes its quote");
            schema.push_str(&format!("metrics top {key}\n"));
        }
    }
    for key in keys_of_first(&metrics, "{ \"class\"") {
        schema.push_str(&format!("metrics class {key}\n"));
    }
    for key in keys_of_first(&metrics, "{ \"name\"") {
        schema.push_str(&format!("metrics hist {key}\n"));
    }
    // Sketch entries also open with `{ "name"`, but only they carry
    // "sub_bits" — that selects the first sketch object.
    for key in keys_of_first(&metrics, "\"sub_bits\"") {
        schema.push_str(&format!("metrics sketch {key}\n"));
    }
    for key in keys_of_first(&metrics, "{ \"epoch\"") {
        schema.push_str(&format!("metrics epoch {key}\n"));
    }
    // SLO events share the epochs' line shape; "window" only appears in
    // event objects, so it selects the first one.
    for key in keys_of_first(&metrics, "\"window\"") {
        schema.push_str(&format!("metrics slo_event {key}\n"));
    }
    for line in metrics.lines() {
        if let Some(rest) = line.strip_prefix("    \"") {
            let key = rest.split('"').next().expect("memo key closes its quote");
            schema.push_str(&format!("metrics memo {key}\n"));
        }
    }
    for (section, needle) in [
        ("meta", "\"ph\":\"M\""),
        ("span", "\"ph\":\"X\""),
        ("shed", "\"cat\":\"admission\""),
        ("preempt", "\"cat\":\"scheduler\""),
        ("flow_s", "\"ph\":\"s\""),
        ("flow_f", "\"ph\":\"f\""),
        ("counter", "\"ph\":\"C\""),
    ] {
        for key in keys_of_first(&trace, needle) {
            schema.push_str(&format!("trace {section} {key}\n"));
        }
    }

    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("rust/testdata/telemetry_schema.golden");
    let fixture = std::fs::read_to_string(&path).expect("golden schema fixture exists");
    let pinned: String = fixture
        .lines()
        .filter(|l| !l.trim().is_empty() && !l.starts_with('#'))
        .map(|l| format!("{l}\n"))
        .collect();
    assert_eq!(
        schema, pinned,
        "telemetry schema drifted from {path:?} — if the change is deliberate, update the fixture"
    );
}

// ---------------------------------------------------------------------------
// Bounded-memory stats and the streaming metrics artifact (PR 8).
// ---------------------------------------------------------------------------

use wienna::telemetry::{stream_to_metrics_v1, MetricsStreamWriter, NonBlockingLineSink};

/// A saturated two-shard cluster with tight SLOs — hot enough that the
/// burn-rate monitor has something to page about — parameterized over
/// memory mode and worker-thread count. Load is pegged at 2.5× the
/// fleet's own capacity estimate so the overload (and the violations it
/// causes) survive cost-model retuning.
fn hot_cluster(telemetry: TelemetryConfig, threads: usize, seed: u64) -> (Cluster, Source) {
    let mix = tiny_mix(1.0);
    let mut probe = Fleet::new(
        PackageSpec::homogeneous(2, DesignPoint::WIENNA_C),
        RoutePolicy::EarliestDeadline,
    );
    let rate = probe.estimate_capacity_rps(&mix, 8) * 2.5;
    let cluster = Cluster::new(
        PackageSpec::homogeneous(2, DesignPoint::WIENNA_C),
        ClusterConfig {
            shards: 2,
            threads,
            classes: ClassMix::single(TrafficClass::Interactive, 1.0, false),
            admission: AdmissionConfig::admit_all(),
            telemetry,
            ..Default::default()
        },
    );
    let source = Source::poisson(mix, rate, seed);
    (cluster, source)
}

/// Tentpole (a): `--bounded-stats` percentiles come off the log-bucketed
/// histograms — the per-request latency `Vec` is never grown — and land
/// within the documented one-bucket error bound (est/exact in (1/2, 2])
/// of the exact-oracle run, across a seeded sweep. Counters, epoch
/// counts, and SLO alert totals are mode-independent.
#[test]
fn bounded_percentiles_track_the_exact_oracle() {
    for seed in [3u64, 17, 40] {
        let (cluster, mut source) = hot_cluster(TelemetryConfig::enabled(), 2, seed);
        let exact = cluster.run(&mut source, ms_to_cycles(8.0));
        let (cluster, mut source) = hot_cluster(TelemetryConfig::bounded(), 2, seed);
        let bounded = cluster.run(&mut source, ms_to_cycles(8.0));

        assert!(!exact.is_bounded() && bounded.is_bounded());
        assert_eq!(bounded.serve.exact_samples(), 0, "seed {seed}: bounded mode grew a latency Vec");
        assert!(exact.serve.exact_samples() > 0, "seed {seed}: oracle run kept exact samples");

        // The simulation itself is identical — only the recorder differs.
        assert_eq!(exact.serve.completed(), bounded.serve.completed(), "seed {seed}");
        assert_eq!(exact.serve.shed(), bounded.serve.shed(), "seed {seed}");
        assert_eq!(exact.epochs, bounded.epochs, "seed {seed}");
        assert_eq!(exact.slo_alert_counts(), bounded.slo_alert_counts(), "seed {seed}");
        assert!(exact.serve.completed() > 50, "seed {seed}: the regime must serve real traffic");

        for p in [50.0, 95.0, 99.0] {
            let e = exact.serve.latency_ms(p);
            let b = bounded.serve.latency_ms(p);
            let ratio = b / e;
            assert!(
                ratio > 0.5 && ratio <= 2.0,
                "seed {seed} p{p}: histogram estimate {b} vs exact {e} (ratio {ratio}) \
                 escapes the one-bucket bound"
            );
        }

        // Bounded mode still fills the telemetry histograms — via the
        // deterministic event fold instead of the span log.
        let t = bounded.telemetry.as_ref().expect("bounded run arms the registry");
        assert!(t.bounded && t.log.spans.is_empty(), "seed {seed}: bounded mode keeps no spans");
        assert_eq!(t.metrics.latency_ms.count, bounded.serve.completed(), "seed {seed}");
    }
}

/// Tentpole (b): streaming a run through `MetricsStreamWriter` and
/// reconstructing with `stream_to_metrics_v1` reproduces the buffered
/// `metrics_json` artifact byte for byte — and the stream itself is
/// byte-identical at 1, 2, and 4 worker threads.
#[test]
fn streamed_cluster_run_reconstructs_the_buffered_artifact() {
    let (cluster, mut source) = hot_cluster(TelemetryConfig::enabled(), 2, 7);
    let buffered_stats = cluster.run(&mut source, ms_to_cycles(8.0));
    let buffered = buffered_stats.metrics_json(None);

    let mut streams = Vec::new();
    for threads in [1usize, 2, 4] {
        let (cluster, mut source) = hot_cluster(TelemetryConfig::enabled(), threads, 7);
        let mut sink: Vec<u8> = Vec::new();
        let mut w = MetricsStreamWriter::new(&mut sink);
        let stats = cluster.run_streaming(&mut source, ms_to_cycles(8.0), &mut w);
        w.write_summary(&stats.metrics_json_summary(None));
        w.finish().expect("Vec sink never errors");
        streams.push(String::from_utf8(sink).expect("stream is UTF-8"));
    }
    assert_eq!(streams[0], streams[1], "stream differs between 1 and 2 threads");
    assert_eq!(streams[0], streams[2], "stream differs between 1 and 4 threads");

    let rebuilt = stream_to_metrics_v1(&streams[0]).expect("well-formed stream reconstructs");
    assert_eq!(rebuilt, buffered, "reconstructed stream != buffered artifact");
}

/// The burn-rate monitor pages on this regime (tight SLO under sustained
/// overload), stamps events with barrier cycles, and produces the
/// identical alert timeline at any thread count — single-threaded
/// barrier evaluation is what makes that possible.
#[test]
fn slo_monitor_pages_deterministically_under_overload() {
    let mut timelines = Vec::new();
    for threads in [1usize, 2, 4] {
        let (cluster, mut source) = hot_cluster(TelemetryConfig::enabled(), threads, 21);
        let stats = cluster.run(&mut source, ms_to_cycles(8.0));
        let t = stats.telemetry.as_ref().unwrap();
        assert!(
            t.metrics.slo_events.iter().any(|e| e.kind == SloEventKind::Raise),
            "a 2.5x-overloaded 1 ms-SLO run must raise at least one alert"
        );
        let (raised, active) = stats.slo_alert_counts();
        assert_eq!(
            raised,
            t.metrics.slo_events.iter().filter(|e| e.kind == SloEventKind::Raise).count() as u64
        );
        assert!(active <= raised);
        let epoch_cycles: Vec<f64> = t.metrics.epochs.iter().map(|s| s.cycle).collect();
        for e in &t.metrics.slo_events {
            assert!(
                epoch_cycles.contains(&e.cycle),
                "event at cycle {} was not stamped at an epoch barrier",
                e.cycle
            );
        }
        timelines.push(format!("{:?}", t.metrics.slo_events));
    }
    assert_eq!(timelines[0], timelines[1], "alert timeline differs between 1 and 2 threads");
    assert_eq!(timelines[0], timelines[2], "alert timeline differs between 1 and 4 threads");
}

/// Tentpole (PR 9): the sketch resolution knob (`--quantile-error EPS`)
/// holds end to end — cluster-run quantiles from sketch-backed bounded
/// runs land within EPS (relative) of the exact-oracle run at every
/// swept resolution, with the per-shard sketches merged across epoch
/// barriers along the way.
#[test]
fn sketch_resolution_knob_bounds_the_quantile_error_end_to_end() {
    let (cluster, mut source) = hot_cluster(TelemetryConfig::enabled(), 2, 13);
    let exact = cluster.run(&mut source, ms_to_cycles(8.0));
    assert!(exact.serve.completed() > 50, "the regime must serve real traffic");
    for eps in [0.05f64, 0.01, 0.005] {
        let (cluster, mut source) = hot_cluster(TelemetryConfig::bounded_with(eps), 2, 13);
        let bounded = cluster.run(&mut source, ms_to_cycles(8.0));
        assert!(bounded.is_bounded(), "eps {eps}: run must be sketch-backed");
        assert_eq!(
            bounded.serve.exact_samples(),
            0,
            "eps {eps}: bounded mode grew a latency Vec"
        );
        assert_eq!(
            exact.serve.completed(),
            bounded.serve.completed(),
            "eps {eps}: the simulation itself diverged"
        );
        for p in [50.0, 90.0, 95.0, 99.0, 100.0] {
            let e = exact.serve.latency_ms(p);
            let b = bounded.serve.latency_ms(p);
            let rel = (b - e).abs() / e;
            assert!(
                rel <= eps + 1e-9,
                "eps {eps} p{p}: sketch estimate {b} vs exact {e} escapes the \
                 configured bound (relative error {rel})"
            );
        }
    }
}

/// Sketch-backed bounded stats are byte-identical across worker-thread
/// counts at a non-default resolution: the per-shard sketches merge as
/// integer bucket counts in shard-id order at each barrier, so neither
/// the stats JSON nor the metrics artifact can see the thread count.
#[test]
fn bounded_sketch_artifacts_are_byte_identical_across_threads() {
    let mut artifacts = Vec::new();
    for threads in [1usize, 2, 4] {
        let (cluster, mut source) = hot_cluster(TelemetryConfig::bounded_with(0.02), threads, 7);
        let stats = cluster.run(&mut source, ms_to_cycles(8.0));
        assert!(stats.is_bounded());
        artifacts.push((stats.to_json(), stats.metrics_json(None)));
    }
    assert_eq!(artifacts[0], artifacts[1], "bounded artifacts differ between 1 and 2 threads");
    assert_eq!(artifacts[0], artifacts[2], "bounded artifacts differ between 1 and 4 threads");
}

/// Tentpole (PR 9, live export): streaming a run through a non-blocking
/// sink over a real loopback TCP socket delivers exactly the bytes a
/// `Vec` sink records for the same seeded run — nothing reordered,
/// nothing dropped, nothing perturbed by the socket's backpressure.
#[test]
fn tcp_streamed_metrics_match_the_in_memory_stream_byte_for_byte() {
    use std::io::Read as _;

    let (cluster, mut source) = hot_cluster(TelemetryConfig::enabled(), 2, 7);
    let mut reference: Vec<u8> = Vec::new();
    {
        let mut w = MetricsStreamWriter::new(&mut reference);
        let stats = cluster.run_streaming(&mut source, ms_to_cycles(8.0), &mut w);
        w.write_summary(&stats.metrics_json_summary(None));
        w.finish().expect("Vec sink never errors");
    }

    let listener = std::net::TcpListener::bind("127.0.0.1:0").expect("bind an ephemeral port");
    let addr = listener.local_addr().expect("bound socket has an address");
    let reader = std::thread::spawn(move || {
        let (mut conn, _) = listener.accept().expect("simulator connects");
        let mut buf = Vec::new();
        conn.read_to_end(&mut buf).expect("drain the stream to EOF");
        buf
    });

    let conn = std::net::TcpStream::connect(addr).expect("connect to the loopback listener");
    let _ = conn.set_nodelay(true);
    conn.set_nonblocking(true).expect("non-blocking export socket");
    let mut sink = NonBlockingLineSink::new(conn, 4 << 20);
    let (cluster, mut source) = hot_cluster(TelemetryConfig::enabled(), 2, 7);
    {
        let mut w = MetricsStreamWriter::new(&mut sink);
        let stats = cluster.run_streaming(&mut source, ms_to_cycles(8.0), &mut w);
        w.write_summary(&stats.metrics_json_summary(None));
        w.finish().expect("non-blocking sink absorbs socket errors");
    }
    let (conn, dropped) = sink.finish(std::time::Duration::from_secs(30));
    drop(conn); // close the write half so the reader sees EOF

    let received = reader.join().expect("reader thread");
    assert_eq!(dropped, 0, "a loopback reader keeps up — nothing may drop");
    assert_eq!(
        received, reference,
        "bytes received over TCP differ from the in-memory stream"
    );
}

/// Satellite 1: the per-package gauges ride every epoch sample — one
/// entry per package in shard-major order, occupancies and token waits
/// finite and non-negative, and a saturated run shows nonzero occupancy
/// at the final barrier.
#[test]
fn epoch_samples_carry_per_package_gauges() {
    let (cluster, mut source) = hot_cluster(TelemetryConfig::enabled(), 2, 5);
    let stats = cluster.run(&mut source, ms_to_cycles(8.0));
    let t = stats.telemetry.as_ref().unwrap();
    let packages: usize = t.metrics.epochs.last().unwrap().mac_occupancy_by_pkg.len();
    assert!(packages >= 2, "two shards of WIENNA_C expose at least two packages");
    for s in &t.metrics.epochs {
        assert_eq!(s.mac_occupancy_by_pkg.len(), packages, "gauge arity changed mid-run");
        assert_eq!(s.token_wait_by_pkg.len(), packages, "gauge arity changed mid-run");
        // A batch's dist cycles are booked in full at dispatch, so the
        // gauge can transiently overshoot 1.0 right after a barrier —
        // but never by more than one batch's worth.
        for &o in &s.mac_occupancy_by_pkg {
            assert!(o >= 0.0 && o.is_finite(), "occupancy {o} is not a finite gauge");
        }
        for &w in &s.token_wait_by_pkg {
            assert!(w >= 0.0 && w.is_finite());
        }
    }
    let last = t.metrics.epochs.last().unwrap();
    assert!(
        last.mac_occupancy_by_pkg.iter().any(|&o| o > 0.0),
        "a saturated run must show nonzero MAC occupancy somewhere"
    );
}
