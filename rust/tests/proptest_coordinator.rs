//! Property-based tests over coordinator invariants (routing, batching,
//! state), driven by the crate's deterministic SplitMix64 generator.
//!
//! Each property runs over hundreds of randomly drawn (layer, package,
//! strategy) configurations; failures print the seed for reproduction.

use wienna::config::{DesignPoint, SystemConfig};
use wienna::coordinator::{Coordinator, StrategyPolicy};
use wienna::cost::{evaluate_layer, CostEngine};
use wienna::dataflow::{partition, ChipletArch, MapPolicy, Strategy};
use wienna::nop::sim::MeshSim;
use wienna::testutil::Rng;
use wienna::workload::{Layer, OpKind};

/// Draw a random but well-formed layer.
fn arb_layer(rng: &mut Rng) -> Layer {
    match rng.range_u64(0, 3) {
        0 => {
            // Conv2D with padded input extents.
            let r = *rng.pick(&[1u64, 3, 5, 7]);
            let stride = *rng.pick(&[1u64, 2]);
            let yo = rng.range_u64(1, 56);
            let y = (yo - 1) * stride + r;
            Layer::conv(
                "p_conv",
                rng.range_u64(1, 64),
                rng.range_u64(1, 512),
                rng.range_u64(1, 512),
                y,
                y,
                r,
                r,
                stride,
            )
        }
        1 => Layer::fc("p_fc", rng.range_u64(1, 64), rng.range_u64(1, 4096), rng.range_u64(1, 4096)),
        2 => Layer::residual("p_res", rng.range_u64(1, 64), rng.range_u64(1, 512), rng.range_u64(1, 56), rng.range_u64(1, 56)),
        _ => Layer::upconv(
            "p_up",
            rng.range_u64(1, 8),
            rng.range_u64(1, 256),
            rng.range_u64(1, 256),
            rng.range_u64(2, 32),
            rng.range_u64(2, 32),
            2,
            2,
            2,
        ),
    }
}

fn arb_sys(rng: &mut Rng) -> SystemConfig {
    let nc = *rng.pick(&[4u64, 16, 64, 256, 1024]);
    SystemConfig {
        num_chiplets: nc,
        pes_per_chiplet: *rng.pick(&[16u64, 64, 256]),
        ..Default::default()
    }
}

#[test]
fn prop_partition_conserves_work_and_bytes() {
    let mut rng = Rng::new(0xC0FFEE);
    for iter in 0..500 {
        let layer = arb_layer(&mut rng);
        let sys = arb_sys(&mut rng);
        let s = *rng.pick(&Strategy::ALL);
        let p = partition::partition(&layer, s, sys.num_chiplets, sys.bytes_per_elem);

        // Work conservation: used chiplets x per-chiplet sub-problem must
        // cover the layer's MACs.
        assert!(
            p.used_chiplets * p.sub_layer.macs() >= layer.macs(),
            "iter {iter}: {s} on {layer:?}: {} x {} < {}",
            p.used_chiplets,
            p.sub_layer.macs(),
            layer.macs()
        );
        // Never more chiplets than available or than parallelism.
        assert!(p.used_chiplets >= 1 && p.used_chiplets <= sys.num_chiplets);
        // Traffic sanity: delivered >= sent >= 0, multicast factor >= 1.
        for t in &p.traffic {
            assert!(t.avg_dests >= 1.0 - 1e-9, "iter {iter}");
            assert!(t.avg_dests <= sys.num_chiplets as f64 + 1e-9, "iter {iter}");
        }
        assert!(p.multicast_factor() >= 1.0 - 1e-9, "iter {iter}");
        // The partitioned dims never exceed the original.
        assert!(p.sub_layer.k <= layer.k && p.sub_layer.n <= layer.n);
    }
}

#[test]
fn prop_intra_mapping_bounds() {
    let mut rng = Rng::new(0xBEEF);
    for iter in 0..500 {
        let layer = arb_layer(&mut rng);
        let pes = *rng.pick(&[16u64, 64, 128, 256]);
        let arch = *rng.pick(&[ChipletArch::NvdlaLike, ChipletArch::ShidiannaoLike]);
        let m = wienna::dataflow::intra::map_layer(&layer, arch, pes, MapPolicy::Flexible, 1);
        // 1 MAC/PE/cycle is a hard roof.
        assert!(m.cycles * pes >= layer.macs(), "iter {iter}: {arch:?} {layer:?}");
        assert!(m.utilization > 0.0 && m.utilization <= 1.0 + 1e-9, "iter {iter}: util {}", m.utilization);
        assert_eq!(m.d0 * m.d1, if layer.op == OpKind::ResidualAdd { pes } else { pes }, "iter {iter}");
    }
}

#[test]
fn prop_latency_monotone_in_bandwidth() {
    // More distribution bandwidth never hurts.
    let mut rng = Rng::new(0x5EED);
    let sys = SystemConfig::default();
    for iter in 0..200 {
        let layer = arb_layer(&mut rng);
        let s = *rng.pick(&Strategy::ALL);
        let lo = evaluate_layer(&CostEngine::ideal(&sys, 8.0), &layer, s).latency;
        let hi = evaluate_layer(&CostEngine::ideal(&sys, 64.0), &layer, s).latency;
        assert!(hi <= lo + 1e-6, "iter {iter}: {s} bw8 {lo} < bw64 {hi}");
    }
}

#[test]
fn prop_schedule_bytes_match_plan() {
    // The coordinator's concrete transfer lists carry exactly the plan's
    // payload, for every strategy and random layer.
    let mut rng = Rng::new(0xACE);
    for iter in 0..200 {
        let layer = arb_layer(&mut rng);
        let sys = arb_sys(&mut rng);
        let policy = match rng.range_u64(0, 3) {
            0 => StrategyPolicy::Fixed(Strategy::KpCp),
            1 => StrategyPolicy::Fixed(Strategy::NpCp),
            2 => StrategyPolicy::Fixed(Strategy::YpXp),
            _ => StrategyPolicy::Adaptive,
        };
        let coord = Coordinator::new(sys, DesignPoint::WIENNA_C, policy);
        let sched = coord.schedule_layer(&layer);
        assert_eq!(sched.scheduled_bytes(), sched.plan.sent_bytes(), "iter {iter}: {layer:?}");
        // Every transfer destination is a valid used chiplet node.
        let side = coord.sys.mesh_side() as u32;
        for t in sched.preload.iter().chain(sched.stream.iter()) {
            assert!(!t.dests.is_empty(), "iter {iter}");
            for d in &t.dests {
                assert!(d.row < side && d.col < side, "iter {iter}: dest {d:?} outside {side}x{side}");
            }
        }
    }
}

#[test]
fn prop_sim_never_faster_than_serialization() {
    // The cycle-level sim can never beat the injection-port serialization
    // bound: sum of (bytes x copies) / link_bw.
    let mut rng = Rng::new(0xF00D);
    for iter in 0..100 {
        let layer = arb_layer(&mut rng);
        let sys = SystemConfig { num_chiplets: 16, pes_per_chiplet: 64, ..Default::default() };
        let coord = Coordinator::new(sys, DesignPoint::INTERPOSER_A, StrategyPolicy::Adaptive);
        let sched = coord.schedule_layer(&layer);
        let sim = MeshSim::new(4, 16.0);
        let all: Vec<_> = sched.preload.iter().chain(sched.stream.iter()).cloned().collect();
        if all.is_empty() {
            continue;
        }
        let report = sim.run_distribution(&all);
        let bound: f64 = all.iter().map(|t| (t.bytes * t.dests.len() as u64) as f64 / 16.0).sum();
        assert!(
            report.makespan >= bound - 1e-6,
            "iter {iter}: sim {} < serialization bound {bound}",
            report.makespan
        );
    }
}

#[test]
fn prop_reuse_invariants() {
    // Algorithmic reuse is >= 1 for every tensor a layer touches, and
    // spatial multicast never exceeds the used-chiplet count.
    use wienna::dataflow::reuse;
    let mut rng = Rng::new(0x5E1FE);
    for iter in 0..300 {
        let layer = arb_layer(&mut rng);
        let alg = reuse::algorithmic(&layer);
        assert!(alg.input >= 1.0 - 1e-9, "iter {iter}: input reuse {}", alg.input);
        assert!(alg.output >= 1.0 - 1e-9, "iter {iter}");
        if layer.weight_elems() > 0 {
            assert!(alg.weight >= 1.0 - 1e-9, "iter {iter}");
        }
        let nc = *rng.pick(&[16u64, 64, 256]);
        for s in Strategy::ALL {
            let sp = reuse::spatial(&layer, s, nc);
            assert!(sp.input_spatial <= nc as f64 + 1e-9, "iter {iter}");
            assert!(sp.weight_spatial <= nc as f64 + 1e-9, "iter {iter}");
        }
    }
}

#[test]
fn prop_mac_schedules_collision_free_and_lossless() {
    // Every coordinator schedule compiles into a collision-free TDM
    // sequence that carries exactly the scheduled payload.
    use wienna::nop::TdmMac;
    let mut rng = Rng::new(0x7D7);
    for iter in 0..150 {
        let layer = arb_layer(&mut rng);
        let sys = arb_sys(&mut rng);
        let coord = Coordinator::new(sys, DesignPoint::WIENNA_C, StrategyPolicy::Adaptive);
        let sched = coord.schedule_layer(&layer);
        let all: Vec<_> = sched.preload.iter().chain(sched.stream.iter()).cloned().collect();
        let mac = TdmMac::new(16.0);
        let tdm = mac.compile(&all, iter % 2 == 0);
        assert!(mac.verify(&tdm), "iter {iter}");
        let slot_bytes: u64 = tdm.slots.iter().map(|s| s.bytes).sum();
        assert_eq!(slot_bytes, sched.scheduled_bytes(), "iter {iter}");
    }
}

#[test]
fn prop_hetero_proportional_never_worse_than_uniform() {
    use wienna::coordinator::hetero::{partition_hetero, partition_uniform, ChipletClass, HeteroPackage};
    use wienna::dataflow::ChipletArch;
    let mut rng = Rng::new(0x4E7);
    for iter in 0..150 {
        let layer = arb_layer(&mut rng);
        let pkg = HeteroPackage {
            classes: vec![
                ChipletClass {
                    name: "big".into(),
                    count: rng.range_u64(1, 32),
                    pes: 256,
                    arch: ChipletArch::NvdlaLike,
                },
                ChipletClass {
                    name: "small".into(),
                    count: rng.range_u64(1, 128),
                    pes: 64,
                    arch: ChipletArch::NvdlaLike,
                },
            ],
        };
        let s = *rng.pick(&Strategy::ALL);
        let prop = partition_hetero(&layer, s, &pkg, 1);
        let unif = partition_uniform(&layer, s, &pkg, 1);
        // Allow tiny rounding slack on the unit split.
        assert!(
            prop.makespan as f64 <= unif.makespan as f64 * 1.05 + 16.0,
            "iter {iter}: {s} prop {} vs unif {}",
            prop.makespan,
            unif.makespan
        );
    }
}

#[test]
fn prop_trace_round_trip() {
    use wienna::workload::trace;
    let mut rng = Rng::new(0x77ACE);
    for iter in 0..100 {
        let layers: Vec<_> = (0..rng.range_u64(1, 8)).map(|_| arb_layer(&mut rng)).collect();
        let m = wienna::workload::Model { name: format!("fuzz{iter}"), layers };
        let text = trace::dump(&m);
        let back = trace::parse(&text).unwrap_or_else(|e| panic!("iter {iter}: {e:#}\n{text}"));
        assert_eq!(m.layers, back.layers, "iter {iter}");
    }
}

#[test]
fn prop_adaptive_is_min_of_fixed() {
    let mut rng = Rng::new(0xDADA);
    let sys = SystemConfig::default();
    let engine = CostEngine::for_design_point(&sys, DesignPoint::WIENNA_A);
    for iter in 0..200 {
        let layer = arb_layer(&mut rng);
        let (_, best) = wienna::cost::best_strategy(&engine, &layer);
        for s in Strategy::ALL {
            let c = evaluate_layer(&engine, &layer, s);
            assert!(best.latency <= c.latency + 1e-6, "iter {iter}: adaptive {} > {s} {}", best.latency, c.latency);
        }
    }
}
