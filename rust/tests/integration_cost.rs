//! Integration tests over the full cost pipeline: workload -> partition ->
//! intra-chiplet mapping -> NoP models -> phase timeline, checking the
//! paper's qualitative claims end to end.

use wienna::config::{DesignPoint, SystemConfig};
use wienna::cost::{evaluate_layer, evaluate_model, CostEngine};
use wienna::dataflow::Strategy;
use wienna::energy::model_distribution_energy;
use wienna::workload::{classify, resnet50::resnet50, unet::unet, LayerType};

fn sys() -> SystemConfig {
    SystemConfig::default()
}

#[test]
fn headline_resnet50_speedup_band() {
    // Paper Fig 7: 2.7-5.1x end-to-end over the interposer baselines.
    // Accept a wider band for the reimplemented substrate: >= 1.8x and
    // <= 10x across the {WIENNA} x {Interposer} grid.
    let m = resnet50(64);
    let th: Vec<f64> = DesignPoint::ALL
        .iter()
        .map(|&dp| evaluate_model(&CostEngine::for_design_point(&sys(), dp), &m, None).macs_per_cycle)
        .collect();
    let (ic, ia, wc, wa) = (th[0], th[1], th[2], th[3]);
    let min_gain = (wc / ia).min(wa / ia).min(wc / ic).min(wa / ic);
    let max_gain = (wc / ia).max(wa / ia).max(wc / ic).max(wa / ic);
    assert!(min_gain > 1.2, "min gain {min_gain:.2}");
    assert!(max_gain > 2.2 && max_gain < 12.0, "max gain {max_gain:.2}");
}

#[test]
fn headline_unet_speedup_band() {
    let m = unet(64);
    let th: Vec<f64> = DesignPoint::ALL
        .iter()
        .map(|&dp| evaluate_model(&CostEngine::for_design_point(&sys(), dp), &m, None).macs_per_cycle)
        .collect();
    assert!(th[2] > th[1], "WIENNA-C must beat Interposer-A at equal BW");
    assert!(th[3] > th[2], "aggressive WIENNA beats conservative");
    assert!(th[1] > th[0], "aggressive interposer beats conservative");
}

#[test]
fn equal_bandwidth_wienna_wins_on_broadcast() {
    // WIENNA-C and Interposer-A share 16 B/cyc; the broadcast advantage
    // must be visible on both networks (paper: 2.58x / 2.21x).
    for m in [resnet50(64), unet(64)] {
        let w = evaluate_model(&CostEngine::for_design_point(&sys(), DesignPoint::WIENNA_C), &m, None);
        let i = evaluate_model(&CostEngine::for_design_point(&sys(), DesignPoint::INTERPOSER_A), &m, None);
        let r = w.macs_per_cycle / i.macs_per_cycle;
        assert!(r > 1.3 && r < 8.0, "{}: {r:.2}x", m.name);
    }
}

#[test]
fn adaptive_beats_fixed_on_both_models() {
    // Paper: +4.7% (ResNet50) and +9.1% (UNet) over all-KP-CP.
    for m in [resnet50(64), unet(64)] {
        let e = CostEngine::for_design_point(&sys(), DesignPoint::WIENNA_C);
        let ad = evaluate_model(&e, &m, None).macs_per_cycle;
        let kp = evaluate_model(&e, &m, Some(Strategy::KpCp)).macs_per_cycle;
        assert!(ad >= kp, "{}: adaptive {ad:.0} < kp-cp {kp:.0}", m.name);
    }
}

#[test]
fn energy_reduction_everywhere() {
    // Paper Fig 9: WIENNA reduces distribution energy across all
    // strategies and both DNNs; average 38.2%.
    let mut all = Vec::new();
    for m in [resnet50(16), unet(4)] {
        for s in [None, Some(Strategy::KpCp), Some(Strategy::NpCp), Some(Strategy::YpXp)] {
            let c = model_distribution_energy(&sys(), &m, s);
            assert!(c.reduction() > 0.0, "{} {:?}", m.name, s);
            all.push(c.reduction());
        }
    }
    let avg = all.iter().sum::<f64>() / all.len() as f64;
    assert!(avg > 0.2 && avg < 0.95, "avg reduction {:.1}%", avg * 100.0);
}

#[test]
fn observation1_strategy_preferences() {
    // High-res conv layers favor YP-XP; FC layers favor KP-CP (Fig 3).
    let e = CostEngine::ideal(&sys(), 64.0);
    let m = resnet50(64);
    let mut hi_votes = std::collections::HashMap::new();
    let mut fc_votes = std::collections::HashMap::new();
    for l in &m.layers {
        let (s, _) = wienna::cost::best_strategy(&e, l);
        match classify(l) {
            LayerType::HighRes => *hi_votes.entry(s).or_insert(0) += 1,
            LayerType::FullyConnected => *fc_votes.entry(s).or_insert(0) += 1,
            _ => {}
        }
    }
    let top = |v: &std::collections::HashMap<Strategy, i32>| *v.iter().max_by_key(|(_, &c)| c).unwrap().0;
    assert_eq!(top(&hi_votes), Strategy::YpXp, "{hi_votes:?}");
    assert_eq!(top(&fc_votes), Strategy::KpCp, "{fc_votes:?}");
}

#[test]
fn fig8_nonmonotonic_or_spread() {
    // Fig 8: throughput is not a monotone function of chiplet count for
    // all (model, strategy) combinations.
    let m = resnet50(64);
    let mut any_nonmonotone = false;
    for s in Strategy::ALL {
        let th: Vec<f64> = [32u64, 64, 128, 256, 512, 1024]
            .iter()
            .map(|&nc| {
                let e = CostEngine::for_design_point(&SystemConfig::with_chiplets(nc), DesignPoint::WIENNA_C);
                evaluate_model(&e, &m, Some(s)).macs_per_cycle
            })
            .collect();
        let increasing = th.windows(2).all(|w| w[1] >= w[0]);
        let decreasing = th.windows(2).all(|w| w[1] <= w[0]);
        if !increasing && !decreasing {
            any_nonmonotone = true;
        }
    }
    assert!(any_nonmonotone, "expected a non-monotonic cluster-size curve");
}

#[test]
fn multicast_factor_ranking() {
    // Fig 10: KP-CP exposes the highest average multicast factor.
    let m = resnet50(64);
    let mut avg = [0.0f64; 3];
    for (i, &s) in Strategy::ALL.iter().enumerate() {
        let mut total = 0.0;
        for l in &m.layers {
            let p = wienna::dataflow::partition::partition(l, s, 256, 1);
            total += p.multicast_factor();
        }
        avg[i] = total / m.layers.len() as f64;
    }
    assert!(avg[0] > avg[1] && avg[0] > avg[2], "KP-CP should rank first: {avg:?}");
}

#[test]
fn bottleneck_classification_consistent() {
    let e = CostEngine::for_design_point(&sys(), DesignPoint::INTERPOSER_C);
    let m = resnet50(16);
    for l in &m.layers {
        for s in Strategy::ALL {
            let c = evaluate_layer(&e, l, s);
            // The latency must be at least the bottleneck phase length.
            let t = c.timeline;
            let steady = t.stream.max(t.compute).max(t.collect);
            assert!(c.latency >= steady, "{}", l.name);
            assert!(c.latency <= t.preload + steady + t.fill + 1e-6);
        }
    }
}

#[test]
fn local_buffer_requirements_reported() {
    let e = CostEngine::for_design_point(&sys(), DesignPoint::WIENNA_C);
    let m = unet(4);
    for l in &m.layers {
        let c = evaluate_layer(&e, l, Strategy::KpCp);
        assert!(c.local_buffer_bytes > 0, "{}", l.name);
    }
}
