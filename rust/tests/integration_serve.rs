//! Integration tests over the serving subsystem: sources -> routing ->
//! dynamic batching -> completion statistics, end to end.
//!
//! Scenarios use the tiny CNN so debug-mode runs stay fast; the cost
//! cache keeps every run to a handful of `evaluate_model` calls.

use wienna::config::DesignPoint;
use wienna::serve::{
    ms_to_cycles, Fleet, MixEntry, ModelKind, PackageSpec, RoutePolicy, ServeStats, Source,
    WorkloadMix,
};

fn tiny_mix(slo_ms: f64) -> WorkloadMix {
    WorkloadMix::new(vec![MixEntry {
        kind: ModelKind::TinyCnn,
        weight: 1.0,
        slo_cycles: ms_to_cycles(slo_ms),
    }])
}

fn two_model_mix() -> WorkloadMix {
    WorkloadMix::new(vec![
        MixEntry { kind: ModelKind::TinyCnn, weight: 3.0, slo_cycles: ms_to_cycles(20.0) },
        MixEntry { kind: ModelKind::Mlp, weight: 1.0, slo_cycles: ms_to_cycles(40.0) },
    ])
}

fn poisson_run(load: f64, slo_ms: f64, seed: u64) -> (Fleet, ServeStats) {
    let mut fleet =
        Fleet::new(PackageSpec::homogeneous(2, DesignPoint::WIENNA_C), RoutePolicy::EarliestDeadline);
    let mix = tiny_mix(slo_ms);
    let capacity = fleet.estimate_capacity_rps(&mix, 8);
    let mut source = Source::poisson(mix, capacity * load, seed);
    let mut stats = ServeStats::new();
    fleet.run(&mut source, ms_to_cycles(20.0), &mut stats);
    (fleet, stats)
}

#[test]
fn deterministic_given_seed() {
    let (_, a) = poisson_run(0.7, 30.0, 99);
    let (_, b) = poisson_run(0.7, 30.0, 99);
    assert_eq!(a.arrived(), b.arrived());
    assert_eq!(a.completed(), b.completed());
    assert_eq!(a.latency_ms(99.0), b.latency_ms(99.0));
    assert_eq!(a.mean_batch(), b.mean_batch());
}

#[test]
fn light_load_meets_generous_slo() {
    let (_, stats) = poisson_run(0.2, 50.0, 4);
    assert!(stats.completed() > 0);
    assert!(
        stats.violation_rate() < 0.05,
        "light load violated {:.1}%",
        stats.violation_rate() * 100.0
    );
    // Near-idle fleet: batches stay small.
    assert!(stats.mean_batch() < 4.0, "mean batch {:.2}", stats.mean_batch());
}

#[test]
fn overload_violates_and_batches_up() {
    let (_, light) = poisson_run(0.2, 10.0, 4);
    let (_, heavy) = poisson_run(2.5, 10.0, 4);
    assert!(
        heavy.violation_rate() > light.violation_rate(),
        "overload {:.2} vs light {:.2}",
        heavy.violation_rate(),
        light.violation_rate()
    );
    assert!(
        heavy.mean_batch() > light.mean_batch(),
        "overload batch {:.2} vs light {:.2}",
        heavy.mean_batch(),
        light.mean_batch()
    );
    assert!(heavy.latency_ms(99.0) > light.latency_ms(99.0));
}

#[test]
fn conservation_across_sources_and_policies() {
    for policy in RoutePolicy::ALL {
        // Open loop: replayed gap trace over two models.
        let gaps: Vec<f64> = (0..200).map(|i| 0.01 + 0.002 * (i % 7) as f64).collect();
        let mut fleet = Fleet::new(PackageSpec::homogeneous(3, DesignPoint::WIENNA_C), policy);
        let mut source = Source::replay(two_model_mix(), &gaps, 5);
        let mut stats = ServeStats::new();
        fleet.run(&mut source, f64::INFINITY, &mut stats);
        assert_eq!(source.emitted(), 200, "{}", policy.label());
        assert_eq!(stats.arrived(), 200);
        assert_eq!(stats.completed(), 200);
        assert_eq!(fleet.queued_total(), 0);
        assert_eq!(fleet.in_flight_total(), 0);
        let per_pkg: u64 = fleet.packages.iter().map(|p| p.requests_completed).sum();
        assert_eq!(per_pkg, 200);
    }
}

#[test]
fn closed_loop_serves_every_client_request() {
    let clients = 8;
    let per_client = 5;
    let mut fleet =
        Fleet::new(PackageSpec::homogeneous(2, DesignPoint::WIENNA_A), RoutePolicy::LeastLoaded);
    let mut source = Source::closed_loop(two_model_mix(), clients, 0.5, per_client, 11);
    let mut stats = ServeStats::new();
    fleet.run(&mut source, f64::INFINITY, &mut stats);
    let expected = (clients as u64) * per_client;
    assert_eq!(source.emitted(), expected);
    assert_eq!(stats.completed(), expected);
    // Closed loop never queues more than one request per client.
    assert!(fleet.packages.iter().all(|p| p.queue.peak_depth <= clients));
}

#[test]
fn cost_cache_stays_hot_in_the_event_loop() {
    let (fleet, stats) = poisson_run(1.0, 30.0, 21);
    assert!(stats.completed() > 20, "need a busy run, got {}", stats.completed());
    // Misses are bounded by the distinct (model, batch) keys, hits grow
    // with traffic: the hot loop must not re-run evaluate_model.
    let max_keys = 2 * fleet.batcher.candidates.len() as u64 + 2;
    assert!(fleet.cache.misses <= max_keys, "{} misses", fleet.cache.misses);
    assert!(
        fleet.cache.hits > 4 * fleet.cache.misses,
        "{} hits vs {} misses",
        fleet.cache.hits,
        fleet.cache.misses
    );
}

#[test]
fn percentiles_are_ordered_and_bounded_by_max() {
    let (_, stats) = poisson_run(1.2, 15.0, 8);
    let p50 = stats.latency_ms(50.0);
    let p95 = stats.latency_ms(95.0);
    let p99 = stats.latency_ms(99.0);
    let p100 = stats.latency_ms(100.0);
    assert!(p50 <= p95 && p95 <= p99 && p99 <= p100, "{p50} {p95} {p99} {p100}");
    assert!(p50 > 0.0);
}

#[test]
fn hetero_fleet_with_slo_routing_beats_round_robin_on_goodput() {
    let specs = || {
        let mut v = PackageSpec::homogeneous(1, DesignPoint::WIENNA_A);
        v.extend(PackageSpec::homogeneous(1, DesignPoint::INTERPOSER_C));
        v
    };
    let mix = tiny_mix(8.0);
    let run = |policy| {
        let mut fleet = Fleet::new(specs(), policy);
        let capacity = fleet.estimate_capacity_rps(&mix, 8);
        let mut source = Source::poisson(mix.clone(), capacity * 0.9, 17);
        let mut stats = ServeStats::new();
        fleet.run(&mut source, ms_to_cycles(20.0), &mut stats);
        stats
    };
    let rr = run(RoutePolicy::RoundRobin);
    let edf = run(RoutePolicy::EarliestDeadline);
    assert!(
        edf.violation_rate() <= rr.violation_rate(),
        "edf {:.3} vs rr {:.3}",
        edf.violation_rate(),
        rr.violation_rate()
    );
}
