//! Integration tests for `wienna::cluster`: the sharded multi-tenant
//! serving engine, end to end.
//!
//! The load-bearing guarantees proven here:
//!
//! 1. **Determinism**: a fixed seed yields bit-identical `ClusterStats`
//!    (compared as the emitted stats JSON) across 1/2/4 worker threads —
//!    open-loop, closed-loop, and with epoch-barrier work stealing on;
//!    the property the CI determinism gate re-checks on the built
//!    binary, and the `testutil::fuzz_determinism` harness sweeps over
//!    randomized configurations.
//! 2. **Conservation under admission control and stealing**: shed +
//!    completed always equals arrived after a drained run, per class and
//!    globally, across randomized configurations — and the event trace
//!    proves no request is ever finalized twice (i.e. executed on two
//!    shards), however much the steal pass moves work around.
//! 3. **Schema stability**: the stats-JSON field names and order are
//!    pinned against a golden fixture, catching accidental renames and
//!    reorders the (within-run) determinism diff cannot see.

use std::collections::HashMap;
use wienna::cluster::{
    AdmissionConfig, ClassMix, ClassSpec, Cluster, ClusterConfig, SyncConfig, TrafficClass,
};
use wienna::config::DesignPoint;
use wienna::serve::{ms_to_cycles, MixEntry, ModelKind, PackageSpec, RoutePolicy, Source, WorkloadMix};
use wienna::testutil::Rng;
use wienna::workload::trace::synthetic_arrivals;

fn tiny_mix(slo_ms: f64) -> WorkloadMix {
    WorkloadMix::new(vec![MixEntry {
        kind: ModelKind::TinyCnn,
        weight: 1.0,
        slo_cycles: ms_to_cycles(slo_ms),
    }])
}

fn two_model_mix() -> WorkloadMix {
    WorkloadMix::new(vec![
        MixEntry { kind: ModelKind::TinyCnn, weight: 3.0, slo_cycles: ms_to_cycles(25.0) },
        MixEntry { kind: ModelKind::Mlp, weight: 1.0, slo_cycles: ms_to_cycles(50.0) },
    ])
}

fn run_cluster(packages: usize, shards: usize, threads: usize, rate: f64) -> wienna::cluster::ClusterStats {
    let cluster = Cluster::new(
        PackageSpec::homogeneous(packages, DesignPoint::WIENNA_C),
        ClusterConfig { shards, threads, ..Default::default() },
    );
    let mut source = Source::poisson(two_model_mix(), rate, 42);
    cluster.run(&mut source, ms_to_cycles(15.0))
}

/// Acceptance criterion: bit-identical `ServeStats` for the same seed
/// across 1/2/4 shard worker threads on a 16-package fleet.
#[test]
fn stats_are_bit_identical_across_1_2_4_threads() {
    let t1 = run_cluster(16, 4, 1, 6000.0);
    let t2 = run_cluster(16, 4, 2, 6000.0);
    let t4 = run_cluster(16, 4, 4, 6000.0);
    assert!(t1.serve.completed() > 0, "the run must actually serve traffic");
    let (j1, j2, j4) = (t1.to_json(), t2.to_json(), t4.to_json());
    assert_eq!(j1, j2, "1-thread vs 2-thread stats JSON diverged");
    assert_eq!(j1, j4, "1-thread vs 4-thread stats JSON diverged");
    // Spot-check the underlying f64s, not just their formatting.
    assert_eq!(t1.serve.latency_ms(99.0).to_bits(), t4.serve.latency_ms(99.0).to_bits());
    assert_eq!(t1.serve.end_cycle().to_bits(), t4.serve.end_cycle().to_bits());
    assert_eq!(t1.serve.mean_batch().to_bits(), t2.serve.mean_batch().to_bits());
}

/// Shard count is part of the semantics; it may legitimately change the
/// numbers — but for a fixed shard count the seed pins everything.
#[test]
fn repeat_runs_are_identical_and_shard_count_is_semantic() {
    let a = run_cluster(8, 2, 2, 5000.0);
    let b = run_cluster(8, 2, 2, 5000.0);
    assert_eq!(a.to_json(), b.to_json());
    let c = run_cluster(8, 8, 2, 5000.0);
    assert_eq!(c.shards, 8);
    // Same arrivals either way (ingress is shard-independent).
    assert_eq!(a.serve.arrived(), c.serve.arrived());
}

/// Property test: across randomized configurations, request accounting
/// balances exactly — arrived == completed + shed, per class and
/// globally, with queues drained.
#[test]
fn admission_accounting_balances_across_random_configs() {
    let mut rng = Rng::new(2026);
    for trial in 0..10 {
        let packages = rng.range_u64(1, 6) as usize;
        let shards = rng.range_u64(1, 4) as usize;
        let threads = rng.range_u64(1, 4) as usize;
        let rate = 1000.0 + rng.next_f32() as f64 * 14000.0;
        let queue_cap = match rng.range_u64(0, 3) {
            0 => None,
            1 => Some(0),
            n => Some((4 * n) as usize),
        };
        let policy = *rng.pick(&RoutePolicy::ALL);
        let preemption = rng.range_u64(0, 1) == 1;
        let shed_late = rng.range_u64(0, 1) == 1;
        let cluster = Cluster::new(
            PackageSpec::homogeneous(packages, DesignPoint::WIENNA_C),
            ClusterConfig {
                shards,
                threads,
                policy,
                preemption,
                admission: AdmissionConfig { queue_cap, shed_late },
                ..Default::default()
            },
        );
        let mut source = Source::poisson(two_model_mix(), rate, 7 + trial);
        let stats = cluster.run(&mut source, ms_to_cycles(8.0));
        let label = format!(
            "trial {trial}: {packages} pkg, {shards} shards, {threads} thr, cap {queue_cap:?}, {} rate {rate:.0}",
            policy.label()
        );
        assert_eq!(
            stats.serve.arrived(),
            stats.serve.completed() + stats.serve.shed(),
            "{label}: arrived != completed + shed"
        );
        assert_eq!(
            stats.shed_queue_full + stats.shed_deadline,
            stats.serve.shed(),
            "{label}: shed reasons don't sum"
        );
        let class_total: u64 =
            stats.per_class.values().map(|m| m.completed + m.shed).sum();
        assert_eq!(class_total, stats.serve.arrived(), "{label}: per-class balance");
        let pkg_completed: u64 = stats.packages.iter().map(|p| p.requests_completed).sum();
        assert_eq!(pkg_completed, stats.serve.completed(), "{label}: per-package balance");
    }
}

#[test]
fn zero_cap_sheds_everything_uncapped_sheds_nothing() {
    let run_with = |admission: AdmissionConfig| {
        let cluster = Cluster::new(
            PackageSpec::homogeneous(4, DesignPoint::WIENNA_C),
            ClusterConfig { shards: 2, threads: 2, admission, ..Default::default() },
        );
        let mut source = Source::poisson(tiny_mix(25.0), 4000.0, 13);
        cluster.run(&mut source, ms_to_cycles(10.0))
    };
    let all_shed = run_with(AdmissionConfig { queue_cap: Some(0), shed_late: false });
    assert!(all_shed.serve.arrived() > 0);
    assert_eq!(all_shed.serve.shed(), all_shed.serve.arrived(), "cap 0 must shed everything");
    assert_eq!(all_shed.serve.completed(), 0);

    let none_shed = run_with(AdmissionConfig::admit_all());
    assert_eq!(none_shed.serve.shed(), 0, "uncapped + no deadline shedding must shed nothing");
    assert_eq!(none_shed.serve.completed(), none_shed.serve.arrived());
}

/// Tighter queue caps can only increase the shed rate (same traffic).
#[test]
fn shed_rate_grows_as_caps_tighten() {
    // 4x the estimated fleet capacity so queues genuinely build and the
    // caps bind (an absolute rate could silently under-load the fleet).
    let overload = 4.0
        * wienna::serve::Fleet::new(
            PackageSpec::homogeneous(2, DesignPoint::WIENNA_C),
            RoutePolicy::EarliestDeadline,
        )
        .estimate_capacity_rps(&tiny_mix(25.0), 8);
    let shed_at = |cap: Option<usize>| {
        let cluster = Cluster::new(
            PackageSpec::homogeneous(2, DesignPoint::WIENNA_C),
            ClusterConfig {
                shards: 2,
                threads: 2,
                admission: AdmissionConfig { queue_cap: cap, shed_late: false },
                ..Default::default()
            },
        );
        let mut source = Source::poisson(tiny_mix(25.0), overload, 5);
        cluster.run(&mut source, ms_to_cycles(10.0)).serve.shed_rate()
    };
    let loose = shed_at(None);
    let mid = shed_at(Some(8));
    let tight = shed_at(Some(1));
    assert_eq!(loose, 0.0);
    assert!(tight >= mid, "cap 1 shed {tight:.3} vs cap 8 shed {mid:.3}");
    assert!(mid > 0.0, "an overloaded cap-8 queue must shed something");
}

/// The class mix steers per-class traffic shares and the per-class stats
/// see deadline scaling (best-effort never violates).
#[test]
fn per_class_accounting_reflects_the_population() {
    // ~300 arrivals so the (deterministic, seed-fixed) class draw sits
    // well inside the tolerance band.
    let stats = run_cluster(8, 4, 2, 20_000.0);
    let total: u64 = stats.per_class.values().map(|m| m.arrived).sum();
    assert_eq!(total, stats.serve.arrived());
    let share = |c: TrafficClass| {
        stats.per_class.get(&c).map_or(0.0, |m| m.arrived as f64 / total as f64)
    };
    assert!((share(TrafficClass::Interactive) - 0.5).abs() < 0.12, "interactive {}", share(TrafficClass::Interactive));
    assert!((share(TrafficClass::Batch) - 0.3).abs() < 0.12, "batch {}", share(TrafficClass::Batch));
    assert!((share(TrafficClass::BestEffort) - 0.2).abs() < 0.12, "best-effort {}", share(TrafficClass::BestEffort));
    if let Some(be) = stats.per_class.get(&TrafficClass::BestEffort) {
        assert_eq!(be.slo_violated, 0, "best-effort has no deadline to violate");
    }
}

/// Acceptance criterion of the sync tentpole: 1/2/4-thread stats JSON is
/// bit-identical with `--closed-loop` and `--steal` both enabled (the
/// regime where completion feedback AND stolen work cross shards at
/// every epoch barrier).
#[test]
fn closed_loop_with_stealing_is_bit_identical_across_threads() {
    let run = |threads: usize| {
        let cluster = Cluster::new(
            PackageSpec::homogeneous(8, DesignPoint::WIENNA_C),
            ClusterConfig {
                shards: 4,
                threads,
                sync: SyncConfig { steal: true, epoch_cycles: ms_to_cycles(0.25), ..Default::default() },
                ..Default::default()
            },
        );
        let mut source = Source::closed_loop(two_model_mix(), 24, 0.4, 12, 77);
        cluster.run(&mut source, f64::INFINITY)
    };
    let t1 = run(1);
    let t2 = run(2);
    let t4 = run(4);
    assert_eq!(t1.serve.arrived(), 24 * 12, "every client request was issued");
    assert!(t1.serve.completed() > 0);
    assert!(t1.epochs > 1, "closed-loop runs are windowed");
    let (j1, j2, j4) = (t1.to_json(), t2.to_json(), t4.to_json());
    assert_eq!(j1, j2, "1-thread vs 2-thread closed-loop+steal JSON diverged");
    assert_eq!(j1, j4, "1-thread vs 4-thread closed-loop+steal JSON diverged");
    assert_eq!(t1.serve.latency_ms(99.0).to_bits(), t4.serve.latency_ms(99.0).to_bits());
    assert_eq!(t1.steals, t4.steals);
}

/// The determinism fuzz harness (`testutil::fuzz_determinism`): random
/// caps, class populations, epoch widths, steal on/off, randomized
/// fault plans with MAC contention, and all three
/// source families, each asserted bit-identical at 1/2/4 threads. The
/// harness panics on any divergence; here we also pin that it actually
/// covered the closed-loop, stealing, and chaos regimes.
#[test]
fn fuzz_determinism_sweeps_randomized_configs() {
    let summary = wienna::testutil::fuzz_determinism(0xF00D, 9);
    assert_eq!(summary.trials, 9);
    assert!(summary.closed_loop_trials >= 3, "closed-loop regimes covered");
    assert!(summary.steal_trials >= 3, "stealing regimes covered");
    assert!(summary.chaos_trials >= 4, "fault/contention regimes covered");
    assert!(summary.requests > 0, "the sweep served real traffic");
}

/// Property test (steal satellite): with stealing enabled under
/// randomized skewed class mixes, request conservation holds per class
/// (`completed + shed == arrived`) and no request is ever finalized on
/// two shards — the event trace shows every admitted id exactly once.
#[test]
fn stealing_conserves_requests_and_never_duplicates_execution() {
    let mut rng = Rng::new(0x57EA1);
    for trial in 0..8u64 {
        // A deliberately skewed class population: one dominant class with
        // the rest as slivers, random SLO handling.
        let dominant = *rng.pick(&TrafficClass::ALL);
        let classes = ClassMix::new(
            TrafficClass::ALL
                .iter()
                .map(|&class| ClassSpec {
                    class,
                    weight: if class == dominant { 10.0 } else { 0.2 + rng.next_f32() as f64 },
                    slo_scale: if rng.range_u64(0, 2) == 0 {
                        f64::INFINITY
                    } else {
                        1.0 + rng.next_f32() as f64 * 3.0
                    },
                    deadline_shed: rng.range_u64(0, 1) == 1,
                })
                .collect(),
        );
        let queue_cap = match rng.range_u64(0, 2) {
            0 => None,
            n => Some((6 * n) as usize),
        };
        let cluster = Cluster::new(
            PackageSpec::homogeneous(8, DesignPoint::WIENNA_C),
            ClusterConfig {
                shards: 4,
                threads: rng.range_u64(1, 4) as usize,
                classes,
                admission: AdmissionConfig { queue_cap, shed_late: rng.range_u64(0, 1) == 1 },
                // Cap the batch so a hot package can't swallow its whole
                // queue in one dispatch — queued work must exist for the
                // steal pass to have anything to move.
                batcher: wienna::serve::BatcherConfig { max_batch: 4, candidates: vec![1, 2, 4] },
                sync: SyncConfig {
                    steal: true,
                    epoch_cycles: ms_to_cycles(0.1 + rng.next_f32() as f64),
                    ..Default::default()
                },
                ..Default::default()
            },
        );
        // Skewed *arrival* pattern too: every client of stripe 0 (client
        // index ≡ 0 mod 4) is hot, the rest issue one or two requests.
        // Sixteen concurrent hot clients behind one 2-package stripe far
        // exceed what one dispatch round can absorb at the batch cap
        // above (2 packages x batch 4), so real backlog stays queued on
        // the hot shard and the steal pass genuinely moves work.
        let counts: Vec<usize> = (0..64)
            .map(|i| if i % 4 == 0 { 20 } else { 1 + rng.range_u64(0, 1) as usize })
            .collect();
        let traces = synthetic_arrivals(&counts, 0.05 + rng.next_f32() as f64 * 0.1, 0.5, 100 + trial);
        let mut source = Source::client_trace(two_model_mix(), &traces, 100 + trial);
        let (stats, trace) = cluster.run_traced(&mut source, f64::INFINITY);
        let label = format!("steal trial {trial}");

        // Per-class and global conservation.
        assert_eq!(
            stats.serve.arrived(),
            stats.serve.completed() + stats.serve.shed(),
            "{label}: arrived != completed + shed"
        );
        for (class, m) in &stats.per_class {
            assert_eq!(
                m.arrived,
                m.completed + m.shed,
                "{label}: class {} does not balance",
                class.label()
            );
        }
        // No request is finalized twice (executed on two shards) and none
        // vanishes: the trace holds every arrived id exactly once.
        let mut seen: HashMap<u64, usize> = HashMap::new();
        for ev in &trace {
            if let Some(prev_shard) = seen.insert(ev.id, ev.shard) {
                panic!(
                    "{label}: request {} finalized on shard {} and shard {}",
                    ev.id, prev_shard, ev.shard
                );
            }
        }
        assert_eq!(seen.len() as u64, stats.serve.arrived(), "{label}: trace covers every request");
    }
}

/// Stealing actually rebalances a hot stripe: the same skewed trace runs
/// with and without the steal pass; with it, work moves (steals > 0) and
/// the drain finishes measurably earlier — one stripe owns all the real
/// traffic, so without stealing a single package serves ~all of it while
/// three sit idle. (The quantitative ≥20% goodput claim at bench scale
/// lives in `benches/cluster_scale.rs`.)
#[test]
fn stealing_moves_work_off_a_hot_stripe_and_speeds_the_drain() {
    let run = |steal: bool| {
        let cluster = Cluster::new(
            PackageSpec::homogeneous(4, DesignPoint::WIENNA_C),
            ClusterConfig {
                shards: 4, // one package per shard: the skew has nowhere to hide
                threads: 2,
                classes: ClassMix::single(TrafficClass::Interactive, 1.0, false),
                admission: AdmissionConfig::admit_all(),
                preemption: false,
                batcher: wienna::serve::BatcherConfig { max_batch: 8, candidates: vec![1, 2, 4, 8] },
                sync: SyncConfig { steal, epoch_cycles: ms_to_cycles(0.1), ..Default::default() },
                ..Default::default()
            },
        );
        // All real traffic on stripe 0: clients 0, 4, 8, ..., 60 are hot
        // (16 concurrent clients against one batch-8-capped package, so
        // at least half of them are queued at any barrier), the rest
        // issue one request each.
        let counts: Vec<usize> = (0..64).map(|i| if i % 4 == 0 { 40 } else { 1 }).collect();
        let traces = synthetic_arrivals(&counts, 0.02, 0.5, 9);
        let mut source = Source::client_trace(tiny_mix(25.0), &traces, 9);
        cluster.run(&mut source, f64::INFINITY)
    };
    let stuck = run(false);
    let stolen = run(true);
    assert_eq!(stuck.steals, 0);
    assert!(stolen.steals > 0, "the hot stripe must donate work");
    assert_eq!(stuck.serve.completed(), stolen.serve.completed(), "admit-all: same requests served");
    assert!(
        stolen.serve.end_cycle() <= 0.9 * stuck.serve.end_cycle(),
        "stealing should cut the skewed drain by >=10%: {} vs {} cycles",
        stolen.serve.end_cycle(),
        stuck.serve.end_cycle()
    );
}

/// Golden-file regression (schema satellite): the stats-JSON field names
/// and order match the checked-in fixture. The determinism gate diffs
/// runs of the *same* binary, so a renamed or reordered field would sail
/// through it — this test catches exactly that. If the schema changes on
/// purpose, regenerate the fixture to match `ClusterStats::to_json`.
#[test]
fn stats_json_schema_matches_the_golden_fixture() {
    // Keys of one per-class JSON object line, in order: the segments of a
    // `"`-split that are immediately followed by a `:`.
    fn object_keys(line: &str) -> Vec<String> {
        let parts: Vec<&str> = line.split('"').collect();
        let mut keys = Vec::new();
        let mut i = 1;
        while i < parts.len() {
            if parts.get(i + 1).is_some_and(|s| s.trim_start().starts_with(':')) {
                keys.push(parts[i].to_string());
            }
            i += 2;
        }
        keys
    }

    let stats = run_cluster(4, 2, 2, 5000.0);
    assert!(stats.serve.completed() > 0, "schema probe must fill the per-class array");
    let json = stats.to_json();
    let mut schema = String::new();
    let mut class_done = false;
    for line in json.lines() {
        if let Some(rest) = line.strip_prefix("  \"") {
            let key = rest.split('"').next().expect("top-level key closes its quote");
            schema.push_str(&format!("top {key}\n"));
        } else if line.starts_with("    {") && !class_done {
            for key in object_keys(line) {
                schema.push_str(&format!("class {key}\n"));
            }
            class_done = true;
        }
    }
    assert!(class_done, "per-class array rendered at least one object");

    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("rust/testdata/cluster_stats_schema.golden");
    let fixture = std::fs::read_to_string(&path).expect("golden schema fixture exists");
    let pinned: String =
        fixture.lines().filter(|l| !l.trim().is_empty() && !l.starts_with('#')).map(|l| format!("{l}\n")).collect();
    assert_eq!(
        schema, pinned,
        "stats JSON schema drifted from {path:?} — if the change is deliberate, update the fixture"
    );
}

/// Single-class cluster (best-effort only, admit-all, no preemption) on
/// one shard serves exactly the same request count as `serve::Fleet` on
/// the same traffic — the cluster engine is a strict superset.
#[test]
fn single_class_single_shard_matches_fleet_throughput() {
    let specs = || PackageSpec::homogeneous(2, DesignPoint::WIENNA_C);
    let mix = tiny_mix(25.0);
    let horizon = ms_to_cycles(10.0);

    let mut fleet = wienna::serve::Fleet::new(specs(), RoutePolicy::EarliestDeadline);
    let mut src = Source::poisson(mix.clone(), 4000.0, 99);
    let mut fleet_stats = wienna::serve::ServeStats::new();
    fleet.run(&mut src, horizon, &mut fleet_stats);

    let cluster = Cluster::new(
        specs(),
        ClusterConfig {
            shards: 1,
            threads: 1,
            classes: ClassMix::single(TrafficClass::BestEffort, 1.0, false),
            admission: AdmissionConfig::admit_all(),
            preemption: false,
            ..Default::default()
        },
    );
    let mut src = Source::poisson(mix, 4000.0, 99);
    let cluster_stats = cluster.run(&mut src, horizon);

    assert_eq!(cluster_stats.serve.arrived(), fleet_stats.arrived());
    assert_eq!(cluster_stats.serve.completed(), fleet_stats.completed());
}

// ---------------------------------------------------------------------------
// Adaptive epoch sizing (`SyncConfig::adaptive`).
// ---------------------------------------------------------------------------

/// One closed-loop run with adaptive windows: the window end is derived
/// from the earliest cross-shard event instead of a fixed stride.
fn run_adaptive(threads: usize, adaptive: bool) -> wienna::cluster::ClusterStats {
    let cluster = Cluster::new(
        PackageSpec::homogeneous(8, DesignPoint::WIENNA_C),
        ClusterConfig {
            shards: 4,
            threads,
            sync: SyncConfig { steal: true, adaptive, ..Default::default() },
            ..Default::default()
        },
    );
    // Closed-loop so the run actually pays barriers (the open-loop
    // no-steal fast path collapses to a single unbounded epoch).
    let mut source = Source::closed_loop(two_model_mix(), 24, 0.4, 12, 77);
    cluster.run(&mut source, f64::INFINITY)
}

/// Adaptive epochs keep every engine guarantee: request conservation,
/// full drain, and byte-identical stats at 1/2/4 worker threads.
#[test]
fn adaptive_epochs_conserve_requests_and_stay_thread_deterministic() {
    let t1 = run_adaptive(1, true);
    let t2 = run_adaptive(2, true);
    let t4 = run_adaptive(4, true);
    assert!(t1.serve.completed() > 0, "the run must serve traffic");
    assert_eq!(
        t1.serve.arrived(),
        t1.serve.completed() + t1.serve.shed() + t1.serve.failed(),
        "conservation under adaptive windows"
    );
    let per_class: u64 = t1.per_class.values().map(|m| m.completed + m.shed + m.failed).sum();
    assert_eq!(per_class, t1.serve.arrived(), "per-class balance");
    let (j1, j2, j4) = (t1.to_json(), t2.to_json(), t4.to_json());
    assert_eq!(j1, j2, "adaptive epochs: 1 vs 2-thread stats diverged");
    assert_eq!(j1, j4, "adaptive epochs: 1 vs 4-thread stats diverged");
}

/// Adaptive windows end at event bounds instead of a fixed stride, which
/// moves every barrier — and with it all cross-shard feedback timing —
/// yet the engine still admits, serves, and drains exactly the same
/// request population as the fixed stride. (Barrier *counts* differ by
/// design: adaptive trades stride-granularity windows for
/// event-resolution ones, paying more barriers under dense completion
/// traffic and fewer across quiet stretches.)
#[test]
fn adaptive_epochs_complete_the_same_work_as_the_fixed_stride() {
    let fixed = run_adaptive(2, false);
    let adaptive = run_adaptive(2, true);
    assert_eq!(
        fixed.serve.arrived(),
        adaptive.serve.arrived(),
        "same client pool either way"
    );
    assert_eq!(
        fixed.serve.completed(),
        adaptive.serve.completed(),
        "every request still completes"
    );
    assert!(fixed.epochs > 0 && adaptive.epochs > 0, "both modes must pay real barriers");
    assert_eq!(
        adaptive.serve.arrived(),
        adaptive.serve.completed() + adaptive.serve.shed() + adaptive.serve.failed(),
        "conservation with event-bound windows"
    );
}

/// Adaptive windows compose with chaos: fault edges clamp the window so
/// kills land on their exact cycle, and the run stays deterministic
/// across thread counts.
#[test]
fn adaptive_epochs_stay_deterministic_under_faults() {
    let run = |threads: usize| {
        let cluster = Cluster::new(
            PackageSpec::homogeneous(8, DesignPoint::WIENNA_C),
            ClusterConfig {
                shards: 4,
                threads,
                sync: SyncConfig { steal: true, adaptive: true, ..Default::default() },
                faults: wienna::fault::FaultPlan::parse("kill:1@1..4;spike:0.3@0..3")
                    .expect("test fault spec"),
                ..Default::default()
            },
        );
        let mut source = Source::closed_loop(two_model_mix(), 16, 0.3, 8, 31);
        cluster.run(&mut source, f64::INFINITY)
    };
    let t1 = run(1);
    let t4 = run(4);
    assert!(t1.serve.completed() > 0);
    assert_eq!(
        t1.serve.arrived(),
        t1.serve.completed() + t1.serve.shed() + t1.serve.failed(),
        "conservation under adaptive windows + faults"
    );
    assert_eq!(t1.to_json(), t4.to_json(), "adaptive + faults: 1 vs 4-thread stats diverged");
}
