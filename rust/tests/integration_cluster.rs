//! Integration tests for `wienna::cluster`: the sharded multi-tenant
//! serving engine, end to end.
//!
//! The two load-bearing guarantees proven here:
//!
//! 1. **Determinism**: a fixed seed yields bit-identical `ClusterStats`
//!    (compared as the emitted stats JSON) across 1/2/4 worker threads —
//!    the property the CI determinism gate re-checks on the built binary.
//! 2. **Conservation under admission control**: shed + completed always
//!    equals arrived after a drained run, across randomized
//!    configurations; a zero-cap queue sheds everything and an uncapped,
//!    non-shedding queue sheds nothing.

use wienna::cluster::{AdmissionConfig, ClassMix, Cluster, ClusterConfig, TrafficClass};
use wienna::config::DesignPoint;
use wienna::serve::{ms_to_cycles, MixEntry, ModelKind, PackageSpec, RoutePolicy, Source, WorkloadMix};
use wienna::testutil::Rng;

fn tiny_mix(slo_ms: f64) -> WorkloadMix {
    WorkloadMix::new(vec![MixEntry {
        kind: ModelKind::TinyCnn,
        weight: 1.0,
        slo_cycles: ms_to_cycles(slo_ms),
    }])
}

fn two_model_mix() -> WorkloadMix {
    WorkloadMix::new(vec![
        MixEntry { kind: ModelKind::TinyCnn, weight: 3.0, slo_cycles: ms_to_cycles(25.0) },
        MixEntry { kind: ModelKind::Mlp, weight: 1.0, slo_cycles: ms_to_cycles(50.0) },
    ])
}

fn run_cluster(packages: usize, shards: usize, threads: usize, rate: f64) -> wienna::cluster::ClusterStats {
    let cluster = Cluster::new(
        PackageSpec::homogeneous(packages, DesignPoint::WIENNA_C),
        ClusterConfig { shards, threads, ..Default::default() },
    );
    let mut source = Source::poisson(two_model_mix(), rate, 42);
    cluster.run(&mut source, ms_to_cycles(15.0))
}

/// Acceptance criterion: bit-identical `ServeStats` for the same seed
/// across 1/2/4 shard worker threads on a 16-package fleet.
#[test]
fn stats_are_bit_identical_across_1_2_4_threads() {
    let t1 = run_cluster(16, 4, 1, 6000.0);
    let t2 = run_cluster(16, 4, 2, 6000.0);
    let t4 = run_cluster(16, 4, 4, 6000.0);
    assert!(t1.serve.completed() > 0, "the run must actually serve traffic");
    let (j1, j2, j4) = (t1.to_json(), t2.to_json(), t4.to_json());
    assert_eq!(j1, j2, "1-thread vs 2-thread stats JSON diverged");
    assert_eq!(j1, j4, "1-thread vs 4-thread stats JSON diverged");
    // Spot-check the underlying f64s, not just their formatting.
    assert_eq!(t1.serve.latency_ms(99.0).to_bits(), t4.serve.latency_ms(99.0).to_bits());
    assert_eq!(t1.serve.end_cycle().to_bits(), t4.serve.end_cycle().to_bits());
    assert_eq!(t1.serve.mean_batch().to_bits(), t2.serve.mean_batch().to_bits());
}

/// Shard count is part of the semantics; it may legitimately change the
/// numbers — but for a fixed shard count the seed pins everything.
#[test]
fn repeat_runs_are_identical_and_shard_count_is_semantic() {
    let a = run_cluster(8, 2, 2, 5000.0);
    let b = run_cluster(8, 2, 2, 5000.0);
    assert_eq!(a.to_json(), b.to_json());
    let c = run_cluster(8, 8, 2, 5000.0);
    assert_eq!(c.shards, 8);
    // Same arrivals either way (ingress is shard-independent).
    assert_eq!(a.serve.arrived(), c.serve.arrived());
}

/// Property test: across randomized configurations, request accounting
/// balances exactly — arrived == completed + shed, per class and
/// globally, with queues drained.
#[test]
fn admission_accounting_balances_across_random_configs() {
    let mut rng = Rng::new(2026);
    for trial in 0..10 {
        let packages = rng.range_u64(1, 6) as usize;
        let shards = rng.range_u64(1, 4) as usize;
        let threads = rng.range_u64(1, 4) as usize;
        let rate = 1000.0 + rng.next_f32() as f64 * 14000.0;
        let queue_cap = match rng.range_u64(0, 3) {
            0 => None,
            1 => Some(0),
            n => Some((4 * n) as usize),
        };
        let policy = *rng.pick(&RoutePolicy::ALL);
        let preemption = rng.range_u64(0, 1) == 1;
        let shed_late = rng.range_u64(0, 1) == 1;
        let cluster = Cluster::new(
            PackageSpec::homogeneous(packages, DesignPoint::WIENNA_C),
            ClusterConfig {
                shards,
                threads,
                policy,
                preemption,
                admission: AdmissionConfig { queue_cap, shed_late },
                ..Default::default()
            },
        );
        let mut source = Source::poisson(two_model_mix(), rate, 7 + trial);
        let stats = cluster.run(&mut source, ms_to_cycles(8.0));
        let label = format!(
            "trial {trial}: {packages} pkg, {shards} shards, {threads} thr, cap {queue_cap:?}, {} rate {rate:.0}",
            policy.label()
        );
        assert_eq!(
            stats.serve.arrived(),
            stats.serve.completed() + stats.serve.shed(),
            "{label}: arrived != completed + shed"
        );
        assert_eq!(
            stats.shed_queue_full + stats.shed_deadline,
            stats.serve.shed(),
            "{label}: shed reasons don't sum"
        );
        let class_total: u64 =
            stats.per_class.values().map(|m| m.completed + m.shed).sum();
        assert_eq!(class_total, stats.serve.arrived(), "{label}: per-class balance");
        let pkg_completed: u64 = stats.packages.iter().map(|p| p.requests_completed).sum();
        assert_eq!(pkg_completed, stats.serve.completed(), "{label}: per-package balance");
    }
}

#[test]
fn zero_cap_sheds_everything_uncapped_sheds_nothing() {
    let run_with = |admission: AdmissionConfig| {
        let cluster = Cluster::new(
            PackageSpec::homogeneous(4, DesignPoint::WIENNA_C),
            ClusterConfig { shards: 2, threads: 2, admission, ..Default::default() },
        );
        let mut source = Source::poisson(tiny_mix(25.0), 4000.0, 13);
        cluster.run(&mut source, ms_to_cycles(10.0))
    };
    let all_shed = run_with(AdmissionConfig { queue_cap: Some(0), shed_late: false });
    assert!(all_shed.serve.arrived() > 0);
    assert_eq!(all_shed.serve.shed(), all_shed.serve.arrived(), "cap 0 must shed everything");
    assert_eq!(all_shed.serve.completed(), 0);

    let none_shed = run_with(AdmissionConfig::admit_all());
    assert_eq!(none_shed.serve.shed(), 0, "uncapped + no deadline shedding must shed nothing");
    assert_eq!(none_shed.serve.completed(), none_shed.serve.arrived());
}

/// Tighter queue caps can only increase the shed rate (same traffic).
#[test]
fn shed_rate_grows_as_caps_tighten() {
    // 4x the estimated fleet capacity so queues genuinely build and the
    // caps bind (an absolute rate could silently under-load the fleet).
    let overload = 4.0
        * wienna::serve::Fleet::new(
            PackageSpec::homogeneous(2, DesignPoint::WIENNA_C),
            RoutePolicy::EarliestDeadline,
        )
        .estimate_capacity_rps(&tiny_mix(25.0), 8);
    let shed_at = |cap: Option<usize>| {
        let cluster = Cluster::new(
            PackageSpec::homogeneous(2, DesignPoint::WIENNA_C),
            ClusterConfig {
                shards: 2,
                threads: 2,
                admission: AdmissionConfig { queue_cap: cap, shed_late: false },
                ..Default::default()
            },
        );
        let mut source = Source::poisson(tiny_mix(25.0), overload, 5);
        cluster.run(&mut source, ms_to_cycles(10.0)).serve.shed_rate()
    };
    let loose = shed_at(None);
    let mid = shed_at(Some(8));
    let tight = shed_at(Some(1));
    assert_eq!(loose, 0.0);
    assert!(tight >= mid, "cap 1 shed {tight:.3} vs cap 8 shed {mid:.3}");
    assert!(mid > 0.0, "an overloaded cap-8 queue must shed something");
}

/// The class mix steers per-class traffic shares and the per-class stats
/// see deadline scaling (best-effort never violates).
#[test]
fn per_class_accounting_reflects_the_population() {
    // ~300 arrivals so the (deterministic, seed-fixed) class draw sits
    // well inside the tolerance band.
    let stats = run_cluster(8, 4, 2, 20_000.0);
    let total: u64 = stats.per_class.values().map(|m| m.arrived).sum();
    assert_eq!(total, stats.serve.arrived());
    let share = |c: TrafficClass| {
        stats.per_class.get(&c).map_or(0.0, |m| m.arrived as f64 / total as f64)
    };
    assert!((share(TrafficClass::Interactive) - 0.5).abs() < 0.12, "interactive {}", share(TrafficClass::Interactive));
    assert!((share(TrafficClass::Batch) - 0.3).abs() < 0.12, "batch {}", share(TrafficClass::Batch));
    assert!((share(TrafficClass::BestEffort) - 0.2).abs() < 0.12, "best-effort {}", share(TrafficClass::BestEffort));
    if let Some(be) = stats.per_class.get(&TrafficClass::BestEffort) {
        assert_eq!(be.slo_violated, 0, "best-effort has no deadline to violate");
    }
}

/// Single-class cluster (best-effort only, admit-all, no preemption) on
/// one shard serves exactly the same request count as `serve::Fleet` on
/// the same traffic — the cluster engine is a strict superset.
#[test]
fn single_class_single_shard_matches_fleet_throughput() {
    let specs = || PackageSpec::homogeneous(2, DesignPoint::WIENNA_C);
    let mix = tiny_mix(25.0);
    let horizon = ms_to_cycles(10.0);

    let mut fleet = wienna::serve::Fleet::new(specs(), RoutePolicy::EarliestDeadline);
    let mut src = Source::poisson(mix.clone(), 4000.0, 99);
    let mut fleet_stats = wienna::serve::ServeStats::new();
    fleet.run(&mut src, horizon, &mut fleet_stats);

    let cluster = Cluster::new(
        specs(),
        ClusterConfig {
            shards: 1,
            threads: 1,
            classes: ClassMix::single(TrafficClass::BestEffort, 1.0, false),
            admission: AdmissionConfig::admit_all(),
            preemption: false,
            ..Default::default()
        },
    );
    let mut src = Source::poisson(mix, 4000.0, 99);
    let cluster_stats = cluster.run(&mut src, horizon);

    assert_eq!(cluster_stats.serve.arrived(), fleet_stats.arrived());
    assert_eq!(cluster_stats.serve.completed(), fleet_stats.completed());
}
