//! Integration tests for `wienna::fault`: deterministic chaos over the
//! sharded cluster engine, end to end.
//!
//! The load-bearing guarantees proven here:
//!
//! 1. **Chaos determinism**: a seeded fault plan (package kill inside a
//!    contention spike) yields bit-identical stats JSON across 1/2/4
//!    worker threads — the same contract the fault-free engine holds,
//!    now with mid-run topology edges, retries, and failover moves in
//!    the event stream.
//! 2. **Conservation under failure**: per class and globally,
//!    `completed + shed + failed == arrived` after every drained run,
//!    across randomized seeded plans; the event trace shows every
//!    request finalized exactly once (a retried request still finalizes
//!    once — retries are not finalizations), on exactly one shard.
//! 3. **Recovery**: with stealing enabled, failover re-routes a dead
//!    shard's backlog to survivors — the run completes strictly more
//!    and fails strictly less than the same scenario without it.
//! 4. **Zero-guards**: a run that completes nothing still emits `0`
//!    (never `NaN`/`null`) for every fraction, percentile, and goodput
//!    field of the stats JSON.

use std::collections::HashMap;
use wienna::cluster::{
    AdmissionConfig, ClassMix, Cluster, ClusterConfig, SyncConfig, TrafficClass,
};
use wienna::config::DesignPoint;
use wienna::fault::{ContentionConfig, FaultPlan};
use wienna::serve::{ms_to_cycles, MixEntry, ModelKind, PackageSpec, Source, WorkloadMix};
use wienna::telemetry::TelemetryConfig;

fn mix(slo_ms: f64) -> WorkloadMix {
    WorkloadMix::new(vec![MixEntry {
        kind: ModelKind::TinyCnn,
        weight: 1.0,
        slo_cycles: ms_to_cycles(slo_ms),
    }])
}

fn chaos_config(faults: &str, contention: f64, steal: bool, threads: usize) -> ClusterConfig {
    ClusterConfig {
        shards: 4,
        threads,
        admission: AdmissionConfig::admit_all(),
        sync: SyncConfig { steal, epoch_cycles: ms_to_cycles(0.25), ..Default::default() },
        faults: FaultPlan::parse(faults).expect("test fault spec"),
        contention: if contention > 0.0 {
            ContentionConfig::with_background(contention)
        } else {
            ContentionConfig::default()
        },
        telemetry: TelemetryConfig::enabled(),
        ..Default::default()
    }
}

/// Acceptance criterion of the fault tentpole: the seeded chaos scenario
/// — a package killed mid-run inside a cluster-wide contention spike,
/// closed-loop clients observing the failures, stealing + failover on —
/// is bit-identical at 1/2/4 worker threads, books token-wait cycles,
/// and still conserves every request.
#[test]
fn seeded_chaos_scenario_is_bit_identical_across_threads() {
    let run = |threads: usize| {
        let cfg = chaos_config("kill:1@2..6;spike:0.4@1..5", 0.3, true, threads);
        let cluster = Cluster::new(PackageSpec::homogeneous(8, DesignPoint::WIENNA_C), cfg);
        let mut source = Source::closed_loop(mix(40.0), 24, 0.3, 12, 2026);
        cluster.run(&mut source, f64::INFINITY)
    };
    let t1 = run(1);
    let t2 = run(2);
    let t4 = run(4);
    assert_eq!(t1.serve.arrived(), 24 * 12, "every client request was issued");
    assert!(t1.serve.completed() > 0, "the fleet survives the plan");
    assert_eq!(
        t1.serve.arrived(),
        t1.serve.completed() + t1.serve.shed() + t1.serve.failed(),
        "conservation under chaos"
    );
    assert!(t1.token_wait_cycles > 0.0, "contention books token-wait time");
    let (j1, j2, j4) = (t1.to_json(), t2.to_json(), t4.to_json());
    assert_eq!(j1, j2, "1-thread vs 2-thread chaos stats JSON diverged");
    assert_eq!(j1, j4, "1-thread vs 4-thread chaos stats JSON diverged");
    assert_eq!(t1.serve.latency_ms(99.0).to_bits(), t4.serve.latency_ms(99.0).to_bits());
    assert_eq!(t1.token_wait_cycles.to_bits(), t4.token_wait_cycles.to_bits());
    assert_eq!(t1.retries(), t4.retries());
    assert_eq!(t1.reroutes(), t4.reroutes());
}

/// A disabled fault layer is byte-invisible: empty plan + contention off
/// produces the exact JSON of a build that never heard of `wienna::fault`
/// (pinned against the same config with the fields defaulted).
#[test]
fn empty_plan_and_disabled_contention_change_nothing() {
    let run = |cfg: ClusterConfig| {
        let cluster = Cluster::new(PackageSpec::homogeneous(4, DesignPoint::WIENNA_C), cfg);
        let mut source = Source::poisson(mix(25.0), 5000.0, 7);
        cluster.run(&mut source, ms_to_cycles(10.0)).to_json()
    };
    let defaulted = run(ClusterConfig { shards: 2, threads: 2, ..Default::default() });
    let explicit = run(ClusterConfig {
        shards: 2,
        threads: 2,
        faults: FaultPlan::parse("").unwrap(),
        contention: ContentionConfig::default(),
        ..Default::default()
    });
    assert_eq!(defaulted, explicit, "disabled chaos must be byte-invisible");
}

/// Conservation property under randomized seeded plans (trace audit):
/// across kill / degrade / stall / spike plans and both source families,
/// `completed + shed + failed == arrived` per class and globally, and
/// the merged event trace finalizes every arrived id exactly once — on
/// exactly one shard — however many retries and failover moves happened
/// along the way.
#[test]
fn seeded_plans_conserve_requests_and_finalize_each_id_once() {
    let plans = [
        "kill:0@1..3",
        "kill:1@1;kill:5@1", // both packages of shard 1, permanently
        "degrade:2:3.0@0.5..4;spike:0.5@1..3",
        "stall:3@1..2;kill:6@2..5",
        "kill:0@1..2;kill:4@1.5..3;degrade:1:2.0@0..6",
    ];
    for (trial, spec) in plans.iter().enumerate() {
        for steal in [false, true] {
            let cfg = chaos_config(spec, if trial % 2 == 0 { 0.2 } else { 0.0 }, steal, 2);
            let cluster = Cluster::new(PackageSpec::homogeneous(8, DesignPoint::WIENNA_C), cfg);
            let mut source =
                Source::closed_loop(mix(30.0), 16, 0.2, 8, 0xC0FFEE + trial as u64);
            let (stats, trace) = cluster.run_traced(&mut source, f64::INFINITY);
            let label = format!("plan {trial} ({spec}), steal {steal}");

            assert_eq!(
                stats.serve.arrived(),
                stats.serve.completed() + stats.serve.shed() + stats.serve.failed(),
                "{label}: arrived != completed + shed + failed"
            );
            for (class, m) in &stats.per_class {
                assert_eq!(
                    m.arrived,
                    m.completed + m.shed + m.failed,
                    "{label}: class {} does not balance",
                    class.label()
                );
            }
            // Every id finalized exactly once, on exactly one shard.
            let mut seen: HashMap<u64, usize> = HashMap::new();
            for ev in &trace {
                if let Some(prev) = seen.insert(ev.id, ev.shard) {
                    panic!(
                        "{label}: request {} finalized on shard {} and shard {}",
                        ev.id, prev, ev.shard
                    );
                }
            }
            assert_eq!(
                seen.len() as u64,
                stats.serve.arrived(),
                "{label}: trace covers every request exactly once"
            );
        }
    }
}

/// Recovery (failover satellite): kill both packages of one shard
/// permanently under closed-loop load. With stealing on, the failover
/// pass re-homes the dead shard's backlog onto survivors; without it,
/// everything striped to that shard is stranded and eventually failed.
#[test]
fn failover_rescues_a_dead_shards_backlog() {
    let run = |steal: bool| {
        // Globals 1 and 5 on an 8-package / 4-shard fleet are exactly
        // shard 1's two local packages — killed for good at 1 ms.
        let cfg = chaos_config("kill:1@1;kill:5@1", 0.0, steal, 2);
        let cluster = Cluster::new(PackageSpec::homogeneous(8, DesignPoint::WIENNA_C), cfg);
        let mut source = Source::closed_loop(mix(40.0), 24, 0.3, 8, 404);
        cluster.run(&mut source, f64::INFINITY)
    };
    let stranded = run(false);
    let rescued = run(true);
    assert_eq!(stranded.serve.arrived(), rescued.serve.arrived(), "same offered load");
    assert!(
        stranded.serve.failed() > 0,
        "without failover, the dead shard's clients must observe failures"
    );
    assert!(rescued.reroutes() > 0, "failover must re-home the dead shard's queue");
    assert!(
        rescued.serve.completed() > stranded.serve.completed(),
        "failover recovers goodput: {} vs {} completions",
        rescued.serve.completed(),
        stranded.serve.completed()
    );
    assert!(
        rescued.serve.failed() < stranded.serve.failed(),
        "failover cuts terminal failures: {} vs {}",
        rescued.serve.failed(),
        stranded.serve.failed()
    );
    // The drain gauge saw the shard die and (eventually) empty out.
    assert!(rescued.dead_shard_drain_ms() >= 0.0);
}

/// Sub-epoch drain resolution (PR 9 satellite): the drain gauge ends at
/// the exact finalization cycle of the last request failover-rerouted
/// off the dead shard, not at the epoch barrier that happened to follow
/// it. Death is stamped at a barrier — an exact multiple of the epoch
/// length — so an epoch-edge drain bound would make the measured drain
/// an exact multiple too; the refined gauge lands strictly inside a
/// window. The gauge is also thread-count-invariant.
#[test]
fn dead_shard_drain_is_measured_at_sub_epoch_resolution() {
    let epoch_cycles = ms_to_cycles(0.25); // what chaos_config configures
    let run = |threads: usize| {
        let cfg = chaos_config("kill:1@1;kill:5@1", 0.0, true, threads);
        let cluster = Cluster::new(PackageSpec::homogeneous(8, DesignPoint::WIENNA_C), cfg);
        let mut source = Source::closed_loop(mix(40.0), 24, 0.3, 8, 404);
        cluster.run(&mut source, f64::INFINITY)
    };
    let stats = run(2);
    assert!(stats.reroutes() > 0, "failover must re-home the dead shard's queue");
    let drain = stats.dead_shard_drain_cycles;
    assert!(drain > 0.0, "the dead shard took time to drain");
    let frac = (drain / epoch_cycles).fract();
    assert!(
        frac > 1e-6 && frac < 1.0 - 1e-6,
        "drain {drain} cycles is epoch-edge-rounded (epoch {epoch_cycles}, fraction {frac})"
    );
    assert_eq!(
        drain.to_bits(),
        run(1).dead_shard_drain_cycles.to_bits(),
        "drain gauge depends on the worker-thread count"
    );
    assert_eq!(
        drain.to_bits(),
        run(4).dead_shard_drain_cycles.to_bits(),
        "drain gauge depends on the worker-thread count"
    );
}

/// No-bounce property (stealing satellite): with hysteresis, a stolen
/// request is never stolen again — in a fault-free steal-heavy run every
/// recorded hand-off flow carries a distinct request id, and there is
/// exactly one flow per counted steal.
#[test]
fn stolen_work_never_bounces_between_shards() {
    use wienna::workload::trace::synthetic_arrivals;
    let cluster = Cluster::new(
        PackageSpec::homogeneous(4, DesignPoint::WIENNA_C),
        ClusterConfig {
            shards: 4,
            threads: 2,
            classes: ClassMix::single(TrafficClass::Interactive, 1.0, false),
            admission: AdmissionConfig::admit_all(),
            batcher: wienna::serve::BatcherConfig { max_batch: 8, candidates: vec![1, 2, 4, 8] },
            sync: SyncConfig { steal: true, epoch_cycles: ms_to_cycles(0.1), ..Default::default() },
            telemetry: TelemetryConfig::enabled(),
            ..Default::default()
        },
    );
    let counts: Vec<usize> = (0..64).map(|i| if i % 4 == 0 { 40 } else { 1 }).collect();
    let traces = synthetic_arrivals(&counts, 0.02, 0.5, 9);
    let mut source = Source::client_trace(mix(25.0), &traces, 9);
    let stats = cluster.run(&mut source, f64::INFINITY);
    assert!(stats.steals > 0, "the hot stripe must donate work");
    let flows = &stats.telemetry.as_ref().expect("telemetry on").log.flows;
    assert_eq!(
        flows.len() as u64,
        stats.steals,
        "no faults: every flow is a steal, every steal leaves one flow"
    );
    let mut ids: Vec<u64> = flows.iter().map(|f| f.id).collect();
    ids.sort_unstable();
    let before = ids.len();
    ids.dedup();
    assert_eq!(ids.len(), before, "a request id appears in two flows — stolen work bounced");
    for f in flows {
        assert_ne!(f.from_shard, f.to_shard, "a flow must cross shards");
    }
}

/// Zero-guard regression (satellite): a cap-0 run completes nothing;
/// every fraction, percentile, and goodput field of the stats JSON must
/// read `0`, not `NaN`/`null`, in both the fault-free and chaotic
/// configurations.
#[test]
fn zero_completion_runs_emit_zeroes_not_nan() {
    for spec in ["", "kill:0@1..2"] {
        let cluster = Cluster::new(
            PackageSpec::homogeneous(4, DesignPoint::WIENNA_C),
            ClusterConfig {
                shards: 2,
                threads: 2,
                admission: AdmissionConfig { queue_cap: Some(0), shed_late: false },
                faults: FaultPlan::parse(spec).unwrap(),
                ..Default::default()
            },
        );
        let mut source = Source::poisson(mix(25.0), 3000.0, 3);
        let stats = cluster.run(&mut source, ms_to_cycles(5.0));
        assert!(stats.serve.arrived() > 0, "traffic was offered");
        assert_eq!(stats.serve.completed(), 0, "cap 0 completes nothing");
        let json = stats.to_json();
        assert!(!json.contains("NaN"), "stats JSON leaked a NaN (faults {spec:?}):\n{json}");
        assert!(!json.contains("null"), "stats JSON leaked a null (faults {spec:?}):\n{json}");
        for field in
            ["p50_ms", "p95_ms", "p99_ms", "tail_amplification", "goodput_rps", "mean_batch",
             "queue_frac", "dist_frac", "compute_frac", "collect_frac", "throttle_frac"]
        {
            assert!(
                json.contains(&format!("\"{field}\": 0")),
                "{field} should be zero-guarded (faults {spec:?}):\n{json}"
            );
        }
        assert_eq!(stats.tail_amplification(), 0.0);
        assert_eq!(stats.failover_goodput_rps(), 0.0);
    }
}
