//! Validation: the analytical mesh NoP model against the cycle-level
//! simulator. The analytic model is the engine behind every figure, so
//! its serialization and fill-latency assumptions are bounded here.

use wienna::config::{DesignPoint, SystemConfig};
use wienna::coordinator::collective::{simulate_collection, simulate_distribution};
use wienna::coordinator::{Coordinator, StrategyPolicy};
use wienna::dataflow::Strategy;
use wienna::nop::sim::{MeshSim, NodeId, Transfer};
use wienna::nop::MeshNop;
use wienna::workload::{conv_padded, resnet50::resnet50, Layer};

/// Relative agreement bound between the sim and the analytic model for
/// distribution phases. Pipelining effects and per-column packing differ,
/// so the bound is loose but two-sided (the model is neither wildly
/// optimistic nor pessimistic).
const AGREEMENT: f64 = 2.0;

fn check_layer(layer: &Layer, nc: u64, strategy: Strategy) {
    let sys = SystemConfig { num_chiplets: nc, pes_per_chiplet: 64, ..Default::default() };
    let side = sys.mesh_side() as u32;
    let coord = Coordinator::new(sys, DesignPoint::INTERPOSER_A, StrategyPolicy::Fixed(strategy));
    let sched = coord.schedule_layer(layer);
    let analytic = sched.selection.cost.timeline.preload + sched.selection.cost.timeline.stream;
    let sim = simulate_distribution(&sched, side, DesignPoint::INTERPOSER_A.distribution_bw());
    let ratio = sim.makespan / analytic.max(1.0);
    assert!(
        ratio > 1.0 / AGREEMENT && ratio < AGREEMENT,
        "{} {strategy} on {nc} chiplets: sim {} vs analytic {analytic} (ratio {ratio:.2})",
        layer.name,
        sim.makespan,
    );
}

#[test]
fn distribution_agreement_across_strategies() {
    let layer = conv_padded("c", 4, 64, 32, 28, 28, 3, 3, 1);
    for s in Strategy::ALL {
        for nc in [16u64, 64] {
            check_layer(&layer, nc, s);
        }
    }
}

#[test]
fn distribution_agreement_on_resnet_prefix() {
    let m = resnet50(4);
    for l in m.layers.iter().take(8) {
        check_layer(l, 16, Strategy::KpCp);
    }
}

#[test]
fn injected_copies_match_analytic_amplification() {
    // A broadcast of B bytes to all nodes must inject ~dests copies in
    // the no-multicast baseline (packetization may add a few).
    let sim = MeshSim::new(8, 16.0);
    let r = sim.run_distribution(&[Transfer::broadcast(4096, 8)]);
    assert_eq!(r.injected_copies, 64);
    let mesh = MeshNop::new(64, 16.0, true);
    assert_eq!(mesh.injection_copies(64.0), 64.0);
}

#[test]
fn collection_agreement() {
    let sys = SystemConfig { num_chiplets: 64, pes_per_chiplet: 64, ..Default::default() };
    let coord = Coordinator::new(sys.clone(), DesignPoint::INTERPOSER_A, StrategyPolicy::Fixed(Strategy::KpCp));
    let layer = conv_padded("c", 2, 64, 32, 28, 28, 3, 3, 1);
    let sched = coord.schedule_layer(&layer);
    let sim = simulate_collection(&sched, 8, sys.collection_bw_per_link);
    let mesh = MeshNop::new(64, sys.collection_bw_per_link, true);
    let analytic = mesh.collection_cycles(sched.plan.collect_bytes);
    let ratio = sim.makespan / analytic.max(1.0);
    // Collection converges on the drain links; the analytic model uses
    // the aggregate-edge approximation.
    assert!(ratio > 0.5 && ratio < 4.0, "sim {} vs analytic {analytic} ({ratio:.2})", sim.makespan);
}

#[test]
fn sim_hop_latency_visible_on_small_transfers() {
    // A tiny unicast to the far corner is latency- (not bandwidth-)
    // dominated: makespan ≈ hops + ser.
    let sim = MeshSim::new(16, 16.0);
    let r = sim.run_distribution(&[Transfer::unicast(16, NodeId::new(15, 15))]);
    assert!((r.makespan - (31.0 + 1.0)).abs() < 1e-9, "makespan {}", r.makespan);
}

#[test]
fn wireless_mac_schedule_matches_analytic_model() {
    // The TDM MAC (link layer) and the WirelessNop analytic model must
    // agree on distribution time up to per-slot overhead.
    use wienna::nop::{TdmMac, WirelessNop};
    use wienna::nop::transceiver::TrxDesignPoint;

    let sys = SystemConfig { num_chiplets: 64, pes_per_chiplet: 64, ..Default::default() };
    let coord = Coordinator::new(sys, DesignPoint::WIENNA_C, StrategyPolicy::Adaptive);
    let layer = conv_padded("c", 4, 64, 32, 28, 28, 3, 3, 1);
    let sched = coord.schedule_layer(&layer);

    let all: Vec<Transfer> = sched.preload.iter().chain(sched.stream.iter()).cloned().collect();
    let mac = TdmMac { bw: 16.0, reconfig_guard_cycles: 0.0, slot_overhead_cycles: 0.0 };
    let tdm = mac.compile(&all, false);
    assert!(mac.verify(&tdm), "TDM schedule must be collision-free");

    let w = WirelessNop::new(16.0, TrxDesignPoint::Conservative);
    let analytic = w.distribution(&sched.plan.traffic);
    let analytic_total = analytic.preload_cycles + analytic.stream_cycles;
    let ratio = tdm.makespan / analytic_total;
    assert!(
        (ratio - 1.0).abs() < 0.05,
        "TDM {} vs analytic {analytic_total} (ratio {ratio:.3})",
        tdm.makespan
    );
}

#[test]
fn wireless_mac_feasible_at_package_scale() {
    // Close the loop down to the physical layer: the Table-4 air rates
    // must be feasible on the engineered package channel.
    use wienna::nop::{Channel, TdmMac};
    let ch = Channel::default();
    assert!(TdmMac::new(16.0).feasible_on(&ch, 0.040, 10.0, 1e-9));
    assert!(TdmMac::new(32.0).feasible_on(&ch, 0.040, 10.0, 1e-12));
}

#[test]
fn forwarding_ablation_strictly_faster_on_broadcasts() {
    let base = MeshSim::new(8, 16.0);
    let mut fwd = MeshSim::new(8, 16.0);
    fwd.multicast_forwarding = true;
    let t = vec![Transfer::broadcast(4096, 8); 4];
    let rb = base.run_distribution(&t);
    let rf = fwd.run_distribution(&t);
    assert!(
        rf.makespan < rb.makespan / 4.0,
        "forwarding {} vs baseline {}",
        rf.makespan,
        rb.makespan
    );
}
