//! End-to-end integration over the PJRT runtime: artifacts -> executable
//! cache -> package executor -> numerics vs the naive oracle.
//!
//! These tests need `make artifacts` to have run; they are skipped (with
//! a loud message) when the artifact directory is absent so that pure
//! Rust-side CI still passes.

use std::path::Path;
use std::sync::Arc;
use wienna::config::{DesignPoint, SystemConfig};
use wienna::coordinator::exec::{deterministic_weights, naive_conv, Tensor};
use wienna::coordinator::{Coordinator, PackageExecutor, StrategyPolicy};
use wienna::dataflow::Strategy;
use wienna::runtime::ExecutableCache;
use wienna::workload::tiny::tiny_cnn;
use wienna::workload::Layer;

fn artifacts_dir() -> Option<&'static Path> {
    let p = Path::new("artifacts");
    if p.join("manifest.txt").exists() {
        Some(p)
    } else {
        eprintln!("SKIPPED: artifacts/ not built (run `make artifacts`)");
        None
    }
}

fn cache() -> Option<Arc<ExecutableCache>> {
    artifacts_dir().map(|d| Arc::new(ExecutableCache::new(d).expect("load artifacts")))
}

#[test]
fn manifest_has_expected_artifacts() {
    let Some(c) = cache() else { return };
    assert!(c.manifest().get("matmul64").is_ok());
    assert!(c.manifest().get("add4096").is_ok());
}

#[test]
fn matmul_artifact_matches_cpu_reference() {
    let Some(c) = cache() else { return };
    // a = counting matrix, b = identity-ish.
    let a: Vec<f32> = (0..64 * 64).map(|i| (i % 13) as f32 * 0.25 - 1.0).collect();
    let mut b = vec![0.0f32; 64 * 64];
    for i in 0..64 {
        b[i * 64 + i] = 2.0;
    }
    let out = c.execute_f32("matmul64", &[&a, &b]).unwrap();
    for i in 0..64 * 64 {
        assert!((out[i] - 2.0 * a[i]).abs() < 1e-4, "elem {i}: {} vs {}", out[i], 2.0 * a[i]);
    }
}

#[test]
fn add_artifact_adds() {
    let Some(c) = cache() else { return };
    let a: Vec<f32> = (0..4096).map(|i| i as f32).collect();
    let b: Vec<f32> = (0..4096).map(|i| -2.0 * i as f32).collect();
    let out = c.execute_f32("add4096", &[&a, &b]).unwrap();
    for i in 0..4096 {
        assert_eq!(out[i], -(i as f32));
    }
}

#[test]
fn wrong_input_shapes_rejected() {
    let Some(c) = cache() else { return };
    let short = vec![0.0f32; 10];
    let ok = vec![0.0f32; 64 * 64];
    assert!(c.execute_f32("matmul64", &[&short, &ok]).is_err());
    assert!(c.execute_f32("matmul64", &[&ok]).is_err());
    assert!(c.execute_f32("no_such_artifact", &[&ok, &ok]).is_err());
}

#[test]
fn conv_layer_via_xla_matches_oracle() {
    let Some(c) = cache() else { return };
    let sys = SystemConfig { num_chiplets: 16, pes_per_chiplet: 64, ..Default::default() };
    let coord = Coordinator::new(sys, DesignPoint::WIENNA_C, StrategyPolicy::Fixed(Strategy::KpCp));
    let mut exec = PackageExecutor::new(coord, c);
    let layer = wienna::workload::conv_padded("itest", 1, 8, 4, 12, 12, 3, 3, 1);
    let input = Tensor::from_fn(1, 4, 12, 12, |_, ci, y, x| ((ci * 31 + y * 7 + x) % 11) as f32 * 0.1 - 0.5);
    let w = deterministic_weights("itest", 8, 4, 3, 3);
    let (out, stats) = exec.conv_layer(&layer, &input, &w).unwrap();
    let oracle = naive_conv(&layer, &input, &w);
    let err = out
        .data
        .iter()
        .zip(oracle.data.iter())
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(err < 1e-4, "max err {err}");
    assert!(stats.tiles_dispatched > 0);
}

#[test]
fn full_tiny_cnn_e2e_all_policies() {
    let Some(c) = cache() else { return };
    let input = Tensor::from_fn(1, 16, 32, 32, |_, ci, y, x| ((ci * 5 + y * 3 + x) % 17) as f32 * 0.05 - 0.4);
    for policy in [
        StrategyPolicy::Adaptive,
        StrategyPolicy::Fixed(Strategy::KpCp),
        StrategyPolicy::Fixed(Strategy::YpXp),
    ] {
        let sys = SystemConfig { num_chiplets: 16, pes_per_chiplet: 64, ..Default::default() };
        let coord = Coordinator::new(sys, DesignPoint::WIENNA_C, policy);
        let mut exec = PackageExecutor::new(coord, c.clone());
        let report = exec.run_model(&tiny_cnn(1), &input).unwrap();
        assert!(
            report.max_abs_err < 1e-3,
            "{policy:?}: max err {}",
            report.max_abs_err
        );
        assert_eq!(report.output_len, 64);
        // Numerics must be identical regardless of the partition policy —
        // partitioning moves data, it must not change math.
    }
}

#[test]
fn corrupt_hlo_artifact_fails_loudly_not_silently() {
    // Failure injection: a manifest that points at garbage HLO text must
    // fail at compile time with a useful error, not produce numbers.
    use wienna::testutil::TempDir;
    let d = TempDir::new("wienna_corrupt");
    std::fs::write(
        d.path().join("manifest.txt"),
        "version 1\nartifact bad bad.hlo.txt f32 2x2;2x2 2x2\n",
    )
    .unwrap();
    std::fs::write(d.path().join("bad.hlo.txt"), "this is not HLO text {{{").unwrap();
    let cache = ExecutableCache::new(d.path()).expect("manifest itself is well-formed");
    let a = vec![0.0f32; 4];
    let err = cache.execute_f32("bad", &[&a, &a]).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("bad"), "error should name the artifact: {msg}");
}

#[test]
fn truncated_manifest_rejected() {
    use wienna::testutil::TempDir;
    let d = TempDir::new("wienna_trunc");
    std::fs::write(d.path().join("manifest.txt"), "version 1\nartifact m m.hlo.txt f32\n").unwrap();
    assert!(ExecutableCache::new(d.path()).is_err());
}

#[test]
fn residual_layer_via_xla() {
    let Some(c) = cache() else { return };
    let sys = SystemConfig { num_chiplets: 16, pes_per_chiplet: 64, ..Default::default() };
    let coord = Coordinator::new(sys, DesignPoint::WIENNA_C, StrategyPolicy::Adaptive);
    let mut exec = PackageExecutor::new(coord, c);
    let a = Tensor::from_fn(1, 8, 10, 10, |_, ci, y, x| (ci + y + x) as f32);
    let b = Tensor::from_fn(1, 8, 10, 10, |_, ci, y, x| -((ci * y * x) as f32));
    let layer = Layer::residual("r", 1, 8, 10, 10);
    let (out, _) = exec.residual_layer(&layer, &a, &b).unwrap();
    for i in 0..a.data.len() {
        assert_eq!(out.data[i], a.data[i] + b.data[i]);
    }
}
