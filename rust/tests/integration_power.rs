//! Integration tests for `wienna::power`: energy conservation, governor
//! behavior under a cap, thread-count determinism of the energy-extended
//! cluster stats JSON, and the Pareto mode of the auto-sizer.

use wienna::cluster::{AdmissionConfig, Cluster, ClusterConfig, SyncConfig, TrafficClass};
use wienna::config::DesignPoint;
use wienna::fault::FaultPlan;
use wienna::power::{dominates, PowerConfig};
use wienna::search::{autosize, AutosizeConfig, CostModel, FleetPlan, SearchSpace};
use wienna::serve::{
    ms_to_cycles, Fleet, MixEntry, ModelKind, PackageSpec, RoutePolicy, ServeStats, Source,
    WorkloadMix,
};

fn tiny_mix(slo_ms: f64) -> WorkloadMix {
    WorkloadMix::new(vec![MixEntry {
        kind: ModelKind::TinyCnn,
        weight: 1.0,
        slo_cycles: ms_to_cycles(slo_ms),
    }])
}

fn run_fleet(packages: usize, load: f64, power: PowerConfig) -> ServeStats {
    let mut fleet = Fleet::new(
        PackageSpec::homogeneous(packages, DesignPoint::WIENNA_C),
        RoutePolicy::EarliestDeadline,
    )
    .with_power(power);
    let mix = tiny_mix(50.0);
    let cap = fleet.estimate_capacity_rps(&mix, 8);
    let mut source = Source::poisson(mix, cap * load, 7);
    let mut stats = ServeStats::new();
    fleet.run(&mut source, ms_to_cycles(25.0), &mut stats);
    stats
}

fn run_cluster(threads: usize, rate: f64, cfg: ClusterConfig) -> wienna::cluster::ClusterStats {
    let cluster = Cluster::new(
        PackageSpec::homogeneous(4, DesignPoint::WIENNA_C),
        ClusterConfig { shards: 4, threads, ..cfg },
    );
    let mut source = Source::poisson(tiny_mix(25.0), rate, 42);
    cluster.run(&mut source, ms_to_cycles(10.0))
}

#[test]
fn fleet_average_power_respects_the_cap() {
    // Establish the uncapped draw, then cap at 70% of it: the governor's
    // conservative projection (active-rate leakage floor for the whole
    // fleet) means the realized average can only land below the cap.
    let base = run_fleet(2, 0.9, PowerConfig::default());
    let e0 = base.energy.unwrap();
    let p0 = e0.avg_power_w(base.end_cycle());
    assert!(p0 > 0.0);
    let cap = 0.7 * p0;
    // Scenario precondition: the cap must sit above the un-gateable
    // leakage floor, or no governor could ever meet it.
    let power = PowerConfig::with_cap(cap);
    let floor =
        2.0 * power.model.active_leakage_w(&wienna::config::SystemConfig::default());
    assert!(cap > floor * 1.1, "ill-posed scenario: cap {cap:.1} W vs leakage floor {floor:.1} W");
    let capped = run_fleet(2, 0.9, power);
    let e1 = capped.energy.unwrap();
    assert!(e1.throttled_batches > 0, "a 0.7x cap should throttle at 0.9x load");
    let achieved = e1.avg_power_w(capped.end_cycle());
    assert!(achieved <= cap * 1.05, "avg {achieved:.1} W vs cap {cap:.1} W");
    // Closed loop, not bookkeeping: the same requests completed, later.
    assert_eq!(base.completed(), capped.completed());
    assert!(capped.end_cycle() > base.end_cycle());
}

#[test]
fn cluster_energy_conserves_per_class_and_per_package() {
    // Overloaded default cluster (preemption + admission on): per-class
    // dynamic energies must still sum to the fleet's dynamic total, and
    // the fleet total to the per-package meters.
    let stats = run_cluster(2, 20_000.0, ClusterConfig::default());
    assert!(stats.preemptions > 0 || stats.serve.shed() > 0, "want a stressed run");
    let by_class: f64 = stats.class_energy_mj.iter().sum();
    let dynamic = stats.energy.dynamic_mj();
    assert!(dynamic > 0.0);
    assert!(
        (by_class - dynamic).abs() <= 1e-9 * dynamic.max(1.0),
        "class sum {by_class} vs fleet dynamic {dynamic}"
    );
    let by_package: f64 = stats.packages.iter().map(|p| p.meter.dynamic_mj()).sum();
    assert!(
        (by_package - dynamic).abs() <= 1e-9 * dynamic.max(1.0),
        "package sum {by_package} vs fleet dynamic {dynamic}"
    );
    // Every class that completed work burned energy.
    for (class, m) in &stats.per_class {
        if m.completed > 0 {
            assert!(
                stats.class_energy_mj[class.index()] > 0.0,
                "{} completed {} requests on zero energy",
                class.label(),
                m.completed
            );
        }
    }
}

#[test]
fn cluster_stats_json_with_energy_is_thread_count_invariant() {
    // The determinism gate, governor engaged: capped runs must still be
    // bit-identical across worker-thread counts (the cap partitions
    // statically across shards, never across threads). The cap derives
    // from the uncapped run's measured draw so it reliably bites.
    let base = run_cluster(1, 8_000.0, ClusterConfig::default());
    let p0 = base.energy.avg_power_w(base.serve.end_cycle());
    assert!(p0 > 0.0);
    let cfg = || ClusterConfig { power: PowerConfig::with_cap(0.5 * p0), ..Default::default() };
    let a = run_cluster(1, 8_000.0, cfg());
    let b = run_cluster(2, 8_000.0, cfg());
    let c = run_cluster(4, 8_000.0, cfg());
    assert_eq!(a.to_json(), b.to_json(), "1 vs 2 threads (capped)");
    assert_eq!(a.to_json(), c.to_json(), "1 vs 4 threads (capped)");
    assert!(a.to_json().contains("\"dynamic_mj\": "));
    assert!(a.energy.throttled_batches > 0, "a 0.5x cap should bite");
}

#[test]
fn uncapped_cluster_latency_stats_match_a_power_disabled_config() {
    // Energy is additive: flipping power gating (which changes only the
    // leakage integral) must leave every latency statistic identical.
    let gated = run_cluster(2, 6_000.0, ClusterConfig::default());
    let ungated = run_cluster(
        2,
        6_000.0,
        ClusterConfig {
            power: PowerConfig {
                model: wienna::power::PowerModel {
                    power_gating: false,
                    ..Default::default()
                },
                ..Default::default()
            },
            ..Default::default()
        },
    );
    assert_eq!(gated.serve.completed(), ungated.serve.completed());
    assert_eq!(gated.serve.end_cycle(), ungated.serve.end_cycle());
    assert_eq!(gated.serve.latency_ms(99.0), ungated.serve.latency_ms(99.0));
    assert_eq!(gated.energy.dynamic_mj(), ungated.energy.dynamic_mj());
    assert!(gated.energy.leakage_mj < ungated.energy.leakage_mj, "gating must save leakage");
    // Interactive class exists and its latency is unchanged too.
    assert_eq!(
        gated.class_latency_ms(TrafficClass::Interactive, 99.0),
        ungated.class_latency_ms(TrafficClass::Interactive, 99.0)
    );
}

#[test]
fn search_pareto_front_survives_exhaustive_dominance_audit() {
    let mix = tiny_mix(20.0);
    let mut cfg = AutosizeConfig::new(20.0, 1800.0, mix);
    cfg.horizon_ms = 10.0;
    cfg.threads = 2;
    let r = autosize(&cfg, &SearchSpace::tiny(), &CostModel::default());
    assert!(!r.plans.is_empty(), "tiny space must produce feasible fleets");
    assert!(!r.pareto.is_empty());
    let triple = |p: &FleetPlan| [p.fleet_cost, p.energy_per_req_j, p.p99_ms];
    let fronts: Vec<[f64; 3]> = r.pareto.iter().map(&triple).collect();
    let all: Vec<[f64; 3]> = r.plans.iter().map(&triple).collect();
    // 1. No front member is dominated by any plan (exhaustive).
    for f in &fronts {
        for p in &all {
            assert!(!dominates(p, f), "front point {f:?} dominated by {p:?}");
        }
    }
    // 2. Every plan off the front is dominated by some front member.
    for p in &all {
        if !fronts.contains(p) {
            assert!(fronts.iter().any(|f| dominates(f, p)), "non-front point {p:?} undominated");
        }
    }
    // 3. The cheapest-only answer is a member of the front.
    let best = triple(&r.best.expect("feasible search has a best plan"));
    assert!(fronts.contains(&best), "cheapest answer {best:?} missing from the front");
    // 4. Probed energies are real measurements.
    for p in &r.plans {
        assert!(p.energy_per_req_j > 0.0, "plan without probed energy");
    }
}

/// The stranded-cap fix (`SyncConfig::rebalance_caps`), end to end: a
/// fault plan kills every package of one of two shards mid-run under a
/// biting fleet cap. Without rebalancing, the dead shard's half of the
/// cap strands and the survivors — now serving the whole failover load —
/// stay pinned to their original slice. With rebalancing (the default),
/// the barrier re-splits the cap over live packages, so the survivors'
/// slice doubles, the governor picks faster DVFS rungs, and fewer
/// dispatches throttle — while the fleet-average draw still respects the
/// configured cap, and the run stays thread-count-deterministic.
#[test]
fn rebalanced_caps_flow_a_dead_shards_watts_to_the_survivors() {
    // Shard 0 of 2 owns global packages {0, 2, 4, 6}; killing all four
    // at 1 ms leaves shard 1 serving everything from then on. Stealing
    // must be on so the dead shard's backlog fails over.
    let run = |rebalance: bool, cap_w: Option<f64>, threads: usize| {
        let cluster = Cluster::new(
            PackageSpec::homogeneous(8, DesignPoint::WIENNA_C),
            ClusterConfig {
                shards: 2,
                threads,
                admission: AdmissionConfig::admit_all(),
                sync: SyncConfig { steal: true, rebalance_caps: rebalance, ..Default::default() },
                faults: FaultPlan::parse("kill:0@1;kill:2@1;kill:4@1;kill:6@1")
                    .expect("test fault spec"),
                power: match cap_w {
                    Some(w) => PowerConfig::with_cap(w),
                    None => PowerConfig::default(),
                },
                ..Default::default()
            },
        );
        let mut source = Source::closed_loop(tiny_mix(50.0), 24, 0.3, 10, 11);
        cluster.run(&mut source, f64::INFINITY)
    };

    // Size the cap from the measured uncapped draw of the same faulted
    // scenario so it reliably bites on the surviving half of the fleet.
    let base = run(true, None, 2);
    let p0 = base.energy.avg_power_w(base.serve.end_cycle());
    assert!(p0 > 0.0, "baseline run must draw power");
    let cap = 0.6 * p0;

    let on = run(true, Some(cap), 2);
    let off = run(false, Some(cap), 2);

    // Same closed-loop population, conserved, in both modes.
    assert_eq!(on.serve.arrived(), 24 * 10);
    assert_eq!(off.serve.arrived(), 24 * 10);
    for s in [&on, &off] {
        assert!(s.serve.completed() > 0, "survivors must serve the failover load");
        assert_eq!(
            s.serve.arrived(),
            s.serve.completed() + s.serve.shed() + s.serve.failed(),
            "conservation under kill + cap"
        );
    }

    // The cap bites: with half the cap stranded on dead silicon, the
    // survivors cannot run everything at nominal.
    assert!(off.energy.throttled_batches > 0, "a 0.6x cap must throttle the stranded config");
    // Fleet-average draw respects the configured cap either way — the
    // rebalanced slices still sum to the fleet cap.
    for (name, s) in [("rebalanced", &on), ("stranded", &off)] {
        let avg = s.energy.avg_power_w(s.serve.end_cycle());
        assert!(avg <= cap * 1.05, "{name}: avg {avg:.1} W above cap {cap:.1} W");
    }
    // The fix itself: the survivors' doubled slice buys faster DVFS
    // rungs, so strictly fewer dispatches throttle than when the dead
    // shard's watts strand.
    assert!(
        on.energy.throttled_batches < off.energy.throttled_batches,
        "rebalanced caps must throttle less (rebalanced {} vs stranded {})",
        on.energy.throttled_batches,
        off.energy.throttled_batches
    );

    // Determinism gate: the rebalance decision is barrier-state-only,
    // so the fixed run is byte-identical across worker-thread counts.
    let one = run(true, Some(cap), 1);
    assert_eq!(one.to_json(), on.to_json(), "rebalance_caps: 1 vs 2-thread stats diverged");
}

#[test]
fn calibrated_eta_cluster_runs_conserve_and_drain() {
    // The per-decision guarantee (calibrated never sheds what the
    // conservative estimate serves) is property-tested in
    // `cluster::admission` and pinned by the deep-backlog scenario in
    // `cluster::shard`; here the calibrated estimator goes through the
    // full sharded engine: conservation and determinism must hold.
    let cfg = || ClusterConfig { calibrated_eta: true, ..Default::default() };
    let a = run_cluster(1, 20_000.0, cfg());
    let b = run_cluster(4, 20_000.0, cfg());
    assert_eq!(a.to_json(), b.to_json(), "calibrated ETA must stay thread-deterministic");
    assert_eq!(a.serve.arrived(), a.serve.completed() + a.serve.shed());
    assert!(a.serve.completed() > 0);
}
