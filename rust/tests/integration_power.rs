//! Integration tests for `wienna::power`: energy conservation, governor
//! behavior under a cap, thread-count determinism of the energy-extended
//! cluster stats JSON, and the Pareto mode of the auto-sizer.

use wienna::cluster::{Cluster, ClusterConfig, TrafficClass};
use wienna::config::DesignPoint;
use wienna::power::{dominates, PowerConfig};
use wienna::search::{autosize, AutosizeConfig, CostModel, FleetPlan, SearchSpace};
use wienna::serve::{
    ms_to_cycles, Fleet, MixEntry, ModelKind, PackageSpec, RoutePolicy, ServeStats, Source,
    WorkloadMix,
};

fn tiny_mix(slo_ms: f64) -> WorkloadMix {
    WorkloadMix::new(vec![MixEntry {
        kind: ModelKind::TinyCnn,
        weight: 1.0,
        slo_cycles: ms_to_cycles(slo_ms),
    }])
}

fn run_fleet(packages: usize, load: f64, power: PowerConfig) -> ServeStats {
    let mut fleet = Fleet::new(
        PackageSpec::homogeneous(packages, DesignPoint::WIENNA_C),
        RoutePolicy::EarliestDeadline,
    )
    .with_power(power);
    let mix = tiny_mix(50.0);
    let cap = fleet.estimate_capacity_rps(&mix, 8);
    let mut source = Source::poisson(mix, cap * load, 7);
    let mut stats = ServeStats::new();
    fleet.run(&mut source, ms_to_cycles(25.0), &mut stats);
    stats
}

fn run_cluster(threads: usize, rate: f64, cfg: ClusterConfig) -> wienna::cluster::ClusterStats {
    let cluster = Cluster::new(
        PackageSpec::homogeneous(4, DesignPoint::WIENNA_C),
        ClusterConfig { shards: 4, threads, ..cfg },
    );
    let mut source = Source::poisson(tiny_mix(25.0), rate, 42);
    cluster.run(&mut source, ms_to_cycles(10.0))
}

#[test]
fn fleet_average_power_respects_the_cap() {
    // Establish the uncapped draw, then cap at 70% of it: the governor's
    // conservative projection (active-rate leakage floor for the whole
    // fleet) means the realized average can only land below the cap.
    let base = run_fleet(2, 0.9, PowerConfig::default());
    let e0 = base.energy.unwrap();
    let p0 = e0.avg_power_w(base.end_cycle());
    assert!(p0 > 0.0);
    let cap = 0.7 * p0;
    // Scenario precondition: the cap must sit above the un-gateable
    // leakage floor, or no governor could ever meet it.
    let power = PowerConfig::with_cap(cap);
    let floor =
        2.0 * power.model.active_leakage_w(&wienna::config::SystemConfig::default());
    assert!(cap > floor * 1.1, "ill-posed scenario: cap {cap:.1} W vs leakage floor {floor:.1} W");
    let capped = run_fleet(2, 0.9, power);
    let e1 = capped.energy.unwrap();
    assert!(e1.throttled_batches > 0, "a 0.7x cap should throttle at 0.9x load");
    let achieved = e1.avg_power_w(capped.end_cycle());
    assert!(achieved <= cap * 1.05, "avg {achieved:.1} W vs cap {cap:.1} W");
    // Closed loop, not bookkeeping: the same requests completed, later.
    assert_eq!(base.completed(), capped.completed());
    assert!(capped.end_cycle() > base.end_cycle());
}

#[test]
fn cluster_energy_conserves_per_class_and_per_package() {
    // Overloaded default cluster (preemption + admission on): per-class
    // dynamic energies must still sum to the fleet's dynamic total, and
    // the fleet total to the per-package meters.
    let stats = run_cluster(2, 20_000.0, ClusterConfig::default());
    assert!(stats.preemptions > 0 || stats.serve.shed() > 0, "want a stressed run");
    let by_class: f64 = stats.class_energy_mj.iter().sum();
    let dynamic = stats.energy.dynamic_mj();
    assert!(dynamic > 0.0);
    assert!(
        (by_class - dynamic).abs() <= 1e-9 * dynamic.max(1.0),
        "class sum {by_class} vs fleet dynamic {dynamic}"
    );
    let by_package: f64 = stats.packages.iter().map(|p| p.meter.dynamic_mj()).sum();
    assert!(
        (by_package - dynamic).abs() <= 1e-9 * dynamic.max(1.0),
        "package sum {by_package} vs fleet dynamic {dynamic}"
    );
    // Every class that completed work burned energy.
    for (class, m) in &stats.per_class {
        if m.completed > 0 {
            assert!(
                stats.class_energy_mj[class.index()] > 0.0,
                "{} completed {} requests on zero energy",
                class.label(),
                m.completed
            );
        }
    }
}

#[test]
fn cluster_stats_json_with_energy_is_thread_count_invariant() {
    // The determinism gate, governor engaged: capped runs must still be
    // bit-identical across worker-thread counts (the cap partitions
    // statically across shards, never across threads). The cap derives
    // from the uncapped run's measured draw so it reliably bites.
    let base = run_cluster(1, 8_000.0, ClusterConfig::default());
    let p0 = base.energy.avg_power_w(base.serve.end_cycle());
    assert!(p0 > 0.0);
    let cfg = || ClusterConfig { power: PowerConfig::with_cap(0.5 * p0), ..Default::default() };
    let a = run_cluster(1, 8_000.0, cfg());
    let b = run_cluster(2, 8_000.0, cfg());
    let c = run_cluster(4, 8_000.0, cfg());
    assert_eq!(a.to_json(), b.to_json(), "1 vs 2 threads (capped)");
    assert_eq!(a.to_json(), c.to_json(), "1 vs 4 threads (capped)");
    assert!(a.to_json().contains("\"dynamic_mj\": "));
    assert!(a.energy.throttled_batches > 0, "a 0.5x cap should bite");
}

#[test]
fn uncapped_cluster_latency_stats_match_a_power_disabled_config() {
    // Energy is additive: flipping power gating (which changes only the
    // leakage integral) must leave every latency statistic identical.
    let gated = run_cluster(2, 6_000.0, ClusterConfig::default());
    let ungated = run_cluster(
        2,
        6_000.0,
        ClusterConfig {
            power: PowerConfig {
                model: wienna::power::PowerModel {
                    power_gating: false,
                    ..Default::default()
                },
                ..Default::default()
            },
            ..Default::default()
        },
    );
    assert_eq!(gated.serve.completed(), ungated.serve.completed());
    assert_eq!(gated.serve.end_cycle(), ungated.serve.end_cycle());
    assert_eq!(gated.serve.latency_ms(99.0), ungated.serve.latency_ms(99.0));
    assert_eq!(gated.energy.dynamic_mj(), ungated.energy.dynamic_mj());
    assert!(gated.energy.leakage_mj < ungated.energy.leakage_mj, "gating must save leakage");
    // Interactive class exists and its latency is unchanged too.
    assert_eq!(
        gated.class_latency_ms(TrafficClass::Interactive, 99.0),
        ungated.class_latency_ms(TrafficClass::Interactive, 99.0)
    );
}

#[test]
fn search_pareto_front_survives_exhaustive_dominance_audit() {
    let mix = tiny_mix(20.0);
    let mut cfg = AutosizeConfig::new(20.0, 1800.0, mix);
    cfg.horizon_ms = 10.0;
    cfg.threads = 2;
    let r = autosize(&cfg, &SearchSpace::tiny(), &CostModel::default());
    assert!(!r.plans.is_empty(), "tiny space must produce feasible fleets");
    assert!(!r.pareto.is_empty());
    let triple = |p: &FleetPlan| [p.fleet_cost, p.energy_per_req_j, p.p99_ms];
    let fronts: Vec<[f64; 3]> = r.pareto.iter().map(&triple).collect();
    let all: Vec<[f64; 3]> = r.plans.iter().map(&triple).collect();
    // 1. No front member is dominated by any plan (exhaustive).
    for f in &fronts {
        for p in &all {
            assert!(!dominates(p, f), "front point {f:?} dominated by {p:?}");
        }
    }
    // 2. Every plan off the front is dominated by some front member.
    for p in &all {
        if !fronts.contains(p) {
            assert!(fronts.iter().any(|f| dominates(f, p)), "non-front point {p:?} undominated");
        }
    }
    // 3. The cheapest-only answer is a member of the front.
    let best = triple(&r.best.expect("feasible search has a best plan"));
    assert!(fronts.contains(&best), "cheapest answer {best:?} missing from the front");
    // 4. Probed energies are real measurements.
    for p in &r.plans {
        assert!(p.energy_per_req_j > 0.0, "plan without probed energy");
    }
}

#[test]
fn calibrated_eta_cluster_runs_conserve_and_drain() {
    // The per-decision guarantee (calibrated never sheds what the
    // conservative estimate serves) is property-tested in
    // `cluster::admission` and pinned by the deep-backlog scenario in
    // `cluster::shard`; here the calibrated estimator goes through the
    // full sharded engine: conservation and determinism must hold.
    let cfg = || ClusterConfig { calibrated_eta: true, ..Default::default() };
    let a = run_cluster(1, 20_000.0, cfg());
    let b = run_cluster(4, 20_000.0, cfg());
    assert_eq!(a.to_json(), b.to_json(), "calibrated ETA must stay thread-deterministic");
    assert_eq!(a.serve.arrived(), a.serve.completed() + a.serve.shed());
    assert!(a.serve.completed() > 0);
}
