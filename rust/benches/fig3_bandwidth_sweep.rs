//! Fig 3 — The impact of distribution bandwidth on throughput.
//!
//! Sweeps the global-SRAM read bandwidth on an idealized distribution
//! fabric (multicast-free, as the motivation study assumes) and prints
//! MACs/cycle per (layer type x partitioning strategy) for ResNet-50 and
//! UNet. The paper's observations to reproduce:
//!
//! * Observation I — high-res layers favor YP-XP, low-res/FC favor KP-CP;
//! * Observation II — high-res + YP-XP saturates at the 16K MACs/cycle
//!   peak by 64 B/cycle; ResNet-50 low-res saturates around half peak
//!   beyond 128 B/cycle.

use wienna::config::SystemConfig;
use wienna::cost::{evaluate_layer, CostEngine};
use wienna::dataflow::Strategy;
use wienna::report::Table;
use wienna::testutil::bench;
use wienna::workload::{classify, LayerType, Model};
use wienna::workload::{resnet50::resnet50, unet::unet};

const BANDWIDTHS: [f64; 10] = [1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0, 256.0, 512.0];

fn type_throughput(engine: &CostEngine, model: &Model, ty: LayerType, strategy: Strategy) -> f64 {
    let layers: Vec<_> = model.layers.iter().filter(|l| classify(l) == ty).collect();
    if layers.is_empty() {
        return 0.0;
    }
    let mut macs = 0u64;
    let mut cycles = 0.0;
    for l in layers {
        let c = evaluate_layer(engine, l, strategy);
        macs += c.macs;
        cycles += c.latency;
    }
    macs as f64 / cycles
}

fn main() {
    let sys = SystemConfig::default();
    for model in [resnet50(64), unet(64)] {
        println!("\n##### Fig 3 — {} (ideal fabric, swept SRAM read BW)", model.name);
        for ty in model.layer_types() {
            let mut t = Table::new(
                &format!("{} layers — MACs/cycle vs BW (B/cycle)", ty.label()),
                &["strategy", "1", "2", "4", "8", "16", "32", "64", "128", "256", "512"],
            );
            for s in Strategy::ALL {
                let mut row = vec![s.label().to_string()];
                for bw in BANDWIDTHS {
                    let e = CostEngine::ideal(&sys, bw);
                    row.push(format!("{:.0}", type_throughput(&e, &model, ty, s)));
                }
                t.row(row);
            }
            print!("{}", t.render());
            t.save_csv(&format!("bench_out/fig3_{}_{}.csv", model.name, ty.label().to_lowercase().replace('-', ""))).ok();
        }
    }

    // Observation II spot checks.
    let sys = SystemConfig::default();
    let rn = resnet50(64);
    let hi64 = type_throughput(&CostEngine::ideal(&sys, 64.0), &rn, LayerType::HighRes, Strategy::YpXp);
    let peak = sys.total_pes() as f64;
    println!("\nhigh-res YP-XP @64 B/cyc: {:.0} MACs/cyc ({:.0}% of the 16K peak)", hi64, hi64 / peak * 100.0);
    let lo128 = type_throughput(&CostEngine::ideal(&sys, 128.0), &rn, LayerType::LowRes, Strategy::KpCp);
    println!("low-res  KP-CP @128 B/cyc: {:.0} MACs/cyc ({:.0}% of peak)", lo128, lo128 / peak * 100.0);

    // Timing: one full sweep is the unit of work.
    bench("fig3_full_sweep(resnet50)", 10, || {
        let e = CostEngine::ideal(&sys, 64.0);
        Strategy::ALL
            .iter()
            .map(|&s| type_throughput(&e, &rn, LayerType::HighRes, s))
            .sum::<f64>()
    });
}
