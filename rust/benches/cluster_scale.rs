//! Cluster scaling bench: event-loop thread scaling + shed-rate sweep.
//!
//! Part 1 — **thread scaling**: one 16-package WIENNA-C fleet in 8
//! shards serves the canonical CNN/transformer mix at 0.9x capacity for a
//! fixed simulated horizon, timed at 1, 2 and 4 worker threads. The
//! shards are pure functions of their input slices, so every run produces
//! bit-identical stats (asserted) — threads only buy wall-clock. The
//! headline number is the 4-thread speedup over 1 thread (the PR target
//! is > 1.5x on a 4-core runner).
//!
//! Part 2 — **shed-rate sweep**: the same cluster at 1.5x capacity under
//! queue caps from 0 to unbounded, reporting shed %, per-class p99 and
//! goodput — the admission-control dial from "drop everything" to "queue
//! everything".
//!
//! Part 3 — **skewed-mix steal sweep**: closed-loop client traces whose
//! hot clients all stripe to 4 / 2 / 1 of the shards (session-affinity
//! striping makes hot clients hot shards), run with and without the
//! epoch-barrier work-stealing pass. Static striping strands the skewed
//! load on the hot stripe's packages while the rest idle; stealing must
//! recover **>= 20% goodput at the fully-skewed point** (asserted — this
//! is the PR's acceptance criterion).
//!
//! All parts run under a `cost::memo::run_scope` after a warm-up pass,
//! so the timed runs see a hot layer memo (steady-state behavior) and the
//! bench process doesn't leak its working set into `memo::stats()`.

use wienna::cluster::{AdmissionConfig, ClassMix, Cluster, ClusterConfig, SyncConfig, TrafficClass};
use wienna::config::DesignPoint;
use wienna::cost::memo;
use wienna::report::Table;
use wienna::serve::{
    ms_to_cycles, BatcherConfig, Fleet, ModelKind, PackageSpec, RoutePolicy, Source, WorkloadMix,
};
use wienna::testutil::bench;
use wienna::workload::trace::synthetic_arrivals;

const PACKAGES: usize = 16;
const SHARDS: usize = 8;
/// Requests per timed run. Fixed event count (the horizon is derived
/// from it) so per-shard work dwarfs thread spawn/merge overhead and the
/// speedup measures the event loops, whatever the fleet's capacity is.
const SCALE_REQUESTS: f64 = 40_000.0;
const SWEEP_REQUESTS: f64 = 8_000.0;

fn mix() -> WorkloadMix {
    WorkloadMix::cnn_transformer_default()
}

fn run_once(
    threads: usize,
    rate: f64,
    horizon_ms: f64,
    queue_cap: Option<usize>,
) -> wienna::cluster::ClusterStats {
    let cluster = Cluster::new(
        PackageSpec::homogeneous(PACKAGES, DesignPoint::WIENNA_C),
        ClusterConfig {
            shards: SHARDS,
            threads,
            admission: AdmissionConfig { queue_cap, ..Default::default() },
            ..Default::default()
        },
    );
    let mut source = Source::poisson(mix(), rate, 42);
    cluster.run(&mut source, ms_to_cycles(horizon_ms))
}

fn main() {
    println!("##### Cluster scaling ({PACKAGES} packages, {SHARDS} shards)\n");
    let capacity = Fleet::new(
        PackageSpec::homogeneous(PACKAGES, DesignPoint::WIENNA_C),
        RoutePolicy::EarliestDeadline,
    )
    .estimate_capacity_rps(&mix(), 8);
    let rate = 0.9 * capacity;
    let horizon_ms = SCALE_REQUESTS / rate * 1e3;
    println!(
        "estimated fleet capacity {capacity:.0} req/s -> offered {rate:.0} req/s (0.9x) for {horizon_ms:.0} ms (~{SCALE_REQUESTS:.0} requests)\n"
    );

    // Warm the layer memo once so every timed run sees steady state.
    let warm = run_once(1, rate, horizon_ms, Some(256));
    let _scope = memo::run_scope();

    // --- Part 1: thread scaling -----------------------------------------
    // Determinism cross-check once per thread count, OUTSIDE the timed
    // loop: serializing and diffing multi-KB stats JSON is serial work
    // that would deflate the measured speedup (the integration test and
    // the CI gate re-prove this property anyway).
    let reference = warm.to_json();
    for threads in [2usize, 4] {
        let s = run_once(threads, rate, horizon_ms, Some(256));
        assert_eq!(s.to_json(), reference, "thread count changed the stats");
    }
    let mut means = Vec::new();
    for threads in [1usize, 2, 4] {
        let stats = bench(&format!("cluster/{PACKAGES}pkg_{SHARDS}shard_t{threads}"), 5, || {
            run_once(threads, rate, horizon_ms, Some(256)).serve.completed()
        });
        means.push((threads, stats.mean_ns));
    }
    let t1 = means[0].1;
    println!();
    for &(threads, mean) in &means {
        println!(
            "threads {threads}: {:>8.2} ms/run | speedup {:.2}x vs 1 thread",
            mean / 1e6,
            t1 / mean
        );
    }
    let speedup4 = t1 / means[2].1;
    println!(
        "event-loop throughput at 4 threads: {:.2}x vs single-threaded (target > 1.5x)\n",
        speedup4
    );

    // --- Part 2: shed-rate sweep over queue caps ------------------------
    let overload = 1.5 * capacity;
    let sweep_horizon_ms = SWEEP_REQUESTS / overload * 1e3;
    let mut t = Table::new(
        &format!("admission sweep at {overload:.0} req/s (1.5x capacity, {sweep_horizon_ms:.0} ms)"),
        &["queue cap", "shed %", "queue-full", "deadline", "interactive p99 ms", "batch p99 ms", "goodput req/s"],
    );
    for cap in [Some(0usize), Some(1), Some(4), Some(16), Some(64), Some(256), None] {
        let s = run_once(4, overload, sweep_horizon_ms, cap);
        t.row(vec![
            cap.map_or("none".to_string(), |c| c.to_string()),
            format!("{:.1}", s.serve.shed_rate() * 100.0),
            s.shed_queue_full.to_string(),
            s.shed_deadline.to_string(),
            format!("{:.2}", s.class_latency_ms(TrafficClass::Interactive, 99.0)),
            format!("{:.2}", s.class_latency_ms(TrafficClass::Batch, 99.0)),
            format!("{:.0}", s.serve.goodput_rps()),
        ]);
    }
    print!("{}", t.render());
    t.save_csv("bench_out/cluster_shed.csv").ok();

    // --- Part 3: skewed-mix steal sweep ---------------------------------
    // Closed-loop client trace, 64 clients in 4 stripes of 16 (requests
    // stripe by client). The hot stripes' clients issue back-to-back (the
    // recorded cadence far outruns service, so pushback paces them); the
    // rest issue one request each. Single interactive class, admit-all,
    // batch capped at 4 so a hot stripe's two packages can absorb at most
    // 8 of their 16 concurrent clients per dispatch round — backlog stays
    // queued at every barrier, the regime where static striping strands
    // work and stealing pays.
    const STEAL_PACKAGES: usize = 8; // 2 per stripe: absorb 8 < 16 hot clients
    const STRIPES: usize = 4;
    const CLIENTS_PER_STRIPE: usize = 16;
    const HOT_REQUESTS_TOTAL: usize = 4800;
    let steal_mix = WorkloadMix::single(ModelKind::TinyCnn, 50.0);
    let run_skewed = |hot_stripes: usize, steal: bool| {
        let cluster = Cluster::new(
            PackageSpec::homogeneous(STEAL_PACKAGES, DesignPoint::WIENNA_C),
            ClusterConfig {
                shards: STRIPES,
                threads: 4,
                classes: ClassMix::single(TrafficClass::Interactive, 1.0, false),
                admission: AdmissionConfig::admit_all(),
                preemption: false,
                batcher: BatcherConfig { max_batch: 4, candidates: vec![1, 2, 4] },
                sync: SyncConfig { steal, epoch_cycles: ms_to_cycles(0.1), ..Default::default() },
                ..Default::default()
            },
        );
        let per_hot = HOT_REQUESTS_TOTAL / (CLIENTS_PER_STRIPE * hot_stripes);
        let counts: Vec<usize> = (0..STRIPES * CLIENTS_PER_STRIPE)
            .map(|i| if i % STRIPES < hot_stripes { per_hot } else { 1 })
            .collect();
        let traces = synthetic_arrivals(&counts, 0.02, 0.5, 42);
        let mut source = Source::client_trace(steal_mix.clone(), &traces, 42);
        cluster.run(&mut source, f64::INFINITY)
    };
    let mut t = Table::new(
        &format!(
            "skewed-mix steal sweep ({STEAL_PACKAGES} pkg / {STRIPES} shards, ~{HOT_REQUESTS_TOTAL} hot requests)"
        ),
        &["hot stripes", "steals", "static goodput", "steal goodput", "gain", "static p99 ms", "steal p99 ms"],
    );
    let mut gain_at_full_skew = 0.0f64;
    for hot_stripes in [4usize, 2, 1] {
        let stuck = run_skewed(hot_stripes, false);
        let stolen = run_skewed(hot_stripes, true);
        assert_eq!(
            stuck.serve.completed(),
            stolen.serve.completed(),
            "admit-all: stealing must serve exactly the same requests"
        );
        let gain = stolen.serve.goodput_rps() / stuck.serve.goodput_rps();
        if hot_stripes == 1 {
            gain_at_full_skew = gain;
        }
        t.row(vec![
            hot_stripes.to_string(),
            stolen.steals.to_string(),
            format!("{:.0}", stuck.serve.goodput_rps()),
            format!("{:.0}", stolen.serve.goodput_rps()),
            format!("{gain:.2}x"),
            format!("{:.2}", stuck.serve.latency_ms(99.0)),
            format!("{:.2}", stolen.serve.latency_ms(99.0)),
        ]);
    }
    print!("{}", t.render());
    t.save_csv("bench_out/cluster_steal.csv").ok();
    println!(
        "work stealing at full skew (1 hot stripe of {STRIPES}): {gain_at_full_skew:.2}x goodput vs static striping (target >= 1.2x)"
    );
    assert!(
        gain_at_full_skew >= 1.2,
        "stealing must recover >= 20% goodput on the fully-skewed mix, got {gain_at_full_skew:.2}x"
    );

    let ms = memo::stats();
    println!(
        "\nlayer memo: {} entries (cap {}), {:.1}% hit rate ({} hits / {} misses, {} evictions)",
        ms.entries,
        ms.capacity,
        ms.hit_rate() * 100.0,
        ms.hits,
        ms.misses,
        ms.evictions
    );

    match wienna::testutil::write_bench_json("BENCH_cluster.json") {
        Ok(p) => println!("bench json -> {}", p.display()),
        Err(e) => eprintln!("bench json write failed: {e}"),
    }
}
