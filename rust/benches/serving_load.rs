//! Serving load sweep — offered load vs. tail latency, goodput and SLO
//! violations per design point, locating each design's saturation knee.
//!
//! For every design point the sweep offers Poisson traffic at a fraction
//! of the fleet's estimated capacity and reports the achieved goodput;
//! the *knee* is the first load level where goodput stops tracking the
//! offered rate (falls below 90% of it). WIENNA's wireless distribution
//! plane should push the knee to a higher absolute request rate than the
//! interposer baseline at the same nominal bandwidth (WIENNA-C vs
//! Interposer-A, the Fig-7 comparison replayed under traffic).

use wienna::config::DesignPoint;
use wienna::report::Table;
use wienna::serve::{ms_to_cycles, Fleet, PackageSpec, RoutePolicy, ServeStats, Source, WorkloadMix};
use wienna::testutil::bench;

/// The crate's canonical ResNet-50 / UNet / BERT serving mix.
fn mix() -> WorkloadMix {
    WorkloadMix::cnn_transformer_default()
}

const LOADS: [f64; 8] = [0.2, 0.4, 0.6, 0.8, 1.0, 1.2, 1.6, 2.0];
const PACKAGES: usize = 2;
const HORIZON_MS: f64 = 50.0;

struct Point {
    load: f64,
    offered_rps: f64,
    goodput_rps: f64,
    p99_ms: f64,
    violations: f64,
    mean_batch: f64,
}

fn sweep(dp: DesignPoint) -> Vec<Point> {
    LOADS
        .iter()
        .map(|&load| {
            let mut fleet = Fleet::new(
                PackageSpec::homogeneous(PACKAGES, dp),
                RoutePolicy::EarliestDeadline,
            );
            let capacity = fleet.estimate_capacity_rps(&mix(), 8);
            let offered_rps = capacity * load;
            let mut source = Source::poisson(mix(), offered_rps, 42);
            let mut stats = ServeStats::new();
            fleet.run(&mut source, ms_to_cycles(HORIZON_MS), &mut stats);
            Point {
                load,
                offered_rps,
                goodput_rps: stats.goodput_rps(),
                p99_ms: stats.latency_ms(99.0),
                violations: stats.violation_rate(),
                mean_batch: stats.mean_batch(),
            }
        })
        .collect()
}

/// First load level where goodput drops below 90% of the offered rate.
fn knee(points: &[Point]) -> Option<&Point> {
    points.iter().find(|p| p.goodput_rps < 0.9 * p.offered_rps)
}

fn main() {
    println!("##### Serving load sweep ({PACKAGES}-package fleets, {HORIZON_MS} ms of traffic per point)\n");
    for dp in [DesignPoint::INTERPOSER_C, DesignPoint::INTERPOSER_A, DesignPoint::WIENNA_C, DesignPoint::WIENNA_A] {
        let points = sweep(dp);
        let mut t = Table::new(
            &format!("{} — offered load vs. serving quality", dp.label()),
            &["load", "offered req/s", "goodput req/s", "p99 ms", "SLO viol %", "mean batch"],
        );
        for p in &points {
            t.row(vec![
                format!("{:.1}", p.load),
                format!("{:.0}", p.offered_rps),
                format!("{:.0}", p.goodput_rps),
                format!("{:.2}", p.p99_ms),
                format!("{:.1}", p.violations * 100.0),
                format!("{:.2}", p.mean_batch),
            ]);
        }
        print!("{}", t.render());
        t.save_csv(&format!("bench_out/serving_load_{}.csv", dp.label())).ok();
        match knee(&points) {
            Some(k) => println!(
                "saturation knee at load {:.1} ({:.0} req/s offered, {:.0} req/s good)\n",
                k.load, k.offered_rps, k.goodput_rps
            ),
            None => println!("no saturation knee up to load {:.1}\n", LOADS[LOADS.len() - 1]),
        }
    }

    // Absolute capacity comparison at the equal-bandwidth pair.
    let mut wc = Fleet::new(PackageSpec::homogeneous(PACKAGES, DesignPoint::WIENNA_C), RoutePolicy::EarliestDeadline);
    let mut ia = Fleet::new(PackageSpec::homogeneous(PACKAGES, DesignPoint::INTERPOSER_A), RoutePolicy::EarliestDeadline);
    let cap_wc = wc.estimate_capacity_rps(&mix(), 8);
    let cap_ia = ia.estimate_capacity_rps(&mix(), 8);
    println!(
        "estimated capacity at 16 B/cyc distribution BW: WIENNA-C {cap_wc:.0} req/s vs Interposer-A {cap_ia:.0} req/s ({:.2}x)",
        cap_wc / cap_ia
    );

    // Hot-loop timing: one full 50 ms simulated run at 0.8 load.
    bench("serve/50ms_wienna_c_load0.8", 10, || {
        let mut fleet = Fleet::new(
            PackageSpec::homogeneous(PACKAGES, DesignPoint::WIENNA_C),
            RoutePolicy::EarliestDeadline,
        );
        let capacity = fleet.estimate_capacity_rps(&mix(), 8);
        let mut source = Source::poisson(mix(), capacity * 0.8, 42);
        let mut stats = ServeStats::new();
        fleet.run(&mut source, ms_to_cycles(HORIZON_MS), &mut stats);
        stats.completed()
    });

    match wienna::testutil::write_bench_json("BENCH_serving.json") {
        Ok(p) => println!("bench json -> {}", p.display()),
        Err(e) => eprintln!("bench json write failed: {e}"),
    }
}
