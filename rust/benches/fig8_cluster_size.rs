//! Fig 8 — Impact of cluster size (chiplet count at a fixed 16384-PE
//! budget) for the three partitioning strategies on both DNNs.
//!
//! Paper findings to reproduce: throughput is *not* monotonic in chiplet
//! count (chiplet size is an optimizable design parameter), and WIENNA is
//! consistently faster and more sensitive to the cluster size than the
//! interposer baseline.

use wienna::config::{DesignPoint, SystemConfig};
use wienna::cost::{evaluate_model, CostEngine};
use wienna::dataflow::Strategy;
use wienna::report::Table;
use wienna::testutil::bench;
use wienna::workload::{resnet50::resnet50, unet::unet};

const CHIPLETS: [u64; 6] = [32, 64, 128, 256, 512, 1024];

fn main() {
    for model in [resnet50(64), unet(64)] {
        println!("\n##### Fig 8 — {} (16384 PEs total)", model.name);
        for dp in [DesignPoint::WIENNA_C, DesignPoint::INTERPOSER_A] {
            let mut t = Table::new(
                &format!("{} — MACs/cycle vs chiplet count", dp.label()),
                &["chiplets", "PEs/chiplet", "KP-CP", "NP-CP", "YP-XP"],
            );
            for nc in CHIPLETS {
                let sys = SystemConfig::with_chiplets(nc);
                let e = CostEngine::for_design_point(&sys, dp);
                let th: Vec<String> = Strategy::ALL
                    .iter()
                    .map(|&s| format!("{:.0}", evaluate_model(&e, &model, Some(s)).macs_per_cycle))
                    .collect();
                t.row(vec![nc.to_string(), sys.pes_per_chiplet.to_string(), th[0].clone(), th[1].clone(), th[2].clone()]);
            }
            print!("{}", t.render());
            t.save_csv(&format!("bench_out/fig8_{}_{}.csv", model.name, dp.label())).ok();
        }

        // Sensitivity (paper: 77.5% avg change for WIENNA vs 62.5% for the
        // interposer between 64 and 512 PEs/chiplet, i.e. 256 vs 32
        // chiplets).
        for dp in [DesignPoint::WIENNA_C, DesignPoint::INTERPOSER_A] {
            let mut diffs = Vec::new();
            for s in Strategy::ALL {
                let th_256 = evaluate_model(&CostEngine::for_design_point(&SystemConfig::with_chiplets(256), dp), &model, Some(s)).macs_per_cycle;
                let th_32 = evaluate_model(&CostEngine::for_design_point(&SystemConfig::with_chiplets(32), dp), &model, Some(s)).macs_per_cycle;
                diffs.push((th_256.max(th_32) / th_256.min(th_32) - 1.0) * 100.0);
            }
            println!(
                "{}: avg |change| from 64 to 512 PEs/chiplet = {:.1}%  (paper: WIENNA 77.5%, interposer 62.5%)",
                dp.label(),
                diffs.iter().sum::<f64>() / diffs.len() as f64
            );
        }
    }

    let rn = resnet50(64);
    bench("fig8_sweep(resnet50, 6 sizes x 3 strategies)", 5, || {
        CHIPLETS
            .iter()
            .map(|&nc| {
                let e = CostEngine::for_design_point(&SystemConfig::with_chiplets(nc), DesignPoint::WIENNA_C);
                Strategy::ALL.iter().map(|&s| evaluate_model(&e, &rn, Some(s)).macs_per_cycle).sum::<f64>()
            })
            .sum::<f64>()
    });
}
