//! Fig 10 — Average multicast factor (received bytes / sent bytes) per
//! layer type and partitioning strategy, at cluster size 64 (256 chiplets).
//!
//! The multicast factor quantifies the spatial-reuse opportunity each
//! strategy exposes; the paper correlates high multicast factors (KP-CP)
//! with the largest wireless energy reductions in Fig 9.

use wienna::config::SystemConfig;
use wienna::dataflow::{partition, Strategy};
use wienna::report::Table;
use wienna::testutil::bench;
use wienna::workload::{classify, Model};
use wienna::workload::{resnet50::resnet50, unet::unet};

fn avg_multicast_factor(sys: &SystemConfig, model: &Model, ty: wienna::workload::LayerType, s: Strategy) -> f64 {
    // Byte-weighted average over the layers of this type.
    let mut sent = 0.0;
    let mut recv = 0.0;
    for l in model.layers.iter().filter(|l| classify(l) == ty) {
        let p = partition::partition(l, s, sys.num_chiplets, sys.bytes_per_elem);
        sent += p.sent_bytes() as f64;
        recv += p.sent_bytes() as f64 * p.multicast_factor();
    }
    if sent == 0.0 {
        0.0
    } else {
        recv / sent
    }
}

fn main() {
    // "cluster size of 64" = 64 PEs/chiplet -> 256 chiplets.
    let sys = SystemConfig::with_chiplets(256);
    assert_eq!(sys.pes_per_chiplet, 64);

    for model in [resnet50(64), unet(64)] {
        println!("\n##### Fig 10 — {} (256 chiplets)", model.name);
        let mut t = Table::new(
            "average multicast factor",
            &["layer type", "KP-CP", "NP-CP", "YP-XP"],
        );
        for ty in model.layer_types() {
            let row: Vec<f64> = Strategy::ALL.iter().map(|&s| avg_multicast_factor(&sys, &model, ty, s)).collect();
            t.row(vec![
                ty.label().to_string(),
                format!("{:.1}", row[0]),
                format!("{:.1}", row[1]),
                format!("{:.1}", row[2]),
            ]);
        }
        print!("{}", t.render());
        t.save_csv(&format!("bench_out/fig10_{}.csv", model.name)).ok();

        // Paper observation: KP-CP exposes the highest multicast factor.
        let mut totals = [0.0f64; 3];
        for (i, s) in Strategy::ALL.iter().enumerate() {
            for ty in model.layer_types() {
                totals[i] += avg_multicast_factor(&sys, &model, ty, *s);
            }
        }
        let best = Strategy::ALL[totals
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0];
        println!("highest multicast factor overall: {} (paper: KP-CP)", best.label());
    }

    let rn = resnet50(64);
    bench("fig10_mf(resnet50 all types x strategies)", 20, || {
        rn.layer_types()
            .iter()
            .map(|&ty| Strategy::ALL.iter().map(|&s| avg_multicast_factor(&sys, &rn, ty, s)).sum::<f64>())
            .sum::<f64>()
    });
}
