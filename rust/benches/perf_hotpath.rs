//! Performance benchmarks for the hot paths of each layer (EXPERIMENTS.md
//! §Perf):
//!
//! * L3 cost engine — per-layer evaluation (cold vs memoized), whole-model
//!   adaptive runs, and the full Fig-7 design-point grid (memo + worker
//!   pool — the acceptance metric for the fast-path PR);
//! * L3 cycle-level mesh simulator — flit-hop throughput;
//! * L3 coordinator — schedule generation;
//! * serve hot path — the request loop with telemetry off (the ≤2%
//!   overhead guard for the observability PR), with span recording on,
//!   and with `--bounded-stats` histogram recorders (zero per-request
//!   allocation asserted, same ≤2% envelope);
//! * runtime — PJRT tile dispatch latency (only with `--features pjrt`
//!   and built artifacts).
//!
//! Results are also dumped to `BENCH_perf.json` (override with
//! `$BENCH_JSON`) for the CI perf-trajectory artifact.

use wienna::config::{DesignPoint, SystemConfig};
use wienna::coordinator::{Coordinator, StrategyPolicy};
use wienna::cost::{
    evaluate_grid, evaluate_layer, evaluate_layer_uncached, evaluate_model, evaluate_model_par,
    memo, par, CostEngine,
};
use wienna::dataflow::Strategy;
use wienna::nop::sim::{MeshSim, Transfer};
use wienna::serve::{
    ms_to_cycles, Fleet, MixEntry, ModelKind, PackageSpec, RoutePolicy, ServeStats, Source,
    WorkloadMix,
};
use wienna::telemetry::Recorder;
use wienna::testutil::bench;
use wienna::workload::resnet50::resnet50;
use wienna::workload::unet::unet;

fn main() {
    let sys = SystemConfig::default();
    let rn = resnet50(64);
    let engine = CostEngine::for_design_point(&sys, DesignPoint::WIENNA_C);
    let threads = par::num_threads();
    println!("worker pool: {threads} threads");

    // --- L3 cost engine ---
    let layer = &rn.layers[10];
    bench("cost/evaluate_layer_uncached(conv)", 20_000, || {
        evaluate_layer_uncached(&engine, layer, Strategy::KpCp).latency
    });
    bench("cost/evaluate_layer(conv, memoized)", 20_000, || {
        evaluate_layer(&engine, layer, Strategy::KpCp).latency
    });
    let s = bench("cost/evaluate_model(resnet50 fixed)", 200, || {
        evaluate_model(&engine, &rn, Some(Strategy::KpCp)).macs_per_cycle
    });
    println!("  -> {:.1} layer-evals/ms", rn.layers.len() as f64 / s.mean_ms());
    bench("cost/evaluate_model(resnet50 adaptive)", 100, || evaluate_model(&engine, &rn, None).macs_per_cycle);
    bench("cost/evaluate_model_par(resnet50 adaptive)", 100, || {
        evaluate_model_par(&engine, &rn, None, threads).macs_per_cycle
    });

    // The acceptance metric: the full Fig-7 grid, memo + worker pool. The
    // first iteration pays the cold evaluations; steady-state iterations
    // are pure memo lookups — exactly how the serve loop and the
    // auto-sizer hit the engine.
    let models = [resnet50(64), unet(64)];
    memo::clear();
    let full = bench("cost/full_fig7_grid(2 models x 4 dps)", 10, || {
        evaluate_grid(&sys, &DesignPoint::ALL, &models, None, threads)
            .iter()
            .map(|c| c.macs_per_cycle)
            .sum::<f64>()
    });
    println!("  -> full design-point grid in {:.2} ms (target: well under 1 s)", full.mean_ms());
    let ms = memo::stats();
    println!(
        "  -> memo: {} entries, {:.1}% hit rate ({} hits / {} misses)",
        ms.entries,
        ms.hit_rate() * 100.0,
        ms.hits,
        ms.misses
    );
    // Cold counterpart (memo cleared every iteration) for an honest
    // before/after: parallelism only, no caching.
    bench("cost/full_fig7_grid_cold(no memo reuse)", 10, || {
        memo::clear();
        evaluate_grid(&sys, &DesignPoint::ALL, &models, None, threads)
            .iter()
            .map(|c| c.macs_per_cycle)
            .sum::<f64>()
    });

    // --- coordinator schedule generation ---
    let coord = Coordinator::new(sys.clone(), DesignPoint::WIENNA_C, StrategyPolicy::Adaptive);
    bench("coordinator/run_model(resnet50)", 50, || coord.run_model(&rn).1.total_latency_cycles);

    // --- cycle-level mesh simulator ---
    let sim = MeshSim::new(16, 16.0);
    let transfers: Vec<Transfer> = (0..1000)
        .map(|i| {
            if i % 4 == 0 {
                Transfer::broadcast(256, 16)
            } else {
                Transfer::unicast(4096, wienna::nop::sim::NodeId::new((i % 16) as u32, (i / 16 % 16) as u32))
            }
        })
        .collect();
    let st = bench("nop_sim/1000_transfers(16x16 mesh)", 20, || sim.run_distribution(&transfers).makespan);
    let report = sim.run_distribution(&transfers);
    let flit_hops = report.byte_hops / 16.0; // 16-byte flits
    println!(
        "  -> {:.2} Mflit-hops/s (target >= 1 M/s)",
        flit_hops / st.mean_ns * 1e9 / 1e6
    );

    // --- serve hot path: telemetry overhead guard ---
    // With the recorder off, the only telemetry cost on the request path
    // is the always-on attribution (~10 flops/request) plus one enum
    // discriminant check — the acceptance guard is <= 2% vs the
    // pre-telemetry baseline. The recorder-on row shows the opt-in span
    // logging cost next to it.
    let serve_mix = || {
        WorkloadMix::new(vec![
            MixEntry { kind: ModelKind::TinyCnn, weight: 3.0, slo_cycles: ms_to_cycles(25.0) },
            MixEntry { kind: ModelKind::Mlp, weight: 1.0, slo_cycles: ms_to_cycles(50.0) },
        ])
    };
    let serve_run = |record: bool| {
        let mut fleet = Fleet::new(
            PackageSpec::homogeneous(4, DesignPoint::WIENNA_C),
            RoutePolicy::EarliestDeadline,
        );
        fleet.recorder = Recorder::new(record);
        let mut stats = ServeStats::new();
        let mut source = Source::poisson(serve_mix(), 4000.0, 42);
        fleet.run(&mut source, ms_to_cycles(50.0), &mut stats);
        stats.completed()
    };
    let off = bench("serve/hot_path(telemetry off)", 20, || serve_run(false));
    let on = bench("serve/hot_path(telemetry on)", 20, || serve_run(true));
    println!(
        "  -> span recording costs {:+.1}% on the serve hot path (off-path guard: <= 2%)",
        (on.mean_ns / off.mean_ns - 1.0) * 100.0
    );

    // --- bounded stats (--bounded-stats): allocation + overhead guard ---
    // Histogram-backed recorders replace the per-request latency Vecs;
    // the guard is the same <= 2% envelope as the exact path, and the
    // zero-allocation claim is asserted outright, not just timed.
    let serve_run_stats = |bounded: bool| {
        let mut fleet = Fleet::new(
            PackageSpec::homogeneous(4, DesignPoint::WIENNA_C),
            RoutePolicy::EarliestDeadline,
        );
        let mut stats = if bounded { ServeStats::bounded() } else { ServeStats::new() };
        let mut source = Source::poisson(serve_mix(), 4000.0, 42);
        fleet.run(&mut source, ms_to_cycles(50.0), &mut stats);
        if bounded {
            assert_eq!(stats.exact_samples(), 0, "bounded stats grew a latency Vec");
        } else {
            assert!(stats.exact_samples() > 0, "exact stats lost their samples");
        }
        stats.completed()
    };
    let exact_stats = bench("serve/hot_path(exact stats)", 20, || serve_run_stats(false));
    let bounded_stats = bench("serve/hot_path(bounded stats)", 20, || serve_run_stats(true));
    println!(
        "  -> bounded stats cost {:+.1}% vs exact recorders (guard: <= 2%)",
        (bounded_stats.mean_ns / exact_stats.mean_ns - 1.0) * 100.0
    );

    // --- PJRT dispatch (needs `make artifacts` and `--features pjrt`) ---
    #[cfg(feature = "pjrt")]
    match wienna::runtime::ExecutableCache::new(std::path::Path::new("artifacts")) {
        Ok(cache) => {
            cache.warm_up().expect("compile artifacts");
            let a = vec![1.0f32; 64 * 64];
            let b = vec![0.5f32; 64 * 64];
            bench("runtime/matmul64_dispatch", 200, || {
                cache.execute_f32("matmul64", &[&a, &b]).unwrap().len()
            });
            if cache.manifest().get("matmul128").is_ok() {
                let a = vec![1.0f32; 128 * 128];
                let b = vec![0.5f32; 128 * 128];
                bench("runtime/matmul128_dispatch", 200, || {
                    cache.execute_f32("matmul128", &[&a, &b]).unwrap().len()
                });
            }
            let x = vec![1.0f32; 4096];
            bench("runtime/add4096_dispatch", 200, || cache.execute_f32("add4096", &[&x, &x]).unwrap().len());
        }
        Err(e) => println!("runtime benches skipped (artifacts not built): {e:#}"),
    }

    match wienna::testutil::write_bench_json("BENCH_perf.json") {
        Ok(p) => println!("bench json -> {}", p.display()),
        Err(e) => eprintln!("bench json write failed: {e}"),
    }
}
