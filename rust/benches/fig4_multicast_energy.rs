//! Fig 4 — Average per-bit energy of a multicast transmission.
//!
//! Compares, as a function of the destination count: (a) a silicon
//! interposer with direct (dedicated point-to-point) connections, (b) a
//! mesh NoP without hardware multicast (replicated unicasts, avg-hop
//! energy per copy), and (c) the wireless NoP (one TX burst + d active
//! receivers), at two bit-error rates. The paper's message: wireless
//! crosses below the electrical options as fan-out grows.

use wienna::config::SystemConfig;
use wienna::nop::technology::interposer_hop_energy_pj;
use wienna::nop::transceiver::TrxDesignPoint;
use wienna::nop::{MeshNop, WirelessNop};
use wienna::report::Table;
use wienna::testutil::bench;

fn main() {
    let sys = SystemConfig::default();
    let mesh = MeshNop::new(sys.num_chiplets, 16.0, true);
    let direct_pj = interposer_hop_energy_pj(true); // one dedicated link per dest

    let mut t = Table::new(
        "Fig 4 — multicast energy (pJ per sent bit) vs destinations, 256-chiplet package",
        &["dests", "direct", "mesh", "wireless 1e-9", "wireless 1e-12"],
    );
    let mut crossover: Option<u64> = None;
    for d in [1u64, 2, 4, 8, 16, 32, 64, 128, 256] {
        let df = d as f64;
        let direct = df * direct_pj;
        let mesh_e = mesh.multicast_pj_per_sent_bit(df);
        let mut w9 = WirelessNop::new(16.0, TrxDesignPoint::Conservative);
        w9.ber = 1e-9;
        let mut w12 = w9.clone();
        w12.ber = 1e-12;
        let w9e = w9.multicast_pj_per_sent_bit(df);
        if crossover.is_none() && w9e < mesh_e {
            crossover = Some(d);
        }
        t.row(vec![
            d.to_string(),
            format!("{:.2}", direct),
            format!("{:.2}", mesh_e),
            format!("{:.2}", w9e),
            format!("{:.2}", w12.multicast_pj_per_sent_bit(df)),
        ]);
    }
    print!("{}", t.render());
    t.save_csv("bench_out/fig4_multicast_energy.csv").ok();
    match crossover {
        Some(d) => println!("wireless(1e-9) beats the mesh from {d} destinations onward"),
        None => println!("no crossover observed (unexpected)"),
    }

    bench("fig4_energy_table", 1000, || {
        let w = WirelessNop::new(16.0, TrxDesignPoint::Conservative);
        (1..=256).map(|d| w.multicast_pj_per_sent_bit(d as f64) + mesh.multicast_pj_per_sent_bit(d as f64)).sum::<f64>()
    });
}
