//! Table 2 — 2.5D interconnect technologies, plus the Fig-1 transceiver
//! survey fit the wireless rows derive from.

use wienna::config::SystemConfig;
use wienna::nop::technology::TECHNOLOGIES;
use wienna::nop::transceiver::{required_gbps, Transceiver, TrxDesignPoint};
use wienna::report::Table;
use wienna::testutil::bench;

fn main() {
    let nc = SystemConfig::default().num_chiplets as f64;

    let mut t = Table::new(
        &format!("Table 2 — 2.5D interconnect technologies (N_C = {nc})"),
        &["technology", "node (nm)", "BWD (Gbps/mm)", "energy (pJ/bit)", "LL (mm)", "avg hops"],
    );
    for tech in TECHNOLOGIES {
        t.row(vec![
            tech.name.to_string(),
            tech.node_nm.to_string(),
            format!("{:.1}", (tech.bw_density_gbps_mm)(nc)),
            format!("{:.2}", (tech.energy_pj_per_bit)(nc)),
            tech.link_length_mm.map(|l| format!("{l:.1}")).unwrap_or_else(|| "N/A".into()),
            format!("{:.1}", tech.avg_hops(nc)),
        ]);
    }
    print!("{}", t.render());
    t.save_csv("bench_out/table2_technologies.csv").ok();

    // Fig 1: the transceiver survey fit at a sweep of datarates.
    let trx = Transceiver::default();
    let mut f = Table::new(
        "Fig 1 — transceiver area/power vs datarate (fit anchored at [27], BER 1e-9)",
        &["datarate (Gb/s)", "area (mm2)", "power (mW)", "pJ/bit"],
    );
    for gbps in [10.0, 20.0, 48.0, 64.0, 100.0, 128.0] {
        f.row(vec![
            format!("{gbps:.0}"),
            format!("{:.2}", trx.area_mm2(gbps)),
            format!("{:.1}", trx.power_mw(gbps, 1e-9)),
            format!("{:.2}", trx.pj_per_bit(gbps, 1e-9)),
        ]);
    }
    print!("{}", f.render());
    f.save_csv("bench_out/fig1_transceiver_fit.csv").ok();

    println!(
        "\ndesign points: conservative {:.2} pJ/bit unicast (RX {:.2}), aggressive {:.2} (RX {:.2})",
        TrxDesignPoint::Conservative.unicast_pj_per_bit(),
        TrxDesignPoint::Conservative.rx_pj_per_bit(),
        TrxDesignPoint::Aggressive.unicast_pj_per_bit(),
        TrxDesignPoint::Aggressive.rx_pj_per_bit(),
    );
    println!(
        "WIENNA-C needs {:.0} Gb/s, WIENNA-A {:.0} Gb/s at 500 MHz",
        required_gbps(16.0, 500e6),
        required_gbps(32.0, 500e6)
    );

    bench("table2_render", 1000, || {
        TECHNOLOGIES.iter().map(|t| (t.energy_pj_per_bit)(nc) + t.avg_hops(nc)).sum::<f64>()
    });
}
