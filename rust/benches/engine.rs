//! Engine bench: calendar-queue scheduler vs the legacy scan loop.
//!
//! Part 1 — **scheduler head-to-head**: one fat shard (256 packages —
//! the regime where the legacy loop's two O(packages)-per-event scans
//! dominate) serves the canonical CNN/transformer mix open-loop at 0.9x
//! capacity, timed under `--scheduler legacy` and the default calendar
//! queue. Both runs are asserted byte-identical (outside the timed
//! loop — the fuzz harness and CI gate re-prove this on every change);
//! the headline metrics are `engine/requests_per_sec`,
//! `engine/events_per_sec` and `engine/speedup_vs_legacy_x` (the PR
//! acceptance target is >= 3x).
//!
//! Part 2 — **thread scaling**: the calendar engine across 8 shards at
//! 1/2/4 worker threads, reporting `engine/thread_scaling_x` (4-thread
//! speedup over 1). Shards are pure functions of their input slices, so
//! the stats stay bit-identical (asserted) — threads only buy wall-clock.
//!
//! Everything runs under a `cost::memo::run_scope` after a warm-up pass
//! so the timed runs see a hot layer memo, and every timing/metric lands
//! in `BENCH_engine.json` for the CI perf job.

use wienna::cluster::{AdmissionConfig, Cluster, ClusterConfig, SchedulerKind};
use wienna::config::DesignPoint;
use wienna::cost::memo;
use wienna::serve::{
    ms_to_cycles, Fleet, PackageSpec, RoutePolicy, Source, WorkloadMix,
};
use wienna::testutil::{bench, record_metric};

/// One fat shard: the legacy loop scans all packages twice per event,
/// so per-event cost grows with the package count while the calendar
/// queue's stays near-constant. 256 packages puts the difference well
/// past measurement noise.
const HEAD_PACKAGES: usize = 256;
const HEAD_REQUESTS: f64 = 30_000.0;

/// Part 2 topology (per-shard package count matters less here — this
/// part measures the barrier/parallelism overhead, not the scans).
const SCALE_PACKAGES: usize = 64;
const SCALE_SHARDS: usize = 8;
const SCALE_REQUESTS: f64 = 30_000.0;

fn mix() -> WorkloadMix {
    WorkloadMix::cnn_transformer_default()
}

fn run_once(
    packages: usize,
    shards: usize,
    threads: usize,
    scheduler: SchedulerKind,
    rate: f64,
    horizon_ms: f64,
) -> wienna::cluster::ClusterStats {
    let cluster = Cluster::new(
        PackageSpec::homogeneous(packages, DesignPoint::WIENNA_C),
        ClusterConfig {
            shards,
            threads,
            scheduler,
            admission: AdmissionConfig::admit_all(),
            ..Default::default()
        },
    );
    let mut source = Source::poisson(mix(), rate, 42);
    cluster.run(&mut source, ms_to_cycles(horizon_ms))
}

/// Simulated events a run processed: every arrival plus every
/// finalization (completion, shed or failure) is one trip around the
/// engine's event loop.
fn events_of(stats: &wienna::cluster::ClusterStats) -> u64 {
    stats.serve.arrived() + stats.serve.completed() + stats.serve.shed() + stats.serve.failed()
}

fn main() {
    println!("##### Engine: calendar queue vs legacy scan loop\n");

    // --- Part 1: scheduler head-to-head ---------------------------------
    let capacity = Fleet::new(
        PackageSpec::homogeneous(HEAD_PACKAGES, DesignPoint::WIENNA_C),
        RoutePolicy::EarliestDeadline,
    )
    .estimate_capacity_rps(&mix(), 8);
    let rate = 0.9 * capacity;
    let horizon_ms = HEAD_REQUESTS / rate * 1e3;
    println!(
        "1 shard x {HEAD_PACKAGES} packages: capacity {capacity:.0} req/s -> offered {rate:.0} req/s (0.9x) for {horizon_ms:.2} ms (~{HEAD_REQUESTS:.0} requests)\n"
    );

    // Warm the layer memo, and pin the equivalence outside the timed
    // loop: the oracle must reproduce the calendar run byte for byte.
    let reference = run_once(HEAD_PACKAGES, 1, 1, SchedulerKind::Calendar, rate, horizon_ms);
    let legacy = run_once(HEAD_PACKAGES, 1, 1, SchedulerKind::Legacy, rate, horizon_ms);
    assert_eq!(
        reference.to_json(),
        legacy.to_json(),
        "calendar and legacy schedulers must produce byte-identical stats"
    );
    let events = events_of(&reference);
    let completed = reference.serve.completed();
    let _scope = memo::run_scope();

    let cal = bench(&format!("engine/calendar_1shard_{HEAD_PACKAGES}pkg"), 5, || {
        run_once(HEAD_PACKAGES, 1, 1, SchedulerKind::Calendar, rate, horizon_ms)
            .serve
            .completed()
    });
    let leg = bench(&format!("engine/legacy_1shard_{HEAD_PACKAGES}pkg"), 5, || {
        run_once(HEAD_PACKAGES, 1, 1, SchedulerKind::Legacy, rate, horizon_ms)
            .serve
            .completed()
    });

    let cal_s = cal.mean_ns / 1e9;
    let leg_s = leg.mean_ns / 1e9;
    let rps = completed as f64 / cal_s;
    let legacy_rps = completed as f64 / leg_s;
    let eps = events as f64 / cal_s;
    let speedup = leg.mean_ns / cal.mean_ns;
    record_metric("engine/requests_per_sec", rps);
    record_metric("engine/legacy_requests_per_sec", legacy_rps);
    record_metric("engine/events_per_sec", eps);
    record_metric("engine/speedup_vs_legacy_x", speedup);
    println!(
        "\ncalendar {:.2} ms/run ({rps:.0} req/s, {eps:.0} events/s) | legacy {:.2} ms/run ({legacy_rps:.0} req/s) | speedup {speedup:.2}x (target >= 3x)\n",
        cal.mean_ms(),
        leg.mean_ms()
    );

    // --- Part 2: thread scaling (calendar engine) -----------------------
    let capacity = Fleet::new(
        PackageSpec::homogeneous(SCALE_PACKAGES, DesignPoint::WIENNA_C),
        RoutePolicy::EarliestDeadline,
    )
    .estimate_capacity_rps(&mix(), 8);
    let rate = 0.9 * capacity;
    let horizon_ms = SCALE_REQUESTS / rate * 1e3;
    println!(
        "{SCALE_SHARDS} shards x {} packages each: offered {rate:.0} req/s for {horizon_ms:.2} ms (~{SCALE_REQUESTS:.0} requests)\n",
        SCALE_PACKAGES / SCALE_SHARDS
    );

    // Determinism cross-check outside the timed loop, as in
    // `benches/cluster_scale.rs`.
    let t1_json =
        run_once(SCALE_PACKAGES, SCALE_SHARDS, 1, SchedulerKind::Calendar, rate, horizon_ms)
            .to_json();
    for threads in [2usize, 4] {
        let s =
            run_once(SCALE_PACKAGES, SCALE_SHARDS, threads, SchedulerKind::Calendar, rate, horizon_ms);
        assert_eq!(s.to_json(), t1_json, "thread count changed the stats");
    }
    let mut means = Vec::new();
    for threads in [1usize, 2, 4] {
        let st = bench(&format!("engine/calendar_{SCALE_SHARDS}shard_t{threads}"), 5, || {
            run_once(SCALE_PACKAGES, SCALE_SHARDS, threads, SchedulerKind::Calendar, rate, horizon_ms)
                .serve
                .completed()
        });
        means.push((threads, st.mean_ns));
    }
    let scaling = means[0].1 / means[2].1;
    record_metric("engine/thread_scaling_x", scaling);
    println!();
    for &(threads, mean) in &means {
        println!(
            "threads {threads}: {:>8.2} ms/run | speedup {:.2}x vs 1 thread",
            mean / 1e6,
            means[0].1 / mean
        );
    }
    println!("\ncalendar-engine thread scaling at 4 threads: {scaling:.2}x vs single-threaded");

    assert!(
        speedup >= 1.0,
        "the calendar queue must never lose to the legacy scan loop, got {speedup:.2}x"
    );

    let ms = memo::stats();
    println!(
        "\nlayer memo: {} entries (cap {}), {:.1}% hit rate ({} hits / {} misses, {} evictions)",
        ms.entries,
        ms.capacity,
        ms.hit_rate() * 100.0,
        ms.hits,
        ms.misses,
        ms.evictions
    );

    match wienna::testutil::write_bench_json("BENCH_engine.json") {
        Ok(p) => println!("bench json -> {}", p.display()),
        Err(e) => eprintln!("bench json write failed: {e}"),
    }
}
