//! Table 3 — WIENNA area and power breakdown (256 chiplets x 64 PEs,
//! 13 MiB global SRAM, 65-nm CMOS, 1e-9 BER).
//!
//! Paper numbers to land near: total ~1699 mm² / ~99.8 W; the wireless RX
//! is ~16% of a chiplet's area and ~25% of its power.

use wienna::config::SystemConfig;
use wienna::energy::AreaPowerBreakdown;
use wienna::report::Table;
use wienna::testutil::bench;

fn main() {
    let sys = SystemConfig::default();
    let b = AreaPowerBreakdown::for_system(&sys, 16.0, 1e-9);

    let (ta, tp) = (b.total_area_mm2(), b.total_power_mw());
    let mut t = Table::new(
        "Table 3 — WIENNA area and power breakdown (256 chiplets x 64 PEs)",
        &["component", "count", "area (mm2)", "area %", "power (mW)", "power %"],
    );
    for c in &b.components {
        t.row(vec![
            c.name.clone(),
            c.count.to_string(),
            format!("{:.0}", c.area_mm2),
            format!("{:.1}", c.area_mm2 / ta * 100.0),
            format!("{:.0}", c.power_mw),
            format!("{:.1}", c.power_mw / tp * 100.0),
        ]);
    }
    t.row(vec!["Total".into(), "".into(), format!("{ta:.0}"), "100".into(), format!("{tp:.0}"), "100".into()]);
    print!("{}", t.render());
    t.save_csv("bench_out/table3_area_power.csv").ok();

    println!("\npaper totals: 1699 mm², 99767 mW  |  measured: {ta:.0} mm², {tp:.0} mW");
    println!(
        "wireless RX share of a chiplet: area {:.1}% (paper 16%), power {:.1}% (paper 25%)",
        b.rx_area_fraction_of_chiplet() * 100.0,
        b.rx_power_fraction_of_chiplet() * 100.0
    );

    // Scaling corner: larger chiplets amortize the RX overhead (paper §4).
    let big = SystemConfig { num_chiplets: 64, pes_per_chiplet: 256, ..Default::default() };
    let bb = AreaPowerBreakdown::for_system(&big, 16.0, 1e-9);
    println!(
        "at 64 chiplets x 256 PEs the RX area share drops to {:.1}%",
        bb.rx_area_fraction_of_chiplet() * 100.0
    );

    bench("table3_breakdown", 1000, || AreaPowerBreakdown::for_system(&sys, 16.0, 1e-9).total_area_mm2());
}
