//! Chaos bench: the cost of failure, measured.
//!
//! Part 1 — **tail amplification under MAC contention**: one 8-package
//! WIENNA-C fleet in 4 shards serves a single-model mix at 0.6x capacity
//! twice — once clean, once with the shared wireless medium at 0.6
//! steady background occupancy. Contention stretches every dispatch's
//! `dist` phase through the closed-form token-queueing delay, and it
//! stretches the *tail* harder than the median: the headline metric is
//! tail amplification (p99/p50) clean vs contended, pinned into
//! `BENCH_chaos.json` for the CI perf job.
//!
//! Part 2 — **time-to-drain a dead shard**: the same fleet, closed-loop
//! clients, both packages of shard 1 killed for good at 2 ms with the
//! steal/failover pass on. The failover sub-pass re-homes the dead
//! shard's backlog onto survivors at the next epoch barrier; the bench
//! pins how long the shard took to drain (death to empty), the goodput
//! recovered vs the same run without failover, and the reroute count.
//!
//! Both parts run after a memo warm-up pass (steady-state layer costs)
//! and record wall-clock timings alongside the scenario metrics.

use wienna::cluster::{AdmissionConfig, Cluster, ClusterConfig, SyncConfig};
use wienna::config::DesignPoint;
use wienna::cost::memo;
use wienna::fault::{ContentionConfig, FaultPlan};
use wienna::report::Table;
use wienna::serve::{
    ms_to_cycles, Fleet, MixEntry, ModelKind, PackageSpec, RoutePolicy, Source, WorkloadMix,
};
use wienna::testutil::{bench, record_metric};

const PACKAGES: usize = 8;
const SHARDS: usize = 4;
const REQUESTS: f64 = 6_000.0;
const BACKGROUND: f64 = 0.6;

fn mix() -> WorkloadMix {
    WorkloadMix::new(vec![MixEntry {
        kind: ModelKind::TinyCnn,
        weight: 1.0,
        slo_cycles: ms_to_cycles(40.0),
    }])
}

fn run_contended(background: f64, rate: f64, horizon_ms: f64) -> wienna::cluster::ClusterStats {
    let cluster = Cluster::new(
        PackageSpec::homogeneous(PACKAGES, DesignPoint::WIENNA_C),
        ClusterConfig {
            shards: SHARDS,
            threads: 4,
            admission: AdmissionConfig::admit_all(),
            contention: if background > 0.0 {
                ContentionConfig::with_background(background)
            } else {
                ContentionConfig::default()
            },
            ..Default::default()
        },
    );
    let mut source = Source::poisson(mix(), rate, 42);
    cluster.run(&mut source, ms_to_cycles(horizon_ms))
}

fn run_dead_shard(steal: bool) -> wienna::cluster::ClusterStats {
    // Globals 1 and 5 on an 8-package / 4-shard fleet are exactly shard
    // 1's two local packages — dead for good at 2 ms under closed-loop
    // load, so real backlog is stranded there unless failover moves it.
    let cluster = Cluster::new(
        PackageSpec::homogeneous(PACKAGES, DesignPoint::WIENNA_C),
        ClusterConfig {
            shards: SHARDS,
            threads: 4,
            admission: AdmissionConfig::admit_all(),
            sync: SyncConfig { steal, epoch_cycles: ms_to_cycles(0.25), ..Default::default() },
            faults: FaultPlan::parse("kill:1@2;kill:5@2").expect("bench fault spec"),
            ..Default::default()
        },
    );
    let mut source = Source::closed_loop(mix(), 32, 0.3, 10, 404);
    cluster.run(&mut source, f64::INFINITY)
}

fn main() {
    println!("##### Chaos engineering ({PACKAGES} packages, {SHARDS} shards)\n");
    let capacity = Fleet::new(
        PackageSpec::homogeneous(PACKAGES, DesignPoint::WIENNA_C),
        RoutePolicy::EarliestDeadline,
    )
    .estimate_capacity_rps(&mix(), 8);
    let rate = 0.6 * capacity;
    let horizon_ms = REQUESTS / rate * 1e3;
    println!(
        "estimated fleet capacity {capacity:.0} req/s -> offered {rate:.0} req/s (0.6x) for {horizon_ms:.0} ms (~{REQUESTS:.0} requests)\n"
    );

    // Warm the layer memo once so every timed run sees steady state.
    let _ = run_contended(0.0, rate, horizon_ms);
    let _scope = memo::run_scope();

    // --- Part 1: tail amplification under contention --------------------
    bench(&format!("chaos/clean_{PACKAGES}pkg"), 3, || {
        run_contended(0.0, rate, horizon_ms).serve.completed()
    });
    bench(&format!("chaos/contended_bg{BACKGROUND}"), 3, || {
        run_contended(BACKGROUND, rate, horizon_ms).serve.completed()
    });
    let clean = run_contended(0.0, rate, horizon_ms);
    let hot = run_contended(BACKGROUND, rate, horizon_ms);
    assert_eq!(clean.token_wait_cycles, 0.0, "no contention, no token wait");
    assert!(hot.token_wait_cycles > 0.0, "contention must book token-wait cycles");
    assert!(
        hot.serve.latency_ms(99.0) > clean.serve.latency_ms(99.0),
        "contention must stretch the tail: p99 {:.3} vs {:.3} ms",
        hot.serve.latency_ms(99.0),
        clean.serve.latency_ms(99.0)
    );
    let mut t = Table::new(
        &format!("tail amplification at {BACKGROUND} background MAC load"),
        &["run", "completed", "p50 ms", "p99 ms", "tail amp", "dist frac", "token wait Mcyc"],
    );
    for (name, s) in [("clean", &clean), ("contended", &hot)] {
        t.row(vec![
            name.to_string(),
            s.serve.completed().to_string(),
            format!("{:.3}", s.serve.latency_ms(50.0)),
            format!("{:.3}", s.serve.latency_ms(99.0)),
            format!("{:.2}x", s.tail_amplification()),
            format!("{:.3}", s.serve.attr.fractions()[1]),
            format!("{:.2}", s.token_wait_cycles / 1e6),
        ]);
    }
    print!("{}", t.render());
    t.save_csv("bench_out/chaos_tail.csv").ok();
    record_metric("chaos/tail_amplification_clean_x", clean.tail_amplification());
    record_metric("chaos/tail_amplification_contended_x", hot.tail_amplification());
    println!();

    // --- Part 2: dead-shard drain under failover -------------------------
    bench("chaos/dead_shard_failover", 3, || run_dead_shard(true).serve.completed());
    let stranded = run_dead_shard(false);
    let rescued = run_dead_shard(true);
    assert!(rescued.reroutes() > 0, "failover must re-home the dead shard's queue");
    assert!(
        rescued.serve.completed() > stranded.serve.completed(),
        "failover must recover goodput: {} vs {} completions",
        rescued.serve.completed(),
        stranded.serve.completed()
    );
    let mut t = Table::new(
        "dead shard (both packages of shard 1 killed at 2 ms)",
        &["run", "completed", "failed", "retries", "reroutes", "drain ms", "failover goodput req/s"],
    );
    for (name, s) in [("static", &stranded), ("failover", &rescued)] {
        t.row(vec![
            name.to_string(),
            s.serve.completed().to_string(),
            s.serve.failed().to_string(),
            s.retries().to_string(),
            s.reroutes().to_string(),
            format!("{:.3}", s.dead_shard_drain_ms()),
            format!("{:.0}", s.failover_goodput_rps()),
        ]);
    }
    print!("{}", t.render());
    t.save_csv("bench_out/chaos_drain.csv").ok();
    record_metric("chaos/dead_shard_drain_ms", rescued.dead_shard_drain_ms());
    record_metric("chaos/failover_goodput_rps", rescued.failover_goodput_rps());
    record_metric(
        "chaos/failover_goodput_gain_x",
        rescued.serve.completed() as f64 / stranded.serve.completed().max(1) as f64,
    );

    let ms = memo::stats();
    println!(
        "\nlayer memo: {} entries (cap {}), {:.1}% hit rate ({} hits / {} misses, {} evictions)",
        ms.entries,
        ms.capacity,
        ms.hit_rate() * 100.0,
        ms.hits,
        ms.misses,
        ms.evictions
    );

    match wienna::testutil::write_bench_json("BENCH_chaos.json") {
        Ok(p) => println!("bench json -> {}", p.display()),
        Err(e) => eprintln!("bench json write failed: {e}"),
    }
}
