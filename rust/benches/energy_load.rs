//! Energy/power load bench: sweep the fleet power cap and chart the
//! throughput/energy knee.
//!
//! One 8-package WIENNA-C fleet serves the canonical CNN/transformer mix
//! at 0.9x capacity. An uncapped pass establishes the fleet's natural
//! draw P0; the sweep then re-runs the identical traffic under caps from
//! 1.2x down to 0.35x P0 and reports, per cap: drain time, p99,
//! dynamic/leakage energy, energy per request, achieved average power and
//! the throttled-batch share. The interesting output is the **knee** —
//! the cap below which the DVFS governor's V² energy savings stop paying
//! for the throughput it gives up (p99 and drain time blow up faster
//! than mJ/req falls).
//!
//! Each sweep point is also timed with `testutil::bench`, so the CI perf
//! job uploads a machine-readable `BENCH_energy.json` alongside the
//! other bench artifacts.

use wienna::config::DesignPoint;
use wienna::cost::memo;
use wienna::power::PowerConfig;
use wienna::report::Table;
use wienna::serve::{
    ms_to_cycles, Fleet, PackageSpec, RoutePolicy, ServeStats, Source, WorkloadMix,
};
use wienna::testutil::bench;

const PACKAGES: usize = 8;
/// Fixed request count per run (horizon derives from it): enough events
/// to reach steady-state batching, small enough to keep the sweep quick.
const REQUESTS: f64 = 4_000.0;

fn mix() -> WorkloadMix {
    WorkloadMix::cnn_transformer_default()
}

fn run_once(rate: f64, horizon_ms: f64, cap_w: Option<f64>) -> ServeStats {
    let mut fleet = Fleet::new(
        PackageSpec::homogeneous(PACKAGES, DesignPoint::WIENNA_C),
        RoutePolicy::EarliestDeadline,
    );
    if let Some(w) = cap_w {
        fleet.power = PowerConfig::with_cap(w);
    }
    let mut source = Source::poisson(mix(), rate, 42);
    let mut stats = ServeStats::new();
    fleet.run(&mut source, ms_to_cycles(horizon_ms), &mut stats);
    stats
}

fn main() {
    println!("##### Energy/power cap sweep ({PACKAGES} x WIENNA-C)\n");
    let capacity = Fleet::new(
        PackageSpec::homogeneous(PACKAGES, DesignPoint::WIENNA_C),
        RoutePolicy::EarliestDeadline,
    )
    .estimate_capacity_rps(&mix(), 8);
    let rate = 0.9 * capacity;
    let horizon_ms = REQUESTS / rate * 1e3;
    println!(
        "estimated capacity {capacity:.0} req/s -> offered {rate:.0} req/s (0.9x) for {horizon_ms:.0} ms (~{REQUESTS:.0} requests)"
    );

    // Uncapped baseline fixes the sweep's power scale.
    let base = run_once(rate, horizon_ms, None);
    let e0 = base.energy.expect("serve runs meter energy");
    let p0 = e0.avg_power_w(base.end_cycle());
    println!(
        "uncapped: {:.1} W avg | {:.2} mJ/req | p99 {:.2} ms\n",
        p0,
        e0.energy_per_req_j(base.completed()) * 1e3,
        base.latency_ms(99.0)
    );

    // Warm pass above populated the layer memo; scope the sweep's inserts.
    let _scope = memo::run_scope();

    let mut t = Table::new(
        &format!("power-cap sweep at {rate:.0} req/s (baseline {p0:.0} W)"),
        &[
            "cap W",
            "drain ms",
            "p99 ms",
            "dynamic mJ",
            "leakage mJ",
            "mJ/req",
            "avg W",
            "throttled %",
        ],
    );
    for frac in [None, Some(1.2), Some(1.0), Some(0.8), Some(0.65), Some(0.5), Some(0.35)] {
        let cap = frac.map(|f| f * p0);
        let label = frac.map_or("none".to_string(), |f| format!("{:.0}", f * p0));
        bench(&format!("energy/cap_{label}w"), 3, || run_once(rate, horizon_ms, cap).completed());
        let s = run_once(rate, horizon_ms, cap);
        let e = s.energy.expect("serve runs meter energy");
        let dispatches = s.dispatches().max(1);
        t.row(vec![
            label,
            format!("{:.1}", wienna::serve::cycles_to_ms(s.end_cycle())),
            format!("{:.2}", s.latency_ms(99.0)),
            format!("{:.1}", e.dynamic_mj()),
            format!("{:.1}", e.leakage_mj),
            format!("{:.2}", e.energy_per_req_j(s.completed()) * 1e3),
            format!("{:.1}", e.avg_power_w(s.end_cycle())),
            format!("{:.1}", e.throttled_batches as f64 / dispatches as f64 * 100.0),
        ]);
    }
    print!("{}", t.render());
    t.save_csv("bench_out/energy_cap_sweep.csv").ok();

    // Sanity anchors the CI log can grep: the tightest cap must throttle,
    // and a generous cap must not.
    let loose = run_once(rate, horizon_ms, Some(1.2 * p0));
    let tight = run_once(rate, horizon_ms, Some(0.35 * p0));
    let e_loose = loose.energy.unwrap();
    let e_tight = tight.energy.unwrap();
    assert!(e_tight.throttled_batches > 0, "0.35x cap did not throttle");
    assert!(
        e_tight.dynamic_mj() < e_loose.dynamic_mj(),
        "throttling did not cut dynamic energy"
    );
    println!(
        "\nknee check: 0.35x cap throttled {:.1}% of batches and cut dynamic energy {:.1}% (drain {:.0} -> {:.0} ms)",
        e_tight.throttled_batches as f64 / tight.dispatches().max(1) as f64 * 100.0,
        (1.0 - e_tight.dynamic_mj() / e_loose.dynamic_mj()) * 100.0,
        wienna::serve::cycles_to_ms(loose.end_cycle()),
        wienna::serve::cycles_to_ms(tight.end_cycle()),
    );

    match wienna::testutil::write_bench_json("BENCH_energy.json") {
        Ok(p) => println!("bench json -> {}", p.display()),
        Err(e) => eprintln!("bench json write failed: {e}"),
    }
}
