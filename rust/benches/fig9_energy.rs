//! Fig 9 — Energy of distributing input activations and filters from the
//! global SRAM to the chiplets, interposer vs WIENNA, per partitioning
//! strategy and per layer type, plus the end-to-end reduction inset (9c).
//!
//! Paper claim: WIENNA always reduces distribution energy; average 38.2%.

use wienna::config::{DesignPoint, SystemConfig};
use wienna::cost::{evaluate_layer, CostEngine};
use wienna::dataflow::Strategy;
use wienna::energy::model_distribution_energy;
use wienna::report::Table;
use wienna::testutil::bench;
use wienna::workload::{classify, Model};
use wienna::workload::{resnet50::resnet50, unet::unet};

fn per_type_energy(sys: &SystemConfig, model: &Model, strategy: Strategy) -> Table {
    let ei = CostEngine::for_design_point(sys, DesignPoint::INTERPOSER_C);
    let ew = CostEngine::for_design_point(sys, DesignPoint::WIENNA_C);
    let mut t = Table::new(
        &format!("{} under {} — distribution energy (mJ)", model.name, strategy.label()),
        &["layer type", "interposer", "WIENNA", "reduction"],
    );
    for ty in model.layer_types() {
        let mut ipj = 0.0;
        let mut wpj = 0.0;
        for l in model.layers.iter().filter(|l| classify(l) == ty) {
            ipj += evaluate_layer(&ei, l, strategy).dist_energy_pj;
            wpj += evaluate_layer(&ew, l, strategy).dist_energy_pj;
        }
        t.row(vec![
            ty.label().to_string(),
            format!("{:.2}", ipj * 1e-9),
            format!("{:.2}", wpj * 1e-9),
            format!("{:.1}%", (1.0 - wpj / ipj) * 100.0),
        ]);
    }
    t
}

fn main() {
    let sys = SystemConfig::default();
    let mut reductions = Vec::new();

    for model in [resnet50(64), unet(64)] {
        println!("\n##### Fig 9 — {}", model.name);
        for s in Strategy::ALL {
            let t = per_type_energy(&sys, &model, s);
            print!("{}", t.render());
            t.save_csv(&format!("bench_out/fig9_{}_{}.csv", model.name, s.label())).ok();
        }
        // Inset (c): end-to-end reduction, adaptive strategy sequence.
        let cmp = model_distribution_energy(&sys, &model, None);
        println!(
            "end-to-end (adaptive): interposer {:.1} mJ vs WIENNA {:.1} mJ -> reduction {:.1}%",
            cmp.interposer_pj * 1e-9,
            cmp.wienna_pj * 1e-9,
            cmp.reduction() * 100.0
        );
        reductions.push(cmp.reduction());
        for s in Strategy::ALL {
            let c = model_distribution_energy(&sys, &model, Some(s));
            reductions.push(c.reduction());
        }
    }

    println!(
        "\naverage reduction across models/strategies: {:.1}%  (paper: 38.2%)",
        reductions.iter().sum::<f64>() / reductions.len() as f64 * 100.0
    );

    let rn = resnet50(64);
    bench("fig9_energy_eval(resnet50)", 10, || model_distribution_energy(&sys, &rn, None).reduction());
}
