//! Ablation studies for the design choices DESIGN.md calls out:
//!
//! * **A1 — mesh multicast capability**: Table-4 baseline (replicated
//!   unicasts) vs the `tree_multicast` path-forwarding mesh. Quantifies
//!   how much of WIENNA's win survives a smarter electrical baseline.
//! * **A2 — inter-layer pipelining**: sequential Fig-6 schedules vs
//!   next-layer preload overlap (double buffering).
//! * **A3 — intra-chiplet mapping flexibility**: fixed NVDLA-style 8x8
//!   array vs the flexible divisor-pair mapper.
//! * **A4 — HBM staging**: the paper's SRAM-fed assumption vs the
//!   explicit HBM→SRAM refill bound.
//! * **A5 — MAC reconfiguration guard**: adaptive strategy switching cost
//!   on the wireless TDM schedule.

use wienna::config::{DesignPoint, SystemConfig};
use wienna::coordinator::pipeline::pipeline_makespan;
use wienna::coordinator::{Coordinator, StrategyPolicy};
use wienna::cost::memory::HbmModel;
use wienna::cost::{evaluate_model, CostEngine, DistFabric};
use wienna::dataflow::MapPolicy;
use wienna::nop::{MeshNop, TdmMac};
use wienna::report::Table;
use wienna::testutil::bench;
use wienna::workload::{resnet50::resnet50, unet::unet};

fn main() {
    let sys = SystemConfig::default();
    let models = [resnet50(64), unet(64)];

    // --- A1: mesh multicast capability ---
    let mut t = Table::new(
        "A1 — interposer multicast capability (end-to-end MACs/cycle, adaptive)",
        &["model", "no multicast (Table 4)", "tree forwarding", "WIENNA-C", "WIENNA gain vs tree"],
    );
    for m in &models {
        let base = CostEngine::for_design_point(&sys, DesignPoint::INTERPOSER_A);
        let mut tree = base.clone();
        if let DistFabric::Mesh(mesh) = &mut tree.dist {
            mesh.tree_multicast = true;
        }
        let w = CostEngine::for_design_point(&sys, DesignPoint::WIENNA_C);
        let b = evaluate_model(&base, m, None).macs_per_cycle;
        let tr = evaluate_model(&tree, m, None).macs_per_cycle;
        let wi = evaluate_model(&w, m, None).macs_per_cycle;
        t.row(vec![
            m.name.clone(),
            format!("{b:.0}"),
            format!("{tr:.0}"),
            format!("{wi:.0}"),
            format!("{:.2}x", wi / tr),
        ]);
    }
    print!("{}", t.render());
    t.save_csv("bench_out/ablation_multicast.csv").ok();

    // Energy side of A1.
    let mut te = Table::new(
        "A1e — distribution energy reduction vs interposer baseline flavor",
        &["model", "vs no-multicast mesh", "vs tree-forwarding mesh"],
    );
    for m in &models {
        let ew = CostEngine::for_design_point(&sys, DesignPoint::WIENNA_C);
        let ei = CostEngine::for_design_point(&sys, DesignPoint::INTERPOSER_C);
        let mut et = ei.clone();
        if let DistFabric::Mesh(mesh) = &mut et.dist {
            mesh.tree_multicast = true;
        }
        // Same (WIENNA-selected) strategy sequence on all three fabrics.
        let mut wpj = 0.0;
        let mut ipj = 0.0;
        let mut tpj = 0.0;
        for l in &m.layers {
            let (s, wc) = wienna::cost::best_strategy(&ew, l);
            wpj += wc.dist_energy_pj;
            ipj += wienna::cost::evaluate_layer(&ei, l, s).dist_energy_pj;
            tpj += wienna::cost::evaluate_layer(&et, l, s).dist_energy_pj;
        }
        te.row(vec![
            m.name.clone(),
            format!("{:.1}%", (1.0 - wpj / ipj) * 100.0),
            format!("{:.1}%", (1.0 - wpj / tpj) * 100.0),
        ]);
    }
    print!("{}", te.render());
    te.save_csv("bench_out/ablation_multicast_energy.csv").ok();

    // --- A2: inter-layer pipelining ---
    let mut tp = Table::new(
        "A2 — inter-layer pipelining (WIENNA-C, adaptive)",
        &["model", "sequential (cyc)", "pipelined (cyc)", "speedup", "hidden preloads"],
    );
    for m in &models {
        let e = CostEngine::for_design_point(&sys, DesignPoint::WIENNA_C);
        let costs = evaluate_model(&e, m, None).layers;
        // 512 KiB local buffer per chiplet (Simba-class).
        let r = pipeline_makespan(&costs, 512 * 1024);
        tp.row(vec![
            m.name.clone(),
            format!("{:.0}", r.sequential_cycles),
            format!("{:.0}", r.pipelined_cycles),
            format!("{:.3}x", r.speedup()),
            format!("{}/{}", r.fully_hidden, costs.len().saturating_sub(1)),
        ]);
    }
    print!("{}", tp.render());
    tp.save_csv("bench_out/ablation_pipeline.csv").ok();

    // --- A3: mapping flexibility ---
    let mut tm = Table::new(
        "A3 — intra-chiplet mapping policy (WIENNA-C, adaptive, MACs/cycle)",
        &["model", "fixed 8x8 array", "flexible divisor-pair", "gain"],
    );
    for m in &models {
        let mut fixed = CostEngine::for_design_point(&sys, DesignPoint::WIENNA_C);
        fixed.map_policy = MapPolicy::Fixed { dim0: 8, dim1: 8 };
        let flex = CostEngine::for_design_point(&sys, DesignPoint::WIENNA_C);
        let f = evaluate_model(&fixed, m, None).macs_per_cycle;
        let x = evaluate_model(&flex, m, None).macs_per_cycle;
        tm.row(vec![m.name.clone(), format!("{f:.0}"), format!("{x:.0}"), format!("{:.2}x", x / f)]);
    }
    print!("{}", tm.render());
    tm.save_csv("bench_out/ablation_mapping.csv").ok();

    // --- A4: HBM staging ---
    let mut th = Table::new(
        "A4 — HBM->SRAM staging bound (WIENNA-C, adaptive)",
        &["model", "SRAM-fed (paper)", "HBM 64 B/cyc", "HBM 256 B/cyc", "spilling layers"],
    );
    for m in &models {
        let base = CostEngine::for_design_point(&sys, DesignPoint::WIENNA_C);
        let mut hbm64 = base.clone();
        hbm64.hbm = Some(HbmModel::default());
        let mut hbm256 = base.clone();
        hbm256.hbm = Some(HbmModel { bw_bytes_per_cycle: 256.0, ..HbmModel::default() });
        let b = evaluate_model(&base, m, None);
        let h64 = evaluate_model(&hbm64, m, None);
        let h256 = evaluate_model(&hbm256, m, None);
        let spills = h64.layers.iter().filter(|l| l.staging.as_ref().is_some_and(|s| !s.resident)).count();
        th.row(vec![
            m.name.clone(),
            format!("{:.0}", b.macs_per_cycle),
            format!("{:.0}", h64.macs_per_cycle),
            format!("{:.0}", h256.macs_per_cycle),
            format!("{spills}/{}", m.layers.len()),
        ]);
    }
    print!("{}", th.render());
    th.save_csv("bench_out/ablation_hbm.csv").ok();

    // --- A5: MAC reconfiguration guard ---
    let coord = Coordinator::new(sys.clone(), DesignPoint::WIENNA_C, StrategyPolicy::Adaptive);
    let m = &models[0];
    let (schedules, _) = coord.run_model(m);
    let mac = TdmMac::new(16.0);
    let mut guard_total = 0.0;
    let mut airtime_total = 0.0;
    let mut prev: Option<wienna::dataflow::Strategy> = None;
    for s in &schedules {
        let reconf = prev.is_some_and(|p| p != s.selection.strategy);
        prev = Some(s.selection.strategy);
        let all: Vec<_> = s.preload.iter().chain(s.stream.iter()).cloned().collect();
        let sched = mac.compile(&all, reconf);
        guard_total += sched.guard_cycles;
        airtime_total += sched.airtime();
    }
    println!(
        "A5 — adaptive reconfiguration guard on {}: {:.0} guard cycles vs {:.0} airtime cycles ({:.4}% overhead)",
        m.name,
        guard_total,
        airtime_total,
        guard_total / airtime_total * 100.0
    );

    // A1 check for the mesh sanity: tree forwarding must never be slower.
    let mesh = MeshNop::new(256, 16.0, true);
    let mut tree_mesh = mesh.clone();
    tree_mesh.tree_multicast = true;
    assert!(tree_mesh.injection_copies(256.0) <= mesh.injection_copies(256.0));

    bench("ablation_grid(all)", 5, || {
        models
            .iter()
            .map(|m| evaluate_model(&CostEngine::for_design_point(&sys, DesignPoint::WIENNA_C), m, None).macs_per_cycle)
            .sum::<f64>()
    });
}
