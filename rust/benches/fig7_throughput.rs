//! Fig 7 — Throughput of conservative/aggressive interposer- and
//! WIENNA-based accelerators, per layer type and end-to-end.
//!
//! Headline claims reproduced here (shape, not absolute numbers):
//! * WIENNA improves end-to-end throughput 2.7–5.1x on ResNet-50 and
//!   2.2–3.8x on UNet over the interposer baselines;
//! * WIENNA-C beats Interposer-A (equal 16 B/cyc distribution BW);
//! * adaptive partitioning gains a few extra percent over all-KP-CP.

use wienna::config::{DesignPoint, SystemConfig};
use wienna::cost::{evaluate_layer, evaluate_model, CostEngine};
use wienna::dataflow::Strategy;
use wienna::report::Table;
use wienna::testutil::bench;
use wienna::workload::{classify, LayerType, Model};
use wienna::workload::{resnet50::resnet50, unet::unet};

fn type_throughput(engine: &CostEngine, model: &Model, ty: LayerType, strategy: Strategy) -> f64 {
    let mut macs = 0u64;
    let mut cycles = 0.0;
    for l in model.layers.iter().filter(|l| classify(l) == ty) {
        let c = evaluate_layer(engine, l, strategy);
        macs += c.macs;
        cycles += c.latency;
    }
    if cycles == 0.0 {
        0.0
    } else {
        macs as f64 / cycles
    }
}

fn main() {
    let sys = SystemConfig::default();

    for model in [resnet50(64), unet(64)] {
        println!("\n##### Fig 7 — {}", model.name);
        // Per layer type x strategy x design point.
        for ty in model.layer_types() {
            let mut t = Table::new(
                &format!("{} layers — MACs/cycle", ty.label()),
                &["strategy", "Interposer-C", "Interposer-A", "WIENNA-C", "WIENNA-A"],
            );
            for s in Strategy::ALL {
                let mut row = vec![s.label().to_string()];
                for dp in DesignPoint::ALL {
                    let e = CostEngine::for_design_point(&sys, dp);
                    row.push(format!("{:.0}", type_throughput(&e, &model, ty, s)));
                }
                t.row(row);
            }
            print!("{}", t.render());
            t.save_csv(&format!("bench_out/fig7_{}_{}.csv", model.name, ty.label().to_lowercase().replace('-', ""))).ok();
        }

        // End-to-end with adaptive partitioning.
        let mut e2e = Table::new(
            "end-to-end (adaptive) — MACs/cycle",
            &["design", "MACs/cycle", "vs Interposer-C", "vs Interposer-A"],
        );
        let mut th = Vec::new();
        for dp in DesignPoint::ALL {
            let e = CostEngine::for_design_point(&sys, dp);
            th.push(evaluate_model(&e, &model, None).macs_per_cycle);
        }
        for (i, dp) in DesignPoint::ALL.iter().enumerate() {
            e2e.row(vec![
                dp.label(),
                format!("{:.0}", th[i]),
                format!("{:.2}x", th[i] / th[0]),
                format!("{:.2}x", th[i] / th[1]),
            ]);
        }
        print!("{}", e2e.render());
        e2e.save_csv(&format!("bench_out/fig7_{}_e2e.csv", model.name)).ok();

        println!(
            "WIENNA speedup band: {:.2}x – {:.2}x  (paper: 2.7–5.1x ResNet50, 2.2–3.8x UNet)",
            (th[2] / th[1]).min(th[3] / th[1]),
            (th[2] / th[0]).max(th[3] / th[0])
        );
        println!("equal-bandwidth check — WIENNA-C vs Interposer-A: {:.2}x (paper: 2.58x / 2.21x)", th[2] / th[1]);

        // Adaptive vs all-KP-CP on WIENNA-C.
        let e = CostEngine::for_design_point(&sys, DesignPoint::WIENNA_C);
        let kpcp = evaluate_model(&e, &model, Some(Strategy::KpCp)).macs_per_cycle;
        let ad = evaluate_model(&e, &model, None).macs_per_cycle;
        println!("adaptive vs all-KP-CP: +{:.1}% (paper: +4.7% ResNet50, +9.1% UNet)", (ad / kpcp - 1.0) * 100.0);
    }

    let rn = resnet50(64);
    bench("fig7_e2e_eval(resnet50, 4 design points)", 5, || {
        DesignPoint::ALL
            .iter()
            .map(|&dp| evaluate_model(&CostEngine::for_design_point(&sys, dp), &rn, None).macs_per_cycle)
            .sum::<f64>()
    });
}
