//! Multi-tenant traffic classes: interactive / batch / best-effort.
//!
//! A class bundles everything that distinguishes one tenant population's
//! traffic from another's *besides* the model being run: its share of the
//! request stream, how much its SLO deadline is relaxed relative to the
//! mix entry's base SLO, and whether admission control may shed it for
//! being hopelessly late. Classes are totally ordered by scheduling
//! priority — the dispatcher always serves the highest-priority class
//! with queued work first, and (optionally) preempts a lower-class batch
//! already on the array when an interactive request would otherwise miss
//! its deadline.
//!
//! Class assignment is a **pure function of `(seed, request id)`** — not
//! of simulation state — so any sharded layout of the same request stream
//! tags every request identically. That property is one leg of the
//! cluster's bit-identical-at-any-thread-count guarantee.

use crate::serve::Request;
use crate::testutil::Rng;

/// Number of traffic classes (array dimension in the shard engine).
pub const NUM_CLASSES: usize = 3;

/// A tenant traffic class, ordered by scheduling priority (the derived
/// `Ord` puts `Interactive` first — highest priority).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum TrafficClass {
    /// Latency-sensitive user-facing traffic: full-strength SLO, may be
    /// shed when its deadline is already unreachable (a late answer is
    /// worthless), preempts lower classes when enabled.
    Interactive,
    /// Throughput-oriented offline work with a relaxed deadline.
    Batch,
    /// Scavenger traffic with no deadline at all; runs whenever nothing
    /// better is queued.
    BestEffort,
}

impl TrafficClass {
    /// All classes, highest priority first.
    pub const ALL: [TrafficClass; NUM_CLASSES] =
        [TrafficClass::Interactive, TrafficClass::Batch, TrafficClass::BestEffort];

    pub fn label(&self) -> &'static str {
        match self {
            TrafficClass::Interactive => "interactive",
            TrafficClass::Batch => "batch",
            TrafficClass::BestEffort => "best-effort",
        }
    }

    /// Scheduling priority; 0 is served first.
    pub fn priority(&self) -> usize {
        *self as usize
    }

    /// Dense index for per-class arrays (identical to priority).
    pub fn index(&self) -> usize {
        *self as usize
    }
}

/// Per-class traffic configuration.
#[derive(Debug, Clone, Copy)]
pub struct ClassSpec {
    pub class: TrafficClass,
    /// Relative share of the request stream (need not sum to 1).
    pub weight: f64,
    /// Multiplier on the mix entry's SLO window. `f64::INFINITY` removes
    /// the deadline entirely (best-effort).
    pub slo_scale: f64,
    /// Whether deadline-aware load shedding may refuse this class's
    /// arrivals when their predicted completion already misses the
    /// deadline.
    pub deadline_shed: bool,
}

/// The tenant population: class weights and per-class SLO handling.
#[derive(Debug, Clone)]
pub struct ClassMix {
    specs: Vec<ClassSpec>,
}

impl Default for ClassMix {
    /// A production-flavored default: half the stream is interactive at
    /// the mix SLO, 30% is batch at a 4x-relaxed deadline, the rest is
    /// deadline-free best-effort filler.
    fn default() -> Self {
        ClassMix::new(vec![
            ClassSpec { class: TrafficClass::Interactive, weight: 0.5, slo_scale: 1.0, deadline_shed: true },
            ClassSpec { class: TrafficClass::Batch, weight: 0.3, slo_scale: 4.0, deadline_shed: false },
            ClassSpec {
                class: TrafficClass::BestEffort,
                weight: 0.2,
                slo_scale: f64::INFINITY,
                deadline_shed: false,
            },
        ])
    }
}

impl ClassMix {
    pub fn new(specs: Vec<ClassSpec>) -> Self {
        assert!(!specs.is_empty(), "class mix needs at least one class");
        assert!(specs.iter().all(|s| s.weight > 0.0 && s.slo_scale >= 1.0));
        let mut seen = [false; NUM_CLASSES];
        for s in &specs {
            assert!(!seen[s.class.index()], "duplicate class {}", s.class.label());
            seen[s.class.index()] = true;
        }
        ClassMix { specs }
    }

    /// A single-class population (used by tests and the single-tenant
    /// compatibility path).
    pub fn single(class: TrafficClass, slo_scale: f64, deadline_shed: bool) -> Self {
        ClassMix::new(vec![ClassSpec { class, weight: 1.0, slo_scale, deadline_shed }])
    }

    pub fn specs(&self) -> &[ClassSpec] {
        &self.specs
    }

    /// The spec for `class`, if this population carries that class.
    pub fn spec_for(&self, class: TrafficClass) -> Option<&ClassSpec> {
        self.specs.iter().find(|s| s.class == class)
    }

    fn total_weight(&self) -> f64 {
        self.specs.iter().map(|s| s.weight).sum()
    }

    /// Assign a class to request `req_id` — a pure function of
    /// `(seed, req_id)`, independent of any simulation state (see the
    /// module docs for why that matters).
    pub fn assign(&self, seed: u64, req_id: u64) -> &ClassSpec {
        // One SplitMix64 draw keyed by (seed, id): SplitMix is an
        // avalanche permutation, so consecutive ids decorrelate fully.
        let mut rng = Rng::new(seed ^ req_id.wrapping_mul(0x9E3779B97F4A7C15));
        let mut u = rng.next_f32() as f64 * self.total_weight();
        for s in &self.specs {
            if u < s.weight {
                return s;
            }
            u -= s.weight;
        }
        self.specs.last().unwrap()
    }

    /// Tag `req` with its class and stretch its deadline by the class's
    /// SLO scale. Returns the assigned class.
    pub fn classify(&self, seed: u64, req: &mut Request) -> TrafficClass {
        let spec = self.assign(seed, req.id);
        // An infinite scale removes the deadline outright — computed as
        // `window * INFINITY` it would turn a zero window into a NaN
        // deadline, which the EDF comparators must never see.
        req.deadline = if spec.slo_scale.is_finite() {
            let window = req.deadline - req.arrival;
            req.arrival + window * spec.slo_scale
        } else {
            f64::INFINITY
        };
        spec.class
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::ModelKind;

    fn req(id: u64) -> Request {
        Request { id, kind: ModelKind::TinyCnn, arrival: 1000.0, deadline: 2000.0, client: None }
    }

    #[test]
    fn priority_order_is_interactive_first() {
        assert!(TrafficClass::Interactive < TrafficClass::Batch);
        assert!(TrafficClass::Batch < TrafficClass::BestEffort);
        assert_eq!(TrafficClass::Interactive.priority(), 0);
        assert_eq!(TrafficClass::ALL[0], TrafficClass::Interactive);
    }

    #[test]
    fn assignment_is_deterministic_in_seed_and_id() {
        let mix = ClassMix::default();
        for id in 0..200 {
            assert_eq!(mix.assign(7, id).class, mix.assign(7, id).class);
        }
        // A different seed produces a different tagging somewhere.
        let differs = (0..200).any(|id| mix.assign(7, id).class != mix.assign(8, id).class);
        assert!(differs, "seed must steer the class assignment");
    }

    #[test]
    fn assignment_respects_weights() {
        let mix = ClassMix::default();
        let n = 8000u64;
        let mut counts = [0u64; NUM_CLASSES];
        for id in 0..n {
            counts[mix.assign(42, id).class.index()] += 1;
        }
        let frac = |c: usize| counts[c] as f64 / n as f64;
        assert!((frac(0) - 0.5).abs() < 0.05, "interactive {:.2}", frac(0));
        assert!((frac(1) - 0.3).abs() < 0.05, "batch {:.2}", frac(1));
        assert!((frac(2) - 0.2).abs() < 0.05, "best-effort {:.2}", frac(2));
    }

    #[test]
    fn classify_scales_the_deadline() {
        let mix = ClassMix::single(TrafficClass::Batch, 4.0, false);
        let mut r = req(3);
        let class = mix.classify(1, &mut r);
        assert_eq!(class, TrafficClass::Batch);
        assert!((r.deadline - (1000.0 + 4.0 * 1000.0)).abs() < 1e-9);

        let free = ClassMix::single(TrafficClass::BestEffort, f64::INFINITY, false);
        let mut r = req(4);
        free.classify(1, &mut r);
        assert!(r.deadline.is_infinite(), "best-effort carries no deadline");

        // A zero SLO window with an infinite scale must yield an infinite
        // deadline, not the NaN that 0 * INFINITY would produce (NaN
        // deadlines panic the EDF comparators).
        let mut zero = Request {
            id: 5,
            kind: ModelKind::TinyCnn,
            arrival: 1000.0,
            deadline: 1000.0,
            client: None,
        };
        free.classify(1, &mut zero);
        assert!(zero.deadline.is_infinite());
    }

    #[test]
    #[should_panic(expected = "duplicate class")]
    fn duplicate_classes_are_rejected() {
        ClassMix::new(vec![
            ClassSpec { class: TrafficClass::Batch, weight: 1.0, slo_scale: 1.0, deadline_shed: false },
            ClassSpec { class: TrafficClass::Batch, weight: 1.0, slo_scale: 2.0, deadline_shed: false },
        ]);
    }
}
