//! Bucketed completion calendar — the calendar-queue scheduler's index of
//! in-flight batch completions, keyed by cycle.
//!
//! The legacy event loop finds the next completion with an O(packages)
//! scan over `Package::busy_until` on every event. This structure keeps
//! the same information sorted: one entry per in-flight batch, bucketed
//! by the high bits of the completion cycle's IEEE-754 representation.
//! Positive finite doubles order exactly like their bit patterns, so
//! `bits >> BUCKET_SHIFT` partitions the cycle axis monotonically — the
//! first non-empty bucket always contains the globally earliest entry,
//! and the bucket width adapts to the magnitude of the clock (each
//! bucket spans a ~2⁻²⁰ relative range) with no tuning parameter.
//!
//! Entries are invalidated *lazily*: a preemption or fault abort simply
//! leaves its entry behind, and [`CompletionCalendar::peek_min`] purges
//! entries its caller's validity predicate rejects while scanning. That
//! keeps every mutation site in the shard loop O(log buckets) and pushes
//! all cleanup onto the (already bucket-local) peek path.
//!
//! Tie-breaking matters for determinism: entries compare as
//! `(cycle_bits, package)` tuples, so two batches completing on the same
//! cycle resolve to the lowest package index — exactly the order the
//! legacy strict-`<` scan produced.

use std::collections::BTreeMap;

/// High bits of the f64 bit pattern used as the bucket key. Dropping the
/// low 32 mantissa bits groups completions into buckets spanning about a
/// 2⁻²⁰ relative range of the cycle value — fine enough that a bucket
/// rarely holds more than the batches of one dispatch wave, coarse
/// enough that the `BTreeMap` stays tiny.
const BUCKET_SHIFT: u32 = 32;

/// One entry per in-flight batch: `(busy_until.to_bits(), package)`.
#[derive(Debug, Default)]
pub(crate) struct CompletionCalendar {
    buckets: BTreeMap<i64, Vec<(u64, usize)>>,
    len: usize,
}

impl CompletionCalendar {
    pub(crate) fn new() -> Self {
        CompletionCalendar::default()
    }

    fn bucket_key(bits: u64) -> i64 {
        (bits >> BUCKET_SHIFT) as i64
    }

    /// Index a batch completing at cycle `at` on `pkg`. `at` must be a
    /// positive finite cycle (a dispatched batch always ends after 0).
    pub(crate) fn insert(&mut self, at: f64, pkg: usize) {
        debug_assert!(at.is_finite() && at > 0.0, "completion cycle {at} out of range");
        let bits = at.to_bits();
        self.buckets.entry(Self::bucket_key(bits)).or_default().push((bits, pkg));
        self.len += 1;
    }

    /// Remove one known-present entry (the peeked minimum, about to be
    /// completed). Stale aliases of the same `(bits, pkg)` pair are left
    /// behind for the lazy purge.
    pub(crate) fn remove(&mut self, bits: u64, pkg: usize) {
        let key = Self::bucket_key(bits);
        let bucket = self.buckets.get_mut(&key).expect("removing from a present bucket");
        let pos = bucket
            .iter()
            .position(|&e| e == (bits, pkg))
            .expect("removing a present calendar entry");
        bucket.swap_remove(pos);
        self.len -= 1;
        if bucket.is_empty() {
            self.buckets.remove(&key);
        }
    }

    /// The earliest valid entry as `(cycle_bits, package)`, purging every
    /// invalid (stale) entry encountered on the way. `valid(pkg, bits)`
    /// decides liveness — the shard passes "package busy with exactly
    /// this `busy_until`". Within a bucket the minimum is taken over the
    /// `(bits, pkg)` tuple order, so equal-cycle ties resolve to the
    /// lowest package index.
    pub(crate) fn peek_min(
        &mut self,
        valid: impl Fn(usize, u64) -> bool,
    ) -> Option<(u64, usize)> {
        loop {
            let (&key, _) = self.buckets.iter().next()?;
            let bucket = self.buckets.get_mut(&key).expect("first bucket exists");
            let before = bucket.len();
            bucket.retain(|&(bits, pkg)| valid(pkg, bits));
            self.len -= before - bucket.len();
            match bucket.iter().copied().min() {
                Some(entry) => return Some(entry),
                None => {
                    self.buckets.remove(&key);
                }
            }
        }
    }

    /// Live + stale entries currently indexed (tests only).
    #[cfg(test)]
    pub(crate) fn len(&self) -> usize {
        self.len
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn drain_valid(cal: &mut CompletionCalendar) -> Vec<(f64, usize)> {
        let mut out = Vec::new();
        while let Some((bits, pkg)) = cal.peek_min(|_, _| true) {
            cal.remove(bits, pkg);
            out.push((f64::from_bits(bits), pkg));
        }
        out
    }

    #[test]
    fn entries_pop_in_cycle_then_package_order() {
        let mut cal = CompletionCalendar::new();
        // Spread across magnitudes so several buckets exist, plus an
        // exact tie on 500.0 that must resolve to the lower package.
        for &(t, p) in &[(500.0, 3), (0.25, 1), (500.0, 2), (1e9, 0), (499.9999, 7)] {
            cal.insert(t, p);
        }
        assert_eq!(cal.len(), 5);
        let order = drain_valid(&mut cal);
        assert_eq!(
            order,
            vec![(0.25, 1), (499.9999, 7), (500.0, 2), (500.0, 3), (1e9, 0)]
        );
        assert_eq!(cal.len(), 0);
    }

    #[test]
    fn stale_entries_are_purged_by_peek() {
        let mut cal = CompletionCalendar::new();
        cal.insert(10.0, 0); // will be invalidated (e.g. preempted)
        cal.insert(20.0, 1);
        let got = cal.peek_min(|pkg, _| pkg != 0);
        assert_eq!(got, Some((20.0f64.to_bits(), 1)));
        assert_eq!(cal.len(), 1, "the stale entry is gone after the scan");
        // A fully stale calendar answers None and ends empty.
        let mut dead = CompletionCalendar::new();
        dead.insert(1.0, 0);
        dead.insert(2.0, 1);
        assert_eq!(dead.peek_min(|_, _| false), None);
        assert_eq!(dead.len(), 0);
    }

    #[test]
    fn duplicate_alias_survives_a_single_remove() {
        // A preempted batch's stale entry can alias a re-dispatch with an
        // identical busy_until. Removing the peeked minimum must take
        // exactly one of them; the twin is purged once it goes stale.
        let mut cal = CompletionCalendar::new();
        cal.insert(5.0, 2);
        cal.insert(5.0, 2);
        let (bits, pkg) = cal.peek_min(|_, _| true).unwrap();
        cal.remove(bits, pkg);
        assert_eq!(cal.len(), 1);
        assert_eq!(cal.peek_min(|_, _| false), None, "the twin purges as stale");
    }

    #[test]
    fn peek_skips_whole_stale_buckets() {
        let mut cal = CompletionCalendar::new();
        cal.insert(1.0, 0); // bucket A — goes stale
        cal.insert(1e12, 1); // bucket far away
        let got = cal.peek_min(|pkg, _| pkg == 1);
        assert_eq!(got, Some((1e12f64.to_bits(), 1)));
    }
}
