//! `wienna::cluster` — sharded multi-tenant serving over package fleets.
//!
//! The datacenter tier above [`serve::Fleet`](crate::serve::Fleet): where
//! `serve` runs one single-threaded event loop over one fleet with one
//! best-effort traffic class, this module simulates a *cluster* —
//! a large package fleet partitioned into shards that run their event
//! loops on worker threads, serving mixed, prioritized tenant traffic
//! under admission control. Three guarantees shape the design:
//!
//! 1. **Determinism at any thread count.** Arrivals are generated and
//!    classified centrally (pure functions of the seed and request id),
//!    statically striped across shards (by request id for open-loop
//!    sources, by issuing client for closed-loop ones), and each shard's
//!    window simulation depends only on its input. The per-shard event
//!    streams are interleaved by a deterministic
//!    `(epoch, cycle, shard, seq)` merge ([`merge`]), and everything that
//!    crosses shards — closed-loop completion feedback, stolen work —
//!    does so at single-threaded epoch barriers ([`sync`]). A fixed seed
//!    therefore yields **bit-identical [`ClusterStats`]** whether the run
//!    used 1 worker thread or 64 — the integration suite, the
//!    `testutil::fuzz_determinism` harness and the CI determinism gate
//!    all diff the emitted stats JSON across thread counts.
//! 2. **Multi-tenant traffic classes.** Every request is tagged
//!    interactive / batch / best-effort ([`class`]); dispatch is strict
//!    priority across classes (EDF across models within a class), and an
//!    interactive arrival may optionally *preempt* an in-flight
//!    lower-class batch that would make it miss its deadline.
//! 3. **Per-package admission control.** Queue caps and deadline-aware
//!    load shedding ([`admission`]) bound memory and stop the cluster
//!    from burning cycles on answers that are already late; a full queue
//!    displaces its newest strictly-lower-class occupant rather than
//!    refusing a higher-class arrival, so scavenger backlog can never
//!    crowd out interactive traffic. Shed counts and per-class SLO
//!    attainment land in [`ClusterStats`].
//!
//! Sharding is static, mirroring how L7 load balancers stripe traffic
//! across cells; the route policy balances load *within* each shard, and
//! the opt-in epoch-barrier **work-stealing pass**
//! ([`SyncConfig::steal`]) rebalances queued batches *across* shards
//! when skewed traffic leaves a stripe hot. Closed-loop sources
//! (`Source::closed_loop`, `Source::client_trace`) run under the
//! conservative time-window scheme of [`sync`]; open-loop sources
//! without stealing take a zero-barrier fast path that is byte-identical
//! to the pre-sync engine.
//!
//! ## Example
//!
//! ```no_run
//! use wienna::cluster::{Cluster, ClusterConfig, SyncConfig};
//! use wienna::config::DesignPoint;
//! use wienna::serve::{ms_to_cycles, ModelKind, PackageSpec, Source, WorkloadMix};
//!
//! // 16 WIENNA-C packages, 4 shards, work stealing at the epoch edges.
//! let cluster = Cluster::new(
//!     PackageSpec::homogeneous(16, DesignPoint::WIENNA_C),
//!     ClusterConfig {
//!         shards: 4,
//!         sync: SyncConfig { steal: true, ..Default::default() },
//!         ..Default::default()
//!     },
//! );
//! let mix = WorkloadMix::single(ModelKind::ResNet50, 25.0);
//! // A closed-loop client pool: 64 clients, 2 ms think time.
//! let mut source = Source::closed_loop(mix, 64, 2.0, 50, 42);
//! let stats = cluster.run(&mut source, f64::INFINITY);
//! println!(
//!     "interactive p99 {:.2} ms | shed {:.1}% | steals {} over {} epochs",
//!     stats.class_latency_ms(wienna::cluster::TrafficClass::Interactive, 99.0),
//!     stats.serve.shed_rate() * 100.0,
//!     stats.steals,
//!     stats.epochs,
//! );
//! ```

pub mod admission;
mod calendar;
pub mod class;
pub mod merge;
pub mod shard;
pub mod sync;

pub use admission::{AdmissionConfig, ShedReason};
pub use class::{ClassMix, ClassSpec, TrafficClass, NUM_CLASSES};
pub use merge::ClusterStats;
pub use sync::{SyncConfig, TraceEvent};

use crate::cost::par;
use crate::fault::{ContentionConfig, FaultPlan, RetryPolicy};
use crate::power::PowerConfig;
use crate::serve::{BatcherConfig, PackageSpec, RoutePolicy, Source};

/// Everything that configures a cluster besides its package specs.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    /// Shards the fleet is partitioned into. Part of the *semantics*
    /// (sharding changes routing locality), unlike `threads`, which only
    /// changes how fast the simulation runs. Clamped to the package count.
    pub shards: usize,
    /// Worker threads the shard simulations fan out over.
    pub threads: usize,
    /// Routing policy applied within each shard.
    pub policy: RoutePolicy,
    pub batcher: BatcherConfig,
    /// Tenant population: class weights and per-class SLO handling.
    pub classes: ClassMix,
    /// Per-package admission control.
    pub admission: AdmissionConfig,
    /// Allow higher classes to abort in-flight lower-class batches.
    pub preemption: bool,
    /// Time-window synchronization: epoch width and the epoch-barrier
    /// work-stealing pass ([`sync`]).
    pub sync: SyncConfig,
    /// Energy metering + optional power-cap governor (`wienna::power`).
    /// The fleet-level cap is statically partitioned across shards in
    /// proportion to the packages each governs, so shard simulations stay
    /// independent (and thread-count-deterministic); stolen work runs
    /// under its *victim's* cap slice. No cap by default.
    pub power: PowerConfig,
    /// Fold in-class batching gains into the deadline-shed / EDF-routing
    /// completion estimate (ROADMAP: the batch-1 estimate is too
    /// conservative under deep backlogs). The calibrated estimate is
    /// never larger than the conservative one, so it can only *admit
    /// more*, never shed a request the conservative estimate would have
    /// served. Off by default: switching estimators changes scheduling
    /// decisions, and the default output is kept byte-compatible.
    pub calibrated_eta: bool,
    /// Seed of the class-assignment hash (independent of the arrival
    /// seed, so the same traffic can be re-tagged).
    pub class_seed: u64,
    /// Observability (`wienna::telemetry`): arm the per-request span
    /// recorder and the per-epoch metrics sampler. Off by default — the
    /// always-on cycle-attribution sums are collected regardless, but
    /// span retention costs memory proportional to the request count.
    /// Enabled output is still bit-identical at any thread count.
    pub telemetry: crate::telemetry::TelemetryConfig,
    /// Seeded chaos scenario (`wienna::fault`): package deaths,
    /// degradations, shard stalls and contention spikes at fixed cycles.
    /// Empty by default — with no plan the engine's arithmetic is
    /// untouched bit for bit.
    pub faults: FaultPlan,
    /// Shared-medium MAC contention model (`wienna::fault`). Disabled by
    /// default for the same byte-compatibility reason.
    pub contention: ContentionConfig,
    /// Retry/backoff policy for dispatches that die under a package
    /// death. Only consulted when a fault plan is active.
    pub retry: RetryPolicy,
    /// Which per-shard event scheduler drives the simulation. The
    /// default calendar queue and the legacy full-scan loop are
    /// byte-identical in every artifact; the legacy path is kept as the
    /// equivalence oracle behind `--scheduler legacy`.
    pub scheduler: SchedulerKind,
}

/// Per-shard event-scheduler selection ([`ClusterConfig::scheduler`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedulerKind {
    /// Bucketed completion calendar + dirty-set dispatch (the fast
    /// default): O(log buckets) completion lookup instead of an
    /// O(packages) scan per event.
    Calendar,
    /// The original full-scan event loop, kept verbatim as the
    /// determinism oracle the calendar path is tested against.
    Legacy,
}

impl Default for SchedulerKind {
    fn default() -> Self {
        SchedulerKind::Calendar
    }
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            shards: 4,
            threads: par::num_threads(),
            policy: RoutePolicy::EarliestDeadline,
            batcher: BatcherConfig::default(),
            classes: ClassMix::default(),
            admission: AdmissionConfig::default(),
            preemption: true,
            sync: SyncConfig::default(),
            power: PowerConfig::default(),
            calibrated_eta: false,
            class_seed: 0xC1A5,
            telemetry: crate::telemetry::TelemetryConfig::default(),
            faults: FaultPlan::default(),
            contention: ContentionConfig::default(),
            retry: RetryPolicy::default(),
            scheduler: SchedulerKind::Calendar,
        }
    }
}

/// A sharded cluster of packages plus its serving configuration.
pub struct Cluster {
    /// Package specs, already partitioned round-robin across shards so
    /// heterogeneous fleets spread evenly.
    pub(crate) specs_by_shard: Vec<Vec<PackageSpec>>,
    pub cfg: ClusterConfig,
}

impl Cluster {
    pub fn new(specs: Vec<PackageSpec>, mut cfg: ClusterConfig) -> Self {
        assert!(!specs.is_empty(), "cluster needs at least one package");
        cfg.shards = cfg.shards.clamp(1, specs.len());
        let mut by_shard: Vec<Vec<PackageSpec>> = (0..cfg.shards).map(|_| Vec::new()).collect();
        for (i, s) in specs.into_iter().enumerate() {
            by_shard[i % cfg.shards].push(s);
        }
        Cluster { specs_by_shard: by_shard, cfg }
    }

    pub fn shards(&self) -> usize {
        self.specs_by_shard.len()
    }

    pub fn packages_total(&self) -> usize {
        self.specs_by_shard.iter().map(|s| s.len()).sum()
    }

    /// Run the epoch-synchronized sharded simulation: admit arrivals
    /// issued up to `horizon_cycles`, classify and stripe them across
    /// shards, simulate window by window (parallel over `cfg.threads`
    /// workers), exchange completion feedback and stolen work at the
    /// deterministic epoch barriers, and drain everything admitted. Both
    /// open- and closed-loop sources are accepted (see [`sync`]).
    pub fn run(&self, source: &mut Source, horizon_cycles: f64) -> ClusterStats {
        sync::run_sync(self, source, horizon_cycles, None, None)
    }

    /// [`Cluster::run`], additionally returning every finalized request
    /// in merged event order — which shard served or shed it, and when.
    /// The conservation property tests audit this trace (each admitted
    /// request finalized exactly once, on exactly one shard, stealing or
    /// not); it is also a useful debugging artifact.
    pub fn run_traced(&self, source: &mut Source, horizon_cycles: f64) -> (ClusterStats, Vec<TraceEvent>) {
        let mut trace = Vec::new();
        let stats = sync::run_sync(self, source, horizon_cycles, Some(&mut trace), None);
        (stats, trace)
    }

    /// [`Cluster::run`] with incremental metrics streaming: each epoch
    /// barrier appends its sample (and any SLO raise/clear events) to
    /// `writer` as `wienna-metrics-stream-v1` JSONL lines the moment it
    /// completes. The writer only ever runs at the single-threaded
    /// barrier, so the streamed byte sequence is identical at any worker
    /// thread count. The caller finishes the artifact by writing the
    /// summary line (see [`crate::telemetry::MetricsStreamWriter`]).
    pub fn run_streaming(
        &self,
        source: &mut Source,
        horizon_cycles: f64,
        writer: &mut crate::telemetry::MetricsStreamWriter<'_>,
    ) -> ClusterStats {
        sync::run_sync(self, source, horizon_cycles, None, Some(writer))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DesignPoint;
    use crate::serve::{ms_to_cycles, MixEntry, ModelKind, WorkloadMix};

    fn tiny_mix() -> WorkloadMix {
        WorkloadMix::new(vec![MixEntry {
            kind: ModelKind::TinyCnn,
            weight: 1.0,
            slo_cycles: ms_to_cycles(25.0),
        }])
    }

    fn run(shards: usize, threads: usize, rate: f64) -> ClusterStats {
        let cluster = Cluster::new(
            PackageSpec::homogeneous(4, DesignPoint::WIENNA_C),
            ClusterConfig { shards, threads, ..Default::default() },
        );
        let mut source = Source::poisson(tiny_mix(), rate, 42);
        cluster.run(&mut source, ms_to_cycles(10.0))
    }

    #[test]
    fn thread_count_does_not_change_the_stats_json() {
        let a = run(4, 1, 4000.0);
        let b = run(4, 2, 4000.0);
        let c = run(4, 4, 4000.0);
        assert_eq!(a.to_json(), b.to_json(), "1 vs 2 threads");
        assert_eq!(a.to_json(), c.to_json(), "1 vs 4 threads");
        assert!(a.serve.completed() > 0);
        assert_eq!(a.epochs, 1, "open-loop no-steal runs one unbounded epoch");
    }

    #[test]
    fn conservation_holds_with_admission_control() {
        let stats = run(4, 2, 20_000.0); // overload → sheds
        assert_eq!(
            stats.serve.arrived(),
            stats.serve.completed() + stats.serve.shed() + stats.serve.failed(),
            "arrived = completed + shed + failed after a drained run"
        );
        assert_eq!(
            stats.shed_queue_full + stats.shed_deadline + stats.shed_overload,
            stats.serve.shed()
        );
        assert_eq!(stats.serve.failed(), 0, "no faults injected, nothing may fail");
        let by_class_arrived: u64 = stats.per_class.values().map(|m| m.arrived).sum();
        assert_eq!(by_class_arrived, stats.serve.arrived());
        let by_class_done: u64 =
            stats.per_class.values().map(|m| m.completed + m.shed + m.failed).sum();
        assert_eq!(by_class_done, stats.serve.arrived());
    }

    #[test]
    fn interactive_outranks_lower_classes_under_overload() {
        // Offer 4x the fleet's estimated capacity for 20 ms: queues blow
        // up, deadline shedding bounds admitted-interactive waits near
        // the 25 ms SLO, and the drain stretches batch/best-effort tails
        // far past it. Strict priority must keep interactive latency
        // below the classes it bypasses (their deadlines differ, so
        // compare raw latency, not violation rates).
        let mut probe = crate::serve::Fleet::new(
            PackageSpec::homogeneous(4, DesignPoint::WIENNA_C),
            RoutePolicy::EarliestDeadline,
        );
        let cap = probe.estimate_capacity_rps(&tiny_mix(), 8);
        let cluster = Cluster::new(
            PackageSpec::homogeneous(4, DesignPoint::WIENNA_C),
            ClusterConfig { shards: 2, threads: 2, ..Default::default() },
        );
        let mut source = Source::poisson(tiny_mix(), cap * 4.0, 42);
        let stats = cluster.run(&mut source, ms_to_cycles(20.0));
        let i = stats.class_latency_ms(TrafficClass::Interactive, 99.0);
        let b = stats.class_latency_ms(TrafficClass::Batch, 99.0);
        let e = stats.class_latency_ms(TrafficClass::BestEffort, 99.0);
        assert!(i.is_finite() && b.is_finite() && e.is_finite(), "all classes completed work");
        assert!(i < b, "interactive p99 {i:.2} ms vs batch {b:.2} ms");
        assert!(i < e, "interactive p99 {i:.2} ms vs best-effort {e:.2} ms");
    }

    #[test]
    fn shards_clamp_to_package_count() {
        let c = Cluster::new(
            PackageSpec::homogeneous(2, DesignPoint::WIENNA_C),
            ClusterConfig { shards: 16, ..Default::default() },
        );
        assert_eq!(c.shards(), 2);
        assert_eq!(c.packages_total(), 2);
    }

    #[test]
    fn closed_loop_sources_now_run_and_drain_fully() {
        // The tentpole: the old engine rejected closed-loop sources; the
        // sync layer runs them. Every client issues every request, all of
        // them complete (admit-all so the count is exact), and the pool's
        // pushback serializes each client's stream.
        let clients = 6;
        let per_client = 5u64;
        let cluster = Cluster::new(
            PackageSpec::homogeneous(4, DesignPoint::WIENNA_C),
            ClusterConfig {
                shards: 2,
                threads: 2,
                admission: AdmissionConfig::admit_all(),
                ..Default::default()
            },
        );
        let mut source = Source::closed_loop(tiny_mix(), clients, 0.5, per_client, 9);
        let stats = cluster.run(&mut source, f64::INFINITY);
        assert_eq!(stats.serve.arrived(), clients as u64 * per_client);
        assert_eq!(stats.serve.completed(), stats.serve.arrived());
        assert_eq!(stats.serve.shed(), 0);
        assert!(stats.epochs > 1, "closed-loop runs are windowed");
    }

    #[test]
    fn shed_requests_still_rearm_their_closed_loop_clients() {
        // A shed is a fast-fail response: the client observes it and
        // issues its next request. A zero-cap cluster sheds every single
        // arrival, yet every client must still issue its full session —
        // were sheds swallowed, each client would stall after its first
        // request and `arrived` would collapse to the client count.
        let clients = 5;
        let per_client = 4u64;
        let cluster = Cluster::new(
            PackageSpec::homogeneous(4, DesignPoint::WIENNA_C),
            ClusterConfig {
                shards: 2,
                threads: 2,
                admission: AdmissionConfig { queue_cap: Some(0), shed_late: false },
                ..Default::default()
            },
        );
        let mut source = Source::closed_loop(tiny_mix(), clients, 0.3, per_client, 21);
        let stats = cluster.run(&mut source, f64::INFINITY);
        assert_eq!(stats.serve.arrived(), clients as u64 * per_client);
        assert_eq!(stats.serve.shed(), stats.serve.arrived(), "cap 0 sheds everything");
        assert_eq!(stats.serve.completed(), 0);
    }

    #[test]
    fn client_trace_source_runs_on_the_cluster() {
        // Recorded per-client timestamps replay under the sync layer; the
        // run drains every recorded request exactly once.
        let traces = vec![vec![0.1, 0.4, 2.0], vec![0.2, 0.9], vec![1.5]];
        let total: u64 = traces.iter().map(|c| c.len() as u64).sum();
        let cluster = Cluster::new(
            PackageSpec::homogeneous(2, DesignPoint::WIENNA_C),
            ClusterConfig {
                shards: 2,
                threads: 2,
                admission: AdmissionConfig::admit_all(),
                ..Default::default()
            },
        );
        let mut source = Source::client_trace(tiny_mix(), &traces, 4);
        let stats = cluster.run(&mut source, f64::INFINITY);
        assert_eq!(stats.serve.arrived(), total);
        assert_eq!(stats.serve.completed(), total);
    }
}
