//! `wienna::cluster` — sharded multi-tenant serving over package fleets.
//!
//! The datacenter tier above [`serve::Fleet`](crate::serve::Fleet): where
//! `serve` runs one single-threaded event loop over one fleet with one
//! best-effort traffic class, this module simulates a *cluster* —
//! a large package fleet partitioned into shards that run their event
//! loops on worker threads, serving mixed, prioritized tenant traffic
//! under admission control. Three guarantees shape the design:
//!
//! 1. **Determinism at any thread count.** Arrivals are generated and
//!    classified centrally (pure functions of the seed and request id),
//!    statically striped across shards by request id, and each shard's
//!    simulation depends only on its input slice. The per-shard event
//!    streams are then interleaved by a deterministic
//!    `(cycle, shard, seq)` merge ([`merge`]). A fixed seed therefore
//!    yields **bit-identical [`ClusterStats`]** whether the run used 1
//!    worker thread or 64 — the integration suite and the CI determinism
//!    gate both diff the emitted stats JSON across thread counts.
//! 2. **Multi-tenant traffic classes.** Every request is tagged
//!    interactive / batch / best-effort ([`class`]); dispatch is strict
//!    priority across classes (EDF across models within a class), and an
//!    interactive arrival may optionally *preempt* an in-flight
//!    lower-class batch that would make it miss its deadline.
//! 3. **Per-package admission control.** Queue caps and deadline-aware
//!    load shedding ([`admission`]) bound memory and stop the cluster
//!    from burning cycles on answers that are already late; a full queue
//!    displaces its newest strictly-lower-class occupant rather than
//!    refusing a higher-class arrival, so scavenger backlog can never
//!    crowd out interactive traffic. Shed counts and per-class SLO
//!    attainment land in [`ClusterStats`].
//!
//! Sharding is static (round-robin by request id), mirroring how L7 load
//! balancers stripe traffic across cells; the route policy balances load
//! *within* each shard. Closed-loop sources need completion feedback and
//! therefore stay on `Fleet::run`; the cluster engine takes open-loop
//! sources (Poisson, trace replay), which it can materialize up front.
//!
//! ## Example
//!
//! ```no_run
//! use wienna::cluster::{Cluster, ClusterConfig};
//! use wienna::config::DesignPoint;
//! use wienna::serve::{ms_to_cycles, ModelKind, PackageSpec, Source, WorkloadMix};
//!
//! // 16 WIENNA-C packages, 4 shards, default classes + admission.
//! let cluster = Cluster::new(
//!     PackageSpec::homogeneous(16, DesignPoint::WIENNA_C),
//!     ClusterConfig { shards: 4, ..Default::default() },
//! );
//! let mix = WorkloadMix::single(ModelKind::ResNet50, 25.0);
//! let mut source = Source::poisson(mix, 8000.0, 42);
//! let stats = cluster.run(&mut source, ms_to_cycles(100.0));
//! println!(
//!     "interactive p99 {:.2} ms | shed {:.1}% | preemptions {}",
//!     stats.class_latency_ms(wienna::cluster::TrafficClass::Interactive, 99.0),
//!     stats.serve.shed_rate() * 100.0,
//!     stats.preemptions,
//! );
//! ```

pub mod admission;
pub mod class;
pub mod merge;
pub mod shard;

pub use admission::{AdmissionConfig, ShedReason};
pub use class::{ClassMix, ClassSpec, TrafficClass, NUM_CLASSES};
pub use merge::ClusterStats;

use crate::cost::par;
use crate::power::PowerConfig;
use crate::serve::{BatcherConfig, PackageSpec, RoutePolicy, Source};
use shard::ClassedRequest;

/// Everything that configures a cluster besides its package specs.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    /// Shards the fleet is partitioned into. Part of the *semantics*
    /// (sharding changes routing locality), unlike `threads`, which only
    /// changes how fast the simulation runs. Clamped to the package count.
    pub shards: usize,
    /// Worker threads the shard simulations fan out over.
    pub threads: usize,
    /// Routing policy applied within each shard.
    pub policy: RoutePolicy,
    pub batcher: BatcherConfig,
    /// Tenant population: class weights and per-class SLO handling.
    pub classes: ClassMix,
    /// Per-package admission control.
    pub admission: AdmissionConfig,
    /// Allow higher classes to abort in-flight lower-class batches.
    pub preemption: bool,
    /// Energy metering + optional power-cap governor (`wienna::power`).
    /// The fleet-level cap is statically partitioned across shards in
    /// proportion to the packages each governs, so shard simulations stay
    /// independent (and thread-count-deterministic). No cap by default.
    pub power: PowerConfig,
    /// Fold in-class batching gains into the deadline-shed / EDF-routing
    /// completion estimate (ROADMAP: the batch-1 estimate is too
    /// conservative under deep backlogs). The calibrated estimate is
    /// never larger than the conservative one, so it can only *admit
    /// more*, never shed a request the conservative estimate would have
    /// served. Off by default: switching estimators changes scheduling
    /// decisions, and the default output is kept byte-compatible.
    pub calibrated_eta: bool,
    /// Seed of the class-assignment hash (independent of the arrival
    /// seed, so the same traffic can be re-tagged).
    pub class_seed: u64,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            shards: 4,
            threads: par::num_threads(),
            policy: RoutePolicy::EarliestDeadline,
            batcher: BatcherConfig::default(),
            classes: ClassMix::default(),
            admission: AdmissionConfig::default(),
            preemption: true,
            power: PowerConfig::default(),
            calibrated_eta: false,
            class_seed: 0xC1A5,
        }
    }
}

/// A sharded cluster of packages plus its serving configuration.
pub struct Cluster {
    /// Package specs, already partitioned round-robin across shards so
    /// heterogeneous fleets spread evenly.
    specs_by_shard: Vec<Vec<PackageSpec>>,
    pub cfg: ClusterConfig,
}

impl Cluster {
    pub fn new(specs: Vec<PackageSpec>, mut cfg: ClusterConfig) -> Self {
        assert!(!specs.is_empty(), "cluster needs at least one package");
        cfg.shards = cfg.shards.clamp(1, specs.len());
        let mut by_shard: Vec<Vec<PackageSpec>> = (0..cfg.shards).map(|_| Vec::new()).collect();
        for (i, s) in specs.into_iter().enumerate() {
            by_shard[i % cfg.shards].push(s);
        }
        Cluster { specs_by_shard: by_shard, cfg }
    }

    pub fn shards(&self) -> usize {
        self.specs_by_shard.len()
    }

    pub fn packages_total(&self) -> usize {
        self.specs_by_shard.iter().map(|s| s.len()).sum()
    }

    /// Run the sharded simulation: admit arrivals up to `horizon_cycles`,
    /// classify and stripe them across shards, simulate every shard
    /// (parallel over `cfg.threads` workers), and merge the event streams
    /// deterministically.
    pub fn run(&self, source: &mut Source, horizon_cycles: f64) -> ClusterStats {
        assert!(
            source.is_open_loop(),
            "the cluster engine materializes arrivals up front; closed-loop sources need serve::Fleet::run"
        );
        assert!(
            horizon_cycles.is_finite() || source.is_bounded(),
            "an unbounded (Poisson) source needs a finite horizon"
        );
        let shards = self.shards();
        let mut stats = ClusterStats::new(shards);

        // Ingress: classify (pure in (class_seed, id)) and stripe by id.
        let mut inputs: Vec<Vec<ClassedRequest>> = (0..shards).map(|_| Vec::new()).collect();
        while let Some(t) = source.next_arrival_at() {
            if t > horizon_cycles {
                break;
            }
            let mut req = source.pop();
            let class = self.cfg.classes.classify(self.cfg.class_seed, &mut req);
            stats.record_ingress(&req, class);
            inputs[(req.id % shards as u64) as usize].push(ClassedRequest { req, class });
        }

        // The fleet power cap splits across shards in proportion to the
        // packages each governs (shards simulate independently — a shared
        // dynamic budget would couple them and break determinism).
        let total_packages = self.packages_total();
        let shard_caps: Vec<Option<f64>> = self
            .specs_by_shard
            .iter()
            .map(|s| self.cfg.power.shard_cap(s.len(), total_packages))
            .collect();

        // Shard simulations are pure functions of their input slice, so
        // the thread count can only change wall-clock time, not results.
        let outcomes = par::par_map(shards, self.cfg.threads, |s| {
            shard::run_shard(s, self.specs_by_shard[s].clone(), &inputs[s], &self.cfg, shard_caps[s])
        });

        merge::merge_into(&mut stats, outcomes, &self.cfg.power.model);
        stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DesignPoint;
    use crate::serve::{ms_to_cycles, MixEntry, ModelKind, WorkloadMix};

    fn tiny_mix() -> WorkloadMix {
        WorkloadMix::new(vec![MixEntry {
            kind: ModelKind::TinyCnn,
            weight: 1.0,
            slo_cycles: ms_to_cycles(25.0),
        }])
    }

    fn run(shards: usize, threads: usize, rate: f64) -> ClusterStats {
        let cluster = Cluster::new(
            PackageSpec::homogeneous(4, DesignPoint::WIENNA_C),
            ClusterConfig { shards, threads, ..Default::default() },
        );
        let mut source = Source::poisson(tiny_mix(), rate, 42);
        cluster.run(&mut source, ms_to_cycles(10.0))
    }

    #[test]
    fn thread_count_does_not_change_the_stats_json() {
        let a = run(4, 1, 4000.0);
        let b = run(4, 2, 4000.0);
        let c = run(4, 4, 4000.0);
        assert_eq!(a.to_json(), b.to_json(), "1 vs 2 threads");
        assert_eq!(a.to_json(), c.to_json(), "1 vs 4 threads");
        assert!(a.serve.completed() > 0);
    }

    #[test]
    fn conservation_holds_with_admission_control() {
        let stats = run(4, 2, 20_000.0); // overload → sheds
        assert_eq!(
            stats.serve.arrived(),
            stats.serve.completed() + stats.serve.shed(),
            "arrived = completed + shed after a drained run"
        );
        assert_eq!(stats.shed_queue_full + stats.shed_deadline, stats.serve.shed());
        let by_class_arrived: u64 = stats.per_class.values().map(|m| m.arrived).sum();
        assert_eq!(by_class_arrived, stats.serve.arrived());
        let by_class_done: u64 = stats.per_class.values().map(|m| m.completed + m.shed).sum();
        assert_eq!(by_class_done, stats.serve.arrived());
    }

    #[test]
    fn interactive_outranks_lower_classes_under_overload() {
        // Offer 4x the fleet's estimated capacity for 20 ms: queues blow
        // up, deadline shedding bounds admitted-interactive waits near
        // the 25 ms SLO, and the drain stretches batch/best-effort tails
        // far past it. Strict priority must keep interactive latency
        // below the classes it bypasses (their deadlines differ, so
        // compare raw latency, not violation rates).
        let mut probe = crate::serve::Fleet::new(
            PackageSpec::homogeneous(4, DesignPoint::WIENNA_C),
            RoutePolicy::EarliestDeadline,
        );
        let cap = probe.estimate_capacity_rps(&tiny_mix(), 8);
        let cluster = Cluster::new(
            PackageSpec::homogeneous(4, DesignPoint::WIENNA_C),
            ClusterConfig { shards: 2, threads: 2, ..Default::default() },
        );
        let mut source = Source::poisson(tiny_mix(), cap * 4.0, 42);
        let stats = cluster.run(&mut source, ms_to_cycles(20.0));
        let i = stats.class_latency_ms(TrafficClass::Interactive, 99.0);
        let b = stats.class_latency_ms(TrafficClass::Batch, 99.0);
        let e = stats.class_latency_ms(TrafficClass::BestEffort, 99.0);
        assert!(i.is_finite() && b.is_finite() && e.is_finite(), "all classes completed work");
        assert!(i < b, "interactive p99 {i:.2} ms vs batch {b:.2} ms");
        assert!(i < e, "interactive p99 {i:.2} ms vs best-effort {e:.2} ms");
    }

    #[test]
    fn shards_clamp_to_package_count() {
        let c = Cluster::new(
            PackageSpec::homogeneous(2, DesignPoint::WIENNA_C),
            ClusterConfig { shards: 16, ..Default::default() },
        );
        assert_eq!(c.shards(), 2);
        assert_eq!(c.packages_total(), 2);
    }

    #[test]
    #[should_panic(expected = "closed-loop")]
    fn closed_loop_sources_are_rejected() {
        let cluster = Cluster::new(
            PackageSpec::homogeneous(2, DesignPoint::WIENNA_C),
            ClusterConfig::default(),
        );
        let mut source = Source::closed_loop(tiny_mix(), 2, 1.0, 2, 1);
        cluster.run(&mut source, f64::INFINITY);
    }
}
