//! The per-shard serving engine: a class-aware discrete-event loop over
//! one slice of the cluster's packages.
//!
//! A shard receives its arrivals **pre-routed and pre-classified** by the
//! cluster ingress (`cluster::sync`), so its simulation depends only on
//! that input — never on scheduling, other shards, or the worker-thread
//! count. Shards therefore run embarrassingly parallel under `cost::par`
//! and still produce bit-identical event streams at any thread count;
//! `cluster::merge` interleaves the streams afterwards.
//!
//! Since the time-window refactor a shard is **resumable**: the sync
//! layer calls [`ShardSim::step`] once per epoch with that epoch's
//! arrival slice and the window end, and the shard carries its clock,
//! queues, in-flight batches and accounting across calls. A completion
//! falling on or past the window end stays in flight until the epoch
//! that contains it — that is the conservative synchronization contract
//! that lets epoch barriers exchange completion feedback and stolen work
//! deterministically.
//!
//! Inside a shard the loop mirrors `serve::Fleet::run`, extended with the
//! multi-tenant machinery:
//!
//! * one [`QueueSet`] per `(package, traffic class)` — strict priority
//!   across classes, EDF across models within a class, FIFO within a
//!   model;
//! * per-package admission control at routing time
//!   (`cluster::admission`): queue caps and deadline-aware shedding;
//! * optional preemption: an arriving higher-class request whose deadline
//!   cannot survive waiting for the in-flight lower-class batch aborts
//!   that batch (`Package::preempt_batch`) and sends its requests back to
//!   the front of their queue.

use super::admission::{batching_gain, ShedReason};
use super::calendar::CompletionCalendar;
use super::class::{TrafficClass, NUM_CLASSES};
use super::{ClusterConfig, SchedulerKind};
use crate::fault::ShardFaults;
use crate::nop::mac::token_wait_cycles;
use crate::power::DvfsLevel;
use crate::serve::{choose_batch, CostCache, ModelKind, Package, PackageSpec, QueueSet, Request, RoutePolicy};
use crate::telemetry::{
    PhaseBreakdown, PhaseTotals, PreemptSpan, QuantileSketch, Recorder, ShedSpan, SpanLog,
    SpanRecord,
};
use std::collections::{BTreeMap, HashMap, HashSet};

/// One ingress-classified request bound for a shard.
#[derive(Debug, Clone)]
pub(crate) struct ClassedRequest {
    pub req: Request,
    pub class: TrafficClass,
    /// Cycle at which the request becomes visible to this shard. For a
    /// fresh arrival this is `req.arrival`; for a request stolen at an
    /// epoch barrier it is the barrier cycle (the request cannot be
    /// served before the shard that held it handed it over).
    pub ready_at: f64,
    /// Stolen requests were admitted once already on their donor shard:
    /// they bypass admission control here (dropping already-admitted work
    /// would be worse — the same rule preemption requeues follow).
    pub stolen: bool,
}

impl ClassedRequest {
    /// A fresh (never-admitted) ingress arrival.
    pub(crate) fn fresh(req: Request, class: TrafficClass) -> Self {
        ClassedRequest { ready_at: req.arrival, stolen: false, req, class }
    }
}

/// What happened to a request inside the shard.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum ShardEventOutcome {
    Completed,
    Shed(ShedReason),
    /// The request's dispatch died under it (package death) and every
    /// retry was exhausted — or it was stranded on dead hardware past all
    /// repair windows. Terminal, observed by closed-loop clients exactly
    /// like a completion.
    Failed,
}

/// One emitted event, in shard-chronological order.
#[derive(Debug, Clone)]
pub(crate) struct ShardEvent {
    pub cycle: f64,
    pub outcome: ShardEventOutcome,
    pub class: TrafficClass,
    pub req: Request,
    /// Queue-phase cycles of a completion (0.0 for sheds/failures) —
    /// feeds the bounded-stats queue-wait histogram without a span log.
    pub queue_cycles: f64,
    /// Size of the batch a completion rode in (0 for sheds/failures).
    pub batch: u64,
}

/// Shard-local bounded-stats latency sketches (`--bounded-stats`),
/// recorded at completion time and handed to the merge at each epoch
/// barrier in shard-major order ([`ShardSim::take_sketches`]). Purely
/// shard-deterministic — the sketches depend only on this shard's event
/// stream, so absorbing them in fixed shard order at the barrier keeps
/// cluster quantiles bit-identical at any worker-thread count.
#[derive(Debug)]
pub(crate) struct ShardSketches {
    /// Completion latency (cycles), all classes and models.
    pub(crate) all: QuantileSketch,
    /// Same, keyed per model kind (entries created on first completion).
    pub(crate) per_model: BTreeMap<ModelKind, QuantileSketch>,
    /// Same, per traffic class (`class.index()` order).
    pub(crate) per_class: [QuantileSketch; NUM_CLASSES],
    /// Resolution for lazily created `per_model` entries.
    eps: f64,
}

impl ShardSketches {
    pub(crate) fn new(eps: f64) -> Self {
        ShardSketches {
            all: QuantileSketch::new(eps),
            per_model: BTreeMap::new(),
            per_class: std::array::from_fn(|_| QuantileSketch::new(eps)),
            eps,
        }
    }

    pub(crate) fn record(&mut self, kind: ModelKind, class: TrafficClass, latency: f64) {
        let eps = self.eps;
        self.all.record(latency);
        self.per_model.entry(kind).or_insert_with(|| QuantileSketch::new(eps)).record(latency);
        self.per_class[class.index()].record(latency);
    }

    pub(crate) fn is_empty(&self) -> bool {
        self.all.is_empty()
    }
}

/// Everything a finished shard hands back for the final accounting merge
/// (events travel separately, one batch per epoch via [`ShardSim::step`]).
#[derive(Debug)]
pub(crate) struct ShardOutcome {
    /// Dispatched-batch-size histogram.
    pub dispatch_hist: BTreeMap<u64, u64>,
    pub preemptions: u64,
    /// Final package state (utilization + energy accounting), shard-local
    /// order.
    pub packages: Vec<Package>,
    /// Dynamic energy attributed to each traffic class (a dispatched
    /// batch is single-class), preemption-rollback included.
    pub class_energy_mj: [f64; NUM_CLASSES],
    pub end_cycle: f64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    /// Always-on cycle attribution over this shard's completions.
    pub attr_run: PhaseTotals,
    /// Same, split per traffic class (`class.index()` order).
    pub attr_class: [PhaseTotals; NUM_CLASSES],
    /// Retries scheduled per class (chaos layer; all-zero without faults).
    pub class_retries: [u64; NUM_CLASSES],
    /// Requests re-routed off a dead package per class.
    pub class_reroutes: [u64; NUM_CLASSES],
    /// Completions that met their SLO while a package-death outage window
    /// was open anywhere in the plan — the numerator of the failover
    /// goodput stat.
    pub outage_slo_met: u64,
    /// Cumulative shared-medium token-wait cycles this shard's dispatches
    /// accrued (exactly 0.0 with contention disabled).
    pub token_wait_cycles: f64,
    /// The shard's span log (empty unless `cfg.telemetry.enabled`); the
    /// merge absorbs these in shard-id order and stamps the shard field.
    pub log: SpanLog,
}

pub(crate) struct ShardSim<'a> {
    cfg: &'a ClusterConfig,
    /// This shard's slice of the fleet power cap (`PowerConfig::shard_cap`).
    cap_w: Option<f64>,
    packages: Vec<Package>,
    /// Admission queues, indexed `[package][class]`.
    queues: Vec<Vec<QueueSet>>,
    /// Batch-1 backlog estimate per `[package][class]`, for load-aware
    /// routing and priority-aware completion estimates.
    backlog: Vec<[f64; NUM_CLASSES]>,
    /// Class of each package's in-flight batch.
    inflight_class: Vec<Option<TrafficClass>>,
    cache: CostCache,
    rr_cursor: usize,
    /// Shard-local clock: the cycle of the last processed event. Persists
    /// across [`ShardSim::step`] calls.
    now: f64,
    events: Vec<ShardEvent>,
    dispatch_hist: BTreeMap<u64, u64>,
    class_energy_mj: [f64; NUM_CLASSES],
    preemptions: u64,
    attr_run: PhaseTotals,
    attr_class: [PhaseTotals; NUM_CLASSES],
    /// Span recorder, armed by `cfg.telemetry.enabled`. Shard-local: the
    /// records it accumulates depend only on this shard's deterministic
    /// event stream, never on thread scheduling.
    recorder: Recorder,
    /// This shard's slice of the seeded fault plan (empty by default —
    /// every fault query short-circuits and the pre-fault arithmetic is
    /// untouched bit for bit).
    faults: ShardFaults,
    /// Requests whose dispatch died under them, waiting out a backoff:
    /// `(ready_cycle, seq, class, request)`. Fired in `(ready, seq)`
    /// order — deterministic regardless of insertion interleaving.
    retry_pending: Vec<(f64, u64, TrafficClass, Request)>,
    retry_seq: u64,
    /// Per-request retry attempt counts (lookup only — never iterated, so
    /// hash order cannot leak into the event stream).
    attempts: HashMap<u64, u32>,
    /// Requests this shard received via steal/failover. Donor-side
    /// hysteresis: `newest_queued` never offers them again, so a request
    /// cannot bounce between shards on alternating barriers.
    stolen_ids: HashSet<u64>,
    class_retries: [u64; NUM_CLASSES],
    class_reroutes: [u64; NUM_CLASSES],
    outage_slo_met: u64,
    token_wait: f64,
    /// Token-wait cycles accrued per package (shard-local order); sums
    /// to `token_wait`. Feeds the per-package epoch gauge tracks.
    token_wait_by_pkg: Vec<f64>,
    /// Bounded-stats latency sketches, armed by `cfg.telemetry.bounded`
    /// and drained by the barrier via [`ShardSim::take_sketches`].
    sketches: Option<Box<ShardSketches>>,
    /// Calendar-queue completion index (`SchedulerKind::Calendar`): one
    /// entry per in-flight batch, keyed by completion cycle. Entries
    /// orphaned by a preemption or fault abort are purged lazily at the
    /// next peek. `None` under the legacy scheduler.
    cal: Option<CompletionCalendar>,
    /// Dispatch dirty set (calendar scheduler): packages whose
    /// dispatchability may have changed since the last dispatch pass.
    /// The legacy loop rescans every package on every event instead.
    dirty: Vec<bool>,
    dirty_list: Vec<usize>,
}

impl<'a> ShardSim<'a> {
    pub(crate) fn new(specs: Vec<PackageSpec>, cfg: &'a ClusterConfig, cap_w: Option<f64>) -> Self {
        assert!(!specs.is_empty(), "a shard needs at least one package");
        let n = specs.len();
        ShardSim {
            cfg,
            cap_w,
            packages: specs.into_iter().map(Package::new).collect(),
            queues: (0..n).map(|_| (0..NUM_CLASSES).map(|_| QueueSet::new()).collect()).collect(),
            backlog: vec![[0.0; NUM_CLASSES]; n],
            inflight_class: vec![None; n],
            cache: CostCache::new(),
            rr_cursor: 0,
            now: 0.0,
            events: Vec::new(),
            dispatch_hist: BTreeMap::new(),
            class_energy_mj: [0.0; NUM_CLASSES],
            preemptions: 0,
            attr_run: PhaseTotals::default(),
            attr_class: [PhaseTotals::default(); NUM_CLASSES],
            recorder: Recorder::new(cfg.telemetry.spans),
            faults: ShardFaults::empty(n),
            retry_pending: Vec::new(),
            retry_seq: 0,
            attempts: HashMap::new(),
            stolen_ids: HashSet::new(),
            class_retries: [0; NUM_CLASSES],
            class_reroutes: [0; NUM_CLASSES],
            outage_slo_met: 0,
            token_wait: 0.0,
            token_wait_by_pkg: vec![0.0; n],
            sketches: if cfg.telemetry.bounded {
                Some(Box::new(ShardSketches::new(cfg.telemetry.quantile_error)))
            } else {
                None
            },
            cal: match cfg.scheduler {
                SchedulerKind::Calendar => Some(CompletionCalendar::new()),
                SchedulerKind::Legacy => None,
            },
            dirty: vec![false; n],
            dirty_list: Vec::new(),
        }
    }

    /// Flag package `i` for the calendar loop's next dispatch pass.
    fn mark_dirty(&mut self, i: usize) {
        if !self.dirty[i] {
            self.dirty[i] = true;
            self.dirty_list.push(i);
        }
    }

    /// Flag every package (step entry, fault edges — anything that can
    /// change dispatchability shard-wide).
    fn mark_all_dirty(&mut self) {
        for i in 0..self.packages.len() {
            self.mark_dirty(i);
        }
    }

    /// Hand the sketches accumulated since the last call to the barrier,
    /// leaving fresh (same-resolution) empties behind. `None` when the
    /// run is not bounded or nothing completed this epoch — skipping
    /// empty merges keeps the absorb from lazily creating spurious
    /// per-model/per-class stats entries.
    pub(crate) fn take_sketches(&mut self) -> Option<ShardSketches> {
        let sk = self.sketches.as_mut()?;
        if sk.is_empty() {
            return None;
        }
        let eps = sk.eps;
        Some(std::mem::replace(&mut **sk, ShardSketches::new(eps)))
    }

    /// Arm this shard's slice of a seeded fault plan (see
    /// [`crate::fault::FaultPlan::for_shard`]).
    pub(crate) fn with_faults(mut self, faults: ShardFaults) -> Self {
        self.faults = faults;
        self
    }

    /// Memoized batch-1 service estimate of `kind` on package `i`.
    fn est1(&mut self, i: usize, kind: ModelKind) -> f64 {
        self.cache
            .get(
                &self.packages[i].engine,
                self.packages[i].spec.dp,
                kind,
                1,
                self.packages[i].spec.local_buffer_bytes,
            )
            .latency
    }

    fn queued_total(&self, i: usize) -> usize {
        self.queues[i].iter().map(|q| q.depth_total()).sum()
    }

    /// Requests waiting in this shard's admission queues (all packages).
    pub(crate) fn queued_total_all(&self) -> usize {
        (0..self.packages.len()).map(|i| self.queued_total(i)).sum()
    }

    /// Whether the shard holds no queued, in-flight, or retry-pending
    /// work.
    pub(crate) fn is_drained(&self) -> bool {
        self.packages.iter().all(|p| p.is_idle())
            && self.queued_total_all() == 0
            && self.retry_pending.is_empty()
    }

    /// Earliest pending in-flight completion, if any batch is running.
    pub(crate) fn next_completion(&self) -> Option<f64> {
        self.packages
            .iter()
            .filter(|p| !p.is_idle())
            .map(|p| p.busy_until())
            .fold(None, |acc: Option<f64>, t| Some(acc.map_or(t, |a| a.min(t))))
    }

    /// All pending work on package `i`: busy remainder plus every class's
    /// batch-1 backlog estimate.
    fn load(&self, i: usize, now: f64) -> f64 {
        let busy_rem = (self.packages[i].busy_until() - now).max(0.0);
        busy_rem + self.backlog[i].iter().sum::<f64>()
    }

    /// Total pending work across the shard at `at` (the barrier's load
    /// metric for the steal pass: estimated cycles, not request counts,
    /// so a queue of heavy models outweighs a deeper queue of light ones).
    pub(crate) fn load_total(&self, at: f64) -> f64 {
        (0..self.packages.len()).map(|i| self.load(i, at)).sum()
    }

    /// The `(package, class, kind)` of the steal candidate: the
    /// newest-admitted queued request of the **lowest** queued class
    /// (class-aware stealing moves best-effort work first — migrating a
    /// deadline-critical interactive request is a last resort), skipping
    /// requests this shard itself received via a steal (donor-side
    /// hysteresis: once moved, a request never moves again, so it cannot
    /// bounce between shards on alternating barriers). Newest-first keeps
    /// FIFO order intact for everything that stays behind.
    fn newest_queued(&self) -> Option<(usize, usize, ModelKind)> {
        for ci in (0..NUM_CLASSES).rev() {
            let mut best: Option<(u64, usize, ModelKind)> = None;
            for i in 0..self.queues.len() {
                if let Some(r) = self.queues[i][ci].peek_newest() {
                    if !self.stolen_ids.contains(&r.id) && best.map_or(true, |(id, ..)| r.id > id) {
                        best = Some((r.id, i, r.kind));
                    }
                }
            }
            if let Some((_, i, k)) = best {
                return Some((i, ci, k));
            }
        }
        None
    }

    /// Batch-1 service estimate of the current steal candidate (`None`
    /// when nothing is queued). The barrier uses this to decide whether a
    /// move actually shrinks the donor/victim imbalance.
    pub(crate) fn steal_cost(&mut self) -> Option<f64> {
        let (i, _, kind) = self.newest_queued()?;
        Some(self.est1(i, kind))
    }

    /// Remove and return the newest-admitted queued request for transfer
    /// to another shard, rolling its share out of the backlog estimate.
    pub(crate) fn steal_newest(&mut self) -> Option<(Request, TrafficClass)> {
        let (i, ci, kind) = self.newest_queued()?;
        let req = self.queues[i][ci].pop_newest()?;
        let est = self.est1(i, kind);
        self.backlog[i][ci] = (self.backlog[i][ci] - est).max(0.0);
        Some((req, TrafficClass::ALL[ci]))
    }

    /// Estimated wait-plus-service for a `class` arrival of `kind` on
    /// package `i`: the busy remainder, the backlog of classes at the
    /// same or higher priority (lower classes will be bypassed), and its
    /// own batch-1 service time.
    ///
    /// With `ClusterConfig::calibrated_eta` the backlog term is scaled by
    /// the in-class batching gain the dispatcher will actually achieve at
    /// this queue depth (`admission::batching_gain`, always ≤ 1), fixing
    /// the ROADMAP's "too conservative under deep backlogs" shedding.
    fn eta_wait(&mut self, i: usize, class: TrafficClass, kind: ModelKind, now: f64) -> f64 {
        let service1 = self.est1(i, kind);
        let busy_rem = (self.packages[i].busy_until() - now).max(0.0);
        let mut ahead: f64 = self.backlog[i][..=class.index()].iter().sum();
        if self.cfg.calibrated_eta {
            let depth: usize =
                self.queues[i][..=class.index()].iter().map(|q| q.depth_total()).sum();
            ahead *= batching_gain(
                &mut self.cache,
                &self.packages[i].engine,
                self.packages[i].spec.dp,
                kind,
                depth as u64,
                &self.cfg.batcher,
                self.packages[i].spec.local_buffer_bytes,
            );
        }
        busy_rem + ahead + service1
    }

    /// Preemption-aware completion estimate — THE estimate both EDF
    /// routing and admission use, so they cannot disagree: when the
    /// in-flight batch is strictly lower class and preemption is on, the
    /// arrival would not wait for it, so its busy remainder leaves the
    /// estimate. (Deadline shedding must not refuse — nor routing steer
    /// away from — a request that preemption can still rescue.)
    fn completion_eta(&mut self, i: usize, class: TrafficClass, kind: ModelKind, now: f64) -> f64 {
        let mut wait = self.eta_wait(i, class, kind, now);
        let can_preempt = self.cfg.preemption
            && self.inflight_class[i].is_some_and(|v| v.priority() > class.priority());
        if can_preempt {
            wait -= (self.packages[i].busy_until() - now).max(0.0);
        }
        now + wait
    }

    /// Pick the target package for one arrival under the route policy.
    fn route(&mut self, now: f64, kind: ModelKind, class: TrafficClass) -> usize {
        match self.cfg.policy {
            RoutePolicy::RoundRobin => {
                let i = self.rr_cursor % self.packages.len();
                self.rr_cursor += 1;
                i
            }
            RoutePolicy::LeastLoaded => {
                let mut best = 0;
                for i in 1..self.packages.len() {
                    if self.load(i, now) < self.load(best, now) {
                        best = i;
                    }
                }
                best
            }
            RoutePolicy::EarliestDeadline => {
                let mut best = 0;
                let mut best_eta = f64::INFINITY;
                for i in 0..self.packages.len() {
                    let eta = self.completion_eta(i, class, kind, now);
                    if eta < best_eta {
                        best_eta = eta;
                        best = i;
                    }
                }
                best
            }
        }
    }

    /// Fault-aware routing wrapper: the policy's pick, unless that
    /// package is currently dead — then the least-loaded live package
    /// (deterministic scan, lowest index wins ties). With every package
    /// dead the policy's pick stands: the request queues on dead hardware
    /// and either a repair edge, the barrier failover pass, or terminal
    /// stranding handles it. With no fault plan this is exactly `route`.
    fn route_target(&mut self, now: f64, kind: ModelKind, class: TrafficClass) -> usize {
        let idx = self.route(now, kind, class);
        if self.faults.is_empty() || !self.faults.package_dead(idx, now) {
            return idx;
        }
        let mut best: Option<usize> = None;
        for i in 0..self.packages.len() {
            if self.faults.package_dead(i, now) {
                continue;
            }
            if best.map_or(true, |b| self.load(i, now) < self.load(b, now)) {
                best = Some(i);
            }
        }
        best.unwrap_or(idx)
    }

    /// Enqueue one request on package `idx` without admission control
    /// (already-admitted work: the `Ok` path of [`ShardSim::admit`], and
    /// stolen requests re-homed at an epoch barrier).
    fn enqueue(&mut self, idx: usize, req: Request, class: TrafficClass, now: f64) {
        self.mark_dirty(idx);
        let service1 = self.est1(idx, req.kind);
        let deadline = req.deadline;
        self.backlog[idx][class.index()] += service1;
        self.queues[idx][class.index()].push(req);
        self.maybe_preempt(idx, class, deadline, now);
    }

    /// Route one arrival, apply admission control, enqueue or shed, and
    /// run the preemption check.
    fn admit(&mut self, now: f64, req: Request, class: TrafficClass) {
        // Graceful degradation under sustained shared-medium contention:
        // shed arriving best-effort work before the token-wait stretch
        // inflates every class's tail.
        if self.cfg.contention.enabled && class == TrafficClass::BestEffort {
            let load = self.cfg.contention.effective_load(self.faults.spike_extra(now));
            if self.cfg.contention.sheds_best_effort(load) {
                if let Some(log) = self.recorder.log_mut() {
                    log.sheds.push(ShedSpan {
                        id: req.id,
                        kind: req.kind,
                        class: Some(class),
                        shard: 0,
                        arrival: req.arrival,
                        cycle: now,
                        reason: ShedReason::Overload,
                    });
                }
                self.events.push(ShardEvent {
                    cycle: now,
                    outcome: ShardEventOutcome::Shed(ShedReason::Overload),
                    class,
                    req,
                    queue_cycles: 0.0,
                    batch: 0,
                });
                return;
            }
        }
        let kind = req.kind;
        let idx = self.route_target(now, kind, class);
        let eta = self.completion_eta(idx, class, kind, now);
        let depth = self.queued_total(idx);
        let deadline_shed =
            self.cfg.classes.spec_for(class).map_or(false, |s| s.deadline_shed);
        match self.cfg.admission.admit(depth, eta, req.deadline, deadline_shed) {
            Err(ShedReason::QueueFull) if self.push_out_lowest(idx, class, now) => {
                // A strictly-lower-class queued request was displaced to
                // make room: priority isolation extends to admission, so
                // scavenger backlog can never crowd a full queue against
                // higher-class arrivals.
                self.enqueue(idx, req, class, now);
            }
            Err(reason) => {
                if let Some(log) = self.recorder.log_mut() {
                    log.sheds.push(ShedSpan {
                        id: req.id,
                        kind: req.kind,
                        class: Some(class),
                        shard: 0,
                        arrival: req.arrival,
                        cycle: now,
                        reason,
                    });
                }
                self.events.push(ShardEvent {
                    cycle: now,
                    outcome: ShardEventOutcome::Shed(reason),
                    class,
                    req,
                    queue_cycles: 0.0,
                    batch: 0,
                });
            }
            Ok(()) => {
                self.enqueue(idx, req, class, now);
            }
        }
    }

    /// Re-home a request stolen from another shard at an epoch barrier:
    /// route and enqueue, skipping admission control — the donor admitted
    /// it once already, and shedding admitted work on transfer would make
    /// stealing lossy (the conservation property test forbids that). The
    /// queue cap may transiently overshoot, exactly like a preemption
    /// requeue.
    fn inject(&mut self, now: f64, req: Request, class: TrafficClass) {
        self.stolen_ids.insert(req.id);
        let idx = self.route_target(now, req.kind, class);
        self.enqueue(idx, req, class, now);
    }

    /// Push-out on a full queue: shed the *newest* queued request of the
    /// lowest class strictly below `class` on package `idx`, freeing its
    /// slot. Returns whether a victim was found (same-or-higher-class
    /// occupants are never displaced — FIFO fairness within a priority
    /// level stays intact).
    fn push_out_lowest(&mut self, idx: usize, class: TrafficClass, now: f64) -> bool {
        for victim_class in TrafficClass::ALL.iter().rev() {
            if victim_class.priority() <= class.priority() {
                return false;
            }
            let ci = victim_class.index();
            if let Some(victim) = self.queues[idx][ci].pop_newest() {
                let v1 = self.est1(idx, victim.kind);
                self.backlog[idx][ci] = (self.backlog[idx][ci] - v1).max(0.0);
                if let Some(log) = self.recorder.log_mut() {
                    log.sheds.push(ShedSpan {
                        id: victim.id,
                        kind: victim.kind,
                        class: Some(*victim_class),
                        shard: 0,
                        arrival: victim.arrival,
                        cycle: now,
                        reason: ShedReason::QueueFull,
                    });
                }
                self.events.push(ShardEvent {
                    cycle: now,
                    outcome: ShardEventOutcome::Shed(ShedReason::QueueFull),
                    class: *victim_class,
                    req: victim,
                    queue_cycles: 0.0,
                    batch: 0,
                });
                return true;
            }
        }
        false
    }

    /// Abort the in-flight batch on `idx` when a just-queued higher-class
    /// request cannot survive waiting for it. The preempted requests go
    /// back to the *front* of their class queue (they keep their original
    /// deadlines); the cycles the aborted batch already burnt stay
    /// counted as busy — preemption has a real cost.
    fn maybe_preempt(&mut self, idx: usize, class: TrafficClass, deadline: f64, now: f64) {
        if !self.cfg.preemption || !deadline.is_finite() {
            return;
        }
        let Some(victim) = self.inflight_class[idx] else {
            return;
        };
        if victim.priority() <= class.priority() {
            return; // only ever preempt strictly lower-priority work
        }
        if now >= self.packages[idx].busy_until() {
            // The batch completes at this very cycle (arrival/completion
            // tie): preempting would discard fully-finished work and
            // re-serve it. Let the completion fire.
            return;
        }
        // Completion estimate if the batch is NOT preempted: batch end,
        // then everything queued at the same or higher priority — the
        // request itself included (its service1 is already in the
        // backlog). Must mirror the admission ETA, which admitted this
        // request assuming a preemption would rescue it; a looser check
        // here would admit-then-neither-preempt-nor-meet.
        let pending: f64 = self.backlog[idx][..=class.index()].iter().sum();
        if self.packages[idx].busy_until() + pending <= deadline {
            return; // waiting still meets the deadline: don't waste work
        }
        if now + pending > deadline {
            // Hopeless even with an immediate preemption (possible for
            // classes admission does not deadline-shed): aborting the
            // victim batch would burn its work for nothing.
            return;
        }
        let (reqs, rolled_mj) = self.packages[idx].preempt_batch(now);
        self.class_energy_mj[victim.index()] -= rolled_mj;
        if let Some(log) = self.recorder.log_mut() {
            log.preemptions.push(PreemptSpan {
                cycle: now,
                shard: 0,
                package: idx,
                batch: reqs.len(),
            });
        }
        let vkind = reqs[0].kind;
        let v1 = self.est1(idx, vkind);
        self.backlog[idx][victim.index()] += v1 * reqs.len() as f64;
        self.queues[idx][victim.index()].requeue_front(reqs);
        self.inflight_class[idx] = None;
        self.preemptions += 1;
        // The aborted batch's calendar entry is now stale; the next peek
        // purges it. The freed package is immediately dispatchable.
        self.mark_dirty(idx);
    }

    /// The governor's DVFS decision for this shard's cap slice (see
    /// `Fleet::governor_level` — same projection, shard-local scope).
    fn governor_level(&self, cost: &crate::serve::BatchCost) -> DvfsLevel {
        let Some(cap) = self.cap_w else {
            return DvfsLevel::NOMINAL;
        };
        let model = &self.cfg.power.model;
        let floor: f64 = self.packages.iter().map(|p| model.active_leakage_w(&p.spec.sys)).sum();
        let inflight: f64 = self.packages.iter().map(|p| p.meter.inflight_w()).sum();
        self.cfg.power.choose_level(cap, floor, inflight, cost)
    }

    /// Dispatch one batch on idle package `i`: strict class priority,
    /// then EDF across that class's model queues.
    fn try_dispatch(&mut self, i: usize, now: f64) {
        debug_assert!(self.packages[i].is_idle());
        if !self.faults.is_empty() && (self.faults.stalled(now) || self.faults.package_dead(i, now)) {
            // Dead packages serve nothing; a stalled shard's dispatcher is
            // wedged (queues still accept arrivals). The next fault edge
            // re-triggers dispatch.
            return;
        }
        for class in TrafficClass::ALL {
            let ci = class.index();
            if self.queues[i][ci].is_empty() {
                continue;
            }
            let kind = self.queues[i][ci].edf_kind().expect("non-empty queue has an EDF head");
            let depth = self.queues[i][ci].depth(kind) as u64;
            let head_deadline =
                self.queues[i][ci].head_deadline(kind).expect("EDF head has a deadline");
            let mut decision = choose_batch(
                &self.cfg.batcher,
                &mut self.cache,
                &self.packages[i].engine,
                self.packages[i].spec.dp,
                kind,
                depth,
                now,
                head_deadline,
                self.packages[i].spec.local_buffer_bytes,
            );
            if !self.faults.is_empty() {
                // A degraded package runs the same work at a slower clock:
                // latency and plane busy cycles stretch by 1/factor,
                // dynamic energy (work, not time) is unchanged.
                let factor = self.faults.degrade_factor(i, now);
                if factor < 1.0 {
                    let s = 1.0 / factor;
                    decision.cost.latency *= s;
                    decision.cost.dist_busy *= s;
                    decision.cost.compute_busy *= s;
                    decision.cost.collect_busy *= s;
                }
            }
            if self.cfg.contention.enabled {
                // Shared-medium contention: the distribution phase waits
                // for the MAC token before it streams. The wait stretches
                // both the batch latency and its dist busy cycles, so the
                // meter and the five-phase attribution book it under
                // `dist` automatically. Waiting burns no TX energy.
                let load = self.cfg.contention.effective_load(self.faults.spike_extra(now));
                let wait = token_wait_cycles(decision.cost.dist_busy, decision.cost.latency, load);
                if wait > 0.0 {
                    decision.cost.latency += wait;
                    decision.cost.dist_busy += wait;
                    self.token_wait += wait;
                    self.token_wait_by_pkg[i] += wait;
                }
            }
            let est1 = self.est1(i, kind);
            let level = self.governor_level(&decision.cost);
            let energy =
                self.cfg.power.model.batch_dynamic(&decision.cost).scaled(level.energy_scale);
            let reqs = self.queues[i][ci].pop_batch(kind, decision.batch as usize);
            debug_assert_eq!(reqs.len(), decision.batch as usize);
            self.backlog[i][ci] = (self.backlog[i][ci] - est1 * reqs.len() as f64).max(0.0);
            self.class_energy_mj[ci] += energy.total_mj();
            self.packages[i].begin_batch(now, &decision, reqs, level, energy);
            if let Some(cal) = &mut self.cal {
                cal.insert(self.packages[i].busy_until(), i);
            }
            self.inflight_class[i] = Some(class);
            *self.dispatch_hist.entry(decision.batch).or_insert(0) += 1;
            return;
        }
    }

    /// Complete the in-flight batch on `i`, emitting completion events
    /// and folding each request's cycle attribution into the shard sums.
    fn complete(&mut self, i: usize) {
        self.mark_dirty(i);
        let class = self.inflight_class[i].take().expect("completing package has a batch class");
        // The dispatch cycle and predicted cost vanish with finish_batch —
        // capture them first.
        let span = self.packages[i].inflight_span();
        let (t, reqs) = self.packages[i].finish_batch();
        let batch = reqs.len();
        for req in reqs {
            if !self.faults.is_empty() && self.faults.in_outage(t) && t <= req.deadline {
                self.outage_slo_met += 1;
            }
            let mut queue_cycles = 0.0;
            if let Some((dispatched, cost)) = span {
                let phases = PhaseBreakdown::attribute(req.arrival, dispatched, t, &cost);
                queue_cycles = phases.queue;
                self.attr_run.record(&phases);
                self.attr_class[class.index()].record(&phases);
                self.packages[i].attr.record(&phases);
                if let Some(log) = self.recorder.log_mut() {
                    log.spans.push(SpanRecord {
                        id: req.id,
                        kind: req.kind,
                        class: Some(class),
                        shard: 0,
                        package: i,
                        batch,
                        arrival: req.arrival,
                        dispatched,
                        completed: t,
                        phases,
                    });
                }
            }
            if let Some(sk) = &mut self.sketches {
                sk.record(req.kind, class, t - req.arrival);
            }
            self.events.push(ShardEvent {
                cycle: t,
                outcome: ShardEventOutcome::Completed,
                class,
                req,
                queue_cycles,
                batch: batch as u64,
            });
        }
    }

    /// Record one retry attempt for `req` at cycle `t`: schedule it into
    /// `retry_pending` behind a capped exponential backoff, or — past the
    /// attempt cap — fail it terminally.
    fn schedule_retry(&mut self, t: f64, req: Request, class: TrafficClass) {
        let attempts = self.attempts.entry(req.id).or_insert(0);
        *attempts += 1;
        let attempt = *attempts;
        if attempt > self.cfg.retry.max_retries {
            self.fail(t, req, class);
            return;
        }
        self.class_retries[class.index()] += 1;
        let ready = t + self.cfg.retry.backoff_cycles_jittered(req.id, attempt);
        self.retry_seq += 1;
        self.retry_pending.push((ready, self.retry_seq, class, req));
    }

    /// Emit a terminal failure event (retries exhausted or stranded).
    fn fail(&mut self, t: f64, req: Request, class: TrafficClass) {
        self.events.push(ShardEvent {
            cycle: t,
            outcome: ShardEventOutcome::Failed,
            class,
            req,
            queue_cycles: 0.0,
            batch: 0,
        });
    }

    /// Earliest pending retry-ready cycle, if any.
    fn next_retry_at(&self) -> Option<f64> {
        self.retry_pending
            .iter()
            .map(|&(ready, ..)| ready)
            .fold(None, |acc: Option<f64>, t| Some(acc.map_or(t, |a| a.min(t))))
    }

    /// Fire the earliest pending retry (ties by scheduling sequence): if
    /// any package is live it is re-routed and enqueued (admission is
    /// skipped — the request was admitted once already); with every
    /// package dead it backs off again, eventually failing at the cap.
    fn fire_retry(&mut self) {
        debug_assert!(!self.retry_pending.is_empty());
        let mut best = 0;
        for j in 1..self.retry_pending.len() {
            let (tj, sj) = (self.retry_pending[j].0, self.retry_pending[j].1);
            let (tb, sb) = (self.retry_pending[best].0, self.retry_pending[best].1);
            if tj < tb || (tj == tb && sj < sb) {
                best = j;
            }
        }
        let (_, _, class, req) = self.retry_pending.swap_remove(best);
        let t = self.now;
        let any_live = (0..self.packages.len()).any(|p| !self.faults.package_dead(p, t));
        if !any_live {
            self.schedule_retry(t, req, class);
            return;
        }
        let idx = self.route_target(t, req.kind, class);
        self.enqueue(idx, req, class, t);
    }

    /// Apply every fault state flip at cycle `t`: abort in-flight batches
    /// on packages that are now dead (their requests enter the retry
    /// path), and re-route work queued on dead packages to survivors
    /// (counted per class). Repair edges need no action — the dispatch
    /// loop picks the package back up on the next iteration.
    fn apply_fault_edges(&mut self, t: f64) {
        for i in 0..self.packages.len() {
            if !self.faults.package_dead(i, t) {
                continue;
            }
            if !self.packages[i].is_idle() {
                let class =
                    self.inflight_class[i].take().expect("in-flight batch has a class");
                let (reqs, rolled_mj) = self.packages[i].preempt_batch(t);
                self.class_energy_mj[class.index()] -= rolled_mj;
                for req in reqs {
                    self.schedule_retry(t, req, class);
                }
            }
            if self.queued_total(i) > 0 {
                let live_exists =
                    (0..self.packages.len()).any(|p| p != i && !self.faults.package_dead(p, t));
                if live_exists {
                    for ci in 0..NUM_CLASSES {
                        let moved = self.drain_package_class(i, ci);
                        self.class_reroutes[ci] += moved.len() as u64;
                        for req in moved {
                            let idx = self.route_target(t, req.kind, TrafficClass::ALL[ci]);
                            self.enqueue(idx, req, TrafficClass::ALL[ci], t);
                        }
                    }
                }
                // With no survivor the work stays parked: a repair edge,
                // the barrier failover pass, or terminal stranding will
                // move it.
            }
        }
    }

    /// Pop every request queued under `(package, class)` in deterministic
    /// EDF-head order, zeroing that backlog slot.
    fn drain_package_class(&mut self, i: usize, ci: usize) -> Vec<Request> {
        let mut out = Vec::new();
        while let Some(kind) = self.queues[i][ci].edf_kind() {
            let depth = self.queues[i][ci].depth(kind) as usize;
            out.extend(self.queues[i][ci].pop_batch(kind, depth));
        }
        self.backlog[i][ci] = 0.0;
        out
    }

    /// Take every queued request off this shard (the barrier failover
    /// pass for a fully dead shard): FIFO per model queue, package-major
    /// then class-major order, backlogs zeroed. Deliberately bypasses the
    /// steal-candidate hysteresis — a dead shard serves nothing, so
    /// everything must move.
    pub(crate) fn drain_all_queued(&mut self) -> Vec<(Request, TrafficClass)> {
        let mut out = Vec::new();
        for i in 0..self.packages.len() {
            for ci in 0..NUM_CLASSES {
                for req in self.drain_package_class(i, ci) {
                    out.push((req, TrafficClass::ALL[ci]));
                }
            }
        }
        out
    }

    /// Whether every package of this shard is dead at `t` (the barrier's
    /// failover trigger).
    pub(crate) fn fully_dead_at(&self, t: f64) -> bool {
        self.faults.fully_dead(t)
    }

    /// Earliest future cycle at which this shard can act without a new
    /// arrival or an in-flight completion: the next pending retry, or —
    /// when queued work sits wedged behind a fault window — the next
    /// fault edge (package repair, stall end). `None` when nothing
    /// shard-internal is scheduled; the epoch loop's drain check and
    /// window-skip jump both consult this so fault runs neither stop
    /// early nor leap over a wakeup.
    pub(crate) fn next_wakeup(&self) -> Option<f64> {
        let mut t = self.next_retry_at();
        if !self.faults.is_empty() && self.queued_total_all() > 0 {
            t = match (t, self.faults.next_edge_after(self.now)) {
                (Some(a), Some(b)) => Some(a.min(b)),
                (a, None) => a,
                (None, b) => b,
            };
        }
        t
    }

    /// Work invisible to the arrival/completion scans that will still
    /// fire later (see [`Self::next_wakeup`]).
    pub(crate) fn has_future_work(&self) -> bool {
        self.next_wakeup().is_some()
    }

    /// Batch-1 service estimate of `kind` on this shard's first package —
    /// the barrier's load-update unit when failover hands a dead shard's
    /// request to this (victim) shard.
    pub(crate) fn estimate_service1(&mut self, kind: ModelKind) -> f64 {
        self.est1(0, kind)
    }

    /// Terminal cleanup after the epoch loop: work still queued here can
    /// never run (its hardware is dead or stalled past every repair
    /// edge). Emit a `Failed` event for each so the run drains and the
    /// conservation property (`arrived == completed + shed + failed`)
    /// holds. Returns the emitted events for one final fold.
    pub(crate) fn fail_stranded(&mut self) -> Vec<ShardEvent> {
        let t = self.now;
        for (req, class) in self.drain_all_queued() {
            self.fail(t, req, class);
        }
        let mut pending = std::mem::take(&mut self.retry_pending);
        pending.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        for (_, _, class, req) in pending {
            self.fail(t, req, class);
        }
        std::mem::take(&mut self.events)
    }

    /// Run one epoch: admit `arrivals` (ascending `ready_at`, all below
    /// `end`) in slice order interleaved with completions, retry firings
    /// and fault edges, processing every event with cycle strictly below
    /// `end`; a completion landing on or past `end` stays in flight for a
    /// later epoch. Returns the events emitted this epoch, chronological
    /// within the shard. The shard's clock, queues and accounting persist
    /// across calls; an `end` of `f64::INFINITY` drains the shard
    /// completely (fault edges and backoffs included).
    pub(crate) fn step(&mut self, arrivals: &[ClassedRequest], end: f64) -> Vec<ShardEvent> {
        match self.cfg.scheduler {
            SchedulerKind::Legacy => self.step_legacy(arrivals, end),
            SchedulerKind::Calendar => self.step_calendar(arrivals.to_vec(), end),
        }
    }

    /// [`ShardSim::step`] over an owned arrival slice — the sync layer's
    /// hot path. The calendar scheduler consumes the requests in place
    /// (no per-arrival clone on the dispatch path); the legacy oracle
    /// still clones, exactly as it always did.
    pub(crate) fn step_owned(&mut self, arrivals: Vec<ClassedRequest>, end: f64) -> Vec<ShardEvent> {
        match self.cfg.scheduler {
            SchedulerKind::Legacy => self.step_legacy(&arrivals, end),
            SchedulerKind::Calendar => self.step_calendar(arrivals, end),
        }
    }

    /// The pre-calendar event loop, kept verbatim as the equivalence
    /// oracle (`--scheduler legacy`): O(packages) next-completion scan
    /// and a full dispatch rescan on every event. Every scheduling
    /// decision here must stay bit-identical to
    /// [`ShardSim::step_calendar`] — the fuzz harness diffs the two.
    fn step_legacy(&mut self, arrivals: &[ClassedRequest], end: f64) -> Vec<ShardEvent> {
        let mut cursor = 0usize;
        loop {
            for i in 0..self.packages.len() {
                if self.packages[i].is_idle() && self.queued_total(i) > 0 {
                    self.try_dispatch(i, self.now);
                }
            }

            let next_arrival = arrivals.get(cursor).map(|a| a.ready_at);
            let mut next_completion = f64::INFINITY;
            let mut completing = usize::MAX;
            for (i, p) in self.packages.iter().enumerate() {
                if !p.is_idle() && p.busy_until() < next_completion {
                    next_completion = p.busy_until();
                    completing = i;
                }
            }
            // Fault edges and retry firings compete with arrivals and
            // completions for the next event. Tie order at an equal
            // cycle: fault edge first (the state must flip before
            // anything else books work at that cycle), then retry, then
            // arrival, then completion — preserving the pre-fault
            // arrival-before-completion tie rule. Without a fault plan
            // both candidates are infinite and the selection below is
            // arithmetically identical to the pre-fault loop.
            let t_edge = if self.faults.is_empty() {
                f64::INFINITY
            } else {
                self.faults.next_edge_after(self.now).filter(|&t| t < end).unwrap_or(f64::INFINITY)
            };
            let t_retry =
                self.next_retry_at().filter(|&t| t < end).unwrap_or(f64::INFINITY);
            let t_arrival = next_arrival.unwrap_or(f64::INFINITY);

            if t_edge.is_finite()
                && t_edge <= t_retry
                && t_edge <= t_arrival
                && t_edge <= next_completion
            {
                self.now = self.now.max(t_edge);
                self.apply_fault_edges(self.now);
            } else if t_retry.is_finite() && t_retry <= t_arrival && t_retry <= next_completion {
                self.now = self.now.max(t_retry);
                self.fire_retry();
            } else if t_arrival.is_finite() && t_arrival <= next_completion {
                // A `ready_at` in the shard's past (cross-shard feedback
                // or a stolen hand-off that landed inside an already-
                // simulated window) is admitted at the local clock — the
                // conservative-sync approximation, with error bounded by
                // one epoch.
                self.now = self.now.max(t_arrival);
                let a = arrivals[cursor].clone();
                cursor += 1;
                if a.stolen {
                    self.inject(self.now, a.req, a.class);
                } else {
                    self.admit(self.now, a.req, a.class);
                }
            } else if completing != usize::MAX && next_completion < end {
                self.now = self.now.max(next_completion);
                self.complete(completing);
            } else {
                break;
            }
        }
        debug_assert_eq!(cursor, arrivals.len(), "every epoch arrival is below the window end");
        std::mem::take(&mut self.events)
    }

    /// The calendar-queue event loop: decision-for-decision identical to
    /// [`ShardSim::step_legacy`], with the two O(packages)-per-event
    /// scans replaced —
    ///
    /// * the next completion comes from the [`CompletionCalendar`]
    ///   (bucketed by cycle, `(cycle, package)` tie order — the same
    ///   lowest-index rule the legacy strict-`<` scan used);
    /// * the dispatch pass only revisits *dirty* packages (marked on
    ///   enqueue, completion, preemption, and shard-wide on fault edges
    ///   and step entry). Skipped packages cannot have become
    ///   dispatchable: a declined `try_dispatch` has no side effects, and
    ///   dispatching one package never changes another's queues.
    ///
    /// Arrivals are consumed from the owned vector — no per-request
    /// clone. Equal-cycle tie order (edge, retry, arrival, completion)
    /// is reproduced by the exact same `<=` chains.
    fn step_calendar(&mut self, arrivals: Vec<ClassedRequest>, end: f64) -> Vec<ShardEvent> {
        // Barrier mutations (stolen work drained, caps rebalanced) and
        // the window edge itself can all change dispatchability.
        self.mark_all_dirty();
        let mut arrivals = arrivals.into_iter().peekable();
        loop {
            if !self.dirty_list.is_empty() {
                // Ascending package order — the order the legacy full
                // scan visits (token-wait accumulation order included).
                self.dirty_list.sort_unstable();
                let list = std::mem::take(&mut self.dirty_list);
                for i in list {
                    self.dirty[i] = false;
                    if self.packages[i].is_idle() && self.queued_total(i) > 0 {
                        self.try_dispatch(i, self.now);
                    }
                }
            }

            let next_arrival = arrivals.peek().map(|a| a.ready_at);
            let (next_completion, completing) = {
                let pkgs = &self.packages;
                let cal = self.cal.as_mut().expect("calendar scheduler armed");
                match cal.peek_min(|pkg, bits| {
                    !pkgs[pkg].is_idle() && pkgs[pkg].busy_until().to_bits() == bits
                }) {
                    Some((bits, pkg)) => (f64::from_bits(bits), pkg),
                    None => (f64::INFINITY, usize::MAX),
                }
            };
            let t_edge = if self.faults.is_empty() {
                f64::INFINITY
            } else {
                self.faults.next_edge_after(self.now).filter(|&t| t < end).unwrap_or(f64::INFINITY)
            };
            let t_retry =
                self.next_retry_at().filter(|&t| t < end).unwrap_or(f64::INFINITY);
            let t_arrival = next_arrival.unwrap_or(f64::INFINITY);

            if t_edge.is_finite()
                && t_edge <= t_retry
                && t_edge <= t_arrival
                && t_edge <= next_completion
            {
                self.now = self.now.max(t_edge);
                self.apply_fault_edges(self.now);
                // A fault edge can flip liveness / stall state shard-wide.
                self.mark_all_dirty();
            } else if t_retry.is_finite() && t_retry <= t_arrival && t_retry <= next_completion {
                self.now = self.now.max(t_retry);
                self.fire_retry();
            } else if t_arrival.is_finite() && t_arrival <= next_completion {
                self.now = self.now.max(t_arrival);
                let a = arrivals.next().expect("peeked arrival exists");
                if a.stolen {
                    self.inject(self.now, a.req, a.class);
                } else {
                    self.admit(self.now, a.req, a.class);
                }
            } else if completing != usize::MAX && next_completion < end {
                self.now = self.now.max(next_completion);
                self.cal
                    .as_mut()
                    .expect("calendar scheduler armed")
                    .remove(next_completion.to_bits(), completing);
                self.complete(completing);
            } else {
                break;
            }
        }
        debug_assert!(arrivals.next().is_none(), "every epoch arrival is below the window end");
        std::mem::take(&mut self.events)
    }

    /// Replace this shard's power-cap slice — the sync barrier's
    /// stranded-cap rebalance: when a fault plan kills every package on
    /// some shard, the survivors' slices are re-derived from *live*
    /// package counts so the fleet cap is never partially stranded.
    pub(crate) fn set_cap_w(&mut self, cap: Option<f64>) {
        self.cap_w = cap;
    }

    /// This shard's current power-cap slice (tests).
    #[cfg(test)]
    pub(crate) fn cap_w(&self) -> Option<f64> {
        self.cap_w
    }

    /// Packages of this shard not dead at `t` (all of them without a
    /// fault plan) — the numerator/denominator unit of the barrier cap
    /// rebalance.
    pub(crate) fn live_packages(&self, t: f64) -> usize {
        if self.faults.is_empty() {
            return self.packages.len();
        }
        (0..self.packages.len()).filter(|&i| !self.faults.package_dead(i, t)).count()
    }

    /// Shard-local clock (cycle of the last processed event). Barrier
    /// sampling reads this for the open-loop fast path's single sample.
    pub(crate) fn now(&self) -> f64 {
        self.now
    }

    /// Batches currently in flight across this shard's packages.
    pub(crate) fn inflight_batches(&self) -> u64 {
        self.packages.iter().filter(|p| !p.is_idle()).count() as u64
    }

    /// Dynamic power draw of the in-flight batches (watts).
    pub(crate) fn inflight_power_w(&self) -> f64 {
        self.packages.iter().map(|p| p.meter.inflight_w()).sum()
    }

    /// Cumulative shared-medium token-wait cycles accrued so far (epoch
    /// gauge; exactly 0.0 with contention disabled).
    pub(crate) fn token_wait_cycles(&self) -> f64 {
        self.token_wait
    }

    /// Total distribution-plane busy cycles across this shard's packages
    /// so far (numerator of the epoch MAC-occupancy gauge).
    pub(crate) fn dist_busy_cycles(&self) -> f64 {
        self.packages.iter().map(|p| p.dist_busy_cycles).sum()
    }

    /// Distribution-plane busy cycles per package, shard-local order
    /// (per-package MAC-occupancy gauge numerators).
    pub(crate) fn dist_busy_by_pkg(&self) -> impl Iterator<Item = f64> + '_ {
        self.packages.iter().map(|p| p.dist_busy_cycles)
    }

    /// Token-wait cycles per package, shard-local order.
    pub(crate) fn token_wait_by_pkg(&self) -> &[f64] {
        &self.token_wait_by_pkg
    }

    /// Packages on this shard (MAC-occupancy gauge denominator).
    pub(crate) fn package_count(&self) -> usize {
        self.packages.len()
    }

    /// Tear the shard down into its final accounting (after the last
    /// epoch has drained it).
    pub(crate) fn finish(mut self) -> ShardOutcome {
        debug_assert!(self.is_drained(), "finish() called on an undrained shard");
        ShardOutcome {
            dispatch_hist: self.dispatch_hist,
            preemptions: self.preemptions,
            packages: self.packages,
            class_energy_mj: self.class_energy_mj,
            end_cycle: self.now,
            cache_hits: self.cache.hits,
            cache_misses: self.cache.misses,
            attr_run: self.attr_run,
            attr_class: self.attr_class,
            class_retries: self.class_retries,
            class_reroutes: self.class_reroutes,
            outage_slo_met: self.outage_slo_met,
            token_wait_cycles: self.token_wait,
            log: self.recorder.take_log(),
        }
    }
}

/// Run one shard start-to-drain over a classified arrival slice (the
/// single-epoch convenience the unit tests use; the sync layer drives
/// [`ShardSim::step`] epoch by epoch instead). `cap_w` is this shard's
/// (already partitioned) slice of the fleet power cap.
#[cfg(test)]
pub(crate) fn run_shard(
    specs: Vec<PackageSpec>,
    arrivals: &[ClassedRequest],
    cfg: &ClusterConfig,
    cap_w: Option<f64>,
) -> (Vec<ShardEvent>, ShardOutcome) {
    let mut sim = ShardSim::new(specs, cfg, cap_w);
    let events = sim.step(arrivals, f64::INFINITY);
    (events, sim.finish())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DesignPoint;
    use crate::serve::{ms_to_cycles, ModelKind};

    fn arrival(id: u64, at_ms: f64, slo_ms: f64, class: TrafficClass) -> ClassedRequest {
        let arrival = ms_to_cycles(at_ms);
        ClassedRequest::fresh(
            Request {
                id,
                kind: ModelKind::TinyCnn,
                arrival,
                deadline: arrival + ms_to_cycles(slo_ms),
                client: None,
            },
            class,
        )
    }

    fn outcome_of(cfg: &ClusterConfig, arrivals: &[ClassedRequest]) -> (Vec<ShardEvent>, ShardOutcome) {
        run_shard(vec![PackageSpec::new("p0", DesignPoint::WIENNA_C)], arrivals, cfg, None)
    }

    #[test]
    fn drains_everything_and_balances() {
        let cfg = ClusterConfig { admission: super::super::AdmissionConfig::admit_all(), ..Default::default() };
        let arrivals: Vec<ClassedRequest> = (0..40)
            .map(|i| arrival(i, 0.01 * i as f64, 50.0, TrafficClass::ALL[(i % 3) as usize]))
            .collect();
        let (events, out) = outcome_of(&cfg, &arrivals);
        let completed =
            events.iter().filter(|e| e.outcome == ShardEventOutcome::Completed).count();
        assert_eq!(completed, 40, "everything admitted completes");
        assert!(out.end_cycle > 0.0);
        // Events are chronological — the merge relies on this.
        assert!(events.windows(2).all(|w| w[0].cycle <= w[1].cycle));
    }

    #[test]
    fn stepping_in_windows_matches_one_unbounded_epoch() {
        // The resumability contract: slicing the same arrival stream into
        // fixed windows must reproduce the single-epoch run event for
        // event — this is what makes the open-loop fast path (one
        // unbounded epoch) byte-identical to a windowed run.
        let cfg = ClusterConfig { admission: super::super::AdmissionConfig::admit_all(), ..Default::default() };
        let arrivals: Vec<ClassedRequest> = (0..60)
            .map(|i| arrival(i, 0.013 * i as f64, 50.0, TrafficClass::ALL[(i % 3) as usize]))
            .collect();
        let (whole, out_whole) = outcome_of(&cfg, &arrivals);

        let window = ms_to_cycles(0.1);
        let mut sim = ShardSim::new(vec![PackageSpec::new("p0", DesignPoint::WIENNA_C)], &cfg, None);
        let mut stepped: Vec<ShardEvent> = Vec::new();
        let mut cursor = 0usize;
        let mut start = 0.0f64;
        while !sim.is_drained() || cursor < arrivals.len() {
            let end = start + window;
            let mut slice = Vec::new();
            while cursor < arrivals.len() && arrivals[cursor].ready_at < end {
                slice.push(arrivals[cursor].clone());
                cursor += 1;
            }
            stepped.extend(sim.step(&slice, end));
            start = end;
        }
        stepped.extend(sim.step(&[], f64::INFINITY));
        let out_stepped = sim.finish();

        assert_eq!(whole.len(), stepped.len());
        for (a, b) in whole.iter().zip(stepped.iter()) {
            assert_eq!(a.req.id, b.req.id);
            assert_eq!(a.cycle.to_bits(), b.cycle.to_bits(), "event time drifted for id {}", a.req.id);
            assert_eq!(a.outcome, b.outcome);
        }
        assert_eq!(out_whole.end_cycle.to_bits(), out_stepped.end_cycle.to_bits());
        assert_eq!(out_whole.dispatch_hist, out_stepped.dispatch_hist);
    }

    #[test]
    fn zero_cap_sheds_every_arrival() {
        let cfg = ClusterConfig {
            admission: super::super::AdmissionConfig { queue_cap: Some(0), shed_late: false },
            ..Default::default()
        };
        let arrivals: Vec<ClassedRequest> =
            (0..10).map(|i| arrival(i, 0.01 * i as f64, 50.0, TrafficClass::Interactive)).collect();
        let (events, out) = outcome_of(&cfg, &arrivals);
        assert!(events
            .iter()
            .all(|e| e.outcome == ShardEventOutcome::Shed(ShedReason::QueueFull)));
        assert_eq!(events.len(), 10);
        assert_eq!(out.dispatch_hist.len(), 0, "nothing admitted, nothing dispatched");
    }

    #[test]
    fn stolen_requests_bypass_admission_and_keep_their_deadline() {
        // A zero-cap queue sheds every fresh arrival, but a stolen
        // hand-off was admitted on its donor already: it must be served,
        // not shed, and its original deadline must ride along.
        let cfg = ClusterConfig {
            admission: super::super::AdmissionConfig { queue_cap: Some(0), shed_late: false },
            ..Default::default()
        };
        let mut stolen = arrival(3, 0.0, 50.0, TrafficClass::Interactive);
        stolen.stolen = true;
        stolen.ready_at = ms_to_cycles(0.2); // handed over at a barrier
        let (events, _) = outcome_of(&cfg, &[stolen.clone()]);
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].outcome, ShardEventOutcome::Completed);
        assert_eq!(events[0].req.deadline, stolen.req.deadline);
        assert!(events[0].cycle >= stolen.ready_at, "served no earlier than the hand-off");
    }

    #[test]
    fn steal_newest_pops_the_latest_admission_and_updates_load() {
        // Batch-1 batcher so five of the six arrivals stay queued behind
        // the single in-flight dispatch.
        let cfg = ClusterConfig {
            admission: super::super::AdmissionConfig::admit_all(),
            batcher: crate::serve::BatcherConfig { max_batch: 1, candidates: vec![1] },
            ..Default::default()
        };
        let mut sim = ShardSim::new(vec![PackageSpec::new("p0", DesignPoint::WIENNA_C)], &cfg, None);
        let arrivals: Vec<ClassedRequest> =
            (0..6).map(|i| arrival(i, 0.0, 1000.0, TrafficClass::Batch)).collect();
        // Stop the clock before anything completes.
        sim.step(&arrivals, 1.0);
        let queued_before = sim.queued_total_all();
        assert_eq!(queued_before, 5, "one in flight, five queued");
        let load_before = sim.load_total(0.0);
        let cost = sim.steal_cost().expect("candidate exists");
        let (req, class) = sim.steal_newest().expect("steal succeeds");
        assert_eq!(req.id, 5, "newest admission is stolen first");
        assert_eq!(class, TrafficClass::Batch);
        assert_eq!(sim.queued_total_all(), queued_before - 1);
        let load_after = sim.load_total(0.0);
        assert!((load_before - load_after - cost).abs() < 1e-6, "load drops by the candidate estimate");
    }

    #[test]
    fn full_queue_pushes_out_lower_class_instead_of_shedding_interactive() {
        // Queue cap 2, no deadline shedding, no preemption. Four
        // best-effort arrivals fill (and overflow) the queue, then an
        // interactive arrival hits the full queue: the newest queued
        // best-effort request must be pushed out in its favor.
        let cfg = ClusterConfig {
            admission: super::super::AdmissionConfig { queue_cap: Some(2), shed_late: false },
            preemption: false,
            ..Default::default()
        };
        let mut arrivals: Vec<ClassedRequest> =
            (0..4).map(|i| arrival(i, 0.0, 1000.0, TrafficClass::BestEffort)).collect();
        arrivals.push(arrival(4, 0.0, 1000.0, TrafficClass::Interactive));
        let (events, _) = outcome_of(&cfg, &arrivals);
        let shed: Vec<(u64, TrafficClass)> = events
            .iter()
            .filter(|e| matches!(e.outcome, ShardEventOutcome::Shed(_)))
            .map(|e| (e.req.id, e.class))
            .collect();
        // BE id 3 was refused outright (full queue, no lower class to
        // displace); BE id 2 — the newest queued — was pushed out by the
        // interactive arrival. The interactive request itself completes.
        assert_eq!(shed, vec![(3, TrafficClass::BestEffort), (2, TrafficClass::BestEffort)]);
        let completed: Vec<u64> = events
            .iter()
            .filter(|e| e.outcome == ShardEventOutcome::Completed)
            .map(|e| e.req.id)
            .collect();
        assert!(completed.contains(&4), "interactive request must be served, got {completed:?}");
        assert_eq!(completed.len(), 3);
    }

    #[test]
    fn preemption_aborts_a_lower_class_batch() {
        // A best-effort backlog starts first; an interactive request whose
        // deadline cannot survive waiting for the in-flight batch — but
        // IS reachable after a preemption — lands mid-batch and must
        // preempt it, *under the default admission config* (deadline
        // shedding on): the shed estimate must account for preemption or
        // it would drop the request before the preemption check runs.
        // Timings derive from the actual batch-1 latency L1 so the
        // scenario is robust to cost-model changes: the interactive
        // request arrives at 0.05*L1 with a 1.5*L1 window, so waiting
        // (batch end at L1 + own L1 = 2*L1) misses the deadline at
        // 1.55*L1 while preempt-now (0.05*L1 + L1) meets it.
        let spec = PackageSpec::new("p0", DesignPoint::WIENNA_C);
        let engine = crate::cost::CostEngine::for_design_point(&spec.sys, spec.dp);
        let l1 = crate::serve::CostCache::new()
            .get(&engine, spec.dp, ModelKind::TinyCnn, 1, spec.local_buffer_bytes)
            .latency;
        let l1_ms = crate::serve::cycles_to_ms(l1);
        let cfg = ClusterConfig { preemption: true, ..Default::default() };
        let mut arrivals: Vec<ClassedRequest> =
            (0..16).map(|i| arrival(i, 0.0, 1000.0 * l1_ms, TrafficClass::BestEffort)).collect();
        arrivals.push(arrival(16, 0.05 * l1_ms, 1.5 * l1_ms, TrafficClass::Interactive));
        let (events, out) = outcome_of(&cfg, &arrivals);
        assert!(out.preemptions >= 1, "interactive arrival should preempt");
        // Everything still completes (preempted work is requeued, and the
        // rescued interactive request was admitted, not shed).
        let completed =
            events.iter().filter(|e| e.outcome == ShardEventOutcome::Completed).count();
        assert_eq!(completed, 17);

        // Same scenario with preemption off: no preemptions, and the
        // interactive request is now hopeless, so deadline shedding
        // (default-on) refuses it instead.
        let no = ClusterConfig { preemption: false, ..cfg };
        let (events, out) = outcome_of(&no, &arrivals);
        assert_eq!(out.preemptions, 0);
        let shed =
            events.iter().filter(|e| matches!(e.outcome, ShardEventOutcome::Shed(_))).count();
        assert_eq!(shed, 1, "without preemption the interactive arrival is shed as hopeless");
    }

    #[test]
    fn calibrated_eta_rescues_a_deep_backlog_arrival() {
        // ROADMAP satellite: the conservative batch-1 ETA sheds requests
        // that in-class batching would in fact serve in time. Build a deep
        // same-class backlog, then offer an arrival whose deadline sits
        // between the calibrated and the conservative completion estimate:
        // the conservative estimator must shed it, the calibrated one must
        // serve it. Timings derive from the model's own batch-1/batch-32
        // latencies so the scenario survives cost-model drift. The MLP
        // kind is used because its FC-heavy traffic amortizes strongly
        // with batch (weights are batch-invariant), exactly the regime
        // where the conservative estimate overshoots most.
        let kind = ModelKind::Mlp;
        let spec = PackageSpec::new("p0", DesignPoint::WIENNA_C);
        let engine = crate::cost::CostEngine::for_design_point(&spec.sys, spec.dp);
        let mut cache = crate::serve::CostCache::new();
        let l1 = cache.get(&engine, spec.dp, kind, 1, spec.local_buffer_bytes).latency;
        let l32 = cache.get(&engine, spec.dp, kind, 32, spec.local_buffer_bytes).latency;
        let l1_ms = crate::serve::cycles_to_ms(l1);
        let backlog = 40usize;
        // Completion estimates for the probe arrival (it lands just after
        // t=0, one batch-1 dispatch already in flight), both rounded *up*
        // against the simulator's exact values: conservative walks the
        // backlog at l1 each; calibrated amortizes it at ~l32/32.
        let eta_cons = (backlog as f64 + 2.0) * l1;
        let eta_cal = l1 * 2.0 + backlog as f64 * (l32 / 32.0);
        assert!(eta_cal < 0.9 * eta_cons, "batching gain too small to discriminate");
        let deadline = (eta_cal + eta_cons) / 2.0;

        // All interactive (deadline shedding on), no preemption so the
        // admission verdict is the only discriminator.
        let mk = |calibrated| ClusterConfig {
            preemption: false,
            calibrated_eta: calibrated,
            ..Default::default()
        };
        let req_of = |id: u64, at_ms: f64, slo_ms: f64| {
            let at = ms_to_cycles(at_ms);
            ClassedRequest::fresh(
                Request { id, kind, arrival: at, deadline: at + ms_to_cycles(slo_ms), client: None },
                TrafficClass::Interactive,
            )
        };
        let mut arrivals: Vec<ClassedRequest> =
            (0..backlog as u64).map(|i| req_of(i, 0.0, 1e6 * l1_ms)).collect();
        arrivals.push(req_of(backlog as u64, 0.01 * l1_ms, crate::serve::cycles_to_ms(deadline)));

        let (cons_events, _) = outcome_of(&mk(false), &arrivals);
        let shed_cons: Vec<u64> = cons_events
            .iter()
            .filter(|e| matches!(e.outcome, ShardEventOutcome::Shed(_)))
            .map(|e| e.req.id)
            .collect();
        assert_eq!(shed_cons, vec![backlog as u64], "conservative ETA must shed the probe");

        let (cal_events, _) = outcome_of(&mk(true), &arrivals);
        let shed_cal =
            cal_events.iter().filter(|e| matches!(e.outcome, ShardEventOutcome::Shed(_))).count();
        assert_eq!(shed_cal, 0, "calibrated ETA must admit (and serve) everything");
        // The property the satellite pins: calibrated sheds ⊆ conservative
        // sheds on identical input.
        let completed_cal =
            cal_events.iter().filter(|e| e.outcome == ShardEventOutcome::Completed).count();
        assert_eq!(completed_cal, backlog + 1);
    }

    /// Batch-1 latency of `TinyCnn` on the test package, in ms — fault
    /// scenarios scale their timings off this so they survive cost-model
    /// drift.
    fn l1_ms() -> f64 {
        let spec = PackageSpec::new("p0", DesignPoint::WIENNA_C);
        let engine = crate::cost::CostEngine::for_design_point(&spec.sys, spec.dp);
        let l1 = crate::serve::CostCache::new()
            .get(&engine, spec.dp, ModelKind::TinyCnn, 1, spec.local_buffer_bytes)
            .latency;
        crate::serve::cycles_to_ms(l1)
    }

    fn two_packages() -> Vec<PackageSpec> {
        vec![
            PackageSpec::new("p0", DesignPoint::WIENNA_C),
            PackageSpec::new("p1", DesignPoint::WIENNA_C),
        ]
    }

    #[test]
    fn package_death_reroutes_and_retries_to_the_survivor() {
        // Two packages, round-robin, batch-1. Package 0 dies mid-batch:
        // its in-flight request enters the retry path, its queued work is
        // re-routed to package 1, and *everything still completes*.
        let cfg = ClusterConfig {
            admission: super::super::AdmissionConfig::admit_all(),
            batcher: crate::serve::BatcherConfig { max_batch: 1, candidates: vec![1] },
            policy: RoutePolicy::RoundRobin,
            ..Default::default()
        };
        let kill_at = 0.5 * l1_ms();
        let plan = crate::fault::FaultPlan::parse(&format!("kill:0@{kill_at}")).unwrap();
        let arrivals: Vec<ClassedRequest> =
            (0..8).map(|i| arrival(i, 0.0, 1e6, TrafficClass::Batch)).collect();
        let mut sim = ShardSim::new(two_packages(), &cfg, None).with_faults(plan.for_shard(0, 1, 2));
        let events = sim.step(&arrivals, f64::INFINITY);
        let out = sim.finish();
        let completed =
            events.iter().filter(|e| e.outcome == ShardEventOutcome::Completed).count();
        assert_eq!(completed, 8, "survivor absorbs the dead package's work");
        let ci = TrafficClass::Batch.index();
        assert!(out.class_retries[ci] >= 1, "the aborted in-flight request retried");
        assert!(out.class_reroutes[ci] >= 1, "queued work moved off the dead package");
        // Terminal dispositions are unique per id.
        let mut ids: Vec<u64> = events.iter().map(|e| e.req.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 8);
    }

    #[test]
    fn total_death_fails_retries_and_strands_the_queue() {
        // A single package dies permanently mid-batch: the in-flight
        // request exhausts its retries (no survivor) and fails; queued
        // work is stranded and failed by the terminal cleanup. Per-class
        // conservation holds: arrived == completed + failed.
        let cfg = ClusterConfig {
            admission: super::super::AdmissionConfig::admit_all(),
            batcher: crate::serve::BatcherConfig { max_batch: 1, candidates: vec![1] },
            ..Default::default()
        };
        let kill_at = 0.5 * l1_ms();
        let plan = crate::fault::FaultPlan::parse(&format!("kill:0@{kill_at}")).unwrap();
        let arrivals: Vec<ClassedRequest> =
            (0..4).map(|i| arrival(i, 0.0, 1e6, TrafficClass::Interactive)).collect();
        let mut sim = ShardSim::new(
            vec![PackageSpec::new("p0", DesignPoint::WIENNA_C)],
            &cfg,
            None,
        )
        .with_faults(plan.for_shard(0, 1, 1));
        let mut events = sim.step(&arrivals, f64::INFINITY);
        assert!(!sim.is_drained(), "stranded work holds the shard open");
        events.extend(sim.fail_stranded());
        let out = sim.finish();
        let completed =
            events.iter().filter(|e| e.outcome == ShardEventOutcome::Completed).count();
        let failed = events.iter().filter(|e| e.outcome == ShardEventOutcome::Failed).count();
        assert_eq!(completed, 0, "nothing can complete after a total permanent death");
        assert_eq!(failed, 4, "every request fails terminally exactly once");
        assert!(out.class_retries[TrafficClass::Interactive.index()] >= 1);
        let mut ids: Vec<u64> = events.iter().map(|e| e.req.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 4, "no id finalized twice");
    }

    #[test]
    fn repair_window_releases_work_queued_on_a_dead_package() {
        // kill 0 over [0.1, 0.5)*L1: an arrival landing inside the window
        // queues on the dead package (no survivor exists) and is served
        // right after the repair edge — the `has_future_work` contract.
        let cfg = ClusterConfig { admission: super::super::AdmissionConfig::admit_all(), ..Default::default() };
        let l1 = l1_ms();
        let plan = crate::fault::FaultPlan::parse(&format!("kill:0@{}..{}", 0.1 * l1, 0.5 * l1))
            .unwrap();
        let faults = plan.for_shard(0, 1, 1);
        let arrivals = vec![arrival(0, 0.2 * l1, 1e6, TrafficClass::Interactive)];
        let mut sim = ShardSim::new(
            vec![PackageSpec::new("p0", DesignPoint::WIENNA_C)],
            &cfg,
            None,
        )
        .with_faults(faults);
        let events = sim.step(&arrivals, f64::INFINITY);
        let out = sim.finish();
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].outcome, ShardEventOutcome::Completed);
        let repair = ms_to_cycles(0.5 * l1);
        assert!(
            events[0].cycle >= repair,
            "service cannot start before the repair edge: {} < {repair}",
            events[0].cycle
        );
        assert_eq!(out.class_reroutes, [0; NUM_CLASSES], "no survivor, nothing re-routed");
    }

    #[test]
    fn stall_window_wedges_the_dispatcher_but_not_the_queues() {
        let cfg = ClusterConfig { admission: super::super::AdmissionConfig::admit_all(), ..Default::default() };
        let l1 = l1_ms();
        let stall_end = 3.0 * l1;
        let plan =
            crate::fault::FaultPlan::parse(&format!("stall:0@0..{stall_end}")).unwrap();
        let arrivals: Vec<ClassedRequest> =
            (0..3).map(|i| arrival(i, 0.1 * l1 * i as f64, 1e6, TrafficClass::Batch)).collect();
        let (events, _) = {
            let mut sim = ShardSim::new(
                vec![PackageSpec::new("p0", DesignPoint::WIENNA_C)],
                &cfg,
                None,
            )
            .with_faults(plan.for_shard(0, 1, 1));
            let ev = sim.step(&arrivals, f64::INFINITY);
            (ev, sim.finish())
        };
        assert_eq!(events.len(), 3, "stall delays, never drops");
        let stall_end_cycles = ms_to_cycles(stall_end);
        for e in &events {
            assert_eq!(e.outcome, ShardEventOutcome::Completed);
            assert!(e.cycle > stall_end_cycles, "nothing completes inside the stall window");
        }
    }

    #[test]
    fn contention_stretches_the_run_and_books_token_wait() {
        let base = ClusterConfig { admission: super::super::AdmissionConfig::admit_all(), ..Default::default() };
        let contended = ClusterConfig {
            admission: super::super::AdmissionConfig::admit_all(),
            contention: crate::fault::ContentionConfig::with_background(0.6),
            ..Default::default()
        };
        let arrivals: Vec<ClassedRequest> =
            (0..20).map(|i| arrival(i, 0.02 * i as f64, 1e6, TrafficClass::Batch)).collect();
        let (_, out0) = outcome_of(&base, &arrivals);
        let (events, outc) = outcome_of(&contended, &arrivals);
        assert_eq!(out0.token_wait_cycles, 0.0, "disabled contention books zero wait");
        assert!(outc.token_wait_cycles > 0.0);
        assert!(
            outc.end_cycle > out0.end_cycle,
            "token waits must stretch the run: {} <= {}",
            outc.end_cycle,
            out0.end_cycle
        );
        assert_eq!(
            events.iter().filter(|e| e.outcome == ShardEventOutcome::Completed).count(),
            20,
            "contention slows, never drops"
        );
        // The stretch lands in the dist phase (the attribution satellite).
        let f0 = out0.attr_run.fractions();
        let fc = outc.attr_run.fractions();
        assert!(fc[1] > f0[1], "dist fraction must grow under contention: {fc:?} vs {f0:?}");
    }

    #[test]
    fn sustained_contention_sheds_best_effort_first() {
        let cfg = ClusterConfig {
            admission: super::super::AdmissionConfig::admit_all(),
            contention: crate::fault::ContentionConfig {
                enabled: true,
                background_load: 0.5,
                shed_best_effort_above: 0.9,
            },
            ..Default::default()
        };
        // A spike window pushes effective load to 1.0 >= 0.9 over [0, 5ms).
        let plan = crate::fault::FaultPlan::parse("spike:0.5@0..5").unwrap();
        let mut arrivals = vec![
            arrival(0, 0.01, 1e6, TrafficClass::Interactive),
            arrival(1, 0.02, 1e6, TrafficClass::BestEffort),
            arrival(2, 0.03, 1e6, TrafficClass::Batch),
        ];
        arrivals.push(arrival(3, 0.04, 1e6, TrafficClass::BestEffort));
        let mut sim = ShardSim::new(
            vec![PackageSpec::new("p0", DesignPoint::WIENNA_C)],
            &cfg,
            None,
        )
        .with_faults(plan.for_shard(0, 1, 1));
        let events = sim.step(&arrivals, f64::INFINITY);
        sim.finish();
        let shed: Vec<u64> = events
            .iter()
            .filter(|e| e.outcome == ShardEventOutcome::Shed(ShedReason::Overload))
            .map(|e| e.req.id)
            .collect();
        assert_eq!(shed, vec![1, 3], "exactly the best-effort arrivals are shed");
        let completed =
            events.iter().filter(|e| e.outcome == ShardEventOutcome::Completed).count();
        assert_eq!(completed, 2, "higher classes ride through the spike");
    }

    #[test]
    fn steal_candidates_prefer_best_effort_and_skip_stolen_work() {
        // Batch-1 batcher: id 0 goes in flight, ids 1 (best-effort) and 2
        // (batch, newer) stay queued. Class-aware stealing must offer the
        // best-effort request even though the batch one is newer.
        let cfg = ClusterConfig {
            admission: super::super::AdmissionConfig::admit_all(),
            batcher: crate::serve::BatcherConfig { max_batch: 1, candidates: vec![1] },
            ..Default::default()
        };
        let mut sim = ShardSim::new(
            vec![PackageSpec::new("p0", DesignPoint::WIENNA_C)],
            &cfg,
            None,
        );
        let arrivals = vec![
            arrival(0, 0.0, 1000.0, TrafficClass::Interactive),
            arrival(1, 0.0, 1000.0, TrafficClass::BestEffort),
            arrival(2, 0.0, 1000.0, TrafficClass::Batch),
        ];
        sim.step(&arrivals, 1.0);
        let (req, class) = sim.steal_newest().expect("candidate exists");
        assert_eq!((req.id, class), (1, TrafficClass::BestEffort), "lowest class moves first");

        // Hysteresis: a shard holding only *stolen* queued work offers no
        // steal candidate — once moved, a request never moves again.
        let mut victim = ShardSim::new(
            vec![PackageSpec::new("p0", DesignPoint::WIENNA_C)],
            &cfg,
            None,
        );
        let fresh = arrival(7, 0.0, 1000.0, TrafficClass::Batch);
        let mut handed = arrival(9, 0.0, 1000.0, TrafficClass::Batch);
        handed.stolen = true;
        victim.step(&[fresh, handed], 1.0);
        assert_eq!(victim.queued_total_all(), 1, "stolen hand-off queued behind the dispatch");
        assert!(victim.steal_cost().is_none(), "stolen work is never re-offered");
        victim.step(&[], f64::INFINITY);
        victim.finish();
    }

    #[test]
    fn calendar_scheduler_matches_the_legacy_oracle_event_for_event() {
        // The tentpole's non-negotiable: the calendar-queue loop must
        // reproduce the legacy loop's event stream bit for bit — under
        // chaos (kills, spikes), contention, preemption, a power cap,
        // AND windowed stepping (resumability), all at once.
        let l1 = l1_ms();
        let plan = crate::fault::FaultPlan::parse(&format!(
            "kill:0@{}..{};spike:0.4@0..{}",
            0.4 * l1,
            2.0 * l1,
            3.0 * l1
        ))
        .unwrap();
        let arrivals: Vec<ClassedRequest> = (0..30)
            .map(|i| arrival(i, 0.05 * l1 * i as f64, 30.0 * l1, TrafficClass::ALL[(i % 3) as usize]))
            .collect();
        let run = |scheduler: SchedulerKind| {
            let cfg = ClusterConfig {
                admission: super::super::AdmissionConfig::admit_all(),
                batcher: crate::serve::BatcherConfig { max_batch: 1, candidates: vec![1] },
                policy: RoutePolicy::RoundRobin,
                contention: crate::fault::ContentionConfig::with_background(0.3),
                scheduler,
                ..Default::default()
            };
            let mut sim = ShardSim::new(two_packages(), &cfg, Some(300.0))
                .with_faults(plan.for_shard(0, 1, 2));
            let window = ms_to_cycles(0.5 * l1);
            let mut events: Vec<ShardEvent> = Vec::new();
            let mut cursor = 0usize;
            let mut start = 0.0f64;
            while !sim.is_drained() || cursor < arrivals.len() || sim.has_future_work() {
                let end = start + window;
                let mut slice = Vec::new();
                while cursor < arrivals.len() && arrivals[cursor].ready_at < end {
                    slice.push(arrivals[cursor].clone());
                    cursor += 1;
                }
                events.extend(sim.step_owned(slice, end));
                start = end;
            }
            events.extend(sim.step(&[], f64::INFINITY));
            events.extend(sim.fail_stranded());
            let out = sim.finish();
            (events, out)
        };
        let (legacy, out_l) = run(SchedulerKind::Legacy);
        let (calendar, out_c) = run(SchedulerKind::Calendar);
        assert_eq!(legacy.len(), calendar.len(), "event counts diverge");
        for (a, b) in legacy.iter().zip(calendar.iter()) {
            assert_eq!(a.req.id, b.req.id);
            assert_eq!(a.cycle.to_bits(), b.cycle.to_bits(), "cycle drifted for id {}", a.req.id);
            assert_eq!(a.outcome, b.outcome, "outcome drifted for id {}", a.req.id);
            assert_eq!(a.batch, b.batch);
            assert_eq!(a.queue_cycles.to_bits(), b.queue_cycles.to_bits());
        }
        assert_eq!(out_l.end_cycle.to_bits(), out_c.end_cycle.to_bits());
        assert_eq!(out_l.dispatch_hist, out_c.dispatch_hist);
        assert_eq!(out_l.preemptions, out_c.preemptions);
        assert_eq!(out_l.class_retries, out_c.class_retries);
        assert_eq!(out_l.class_reroutes, out_c.class_reroutes);
        assert_eq!(out_l.token_wait_cycles.to_bits(), out_c.token_wait_cycles.to_bits());
    }

    #[test]
    fn raising_the_cap_slice_unthrottles_dispatch() {
        // The stranded-cap fix's mechanism: the sync barrier hands a
        // survivor shard a larger cap slice via `set_cap_w`, and its
        // governor must start choosing faster DVFS levels. A 1 W slice
        // forces the ladder floor; lifting the cap before dispatch must
        // complete bit-identically to a never-capped run.
        let cfg = ClusterConfig {
            admission: super::super::AdmissionConfig::admit_all(),
            ..Default::default()
        };
        let arrivals = vec![arrival(0, 0.0, 1e6, TrafficClass::Interactive)];
        let run_with = |cap: Option<f64>, raise: Option<Option<f64>>| {
            let mut sim =
                ShardSim::new(vec![PackageSpec::new("p0", DesignPoint::WIENNA_C)], &cfg, cap);
            if let Some(c) = raise {
                sim.set_cap_w(c);
            }
            let ev = sim.step(&arrivals, f64::INFINITY);
            sim.finish();
            ev[0].cycle
        };
        let throttled = run_with(Some(1.0), None);
        let raised = run_with(Some(1.0), Some(None));
        let nominal = run_with(None, None);
        assert!(
            raised < throttled,
            "lifting the cap must speed the batch up: {raised} vs {throttled}"
        );
        assert_eq!(raised.to_bits(), nominal.to_bits(), "a lifted cap equals no cap");
    }
}
