//! Conservative time-window synchronization for the sharded engine.
//!
//! The original cluster engine materialized every (open-loop) arrival up
//! front and ran each shard start-to-finish in isolation — which is why
//! closed-loop sources were rejected and a hot shard could never hand
//! work to an idle one. This module replaces that one-shot fan-out with
//! the classic conservative parallel-discrete-event scheme: simulation
//! advances in fixed-size **epochs** (the lookahead window), each shard
//! simulates one window independently ([`ShardSim::step`]), and at every
//! epoch edge a single-threaded, deterministic **barrier** runs:
//!
//! 1. the window's per-shard event streams are merged in
//!    `(cycle, shard, seq)` order and folded into the stats
//!    (`cluster::merge::fold_events`);
//! 2. **closed-loop feedback** crosses shards: every merged completion
//!    *and shed* (a shed is a fast-fail response the client still
//!    observes) is relayed to the source in that same order, re-arming
//!    `Source::closed_loop` / `Source::client_trace` clients — the two
//!    sources the old engine had to refuse;
//! 3. an optional **work-stealing pass** ([`SyncConfig::steal`])
//!    rebalances queued requests from the most- to the least-loaded
//!    shard in a fixed `(epoch, donor, victim, seq)` order;
//! 4. the next window's arrivals are pulled from the source, classified,
//!    and striped to shards.
//!
//! Everything at the barrier is single-threaded and every shard window is
//! a pure function of its inputs, so stats stay **bit-identical at any
//! worker-thread count** — the same guarantee the one-shot engine had,
//! now with feedback and stealing in the loop.
//!
//! ## Conservatism, exactness, and the window size
//!
//! Feedback and stolen work only cross shards at epoch edges, so the
//! effective cross-shard latency is up to one window
//! ([`SyncConfig::epoch_cycles`]). A client re-armed *inside* the window
//! just simulated is issued with its true ready time; the receiving
//! shard admits it at `max(ready, shard clock)`, so the approximation
//! error is bounded by one window and shrinks as the window does (at the
//! price of more barriers). Two exactness results anchor the design:
//!
//! * **Open-loop, no stealing**: nothing ever crosses shards, so the
//!   engine collapses to a single unbounded epoch that is *byte-identical*
//!   to the old one-shot engine (the existing stats tests pin this).
//! * **Any configuration**: slicing a shard's timeline into windows
//!   without cross-shard traffic reproduces the unsliced run event for
//!   event (`shard::tests::stepping_in_windows_matches_one_unbounded_epoch`).
//!
//! Striping: open-loop requests stripe by request id (as before);
//! closed-loop requests stripe by issuing client, so one client's
//! requests — which are serialized by its own completion feedback anyway
//! — stay on one shard. That mirrors session-affinity load balancing and
//! is exactly the regime where hot clients make hot shards and stealing
//! pays (`benches/cluster_scale.rs` sweeps the skew).

use super::merge;
use super::shard::{ClassedRequest, ShardSim};
use super::{Cluster, ClusterStats, TrafficClass, NUM_CLASSES};
use crate::cost::par;
use crate::serve::{ms_to_cycles, Request, Source};
use crate::telemetry::{
    EpochSample, FlowRecord, MetricsStreamWriter, SloMonitor, Telemetry,
};
use std::collections::HashMap;
use std::sync::Mutex;

/// Epoch-synchronization knobs (`ClusterConfig::sync`).
#[derive(Debug, Clone)]
pub struct SyncConfig {
    /// Width of one synchronization window in cycles: the interval at
    /// which closed-loop feedback and stolen work cross shards. Smaller
    /// windows track a global event loop more closely but pay more
    /// barriers. Ignored (one unbounded epoch) when the source is
    /// open-loop and stealing is off, since nothing would cross shards.
    pub epoch_cycles: f64,
    /// Enable the epoch-barrier work-stealing pass: queued (never
    /// in-flight) requests move from the most- to the least-loaded shard
    /// until the move would no longer shrink the imbalance.
    pub steal: bool,
    /// Adaptive epoch sizing (`--adaptive-epochs`): instead of a fixed
    /// `epoch_cycles` stride, each window ends just past the earliest
    /// in-flight completion bound across shards (clamped by pending
    /// retry timers and fault edges via [`ShardSim::next_wakeup`]), so
    /// quiet stretches pay no barriers and busy ones exchange feedback
    /// at event resolution. Windows with no bound in sight fall back to
    /// the fixed stride. Changes barrier placement — and therefore
    /// cross-shard feedback timing — so outputs are *not* byte-identical
    /// to fixed epochs; they remain bit-identical across thread counts
    /// (the bound is computed single-threaded at the barrier). Ignored
    /// on the open-loop no-steal fast path (one unbounded epoch).
    pub adaptive: bool,
    /// Re-split the fleet power cap over *live* packages at each barrier
    /// when a fault plan is active, so a dead shard's cap slice flows to
    /// the survivors instead of stranding (on by default; the off
    /// position exists for regression tests of the pre-fix behavior).
    pub rebalance_caps: bool,
}

impl Default for SyncConfig {
    fn default() -> Self {
        // 0.5 ms at the Table-4 clock: fine enough that default think
        // times (≥ 1 ms) span multiple windows, coarse enough that a
        // 100 ms run pays ~200 barriers.
        SyncConfig {
            epoch_cycles: ms_to_cycles(0.5),
            steal: false,
            adaptive: false,
            rebalance_caps: true,
        }
    }
}

/// One finalized request in the merged event order — which shard served
/// (or shed) it and when. `Cluster::run_traced` returns these so tests
/// can audit conservation: every admitted request is finalized exactly
/// once, on exactly one shard, stealing or not.
#[derive(Debug, Clone, Copy)]
pub struct TraceEvent {
    pub cycle: f64,
    /// Shard that finalized the request (for a stolen request: the
    /// victim it was moved to, never the donor).
    pub shard: usize,
    pub id: u64,
    pub class: TrafficClass,
    /// `true` for a completion, `false` for a shed.
    pub completed: bool,
}

/// Which shard an arrival is striped to. Open-loop requests stripe by
/// request id; closed-loop requests stripe by their issuing client
/// (session affinity — see the module docs).
fn stripe(req: &Request, shards: usize) -> usize {
    (req.client.map_or(req.id, |c| c as u64) % shards as u64) as usize
}

/// The smaller of two optional event times.
fn min_opt(a: Option<f64>, b: Option<f64>) -> Option<f64> {
    match (a, b) {
        (Some(x), Some(y)) => Some(x.min(y)),
        (x, None) => x,
        (None, y) => y,
    }
}

/// The least f64 strictly greater than a positive finite `x` — used by
/// adaptive epochs to place a window end just *past* the event bounding
/// it, so the event is consumed inside the window and every adaptive
/// epoch makes progress.
fn next_up(x: f64) -> f64 {
    debug_assert!(x.is_finite() && x >= 0.0);
    f64::from_bits(x.to_bits() + 1)
}

/// Run the epoch-synchronized simulation (see module docs). `horizon`
/// bounds *admission*: arrivals issued past it are never admitted, but
/// admitted work always drains. When `trace` is given, every finalized
/// request is recorded in merged order.
pub(crate) fn run_sync(
    cluster: &Cluster,
    source: &mut Source,
    horizon: f64,
    mut trace: Option<&mut Vec<TraceEvent>>,
    mut stream: Option<&mut MetricsStreamWriter<'_>>,
) -> ClusterStats {
    let cfg = &cluster.cfg;
    assert!(
        horizon.is_finite() || source.is_bounded(),
        "an unbounded (Poisson) source needs a finite horizon"
    );
    assert!(cfg.sync.epoch_cycles > 0.0, "epoch width must be positive");
    assert!(
        cfg.sync.epoch_cycles.is_finite() || (source.is_open_loop() && !cfg.sync.steal),
        "closed-loop feedback and stealing need finite epochs"
    );
    let shards = cluster.shards();
    let mut stats =
        ClusterStats::with_mode(shards, cfg.telemetry.bounded, cfg.telemetry.quantile_error);
    // The burn-rate monitor lives outside `stats` (it is evaluation
    // state, not a result); only its raise/clear events land in the
    // registry and the artifacts.
    let mut monitor: Option<SloMonitor> = None;
    if cfg.telemetry.enabled {
        stats.telemetry =
            Some(Box::new(Telemetry { bounded: cfg.telemetry.bounded, ..Default::default() }));
        monitor = Some(SloMonitor::new(cfg.telemetry.slo));
    }

    // Open-loop without stealing has no cross-shard traffic: one
    // unbounded epoch reproduces the pre-sync engine byte for byte and
    // pays no barrier cost.
    let window = if cfg.sync.steal || !source.is_open_loop() {
        cfg.sync.epoch_cycles
    } else {
        f64::INFINITY
    };

    // The fleet power cap splits across shards in proportion to the
    // packages each governs (shards simulate independently — a shared
    // dynamic budget would couple them and break determinism).
    let total_packages = cluster.packages_total();
    let sims: Vec<Mutex<ShardSim>> = cluster
        .specs_by_shard
        .iter()
        .enumerate()
        .map(|(s, specs)| {
            let cap = cfg.power.shard_cap(specs.len(), total_packages);
            // Each shard gets its slice of the fault plan (global package
            // ids map round-robin onto shards, mirroring placement); an
            // empty plan yields an all-empty `ShardFaults` and the
            // pre-fault arithmetic byte for byte.
            let faults = cfg.faults.for_shard(s, shards, specs.len());
            Mutex::new(ShardSim::new(specs.clone(), cfg, cap).with_faults(faults))
        })
        .collect();

    // Time-to-drain accounting for fully dead shards: the first barrier
    // at which each shard had no live package, and the first barrier at
    // or after that at which it held no work.
    let mut death_bar: Vec<Option<f64>> = vec![None; shards];
    let mut drain_bar: Vec<Option<f64>> = vec![None; shards];
    // Sub-epoch drain refinement: which dead shard each failed-over
    // request was drained from (`steal_pass` failover sub-pass), and
    // the latest *exact finalization cycle* observed among each donor's
    // rerouted requests. The map is lookup-only — its hash order never
    // reaches the event stream — so determinism holds.
    let mut rerouted: HashMap<u64, usize> = HashMap::new();
    let mut reroute_done: Vec<Option<f64>> = vec![None; shards];

    // Requests stolen at the previous barrier, awaiting injection into
    // the next window (ready at its start).
    let mut pending: Vec<Vec<ClassedRequest>> = vec![Vec::new(); shards];
    let mut start = 0.0f64;
    loop {
        let end = if !window.is_finite() {
            f64::INFINITY
        } else if cfg.sync.adaptive {
            // Adaptive epochs: end just past the earliest completion /
            // wakeup bound across shards (id-order lock, so the bound —
            // and every barrier placement derived from it — is
            // thread-count-deterministic). Every bound is an event this
            // window will consume, so each adaptive epoch progresses;
            // with nothing in flight, fall back to the fixed stride and
            // let the ingress below decide whether work exists at all.
            let bound = sims
                .iter()
                .map(|m| {
                    let g = m.lock().expect("shard mutex");
                    min_opt(g.next_completion(), g.next_wakeup())
                })
                .fold(None, min_opt);
            match bound {
                Some(b) => next_up(b.max(start)),
                None => start + window,
            }
        } else {
            start + window
        };

        // Ingress for this window: classify (pure in (class_seed, id))
        // and stripe every arrival issued before `end`.
        let mut inputs: Vec<Vec<ClassedRequest>> = std::mem::take(&mut pending);
        // Stolen hand-offs are ready exactly at the window start; an
        // arrival issued earlier (feedback landing inside the previous
        // window) must precede them in the slice's ready order.
        let stolen_counts: Vec<usize> = inputs.iter().map(|v| v.len()).collect();
        while let Some(t) = source.next_arrival_at() {
            if t >= end || t > horizon {
                break;
            }
            let mut req = source.pop();
            let class = cfg.classes.classify(cfg.class_seed, &mut req);
            stats.record_ingress(&req, class);
            let s = stripe(&req, shards);
            let a = ClassedRequest::fresh(req, class);
            if a.ready_at < start && stolen_counts[s] > 0 {
                let at = inputs[s].len() - stolen_counts[s];
                inputs[s].insert(at, a);
            } else {
                inputs[s].push(a);
            }
        }

        // Simulate the window: each shard is a pure function of its
        // accumulated state and this input slice, so the thread count
        // only changes wall-clock time. Slices are handed over by move
        // (`step_owned`) — the striping above was the only copy made.
        let inputs: Vec<Mutex<Vec<ClassedRequest>>> =
            inputs.into_iter().map(Mutex::new).collect();
        let events: Vec<_> = par::par_map(shards, cfg.threads, |s| {
            let taken = std::mem::take(&mut *inputs[s].lock().expect("input mutex"));
            sims[s].lock().expect("shard mutex").step_owned(taken, end)
        });
        stats.epochs += 1;

        // Barrier, single-threaded from here: merge + feedback ...
        merge::fold_events(
            &mut stats,
            &events,
            |t, req| {
                if let Some(&d) = rerouted.get(&req.id) {
                    reroute_done[d] = Some(reroute_done[d].map_or(t, |x| x.max(t)));
                }
                source.on_complete(t, req)
            },
            trace.as_mut().map(|t| &mut **t),
        );
        // Bounded mode: absorb each shard's per-epoch quantile sketches
        // right after the fold, in shard-id order — the deterministic
        // merge point for the sketch track (thread count invisible).
        if stats.bounded {
            for sim in sims.iter() {
                let taken = sim.lock().expect("shard mutex").take_sketches();
                if let Some(sk) = taken {
                    stats.absorb_shard_sketches(sk);
                }
            }
        }

        if end.is_finite() {
            // ... then the stealing pass over the post-window queue state.
            pending = vec![Vec::new(); shards];
            if cfg.sync.steal {
                let mut flows = Vec::new();
                stats.steals += steal_pass(
                    &sims,
                    end,
                    &mut pending,
                    &mut stats.class_reroutes,
                    &mut flows,
                    &mut rerouted,
                );
                if let Some(t) = stats.telemetry.as_mut() {
                    t.log.flows.extend(flows);
                }
            }
            sample_epoch(&mut stats, &sims, end, &mut monitor, &mut stream);
            if !cfg.faults.is_empty() {
                for s in 0..shards {
                    let g = sims[s].lock().expect("shard mutex");
                    if g.fully_dead_at(end) {
                        if death_bar[s].is_none() {
                            death_bar[s] = Some(end);
                        }
                        if drain_bar[s].is_none() && g.is_drained() {
                            drain_bar[s] = Some(end);
                        }
                    }
                }
                // Stranded-cap fix: re-split the fleet cap over *live*
                // packages so a dead shard's slice flows to survivors
                // (and flows back on repair). Barrier-state-only and
                // shard-id-ordered, so thread-count-deterministic.
                if cfg.sync.rebalance_caps && cfg.power.enabled() {
                    rebalance_caps(cfg, &sims, end);
                }
            }

            let have_stolen = pending.iter().any(|p| !p.is_empty());
            let next_arrival = source.next_arrival_at().filter(|&t| t <= horizon);
            let next_completion = sims
                .iter()
                .map(|m| m.lock().expect("shard mutex").next_completion())
                .fold(None, min_opt);
            // Shard-internal wakeups (pending retries, fault edges that
            // unlock wedged queues) also count as progress the drain
            // check must wait for.
            let next_wakeup = sims
                .iter()
                .map(|m| m.lock().expect("shard mutex").next_wakeup())
                .fold(None, min_opt);
            if !have_stolen
                && next_arrival.is_none()
                && next_completion.is_none()
                && next_wakeup.is_none()
            {
                // Nothing can make progress on its own again. Under fault
                // injection, work may still be stranded on hardware that
                // never repairs: fail it now (shard-id order) so the
                // conservation property holds and closed-loop clients
                // observe the errors — which may re-arm them, in which
                // case the run continues.
                if !cfg.faults.is_empty() {
                    let stranded: Vec<_> = sims
                        .iter()
                        .map(|m| m.lock().expect("shard mutex").fail_stranded())
                        .collect();
                    if stranded.iter().any(|v| !v.is_empty()) {
                        merge::fold_events(
                            &mut stats,
                            &stranded,
                            |t, req| {
                                if let Some(&d) = rerouted.get(&req.id) {
                                    reroute_done[d] =
                                        Some(reroute_done[d].map_or(t, |x| x.max(t)));
                                }
                                source.on_complete(t, req)
                            },
                            trace.as_mut().map(|t| &mut **t),
                        );
                        start = end;
                        if source.next_arrival_at().filter(|&t| t <= horizon).is_some() {
                            continue;
                        }
                    }
                }
                break; // drained: no queued work can exist without an in-flight batch
            }
            start = end;
            if !have_stolen {
                // Nothing due for several windows? Jump straight to the
                // window containing the next event. Safe: with no events
                // in between, shard loads cannot change, so the skipped
                // barriers' steal passes would all be no-ops (the pass
                // runs to convergence).
                if let Some(t) = min_opt(min_opt(next_arrival, next_completion), next_wakeup) {
                    if t >= start + window {
                        start = (t / window).floor() * window;
                    }
                }
            }
        } else {
            // The single unbounded epoch drained everything; sample once
            // at the last shard clock so the fast path still emits a
            // (degenerate, all-drained) time series.
            let last = sims
                .iter()
                .map(|m| m.lock().expect("shard mutex").now())
                .fold(0.0f64, f64::max);
            sample_epoch(&mut stats, &sims, last, &mut monitor, &mut stream);
            // The fast path runs open-loop only, so failing stranded
            // work here cannot re-arm anything: one cleanup fold drains
            // the shards for `finish()`.
            if !cfg.faults.is_empty() {
                let stranded: Vec<_> = sims
                    .iter()
                    .map(|m| m.lock().expect("shard mutex").fail_stranded())
                    .collect();
                merge::fold_events(
                    &mut stats,
                    &stranded,
                    |t, req| {
                        if let Some(&d) = rerouted.get(&req.id) {
                            reroute_done[d] = Some(reroute_done[d].map_or(t, |x| x.max(t)));
                        }
                        source.on_complete(t, req)
                    },
                    trace.as_mut().map(|t| &mut **t),
                );
            }
            break;
        }
    }

    if !cfg.faults.is_empty() {
        // A shard that died and never emptied before the run ended
        // drains at its final clock (stranded work failed just above).
        for s in 0..shards {
            if death_bar[s].is_some() && drain_bar[s].is_none() {
                drain_bar[s] = Some(sims[s].lock().expect("shard mutex").now());
            }
        }
        // Per dead shard, the drain end is the exact finalization cycle
        // of the last request failover rerouted off it (sub-epoch
        // resolution); shards that drained without any reroute fall back
        // to the epoch-edge bound recorded at the barrier.
        stats.dead_shard_drain_cycles = death_bar
            .iter()
            .zip(&drain_bar)
            .enumerate()
            .filter_map(|(s, (d, r))| {
                let death = (*d)?;
                let end = reroute_done[s].or(*r)?;
                Some((end - death).max(0.0))
            })
            .fold(0.0f64, f64::max);
    }

    let outcomes: Vec<_> = sims
        .into_iter()
        .map(|m| m.into_inner().expect("shard mutex").finish())
        .collect();
    merge::finalize(&mut stats, outcomes, &cfg.power.model);
    if !cfg.faults.is_empty() {
        // Failover-goodput denominator: cycles of the run overlapped by
        // at least one package-death window of the plan.
        let run_end = stats.serve.end_cycle();
        stats.outage_cycles = cfg
            .faults
            .outage_intervals()
            .iter()
            .map(|&(s, e)| (e.min(run_end) - s.min(run_end)).max(0.0))
            .sum();
    }
    stats
}

/// Re-split the fleet power cap across shards in proportion to each
/// shard's *live* (not fault-killed) packages at barrier cycle `bar` —
/// the stranded-cap fix. A fully dead shard's slice drops to zero (its
/// governor floors, which is moot: it cannot dispatch) and the freed
/// watts raise every survivor's slice, so the fleet keeps drawing up to
/// the configured cap instead of throttling below it. Repair reverses
/// the split at the next barrier. With the whole fleet dead there is
/// nothing to rebalance toward, so the pre-kill slices are kept.
fn rebalance_caps(cfg: &super::ClusterConfig, sims: &[Mutex<ShardSim>], bar: f64) {
    let live: Vec<usize> =
        sims.iter().map(|m| m.lock().expect("shard mutex").live_packages(bar)).collect();
    let total: usize = live.iter().sum();
    if total == 0 {
        return;
    }
    for (s, m) in sims.iter().enumerate() {
        m.lock().expect("shard mutex").set_cap_w(cfg.power.shard_cap(live[s], total));
    }
}

/// Sample the epoch-edge gauges into the metrics registry (no-op when
/// telemetry is off): post-steal queue depth, in-flight batches, and
/// inferred draw across all shards — fleet-wide and per package — plus
/// the cumulative completion / shed / steal counters already folded
/// into `stats`. The SLO burn-rate monitor observes the same barrier,
/// and a streaming writer (when armed) appends the sample and any
/// raise/clear events immediately. Runs at the single-threaded barrier
/// and locks shards in id order, so the series — and the streamed
/// artifact — is bit-identical at any worker-thread count.
fn sample_epoch(
    stats: &mut ClusterStats,
    sims: &[Mutex<ShardSim>],
    cycle: f64,
    monitor: &mut Option<SloMonitor>,
    stream: &mut Option<&mut MetricsStreamWriter<'_>>,
) {
    if stats.telemetry.is_none() {
        return;
    }
    let mut queued = 0u64;
    let mut in_flight_batches = 0u64;
    let mut power_w = 0.0f64;
    let mut dist_busy = 0.0f64;
    let mut token_wait = 0.0f64;
    let mut packages = 0usize;
    let mut mac_occupancy_by_pkg = Vec::new();
    let mut token_wait_by_pkg = Vec::new();
    let pkg_denominator = if cycle > 0.0 && cycle.is_finite() { cycle } else { f64::INFINITY };
    for sim in sims {
        let g = sim.lock().expect("shard mutex");
        queued += g.queued_total_all() as u64;
        in_flight_batches += g.inflight_batches();
        power_w += g.inflight_power_w();
        dist_busy += g.dist_busy_cycles();
        token_wait += g.token_wait_cycles();
        packages += g.package_count();
        // Shard-major package order — the same order `stats.packages`
        // ends up in, so the report's top-N indices are stable.
        for busy in g.dist_busy_by_pkg() {
            mac_occupancy_by_pkg.push(if pkg_denominator.is_finite() {
                busy / pkg_denominator
            } else {
                0.0
            });
        }
        token_wait_by_pkg.extend_from_slice(g.token_wait_by_pkg());
    }
    // Fleet-average occupancy of the shared wireless medium so far: the
    // fraction of elapsed package-cycles spent driving the distribution
    // plane. Climbs toward `nop::mac::MAC_SATURATION` under contention.
    let mac_occupancy = if cycle > 0.0 && cycle.is_finite() && packages > 0 {
        dist_busy / (cycle * packages as f64)
    } else {
        0.0
    };
    let mut shed = [0u64; NUM_CLASSES];
    let mut slo_counts = [(0u64, 0u64); NUM_CLASSES];
    for c in TrafficClass::ALL {
        if let Some(m) = stats.per_class.get(&c) {
            shed[c.index()] = m.shed;
            slo_counts[c.index()] = (m.completed, m.slo_violated);
        }
    }
    let sample = EpochSample {
        epoch: stats.epochs,
        cycle,
        queued,
        in_flight_batches,
        completed: stats.serve.completed(),
        shed,
        steals: stats.steals,
        power_w,
        mac_occupancy,
        token_wait_cycles: token_wait,
        mac_occupancy_by_pkg,
        token_wait_by_pkg,
    };
    // Burn-rate evaluation at the same barrier, over the same
    // deterministically merged counters.
    let events = match monitor.as_mut() {
        Some(m) => m.observe(stats.epochs, cycle, &slo_counts),
        None => Vec::new(),
    };
    let t = stats.telemetry.as_mut().expect("checked above");
    if let Some(w) = stream.as_mut() {
        w.write_epoch(&sample);
        for e in &events {
            w.write_slo_event(e);
        }
    }
    t.metrics.epochs.push(sample);
    t.metrics.slo_events.extend(events);
}

/// The epoch-barrier stealing pass at barrier cycle `bar`: repeatedly
/// move the newest queued request of the most-loaded shard (the donor)
/// to the least-loaded one (the victim), while the move still shrinks
/// the donor/victim gap — i.e. while `load(donor) - load(victim)` exceeds
/// the candidate's own service estimate. Load is estimated *cycles*
/// (busy remainder + batch-1 backlog), not request counts, so a queue of
/// heavy models out-donates a deeper queue of light ones. Ties resolve
/// to the lower shard id, and a request stolen this barrier is not
/// steal-able again until the next one (it travels via `pending`), so
/// the pass terminates after at most the initially-queued request count
/// and its `(epoch, donor, victim, seq)` move order is deterministic.
///
/// Stolen requests are appended to `pending[victim]` with
/// `ready_at = bar`: the victim cannot serve work before the barrier
/// that handed it over.
///
/// **Failover** rides the same pass: before ordinary rebalancing, every
/// *fully dead* shard (no live package at `bar`) is drained entirely —
/// hysteresis does not protect work on hardware that cannot serve it —
/// to the least-loaded live shards, counted per class into `reroutes`.
/// Dead shards are never picked as victims. Every cross-shard move
/// (steal or failover) appends a [`FlowRecord`] so the Chrome trace can
/// draw a flow arrow from donor enqueue to victim service. Failed-over
/// requests are additionally recorded in `rerouted` (request id -> dead
/// donor, first donor wins) so the run loop can timestamp each dead
/// shard's drain with the exact finalization cycle of its last rerouted
/// request instead of rounding up to the epoch edge.
fn steal_pass(
    sims: &[Mutex<ShardSim>],
    bar: f64,
    pending: &mut [Vec<ClassedRequest>],
    reroutes: &mut [u64; NUM_CLASSES],
    flows: &mut Vec<FlowRecord>,
    rerouted: &mut HashMap<u64, usize>,
) -> u64 {
    if sims.len() < 2 {
        return 0;
    }
    let mut guards: Vec<_> =
        sims.iter().map(|m| m.lock().expect("shard mutex")).collect();
    let mut loads: Vec<f64> = guards.iter().map(|g| g.load_total(bar)).collect();

    // Failover sub-pass, shard-id order. Skipped entirely unless some
    // shard is fully dead *and* a live shard exists to take the work
    // (with the whole fleet dead the queues stay stranded and fail at
    // the drain check).
    for donor in 0..guards.len() {
        if !guards[donor].fully_dead_at(bar) {
            continue;
        }
        if !(0..guards.len()).any(|v| v != donor && !guards[v].fully_dead_at(bar)) {
            break;
        }
        let drained = guards[donor].drain_all_queued();
        if drained.is_empty() {
            continue;
        }
        loads[donor] = guards[donor].load_total(bar);
        for (req, class) in drained {
            // Victim: least-loaded live shard, ties -> lower id,
            // re-picked per request as hand-offs pile load on.
            let mut victim: Option<usize> = None;
            for v in 0..guards.len() {
                if v == donor || guards[v].fully_dead_at(bar) {
                    continue;
                }
                if victim.map_or(true, |b| loads[v] < loads[b]) {
                    victim = Some(v);
                }
            }
            let victim = victim.expect("live shard existence checked above");
            loads[victim] += guards[victim].estimate_service1(req.kind);
            reroutes[class.index()] += 1;
            rerouted.entry(req.id).or_insert(donor);
            flows.push(FlowRecord {
                id: req.id,
                class,
                from_shard: donor,
                to_shard: victim,
                cycle: bar,
            });
            pending[victim].push(ClassedRequest { ready_at: bar, stolen: true, req, class });
        }
    }

    let mut moved = 0u64;
    let mut budget: usize = guards.iter().map(|g| g.queued_total_all()).sum();
    while budget > 0 {
        // Donor: most-loaded shard that still has queued (steal-able)
        // work; victim: least-loaded *live* shard. Ties -> lower id.
        let mut donor: Option<usize> = None;
        let mut victim: Option<usize> = None;
        for s in 0..guards.len() {
            if guards[s].queued_total_all() > 0
                && donor.map_or(true, |d| loads[s] > loads[d])
            {
                donor = Some(s);
            }
            if !guards[s].fully_dead_at(bar) && victim.map_or(true, |v: usize| loads[s] < loads[v])
            {
                victim = Some(s);
            }
        }
        let (Some(donor), Some(victim)) = (donor, victim) else { break };
        if donor == victim {
            break;
        }
        let Some(cost) = guards[donor].steal_cost() else { break };
        if loads[donor] - loads[victim] <= cost {
            break; // the move would overshoot: rebalancing has converged
        }
        let (req, class) = guards[donor].steal_newest().expect("steal_cost saw a candidate");
        loads[donor] -= cost;
        loads[victim] += cost;
        flows.push(FlowRecord {
            id: req.id,
            class,
            from_shard: donor,
            to_shard: victim,
            cycle: bar,
        });
        pending[victim].push(ClassedRequest { ready_at: bar, stolen: true, req, class });
        moved += 1;
        budget -= 1;
    }
    moved
}
