//! Per-package admission control: queue caps and deadline-aware shedding.
//!
//! Admission is decided at routing time, before a request touches a
//! queue. Two independent gates:
//!
//! * **queue cap** — a hard bound on how many requests may wait at one
//!   package (all classes combined). Protects queue memory and keeps the
//!   worst-case queueing delay bounded under overload.
//! * **deadline-aware shedding** — refuse a request whose *predicted*
//!   completion already misses its deadline; serving it would burn array
//!   cycles on an answer nobody can use. Only applies to classes that
//!   opted in (`ClassSpec::deadline_shed`) and only when the request
//!   carries a finite deadline.
//!
//! Both decisions are pure functions of the (deterministic) simulation
//! state, so admission introduces no cross-shard coupling.

/// Why a request was refused.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShedReason {
    /// The target package's admission queue is at its cap.
    QueueFull,
    /// The predicted completion misses the request's deadline even before
    /// it queues (deadline-aware load shedding).
    DeadlineHopeless,
    /// Graceful degradation under sustained shared-medium contention:
    /// best-effort arrivals are shed while the effective MAC load sits at
    /// or above `fault::ContentionConfig::shed_best_effort_above`.
    Overload,
}

impl ShedReason {
    pub fn label(&self) -> &'static str {
        match self {
            ShedReason::QueueFull => "queue-full",
            ShedReason::DeadlineHopeless => "deadline",
            ShedReason::Overload => "overload",
        }
    }
}

/// The in-class batching gain used by the *calibrated* completion
/// estimate (`ClusterConfig::calibrated_eta`): the factor by which the
/// dynamic batcher amortizes a depth-`queued_ahead` backlog of `kind`
/// relative to serving it one request at a time.
///
/// The gain is `(latency(B)/B) / latency(1)` at the largest batcher
/// candidate `B` that a dispatch over this backlog could use, clamped to
/// `(0, 1]`. The clamp is a *correctness* bound, not cosmetics: the
/// calibrated ETA scales only the queued-backlog term by this factor, so
/// gain ≤ 1 guarantees `calibrated ETA ≤ conservative ETA` — and since
/// `AdmissionConfig::admit` is monotone in the ETA, the calibrated
/// estimator can never shed a request the conservative one would have
/// served (property-tested below).
pub fn batching_gain(
    cache: &mut crate::serve::CostCache,
    engine: &crate::cost::CostEngine,
    dp: crate::config::DesignPoint,
    kind: crate::serve::ModelKind,
    queued_ahead: u64,
    batcher: &crate::serve::BatcherConfig,
    local_buffer_bytes: u64,
) -> f64 {
    if queued_ahead <= 1 {
        return 1.0;
    }
    let limit = queued_ahead.min(batcher.max_batch);
    // Candidates are ascending; the dispatcher favors the largest one the
    // backlog admits (throughput-optimal under no deadline pressure).
    let Some(&b) = batcher.candidates.iter().filter(|&&b| b <= limit).next_back() else {
        return 1.0;
    };
    if b <= 1 {
        return 1.0;
    }
    let l1 = cache.get(engine, dp, kind, 1, local_buffer_bytes).latency;
    let lb = cache.get(engine, dp, kind, b, local_buffer_bytes).latency;
    // Degenerate service rates (a zero- or infinite-latency estimate from
    // a pathological package shape) make the ratio meaningless — fall
    // back to the conservative gain of 1 rather than dividing through.
    if l1 <= 0.0 || !l1.is_finite() || !lb.is_finite() {
        return 1.0;
    }
    ((lb / b as f64) / l1).clamp(f64::MIN_POSITIVE, 1.0)
}

/// Admission-control knobs, applied per package.
#[derive(Debug, Clone, Copy)]
pub struct AdmissionConfig {
    /// Most requests that may wait at one package, all classes combined
    /// (`None` = unbounded). A cap of 0 sheds every arrival — useful as a
    /// drain switch and as a property-test anchor. Two refinements to the
    /// bound: a higher-class arrival meeting a full queue *displaces* the
    /// newest strictly-lower-class queued request instead of being
    /// refused (priority isolation extends to admission — see
    /// `cluster::shard`), and a preemption requeues its aborted batch
    /// even at cap (dropping already-admitted work would be worse), so
    /// depth can transiently exceed the cap by up to the batcher's max
    /// batch.
    pub queue_cap: Option<usize>,
    /// Enable deadline-aware shedding for classes that allow it.
    pub shed_late: bool,
}

impl Default for AdmissionConfig {
    fn default() -> Self {
        AdmissionConfig { queue_cap: Some(256), shed_late: true }
    }
}

impl AdmissionConfig {
    /// No caps, no shedding: every arrival is admitted (the plain
    /// `serve::Fleet` behavior).
    pub fn admit_all() -> Self {
        AdmissionConfig { queue_cap: None, shed_late: false }
    }

    /// Decide admission for one arrival routed to a package currently
    /// holding `queued_depth` requests, with predicted completion
    /// `eta_cycles` against `deadline_cycles`. `deadline_shed` is the
    /// arriving request's class policy.
    ///
    /// The deadline gate runs *first*: a hopeless request is refused as
    /// hopeless whatever the queue looks like, so a `QueueFull` verdict
    /// certifies the request was still viable — the cluster's push-out
    /// path relies on that to never displace queued work in favor of an
    /// arrival that would miss its deadline anyway.
    ///
    /// The gate checks `eta.is_nan() || eta > deadline` rather than the
    /// bare comparison on purpose: the ETA upstream is built from
    /// service-rate estimates, and a degenerate package (zero service
    /// rate, or an ∞−∞ busy-remainder edge on an empty backlog) yields an
    /// infinite or NaN prediction. `NaN > deadline` is `false`, so the
    /// naive comparison would *silently admit* a request whose completion
    /// estimate is garbage; an ∞ ETA sheds via the ordinary comparison
    /// and the NaN edge is shed explicitly — the unit tests pin all four
    /// corners.
    pub fn admit(
        &self,
        queued_depth: usize,
        eta_cycles: f64,
        deadline_cycles: f64,
        deadline_shed: bool,
    ) -> Result<(), ShedReason> {
        if self.shed_late
            && deadline_shed
            && deadline_cycles.is_finite()
            && (eta_cycles.is_nan() || eta_cycles > deadline_cycles)
        {
            return Err(ShedReason::DeadlineHopeless);
        }
        if let Some(cap) = self.queue_cap {
            if queued_depth >= cap {
                return Err(ShedReason::QueueFull);
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_cap_sheds_everything() {
        let cfg = AdmissionConfig { queue_cap: Some(0), shed_late: false };
        assert_eq!(cfg.admit(0, 0.0, f64::INFINITY, true), Err(ShedReason::QueueFull));
    }

    #[test]
    fn uncapped_and_unshed_admits_everything() {
        let cfg = AdmissionConfig::admit_all();
        assert!(cfg.admit(usize::MAX - 1, 1e18, 1.0, true).is_ok());
    }

    #[test]
    fn cap_binds_at_the_boundary() {
        let cfg = AdmissionConfig { queue_cap: Some(4), shed_late: false };
        assert!(cfg.admit(3, 0.0, f64::INFINITY, false).is_ok());
        assert_eq!(cfg.admit(4, 0.0, f64::INFINITY, false), Err(ShedReason::QueueFull));
    }

    #[test]
    fn hopeless_beats_queue_full_when_both_apply() {
        // The deadline gate runs first: a hopeless arrival at a full
        // queue is refused as hopeless, so QueueFull certifies viability
        // (the push-out path depends on this ordering).
        let cfg = AdmissionConfig { queue_cap: Some(0), shed_late: true };
        assert_eq!(cfg.admit(0, 200.0, 100.0, true), Err(ShedReason::DeadlineHopeless));
        assert_eq!(cfg.admit(0, 200.0, 100.0, false), Err(ShedReason::QueueFull));
    }

    #[test]
    fn prop_batching_gain_is_a_true_gain() {
        // Across random kinds and depths: the gain stays in (0, 1] and
        // never grows with depth beyond the ladder's reach — i.e. the
        // calibrated backlog estimate is never *more* pessimistic than
        // the conservative batch-1 one.
        use crate::config::{DesignPoint, SystemConfig};
        use crate::cost::CostEngine;
        use crate::serve::{BatcherConfig, CostCache, ModelKind};
        let mut rng = crate::testutil::Rng::new(0xE7A);
        let sys = SystemConfig::default();
        let batcher = BatcherConfig::default();
        let mut cache = CostCache::new();
        let kinds = [ModelKind::TinyCnn, ModelKind::Mlp];
        for dp in [DesignPoint::WIENNA_C, DesignPoint::INTERPOSER_A] {
            let engine = CostEngine::for_design_point(&sys, dp);
            for _ in 0..32 {
                let kind = *rng.pick(&kinds);
                let depth = rng.range_u64(0, 300);
                let g = batching_gain(&mut cache, &engine, dp, kind, depth, &batcher, 512 * 1024);
                assert!(g > 0.0 && g <= 1.0, "gain {g} at depth {depth}");
            }
        }
    }

    #[test]
    fn prop_calibrated_eta_never_sheds_what_conservative_serves() {
        // `admit` is monotone in the ETA, and the calibrated ETA scales
        // the backlog by a gain ≤ 1: whatever the conservative estimate
        // admits, the calibrated one admits too (for any depth/deadline).
        let mut rng = crate::testutil::Rng::new(0x5EED);
        let cfg = AdmissionConfig::default();
        for _ in 0..500 {
            let busy = rng.next_f32() as f64 * 1e7;
            let backlog = rng.next_f32() as f64 * 1e8;
            let service1 = rng.next_f32() as f64 * 1e6;
            let gain = (rng.next_f32() as f64).clamp(f64::MIN_POSITIVE, 1.0);
            let deadline = rng.next_f32() as f64 * 2e8;
            let depth = rng.range_u64(0, 200) as usize;
            let conservative = busy + backlog + service1;
            let calibrated = busy + backlog * gain + service1;
            assert!(calibrated <= conservative);
            if cfg.admit(depth, conservative, deadline, true).is_ok() {
                assert!(
                    cfg.admit(depth, calibrated, deadline, true).is_ok(),
                    "calibrated ETA shed a request the conservative one served \
                     (busy {busy}, backlog {backlog}, gain {gain}, deadline {deadline})"
                );
            }
        }
    }

    #[test]
    fn degenerate_eta_edges_shed_instead_of_slipping_through() {
        // The ETA upstream divides by package service rates; a zero-rate
        // package predicts an infinite completion and an ∞−∞ /
        // empty-backlog edge predicts NaN. Neither may silently pass the
        // deadline gate (NaN > d is false, so the naive comparison used
        // to admit it).
        let cfg = AdmissionConfig { queue_cap: None, shed_late: true };
        assert_eq!(
            cfg.admit(0, f64::INFINITY, 100.0, true),
            Err(ShedReason::DeadlineHopeless),
            "infinite ETA (zero service rate) against a finite deadline"
        );
        assert_eq!(
            cfg.admit(0, f64::NAN, 100.0, true),
            Err(ShedReason::DeadlineHopeless),
            "NaN ETA must be treated as hopeless, not silently admitted"
        );
        // With no deadline to miss (or shedding off), the degenerate ETA
        // is irrelevant and the request is admitted.
        assert!(cfg.admit(0, f64::INFINITY, f64::INFINITY, true).is_ok());
        assert!(cfg.admit(0, f64::NAN, f64::INFINITY, true).is_ok());
        assert!(cfg.admit(0, f64::NAN, 100.0, false).is_ok());
        // A NaN ETA at a full queue still reports the deadline verdict
        // first (the gate-ordering contract the push-out path needs).
        let capped = AdmissionConfig { queue_cap: Some(0), shed_late: true };
        assert_eq!(capped.admit(0, f64::NAN, 100.0, true), Err(ShedReason::DeadlineHopeless));
        assert_eq!(capped.admit(0, f64::NAN, 100.0, false), Err(ShedReason::QueueFull));
    }

    #[test]
    fn deadline_shed_respects_class_policy_and_finiteness() {
        let cfg = AdmissionConfig { queue_cap: None, shed_late: true };
        // Hopeless and sheddable: refused.
        assert_eq!(cfg.admit(0, 200.0, 100.0, true), Err(ShedReason::DeadlineHopeless));
        // Hopeless but the class opted out: admitted.
        assert!(cfg.admit(0, 200.0, 100.0, false).is_ok());
        // No deadline at all: admitted.
        assert!(cfg.admit(0, 200.0, f64::INFINITY, true).is_ok());
        // Reachable deadline: admitted.
        assert!(cfg.admit(0, 50.0, 100.0, true).is_ok());
        // Shedding disabled globally: admitted.
        let off = AdmissionConfig { queue_cap: None, shed_late: false };
        assert!(off.admit(0, 200.0, 100.0, true).is_ok());
    }
}
