//! Deterministic merge of per-shard event streams into cluster stats.
//!
//! Shards emit chronologically ordered completion/shed streams that are
//! independent of the worker-thread count (`cluster::shard`). At every
//! epoch barrier the sync layer hands this module one event batch per
//! shard; [`fold_events`] interleaves them into one stream ordered by
//! `(cycle, shard id, emission index)` — exactly the order a
//! single-threaded simulation of that window would produce, with the
//! shard id as the total tie-break — folds it into [`ClusterStats`], and
//! relays completions to the closed-loop feedback hook in the same
//! order. Across epochs the global fold order is therefore
//! `(epoch, cycle, shard id, emission index)`. Because the inputs, the
//! merge order and the feedback order are all thread-count-independent,
//! a fixed RNG seed yields **bit-identical** stats (and stats JSON) at
//! any thread count; `wienna cluster --stats-json` + the CI determinism
//! gate diff exactly this output.

use super::admission::ShedReason;
use super::class::{TrafficClass, NUM_CLASSES};
use super::shard::{ShardEvent, ShardEventOutcome, ShardOutcome, ShardSketches};
use super::sync::TraceEvent;
use crate::config::CLOCK_HZ;
use crate::power::{FleetEnergy, PowerModel};
use crate::serve::{cycles_to_ms, ModelStats, Package, Request, ServeStats};
use crate::telemetry::{PhaseTotals, SloEventKind, Telemetry, DEFAULT_QUANTILE_ERROR, PHASES};
use std::collections::BTreeMap;

/// Cluster-wide serving statistics: the fleet-level [`ServeStats`] plus
/// per-class SLO accounting and the admission/preemption counters.
#[derive(Debug, Default)]
pub struct ClusterStats {
    /// Fleet-level aggregates (latency percentiles, goodput, sheds, batch
    /// histogram) over the merged event stream.
    pub serve: ServeStats,
    /// Per-traffic-class accounting, priority order.
    pub per_class: BTreeMap<TrafficClass, ModelStats>,
    /// Batches aborted by priority preemption.
    pub preemptions: u64,
    /// Queued requests rebalanced to another shard by the epoch-barrier
    /// work-stealing pass (`cluster::sync`).
    pub steals: u64,
    /// Time windows the synchronized run advanced through (1 for the
    /// open-loop, no-steal fast path, which runs one unbounded epoch).
    pub epochs: u64,
    /// Arrivals refused because the target package's queue was at cap.
    pub shed_queue_full: u64,
    /// Arrivals refused by deadline-aware load shedding.
    pub shed_deadline: u64,
    /// Best-effort arrivals shed by graceful degradation under sustained
    /// shared-medium contention (`wienna::fault`).
    pub shed_overload: u64,
    /// Retries scheduled per class under fault injection
    /// (`class.index()` order; all-zero without a fault plan).
    pub class_retries: [u64; NUM_CLASSES],
    /// Requests re-routed off dead hardware per class — shard-internal
    /// re-homes plus barrier failover hand-offs.
    pub class_reroutes: [u64; NUM_CLASSES],
    /// Cycles of the run during which at least one package was dead
    /// (clipped to the run length) — the failover-goodput denominator.
    pub outage_cycles: f64,
    /// SLO-meeting completions that landed inside an outage window.
    pub outage_slo_met: u64,
    /// Time from a shard losing its last package to the last of its
    /// rerouted requests being finalized, at exact sub-epoch cycle
    /// resolution (0 when no shard ever fully died). Shards whose
    /// backlog produced no rerouted finalization fall back to the
    /// epoch-edge drain bound.
    pub dead_shard_drain_cycles: f64,
    /// Cumulative shared-medium token-wait cycles across all dispatches
    /// (exactly 0.0 with contention disabled).
    pub token_wait_cycles: f64,
    /// Shards the run was partitioned into (thread count is deliberately
    /// *not* recorded here — stats must not depend on it).
    pub shards: usize,
    /// Final per-package accounting, shard-major deterministic order.
    pub packages: Vec<Package>,
    /// The run's energy summary (`wienna::power`), aggregated over the
    /// shard-major package list — deterministic at any thread count.
    pub energy: FleetEnergy,
    /// Dynamic energy attributed to each traffic class (dense
    /// `TrafficClass::index()` order), summed over shards in shard order.
    pub class_energy_mj: [f64; NUM_CLASSES],
    /// Shard-local cost-cache totals (hits, misses).
    pub cache_hits: u64,
    pub cache_misses: u64,
    /// Always-on per-class cycle attribution (`class.index()` order),
    /// summed over shards in shard order. The run-level sums live in
    /// `serve.attr`.
    pub class_attr: [PhaseTotals; NUM_CLASSES],
    /// Opt-in telemetry (`ClusterConfig::telemetry`): the merged span
    /// log plus the metrics registry. `None` when disabled — one pointer
    /// of overhead.
    pub telemetry: Option<Box<Telemetry>>,
    /// `--bounded-stats`: every latency recorder (fleet and per-class,
    /// lazily created ones included) is sketch-backed, the event fold
    /// books completion counters only, and per-shard latency sketches
    /// are absorbed at the barrier — O(buckets + epochs) memory however
    /// many requests the run serves.
    pub(crate) bounded: bool,
    /// Sketch resolution (`--quantile-error`) for bounded recorders.
    pub(crate) quantile_error: f64,
}

impl ClusterStats {
    pub(crate) fn new(shards: usize) -> Self {
        ClusterStats::with_mode(shards, false, DEFAULT_QUANTILE_ERROR)
    }

    /// Stats in the given memory mode (`bounded` = `--bounded-stats`,
    /// `quantile_error` = the sketch resolution, bounded mode only).
    pub(crate) fn with_mode(shards: usize, bounded: bool, quantile_error: f64) -> Self {
        ClusterStats {
            shards,
            bounded,
            quantile_error,
            serve: if bounded { ServeStats::bounded_with(quantile_error) } else { ServeStats::new() },
            ..Default::default()
        }
    }

    /// Whether the latency recorders are sketch-backed.
    pub fn is_bounded(&self) -> bool {
        self.bounded
    }

    /// A per-class entry in this run's memory mode.
    fn class_entry(&mut self, class: TrafficClass) -> &mut ModelStats {
        let bounded = self.bounded;
        let eps = self.quantile_error;
        self.per_class.entry(class).or_insert_with(|| ModelStats::with_error(bounded, eps))
    }

    /// Merge one shard's bounded-stats latency sketches into the fleet,
    /// per-model, and per-class recorders. Called at the sync barrier in
    /// shard-id order; sketch merges are integer-exact, so given that
    /// fixed order the result is independent of the worker-thread count.
    /// Empty tracks are skipped so the absorb never lazily creates a
    /// stats entry for a class or model with no traffic.
    pub(crate) fn absorb_shard_sketches(&mut self, sk: ShardSketches) {
        debug_assert!(self.bounded, "sketch absorb on an exact-mode run");
        if !sk.all.is_empty() {
            self.serve.absorb_latency_sketch(&sk.all);
        }
        for (kind, s) in &sk.per_model {
            if !s.is_empty() {
                self.serve.absorb_model_latency_sketch(*kind, s);
            }
        }
        for (ci, s) in sk.per_class.iter().enumerate() {
            if !s.is_empty() {
                self.class_entry(TrafficClass::ALL[ci]).latency.absorb_sketch(s);
            }
        }
    }

    /// Record one classified arrival at cluster ingress.
    pub(crate) fn record_ingress(&mut self, req: &Request, class: TrafficClass) {
        self.serve.record_arrival(req);
        self.class_entry(class).arrived += 1;
    }

    /// SLO burn-rate alert totals over the run: `(raised, still active
    /// at the end)`. `(0, 0)` without telemetry — the stats JSON never
    /// goes null.
    pub fn slo_alert_counts(&self) -> (u64, u64) {
        let Some(t) = self.telemetry.as_ref() else { return (0, 0) };
        let mut raised = 0u64;
        let mut active = 0i64;
        for e in &t.metrics.slo_events {
            match e.kind {
                SloEventKind::Raise => {
                    raised += 1;
                    active += 1;
                }
                SloEventKind::Clear => active -= 1,
            }
        }
        (raised, active.max(0) as u64)
    }

    /// Latency percentile of one class, in milliseconds (`NaN` when the
    /// class completed nothing).
    pub fn class_latency_ms(&self, class: TrafficClass, p: f64) -> f64 {
        self.per_class.get(&class).map_or(f64::NAN, |m| cycles_to_ms(m.latency.percentile(p)))
    }

    /// Per-class SLO violation rate (0 when nothing completed).
    pub fn class_violation_rate(&self, class: TrafficClass) -> f64 {
        self.per_class.get(&class).map_or(0.0, |m| {
            if m.completed == 0 {
                0.0
            } else {
                m.slo_violated as f64 / m.completed as f64
            }
        })
    }

    /// Total retries scheduled across classes.
    pub fn retries(&self) -> u64 {
        self.class_retries.iter().sum()
    }

    /// Total re-routes off dead hardware across classes.
    pub fn reroutes(&self) -> u64 {
        self.class_reroutes.iter().sum()
    }

    /// Tail amplification: p99 / p50 latency. Contention and failover
    /// stretch the tail much faster than the median, so this is the
    /// headline chaos metric. 0 when fewer than one completion (or a
    /// degenerate zero median).
    pub fn tail_amplification(&self) -> f64 {
        let p50 = self.serve.latency_ms(50.0);
        let p99 = self.serve.latency_ms(99.0);
        if p50.is_finite() && p50 > 0.0 && p99.is_finite() {
            p99 / p50
        } else {
            0.0
        }
    }

    /// Goodput (SLO-meeting completions per second) measured only over
    /// the outage windows of the fault plan — how much useful work the
    /// survivors pushed while part of the fleet was dead. 0 when the
    /// plan had no outage overlapping the run.
    pub fn failover_goodput_rps(&self) -> f64 {
        if self.outage_cycles <= 0.0 {
            return 0.0;
        }
        self.outage_slo_met as f64 / (self.outage_cycles / CLOCK_HZ)
    }

    /// Time-to-drain a fully dead shard, in milliseconds (0 when no
    /// shard ever lost all its packages).
    pub fn dead_shard_drain_ms(&self) -> f64 {
        if self.dead_shard_drain_cycles > 0.0 {
            cycles_to_ms(self.dead_shard_drain_cycles)
        } else {
            0.0
        }
    }

    /// Machine-readable summary. Deterministic field order; floats are
    /// printed with Rust's shortest-round-trip formatting, so two JSON
    /// dumps are byte-identical iff the underlying stats are bit-identical
    /// (the CI determinism gate diffs this across thread counts). The
    /// field schema — names and order — is pinned by the golden fixture
    /// at `rust/testdata/cluster_stats_schema.golden`.
    pub fn to_json(&self) -> String {
        // Zero-completion (or otherwise degenerate) runs have NaN
        // percentiles and fractions internally; the wire format pins
        // them to `0` so downstream JSON consumers never see `null`/NaN
        // in a rate, percentile, or fraction field.
        fn z(v: f64) -> String {
            if v.is_finite() {
                format!("{v}")
            } else {
                "0".to_string()
            }
        }
        let mut s = String::from("{\n");
        s.push_str(&format!("  \"shards\": {},\n", self.shards));
        s.push_str(&format!("  \"arrived\": {},\n", self.serve.arrived()));
        s.push_str(&format!("  \"completed\": {},\n", self.serve.completed()));
        s.push_str(&format!("  \"shed\": {},\n", self.serve.shed()));
        s.push_str(&format!("  \"shed_queue_full\": {},\n", self.shed_queue_full));
        s.push_str(&format!("  \"shed_deadline\": {},\n", self.shed_deadline));
        s.push_str(&format!("  \"shed_overload\": {},\n", self.shed_overload));
        s.push_str(&format!("  \"failed\": {},\n", self.serve.failed()));
        s.push_str(&format!("  \"retries\": {},\n", self.retries()));
        s.push_str(&format!("  \"reroutes\": {},\n", self.reroutes()));
        s.push_str(&format!("  \"preemptions\": {},\n", self.preemptions));
        s.push_str(&format!("  \"steals\": {},\n", self.steals));
        s.push_str(&format!("  \"epochs\": {},\n", self.epochs));
        s.push_str(&format!("  \"dispatches\": {},\n", self.serve.dispatches()));
        s.push_str(&format!("  \"mean_batch\": {},\n", z(self.serve.mean_batch())));
        s.push_str(&format!("  \"end_cycle\": {},\n", z(self.serve.end_cycle())));
        for p in [50.0, 95.0, 99.0] {
            s.push_str(&format!("  \"p{p:.0}_ms\": {},\n", z(self.serve.latency_ms(p))));
        }
        s.push_str(&format!("  \"tail_amplification\": {},\n", z(self.tail_amplification())));
        s.push_str(&format!("  \"violation_rate\": {},\n", z(self.serve.violation_rate())));
        s.push_str(&format!("  \"goodput_rps\": {},\n", z(self.serve.goodput_rps())));
        s.push_str(&format!(
            "  \"failover_goodput_rps\": {},\n",
            z(self.failover_goodput_rps())
        ));
        s.push_str(&format!("  \"dead_shard_drain_ms\": {},\n", z(self.dead_shard_drain_ms())));
        s.push_str(&format!("  \"dynamic_mj\": {},\n", z(self.energy.dynamic_mj())));
        s.push_str(&format!("  \"leakage_mj\": {},\n", z(self.energy.leakage_mj)));
        s.push_str(&format!("  \"total_energy_mj\": {},\n", z(self.energy.total_mj())));
        s.push_str(&format!(
            "  \"energy_per_req_j\": {},\n",
            z(self.energy.energy_per_req_j(self.serve.completed()))
        ));
        s.push_str(&format!(
            "  \"avg_power_w\": {},\n",
            z(self.energy.avg_power_w(self.serve.end_cycle()))
        ));
        s.push_str(&format!("  \"throttled_batches\": {},\n", self.energy.throttled_batches));
        // Burn-rate monitor totals (`telemetry::slo`); plain zeroes when
        // telemetry is off, so the schema never shifts.
        let (slo_raised, slo_active) = self.slo_alert_counts();
        s.push_str(&format!("  \"slo_alerts_raised\": {slo_raised},\n"));
        s.push_str(&format!("  \"slo_alerts_active\": {slo_active},\n"));
        // Cycle attribution (`wienna::telemetry`): fraction of every
        // completed request's end-to-end cycles spent in each phase.
        let fracs = self.serve.attr.fractions();
        for (name, v) in PHASES.iter().zip(fracs) {
            s.push_str(&format!("  \"{name}_frac\": {},\n", z(v)));
        }
        s.push_str("  \"per_class\": [\n");
        let n = self.per_class.len();
        for (i, (class, m)) in self.per_class.iter().enumerate() {
            let cf = self.class_attr[class.index()].fractions();
            let frac_fields: String = PHASES
                .iter()
                .zip(cf)
                .map(|(name, v)| format!(", \"{name}_frac\": {}", z(v)))
                .collect();
            s.push_str(&format!(
                "    {{\"class\": \"{}\", \"arrived\": {}, \"completed\": {}, \"shed\": {}, \"failed\": {}, \"retries\": {}, \"reroutes\": {}, \"slo_met\": {}, \"slo_violated\": {}, \"p50_ms\": {}, \"p99_ms\": {}, \"energy_mj\": {}{}}}{}\n",
                class.label(),
                m.arrived,
                m.completed,
                m.shed,
                m.failed,
                self.class_retries[class.index()],
                self.class_reroutes[class.index()],
                m.slo_met,
                m.slo_violated,
                z(cycles_to_ms(m.latency.percentile(50.0))),
                z(cycles_to_ms(m.latency.percentile(99.0))),
                z(self.class_energy_mj[class.index()]),
                frac_fields,
                if i + 1 < n { "," } else { "" }
            ));
        }
        s.push_str("  ]\n}\n");
        s
    }

    /// Serialize the collected telemetry (histograms, epoch series,
    /// attribution, optional memo counters) — `wienna cluster
    /// --metrics-out`. Panics unless the run enabled
    /// `ClusterConfig::telemetry`.
    pub fn metrics_json(&self, memo: Option<crate::cost::MemoStats>) -> String {
        let t = self.telemetry.as_ref().expect("run with ClusterConfig::telemetry enabled");
        let sketches = self.named_sketches();
        crate::telemetry::metrics_json_with(t, &self.serve.attr, Some(&self.class_attr), memo, &sketches)
    }

    /// [`ClusterStats::metrics_json`] with the epochs array left empty:
    /// the summary line a `wienna-metrics-stream-v1` stream is sealed
    /// with. `telemetry::stream_to_metrics_v1` splices the streamed
    /// epoch lines back in to reproduce the buffered artifact byte for
    /// byte.
    pub fn metrics_json_summary(&self, memo: Option<crate::cost::MemoStats>) -> String {
        let t = self.telemetry.as_ref().expect("run with ClusterConfig::telemetry enabled");
        let sketches = self.named_sketches();
        crate::telemetry::metrics_json_summary_with(
            t,
            &self.serve.attr,
            Some(&self.class_attr),
            memo,
            &sketches,
        )
    }

    /// The artifact's `sketches` block: under `--bounded-stats` the
    /// fleet and per-class ε-bounded latency sketches ride along at
    /// full sketch resolution (empty in exact mode), so `wienna
    /// report` can answer the same quantiles the stats line printed.
    fn named_sketches(&self) -> Vec<crate::telemetry::NamedSketch<'_>> {
        let mut out = Vec::new();
        if let Some(sk) = self.serve.latency_sketch() {
            out.push(("latency_ms".to_string(), sk));
        }
        for (class, m) in &self.per_class {
            if let Some(sk) = m.latency.sketch() {
                out.push((format!("latency_ms_{}", class.label().replace('-', "_")), sk));
            }
        }
        out
    }

    /// Serialize the span log as a Chrome trace-event (Perfetto-loadable)
    /// JSON — `wienna cluster --trace-out`. Panics unless the run enabled
    /// `ClusterConfig::telemetry`.
    pub fn chrome_trace(&self) -> String {
        let t = self.telemetry.as_ref().expect("run with ClusterConfig::telemetry enabled");
        crate::telemetry::chrome_trace(t)
    }
}

/// Fold one epoch's per-shard event batches into `stats` via the
/// deterministic k-way merge (see module docs for the ordering contract).
/// `by_shard[s]` is shard `s`'s chronological event stream for this
/// epoch. Every finalized request — completion *or* shed — is relayed to
/// `feedback` in merged order: that is the hook closed-loop sources hang
/// their re-arm logic on, and a shed is a fast-fail response the client
/// still observes (were sheds swallowed, one shed would silently cancel
/// all of that client's remaining requests, shrinking the offered load
/// under any shedding admission config). Every event also lands in
/// `trace` (when asked for) so tests can audit exactly which shard
/// finalized which request.
pub(crate) fn fold_events(
    stats: &mut ClusterStats,
    by_shard: &[Vec<ShardEvent>],
    mut feedback: impl FnMut(f64, &Request),
    mut trace: Option<&mut Vec<TraceEvent>>,
) {
    let mut cursors = vec![0usize; by_shard.len()];
    loop {
        // Ties across shards resolve to the lower shard id (`c < bc`
        // keeps the first-found minimum).
        let mut best: Option<(f64, usize)> = None;
        for (s, evs) in by_shard.iter().enumerate() {
            if cursors[s] < evs.len() {
                let c = evs[cursors[s]].cycle;
                let better = match best {
                    None => true,
                    Some((bc, _)) => c < bc,
                };
                if better {
                    best = Some((c, s));
                }
            }
        }
        let Some((_, s)) = best else {
            break;
        };
        let ev = &by_shard[s][cursors[s]];
        cursors[s] += 1;
        let bounded = stats.bounded;
        let eps = stats.quantile_error;
        let m = stats
            .per_class
            .entry(ev.class)
            .or_insert_with(|| ModelStats::with_error(bounded, eps));
        match ev.outcome {
            ShardEventOutcome::Completed => {
                if bounded {
                    // Latencies reach the recorders as whole per-shard
                    // sketches at the barrier (`absorb_shard_sketches`)
                    // — the fold books counters only.
                    m.record_completion_counters(&ev.req, ev.cycle);
                    stats.serve.record_completion_counters(&ev.req, ev.cycle);
                } else {
                    m.record_completion(&ev.req, ev.cycle);
                    stats.serve.record_completion(&ev.req, ev.cycle);
                }
                feedback(ev.cycle, &ev.req);
            }
            ShardEventOutcome::Shed(reason) => {
                m.shed += 1;
                match reason {
                    ShedReason::QueueFull => stats.shed_queue_full += 1,
                    ShedReason::DeadlineHopeless => stats.shed_deadline += 1,
                    ShedReason::Overload => stats.shed_overload += 1,
                }
                stats.serve.record_shed(&ev.req);
                feedback(ev.cycle, &ev.req);
            }
            ShardEventOutcome::Failed => {
                // A fault-killed request out of retries: terminal, and a
                // closed-loop client observes the error like any other
                // response (it still re-arms).
                m.failed += 1;
                stats.serve.record_failed(&ev.req);
                feedback(ev.cycle, &ev.req);
            }
        }
        // Bounded mode has no span log to stream at finalize — the
        // deterministically merged event stream feeds the telemetry
        // histograms right here instead (same values, same order).
        if stats.bounded && ev.outcome == ShardEventOutcome::Completed {
            if let Some(t) = stats.telemetry.as_mut() {
                let latency = cycles_to_ms(ev.cycle - ev.req.arrival);
                let queue = cycles_to_ms(ev.queue_cycles);
                t.metrics.latency_ms.record(latency);
                t.metrics.queue_wait_ms.record(queue);
                t.metrics.batch_size.record(ev.batch as f64);
                t.metrics.class_latency_ms[ev.class.index()].record(latency);
                t.metrics.class_queue_wait_ms[ev.class.index()].record(queue);
            }
        }
        if let Some(t) = trace.as_mut() {
            t.push(TraceEvent {
                cycle: ev.cycle,
                shard: s,
                id: ev.req.id,
                class: ev.class,
                completed: ev.outcome == ShardEventOutcome::Completed,
            });
        }
    }
}

/// Fold the shards' final accounting into `stats` after the last epoch:
/// dispatch histograms, package state, per-class energy and counters
/// merge by shard id — plain sums, order-insensitive but kept
/// deterministic by the shard-major order. `model` prices the leakage
/// integral of the merged package list.
pub(crate) fn finalize(stats: &mut ClusterStats, outcomes: Vec<ShardOutcome>, model: &PowerModel) {
    let mut end_cycle = 0.0f64;
    for o in &outcomes {
        stats.preemptions += o.preemptions;
        stats.cache_hits += o.cache_hits;
        stats.cache_misses += o.cache_misses;
        end_cycle = end_cycle.max(o.end_cycle);
        for ci in 0..NUM_CLASSES {
            stats.class_energy_mj[ci] += o.class_energy_mj[ci];
            stats.class_reroutes[ci] += o.class_reroutes[ci];
            stats.class_retries[ci] += o.class_retries[ci];
            stats.class_attr[ci].merge(&o.attr_class[ci]);
        }
        stats.outage_slo_met += o.outage_slo_met;
        stats.token_wait_cycles += o.token_wait_cycles;
        stats.serve.attr.merge(&o.attr_run);
        for (&batch, &n) in &o.dispatch_hist {
            stats.serve.record_dispatches(batch, n);
        }
    }
    for (s, o) in outcomes.into_iter().enumerate() {
        if let Some(t) = stats.telemetry.as_mut() {
            t.log.absorb(s, o.log);
        }
        stats.packages.extend(o.packages);
    }
    if let Some(t) = stats.telemetry.as_mut() {
        // Orders the merged span log `(cycle, shard, emission index)`
        // and streams it through the histograms — the last
        // thread-count-sensitive-looking step, made deterministic by the
        // shard-order absorb above.
        t.finish();
    }
    stats.serve.finish(end_cycle);
    // Shard-major package order + fixed-order summation: bit-identical
    // energy at any worker-thread count.
    stats.energy = FleetEnergy::collect(&stats.packages, end_cycle, model);
    stats.serve.energy = Some(stats.energy);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::ModelKind;
    use std::collections::BTreeMap;

    fn req(id: u64, arrival: f64, slo: f64) -> Request {
        Request { id, kind: ModelKind::TinyCnn, arrival, deadline: arrival + slo, client: None }
    }

    fn completion(cycle: f64, id: u64, class: TrafficClass) -> ShardEvent {
        ShardEvent {
            cycle,
            outcome: ShardEventOutcome::Completed,
            class,
            req: req(id, 0.0, 1e9),
            queue_cycles: cycle / 2.0,
            batch: 1,
        }
    }

    fn empty_outcome(end_cycle: f64) -> ShardOutcome {
        ShardOutcome {
            dispatch_hist: BTreeMap::new(),
            preemptions: 0,
            packages: Vec::new(),
            class_energy_mj: [0.0; NUM_CLASSES],
            end_cycle,
            cache_hits: 0,
            cache_misses: 0,
            attr_run: PhaseTotals::default(),
            attr_class: [PhaseTotals::default(); NUM_CLASSES],
            class_retries: [0; NUM_CLASSES],
            class_reroutes: [0; NUM_CLASSES],
            outage_slo_met: 0,
            token_wait_cycles: 0.0,
            log: crate::telemetry::SpanLog::default(),
        }
    }

    #[test]
    fn merge_orders_by_cycle_then_shard_and_feeds_back_in_order() {
        let a = vec![
            completion(10.0, 0, TrafficClass::Interactive),
            completion(30.0, 1, TrafficClass::Interactive),
        ];
        let b = vec![
            completion(10.0, 2, TrafficClass::Batch),
            completion(20.0, 3, TrafficClass::Batch),
        ];
        let mut stats = ClusterStats::new(2);
        for e in a.iter().chain(b.iter()) {
            stats.record_ingress(&e.req, e.class);
        }
        let mut feedback_order = Vec::new();
        let mut trace = Vec::new();
        fold_events(
            &mut stats,
            &[a, b],
            |t, r| feedback_order.push((t, r.id)),
            Some(&mut trace),
        );
        finalize(&mut stats, vec![empty_outcome(30.0), empty_outcome(20.0)], &PowerModel::default());
        assert_eq!(stats.serve.completed(), 4);
        assert_eq!(stats.per_class[&TrafficClass::Interactive].completed, 2);
        assert_eq!(stats.per_class[&TrafficClass::Batch].completed, 2);
        // The cycle-10 tie resolves to shard 0 first, then shard 1, then
        // strictly by cycle — feedback and trace both saw (10/id 0,
        // 10/id 2, 20/id 3, 30/id 1).
        assert_eq!(feedback_order, vec![(10.0, 0), (10.0, 2), (20.0, 3), (30.0, 1)]);
        let traced: Vec<(usize, u64)> = trace.iter().map(|t| (t.shard, t.id)).collect();
        assert_eq!(traced, vec![(0, 0), (1, 2), (1, 3), (0, 1)]);
        assert!(trace.iter().all(|t| t.completed));
        assert_eq!(stats.serve.latency_ms(100.0), cycles_to_ms(30.0));
        assert_eq!(stats.serve.end_cycle(), 30.0, "end cycle is the max over shards");
    }

    #[test]
    fn json_is_deterministic_and_balanced() {
        let mk = || {
            let events = vec![completion(5.0, 0, TrafficClass::Interactive)];
            let mut s = ClusterStats::new(1);
            s.record_ingress(&events[0].req, TrafficClass::Interactive);
            fold_events(&mut s, &[events], |_, _| {}, None);
            finalize(&mut s, vec![empty_outcome(5.0)], &PowerModel::default());
            s
        };
        let s1 = mk();
        let s2 = mk();
        assert_eq!(s1.to_json(), s2.to_json());
        let j = s1.to_json();
        assert!(j.contains("\"arrived\": 1"));
        assert!(j.contains("\"completed\": 1"));
        assert!(j.contains("\"class\": \"interactive\""));
        assert!(j.contains("\"dynamic_mj\": "), "energy fields are part of the gated JSON");
        assert!(j.contains("\"throttled_batches\": 0"));
        assert!(j.contains("\"steals\": 0"), "sync counters are part of the gated JSON");
        assert!(j.contains("\"epochs\": 0"));
        assert!(j.contains("\"energy_mj\": "));
        assert!(j.contains("\"failed\": 0"), "fault counters are part of the gated JSON");
        assert!(j.contains("\"shed_overload\": 0"));
        assert!(j.contains("\"retries\": 0"));
        assert!(j.contains("\"reroutes\": 0"));
        assert!(j.contains("\"tail_amplification\": "));
        assert!(j.contains("\"failover_goodput_rps\": 0"));
        assert!(j.contains("\"dead_shard_drain_ms\": 0"));
        assert!(j.contains("\"slo_alerts_raised\": 0"), "SLO totals are part of the gated JSON");
        assert!(j.contains("\"slo_alerts_active\": 0"));
        assert!(!j.contains(",\n  ]"), "no trailing comma before array close");
    }

    #[test]
    fn bounded_fold_feeds_histograms_and_stays_within_the_bound() {
        let events: Vec<ShardEvent> =
            (0..200).map(|i| completion(100.0 + 37.0 * i as f64, i, TrafficClass::Batch)).collect();
        let mut exact = ClusterStats::new(1);
        let mut bounded = ClusterStats::with_mode(1, true, 0.01);
        bounded.telemetry = Some(Box::new(Telemetry { bounded: true, ..Default::default() }));
        for e in &events {
            exact.record_ingress(&e.req, e.class);
            bounded.record_ingress(&e.req, e.class);
        }
        // The barrier path: the fold books counters only; latencies
        // travel as a per-shard sketch absorbed right after (exactly
        // what `cluster::sync` does each epoch).
        let mut sk = ShardSketches::new(0.01);
        for e in &events {
            sk.record(e.req.kind, e.class, e.cycle - e.req.arrival);
        }
        fold_events(&mut exact, &[events.clone()], |_, _| {}, None);
        fold_events(&mut bounded, &[events], |_, _| {}, None);
        bounded.absorb_shard_sketches(sk);
        finalize(&mut exact, vec![empty_outcome(7500.0)], &PowerModel::default());
        finalize(&mut bounded, vec![empty_outcome(7500.0)], &PowerModel::default());

        assert!(bounded.is_bounded());
        assert_eq!(bounded.serve.exact_samples(), 0, "bounded mode grew a latency Vec");
        assert_eq!(bounded.serve.completed(), exact.serve.completed());
        let t = bounded.telemetry.as_ref().unwrap();
        assert_eq!(t.metrics.latency_ms.count, 200, "fold feeds the registry in bounded mode");
        assert_eq!(t.metrics.queue_wait_ms.count, 200);
        assert_eq!(t.metrics.batch_size.count, 200);
        assert_eq!(t.metrics.class_latency_ms[TrafficClass::Batch.index()].count, 200);
        for p in [50.0, 95.0, 99.0] {
            let ratio = bounded.serve.latency_ms(p) / exact.serve.latency_ms(p);
            assert!(
                (ratio - 1.0).abs() <= 0.01 + 1e-9,
                "p{p}: bounded {} vs exact {} outside the sketch's 1% bound",
                bounded.serve.latency_ms(p),
                exact.serve.latency_ms(p)
            );
            let cr = bounded.class_latency_ms(TrafficClass::Batch, p)
                / exact.class_latency_ms(TrafficClass::Batch, p);
            assert!((cr - 1.0).abs() <= 0.01 + 1e-9, "per-class p{p} outside the bound");
        }
        // Double-finalize safety: `finish` must not re-stream the empty
        // span log over the fold-fed histograms.
        assert_eq!(t.metrics.latency_ms.count, 200);
    }

    #[test]
    fn zero_completion_json_has_no_null_or_nan_fields() {
        // A run that completes nothing (everything shed, or an empty
        // workload) must still emit well-formed numbers: percentiles,
        // fractions and goodput are pinned to 0, never null/NaN.
        let mut stats = ClusterStats::new(1);
        finalize(&mut stats, vec![empty_outcome(0.0)], &PowerModel::default());
        let j = stats.to_json();
        assert!(!j.contains("null"), "zero-completion JSON leaked a null:\n{j}");
        assert!(!j.contains("NaN"), "zero-completion JSON leaked a NaN:\n{j}");
        assert!(j.contains("\"p50_ms\": 0,"));
        assert!(j.contains("\"p99_ms\": 0,"));
        assert!(j.contains("\"tail_amplification\": 0,"));
        assert!(j.contains("\"goodput_rps\": 0,"));
        assert!(j.contains("\"queue_frac\": 0,"));
        assert!(j.contains("\"dist_frac\": 0,"));
        assert_eq!(stats.tail_amplification(), 0.0);
        assert_eq!(stats.failover_goodput_rps(), 0.0);
        assert_eq!(stats.dead_shard_drain_ms(), 0.0);
    }

    #[test]
    fn folding_in_epochs_accumulates_across_calls() {
        // Two fold_events calls (two epochs) must account the same as one
        // call over the concatenation — the incremental-merge contract.
        let mut stats = ClusterStats::new(2);
        let e0 = vec![completion(1.0, 0, TrafficClass::Batch)];
        let e1 = vec![completion(9.0, 1, TrafficClass::Batch)];
        stats.record_ingress(&e0[0].req, TrafficClass::Batch);
        stats.record_ingress(&e1[0].req, TrafficClass::Batch);
        fold_events(&mut stats, &[e0, Vec::new()], |_, _| {}, None);
        fold_events(&mut stats, &[Vec::new(), e1], |_, _| {}, None);
        finalize(&mut stats, vec![empty_outcome(1.0), empty_outcome(9.0)], &PowerModel::default());
        assert_eq!(stats.serve.completed(), 2);
        assert_eq!(stats.per_class[&TrafficClass::Batch].completed, 2);
        assert_eq!(stats.serve.end_cycle(), 9.0);
    }
}
