//! Wireless MAC layer: the statically scheduled TDM sequence of the
//! asymmetric distribution plane (substrate S8, link layer).
//!
//! WIENNA's wireless NoP needs no arbiter — there is exactly one
//! transmitter (at the global SRAM) and distributions are known ahead of
//! time (§4: "distributions are scheduled beforehand", which renders flow
//! and congestion control trivial). The MAC is therefore a deterministic
//! token schedule: an ordered list of airtime slots, one per transfer,
//! each tagged with the receiver set that must power its RX on. Receivers
//! not in the set stay power-gated — this is what makes unicast energy
//! `TX + 1 RX` instead of `TX + N_C RX`.
//!
//! The schedule also models the *reconfiguration guard*: switching the
//! active partitioning strategy between layers re-programs the RX filter
//! tables, costing a small fixed number of cycles (the paper's adaptive
//! reconfigurability is cheap but not free).

use super::channel::Channel;
use super::sim::Transfer;
use crate::config::CLOCK_HZ;

/// One TDM airtime slot.
#[derive(Debug, Clone)]
pub struct Slot {
    /// Start cycle of the slot.
    pub start: f64,
    /// Airtime in cycles (payload bytes / air bandwidth).
    pub cycles: f64,
    /// Number of receivers that must be active.
    pub active_rx: usize,
    /// Payload bytes.
    pub bytes: u64,
}

/// A compiled TDM schedule for one layer's distribution phase.
#[derive(Debug, Clone, Default)]
pub struct TdmSchedule {
    pub slots: Vec<Slot>,
    /// Total makespan in cycles, including the reconfiguration guard.
    pub makespan: f64,
    /// Guard cycles spent on strategy reconfiguration.
    pub guard_cycles: f64,
}

impl TdmSchedule {
    /// Total airtime (busy cycles) of the schedule.
    pub fn airtime(&self) -> f64 {
        self.slots.iter().map(|s| s.cycles).sum()
    }

    /// Medium utilization: airtime / makespan.
    pub fn utilization(&self) -> f64 {
        if self.makespan == 0.0 {
            0.0
        } else {
            self.airtime() / self.makespan
        }
    }

    /// Receiver-activation integral: Σ slot cycles x active receivers —
    /// proportional to total RX energy.
    pub fn rx_cycle_integral(&self) -> f64 {
        self.slots.iter().map(|s| s.cycles * s.active_rx as f64).sum()
    }
}

/// TDM scheduler for the single-TX wireless plane.
#[derive(Debug, Clone)]
pub struct TdmMac {
    /// Air bandwidth in bytes/cycle (Table 4: 16 or 32).
    pub bw: f64,
    /// Guard cycles charged when the partitioning strategy (and hence the
    /// RX filter configuration) changes between consecutive layers.
    pub reconfig_guard_cycles: f64,
    /// Per-slot turnaround overhead in cycles (preamble + header).
    pub slot_overhead_cycles: f64,
}

impl Default for TdmMac {
    fn default() -> Self {
        TdmMac { bw: 16.0, reconfig_guard_cycles: 8.0, slot_overhead_cycles: 0.25 }
    }
}

impl TdmMac {
    pub fn new(bw: f64) -> Self {
        TdmMac { bw, ..Default::default() }
    }

    /// Compile a transfer list into a TDM schedule.
    ///
    /// `reconfigured` marks whether this layer switched strategy relative
    /// to the previous one (adaptive mode) and therefore pays the guard.
    pub fn compile(&self, transfers: &[Transfer], reconfigured: bool) -> TdmSchedule {
        let guard = if reconfigured { self.reconfig_guard_cycles } else { 0.0 };
        let mut t = guard;
        let mut slots = Vec::with_capacity(transfers.len());
        for tr in transfers {
            assert!(!tr.dests.is_empty(), "transfer without destinations");
            let cycles = tr.bytes as f64 / self.bw + self.slot_overhead_cycles;
            slots.push(Slot { start: t, cycles, active_rx: tr.dests.len(), bytes: tr.bytes });
            t += cycles;
        }
        TdmSchedule { slots, makespan: t, guard_cycles: guard }
    }

    /// Verify the schedule is collision-free (slots strictly ordered and
    /// non-overlapping) — the invariant that lets WIENNA drop the arbiter.
    pub fn verify(&self, s: &TdmSchedule) -> bool {
        s.slots.windows(2).all(|w| w[1].start >= w[0].start + w[0].cycles - 1e-9)
    }

    /// Check the physical layer supports this MAC's rate across the
    /// package (closing the loop with `nop/channel.rs`).
    pub fn feasible_on(&self, ch: &Channel, package_diag_m: f64, tx_dbm: f64, ber: f64) -> bool {
        let gbps = self.bw * 8.0 * CLOCK_HZ / 1e9;
        ch.supports(gbps, package_diag_m, tx_dbm, ber)
    }
}

/// Effective shared-medium occupancy of one dispatch: its own airtime
/// share plus the background load other token holders contribute, clamped
/// below saturation so the queueing form below stays finite. The 0.95
/// ceiling models the MAC's practical operating region — a fully
/// saturated token ring serves nothing and the serving layer sheds before
/// reaching it (graceful degradation, `fault::ContentionConfig`).
pub const MAC_SATURATION: f64 = 0.95;

/// Closed-form token-wait delay of one batch's distribution phase on a
/// contended shared medium, in cycles.
///
/// With several co-packaged chiplet multicasts live, the single-TX TDM
/// schedule above stops being the whole story: each package's
/// distribution stream must wait for the token before its slots run.
/// Modeling token arbitration as an M/D/1-style queue on the shared
/// medium (deterministic slot service, Poisson token requests — the
/// standard token-ring waiting-time approximation), the expected wait a
/// stream of `dist_busy` airtime cycles accrues over a batch of latency
/// `latency` at background occupancy `background_load` is
///
/// ```text
/// rho  = clamp(dist_busy / latency + background_load, 0, MAC_SATURATION)
/// wait = dist_busy * rho / (1 - rho)
/// ```
///
/// — the batch's own airtime stretched by the queueing factor
/// `rho/(1-rho)`. At zero background load and a lightly-loaded medium the
/// wait is near zero; as occupancy approaches saturation it blows up,
/// which is exactly the `dist`-phase tail amplification the telemetry
/// alarm watches for. Pure and deterministic: safe for the cluster's
/// byte-identical-at-any-thread-count contract.
pub fn token_wait_cycles(dist_busy: f64, latency: f64, background_load: f64) -> f64 {
    if dist_busy <= 0.0 || latency <= 0.0 {
        return 0.0;
    }
    let rho = (dist_busy / latency + background_load).clamp(0.0, MAC_SATURATION);
    dist_busy * rho / (1.0 - rho)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nop::sim::NodeId;

    fn transfers() -> Vec<Transfer> {
        vec![
            Transfer::unicast(160, NodeId::new(0, 0)),
            Transfer::broadcast(64, 4),
            Transfer::unicast(16, NodeId::new(3, 3)),
        ]
    }

    #[test]
    fn schedule_is_collision_free_and_ordered() {
        let mac = TdmMac::new(16.0);
        let s = mac.compile(&transfers(), false);
        assert!(mac.verify(&s));
        assert_eq!(s.slots.len(), 3);
        assert!(s.makespan >= s.airtime());
    }

    #[test]
    fn guard_charged_only_on_reconfiguration() {
        let mac = TdmMac::new(16.0);
        let a = mac.compile(&transfers(), false);
        let b = mac.compile(&transfers(), true);
        assert_eq!(b.makespan - a.makespan, mac.reconfig_guard_cycles);
        assert_eq!(a.guard_cycles, 0.0);
    }

    #[test]
    fn airtime_matches_payload_over_bw() {
        let mac = TdmMac { bw: 16.0, reconfig_guard_cycles: 0.0, slot_overhead_cycles: 0.0 };
        let s = mac.compile(&transfers(), false);
        let payload: u64 = transfers().iter().map(|t| t.bytes).sum();
        assert!((s.airtime() - payload as f64 / 16.0).abs() < 1e-9);
    }

    #[test]
    fn rx_integral_counts_broadcast_fanout() {
        let mac = TdmMac { bw: 16.0, reconfig_guard_cycles: 0.0, slot_overhead_cycles: 0.0 };
        let s = mac.compile(&transfers(), false);
        // unicast 10cyc x1 + broadcast 4cyc x16 + unicast 1cyc x1.
        assert!((s.rx_cycle_integral() - (10.0 + 64.0 + 1.0)).abs() < 1e-9);
    }

    #[test]
    fn mac_feasible_on_default_channel() {
        let ch = Channel::default();
        assert!(TdmMac::new(16.0).feasible_on(&ch, 0.040, 10.0, 1e-9));
        assert!(TdmMac::new(32.0).feasible_on(&ch, 0.040, 10.0, 1e-9));
    }

    #[test]
    fn full_utilization_without_overhead() {
        let mac = TdmMac { bw: 16.0, reconfig_guard_cycles: 0.0, slot_overhead_cycles: 0.0 };
        let s = mac.compile(&transfers(), false);
        assert!((s.utilization() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn token_wait_is_zero_on_an_idle_medium_and_grows_with_load() {
        assert_eq!(token_wait_cycles(0.0, 100.0, 0.9), 0.0, "no airtime, no wait");
        assert_eq!(token_wait_cycles(10.0, 0.0, 0.9), 0.0, "degenerate latency");
        let w0 = token_wait_cycles(10.0, 100.0, 0.0);
        let w5 = token_wait_cycles(10.0, 100.0, 0.5);
        let w9 = token_wait_cycles(10.0, 100.0, 0.9);
        assert!(w0 > 0.0 && w0 < w5 && w5 < w9, "wait monotone in load: {w0} {w5} {w9}");
        // Self-occupancy alone: rho = 0.1, wait = 10 * 0.1/0.9.
        crate::assert_close!(w0, 10.0 * (0.1 / 0.9));
    }

    #[test]
    fn token_wait_saturates_finite_at_the_clamp() {
        // Past saturation the clamp holds rho at MAC_SATURATION, so the
        // wait stays finite (the serving layer sheds before this regime).
        let w = token_wait_cycles(50.0, 100.0, 2.0);
        crate::assert_close!(w, 50.0 * MAC_SATURATION / (1.0 - MAC_SATURATION));
        assert!(w.is_finite());
    }
}
