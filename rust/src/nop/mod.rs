//! Network-on-Package substrate (S5–S8): interconnect technology models
//! (Table 2), the wireless transceiver scaling model (Fig 1), the
//! analytical mesh-interposer and wireless NoP models, and a cycle-level
//! event-driven mesh simulator used to validate the analytical model.

pub mod channel;
pub mod mac;
pub mod mesh;
pub mod sim;
pub mod technology;
pub mod transceiver;
pub mod wireless;

pub use channel::Channel;
pub use mac::{TdmMac, TdmSchedule};
pub use mesh::MeshNop;
pub use technology::{Technology, TECHNOLOGIES};
pub use transceiver::{Transceiver, TrxDesignPoint};
pub use wireless::WirelessNop;


/// Which NoP performs data *distribution* (SRAM → chiplets). Collection is
/// always on the wired mesh (paper §4: the wireless plane is asymmetric).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum NopKind {
    /// Electrical mesh over the silicon interposer (baseline).
    Interposer,
    /// WIENNA's wireless distribution plane.
    Wireless,
}

impl NopKind {
    pub fn label(&self) -> &'static str {
        match self {
            NopKind::Interposer => "Interposer",
            NopKind::Wireless => "WIENNA",
        }
    }
}

/// Timing/energy of one distribution phase computed by a NoP model.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct DistributionCost {
    /// Cycles to move all *preloaded* (non-streamed) traffic.
    pub preload_cycles: f64,
    /// Cycles to move all *streamed* traffic (overlappable with compute).
    pub stream_cycles: f64,
    /// One-time pipeline-fill latency (hops) in cycles.
    pub fill_latency: f64,
    /// Total distribution energy in picojoules.
    pub energy_pj: f64,
}
