//! Link-level resource model for the mesh simulator.
//!
//! The simulator tracks per-link occupancy with virtual cut-through
//! pipelining: a packet of `S` serialization cycles entering a path of
//! links `l_0..l_h` occupies link `l_i` during `[start + i, start + i + S)`.
//! A link is a unidirectional channel between mesh neighbours (or the SRAM
//! injection port).
//!
//! Perf note (EXPERIMENTS.md §Perf): links are identified by dense
//! indices into flat arrays, not hashed — the simulator's hot loop is
//! `earliest_start`/`commit` over 4–35-link paths, and a HashMap-keyed
//! table cost ~10x the wall time of the dense layout.

use super::packet::NodeId;

/// Unidirectional link identifier.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LinkId {
    /// The single SRAM → mesh injection port (attached at node (0,0)).
    Injection,
    /// Mesh link from `from` towards `to` (must be neighbours).
    Mesh { from: NodeId, to: NodeId },
    /// Drain link from a top-row node into the SRAM edge (collection).
    Drain { col: u32 },
}

impl LinkId {
    /// Dense index within a `side`-wide mesh.
    ///
    /// Layout: `[injection | east(r,c) | west(r,c) | south(r,c) |
    /// north(r,c) | drain(c)]` — directional planes of `side*side` slots
    /// (edge slots unused but keeping the math branch-free).
    pub fn index(&self, side: u32) -> usize {
        let plane = (side * side) as usize;
        match *self {
            LinkId::Injection => 0,
            LinkId::Mesh { from, to } => {
                let base = 1 + (from.row * side + from.col) as usize;
                if to.col == from.col + 1 {
                    base // east
                } else if from.col == to.col + 1 {
                    base + plane // west
                } else if to.row == from.row + 1 {
                    base + 2 * plane // south
                } else {
                    base + 3 * plane // north
                }
            }
            LinkId::Drain { col } => 1 + 4 * plane + col as usize,
        }
    }

    /// Total dense slots for a `side`-wide mesh.
    pub fn table_size(side: u32) -> usize {
        1 + 4 * (side * side) as usize + side as usize
    }
}

/// Per-link occupancy with dense storage.
#[derive(Debug)]
pub struct LinkTable {
    side: u32,
    free_at: Vec<f64>,
    /// Total busy cycles per link, for utilization reporting.
    busy: Vec<f64>,
    /// Total flit-hops moved (bytes x links crossed).
    pub byte_hops: f64,
}

impl LinkTable {
    pub fn new(side: u32) -> Self {
        let n = LinkId::table_size(side);
        LinkTable { side, free_at: vec![0.0; n], busy: vec![0.0; n], byte_hops: 0.0 }
    }

    /// Earliest start time for a cut-through packet over `path` (dense
    /// indices), not before `earliest`: link `i` is entered at `start+i`.
    pub fn earliest_start(&self, path: &[usize], earliest: f64) -> f64 {
        let mut start = earliest;
        for (i, &l) in path.iter().enumerate() {
            let s = self.free_at[l] - i as f64;
            if s > start {
                start = s;
            }
        }
        start
    }

    /// Commit a packet: occupy every link on `path` for `ser` cycles in a
    /// pipelined fashion, moving `bytes` across each. Returns the cycle at
    /// which the tail arrives at the last node.
    pub fn commit(&mut self, path: &[usize], start: f64, ser: f64, bytes: f64) -> f64 {
        for (i, &l) in path.iter().enumerate() {
            let t = start + i as f64;
            self.free_at[l] = t + ser;
            self.busy[l] += ser;
        }
        self.byte_hops += bytes * path.len() as f64;
        start + path.len() as f64 + ser
    }

    /// Resolve a [`LinkId`] path into dense indices.
    pub fn resolve(&self, path: &[LinkId]) -> Vec<usize> {
        path.iter().map(|l| l.index(self.side)).collect()
    }

    /// Peak busy-until time across all links (makespan lower bound).
    pub fn makespan(&self) -> f64 {
        self.free_at.iter().fold(0.0, |a, &b| a.max(b))
    }

    /// Utilization of the busiest link relative to `horizon` cycles.
    pub fn peak_utilization(&self, horizon: f64) -> f64 {
        if horizon <= 0.0 {
            return 0.0;
        }
        self.busy.iter().fold(0.0f64, |a, &b| a.max(b)) / horizon
    }

    pub fn num_links_touched(&self) -> usize {
        self.busy.iter().filter(|&&b| b > 0.0).count()
    }
}

/// Build the XY (column-forwarding) path for one injected copy: from the
/// injection port, east along row 0 to `col`, then south to `max_row`.
pub fn column_path(col: u32, max_row: u32) -> Vec<LinkId> {
    let mut path = vec![LinkId::Injection];
    for c in 0..col {
        path.push(LinkId::Mesh { from: NodeId::new(0, c), to: NodeId::new(0, c + 1) });
    }
    for r in 0..max_row {
        path.push(LinkId::Mesh { from: NodeId::new(r, col), to: NodeId::new(r + 1, col) });
    }
    path
}

/// Dense-index variant of [`column_path`], allocation-conscious: writes
/// into `buf` (cleared first) to avoid per-packet Vec churn.
pub fn column_path_dense(side: u32, col: u32, max_row: u32, buf: &mut Vec<usize>) {
    let plane = (side * side) as usize;
    buf.clear();
    buf.push(0); // injection
    for c in 0..col {
        buf.push(1 + c as usize); // east links of row 0: from (0,c)
    }
    for r in 0..max_row {
        buf.push(1 + 2 * plane + (r * side + col) as usize); // south from (r,col)
    }
}

/// Collection path: from `src` north to row 0, then into the column drain.
pub fn collection_path(src: NodeId) -> Vec<LinkId> {
    let mut path = Vec::new();
    for r in (1..=src.row).rev() {
        path.push(LinkId::Mesh { from: NodeId::new(r, src.col), to: NodeId::new(r - 1, src.col) });
    }
    path.push(LinkId::Drain { col: src.col });
    path
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_indices_unique() {
        let side = 4;
        let mut seen = std::collections::HashSet::new();
        assert!(seen.insert(LinkId::Injection.index(side)));
        for r in 0..side {
            for c in 0..side - 1 {
                assert!(seen.insert(LinkId::Mesh { from: NodeId::new(r, c), to: NodeId::new(r, c + 1) }.index(side)));
                assert!(seen.insert(LinkId::Mesh { from: NodeId::new(r, c + 1), to: NodeId::new(r, c) }.index(side)));
            }
        }
        for r in 0..side - 1 {
            for c in 0..side {
                assert!(seen.insert(LinkId::Mesh { from: NodeId::new(r, c), to: NodeId::new(r + 1, c) }.index(side)));
                assert!(seen.insert(LinkId::Mesh { from: NodeId::new(r + 1, c), to: NodeId::new(r, c) }.index(side)));
            }
        }
        for c in 0..side {
            assert!(seen.insert(LinkId::Drain { col: c }.index(side)));
        }
        assert!(seen.iter().all(|&i| i < LinkId::table_size(side)));
    }

    #[test]
    fn dense_column_path_matches_symbolic() {
        let side = 8;
        let lt = LinkTable::new(side);
        for (col, row) in [(0u32, 0u32), (3, 2), (7, 7)] {
            let symbolic = lt.resolve(&column_path(col, row));
            let mut dense = Vec::new();
            column_path_dense(side, col, row, &mut dense);
            assert_eq!(symbolic, dense, "col {col} row {row}");
        }
    }

    #[test]
    fn column_path_lengths() {
        // col 3, max_row 2: injection + 3 east + 2 south = 6 links.
        assert_eq!(column_path(3, 2).len(), 6);
        assert_eq!(column_path(0, 0), vec![LinkId::Injection]);
    }

    #[test]
    fn cut_through_pipelines_back_to_back() {
        let mut lt = LinkTable::new(4);
        let path = lt.resolve(&column_path(2, 2));
        let s1 = lt.earliest_start(&path, 0.0);
        let e1 = lt.commit(&path, s1, 10.0, 160.0);
        // Tail arrival: start + hops + ser.
        assert_eq!(e1, 0.0 + 5.0 + 10.0);
        // Second packet on the same path starts right after the first
        // clears the injection link, not after full delivery.
        let s2 = lt.earliest_start(&path, 0.0);
        assert_eq!(s2, 10.0);
    }

    #[test]
    fn disjoint_paths_do_not_conflict_after_injection() {
        let mut lt = LinkTable::new(4);
        let p1 = lt.resolve(&column_path(0, 3));
        let p2 = lt.resolve(&column_path(1, 3));
        let s1 = lt.earliest_start(&p1, 0.0);
        lt.commit(&p1, s1, 4.0, 16.0);
        let s2 = lt.earliest_start(&p2, 0.0);
        // Only the shared injection port serializes them.
        assert_eq!(s2, 4.0);
    }

    #[test]
    fn collection_path_goes_north() {
        let p = collection_path(NodeId::new(2, 5));
        assert_eq!(p.len(), 3); // two north hops + drain
        assert!(matches!(p.last(), Some(LinkId::Drain { col: 5 })));
    }

    #[test]
    fn byte_hops_accumulate() {
        let mut lt = LinkTable::new(4);
        let p = lt.resolve(&column_path(2, 1)); // 4 links
        lt.commit(&p, 0.0, 1.0, 10.0);
        assert_eq!(lt.byte_hops, 40.0);
    }
}
