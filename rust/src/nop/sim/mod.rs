//! Cycle-level mesh NoP simulator (substrate S7, validation side).
//!
//! The analytical [`super::MeshNop`] model makes two first-order claims:
//! multicast injection serializes one payload copy per destination column,
//! and pipelined (virtual cut-through) transfers pay hop latency once.
//! This simulator replays the same transfer lists through an explicit
//! `√N_C x √N_C` mesh with per-link occupancy tracking, XY routing and
//! in-column forwarding, so integration tests can bound the analytical
//! model's error instead of trusting it.

pub mod network;
pub mod packet;
pub mod router;

pub use network::{MeshSim, SimReport};
pub use packet::{NodeId, Transfer};
