//! Whole-mesh simulation driver.
//!
//! Replays a transfer list through the link-level model: every transfer is
//! decomposed into one injected copy per destination column (in-column
//! forwarding), copies are scheduled in order through the shared SRAM
//! injection port, and per-link occupancy determines the makespan.

use super::packet::{NodeId, Transfer};
use super::router::{collection_path, LinkTable};

/// Simulation result for one replayed phase.
#[derive(Debug, Clone, Default)]
pub struct SimReport {
    /// Cycle at which the last byte reached its destination.
    pub makespan: f64,
    /// Total bytes x links crossed (proportional to wired energy).
    pub byte_hops: f64,
    /// Number of injected payload copies.
    pub injected_copies: u64,
    /// Busiest-link utilization over the makespan.
    pub peak_link_utilization: f64,
    /// Number of distinct links that carried traffic.
    pub links_touched: usize,
}

/// Cycle-level mesh NoP simulator.
#[derive(Debug, Clone)]
pub struct MeshSim {
    pub side: u32,
    /// Link bandwidth in bytes/cycle.
    pub link_bw: f64,
    /// Packetization granularity in bytes: long transfers are chopped into
    /// packets of at most this size (header overhead is ignored, matching
    /// the analytical model).
    pub max_packet_bytes: u64,
    /// `false` (Table-4 baseline): a multicast is replicated into one
    /// unicast per destination. `true` (ablation, paper §3 "point-to-point
    /// forwarding"): one injected copy per destination column, forwarded
    /// down the column.
    pub multicast_forwarding: bool,
}

impl MeshSim {
    pub fn new(side: u32, link_bw: f64) -> Self {
        MeshSim { side, link_bw, max_packet_bytes: 4096, multicast_forwarding: false }
    }

    /// Destination endpoints one transfer decomposes into (see
    /// `multicast_forwarding`): `(column, deepest row)` per injected copy.
    fn endpoints_for(&self, t: &Transfer) -> Vec<(u32, u32)> {
        if self.multicast_forwarding {
            t.dest_columns().into_iter().map(|col| (col, t.max_row_in_col(col))).collect()
        } else {
            t.dests.iter().map(|d| (d.col, d.row)).collect()
        }
    }

    /// Replay `transfers` through the distribution plane (SRAM →
    /// chiplets) in order.
    pub fn run_distribution(&self, transfers: &[Transfer]) -> SimReport {
        let mut links = LinkTable::new(self.side);
        let mut injected = 0u64;
        let mut makespan: f64 = 0.0;
        let mut path: Vec<usize> = Vec::with_capacity(2 * self.side as usize + 1);
        for t in transfers {
            assert!(!t.dests.is_empty(), "transfer without destinations");
            assert!(t.dests.iter().all(|d| d.col < self.side && d.row < self.side), "destination out of range");
            for (col, row) in self.endpoints_for(t) {
                super::router::column_path_dense(self.side, col, row, &mut path);
                let mut remaining = t.bytes;
                while remaining > 0 {
                    let chunk = remaining.min(self.max_packet_bytes);
                    remaining -= chunk;
                    let ser = chunk as f64 / self.link_bw;
                    let start = links.earliest_start(&path, 0.0);
                    let done = links.commit(&path, start, ser, chunk as f64);
                    makespan = makespan.max(done);
                    injected += 1;
                }
            }
        }
        SimReport {
            makespan,
            byte_hops: links.byte_hops,
            injected_copies: injected,
            peak_link_utilization: links.peak_utilization(makespan),
            links_touched: links.num_links_touched(),
        }
    }

    /// Replay output collection: `bytes_per_chiplet` from every node back
    /// to the SRAM edge drains.
    pub fn run_collection(&self, bytes_per_chiplet: u64) -> SimReport {
        let mut links = LinkTable::new(self.side);
        let mut makespan: f64 = 0.0;
        let mut injected = 0u64;
        for r in 0..self.side {
            for c in 0..self.side {
                let path = links.resolve(&collection_path(NodeId::new(r, c)));
                let mut remaining = bytes_per_chiplet;
                while remaining > 0 {
                    let chunk = remaining.min(self.max_packet_bytes);
                    remaining -= chunk;
                    let ser = chunk as f64 / self.link_bw;
                    let start = links.earliest_start(&path, 0.0);
                    let done = links.commit(&path, start, ser, chunk as f64);
                    makespan = makespan.max(done);
                    injected += 1;
                }
            }
        }
        SimReport {
            makespan,
            byte_hops: links.byte_hops,
            injected_copies: injected,
            peak_link_utilization: links.peak_utilization(makespan),
            links_touched: links.num_links_touched(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unicast_matches_hand_timing() {
        let sim = MeshSim::new(4, 8.0);
        // 64 B to node (1,1): ser 8 cyc, path injection+1E+1S = 3 links.
        let r = sim.run_distribution(&[Transfer::unicast(64, NodeId::new(1, 1))]);
        assert_eq!(r.makespan, 8.0 + 3.0);
        assert_eq!(r.injected_copies, 1);
    }

    #[test]
    fn broadcast_replicates_per_destination() {
        let sim = MeshSim::new(4, 8.0);
        let r = sim.run_distribution(&[Transfer::broadcast(64, 4)]);
        // No multicast hw: 16 unicast copies, 8 cyc each through the
        // shared injection port.
        assert_eq!(r.injected_copies, 16);
        assert!(r.makespan >= 128.0);
        assert!(r.makespan <= 128.0 + 8.0);
    }

    #[test]
    fn forwarding_ablation_injects_one_copy_per_column() {
        let mut sim = MeshSim::new(4, 8.0);
        sim.multicast_forwarding = true;
        let r = sim.run_distribution(&[Transfer::broadcast(64, 4)]);
        assert_eq!(r.injected_copies, 4);
        // Serialization dominates: 4 copies x 8 cyc through the shared
        // injection port, plus pipeline depth of the longest path.
        assert!(r.makespan >= 32.0);
        assert!(r.makespan <= 32.0 + 8.0);
    }

    #[test]
    fn back_to_back_stream_pipelines() {
        let sim = MeshSim::new(4, 8.0);
        // 100 unicasts of 8 B to the far corner: 1 cyc ser each, path 7
        // links; steady state should be ~1 cycle/packet.
        let ts: Vec<Transfer> = (0..100).map(|_| Transfer::unicast(8, NodeId::new(3, 3))).collect();
        let r = sim.run_distribution(&ts);
        assert!(r.makespan < 100.0 + 16.0, "makespan {}", r.makespan);
    }

    #[test]
    fn packetization_splits_long_transfers() {
        let sim = MeshSim { side: 4, link_bw: 8.0, max_packet_bytes: 16, multicast_forwarding: false };
        let r = sim.run_distribution(&[Transfer::unicast(64, NodeId::new(0, 1))]);
        assert_eq!(r.injected_copies, 4);
    }

    #[test]
    fn collection_drains_all_columns_in_parallel() {
        let sim = MeshSim::new(4, 8.0);
        let r = sim.run_collection(64);
        // 4 chiplets per column, 8 cyc each, columns drain independently:
        // ~32 cycles + pipeline depth.
        assert!(r.makespan >= 32.0);
        assert!(r.makespan < 48.0, "makespan {}", r.makespan);
        assert_eq!(r.injected_copies, 16);
    }

    #[test]
    fn byte_hops_track_path_lengths() {
        let sim = MeshSim::new(4, 8.0);
        let r = sim.run_distribution(&[Transfer::unicast(10, NodeId::new(2, 3))]);
        // Path: injection + 3E + 2S = 6 links x 10 B.
        assert_eq!(r.byte_hops, 60.0);
    }
}
