//! Packets and transfers for the cycle-level mesh simulator.


/// A mesh node, addressed by `(row, col)` in a `side x side` grid.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct NodeId {
    pub row: u32,
    pub col: u32,
}

impl NodeId {
    pub fn new(row: u32, col: u32) -> Self {
        NodeId { row, col }
    }

    /// Linear index within a `side`-wide mesh.
    pub fn index(&self, side: u32) -> usize {
        (self.row * side + self.col) as usize
    }

    /// XY-routing hop count from `self` to `other`.
    pub fn hops_to(&self, other: NodeId) -> u32 {
        self.col.abs_diff(other.col) + self.row.abs_diff(other.row)
    }
}

/// One logical transfer from the global SRAM to a set of chiplets.
#[derive(Debug, Clone)]
pub struct Transfer {
    /// Unique payload bytes.
    pub bytes: u64,
    /// Destination chiplets. An empty list is invalid.
    pub dests: Vec<NodeId>,
}

impl Transfer {
    pub fn unicast(bytes: u64, dest: NodeId) -> Self {
        Transfer { bytes, dests: vec![dest] }
    }

    /// Broadcast to every node of a `side x side` mesh.
    pub fn broadcast(bytes: u64, side: u32) -> Self {
        let dests = (0..side).flat_map(|r| (0..side).map(move |c| NodeId::new(r, c))).collect();
        Transfer { bytes, dests }
    }

    /// Multicast to the first `n` nodes in row-major order.
    pub fn multicast_first_n(bytes: u64, side: u32, n: u32) -> Self {
        let dests = (0..n.min(side * side)).map(|i| NodeId::new(i / side, i % side)).collect();
        Transfer { bytes, dests }
    }

    /// Destination columns, deduplicated and sorted. One payload copy is
    /// injected per column (in-column replicas are forwarded).
    pub fn dest_columns(&self) -> Vec<u32> {
        let mut cols: Vec<u32> = self.dests.iter().map(|d| d.col).collect();
        cols.sort_unstable();
        cols.dedup();
        cols
    }

    /// Deepest destination row within `col`.
    pub fn max_row_in_col(&self, col: u32) -> u32 {
        self.dests.iter().filter(|d| d.col == col).map(|d| d.row).max().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hops_are_manhattan() {
        assert_eq!(NodeId::new(0, 0).hops_to(NodeId::new(3, 4)), 7);
        assert_eq!(NodeId::new(2, 2).hops_to(NodeId::new(2, 2)), 0);
    }

    #[test]
    fn broadcast_covers_mesh() {
        let t = Transfer::broadcast(64, 4);
        assert_eq!(t.dests.len(), 16);
        assert_eq!(t.dest_columns(), vec![0, 1, 2, 3]);
        assert_eq!(t.max_row_in_col(2), 3);
    }

    #[test]
    fn multicast_prefix() {
        let t = Transfer::multicast_first_n(8, 4, 6);
        assert_eq!(t.dests.len(), 6);
        // Rows 0 (cols 0-3) and row 1 (cols 0-1).
        assert_eq!(t.max_row_in_col(0), 1);
        assert_eq!(t.max_row_in_col(3), 0);
    }
}
