//! Analytical mesh-interposer NoP model (baseline, substrate S7).
//!
//! The baseline 2.5D accelerator distributes *and* collects over an
//! electrical mesh on the silicon interposer (Table 4); WIENNA uses the
//! mesh for collection only.
//!
//! # Distribution model
//!
//! The mesh has **no hardware multicast** (Table 4). A transfer to `d`
//! destinations is performed as `d` replicated unicasts, all serialized
//! through the global-SRAM injection port at the per-link bandwidth —
//! this is the bandwidth amplification that makes broadcasts the paper's
//! §3 Achilles heel. Each (pipelined) transfer additionally pays a
//! one-time fill latency of the average hop count `√N_C / 2` plus the
//! forwarding depth.
//!
//! An ablation mode (`tree_multicast`) grants the mesh path-based
//! in-column forwarding ("broadcast via point-to-point forwarding", §3),
//! which caps injection copies at one per destination column,
//! `min(d, √N_C)` — used to quantify how much of WIENNA's win survives a
//! smarter electrical baseline (see `benches/` ablations).
//!
//! # Energy model
//!
//! Following the paper's §5.1 method — "the average number of hops
//! multiplied by the per-hop energy" — every *delivered* copy of a byte is
//! charged `avg_hops x E_hop` per bit, i.e.
//! `E = bytes · 8 · d · (√N_C / 2) · E_hop`.
//!
//! # Collection model
//!
//! Output collection converges onto the global SRAM chiplet's mesh links;
//! its `√N_C`-column edge gives an aggregate drain bandwidth of
//! `√N_C x` the link bandwidth (writes are spread over columns and can be
//! hidden behind compute, paper §2).

use super::technology::interposer_hop_energy_pj;
use super::DistributionCost;
use crate::dataflow::TrafficClass;

/// Analytical model of the wired mesh NoP.
#[derive(Debug, Clone)]
pub struct MeshNop {
    /// Chiplet count (mesh is √N_C x √N_C).
    pub num_chiplets: u64,
    /// Per-link bandwidth in bytes/cycle (Table 4: 8 conservative,
    /// 16 aggressive).
    pub link_bw: f64,
    /// Per-hop link energy in pJ/bit.
    pub hop_energy_pj: f64,
    /// Ablation switch: `false` (Table-4 baseline, default) replicates a
    /// multicast into one unicast per destination, all serialized at the
    /// SRAM injection port; `true` grants the mesh path-based in-column
    /// forwarding, capping injection copies at one per destination column.
    pub tree_multicast: bool,
}

impl MeshNop {
    pub fn new(num_chiplets: u64, link_bw: f64, aggressive: bool) -> Self {
        MeshNop {
            num_chiplets,
            link_bw,
            hop_energy_pj: interposer_hop_energy_pj(aggressive),
            tree_multicast: false,
        }
    }

    /// Mesh side length.
    pub fn side(&self) -> f64 {
        (self.num_chiplets as f64).sqrt()
    }

    /// Average unicast hop count, `√N_C / 2` (Table 4).
    pub fn avg_hops(&self) -> f64 {
        self.side() / 2.0
    }

    /// Injection-port copies required for a transfer with `d` average
    /// destinations. The Table-4 baseline has no multicast capability, so
    /// a `d`-destination transfer is `d` replicated unicasts through the
    /// SRAM port; the `tree_multicast` ablation forwards in-column
    /// replicas point-to-point, needing only one copy per column.
    pub fn injection_copies(&self, avg_dests: f64) -> f64 {
        if self.tree_multicast {
            avg_dests.min(self.side()).max(1.0)
        } else {
            avg_dests.max(1.0)
        }
    }

    /// Serialization cycles to push one traffic class through the SRAM
    /// injection port.
    fn class_cycles(&self, t: &TrafficClass) -> f64 {
        t.bytes as f64 * self.injection_copies(t.avg_dests) / self.link_bw
    }

    /// Energy (pJ) to deliver one traffic class.
    ///
    /// Baseline (§5.1 method): every delivered copy travels the average
    /// hop count, `bytes·8·d·(√N_C/2)·E_hop`. Under the `tree_multicast`
    /// ablation the payload crosses a spanning tree instead — roughly the
    /// average hop count to reach the destination region plus one link
    /// per additional destination.
    fn class_energy_pj(&self, t: &TrafficClass) -> f64 {
        if self.tree_multicast {
            let links = self.avg_hops() + (t.avg_dests - 1.0).max(0.0);
            t.bytes as f64 * 8.0 * links * self.hop_energy_pj
        } else {
            t.delivered_bytes() * 8.0 * self.avg_hops() * self.hop_energy_pj
        }
    }

    /// Distribution cost of a set of traffic classes.
    pub fn distribution(&self, traffic: &[TrafficClass]) -> DistributionCost {
        let mut c = DistributionCost::default();
        for t in traffic {
            let cycles = self.class_cycles(t);
            if t.streamed {
                c.stream_cycles += cycles;
            } else {
                c.preload_cycles += cycles;
            }
            c.energy_pj += self.class_energy_pj(t);
        }
        // Pipeline fill: average hops to the first destination plus the
        // in-column forwarding depth for multicasts.
        let max_fanout = traffic.iter().map(|t| t.avg_dests).fold(1.0, f64::max);
        let col_depth = (max_fanout / self.side()).min(self.side()).max(1.0);
        c.fill_latency = self.avg_hops() + col_depth;
        c
    }

    /// Collection cycles for `bytes` of outputs converging on the SRAM
    /// edge (aggregate `√N_C` links).
    pub fn collection_cycles(&self, bytes: u64) -> f64 {
        bytes as f64 / (self.link_bw * self.side())
    }

    /// Collection energy: outputs travel the average hop count once.
    pub fn collection_energy_pj(&self, bytes: u64) -> f64 {
        bytes as f64 * 8.0 * self.avg_hops() * self.hop_energy_pj
    }

    /// Per-sent-bit energy of a `d`-destination multicast (Fig 4's
    /// mesh curve): replicated unicasts, each travelling `avg_hops`
    /// links, or a spanning tree under the `tree_multicast` ablation.
    pub fn multicast_pj_per_sent_bit(&self, dests: f64) -> f64 {
        if self.tree_multicast {
            (self.avg_hops() + (dests - 1.0).max(0.0)) * self.hop_energy_pj
        } else {
            dests * self.avg_hops() * self.hop_energy_pj
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::{TensorKind, TrafficClass};

    fn class(bytes: u64, dests: f64, streamed: bool) -> TrafficClass {
        TrafficClass { tensor: TensorKind::Input, bytes, avg_dests: dests, streamed }
    }

    #[test]
    fn unicast_is_bandwidth_bound() {
        let m = MeshNop::new(256, 16.0, true);
        let c = m.distribution(&[class(1600, 1.0, true)]);
        assert!((c.stream_cycles - 100.0).abs() < 1e-9);
        assert_eq!(c.preload_cycles, 0.0);
    }

    #[test]
    fn broadcast_amplifies_by_destinations() {
        let m = MeshNop::new(256, 16.0, true);
        // 256-dest broadcast with no multicast hw: 256 replicated
        // unicasts through the injection port.
        let c = m.distribution(&[class(1600, 256.0, true)]);
        assert!((c.stream_cycles - 1600.0 * 256.0 / 16.0).abs() < 1e-9);
    }

    #[test]
    fn tree_multicast_ablation_caps_at_mesh_side() {
        let mut m = MeshNop::new(256, 16.0, true);
        m.tree_multicast = true;
        let c = m.distribution(&[class(1600, 256.0, true)]);
        // One copy per column: x16 instead of x256.
        assert!((c.stream_cycles - 1600.0).abs() < 1e-9);
    }

    #[test]
    fn energy_counts_every_copy_and_hop() {
        let m = MeshNop::new(256, 16.0, true);
        let c = m.distribution(&[class(100, 256.0, false)]);
        // 100 B * 256 dests * 8 bit * 8 hops * 0.82 pJ.
        let expect = 100.0 * 256.0 * 8.0 * 8.0 * 0.82;
        assert!((c.energy_pj - expect).abs() < 1e-6);
    }

    #[test]
    fn conservative_link_is_pricier() {
        let c = MeshNop::new(256, 8.0, false);
        let a = MeshNop::new(256, 16.0, true);
        assert!(c.hop_energy_pj > a.hop_energy_pj);
    }

    #[test]
    fn collection_uses_edge_aggregate() {
        let m = MeshNop::new(256, 8.0, false);
        // 16 links * 8 B/cyc = 128 B/cyc drain.
        assert!((m.collection_cycles(1280) - 10.0).abs() < 1e-9);
    }

    #[test]
    fn fill_latency_reasonable() {
        let m = MeshNop::new(256, 8.0, false);
        let c = m.distribution(&[class(16, 1.0, true)]);
        assert!(c.fill_latency >= m.avg_hops());
        assert!(c.fill_latency <= 2.0 * m.side());
    }
}
