//! In-package wireless channel model (substrate S8, physical layer).
//!
//! The paper (§2, citing Timoneda et al. [25]) reports that an engineered
//! package channel keeps system-wide attenuation below 30 dB, compatible
//! with the 65-nm TRX of [27] (48 Gb/s at 25 mm, BER < 1e-12). This module
//! models that link budget: TSV-monopole antennas, log-distance path loss
//! inside the package medium, and the resulting achievable datarate /
//! required TX power per (distance, BER) point.
//!
//! It exists so the MAC layer (`nop/mac.rs`) can verify that a TDM
//! schedule's rate assignments are actually feasible at the package
//! geometry — the analytical models above it assume the Table-4 rates,
//! and this closes the loop.

/// Speed of light in m/s.
const C0: f64 = 2.998e8;

/// Package channel parameters (engineered medium, [25]-style).
#[derive(Debug, Clone)]
pub struct Channel {
    /// Carrier frequency in Hz (60 GHz mm-wave band).
    pub carrier_hz: f64,
    /// Path-loss exponent of the enclosed package medium. Free space is
    /// 2.0; an *engineered* intra-package channel ([25]: tuned lid and
    /// dielectric) behaves nearly waveguide-like, ≈1.0–1.4.
    pub path_loss_exp: f64,
    /// Additional fixed losses (antenna mismatch, dielectric) in dB.
    pub fixed_loss_db: f64,
    /// Receiver noise figure in dB.
    pub noise_figure_db: f64,
    /// Signal bandwidth in Hz available to the NoP.
    pub bandwidth_hz: f64,
}

impl Default for Channel {
    fn default() -> Self {
        Channel {
            carrier_hz: 60e9,
            path_loss_exp: 1.0,
            fixed_loss_db: 4.0,
            noise_figure_db: 8.0,
            bandwidth_hz: 20e9,
        }
    }
}

/// Thermal noise floor in dBm for a given bandwidth.
fn noise_floor_dbm(bandwidth_hz: f64, noise_figure_db: f64) -> f64 {
    -174.0 + 10.0 * bandwidth_hz.log10() + noise_figure_db
}

/// SNR (dB) needed for a given BER under non-coherent OOK-class
/// modulation: BER = 0.5 * exp(-SNR/2)  =>  SNR = -2 ln(2 BER).
pub fn required_snr_db(ber: f64) -> f64 {
    assert!(ber > 0.0 && ber < 0.5);
    let snr_lin = -2.0 * (2.0 * ber).ln();
    10.0 * snr_lin.log10()
}

impl Channel {
    /// Free-space-reference path loss at `distance_m`, in dB.
    pub fn path_loss_db(&self, distance_m: f64) -> f64 {
        assert!(distance_m > 0.0);
        let lambda = C0 / self.carrier_hz;
        let ref_loss = 20.0 * (4.0 * std::f64::consts::PI * 0.001 / lambda).log10(); // at 1 mm
        ref_loss + 10.0 * self.path_loss_exp * (distance_m / 0.001).log10() + self.fixed_loss_db
    }

    /// Worst-case attenuation across a package of the given diagonal (m).
    pub fn worst_case_attenuation_db(&self, package_diag_m: f64) -> f64 {
        self.path_loss_db(package_diag_m)
    }

    /// Required TX power (dBm) to reach `distance_m` at `ber`.
    pub fn required_tx_power_dbm(&self, distance_m: f64, ber: f64) -> f64 {
        noise_floor_dbm(self.bandwidth_hz, self.noise_figure_db) + required_snr_db(ber) + self.path_loss_db(distance_m)
    }

    /// Shannon-bound achievable rate (bit/s) at `distance_m` for a TX
    /// power of `tx_dbm`.
    pub fn achievable_rate_bps(&self, distance_m: f64, tx_dbm: f64) -> f64 {
        let snr_db = tx_dbm - self.path_loss_db(distance_m) - noise_floor_dbm(self.bandwidth_hz, self.noise_figure_db);
        let snr = 10f64.powf(snr_db / 10.0);
        self.bandwidth_hz * (1.0 + snr).log2()
    }

    /// Feasibility check used by the MAC layer: can `gbps` be sustained
    /// across `distance_m` with `tx_dbm` of TX power at `ber`?
    pub fn supports(&self, gbps: f64, distance_m: f64, tx_dbm: f64, ber: f64) -> bool {
        let rate_ok = self.achievable_rate_bps(distance_m, tx_dbm) >= gbps * 1e9;
        let power_ok = tx_dbm >= self.required_tx_power_dbm(distance_m, ber) - 1e-9;
        rate_ok && power_ok
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn attenuation_below_30db_at_package_scale() {
        // [25]: system-wide attenuation below 30 dB is achievable; our
        // defaults must land under that for a 40 mm package diagonal.
        let ch = Channel::default();
        let att = ch.worst_case_attenuation_db(0.040);
        assert!(att < 30.0, "attenuation {att:.1} dB");
        assert!(att > 10.0, "suspiciously low attenuation {att:.1} dB");
    }

    #[test]
    fn path_loss_monotone_in_distance() {
        let ch = Channel::default();
        assert!(ch.path_loss_db(0.040) > ch.path_loss_db(0.010));
        assert!(ch.path_loss_db(0.010) > ch.path_loss_db(0.001));
    }

    #[test]
    fn lower_ber_needs_more_snr() {
        assert!(required_snr_db(1e-12) > required_snr_db(1e-9));
        // OOK-class: 1e-9 needs ~16 dB, 1e-12 ~17.3 dB.
        let s9 = required_snr_db(1e-9);
        assert!(s9 > 12.0 && s9 < 20.0, "{s9}");
    }

    #[test]
    fn table4_rates_feasible_at_modest_power() {
        // The Table-4 WIENNA rates (64 / 128 Gb/s) must be feasible across
        // the 40 mm package with a TX power consistent with the Fig-1
        // power budget (~10 dBm radiated is the right order for 100+ mW
        // transceivers).
        let ch = Channel::default();
        assert!(ch.supports(64.0, 0.040, 10.0, 1e-9), "64 Gb/s infeasible");
        assert!(ch.supports(128.0, 0.040, 10.0, 1e-9), "128 Gb/s infeasible");
    }

    #[test]
    fn absurd_rates_rejected() {
        let ch = Channel::default();
        // >> bandwidth * log2(1+SNR) at any sane power.
        assert!(!ch.supports(10_000.0, 0.040, 10.0, 1e-9));
    }

    #[test]
    fn rate_decreases_with_distance() {
        let ch = Channel::default();
        let near = ch.achievable_rate_bps(0.005, 5.0);
        let far = ch.achievable_rate_bps(0.040, 5.0);
        assert!(near > far);
    }
}
