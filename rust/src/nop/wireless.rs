//! Analytical wireless NoP model (WIENNA's distribution plane, S8).
//!
//! A single transmitter at the global SRAM chiplet and one receiver per
//! accelerator chiplet (paper §4): the plane is *asymmetric* — it only
//! distributes. There are no collisions (one TX), so medium access is a
//! statically scheduled TDM sequence and flow control is trivial; every
//! transfer reaches all of its destinations in a single hop.
//!
//! * A **unicast** keeps one RX active; all other receivers are
//!   power-gated for the duration of the transfer.
//! * A **broadcast/multicast** activates the destination set; the payload
//!   is transmitted exactly once regardless of fan-out — this is the
//!   bandwidth-amplification WIENNA's dataflow co-design exploits.

use super::transceiver::TrxDesignPoint;
use super::DistributionCost;
use crate::dataflow::TrafficClass;

/// Analytical model of the wireless distribution plane.
#[derive(Debug, Clone)]
pub struct WirelessNop {
    /// Air datarate in bytes/cycle (Table 4: 16 conservative,
    /// 32 aggressive).
    pub bw: f64,
    /// Transceiver efficiency design point (Fig 1 scatter end).
    pub trx: TrxDesignPoint,
    /// Target bit-error rate (energy is scaled from the 1e-9 reference).
    pub ber: f64,
}

impl WirelessNop {
    pub fn new(bw: f64, trx: TrxDesignPoint) -> Self {
        WirelessNop { bw, trx, ber: 1e-9 }
    }

    /// Energy (pJ) for one traffic class: one TX burst for the unique
    /// payload plus RX energy per active destination.
    fn class_energy_pj(&self, t: &TrafficClass) -> f64 {
        let bits = t.bytes as f64 * 8.0;
        let scale = TrxDesignPoint::ber_scale(self.ber);
        bits * self.trx.multicast_pj_per_bit(t.avg_dests) * scale
    }

    /// Distribution cost of a set of traffic classes: pure serialization
    /// of unique payload bytes at the air rate, single-hop latency.
    pub fn distribution(&self, traffic: &[TrafficClass]) -> DistributionCost {
        let mut c = DistributionCost::default();
        for t in traffic {
            let cycles = t.bytes as f64 / self.bw;
            if t.streamed {
                c.stream_cycles += cycles;
            } else {
                c.preload_cycles += cycles;
            }
            c.energy_pj += self.class_energy_pj(t);
        }
        c.fill_latency = 1.0; // single hop
        c
    }

    /// Per-sent-bit energy of a `d`-destination multicast (Fig 4's
    /// wireless curve).
    pub fn multicast_pj_per_sent_bit(&self, dests: f64) -> f64 {
        self.trx.multicast_pj_per_bit(dests) * TrxDesignPoint::ber_scale(self.ber)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::{TensorKind, TrafficClass};

    fn class(bytes: u64, dests: f64, streamed: bool) -> TrafficClass {
        TrafficClass { tensor: TensorKind::Input, bytes, avg_dests: dests, streamed }
    }

    #[test]
    fn broadcast_costs_one_transmission() {
        let w = WirelessNop::new(16.0, TrxDesignPoint::Conservative);
        let uni = w.distribution(&[class(1600, 1.0, true)]);
        let bcast = w.distribution(&[class(1600, 256.0, true)]);
        // Same serialization time regardless of fan-out.
        assert_eq!(uni.stream_cycles, bcast.stream_cycles);
        assert!((uni.stream_cycles - 100.0).abs() < 1e-9);
        // But energy grows with the number of active receivers.
        assert!(bcast.energy_pj > uni.energy_pj);
    }

    #[test]
    fn unicast_energy_matches_table2() {
        let w = WirelessNop::new(16.0, TrxDesignPoint::Conservative);
        // 4.01 pJ/bit for TX + 1 RX.
        assert!((w.multicast_pj_per_sent_bit(1.0) - 4.01).abs() < 1e-9);
    }

    #[test]
    fn broadcast_energy_asymptote() {
        let w = WirelessNop::new(16.0, TrxDesignPoint::Conservative);
        // ~1.4 pJ/bit per destination at high fan-out (Table 2).
        let per_dest = w.multicast_pj_per_sent_bit(1024.0) / 1024.0;
        assert!((per_dest - 1.4).abs() < 0.01);
    }

    #[test]
    fn ber_increases_energy() {
        let mut w = WirelessNop::new(16.0, TrxDesignPoint::Aggressive);
        let e9 = w.multicast_pj_per_sent_bit(16.0);
        w.ber = 1e-12;
        let e12 = w.multicast_pj_per_sent_bit(16.0);
        assert!((e12 / e9 - 12.0 / 9.0).abs() < 1e-9);
    }

    #[test]
    fn single_hop_fill() {
        let w = WirelessNop::new(32.0, TrxDesignPoint::Aggressive);
        assert_eq!(w.distribution(&[class(32, 8.0, false)]).fill_latency, 1.0);
    }
}
