//! Wireless transceiver scaling model (paper Fig 1, substrate S6).
//!
//! Fig 1 surveys 70+ short-range mm-wave transceivers and shows area and
//! power growing with datarate. We model both as power laws anchored at
//! the published design points:
//!
//! * the 65-nm TRX of Yu et al. [27]: 48 Gb/s, 1.95 pJ/bit
//!   (=> 93.6 mW) and 0.8 mm² at 25 mm range, BER 1e-12;
//! * the paper's Table 2 "wireless (unicast)" row: 4.01 pJ/bit as the
//!   conservative end of the survey scatter;
//! * the paper's Table 3 instance: RX 1 mm² / 90 mW and TX 2 mm² / 167 mW
//!   at the 256-chiplet design bandwidths.
//!
//! Energy is split between TX and RX; the paper notes Fig 1 assumes a
//! 50/50 TX/RX split but that the split is a design choice. We adopt the
//! asymmetric split implied by Table 2's broadcast row (`1.4·N_C` pJ/bit
//! ⇒ RX ≈ 1.4 pJ/bit conservative), which matches WIENNA's single-TX /
//! many-RX plane. BER scaling follows the paper's normalization of power
//! to a 1e-9 error rate: required energy grows with the exponent of the
//! target error rate.


/// Reference BER all Fig-1 power numbers are normalized to.
pub const REFERENCE_BER_EXP: f64 = 9.0; // BER = 1e-9

/// Conservative / aggressive ends of the Fig-1 survey scatter at a given
/// datarate (paper §5.1 selects one of each for the energy evaluation).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TrxDesignPoint {
    /// Worse end of the scatter: 4.01 pJ/bit unicast (Table 2).
    Conservative,
    /// Best-in-class 65-nm TRX [27]: 1.95 pJ/bit unicast.
    Aggressive,
}

impl TrxDesignPoint {
    /// Total unicast energy per bit (TX + one RX) at the reference BER.
    pub fn unicast_pj_per_bit(&self) -> f64 {
        match self {
            TrxDesignPoint::Conservative => 4.01,
            TrxDesignPoint::Aggressive => 1.95,
        }
    }

    /// RX share of the unicast energy. Anchored so that the conservative
    /// broadcast energy reproduces Table 2's `1.4·N_C` pJ/bit asymptote.
    pub fn rx_pj_per_bit(&self) -> f64 {
        match self {
            TrxDesignPoint::Conservative => 1.4,
            // Same RX fraction (≈ 34.9%) applied to the aggressive point.
            TrxDesignPoint::Aggressive => 0.68,
        }
    }

    /// TX energy per bit (the remainder of the unicast energy).
    pub fn tx_pj_per_bit(&self) -> f64 {
        self.unicast_pj_per_bit() - self.rx_pj_per_bit()
    }

    /// Energy per *transmitted* bit of a multicast to `dests` receivers:
    /// one TX burst plus `dests` active receivers; idle receivers are
    /// power-gated (paper §5.1).
    pub fn multicast_pj_per_bit(&self, dests: f64) -> f64 {
        self.tx_pj_per_bit() + dests * self.rx_pj_per_bit()
    }

    /// Scale an energy figure from the reference BER (1e-9) to `ber`.
    ///
    /// Lower target error rates need proportionally more link budget:
    /// `E(ber) = E_ref * (-log10(ber) / 9)`.
    pub fn ber_scale(ber: f64) -> f64 {
        assert!(ber > 0.0 && ber < 1.0);
        (-ber.log10()) / REFERENCE_BER_EXP
    }
}

/// Power-law fit of the Fig-1 survey: `area = a·r^b`, `power = c·r^d`
/// with `r` in Gb/s.
#[derive(Debug, Clone, Copy)]
pub struct Transceiver {
    /// Area prefactor (mm²) and exponent.
    pub area_a: f64,
    pub area_b: f64,
    /// Power prefactor (mW) and exponent.
    pub power_c: f64,
    pub power_d: f64,
}

impl Default for Transceiver {
    /// Fit anchored at [27] (48 Gb/s → 0.8 mm², 93.6 mW) with mildly
    /// super-linear power (interconnect survey trend: energy/bit degrades
    /// slowly as datarate rises) and sub-linear area scaling.
    fn default() -> Self {
        // area(48) = 0.8 with b = 0.55  => a = 0.8 / 48^0.55
        // power(48) = 93.6 with d = 1.15 => c = 93.6 / 48^1.15
        Transceiver {
            area_a: 0.8 / 48f64.powf(0.55),
            area_b: 0.55,
            power_c: 93.6 / 48f64.powf(1.15),
            power_d: 1.15,
        }
    }
}

impl Transceiver {
    /// TRX area in mm² at `gbps`.
    pub fn area_mm2(&self, gbps: f64) -> f64 {
        self.area_a * gbps.powf(self.area_b)
    }

    /// TRX power in mW at `gbps` and the given bit-error rate.
    pub fn power_mw(&self, gbps: f64, ber: f64) -> f64 {
        self.power_c * gbps.powf(self.power_d) * TrxDesignPoint::ber_scale(ber)
    }

    /// Energy per bit in pJ at `gbps` / `ber`.
    pub fn pj_per_bit(&self, gbps: f64, ber: f64) -> f64 {
        self.power_mw(gbps, ber) / gbps // mW / Gbps == pJ/bit
    }
}

/// Datarate (Gb/s) needed to sustain `bytes_per_cycle` at `clock_hz`.
pub fn required_gbps(bytes_per_cycle: f64, clock_hz: f64) -> f64 {
    bytes_per_cycle * 8.0 * clock_hz / 1e9
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::assert_close;

    #[test]
    fn anchored_at_yu2014() {
        let t = Transceiver::default();
        assert_close!(t.area_mm2(48.0), 0.8);
        assert_close!(t.power_mw(48.0, 1e-9), 93.6);
        assert_close!(t.pj_per_bit(48.0, 1e-9), 1.95);
    }

    #[test]
    fn scaling_is_monotonic() {
        let t = Transceiver::default();
        assert!(t.area_mm2(100.0) > t.area_mm2(10.0));
        assert!(t.power_mw(100.0, 1e-9) > t.power_mw(10.0, 1e-9));
        // Energy/bit degrades mildly with datarate (super-linear power).
        assert!(t.pj_per_bit(100.0, 1e-9) > t.pj_per_bit(10.0, 1e-9));
    }

    #[test]
    fn ber_scaling() {
        // 1e-12 needs 12/9 the energy of 1e-9.
        assert_close!(TrxDesignPoint::ber_scale(1e-12), 12.0 / 9.0);
        assert_close!(TrxDesignPoint::ber_scale(1e-9), 1.0);
    }

    #[test]
    fn design_point_split_reproduces_table2() {
        let c = TrxDesignPoint::Conservative;
        assert_close!(c.tx_pj_per_bit() + c.rx_pj_per_bit(), 4.01);
        // Broadcast asymptote 1.4*Nc.
        let n = 1024.0;
        assert!((c.multicast_pj_per_bit(n) / n - 1.4).abs() < 0.01);
        let a = TrxDesignPoint::Aggressive;
        assert_close!(a.tx_pj_per_bit() + a.rx_pj_per_bit(), 1.95);
    }

    #[test]
    fn required_gbps_at_table4_bandwidths() {
        // 16 B/cyc @ 500 MHz = 64 Gb/s (WIENNA-C), 32 B/cyc = 128 Gb/s.
        assert_close!(required_gbps(16.0, 500e6), 64.0);
        assert_close!(required_gbps(32.0, 500e6), 128.0);
    }

    #[test]
    fn table3_rx_area_ballpark() {
        // Table 3 lists the RX at ~1 mm² for the 64 Gb/s conservative
        // bandwidth; the fit should land in that ballpark (an RX is ~half
        // a TRX; full TRX at 64 Gb/s ≈ 0.94 mm²).
        let t = Transceiver::default();
        let trx = t.area_mm2(64.0);
        assert!(trx > 0.5 && trx < 2.0, "got {trx}");
    }
}
