//! 2.5D interconnect technology comparison (paper Table 2, substrate S5).
//!
//! Bandwidth density, per-bit energy, link length and hop scaling for the
//! six technologies the paper tabulates. The wireless rows are derived
//! from the transceiver survey (Fig 1 / [`super::transceiver`]); `N_C`
//! denotes the chiplet count, so those entries are functions, not
//! constants.


/// Hop-count scaling class of a technology.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HopScaling {
    /// Grows with the mesh diameter, `O(√N_C)`.
    SqrtChiplets,
    /// Single hop regardless of chiplet count.
    One,
}

/// One row of Table 2.
#[derive(Debug, Clone)]
pub struct Technology {
    pub name: &'static str,
    /// Process node in nm.
    pub node_nm: u32,
    /// Bandwidth density in Gbps/mm at the chiplet edge; for the wireless
    /// broadcast row this is the *effective* density `64·√N_C` (delivered
    /// bits across all receivers per transmitted bit).
    pub bw_density_gbps_mm: fn(n_chiplets: f64) -> f64,
    /// Energy per bit in pJ; for wireless broadcast this is `1.4·N_C`
    /// (every active receiver burns RX energy).
    pub energy_pj_per_bit: fn(n_chiplets: f64) -> f64,
    /// Maximum/typical link length in mm (`None` where the paper lists N/A).
    pub link_length_mm: Option<f64>,
    pub hops: HopScaling,
}

impl Technology {
    /// Average hop count for a package of `n` chiplets.
    pub fn avg_hops(&self, n: f64) -> f64 {
        match self.hops {
            HopScaling::SqrtChiplets => n.sqrt() / 2.0,
            HopScaling::One => 1.0,
        }
    }
}

/// Table 2, row by row.
pub const TECHNOLOGIES: &[Technology] = &[
    Technology {
        name: "Silicon Interposer [8]",
        node_nm: 45,
        bw_density_gbps_mm: |_| 450.0,
        energy_pj_per_bit: |_| 5.3,
        link_length_mm: Some(40.0),
        hops: HopScaling::SqrtChiplets,
    },
    Technology {
        name: "Silicon Interposer [22]",
        node_nm: 16,
        bw_density_gbps_mm: |_| 80.0,
        // Simba reports 0.82-1.75 pJ/bit; midpoint used where a scalar is
        // needed, the range is kept by the energy model's design points.
        energy_pj_per_bit: |_| 1.285,
        link_length_mm: Some(6.5),
        hops: HopScaling::SqrtChiplets,
    },
    Technology {
        name: "EMIB (AIB) [14]",
        node_nm: 14,
        bw_density_gbps_mm: |_| 36.4,
        energy_pj_per_bit: |_| 0.85,
        link_length_mm: Some(3.0),
        hops: HopScaling::SqrtChiplets,
    },
    Technology {
        name: "Optical Interposer [29]",
        node_nm: 40,
        bw_density_gbps_mm: |_| 8000.0,
        energy_pj_per_bit: |_| 4.23,
        link_length_mm: None,
        hops: HopScaling::SqrtChiplets,
    },
    Technology {
        name: "Wireless (unicast)",
        node_nm: 65,
        bw_density_gbps_mm: |_| 26.5,
        energy_pj_per_bit: |_| 4.01,
        link_length_mm: Some(40.0),
        hops: HopScaling::One,
    },
    Technology {
        name: "Wireless (broadcast)",
        node_nm: 65,
        bw_density_gbps_mm: |n| 64.0 * n.sqrt(),
        energy_pj_per_bit: |n| 1.4 * n,
        link_length_mm: Some(40.0),
        hops: HopScaling::One,
    },
];

/// Per-hop energy of the evaluated interposer baseline in pJ/bit
/// (Simba-class 16 nm links, Table 2 row 2). Conservative baselines get
/// the worse link, aggressive the better one.
pub fn interposer_hop_energy_pj(aggressive: bool) -> f64 {
    if aggressive {
        0.82
    } else {
        1.75
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_has_six_rows() {
        assert_eq!(TECHNOLOGIES.len(), 6);
    }

    #[test]
    fn wireless_broadcast_scales_with_chiplets() {
        let t = &TECHNOLOGIES[5];
        assert_eq!((t.energy_pj_per_bit)(256.0), 1.4 * 256.0);
        assert_eq!((t.bw_density_gbps_mm)(256.0), 64.0 * 16.0);
        assert_eq!(t.avg_hops(256.0), 1.0);
    }

    #[test]
    fn interposer_hops_grow_with_sqrt() {
        let t = &TECHNOLOGIES[1];
        assert_eq!(t.avg_hops(256.0), 8.0);
        assert_eq!(t.avg_hops(1024.0), 16.0);
    }

    #[test]
    fn crossover_broadcast_favors_wireless_at_scale() {
        // Per delivered bit: interposer broadcast to n dests costs
        // n * hops * E_hop; wireless costs (TX + n*RX). At 256 chiplets the
        // wireless side must win (Fig 4's message).
        let n = 256.0;
        let mesh = n * 8.0 * interposer_hop_energy_pj(true);
        let wireless = (TECHNOLOGIES[5].energy_pj_per_bit)(n);
        assert!(wireless < mesh, "wireless {wireless} vs mesh {mesh}");
    }
}
