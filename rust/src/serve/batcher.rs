//! Dynamic batch-size selection over a memoized cost cache.
//!
//! The batcher turns the analytical cost model into an online scheduling
//! signal: for the current queue depth it queries the latency/throughput
//! frontier over candidate batch sizes and dispatches the batch with the
//! highest throughput whose completion still meets the head-of-line
//! request's SLO deadline. All cost-model evaluations go through
//! [`CostCache`], keyed by `(design point, package shape, model, batch)`,
//! so the simulator's hot loop never re-runs `evaluate_model` for a
//! configuration it has already priced.

use super::request::ModelKind;
use crate::config::{DesignPoint, CLOCK_HZ};
use crate::coordinator::pipeline::pipeline_makespan;
use crate::cost::{evaluate_model, CostEngine};
use std::collections::HashMap;

/// Everything that changes the serving cost of one batch.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct CostKey {
    pub dp: DesignPoint,
    pub num_chiplets: u64,
    pub pes_per_chiplet: u64,
    /// Global SRAM capacity — packages that differ only in SRAM must not
    /// alias (the HBM-staging and search paths vary it).
    pub global_sram_bytes: u64,
    /// Collection-NoP link bandwidth (bytes/cycle/link) as its IEEE-754
    /// bit pattern, so the key stays `Eq + Hash`.
    pub collection_bw_bits: u64,
    /// Tensor element width — scales every traffic class's byte count
    /// (mirrors `cost::EngineKey`).
    pub bytes_per_elem: u64,
    /// Pipelining double-buffer budget — changes the pipelined makespan,
    /// so packages differing only in buffer size must not share entries.
    pub local_buffer_bytes: u64,
    pub kind: ModelKind,
    pub batch: u64,
}

/// Memoized serving cost of one `(design, model, batch)` combination.
#[derive(Debug, Clone, Copy)]
pub struct BatchCost {
    /// Pipelined makespan of one batch in cycles (inter-layer
    /// double-buffered preloads, `coordinator::pipeline`).
    pub latency: f64,
    /// Busy cycles on the distribution plane (wireless or interposer).
    pub dist_busy: f64,
    /// Busy cycles on the chiplets' compute arrays.
    pub compute_busy: f64,
    /// Busy cycles on the wired collection mesh.
    pub collect_busy: f64,
    // --- energy inputs (consumed by `power::PowerModel::batch_dynamic`) ---
    /// Total MACs in the batch.
    pub macs: f64,
    /// Global-SRAM traffic: every distributed byte read + every collected
    /// byte written (mirrors `energy::system`).
    pub sram_bytes: f64,
    /// Distribution energy in pJ, straight from the NoP models (wireless
    /// multicast vs interposer mesh — the Fig-9 machinery).
    pub dist_energy_pj: f64,
    /// Collected bytes × average mesh hops, for the collection-NoP energy.
    pub collect_byte_hops: f64,
}

impl BatchCost {
    /// Steady-state throughput of back-to-back batches of this size.
    pub fn throughput_rps(&self, batch: u64) -> f64 {
        batch as f64 * CLOCK_HZ / self.latency
    }
}

/// Memoized per-`(design, model, batch)` cost store.
#[derive(Debug, Default)]
pub struct CostCache {
    map: HashMap<CostKey, BatchCost>,
    pub hits: u64,
    pub misses: u64,
}

impl CostCache {
    pub fn new() -> Self {
        CostCache::default()
    }

    /// Memoized lookup: runs `evaluate_model` (adaptive strategy per
    /// layer) plus inter-layer pipelining only on a miss.
    pub fn get(
        &mut self,
        engine: &CostEngine,
        dp: DesignPoint,
        kind: ModelKind,
        batch: u64,
        local_buffer_bytes: u64,
    ) -> BatchCost {
        assert!(batch >= 1);
        let key = CostKey {
            dp,
            num_chiplets: engine.sys.num_chiplets,
            pes_per_chiplet: engine.sys.pes_per_chiplet,
            global_sram_bytes: engine.sys.global_sram_bytes,
            collection_bw_bits: engine.sys.collection_bw_per_link.to_bits(),
            bytes_per_elem: engine.sys.bytes_per_elem,
            local_buffer_bytes,
            kind,
            batch,
        };
        if let Some(c) = self.map.get(&key) {
            self.hits += 1;
            return *c;
        }
        self.misses += 1;
        let model = kind.build(batch);
        let cost = evaluate_model(engine, &model, None);
        let pipe = pipeline_makespan(&cost.layers, local_buffer_bytes);
        // The same aggregation the static whole-system path uses
        // (`energy::system_energy`), so the runtime meter can never
        // drift from the paper-figure energy numbers.
        let t = crate::energy::TrafficTotals::from_layers(&cost.layers, engine.sys.avg_mesh_hops());
        let bc = BatchCost {
            latency: pipe.pipelined_cycles,
            dist_busy: cost.layers.iter().map(|l| l.timeline.preload + l.timeline.stream).sum(),
            compute_busy: cost.layers.iter().map(|l| l.timeline.compute).sum(),
            collect_busy: cost.layers.iter().map(|l| l.timeline.collect).sum(),
            macs: t.macs,
            sram_bytes: t.sram_bytes,
            dist_energy_pj: t.dist_energy_pj,
            collect_byte_hops: t.collect_byte_hops,
        };
        self.map.insert(key, bc);
        bc
    }

    /// Distinct configurations priced so far.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
}

/// Dynamic-batcher tuning knobs.
#[derive(Debug, Clone)]
pub struct BatcherConfig {
    /// Largest batch one dispatch may serve.
    pub max_batch: u64,
    /// Candidate batch sizes, ascending; must contain 1.
    pub candidates: Vec<u64>,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig { max_batch: 32, candidates: vec![1, 2, 4, 8, 16, 32] }
    }
}

/// Outcome of one batch-size decision.
#[derive(Debug, Clone, Copy)]
pub struct BatchDecision {
    pub batch: u64,
    pub cost: BatchCost,
    /// Whether the chosen batch is predicted to meet the head-of-line
    /// deadline (`false` only when no candidate could).
    pub meets_slo: bool,
}

/// Pick the batch size for one dispatch.
///
/// Among candidate sizes no larger than the queue depth (and
/// `cfg.max_batch`), pick the highest-throughput batch whose predicted
/// completion `now + latency(b)` still meets `head_deadline`. When no
/// candidate can meet the deadline the head request is late regardless,
/// so the highest-throughput candidate is dispatched instead — shrinking
/// the batch would only deepen the backlog (throughput death spiral).
#[allow(clippy::too_many_arguments)]
pub fn choose_batch(
    cfg: &BatcherConfig,
    cache: &mut CostCache,
    engine: &CostEngine,
    dp: DesignPoint,
    kind: ModelKind,
    queue_depth: u64,
    now: f64,
    head_deadline: f64,
    local_buffer_bytes: u64,
) -> BatchDecision {
    assert!(queue_depth >= 1, "nothing to dispatch");
    let limit = queue_depth.min(cfg.max_batch).max(1);
    let mut best_slo: Option<BatchDecision> = None;
    let mut best_any: Option<BatchDecision> = None;
    for &b in cfg.candidates.iter().filter(|&&b| b <= limit) {
        let cost = cache.get(engine, dp, kind, b, local_buffer_bytes);
        let meets_slo = now + cost.latency <= head_deadline;
        let d = BatchDecision { batch: b, cost, meets_slo };
        let tput = b as f64 / cost.latency;
        let beats = |cur: &Option<BatchDecision>| match cur {
            None => true,
            Some(x) => tput > x.batch as f64 / x.cost.latency,
        };
        if beats(&best_any) {
            best_any = Some(d);
        }
        if meets_slo && beats(&best_slo) {
            best_slo = Some(d);
        }
    }
    best_slo.or(best_any).expect("candidate set always contains batch 1")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;

    fn engine(dp: DesignPoint) -> CostEngine {
        CostEngine::for_design_point(&SystemConfig::default(), dp)
    }

    const BUF: u64 = 512 * 1024;

    #[test]
    fn cache_memoizes() {
        let e = engine(DesignPoint::WIENNA_C);
        let mut cache = CostCache::new();
        let a = cache.get(&e, DesignPoint::WIENNA_C, ModelKind::TinyCnn, 4, BUF);
        assert_eq!(cache.misses, 1);
        assert_eq!(cache.hits, 0);
        let b = cache.get(&e, DesignPoint::WIENNA_C, ModelKind::TinyCnn, 4, BUF);
        assert_eq!(cache.misses, 1);
        assert_eq!(cache.hits, 1);
        assert_eq!(a.latency, b.latency);
        // A different batch is a different key.
        cache.get(&e, DesignPoint::WIENNA_C, ModelKind::TinyCnn, 8, BUF);
        assert_eq!(cache.misses, 2);
        assert_eq!(cache.len(), 2);
        // A different pipelining budget is a different key too.
        cache.get(&e, DesignPoint::WIENNA_C, ModelKind::TinyCnn, 8, BUF / 8);
        assert_eq!(cache.misses, 3);
        assert_eq!(cache.len(), 3);
    }

    #[test]
    fn sram_and_collection_bw_do_not_alias() {
        // ROADMAP item: packages that differ only in SRAM size or
        // collection bandwidth must occupy distinct cache entries.
        let base = SystemConfig::default();
        let small_sram = SystemConfig { global_sram_bytes: base.global_sram_bytes / 4, ..base.clone() };
        let fat_collect = SystemConfig { collection_bw_per_link: 2.0 * base.collection_bw_per_link, ..base.clone() };
        let wide_elems = SystemConfig { bytes_per_elem: 2 * base.bytes_per_elem, ..base.clone() };
        let mut cache = CostCache::new();
        for sys in [&base, &small_sram, &fat_collect, &wide_elems] {
            let e = CostEngine::for_design_point(sys, DesignPoint::WIENNA_C);
            cache.get(&e, DesignPoint::WIENNA_C, ModelKind::TinyCnn, 4, BUF);
        }
        assert_eq!(cache.misses, 4, "each package shape must be priced separately");
        assert_eq!(cache.len(), 4);
    }

    #[test]
    fn batching_amortizes_latency() {
        let e = engine(DesignPoint::WIENNA_C);
        let mut cache = CostCache::new();
        let c1 = cache.get(&e, DesignPoint::WIENNA_C, ModelKind::TinyCnn, 1, BUF);
        let c8 = cache.get(&e, DesignPoint::WIENNA_C, ModelKind::TinyCnn, 8, BUF);
        // Sub-linear latency growth: batch 8 costs less than 8x batch 1.
        assert!(c8.latency < 8.0 * c1.latency);
        assert!(c8.throughput_rps(8) > c1.throughput_rps(1));
    }

    #[test]
    fn energy_inputs_are_populated_and_grow_with_batch() {
        let e = engine(DesignPoint::WIENNA_C);
        let mut cache = CostCache::new();
        let c1 = cache.get(&e, DesignPoint::WIENNA_C, ModelKind::TinyCnn, 1, BUF);
        let c8 = cache.get(&e, DesignPoint::WIENNA_C, ModelKind::TinyCnn, 8, BUF);
        assert!(c1.macs > 0.0 && c1.sram_bytes > 0.0);
        assert!(c1.dist_energy_pj > 0.0 && c1.collect_byte_hops > 0.0);
        // MACs scale exactly linearly with batch; traffic at least grows.
        assert!((c8.macs - 8.0 * c1.macs).abs() < 1e-6 * c8.macs);
        assert!(c8.sram_bytes > c1.sram_bytes);
        assert!(c8.dist_energy_pj > c1.dist_energy_pj);
    }

    #[test]
    fn wireless_distribution_energy_beats_interposer_per_batch() {
        // The Fig-9 comparison must survive the serving-path aggregation.
        let ew = engine(DesignPoint::WIENNA_C);
        let ei = engine(DesignPoint::INTERPOSER_C);
        let mut cache = CostCache::new();
        let w = cache.get(&ew, DesignPoint::WIENNA_C, ModelKind::ResNet50, 4, BUF);
        let i = cache.get(&ei, DesignPoint::INTERPOSER_C, ModelKind::ResNet50, 4, BUF);
        assert!(w.dist_energy_pj < i.dist_energy_pj, "{} vs {}", w.dist_energy_pj, i.dist_energy_pj);
    }

    #[test]
    fn low_load_picks_batch_one() {
        let e = engine(DesignPoint::WIENNA_C);
        let mut cache = CostCache::new();
        let d = choose_batch(
            &BatcherConfig::default(),
            &mut cache,
            &e,
            DesignPoint::WIENNA_C,
            ModelKind::TinyCnn,
            1,
            0.0,
            f64::INFINITY,
            BUF,
        );
        assert_eq!(d.batch, 1);
        assert!(d.meets_slo);
    }

    #[test]
    fn backlog_grows_the_batch() {
        let e = engine(DesignPoint::WIENNA_C);
        let mut cache = CostCache::new();
        let cfg = BatcherConfig::default();
        let mut last = 0;
        for depth in [1u64, 4, 16, 64] {
            let d = choose_batch(
                &cfg,
                &mut cache,
                &e,
                DesignPoint::WIENNA_C,
                ModelKind::TinyCnn,
                depth,
                0.0,
                f64::INFINITY,
                BUF,
            );
            assert!(d.batch >= last, "batch shrank at depth {depth}");
            assert!(d.batch <= depth.min(cfg.max_batch));
            last = d.batch;
        }
        // Deep backlog with no deadline pressure batches well past 1.
        assert!(last >= 4, "deep backlog only reached batch {last}");
    }

    #[test]
    fn tight_deadline_caps_the_batch() {
        let e = engine(DesignPoint::WIENNA_C);
        let mut cache = CostCache::new();
        let cfg = BatcherConfig::default();
        let c1 = cache.get(&e, DesignPoint::WIENNA_C, ModelKind::TinyCnn, 1, BUF);
        let c32 = cache.get(&e, DesignPoint::WIENNA_C, ModelKind::TinyCnn, 32, BUF);
        // Deadline admits batch 1 but not batch 32.
        let deadline = (c1.latency + c32.latency) / 2.0;
        let d = choose_batch(
            &cfg,
            &mut cache,
            &e,
            DesignPoint::WIENNA_C,
            ModelKind::TinyCnn,
            64,
            0.0,
            deadline,
            BUF,
        );
        assert!(d.meets_slo);
        assert!(d.batch < 32, "deadline should cap the batch, got {}", d.batch);
        // An impossible deadline falls back to the highest-throughput
        // batch (the head request is late either way).
        let d = choose_batch(
            &cfg,
            &mut cache,
            &e,
            DesignPoint::WIENNA_C,
            ModelKind::TinyCnn,
            64,
            0.0,
            0.0,
            BUF,
        );
        assert!(!d.meets_slo);
        assert!(d.batch > 1, "overloaded dispatch should keep batching, got {}", d.batch);
    }
}
