//! Requests, the served-model catalog, and arrival processes.
//!
//! A request asks for one inference of a [`ModelKind`] and carries an SLO
//! deadline. Arrival processes generate the request stream: an open-loop
//! Poisson source (arrivals independent of service), an open-loop trace
//! replay (recorded inter-arrival gaps), and a closed-loop client pool
//! (each client waits for its completion plus a think time before issuing
//! the next request — service pushback throttles the offered load).

use crate::config::CLOCK_HZ;
use crate::testutil::Rng;
use crate::workload::{mlp, resnet50, tiny, transformer, unet, Model};

/// Convert milliseconds to cycles at the Table-4 clock.
pub fn ms_to_cycles(ms: f64) -> f64 {
    ms * 1e-3 * CLOCK_HZ
}

/// Convert cycles to milliseconds at the Table-4 clock.
pub fn cycles_to_ms(cycles: f64) -> f64 {
    cycles / CLOCK_HZ * 1e3
}

/// The catalog of servable models. Keys the batcher's cost cache, so each
/// variant must build identically for a given batch size.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum ModelKind {
    /// ResNet-50 classifier (the paper's CNN workload).
    ResNet50,
    /// UNet segmentation network (the paper's second workload).
    UNet,
    /// BERT-base encoder, seq 128 (`workload::transformer`).
    BertBase,
    /// The scaled-down CNN (fast; used by tests).
    TinyCnn,
    /// FC-dominated MLP classifier.
    Mlp,
}

impl ModelKind {
    pub const ALL: [ModelKind; 5] =
        [ModelKind::ResNet50, ModelKind::UNet, ModelKind::BertBase, ModelKind::TinyCnn, ModelKind::Mlp];

    pub fn label(&self) -> &'static str {
        match self {
            ModelKind::ResNet50 => "resnet50",
            ModelKind::UNet => "unet",
            ModelKind::BertBase => "bert-base",
            ModelKind::TinyCnn => "tiny-cnn",
            ModelKind::Mlp => "mlp",
        }
    }

    /// Instantiate the model at `batch` requests per inference.
    pub fn build(&self, batch: u64) -> Model {
        match self {
            ModelKind::ResNet50 => resnet50::resnet50(batch),
            ModelKind::UNet => unet::unet(batch),
            ModelKind::BertBase => transformer::bert_base(batch),
            ModelKind::TinyCnn => tiny::tiny_cnn(batch),
            ModelKind::Mlp => mlp::mlp(batch, 784, 4096, 4, 1000),
        }
    }
}

/// One entry of a traffic mix: a model, its relative share of requests,
/// and its latency SLO.
#[derive(Debug, Clone, Copy)]
pub struct MixEntry {
    pub kind: ModelKind,
    /// Relative traffic weight (need not sum to 1).
    pub weight: f64,
    /// Latency budget in cycles; a request's deadline is
    /// `arrival + slo_cycles`.
    pub slo_cycles: f64,
}

/// A weighted traffic mix over the model catalog.
#[derive(Debug, Clone)]
pub struct WorkloadMix {
    pub entries: Vec<MixEntry>,
}

impl WorkloadMix {
    pub fn new(entries: Vec<MixEntry>) -> Self {
        assert!(!entries.is_empty(), "mix needs at least one entry");
        assert!(entries.iter().all(|e| e.weight > 0.0 && e.slo_cycles > 0.0));
        WorkloadMix { entries }
    }

    /// A single-model mix with an SLO in milliseconds.
    pub fn single(kind: ModelKind, slo_ms: f64) -> Self {
        WorkloadMix::new(vec![MixEntry { kind, weight: 1.0, slo_cycles: ms_to_cycles(slo_ms) }])
    }

    /// The canonical CNN+transformer serving mix shared by the serving
    /// example and the load-sweep bench: ResNet-50 (50% of traffic,
    /// 25 ms SLO), UNet (25%, 50 ms — it is much heavier), BERT-base
    /// (25%, 20 ms).
    pub fn cnn_transformer_default() -> Self {
        WorkloadMix::new(vec![
            MixEntry { kind: ModelKind::ResNet50, weight: 2.0, slo_cycles: ms_to_cycles(25.0) },
            MixEntry { kind: ModelKind::UNet, weight: 1.0, slo_cycles: ms_to_cycles(50.0) },
            MixEntry { kind: ModelKind::BertBase, weight: 1.0, slo_cycles: ms_to_cycles(20.0) },
        ])
    }

    fn total_weight(&self) -> f64 {
        self.entries.iter().map(|e| e.weight).sum()
    }

    /// Draw one entry with probability proportional to its weight.
    fn draw(&self, rng: &mut Rng) -> MixEntry {
        let mut u = rng.next_f32() as f64 * self.total_weight();
        for e in &self.entries {
            if u < e.weight {
                return *e;
            }
            u -= e.weight;
        }
        *self.entries.last().unwrap()
    }
}

/// One inference request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub kind: ModelKind,
    /// Arrival cycle.
    pub arrival: f64,
    /// SLO deadline cycle (`arrival + slo`).
    pub deadline: f64,
    /// Closed-loop client that issued this request (`None` open-loop).
    pub client: Option<usize>,
}

/// Open-loop Poisson arrivals at a fixed offered rate.
#[derive(Debug, Clone)]
pub struct PoissonSource {
    mix: WorkloadMix,
    mean_gap_cycles: f64,
    rng: Rng,
    next_at: f64,
    next_id: u64,
}

/// Open-loop replay of recorded inter-arrival gaps (one pass).
#[derive(Debug, Clone)]
pub struct ReplaySource {
    mix: WorkloadMix,
    /// Remaining gaps in cycles, consumed front to back.
    gaps: Vec<f64>,
    cursor: usize,
    rng: Rng,
    next_at: f64,
    next_id: u64,
}

#[derive(Debug, Clone, Copy)]
struct Client {
    /// When this client issues its next request (`None`: in flight).
    ready_at: Option<f64>,
    remaining: u64,
}

/// Closed-loop client pool: each client re-issues `think` cycles after its
/// previous request completes.
#[derive(Debug, Clone)]
pub struct ClosedLoopSource {
    mix: WorkloadMix,
    think_cycles: f64,
    clients: Vec<Client>,
    rng: Rng,
    next_id: u64,
}

/// An arrival process over a workload mix.
#[derive(Debug, Clone)]
pub enum Source {
    Poisson(PoissonSource),
    Replay(ReplaySource),
    ClosedLoop(ClosedLoopSource),
}

impl Source {
    /// Open-loop Poisson arrivals at `rate_rps` requests per second.
    pub fn poisson(mix: WorkloadMix, rate_rps: f64, seed: u64) -> Source {
        assert!(rate_rps > 0.0);
        let mean_gap_cycles = CLOCK_HZ / rate_rps;
        let mut rng = Rng::new(seed);
        let first = exp_sample(&mut rng, mean_gap_cycles);
        Source::Poisson(PoissonSource { mix, mean_gap_cycles, rng, next_at: first, next_id: 0 })
    }

    /// Open-loop replay of recorded inter-arrival gaps (milliseconds).
    pub fn replay(mix: WorkloadMix, gaps_ms: &[f64], seed: u64) -> Source {
        assert!(!gaps_ms.is_empty());
        let gaps: Vec<f64> = gaps_ms.iter().map(|&g| ms_to_cycles(g)).collect();
        let first = gaps[0];
        Source::Replay(ReplaySource { mix, gaps, cursor: 0, rng: Rng::new(seed), next_at: first, next_id: 0 })
    }

    /// Closed-loop pool of `clients`, each issuing `requests_per_client`
    /// requests with `think_ms` of think time after every completion.
    pub fn closed_loop(mix: WorkloadMix, clients: usize, think_ms: f64, requests_per_client: u64, seed: u64) -> Source {
        assert!(clients > 0 && requests_per_client > 0);
        let think_cycles = ms_to_cycles(think_ms);
        let mut rng = Rng::new(seed);
        let clients = (0..clients)
            .map(|_| Client {
                // Stagger the initial issue times over one think window.
                ready_at: Some(rng.next_f32() as f64 * think_cycles.max(1.0)),
                remaining: requests_per_client,
            })
            .collect();
        Source::ClosedLoop(ClosedLoopSource { mix, think_cycles, clients, rng, next_id: 0 })
    }

    /// Cycle of the next pending arrival, if any.
    pub fn next_arrival_at(&self) -> Option<f64> {
        match self {
            Source::Poisson(s) => Some(s.next_at),
            Source::Replay(s) => {
                if s.cursor < s.gaps.len() {
                    Some(s.next_at)
                } else {
                    None
                }
            }
            Source::ClosedLoop(s) => s
                .clients
                .iter()
                .filter_map(|c| c.ready_at)
                .fold(None, |m: Option<f64>, t| Some(m.map_or(t, |m| m.min(t)))),
        }
    }

    /// Emit the pending arrival (callers must have seen
    /// [`Source::next_arrival_at`] return `Some`).
    pub fn pop(&mut self) -> Request {
        match self {
            Source::Poisson(s) => {
                let e = s.mix.draw(&mut s.rng);
                let req = request(s.next_id, &e, s.next_at, None);
                s.next_id += 1;
                s.next_at += exp_sample(&mut s.rng, s.mean_gap_cycles);
                req
            }
            Source::Replay(s) => {
                assert!(s.cursor < s.gaps.len(), "replay source exhausted");
                let e = s.mix.draw(&mut s.rng);
                let req = request(s.next_id, &e, s.next_at, None);
                s.next_id += 1;
                s.cursor += 1;
                if s.cursor < s.gaps.len() {
                    s.next_at += s.gaps[s.cursor];
                }
                req
            }
            Source::ClosedLoop(s) => {
                let (idx, at) = s
                    .clients
                    .iter()
                    .enumerate()
                    .filter_map(|(i, c)| c.ready_at.map(|t| (i, t)))
                    .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
                    .expect("closed-loop source has no ready client");
                let e = s.mix.draw(&mut s.rng);
                let req = request(s.next_id, &e, at, Some(idx));
                s.next_id += 1;
                s.clients[idx].ready_at = None;
                s.clients[idx].remaining -= 1;
                req
            }
        }
    }

    /// Completion feedback; drives the closed-loop clients and is a no-op
    /// for open-loop sources.
    pub fn on_complete(&mut self, now: f64, req: &Request) {
        if let Source::ClosedLoop(s) = self {
            if let Some(idx) = req.client {
                if s.clients[idx].remaining > 0 {
                    s.clients[idx].ready_at = Some(now + s.think_cycles);
                }
            }
        }
    }

    /// Requests emitted so far.
    pub fn emitted(&self) -> u64 {
        match self {
            Source::Poisson(s) => s.next_id,
            Source::Replay(s) => s.next_id,
            Source::ClosedLoop(s) => s.next_id,
        }
    }

    /// Whether the source runs dry on its own. A Poisson source never
    /// does — running one needs a finite horizon (`Fleet::run` asserts
    /// this); replay and closed-loop sources are finite by construction.
    pub fn is_bounded(&self) -> bool {
        !matches!(self, Source::Poisson(_))
    }
}

fn request(id: u64, e: &MixEntry, at: f64, client: Option<usize>) -> Request {
    Request { id, kind: e.kind, arrival: at, deadline: at + e.slo_cycles, client }
}

/// Exponential inter-arrival sample with the given mean.
fn exp_sample(rng: &mut Rng, mean: f64) -> f64 {
    let u = rng.next_f32() as f64; // [0, 1)
    -mean * (1.0 - u).ln()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mix() -> WorkloadMix {
        WorkloadMix::new(vec![
            MixEntry { kind: ModelKind::TinyCnn, weight: 3.0, slo_cycles: ms_to_cycles(10.0) },
            MixEntry { kind: ModelKind::Mlp, weight: 1.0, slo_cycles: ms_to_cycles(20.0) },
        ])
    }

    #[test]
    fn poisson_rate_matches_mean_gap() {
        let mut s = Source::poisson(mix(), 1000.0, 42);
        let n = 2000;
        let mut last = 0.0;
        let mut total = 0.0;
        for _ in 0..n {
            let r = s.pop();
            assert!(r.arrival >= last);
            total = r.arrival;
            last = r.arrival;
        }
        let mean_gap = total / n as f64;
        let expect = CLOCK_HZ / 1000.0;
        assert!(
            (mean_gap - expect).abs() / expect < 0.1,
            "mean gap {mean_gap:.0} vs expected {expect:.0}"
        );
        assert_eq!(s.emitted(), n);
    }

    #[test]
    fn mix_weights_respected() {
        let mut s = Source::poisson(mix(), 1000.0, 7);
        let mut tiny = 0u64;
        let n = 4000;
        for _ in 0..n {
            if s.pop().kind == ModelKind::TinyCnn {
                tiny += 1;
            }
        }
        let frac = tiny as f64 / n as f64;
        assert!((frac - 0.75).abs() < 0.05, "tiny fraction {frac:.2}");
    }

    #[test]
    fn deadlines_offset_by_slo() {
        let mut s = Source::poisson(mix(), 100.0, 1);
        for _ in 0..50 {
            let r = s.pop();
            let slo = r.deadline - r.arrival;
            let expect = match r.kind {
                ModelKind::TinyCnn => ms_to_cycles(10.0),
                _ => ms_to_cycles(20.0),
            };
            assert!((slo - expect).abs() < 1e-6);
        }
    }

    #[test]
    fn replay_walks_the_trace_once() {
        let mut s = Source::replay(mix(), &[1.0, 2.0, 3.0], 9);
        let a = s.pop().arrival;
        let b = s.pop().arrival;
        let c = s.pop().arrival;
        assert!((a - ms_to_cycles(1.0)).abs() < 1e-6);
        assert!((b - a - ms_to_cycles(2.0)).abs() < 1e-6);
        assert!((c - b - ms_to_cycles(3.0)).abs() < 1e-6);
        assert!(s.next_arrival_at().is_none());
    }

    #[test]
    fn closed_loop_waits_for_completion() {
        let mut s = Source::closed_loop(mix(), 2, 1.0, 2, 3);
        let r1 = s.pop();
        let r2 = s.pop();
        // Both clients are now in flight: no further arrivals.
        assert!(s.next_arrival_at().is_none());
        // Completing r1 re-arms its client one think time later.
        s.on_complete(r1.arrival + 100.0, &r1);
        let t = s.next_arrival_at().expect("client re-armed");
        assert!((t - (r1.arrival + 100.0 + ms_to_cycles(1.0))).abs() < 1e-6);
        let r3 = s.pop();
        assert_eq!(r3.client, r1.client);
        // Each client issues exactly two requests.
        s.on_complete(r3.arrival + 50.0, &r3);
        assert!(s.next_arrival_at().is_none());
        s.on_complete(r2.arrival + 50.0, &r2);
        let r4 = s.pop();
        assert_eq!(r4.client, r2.client);
        s.on_complete(r4.arrival + 50.0, &r4);
        assert!(s.next_arrival_at().is_none());
        assert_eq!(s.emitted(), 4);
    }

    #[test]
    fn model_catalog_builds() {
        for kind in ModelKind::ALL {
            let m = kind.build(2);
            assert!(!m.layers.is_empty(), "{} has layers", kind.label());
            assert!(m.total_macs() > 0);
        }
    }
}
