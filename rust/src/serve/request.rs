//! Requests, the served-model catalog, and arrival processes.
//!
//! A request asks for one inference of a [`ModelKind`] and carries an SLO
//! deadline. Arrival processes generate the request stream: an open-loop
//! Poisson source (arrivals independent of service), an open-loop trace
//! replay (recorded inter-arrival gaps), and a closed-loop client pool
//! (each client waits for its completion plus a think time before issuing
//! the next request — service pushback throttles the offered load).

use crate::config::CLOCK_HZ;
use crate::testutil::Rng;
use crate::workload::{mlp, resnet50, tiny, transformer, unet, Model};

/// Convert milliseconds to cycles at the Table-4 clock.
pub fn ms_to_cycles(ms: f64) -> f64 {
    ms * 1e-3 * CLOCK_HZ
}

/// Convert cycles to milliseconds at the Table-4 clock.
pub fn cycles_to_ms(cycles: f64) -> f64 {
    cycles / CLOCK_HZ * 1e3
}

/// The catalog of servable models. Keys the batcher's cost cache, so each
/// variant must build identically for a given batch size.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum ModelKind {
    /// ResNet-50 classifier (the paper's CNN workload).
    ResNet50,
    /// UNet segmentation network (the paper's second workload).
    UNet,
    /// BERT-base encoder, seq 128 (`workload::transformer`).
    BertBase,
    /// The scaled-down CNN (fast; used by tests).
    TinyCnn,
    /// FC-dominated MLP classifier.
    Mlp,
}

impl ModelKind {
    pub const ALL: [ModelKind; 5] =
        [ModelKind::ResNet50, ModelKind::UNet, ModelKind::BertBase, ModelKind::TinyCnn, ModelKind::Mlp];

    pub fn label(&self) -> &'static str {
        match self {
            ModelKind::ResNet50 => "resnet50",
            ModelKind::UNet => "unet",
            ModelKind::BertBase => "bert-base",
            ModelKind::TinyCnn => "tiny-cnn",
            ModelKind::Mlp => "mlp",
        }
    }

    /// Instantiate the model at `batch` requests per inference.
    pub fn build(&self, batch: u64) -> Model {
        match self {
            ModelKind::ResNet50 => resnet50::resnet50(batch),
            ModelKind::UNet => unet::unet(batch),
            ModelKind::BertBase => transformer::bert_base(batch),
            ModelKind::TinyCnn => tiny::tiny_cnn(batch),
            ModelKind::Mlp => mlp::mlp(batch, 784, 4096, 4, 1000),
        }
    }
}

/// One entry of a traffic mix: a model, its relative share of requests,
/// and its latency SLO.
#[derive(Debug, Clone, Copy)]
pub struct MixEntry {
    pub kind: ModelKind,
    /// Relative traffic weight (need not sum to 1).
    pub weight: f64,
    /// Latency budget in cycles; a request's deadline is
    /// `arrival + slo_cycles`.
    pub slo_cycles: f64,
}

/// A weighted traffic mix over the model catalog.
#[derive(Debug, Clone)]
pub struct WorkloadMix {
    pub entries: Vec<MixEntry>,
}

impl WorkloadMix {
    pub fn new(entries: Vec<MixEntry>) -> Self {
        assert!(!entries.is_empty(), "mix needs at least one entry");
        assert!(entries.iter().all(|e| e.weight > 0.0 && e.slo_cycles > 0.0));
        WorkloadMix { entries }
    }

    /// A single-model mix with an SLO in milliseconds.
    pub fn single(kind: ModelKind, slo_ms: f64) -> Self {
        WorkloadMix::new(vec![MixEntry { kind, weight: 1.0, slo_cycles: ms_to_cycles(slo_ms) }])
    }

    /// The canonical CNN+transformer serving mix shared by the serving
    /// example and the load-sweep bench: ResNet-50 (50% of traffic,
    /// 25 ms SLO), UNet (25%, 50 ms — it is much heavier), BERT-base
    /// (25%, 20 ms).
    pub fn cnn_transformer_default() -> Self {
        WorkloadMix::new(vec![
            MixEntry { kind: ModelKind::ResNet50, weight: 2.0, slo_cycles: ms_to_cycles(25.0) },
            MixEntry { kind: ModelKind::UNet, weight: 1.0, slo_cycles: ms_to_cycles(50.0) },
            MixEntry { kind: ModelKind::BertBase, weight: 1.0, slo_cycles: ms_to_cycles(20.0) },
        ])
    }

    fn total_weight(&self) -> f64 {
        self.entries.iter().map(|e| e.weight).sum()
    }

    /// Draw one entry with probability proportional to its weight.
    fn draw(&self, rng: &mut Rng) -> MixEntry {
        let mut u = rng.next_f32() as f64 * self.total_weight();
        for e in &self.entries {
            if u < e.weight {
                return *e;
            }
            u -= e.weight;
        }
        *self.entries.last().unwrap()
    }
}

/// One inference request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub kind: ModelKind,
    /// Arrival cycle.
    pub arrival: f64,
    /// SLO deadline cycle (`arrival + slo`).
    pub deadline: f64,
    /// Closed-loop client that issued this request (`None` open-loop).
    pub client: Option<usize>,
}

/// Open-loop Poisson arrivals at a fixed offered rate.
#[derive(Debug, Clone)]
pub struct PoissonSource {
    mix: WorkloadMix,
    mean_gap_cycles: f64,
    rng: Rng,
    next_at: f64,
    next_id: u64,
}

/// Open-loop replay of recorded inter-arrival gaps (one pass).
#[derive(Debug, Clone)]
pub struct ReplaySource {
    mix: WorkloadMix,
    /// Remaining gaps in cycles, consumed front to back.
    gaps: Vec<f64>,
    cursor: usize,
    rng: Rng,
    next_at: f64,
    next_id: u64,
}

#[derive(Debug, Clone, Copy)]
struct Client {
    /// When this client issues its next request (`None`: in flight).
    ready_at: Option<f64>,
    remaining: u64,
}

/// Closed-loop client pool: each client re-issues `think` cycles after its
/// previous request completes.
#[derive(Debug, Clone)]
pub struct ClosedLoopSource {
    mix: WorkloadMix,
    think_cycles: f64,
    clients: Vec<Client>,
    rng: Rng,
    next_id: u64,
}

impl ClosedLoopSource {
    /// `(index, ready_at)` of every client with a pending issue time.
    fn ready(&self) -> impl Iterator<Item = (usize, f64)> + '_ {
        self.clients.iter().enumerate().filter_map(|(i, c)| c.ready_at.map(|t| (i, t)))
    }
}

#[derive(Debug, Clone)]
struct TraceClient {
    /// Recorded issue timestamps in cycles, ascending.
    times: Vec<f64>,
    cursor: usize,
    /// When this client issues its next request (`None`: in flight, or
    /// its trace is exhausted).
    ready_at: Option<f64>,
}

/// Closed-loop replay of recorded per-client issue timestamps: client
/// `c`'s `i`-th request is issued at `max(trace[c][i], completion of its
/// previous request)` — the recorded timestamp replaces the fixed think
/// time of [`Source::closed_loop`], so real traces with bursts and lulls
/// drive the load while service pushback still throttles each client.
#[derive(Debug, Clone)]
pub struct ClientTraceSource {
    mix: WorkloadMix,
    clients: Vec<TraceClient>,
    rng: Rng,
    next_id: u64,
}

impl ClientTraceSource {
    /// `(index, ready_at)` of every client with a pending issue time.
    fn ready(&self) -> impl Iterator<Item = (usize, f64)> + '_ {
        self.clients.iter().enumerate().filter_map(|(i, c)| c.ready_at.map(|t| (i, t)))
    }
}

/// An arrival process over a workload mix.
#[derive(Debug, Clone)]
pub enum Source {
    Poisson(PoissonSource),
    Replay(ReplaySource),
    ClosedLoop(ClosedLoopSource),
    ClientTrace(ClientTraceSource),
}

impl Source {
    /// Open-loop Poisson arrivals at `rate_rps` requests per second.
    pub fn poisson(mix: WorkloadMix, rate_rps: f64, seed: u64) -> Source {
        assert!(rate_rps > 0.0);
        let mean_gap_cycles = CLOCK_HZ / rate_rps;
        let mut rng = Rng::new(seed);
        let first = exp_sample(&mut rng, mean_gap_cycles);
        Source::Poisson(PoissonSource { mix, mean_gap_cycles, rng, next_at: first, next_id: 0 })
    }

    /// Open-loop replay of recorded inter-arrival gaps (milliseconds).
    pub fn replay(mix: WorkloadMix, gaps_ms: &[f64], seed: u64) -> Source {
        assert!(!gaps_ms.is_empty());
        let gaps: Vec<f64> = gaps_ms.iter().map(|&g| ms_to_cycles(g)).collect();
        let first = gaps[0];
        Source::Replay(ReplaySource { mix, gaps, cursor: 0, rng: Rng::new(seed), next_at: first, next_id: 0 })
    }

    /// Closed-loop pool of `clients`, each issuing `requests_per_client`
    /// requests with `think_ms` of think time after every completion.
    pub fn closed_loop(mix: WorkloadMix, clients: usize, think_ms: f64, requests_per_client: u64, seed: u64) -> Source {
        assert!(clients > 0 && requests_per_client > 0);
        let think_cycles = ms_to_cycles(think_ms);
        let mut rng = Rng::new(seed);
        let clients = (0..clients)
            .map(|_| Client {
                // Stagger the initial issue times over one think window.
                ready_at: Some(rng.next_f32() as f64 * think_cycles.max(1.0)),
                remaining: requests_per_client,
            })
            .collect();
        Source::ClosedLoop(ClosedLoopSource { mix, think_cycles, clients, rng, next_id: 0 })
    }

    /// Closed-loop replay of recorded per-client issue timestamps
    /// (milliseconds from run start, ascending per client; see
    /// `workload::trace::parse_arrivals` for the on-disk format). Each
    /// client issues its next request at the recorded timestamp, or at
    /// its previous completion when the service is running behind.
    pub fn client_trace(mix: WorkloadMix, clients_ms: &[Vec<f64>], seed: u64) -> Source {
        assert!(!clients_ms.is_empty(), "client trace needs at least one client");
        let clients: Vec<TraceClient> = clients_ms
            .iter()
            .map(|ts| {
                assert!(!ts.is_empty(), "every client needs at least one timestamp");
                assert!(
                    ts.iter().all(|t| t.is_finite() && *t >= 0.0),
                    "client timestamps must be finite and >= 0"
                );
                assert!(
                    ts.windows(2).all(|w| w[0] <= w[1]),
                    "client timestamps must be ascending"
                );
                let times: Vec<f64> = ts.iter().map(|&t| ms_to_cycles(t)).collect();
                let first = times[0];
                TraceClient { times, cursor: 0, ready_at: Some(first) }
            })
            .collect();
        Source::ClientTrace(ClientTraceSource { mix, clients, rng: Rng::new(seed), next_id: 0 })
    }

    /// Cycle of the next pending arrival, if any.
    pub fn next_arrival_at(&self) -> Option<f64> {
        match self {
            Source::Poisson(s) => Some(s.next_at),
            Source::Replay(s) => {
                if s.cursor < s.gaps.len() {
                    Some(s.next_at)
                } else {
                    None
                }
            }
            Source::ClosedLoop(s) => earliest_ready(s.ready()).map(|(_, t)| t),
            Source::ClientTrace(s) => earliest_ready(s.ready()).map(|(_, t)| t),
        }
    }

    /// Emit the pending arrival (callers must have seen
    /// [`Source::next_arrival_at`] return `Some`).
    pub fn pop(&mut self) -> Request {
        match self {
            Source::Poisson(s) => {
                let e = s.mix.draw(&mut s.rng);
                let req = request(s.next_id, &e, s.next_at, None);
                s.next_id += 1;
                s.next_at += exp_sample(&mut s.rng, s.mean_gap_cycles);
                req
            }
            Source::Replay(s) => {
                assert!(s.cursor < s.gaps.len(), "replay source exhausted");
                let e = s.mix.draw(&mut s.rng);
                let req = request(s.next_id, &e, s.next_at, None);
                s.next_id += 1;
                s.cursor += 1;
                if s.cursor < s.gaps.len() {
                    s.next_at += s.gaps[s.cursor];
                }
                req
            }
            Source::ClosedLoop(s) => {
                let (idx, at) =
                    earliest_ready(s.ready()).expect("closed-loop source has no ready client");
                let e = s.mix.draw(&mut s.rng);
                let req = request(s.next_id, &e, at, Some(idx));
                s.next_id += 1;
                s.clients[idx].ready_at = None;
                s.clients[idx].remaining -= 1;
                req
            }
            Source::ClientTrace(s) => {
                let (idx, at) =
                    earliest_ready(s.ready()).expect("client-trace source has no ready client");
                let e = s.mix.draw(&mut s.rng);
                let req = request(s.next_id, &e, at, Some(idx));
                s.next_id += 1;
                let c = &mut s.clients[idx];
                c.ready_at = None;
                c.cursor += 1;
                req
            }
        }
    }

    /// Final-disposition feedback: drives the closed-loop clients and is
    /// a no-op for open-loop sources. The cluster engine relays *sheds*
    /// through here too, not just completions — a shed is a fast-fail
    /// response the client still observes, so it re-arms and issues its
    /// next request rather than silently abandoning the rest of its
    /// session (`cluster::merge::fold_events`).
    pub fn on_complete(&mut self, now: f64, req: &Request) {
        match self {
            Source::ClosedLoop(s) => {
                if let Some(idx) = req.client {
                    if s.clients[idx].remaining > 0 {
                        s.clients[idx].ready_at = Some(now + s.think_cycles);
                    }
                }
            }
            Source::ClientTrace(s) => {
                if let Some(idx) = req.client {
                    let c = &mut s.clients[idx];
                    if c.cursor < c.times.len() {
                        // The recorded issue time, or right now when the
                        // service is running behind the trace.
                        c.ready_at = Some(c.times[c.cursor].max(now));
                    }
                }
            }
            _ => {}
        }
    }

    /// Requests emitted so far.
    pub fn emitted(&self) -> u64 {
        match self {
            Source::Poisson(s) => s.next_id,
            Source::Replay(s) => s.next_id,
            Source::ClosedLoop(s) => s.next_id,
            Source::ClientTrace(s) => s.next_id,
        }
    }

    /// Whether the source runs dry on its own. A Poisson source never
    /// does — running one needs a finite horizon (`Fleet::run` asserts
    /// this); replay, closed-loop and client-trace sources are finite by
    /// construction.
    pub fn is_bounded(&self) -> bool {
        !matches!(self, Source::Poisson(_))
    }

    /// Whether arrivals are independent of completions. Closed-loop
    /// sources (client pool, client-trace replay) need completion
    /// feedback: `Fleet::run` delivers it inline, and the sharded
    /// cluster engine delivers it at its epoch barriers
    /// (`cluster::sync`). Open-loop sources (Poisson, gap replay) need
    /// none, which lets the cluster run them as one unbounded epoch when
    /// work stealing is off.
    pub fn is_open_loop(&self) -> bool {
        matches!(self, Source::Poisson(_) | Source::Replay(_))
    }
}

fn request(id: u64, e: &MixEntry, at: f64, client: Option<usize>) -> Request {
    Request { id, kind: e.kind, arrival: at, deadline: at + e.slo_cycles, client }
}

/// Earliest-ready client of a closed-loop pool: `(index, ready_at)` with
/// ties going to the lowest index. Shared by the fixed-think-time and
/// trace-replay sources so their selection (and any future tie-break or
/// NaN-handling fix) cannot diverge.
fn earliest_ready(ready: impl Iterator<Item = (usize, f64)>) -> Option<(usize, f64)> {
    ready.min_by(|a, b| a.1.partial_cmp(&b.1).expect("ready times are never NaN"))
}

/// Exponential inter-arrival sample with the given mean.
fn exp_sample(rng: &mut Rng, mean: f64) -> f64 {
    let u = rng.next_f32() as f64; // [0, 1)
    -mean * (1.0 - u).ln()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mix() -> WorkloadMix {
        WorkloadMix::new(vec![
            MixEntry { kind: ModelKind::TinyCnn, weight: 3.0, slo_cycles: ms_to_cycles(10.0) },
            MixEntry { kind: ModelKind::Mlp, weight: 1.0, slo_cycles: ms_to_cycles(20.0) },
        ])
    }

    #[test]
    fn poisson_rate_matches_mean_gap() {
        let mut s = Source::poisson(mix(), 1000.0, 42);
        let n = 2000;
        let mut last = 0.0;
        let mut total = 0.0;
        for _ in 0..n {
            let r = s.pop();
            assert!(r.arrival >= last);
            total = r.arrival;
            last = r.arrival;
        }
        let mean_gap = total / n as f64;
        let expect = CLOCK_HZ / 1000.0;
        assert!(
            (mean_gap - expect).abs() / expect < 0.1,
            "mean gap {mean_gap:.0} vs expected {expect:.0}"
        );
        assert_eq!(s.emitted(), n);
    }

    #[test]
    fn mix_weights_respected() {
        let mut s = Source::poisson(mix(), 1000.0, 7);
        let mut tiny = 0u64;
        let n = 4000;
        for _ in 0..n {
            if s.pop().kind == ModelKind::TinyCnn {
                tiny += 1;
            }
        }
        let frac = tiny as f64 / n as f64;
        assert!((frac - 0.75).abs() < 0.05, "tiny fraction {frac:.2}");
    }

    #[test]
    fn deadlines_offset_by_slo() {
        let mut s = Source::poisson(mix(), 100.0, 1);
        for _ in 0..50 {
            let r = s.pop();
            let slo = r.deadline - r.arrival;
            let expect = match r.kind {
                ModelKind::TinyCnn => ms_to_cycles(10.0),
                _ => ms_to_cycles(20.0),
            };
            assert!((slo - expect).abs() < 1e-6);
        }
    }

    #[test]
    fn replay_walks_the_trace_once() {
        let mut s = Source::replay(mix(), &[1.0, 2.0, 3.0], 9);
        let a = s.pop().arrival;
        let b = s.pop().arrival;
        let c = s.pop().arrival;
        assert!((a - ms_to_cycles(1.0)).abs() < 1e-6);
        assert!((b - a - ms_to_cycles(2.0)).abs() < 1e-6);
        assert!((c - b - ms_to_cycles(3.0)).abs() < 1e-6);
        assert!(s.next_arrival_at().is_none());
    }

    #[test]
    fn closed_loop_waits_for_completion() {
        let mut s = Source::closed_loop(mix(), 2, 1.0, 2, 3);
        let r1 = s.pop();
        let r2 = s.pop();
        // Both clients are now in flight: no further arrivals.
        assert!(s.next_arrival_at().is_none());
        // Completing r1 re-arms its client one think time later.
        s.on_complete(r1.arrival + 100.0, &r1);
        let t = s.next_arrival_at().expect("client re-armed");
        assert!((t - (r1.arrival + 100.0 + ms_to_cycles(1.0))).abs() < 1e-6);
        let r3 = s.pop();
        assert_eq!(r3.client, r1.client);
        // Each client issues exactly two requests.
        s.on_complete(r3.arrival + 50.0, &r3);
        assert!(s.next_arrival_at().is_none());
        s.on_complete(r2.arrival + 50.0, &r2);
        let r4 = s.pop();
        assert_eq!(r4.client, r2.client);
        s.on_complete(r4.arrival + 50.0, &r4);
        assert!(s.next_arrival_at().is_none());
        assert_eq!(s.emitted(), 4);
    }

    #[test]
    fn client_trace_replays_timestamps_when_service_keeps_up() {
        // Two clients with recorded issue times; a fast service (instant
        // completions) never delays an issue past its recorded timestamp.
        let traces = vec![vec![1.0, 5.0, 9.0], vec![2.0, 3.0]];
        let mut s = Source::client_trace(mix(), &traces, 7);
        let mut issued = Vec::new();
        while s.next_arrival_at().is_some() {
            let r = s.pop();
            issued.push((r.client.unwrap(), cycles_to_ms(r.arrival)));
            s.on_complete(r.arrival, &r); // completes instantly
        }
        assert_eq!(s.emitted(), 5);
        let expect = [(0, 1.0), (1, 2.0), (1, 3.0), (0, 5.0), (0, 9.0)];
        for ((c, t), (ec, et)) in issued.iter().zip(expect.iter()) {
            assert_eq!(c, ec);
            assert!((t - et).abs() < 1e-9, "issued at {t} ms, trace says {et} ms");
        }
    }

    #[test]
    fn client_trace_defers_to_completion_under_pushback() {
        // One client, issues recorded at 1 ms and 2 ms. Its first request
        // completes only at 10 ms, so the second issue slips to 10 ms.
        let mut s = Source::client_trace(mix(), &[vec![1.0, 2.0]], 3);
        let r1 = s.pop();
        assert!(s.next_arrival_at().is_none(), "client is in flight");
        s.on_complete(ms_to_cycles(10.0), &r1);
        let t = s.next_arrival_at().expect("client re-armed");
        assert!((t - ms_to_cycles(10.0)).abs() < 1e-6);
        let r2 = s.pop();
        s.on_complete(r2.arrival + 1.0, &r2);
        assert!(s.next_arrival_at().is_none(), "trace exhausted");
        assert!(s.is_bounded());
        assert!(!s.is_open_loop());
    }

    #[test]
    fn ready_ties_go_to_the_lowest_client_index() {
        // Pins the documented tie-break of `earliest_ready`: Iterator::
        // min_by returns the FIRST of equally-minimum elements, i.e. the
        // lowest client index (the cluster determinism story leans on
        // stable tie-breaks everywhere).
        let mut s = Source::client_trace(mix(), &[vec![5.0], vec![5.0], vec![5.0]], 1);
        assert_eq!(s.pop().client, Some(0));
        assert_eq!(s.pop().client, Some(1));
        assert_eq!(s.pop().client, Some(2));
    }

    #[test]
    fn open_loop_predicate() {
        assert!(Source::poisson(mix(), 100.0, 1).is_open_loop());
        assert!(Source::replay(mix(), &[1.0], 1).is_open_loop());
        assert!(!Source::closed_loop(mix(), 1, 1.0, 1, 1).is_open_loop());
    }

    #[test]
    fn model_catalog_builds() {
        for kind in ModelKind::ALL {
            let m = kind.build(2);
            assert!(!m.layers.is_empty(), "{} has layers", kind.label());
            assert!(m.total_macs() > 0);
        }
    }
}
