//! Package fleets: routing policies and the discrete-event serving loop.
//!
//! A fleet is N (possibly heterogeneous) WIENNA/interposer packages, each
//! with its own admission [`QueueSet`]. Arrivals are routed to a package
//! by a pluggable [`RoutePolicy`]; each package dispatches homogeneous
//! batches chosen by the dynamic batcher (`serve::batcher`) from its EDF
//! model queue. The event loop advances simulated time from arrival to
//! completion events only — service times come from the memoized cost
//! model, so a multi-second traffic trace simulates in microseconds.

use super::batcher::{choose_batch, BatchCost, BatchDecision, BatcherConfig, CostCache};
use super::queue::QueueSet;
use super::request::{Request, Source};
use super::stats::ServeStats;
use crate::config::{DesignPoint, SystemConfig};
use crate::cost::CostEngine;
use crate::power::{BatchEnergy, DvfsLevel, FleetEnergy, PackageMeter, PowerConfig};
use crate::telemetry::{PhaseBreakdown, PhaseTotals, Recorder, SpanRecord};

/// Static description of one package in the fleet.
#[derive(Debug, Clone)]
pub struct PackageSpec {
    pub name: String,
    pub sys: SystemConfig,
    pub dp: DesignPoint,
    /// Per-chiplet double-buffer budget for inter-layer pipelining.
    pub local_buffer_bytes: u64,
}

impl PackageSpec {
    /// A Table-4 default package at `dp`.
    pub fn new(name: &str, dp: DesignPoint) -> Self {
        PackageSpec {
            name: name.to_string(),
            sys: SystemConfig::default(),
            dp,
            local_buffer_bytes: 512 * 1024,
        }
    }

    /// `count` identical Table-4 packages at `dp`.
    pub fn homogeneous(count: usize, dp: DesignPoint) -> Vec<PackageSpec> {
        (0..count).map(|i| PackageSpec::new(&format!("{}-{i}", dp.label()), dp)).collect()
    }

    /// A fully-custom package — the `search` subsystem varies every axis.
    pub fn custom(name: &str, sys: SystemConfig, dp: DesignPoint, local_buffer_bytes: u64) -> Self {
        PackageSpec { name: name.to_string(), sys, dp, local_buffer_bytes }
    }
}

/// Run-time state and accounting of one package.
#[derive(Debug)]
pub struct Package {
    pub spec: PackageSpec,
    pub(crate) engine: CostEngine,
    pub queue: QueueSet,
    /// Cycle at which the in-flight batch completes.
    busy_until: f64,
    in_flight: Vec<Request>,
    /// Cycle the in-flight batch started, and its full predicted cost —
    /// kept so a preemption can roll the un-run share of the accounting
    /// back (`Package::preempt_batch`).
    batch_start: f64,
    cur_cost: Option<BatchCost>,
    /// Makespan stretch (1/freq) of the in-flight batch's DVFS level.
    cur_stretch: f64,
    /// Runtime energy telemetry (`wienna::power`).
    pub meter: PackageMeter,
    /// Batch-1 estimate of queued work, for load-aware routing.
    backlog_cycles: f64,
    // --- accounting ---
    pub busy_cycles: f64,
    pub dist_busy_cycles: f64,
    pub compute_busy_cycles: f64,
    pub collect_busy_cycles: f64,
    pub batches_dispatched: u64,
    pub requests_completed: u64,
    pub batch_size_sum: u64,
    pub max_batch_seen: u64,
    /// Always-on cycle attribution of requests this package completed
    /// (`wienna::telemetry`).
    pub attr: PhaseTotals,
}

impl Package {
    pub fn new(spec: PackageSpec) -> Self {
        let engine = CostEngine::for_design_point(&spec.sys, spec.dp);
        Package {
            engine,
            spec,
            queue: QueueSet::new(),
            busy_until: 0.0,
            in_flight: Vec::new(),
            batch_start: 0.0,
            cur_cost: None,
            cur_stretch: 1.0,
            meter: PackageMeter::default(),
            backlog_cycles: 0.0,
            busy_cycles: 0.0,
            dist_busy_cycles: 0.0,
            compute_busy_cycles: 0.0,
            collect_busy_cycles: 0.0,
            batches_dispatched: 0,
            requests_completed: 0,
            batch_size_sum: 0,
            max_batch_seen: 0,
            attr: PhaseTotals::default(),
        }
    }

    pub fn is_idle(&self) -> bool {
        self.in_flight.is_empty()
    }

    pub fn in_flight_len(&self) -> usize {
        self.in_flight.len()
    }

    /// Mean dispatched batch size.
    pub fn mean_batch(&self) -> f64 {
        if self.batches_dispatched == 0 {
            0.0
        } else {
            self.batch_size_sum as f64 / self.batches_dispatched as f64
        }
    }

    /// Fraction of `elapsed` cycles the package was serving a batch.
    pub fn utilization(&self, elapsed: f64) -> f64 {
        if elapsed <= 0.0 {
            0.0
        } else {
            (self.busy_cycles / elapsed).min(1.0)
        }
    }

    /// Fraction of `elapsed` the distribution plane (wireless for WIENNA,
    /// interposer mesh for the baseline) was moving data.
    pub fn dist_plane_utilization(&self, elapsed: f64) -> f64 {
        if elapsed <= 0.0 {
            0.0
        } else {
            (self.dist_busy_cycles / elapsed).min(1.0)
        }
    }

    /// Fraction of `elapsed` the chiplet arrays were computing.
    pub fn compute_utilization(&self, elapsed: f64) -> f64 {
        if elapsed <= 0.0 {
            0.0
        } else {
            (self.compute_busy_cycles / elapsed).min(1.0)
        }
    }

    /// Work backlog (busy remainder + queued batch-1 estimates) at `now`.
    pub fn load_cycles(&self, now: f64) -> f64 {
        (self.busy_until - now).max(0.0) + self.backlog_cycles
    }

    /// Cycle at which the in-flight batch completes (stale when idle).
    pub fn busy_until(&self) -> f64 {
        self.busy_until
    }

    /// Grow the batch-1 backlog estimate by one admitted request.
    pub(crate) fn add_backlog(&mut self, cycles: f64) {
        self.backlog_cycles += cycles;
    }

    /// Shrink the backlog estimate after requests leave the queue.
    pub(crate) fn drain_backlog(&mut self, cycles: f64) {
        self.backlog_cycles = (self.backlog_cycles - cycles).max(0.0);
    }

    /// Start serving a dispatched batch: occupy the package until the
    /// predicted completion and record the busy-cycle and energy
    /// accounting. Both event loops (`Fleet::run` and the cluster's
    /// per-shard loop) funnel through here so their per-package accounting
    /// is identical. `level` is the governor's DVFS decision — it
    /// stretches the makespan by `1/freq` — and `energy` the batch's
    /// dynamic energy *already scaled* to that level. At
    /// [`DvfsLevel::NOMINAL`] every multiplier is exactly 1.0, so an
    /// ungoverned run's arithmetic is bit-identical to the pre-power one.
    pub(crate) fn begin_batch(
        &mut self,
        now: f64,
        decision: &BatchDecision,
        reqs: Vec<Request>,
        level: DvfsLevel,
        energy: BatchEnergy,
    ) {
        debug_assert!(self.in_flight.is_empty(), "package already serving a batch");
        debug_assert_eq!(reqs.len(), decision.batch as usize);
        let stretch = 1.0 / level.freq_scale;
        self.busy_until = now + decision.cost.latency * stretch;
        self.batch_start = now;
        self.cur_cost = Some(decision.cost);
        self.cur_stretch = stretch;
        self.busy_cycles += decision.cost.latency * stretch;
        self.dist_busy_cycles += decision.cost.dist_busy * stretch;
        self.compute_busy_cycles += decision.cost.compute_busy * stretch;
        self.collect_busy_cycles += decision.cost.collect_busy * stretch;
        self.meter.begin(energy, decision.cost.latency * stretch, !level.is_nominal());
        self.batches_dispatched += 1;
        self.batch_size_sum += decision.batch;
        self.max_batch_seen = self.max_batch_seen.max(decision.batch);
        self.in_flight = reqs;
    }

    /// Dispatch cycle and predicted cost of the in-flight batch — the
    /// inputs cycle attribution needs. Capture *before*
    /// [`Package::finish_batch`], which clears them.
    pub(crate) fn inflight_span(&self) -> Option<(f64, BatchCost)> {
        self.cur_cost.map(|c| (self.batch_start, c))
    }

    /// Complete the in-flight batch, returning its completion cycle and
    /// the served requests.
    pub(crate) fn finish_batch(&mut self) -> (f64, Vec<Request>) {
        let t = self.busy_until;
        let reqs = std::mem::take(&mut self.in_flight);
        self.requests_completed += reqs.len() as u64;
        self.cur_cost = None;
        self.meter.finish();
        (t, reqs)
    }

    /// Abort the in-flight batch at `now < busy_until`, rolling back the
    /// accounting for the share of the batch that never ran and returning
    /// its requests (plus the mJ of dynamic energy rolled back, so class
    /// attribution can subtract the same amount). The cycles and energy
    /// already burnt stay counted — preempted work is real (wasted) work,
    /// and the utilization and energy numbers must show it.
    pub(crate) fn preempt_batch(&mut self, now: f64) -> (Vec<Request>, f64) {
        debug_assert!(!self.in_flight.is_empty(), "nothing in flight to preempt");
        let cost = self.cur_cost.take().expect("in-flight batch has a recorded cost");
        let stretch = self.cur_stretch;
        let total = self.busy_until - self.batch_start;
        let done = if total > 0.0 { ((now - self.batch_start) / total).clamp(0.0, 1.0) } else { 1.0 };
        let undone = 1.0 - done;
        self.busy_cycles -= cost.latency * stretch * undone;
        self.dist_busy_cycles -= cost.dist_busy * stretch * undone;
        self.compute_busy_cycles -= cost.compute_busy * stretch * undone;
        self.collect_busy_cycles -= cost.collect_busy * stretch * undone;
        let rolled_mj = self.meter.rollback(undone);
        self.busy_until = now;
        (std::mem::take(&mut self.in_flight), rolled_mj)
    }
}

/// How arrivals are assigned to packages.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutePolicy {
    /// Cycle through packages in order.
    RoundRobin,
    /// Send to the package with the least pending work (busy remainder
    /// plus queued batch-1 estimates).
    LeastLoaded,
    /// SLO-aware: send to the package with the earliest estimated
    /// completion for this request (earliest-deadline-first service order
    /// is applied package-locally by the dispatcher).
    EarliestDeadline,
}

impl RoutePolicy {
    pub const ALL: [RoutePolicy; 3] =
        [RoutePolicy::RoundRobin, RoutePolicy::LeastLoaded, RoutePolicy::EarliestDeadline];

    pub fn label(&self) -> &'static str {
        match self {
            RoutePolicy::RoundRobin => "round-robin",
            RoutePolicy::LeastLoaded => "least-loaded",
            RoutePolicy::EarliestDeadline => "earliest-deadline",
        }
    }
}

/// A fleet of packages sharing a routing policy, a batcher configuration,
/// a power configuration (meter always on, governor only under a cap) and
/// one memoized cost cache.
pub struct Fleet {
    pub packages: Vec<Package>,
    pub policy: RoutePolicy,
    pub batcher: BatcherConfig,
    /// Energy metering + optional power-cap governor (`wienna::power`).
    /// The default has no cap: every batch runs at the nominal DVFS level
    /// and latency statistics are bit-identical to an unmetered run.
    pub power: PowerConfig,
    pub cache: CostCache,
    /// Opt-in request-span recorder (`wienna::telemetry`). `Off` by
    /// default: the hot path pays one discriminant check per batch.
    pub recorder: Recorder,
    rr_cursor: usize,
}

impl Fleet {
    pub fn new(specs: Vec<PackageSpec>, policy: RoutePolicy) -> Self {
        assert!(!specs.is_empty(), "fleet needs at least one package");
        Fleet {
            packages: specs.into_iter().map(Package::new).collect(),
            policy,
            batcher: BatcherConfig::default(),
            power: PowerConfig::default(),
            cache: CostCache::new(),
            recorder: Recorder::Off,
            rr_cursor: 0,
        }
    }

    pub fn with_batcher(mut self, batcher: BatcherConfig) -> Self {
        self.batcher = batcher;
        self
    }

    pub fn with_power(mut self, power: PowerConfig) -> Self {
        self.power = power;
        self
    }

    /// The governor's DVFS decision for a batch about to start: project
    /// the fleet's draw (leakage floor + in-flight dynamic power) and
    /// pick the fastest level that keeps it under the cap. Nominal when
    /// no cap is configured.
    fn governor_level(&self, cost: &BatchCost) -> DvfsLevel {
        let Some(cap) = self.power.cap_w else {
            return DvfsLevel::NOMINAL;
        };
        let floor: f64 =
            self.packages.iter().map(|p| self.power.model.active_leakage_w(&p.spec.sys)).sum();
        let inflight: f64 = self.packages.iter().map(|p| p.meter.inflight_w()).sum();
        self.power.choose_level(cap, floor, inflight, cost)
    }

    /// Requests sitting in admission queues.
    pub fn queued_total(&self) -> usize {
        self.packages.iter().map(|p| p.queue.depth_total()).sum()
    }

    /// Requests currently being served.
    pub fn in_flight_total(&self) -> usize {
        self.packages.iter().map(|p| p.in_flight.len()).sum()
    }

    /// Mean dispatched batch size across the fleet.
    pub fn mean_batch(&self) -> f64 {
        let batches: u64 = self.packages.iter().map(|p| p.batches_dispatched).sum();
        if batches == 0 {
            0.0
        } else {
            let sum: u64 = self.packages.iter().map(|p| p.batch_size_sum).sum();
            sum as f64 / batches as f64
        }
    }

    /// Estimate the fleet's sustainable throughput in requests/s for a
    /// traffic mix, with batches of `ref_batch` (used to calibrate offered
    /// load in the examples and the load-sweep bench).
    pub fn estimate_capacity_rps(&mut self, mix: &super::request::WorkloadMix, ref_batch: u64) -> f64 {
        let weight_total: f64 = mix.entries.iter().map(|e| e.weight).sum();
        let mut total_rps = 0.0;
        for i in 0..self.packages.len() {
            let mut cycles_per_req = 0.0;
            for e in &mix.entries {
                let c = self.cache.get(
                    &self.packages[i].engine,
                    self.packages[i].spec.dp,
                    e.kind,
                    ref_batch,
                    self.packages[i].spec.local_buffer_bytes,
                );
                cycles_per_req += (e.weight / weight_total) * c.latency / ref_batch as f64;
            }
            total_rps += crate::config::CLOCK_HZ / cycles_per_req;
        }
        total_rps
    }

    /// Route one arrival to a package queue.
    fn route(&mut self, now: f64, req: Request) {
        let idx = match self.policy {
            RoutePolicy::RoundRobin => {
                let i = self.rr_cursor % self.packages.len();
                self.rr_cursor += 1;
                i
            }
            RoutePolicy::LeastLoaded => {
                let mut best = 0;
                for i in 1..self.packages.len() {
                    if self.packages[i].load_cycles(now) < self.packages[best].load_cycles(now) {
                        best = i;
                    }
                }
                best
            }
            RoutePolicy::EarliestDeadline => {
                // Estimated completion of this request on each package:
                // current load plus its own batch-1 service time.
                let mut best = 0;
                let mut best_eta = f64::INFINITY;
                for i in 0..self.packages.len() {
                    let service = self
                        .cache
                        .get(
                            &self.packages[i].engine,
                            self.packages[i].spec.dp,
                            req.kind,
                            1,
                            self.packages[i].spec.local_buffer_bytes,
                        )
                        .latency;
                    let eta = now + self.packages[i].load_cycles(now) + service;
                    if eta < best_eta {
                        best_eta = eta;
                        best = i;
                    }
                }
                best
            }
        };
        let est = self
            .cache
            .get(
                &self.packages[idx].engine,
                self.packages[idx].spec.dp,
                req.kind,
                1,
                self.packages[idx].spec.local_buffer_bytes,
            )
            .latency;
        let p = &mut self.packages[idx];
        p.add_backlog(est);
        p.queue.push(req);
    }

    /// Dispatch one batch on an idle package with queued work.
    fn dispatch(&mut self, idx: usize, now: f64, stats: &mut ServeStats) {
        debug_assert!(self.packages[idx].is_idle());
        let Some(kind) = self.packages[idx].queue.edf_kind() else {
            return;
        };
        let depth = self.packages[idx].queue.depth(kind) as u64;
        let head_deadline = self.packages[idx].queue.head_deadline(kind).unwrap();
        let decision = choose_batch(
            &self.batcher,
            &mut self.cache,
            &self.packages[idx].engine,
            self.packages[idx].spec.dp,
            kind,
            depth,
            now,
            head_deadline,
            self.packages[idx].spec.local_buffer_bytes,
        );
        let est1 = self
            .cache
            .get(
                &self.packages[idx].engine,
                self.packages[idx].spec.dp,
                kind,
                1,
                self.packages[idx].spec.local_buffer_bytes,
            )
            .latency;
        let level = self.governor_level(&decision.cost);
        let energy = self.power.model.batch_dynamic(&decision.cost).scaled(level.energy_scale);
        let p = &mut self.packages[idx];
        let reqs = p.queue.pop_batch(kind, decision.batch as usize);
        debug_assert_eq!(reqs.len(), decision.batch as usize);
        p.drain_backlog(est1 * reqs.len() as f64);
        p.begin_batch(now, &decision, reqs, level, energy);
        stats.record_dispatch(decision.batch);
    }

    /// Complete the in-flight batch on `idx`.
    fn complete(&mut self, idx: usize, stats: &mut ServeStats, source: &mut Source) {
        let span = self.packages[idx].inflight_span();
        let (t, reqs) = self.packages[idx].finish_batch();
        let batch = reqs.len();
        for r in &reqs {
            stats.record_completion(r, t);
            source.on_complete(t, r);
            if let Some((dispatched, cost)) = span {
                let phases = PhaseBreakdown::attribute(r.arrival, dispatched, t, &cost);
                stats.attr.record(&phases);
                self.packages[idx].attr.record(&phases);
                if let Some(log) = self.recorder.log_mut() {
                    log.spans.push(SpanRecord {
                        id: r.id,
                        kind: r.kind,
                        class: None,
                        shard: 0,
                        package: idx,
                        batch,
                        arrival: r.arrival,
                        dispatched,
                        completed: t,
                        phases,
                    });
                }
            }
        }
    }

    /// Run the discrete-event loop: admit arrivals up to `horizon_cycles`,
    /// then drain every queued and in-flight request. Returns the cycle of
    /// the last event.
    ///
    /// An infinite horizon is only meaningful for sources that run dry on
    /// their own (trace replay, closed loop); an open-loop Poisson source
    /// would make the loop admit arrivals forever.
    pub fn run(&mut self, source: &mut Source, horizon_cycles: f64, stats: &mut ServeStats) -> f64 {
        assert!(
            horizon_cycles.is_finite() || source.is_bounded(),
            "an unbounded (Poisson) source needs a finite horizon"
        );
        let mut now = 0.0f64;
        loop {
            // Put every idle package with queued work to work.
            for i in 0..self.packages.len() {
                if self.packages[i].is_idle() && !self.packages[i].queue.is_empty() {
                    self.dispatch(i, now, stats);
                }
            }

            let next_arrival = source.next_arrival_at().filter(|&t| t <= horizon_cycles);
            let mut next_completion = f64::INFINITY;
            let mut completing = usize::MAX;
            for (i, p) in self.packages.iter().enumerate() {
                if !p.in_flight.is_empty() && p.busy_until < next_completion {
                    next_completion = p.busy_until;
                    completing = i;
                }
            }

            match next_arrival {
                Some(t) if t <= next_completion => {
                    now = now.max(t);
                    let req = source.pop();
                    stats.record_arrival(&req);
                    self.route(now, req);
                }
                _ if completing != usize::MAX => {
                    now = now.max(next_completion);
                    self.complete(completing, stats, source);
                }
                _ => break,
            }
        }
        stats.finish(now);
        stats.energy = Some(FleetEnergy::collect(&self.packages, now, &self.power.model));
        now
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::request::{ms_to_cycles, MixEntry, ModelKind, WorkloadMix};

    fn tiny_mix(slo_ms: f64) -> WorkloadMix {
        WorkloadMix::new(vec![MixEntry {
            kind: ModelKind::TinyCnn,
            weight: 1.0,
            slo_cycles: ms_to_cycles(slo_ms),
        }])
    }

    fn run_at(load: f64, policy: RoutePolicy) -> (Fleet, ServeStats) {
        let mut fleet = Fleet::new(PackageSpec::homogeneous(2, DesignPoint::WIENNA_C), policy);
        let mix = tiny_mix(50.0);
        let cap = fleet.estimate_capacity_rps(&mix, 8);
        let mut source = Source::poisson(mix, cap * load, 11);
        let mut stats = ServeStats::new();
        fleet.run(&mut source, ms_to_cycles(20.0), &mut stats);
        (fleet, stats)
    }

    #[test]
    fn conservation_invariant_holds() {
        for policy in RoutePolicy::ALL {
            let (fleet, stats) = run_at(0.8, policy);
            // The run drains: everything admitted was completed.
            assert_eq!(fleet.queued_total(), 0, "{}", policy.label());
            assert_eq!(fleet.in_flight_total(), 0, "{}", policy.label());
            assert_eq!(stats.arrived(), stats.completed(), "{}", policy.label());
            assert!(stats.arrived() > 0);
            // Per-package accounting adds back up to the fleet totals.
            let by_pkg: u64 = fleet.packages.iter().map(|p| p.requests_completed).sum();
            assert_eq!(by_pkg, stats.completed());
            let admitted: u64 = fleet.packages.iter().map(|p| p.queue.arrived).sum();
            assert_eq!(admitted, stats.arrived());
        }
    }

    #[test]
    fn batch_grows_with_load() {
        let (low_fleet, _) = run_at(0.2, RoutePolicy::LeastLoaded);
        let (high_fleet, _) = run_at(1.6, RoutePolicy::LeastLoaded);
        assert!(
            high_fleet.mean_batch() > low_fleet.mean_batch(),
            "mean batch {:.2} (overload) vs {:.2} (light)",
            high_fleet.mean_batch(),
            low_fleet.mean_batch()
        );
    }

    #[test]
    fn round_robin_spreads_work() {
        let (fleet, _) = run_at(0.8, RoutePolicy::RoundRobin);
        let a = fleet.packages[0].queue.arrived;
        let b = fleet.packages[1].queue.arrived;
        assert!(a.abs_diff(b) <= 1, "round-robin admitted {a} vs {b}");
    }

    #[test]
    fn least_loaded_beats_round_robin_on_hetero_fleet() {
        // One fast wireless package + one slow interposer package: load
        // awareness must not split arrivals 50/50.
        let specs = vec![
            PackageSpec::new("fast", DesignPoint::WIENNA_A),
            PackageSpec::new("slow", DesignPoint::INTERPOSER_C),
        ];
        let mut fleet = Fleet::new(specs, RoutePolicy::LeastLoaded);
        let mix = tiny_mix(50.0);
        let cap = fleet.estimate_capacity_rps(&mix, 8);
        let mut source = Source::poisson(mix, cap * 0.9, 5);
        let mut stats = ServeStats::new();
        fleet.run(&mut source, ms_to_cycles(20.0), &mut stats);
        let fast = fleet.packages[0].requests_completed;
        let slow = fleet.packages[1].requests_completed;
        assert!(fast > slow, "fast {fast} vs slow {slow}");
    }

    #[test]
    fn drains_leftover_queue_after_horizon() {
        // Overload: queues are non-empty at the horizon, and run() must
        // still drain them (completions after the horizon).
        let (fleet, stats) = run_at(3.0, RoutePolicy::EarliestDeadline);
        assert_eq!(fleet.queued_total(), 0);
        assert_eq!(stats.arrived(), stats.completed());
        assert!(stats.end_cycle() > ms_to_cycles(20.0));
    }

    #[test]
    fn energy_is_metered_and_additive() {
        let (fleet, stats) = run_at(0.8, RoutePolicy::LeastLoaded);
        let e = stats.energy.expect("Fleet::run meters energy");
        assert!(e.dynamic_mj() > 0.0 && e.leakage_mj > 0.0);
        assert_eq!(e.throttled_batches, 0, "no cap, no throttling");
        // Fleet totals equal the sum of package meters (same order).
        let by_pkg: f64 = fleet.packages.iter().map(|p| p.meter.dynamic_mj()).sum();
        assert!((e.dynamic_mj() - by_pkg).abs() < 1e-9 * by_pkg.max(1.0));
        assert!(e.energy_per_req_j(stats.completed()) > 0.0);
        assert!(e.avg_power_w(stats.end_cycle()) > 0.0);
    }

    #[test]
    fn generous_cap_leaves_latency_identical() {
        // A cap far above the fleet's draw engages the governor plumbing
        // but never throttles: every latency statistic must be *exactly*
        // what the ungoverned run produces.
        let (_, base) = run_at(0.9, RoutePolicy::EarliestDeadline);
        let mut fleet = Fleet::new(
            PackageSpec::homogeneous(2, DesignPoint::WIENNA_C),
            RoutePolicy::EarliestDeadline,
        )
        .with_power(crate::power::PowerConfig::with_cap(1e6));
        let mix = tiny_mix(50.0);
        let cap = fleet.estimate_capacity_rps(&mix, 8);
        let mut source = Source::poisson(mix, cap * 0.9, 11);
        let mut stats = ServeStats::new();
        fleet.run(&mut source, ms_to_cycles(20.0), &mut stats);
        assert_eq!(stats.end_cycle(), base.end_cycle());
        assert_eq!(stats.latency_ms(50.0), base.latency_ms(50.0));
        assert_eq!(stats.latency_ms(99.0), base.latency_ms(99.0));
        assert_eq!(stats.completed(), base.completed());
        assert_eq!(stats.energy.unwrap().throttled_batches, 0);
    }

    #[test]
    fn tight_cap_throttles_and_cuts_dynamic_energy() {
        let run_capped = |cap_w: Option<f64>| {
            let mut fleet = Fleet::new(
                PackageSpec::homogeneous(2, DesignPoint::WIENNA_C),
                RoutePolicy::EarliestDeadline,
            );
            if let Some(w) = cap_w {
                fleet.power = crate::power::PowerConfig::with_cap(w);
            }
            let mix = tiny_mix(50.0);
            let cap = fleet.estimate_capacity_rps(&mix, 8);
            let mut source = Source::poisson(mix, cap * 0.9, 11);
            let mut stats = ServeStats::new();
            fleet.run(&mut source, ms_to_cycles(20.0), &mut stats);
            stats
        };
        let base = run_capped(None);
        let e0 = base.energy.unwrap();
        let p0 = e0.avg_power_w(base.end_cycle());
        let capped = run_capped(Some(p0 * 0.5));
        let e1 = capped.energy.unwrap();
        assert!(e1.throttled_batches > 0, "a 0.5x cap must throttle");
        // Both runs drain the same arrivals; throttled batches burn less
        // dynamic energy (V² scaling) but finish later.
        assert_eq!(base.completed(), capped.completed());
        assert!(e1.dynamic_mj() < e0.dynamic_mj(), "{} vs {}", e1.dynamic_mj(), e0.dynamic_mj());
        assert!(capped.end_cycle() >= base.end_cycle());
        assert!(capped.latency_ms(99.0) >= base.latency_ms(99.0));
    }

    #[test]
    fn utilization_rises_with_load() {
        let (low, ls) = run_at(0.2, RoutePolicy::LeastLoaded);
        let (high, hs) = run_at(1.2, RoutePolicy::LeastLoaded);
        let u_low: f64 =
            low.packages.iter().map(|p| p.utilization(ls.end_cycle())).sum::<f64>() / 2.0;
        let u_high: f64 =
            high.packages.iter().map(|p| p.utilization(hs.end_cycle())).sum::<f64>() / 2.0;
        assert!(u_high > u_low, "util {u_high:.2} vs {u_low:.2}");
    }
}
