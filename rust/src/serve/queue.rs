//! Per-model FIFO admission queues with conservation counters.
//!
//! Each package owns one [`QueueSet`]: requests are FIFO within a model
//! (batches must be homogeneous in model), and the dispatcher picks the
//! model whose head-of-line request has the earliest deadline (EDF across
//! queues, FIFO within a queue).
//!
//! Storage is struct-of-arrays: one [`Lane`] per model keeps the request
//! fields in parallel `VecDeque`s (the model kind is implied by the
//! lane), so the dispatcher's hot probes — `edf_kind` reading only head
//! deadlines and ids, `depth_total` reading a maintained counter — touch
//! exactly the bytes they need instead of walking whole `Request`
//! structs. `Request` values are materialized only at the API boundary
//! (`pop_batch`, `pop_newest`), which the callers consume by move.

use super::request::{ModelKind, Request};
use std::collections::VecDeque;

/// One model's FIFO lane, struct-of-arrays: index *i* across the four
/// deques is one queued request. The model kind lives on the owning
/// `(ModelKind, Lane)` pair, not per element.
#[derive(Debug, Default)]
struct Lane {
    ids: VecDeque<u64>,
    arrivals: VecDeque<f64>,
    deadlines: VecDeque<f64>,
    clients: VecDeque<Option<usize>>,
}

impl Lane {
    fn len(&self) -> usize {
        self.ids.len()
    }

    fn is_empty(&self) -> bool {
        self.ids.is_empty()
    }

    fn push_back(&mut self, req: Request) {
        self.ids.push_back(req.id);
        self.arrivals.push_back(req.arrival);
        self.deadlines.push_back(req.deadline);
        self.clients.push_back(req.client);
    }

    fn push_front(&mut self, req: Request) {
        self.ids.push_front(req.id);
        self.arrivals.push_front(req.arrival);
        self.deadlines.push_front(req.deadline);
        self.clients.push_front(req.client);
    }

    fn pop_front(&mut self, kind: ModelKind) -> Option<Request> {
        Some(Request {
            id: self.ids.pop_front()?,
            kind,
            arrival: self.arrivals.pop_front().expect("lanes stay parallel"),
            deadline: self.deadlines.pop_front().expect("lanes stay parallel"),
            client: self.clients.pop_front().expect("lanes stay parallel"),
        })
    }

    fn pop_back(&mut self, kind: ModelKind) -> Option<Request> {
        Some(Request {
            id: self.ids.pop_back()?,
            kind,
            arrival: self.arrivals.pop_back().expect("lanes stay parallel"),
            deadline: self.deadlines.pop_back().expect("lanes stay parallel"),
            client: self.clients.pop_back().expect("lanes stay parallel"),
        })
    }

    /// The back element materialized (for the steal pass's peek).
    fn back(&self, kind: ModelKind) -> Option<Request> {
        let i = self.len().checked_sub(1)?;
        Some(Request {
            id: self.ids[i],
            kind,
            arrival: self.arrivals[i],
            deadline: self.deadlines[i],
            client: self.clients[i],
        })
    }
}

/// A set of per-model FIFO queues.
#[derive(Debug, Default)]
pub struct QueueSet {
    lanes: Vec<(ModelKind, Lane)>,
    /// Total queued across lanes, maintained on every mutation so
    /// `depth_total` — probed by the dispatcher, the steal pass, and the
    /// epoch sampler — is O(1).
    depth: usize,
    /// Requests ever admitted to this queue set.
    pub arrived: u64,
    /// Largest total depth observed.
    pub peak_depth: usize,
}

impl QueueSet {
    pub fn new() -> Self {
        QueueSet::default()
    }

    fn lane_mut(&mut self, kind: ModelKind) -> &mut Lane {
        if let Some(pos) = self.lanes.iter().position(|(k, _)| *k == kind) {
            &mut self.lanes[pos].1
        } else {
            self.lanes.push((kind, Lane::default()));
            &mut self.lanes.last_mut().unwrap().1
        }
    }

    /// Admit one request (FIFO within its model queue).
    pub fn push(&mut self, req: Request) {
        self.arrived += 1;
        self.lane_mut(req.kind).push_back(req);
        self.depth += 1;
        if self.depth > self.peak_depth {
            self.peak_depth = self.depth;
        }
    }

    /// Queued requests for one model.
    pub fn depth(&self, kind: ModelKind) -> usize {
        self.lanes.iter().find(|(k, _)| *k == kind).map_or(0, |(_, q)| q.len())
    }

    /// Queued requests across all models.
    pub fn depth_total(&self) -> usize {
        self.depth
    }

    pub fn is_empty(&self) -> bool {
        self.depth == 0
    }

    /// The model whose head-of-line request has the earliest deadline.
    ///
    /// Equal head deadlines are broken by the head request's arrival
    /// sequence (`Request::id`), never by queue-vector position: position
    /// depends on which model happened to arrive at this package first,
    /// so sharded layouts that split the same stream differently would
    /// otherwise dispatch in different orders (the cluster determinism
    /// guarantee forbids that).
    pub fn edf_kind(&self) -> Option<ModelKind> {
        self.lanes
            .iter()
            .filter(|(_, q)| !q.is_empty())
            .min_by(|a, b| {
                a.1.deadlines[0]
                    .partial_cmp(&b.1.deadlines[0])
                    .expect("deadlines are never NaN")
                    .then(a.1.ids[0].cmp(&b.1.ids[0]))
            })
            .map(|(k, _)| *k)
    }

    /// Deadline of the head-of-line request for `kind`.
    pub fn head_deadline(&self, kind: ModelKind) -> Option<f64> {
        self.lanes
            .iter()
            .find(|(k, _)| *k == kind)
            .and_then(|(_, q)| q.deadlines.front())
            .copied()
    }

    /// Pop up to `n` requests of `kind` in FIFO order.
    pub fn pop_batch(&mut self, kind: ModelKind, n: usize) -> Vec<Request> {
        let lane = self.lane_mut(kind);
        let take = n.min(lane.len());
        let mut out = Vec::with_capacity(take);
        for _ in 0..take {
            out.push(lane.pop_front(kind).expect("take clamped to lane length"));
        }
        self.depth -= take;
        out
    }

    /// The most recently admitted queued request (largest arrival seq
    /// across all model queues) — what [`QueueSet::pop_newest`] would
    /// remove. The cluster's steal pass peeks here to price a candidate
    /// move before committing it; the two must select identically.
    pub fn peek_newest(&self) -> Option<Request> {
        self.lanes.iter().filter_map(|(k, q)| q.back(*k)).max_by_key(|r| r.id)
    }

    /// Remove and return the most recently admitted request (largest
    /// arrival seq across all model queues) — the push-out victim when a
    /// higher-priority arrival displaces queued lower-class work, and the
    /// transfer unit of the cluster's epoch-barrier work stealing.
    pub fn pop_newest(&mut self) -> Option<Request> {
        let pos = self
            .lanes
            .iter()
            .enumerate()
            .filter(|(_, (_, q))| !q.is_empty())
            .max_by_key(|(_, (_, q))| q.ids.back().copied().unwrap_or(0))
            .map(|(i, _)| i)?;
        let kind = self.lanes[pos].0;
        let req = self.lanes[pos].1.pop_back(kind);
        if req.is_some() {
            self.depth -= 1;
        }
        req
    }

    /// Return preempted requests to the *front* of their model queues so
    /// they are re-dispatched before anything that arrived after them.
    /// Unlike [`QueueSet::push`] this does not count a new admission —
    /// the requests were admitted once already.
    pub fn requeue_front(&mut self, reqs: Vec<Request>) {
        // Reverse so the earliest request of the preempted batch ends up
        // back at the very head of its queue.
        for req in reqs.into_iter().rev() {
            self.lane_mut(req.kind).push_front(req);
            self.depth += 1;
        }
        if self.depth > self.peak_depth {
            self.peak_depth = self.depth;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, kind: ModelKind, arrival: f64, slo: f64) -> Request {
        Request { id, kind, arrival, deadline: arrival + slo, client: None }
    }

    #[test]
    fn fifo_within_model() {
        let mut q = QueueSet::new();
        for i in 0..5 {
            q.push(req(i, ModelKind::TinyCnn, i as f64, 100.0));
        }
        let batch = q.pop_batch(ModelKind::TinyCnn, 3);
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2]);
        assert_eq!(q.depth(ModelKind::TinyCnn), 2);
        assert_eq!(q.arrived, 5);
    }

    #[test]
    fn edf_picks_earliest_head_deadline() {
        let mut q = QueueSet::new();
        q.push(req(0, ModelKind::TinyCnn, 0.0, 1000.0)); // deadline 1000
        q.push(req(1, ModelKind::Mlp, 10.0, 500.0)); // deadline 510
        assert_eq!(q.edf_kind(), Some(ModelKind::Mlp));
        assert_eq!(q.head_deadline(ModelKind::Mlp), Some(510.0));
        q.pop_batch(ModelKind::Mlp, 1);
        assert_eq!(q.edf_kind(), Some(ModelKind::TinyCnn));
    }

    #[test]
    fn pop_batch_clamps_to_depth() {
        let mut q = QueueSet::new();
        q.push(req(0, ModelKind::TinyCnn, 0.0, 1.0));
        let batch = q.pop_batch(ModelKind::TinyCnn, 8);
        assert_eq!(batch.len(), 1);
        assert!(q.is_empty());
        assert!(q.pop_batch(ModelKind::Mlp, 4).is_empty());
    }

    #[test]
    fn edf_tie_breaks_on_arrival_seq_not_queue_position() {
        // Two models whose heads share an identical deadline. Whichever
        // request arrived first (lower id) must win, regardless of the
        // order the model queues were created in.
        let mut a = QueueSet::new();
        a.push(req(7, ModelKind::TinyCnn, 50.0, 100.0)); // deadline 150, later arrival
        a.push(req(3, ModelKind::Mlp, 50.0, 100.0)); // deadline 150, earlier id
        assert_eq!(a.edf_kind(), Some(ModelKind::Mlp));

        // Same requests, opposite insertion order: same winner.
        let mut b = QueueSet::new();
        b.push(req(3, ModelKind::Mlp, 50.0, 100.0));
        b.push(req(7, ModelKind::TinyCnn, 50.0, 100.0));
        assert_eq!(b.edf_kind(), Some(ModelKind::Mlp));
    }

    #[test]
    fn pop_newest_takes_the_latest_admission_across_models() {
        let mut q = QueueSet::new();
        q.push(req(0, ModelKind::TinyCnn, 0.0, 100.0));
        q.push(req(5, ModelKind::Mlp, 1.0, 100.0));
        q.push(req(3, ModelKind::TinyCnn, 2.0, 100.0));
        // peek and pop must agree at every step (the steal pass prices
        // the peeked candidate, then pops it).
        assert_eq!(q.peek_newest().map(|r| r.id), Some(5));
        assert_eq!(q.pop_newest().map(|r| r.id), Some(5));
        assert_eq!(q.peek_newest().map(|r| r.id), Some(3));
        assert_eq!(q.pop_newest().map(|r| r.id), Some(3));
        assert_eq!(q.pop_newest().map(|r| r.id), Some(0));
        assert!(q.pop_newest().is_none());
        assert!(q.peek_newest().is_none());
    }

    #[test]
    fn requeue_front_restores_fifo_without_recounting() {
        let mut q = QueueSet::new();
        for i in 0..4 {
            q.push(req(i, ModelKind::TinyCnn, i as f64, 100.0));
        }
        let batch = q.pop_batch(ModelKind::TinyCnn, 2); // ids 0, 1
        assert_eq!(q.arrived, 4);
        q.requeue_front(batch);
        assert_eq!(q.arrived, 4, "requeue must not count a new admission");
        let again = q.pop_batch(ModelKind::TinyCnn, 4);
        assert_eq!(again.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2, 3]);
    }

    #[test]
    fn peak_depth_tracks_high_water_mark() {
        let mut q = QueueSet::new();
        for i in 0..4 {
            q.push(req(i, ModelKind::TinyCnn, 0.0, 1.0));
        }
        q.pop_batch(ModelKind::TinyCnn, 4);
        q.push(req(9, ModelKind::TinyCnn, 0.0, 1.0));
        assert_eq!(q.peak_depth, 4);
        assert_eq!(q.depth_total(), 1);
    }
}
