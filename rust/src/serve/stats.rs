//! Serving statistics: tail latency, goodput, SLO violations, batches.
//!
//! Latency percentiles have two modes. The exact path records every
//! completion in a `Vec` and answers nearest-rank percentiles off a
//! sorted view — the test oracle. The bounded path (`--bounded-stats`)
//! streams every sample into a mergeable
//! [`telemetry::sketch::QuantileSketch`](crate::telemetry::QuantileSketch)
//! instead: O(buckets) memory no matter how many requests the run
//! serves, within the configured relative error ε of the exact answer
//! (`--quantile-error`, default 1%). Sketches merge exactly, so the
//! cluster's per-shard sketches can be absorbed at the sync barrier
//! without any quantile drift.

use super::request::{cycles_to_ms, ModelKind, Request};
use crate::config::CLOCK_HZ;
use crate::telemetry::{QuantileSketch, DEFAULT_QUANTILE_ERROR};
use std::collections::BTreeMap;

/// Latency sample recorder: exact (`Vec`-backed, the default) or
/// bounded (histogram-backed, constant memory).
#[derive(Debug, Default, Clone)]
pub struct LatencyRecorder {
    samples: Vec<f64>,
    /// Lazily sorted view, built at most once per recorder state (pushes
    /// invalidate it) so querying p50/p95/p99/p100 sorts only once.
    sorted: std::cell::OnceCell<Vec<f64>>,
    /// Bounded mode: the quantile sketch replaces `samples` entirely
    /// (the Vec never grows), percentiles come from
    /// `QuantileSketch::quantile` within its relative-error bound.
    sketch: Option<Box<QuantileSketch>>,
    /// Exact running max for bounded mode (`f64::max` skips the NaN
    /// seed on the first sample).
    max: f64,
}

impl LatencyRecorder {
    pub fn new() -> Self {
        LatencyRecorder::default()
    }

    /// A bounded-memory recorder: O(buckets), not O(samples), at the
    /// default sketch resolution.
    pub fn bounded() -> Self {
        Self::bounded_with(DEFAULT_QUANTILE_ERROR)
    }

    /// A bounded-memory recorder with relative quantile error ≤ `eps`.
    pub fn bounded_with(eps: f64) -> Self {
        LatencyRecorder {
            sketch: Some(Box::new(QuantileSketch::new(eps))),
            max: f64::NAN,
            ..Default::default()
        }
    }

    /// Whether this recorder is sketch-backed.
    pub fn is_bounded(&self) -> bool {
        self.sketch.is_some()
    }

    /// Merge a shard-local sketch into this (bounded) recorder — the
    /// cluster sync barrier's absorption path. Exact: bucket counts add
    /// as integers, so quantiles match a single-recorder run bit for
    /// bit regardless of shard count or merge order.
    pub fn absorb_sketch(&mut self, other: &QuantileSketch) {
        let sk = self.sketch.as_mut().expect("absorb_sketch on an exact recorder");
        sk.merge(other);
        let m = other.max();
        if !m.is_nan() {
            self.max = self.max.max(m);
        }
    }

    /// How many samples sit in the exact `Vec` — stays 0 for the whole
    /// life of a bounded recorder (bench-guarded in `perf_hotpath`).
    pub fn exact_samples(&self) -> usize {
        self.samples.len()
    }

    /// The backing sketch of a bounded recorder (`None` in exact mode) —
    /// the artifact export serializes it so `wienna report` can answer
    /// quantiles at sketch resolution instead of the coarser
    /// power-of-two histogram buckets.
    pub fn sketch(&self) -> Option<&QuantileSketch> {
        self.sketch.as_deref()
    }

    pub fn push(&mut self, v: f64) {
        if let Some(sk) = &mut self.sketch {
            sk.record(v);
            self.max = self.max.max(v);
            return;
        }
        self.samples.push(v);
        self.sorted = std::cell::OnceCell::new();
    }

    pub fn len(&self) -> usize {
        match &self.sketch {
            Some(sk) => sk.count() as usize,
            None => self.samples.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    fn sorted(&self) -> &[f64] {
        self.sorted.get_or_init(|| {
            let mut s = self.samples.clone();
            // `total_cmp`, not `partial_cmp(..).unwrap()`: a NaN sample
            // (e.g. a degenerate latency) must not panic the whole run —
            // the IEEE total order sorts NaNs after every finite value.
            s.sort_by(f64::total_cmp);
            s
        })
    }

    /// Nearest-rank percentile: the smallest sample such that at least
    /// `p`% of samples are `<=` it. `NaN` when no samples were recorded.
    /// Bounded recorders answer from the sketch — same rank, value
    /// interpolated within its sub-bucket (relative error ≤ ε).
    pub fn percentile(&self, p: f64) -> f64 {
        assert!((0.0..=100.0).contains(&p), "percentile out of range: {p}");
        if let Some(sk) = &self.sketch {
            return sk.quantile(p);
        }
        if self.samples.is_empty() {
            return f64::NAN;
        }
        let sorted = self.sorted();
        let n = sorted.len();
        let rank = ((p / 100.0) * n as f64).ceil() as usize;
        sorted[rank.clamp(1, n) - 1]
    }

    pub fn mean(&self) -> f64 {
        if let Some(sk) = &self.sketch {
            return sk.mean();
        }
        if self.samples.is_empty() {
            f64::NAN
        } else {
            self.samples.iter().sum::<f64>() / self.samples.len() as f64
        }
    }

    pub fn max(&self) -> f64 {
        match &self.sketch {
            Some(_) => self.max,
            None => self.samples.iter().copied().fold(f64::NAN, f64::max),
        }
    }
}

/// Per-model serving counters.
#[derive(Debug, Default, Clone)]
pub struct ModelStats {
    /// Completion latencies in cycles.
    pub latency: LatencyRecorder,
    pub arrived: u64,
    pub completed: u64,
    pub slo_met: u64,
    pub slo_violated: u64,
    /// Requests refused by admission control (queue cap or deadline-aware
    /// load shedding; always 0 for plain `Fleet::run`, which admits
    /// everything).
    pub shed: u64,
    /// Requests that failed terminally under fault injection — dispatch
    /// died, retries exhausted, or stranded on dead hardware
    /// (`wienna::fault`; always 0 without a fault plan).
    pub failed: u64,
}

impl ModelStats {
    /// Stats whose latency recorder matches the run's memory mode, at
    /// the default sketch resolution.
    pub fn with_mode(bounded: bool) -> Self {
        Self::with_error(bounded, DEFAULT_QUANTILE_ERROR)
    }

    /// Stats whose bounded-mode recorder uses quantile error ≤ `eps`.
    pub fn with_error(bounded: bool, eps: f64) -> Self {
        ModelStats {
            latency: if bounded { LatencyRecorder::bounded_with(eps) } else { LatencyRecorder::new() },
            ..Default::default()
        }
    }

    /// Record one completion at `cycle` against `req`'s deadline. The
    /// single definition of "met the SLO" — fleet-level, per-model and
    /// the cluster's per-class accounting all funnel through here.
    pub fn record_completion(&mut self, req: &Request, cycle: f64) {
        self.latency.push(cycle - req.arrival);
        self.record_completion_counters(req, cycle);
    }

    /// The counter half of [`Self::record_completion`] — no latency
    /// push. The cluster's bounded mode books completions through this
    /// and absorbs the latency later as a whole per-shard sketch.
    pub fn record_completion_counters(&mut self, req: &Request, cycle: f64) {
        self.completed += 1;
        if cycle <= req.deadline {
            self.slo_met += 1;
        } else {
            self.slo_violated += 1;
        }
    }
}

/// Fleet-wide serving statistics for one run.
#[derive(Debug, Default)]
pub struct ServeStats {
    pub per_model: BTreeMap<ModelKind, ModelStats>,
    all: ModelStats,
    /// Histogram of dispatched batch sizes.
    pub batch_hist: BTreeMap<u64, u64>,
    /// The run's energy summary (`wienna::power`): per-batch dynamic
    /// energy plus the leakage integral. Set by `Fleet::run` at the end
    /// of the run; purely additive — no latency statistic depends on it.
    pub energy: Option<crate::power::FleetEnergy>,
    /// Always-on cycle attribution over every completed request
    /// (`wienna::telemetry`): where the end-to-end cycles went —
    /// queueing, NoP distribution, compute, collection, DVFS throttle.
    pub attr: crate::telemetry::PhaseTotals,
    dispatches: u64,
    end_cycle: f64,
    /// `--bounded-stats`: every latency recorder (aggregate and
    /// per-model, including ones lazily created later) is
    /// sketch-backed.
    bounded: bool,
    /// Sketch resolution for bounded recorders (`--quantile-error`);
    /// only consulted when `bounded` is set.
    quantile_error: f64,
}

impl ServeStats {
    pub fn new() -> Self {
        ServeStats::default()
    }

    /// Stats in bounded-memory mode: O(buckets) latency recorders at
    /// the default sketch resolution.
    pub fn bounded() -> Self {
        Self::bounded_with(DEFAULT_QUANTILE_ERROR)
    }

    /// Bounded-memory stats with quantile error ≤ `quantile_error`.
    pub fn bounded_with(quantile_error: f64) -> Self {
        ServeStats {
            all: ModelStats::with_error(true, quantile_error),
            bounded: true,
            quantile_error,
            ..Default::default()
        }
    }

    /// Whether the latency recorders are histogram-backed.
    pub fn is_bounded(&self) -> bool {
        self.bounded
    }

    /// Exact `Vec` samples held across all recorders — stays 0 for a
    /// bounded run (the `perf_hotpath` allocation guard).
    pub fn exact_samples(&self) -> usize {
        self.all.latency.exact_samples()
            + self.per_model.values().map(|m| m.latency.exact_samples()).sum::<usize>()
    }

    /// A per-model entry in this run's memory mode.
    fn model_entry(&mut self, kind: ModelKind) -> &mut ModelStats {
        let bounded = self.bounded;
        let eps = self.quantile_error;
        self.per_model.entry(kind).or_insert_with(|| ModelStats::with_error(bounded, eps))
    }

    pub fn record_arrival(&mut self, req: &Request) {
        self.all.arrived += 1;
        self.model_entry(req.kind).arrived += 1;
    }

    pub fn record_dispatch(&mut self, batch: u64) {
        self.record_dispatches(batch, 1);
    }

    /// Record `n` dispatches of the same batch size at once (the cluster
    /// merge folds whole per-shard histograms in).
    pub fn record_dispatches(&mut self, batch: u64, n: u64) {
        self.dispatches += n;
        *self.batch_hist.entry(batch).or_insert(0) += n;
    }

    pub fn record_completion(&mut self, req: &Request, completion_cycle: f64) {
        self.all.record_completion(req, completion_cycle);
        self.model_entry(req.kind).record_completion(req, completion_cycle);
    }

    /// Counter-only completion (no latency push) — the cluster's
    /// bounded mode, where latencies arrive later as per-shard sketches
    /// via [`Self::absorb_latency_sketch`].
    pub fn record_completion_counters(&mut self, req: &Request, completion_cycle: f64) {
        self.all.record_completion_counters(req, completion_cycle);
        self.model_entry(req.kind).record_completion_counters(req, completion_cycle);
    }

    /// Merge a shard-local latency sketch into the aggregate recorder
    /// (bounded mode only).
    pub fn absorb_latency_sketch(&mut self, sk: &QuantileSketch) {
        self.all.latency.absorb_sketch(sk);
    }

    /// Merge a shard-local per-model latency sketch (bounded mode only).
    pub fn absorb_model_latency_sketch(&mut self, kind: ModelKind, sk: &QuantileSketch) {
        self.model_entry(kind).latency.absorb_sketch(sk);
    }

    /// The aggregate latency sketch (`--bounded-stats` only; `None` in
    /// exact mode) — exported into metrics artifacts at full sketch
    /// resolution.
    pub fn latency_sketch(&self) -> Option<&QuantileSketch> {
        self.all.latency.sketch()
    }

    /// Record a request refused by admission control. The request still
    /// counts as arrived (record both), so
    /// `arrived == completed + shed + failed` holds after a drained run.
    pub fn record_shed(&mut self, req: &Request) {
        self.all.shed += 1;
        self.model_entry(req.kind).shed += 1;
    }

    /// Record a request that failed terminally under fault injection
    /// (dispatch died and every retry was exhausted, or it was stranded
    /// on dead hardware). Counts toward the same conservation identity as
    /// sheds: `arrived == completed + shed + failed`.
    pub fn record_failed(&mut self, req: &Request) {
        self.all.failed += 1;
        self.model_entry(req.kind).failed += 1;
    }

    /// Mark the end of the run (cycle of the last event).
    pub fn finish(&mut self, end_cycle: f64) {
        self.end_cycle = end_cycle;
    }

    pub fn arrived(&self) -> u64 {
        self.all.arrived
    }

    /// Requests refused by admission control.
    pub fn shed(&self) -> u64 {
        self.all.shed
    }

    /// Requests that failed terminally under fault injection.
    pub fn failed(&self) -> u64 {
        self.all.failed
    }

    /// Fraction of arrivals refused by admission control.
    pub fn shed_rate(&self) -> f64 {
        if self.all.arrived == 0 {
            0.0
        } else {
            self.all.shed as f64 / self.all.arrived as f64
        }
    }

    pub fn completed(&self) -> u64 {
        self.all.completed
    }

    pub fn dispatches(&self) -> u64 {
        self.dispatches
    }

    pub fn end_cycle(&self) -> f64 {
        self.end_cycle
    }

    pub fn end_seconds(&self) -> f64 {
        self.end_cycle / CLOCK_HZ
    }

    /// Aggregate latency percentile in milliseconds.
    pub fn latency_ms(&self, percentile: f64) -> f64 {
        cycles_to_ms(self.all.latency.percentile(percentile))
    }

    /// Completed requests per second over the whole run.
    pub fn throughput_rps(&self) -> f64 {
        if self.end_cycle <= 0.0 {
            0.0
        } else {
            self.all.completed as f64 / self.end_seconds()
        }
    }

    /// SLO-meeting completions per second over the whole run.
    pub fn goodput_rps(&self) -> f64 {
        if self.end_cycle <= 0.0 {
            0.0
        } else {
            self.all.slo_met as f64 / self.end_seconds()
        }
    }

    /// Fraction of completions that missed their deadline.
    pub fn violation_rate(&self) -> f64 {
        if self.all.completed == 0 {
            0.0
        } else {
            self.all.slo_violated as f64 / self.all.completed as f64
        }
    }

    /// Mean dispatched batch size.
    pub fn mean_batch(&self) -> f64 {
        if self.dispatches == 0 {
            0.0
        } else {
            let weighted: u64 = self.batch_hist.iter().map(|(b, n)| b * n).sum();
            weighted as f64 / self.dispatches as f64
        }
    }

    /// Largest batch ever dispatched.
    pub fn max_batch(&self) -> u64 {
        self.batch_hist.keys().next_back().copied().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::request::ms_to_cycles;
    use crate::testutil::Rng;

    /// Independent oracle, straight from the nearest-rank *definition*
    /// (not the implementation's ceil/clamp formula): the smallest sorted
    /// value whose cumulative sample count reaches `p`% of `n`.
    fn oracle_percentile(samples: &[f64], p: f64) -> f64 {
        let mut s = samples.to_vec();
        s.sort_by(f64::total_cmp);
        let n = s.len();
        for (i, &v) in s.iter().enumerate() {
            if (i + 1) as f64 * 100.0 >= p * n as f64 {
                return v;
            }
        }
        s[n - 1]
    }

    #[test]
    fn percentiles_match_sorted_vector_oracle() {
        let mut rng = Rng::new(123);
        let samples: Vec<f64> = (0..997).map(|_| rng.next_f32() as f64 * 1e6).collect();
        let mut rec = LatencyRecorder::new();
        for &s in &samples {
            rec.push(s);
        }
        for p in [0.0, 1.0, 50.0, 90.0, 95.0, 99.0, 99.9, 100.0] {
            assert_eq!(rec.percentile(p), oracle_percentile(&samples, p), "p{p}");
        }
    }

    #[test]
    fn percentiles_match_hand_computed_values() {
        // Ten known samples in scrambled insertion order.
        let mut rec = LatencyRecorder::new();
        for v in [70.0, 10.0, 90.0, 30.0, 50.0, 100.0, 20.0, 80.0, 40.0, 60.0] {
            rec.push(v);
        }
        // Nearest-rank over {10..100}: p50 -> 5th smallest, p90 -> 9th,
        // p91 rounds the rank up to the 10th, p10 -> 1st.
        assert_eq!(rec.percentile(50.0), 50.0);
        assert_eq!(rec.percentile(90.0), 90.0);
        assert_eq!(rec.percentile(91.0), 100.0);
        assert_eq!(rec.percentile(10.0), 10.0);
        assert_eq!(rec.percentile(0.0), 10.0);
        assert_eq!(rec.percentile(100.0), 100.0);
    }

    #[test]
    fn percentile_edge_cases() {
        let mut rec = LatencyRecorder::new();
        assert!(rec.percentile(50.0).is_nan());
        rec.push(7.0);
        assert_eq!(rec.percentile(0.0), 7.0);
        assert_eq!(rec.percentile(50.0), 7.0);
        assert_eq!(rec.percentile(100.0), 7.0);
        rec.push(3.0);
        // p50 of {3, 7} is the first element (rank ceil(0.5*2)=1).
        assert_eq!(rec.percentile(50.0), 3.0);
        assert_eq!(rec.percentile(100.0), 7.0);
        assert_eq!(rec.mean(), 5.0);
        assert_eq!(rec.max(), 7.0);
    }

    #[test]
    fn bounded_recorder_never_grows_the_vec() {
        let mut rng = Rng::new(7);
        let mut exact = LatencyRecorder::new();
        let mut bounded = LatencyRecorder::bounded();
        for _ in 0..5000 {
            let v = 1.0 + rng.next_f32() as f64 * 1e5;
            exact.push(v);
            bounded.push(v);
        }
        assert!(bounded.is_bounded());
        assert_eq!(bounded.exact_samples(), 0, "bounded mode must not grow the Vec");
        assert_eq!(bounded.len(), exact.len());
        crate::assert_close!(bounded.mean(), exact.mean());
        assert_eq!(bounded.max(), exact.max(), "bounded max is tracked exactly");
        for p in [1.0, 50.0, 90.0, 95.0, 99.0, 100.0] {
            let ratio = bounded.percentile(p) / exact.percentile(p);
            assert!(
                ratio > 0.5 && ratio <= 2.0,
                "p{p}: bounded {} vs exact {} outside the one-bucket bound",
                bounded.percentile(p),
                exact.percentile(p)
            );
        }
    }

    #[test]
    fn bounded_recorder_edge_cases() {
        let rec = LatencyRecorder::bounded();
        assert!(rec.is_empty());
        assert!(rec.percentile(50.0).is_nan());
        assert!(rec.mean().is_nan());
        assert!(rec.max().is_nan());
        let mut rec = LatencyRecorder::bounded();
        rec.push(7.0);
        assert_eq!(rec.max(), 7.0, "first push replaces the NaN max seed");
        assert_eq!(rec.len(), 1);
    }

    #[test]
    fn absorbing_a_shard_sketch_matches_direct_pushes() {
        // The barrier path (record into a shard-local sketch, absorb at
        // the merge) must be bit-identical to pushing straight into the
        // recorder — that is what keeps cluster stats thread-count
        // independent in bounded mode.
        let mut direct = LatencyRecorder::bounded_with(0.01);
        let mut absorbing = LatencyRecorder::bounded_with(0.01);
        let mut sk = QuantileSketch::new(0.01);
        let mut rng = Rng::new(3);
        for _ in 0..2000 {
            let v = 1.0 + rng.next_f32() as f64 * 1e4;
            direct.push(v);
            sk.record(v);
        }
        absorbing.absorb_sketch(&sk);
        assert_eq!(absorbing.len(), direct.len());
        assert_eq!(absorbing.max(), direct.max());
        for p in [1.0, 50.0, 95.0, 99.0, 100.0] {
            assert_eq!(absorbing.percentile(p).to_bits(), direct.percentile(p).to_bits(), "p{p}");
        }
    }

    #[test]
    fn bounded_stats_propagate_to_lazy_model_entries() {
        let mut s = ServeStats::bounded();
        let a = req(0, ModelKind::TinyCnn, 0.0, 100.0);
        s.record_arrival(&a);
        s.record_completion(&a, 90.0);
        assert!(s.is_bounded());
        assert!(s.per_model[&ModelKind::TinyCnn].latency.is_bounded());
        assert_eq!(s.exact_samples(), 0);
        assert_eq!(s.completed(), 1);
        assert!(s.latency_ms(50.0) > 0.0);
    }

    fn req(id: u64, kind: ModelKind, arrival: f64, slo: f64) -> Request {
        Request { id, kind, arrival, deadline: arrival + slo, client: None }
    }

    #[test]
    fn slo_accounting() {
        let mut s = ServeStats::new();
        let a = req(0, ModelKind::TinyCnn, 0.0, 100.0);
        let b = req(1, ModelKind::Mlp, 10.0, 100.0);
        s.record_arrival(&a);
        s.record_arrival(&b);
        s.record_completion(&a, 90.0); // met (90 <= 100)
        s.record_completion(&b, 200.0); // violated (200 > 110)
        s.finish(ms_to_cycles(1.0));
        assert_eq!(s.arrived(), 2);
        assert_eq!(s.completed(), 2);
        assert!((s.violation_rate() - 0.5).abs() < 1e-12);
        // Goodput counts only the SLO-meeting completion: 1 req / 1 ms.
        assert!((s.goodput_rps() - 1000.0).abs() < 1e-6);
        assert!((s.throughput_rps() - 2000.0).abs() < 1e-6);
        let tiny = &s.per_model[&ModelKind::TinyCnn];
        assert_eq!((tiny.slo_met, tiny.slo_violated), (1, 0));
        let mlp = &s.per_model[&ModelKind::Mlp];
        assert_eq!((mlp.slo_met, mlp.slo_violated), (0, 1));
    }

    #[test]
    fn shed_accounting_balances() {
        let mut s = ServeStats::new();
        let a = req(0, ModelKind::TinyCnn, 0.0, 100.0);
        let b = req(1, ModelKind::TinyCnn, 5.0, 100.0);
        s.record_arrival(&a);
        s.record_arrival(&b);
        s.record_shed(&b);
        s.record_completion(&a, 50.0);
        assert_eq!(s.arrived(), 2);
        assert_eq!(s.completed() + s.shed(), s.arrived());
        assert!((s.shed_rate() - 0.5).abs() < 1e-12);
        assert_eq!(s.per_model[&ModelKind::TinyCnn].shed, 1);
    }

    #[test]
    fn nan_sample_does_not_panic_percentiles() {
        // A NaN latency must degrade gracefully, not unwrap-panic inside
        // the sort. IEEE total order puts NaN last, so finite
        // percentiles still answer from the finite samples.
        let mut rec = LatencyRecorder::new();
        for v in [3.0, f64::NAN, 1.0] {
            rec.push(v);
        }
        assert_eq!(rec.percentile(33.0), 1.0);
        assert_eq!(rec.percentile(50.0), 3.0);
        assert!(rec.percentile(100.0).is_nan(), "NaN sorts to the top rank");
        assert_eq!(rec.len(), 3);
    }

    #[test]
    fn batch_histogram_and_means() {
        let mut s = ServeStats::new();
        s.record_dispatch(1);
        s.record_dispatch(4);
        s.record_dispatch(4);
        s.record_dispatch(16);
        assert_eq!(s.dispatches(), 4);
        assert_eq!(s.max_batch(), 16);
        assert!((s.mean_batch() - 6.25).abs() < 1e-12);
    }
}
