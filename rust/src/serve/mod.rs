//! Request serving over fleets of WIENNA packages (substrate S13).
//!
//! The paper motivates WIENNA with real-time inference; this module turns
//! the analytical cost model into a discrete-event *serving* simulator so
//! design points can be compared under production-style traffic instead
//! of one isolated inference:
//!
//! * [`request`] — the served-model catalog (ResNet-50, UNet, BERT-base,
//!   …), SLO-tagged workload mixes, and arrival processes: open-loop
//!   Poisson, open-loop trace replay, and a closed-loop client pool;
//! * [`queue`] — per-model FIFO admission queues (EDF across models);
//! * [`batcher`] — dynamic batch-size selection from the cost model's
//!   latency/throughput frontier, memoized per
//!   `(design, model, batch)` in a [`CostCache`] so the event loop never
//!   re-runs `evaluate_model`;
//! * [`fleet`] — N possibly-heterogeneous packages with pluggable routing
//!   (round-robin, least-loaded, SLO-aware earliest-deadline) and the
//!   event loop itself;
//! * [`stats`] — p50/p95/p99 latency, goodput, SLO-violation rate, batch
//!   histograms and per-plane utilization.
//!
//! ## Example
//!
//! ```no_run
//! use wienna::config::DesignPoint;
//! use wienna::serve::{
//!     Fleet, ModelKind, PackageSpec, RoutePolicy, ServeStats, Source, WorkloadMix,
//! };
//!
//! // Four WIENNA-C packages behind a least-loaded router.
//! let mut fleet = Fleet::new(
//!     PackageSpec::homogeneous(4, DesignPoint::WIENNA_C),
//!     RoutePolicy::LeastLoaded,
//! );
//! // ResNet-50 at a 25 ms SLO, 2000 requests/s offered for 100 ms.
//! let mix = WorkloadMix::single(ModelKind::ResNet50, 25.0);
//! let mut source = Source::poisson(mix, 2000.0, 42);
//! let mut stats = ServeStats::new();
//! fleet.run(&mut source, wienna::serve::ms_to_cycles(100.0), &mut stats);
//! println!(
//!     "p99 {:.2} ms, goodput {:.0} req/s, violations {:.1}%",
//!     stats.latency_ms(99.0),
//!     stats.goodput_rps(),
//!     stats.violation_rate() * 100.0
//! );
//! ```

pub mod batcher;
pub mod fleet;
pub mod queue;
pub mod request;
pub mod stats;

pub use batcher::{choose_batch, BatchCost, BatchDecision, BatcherConfig, CostCache, CostKey};
pub use fleet::{Fleet, Package, PackageSpec, RoutePolicy};
pub use queue::QueueSet;
pub use request::{
    cycles_to_ms, ms_to_cycles, ClientTraceSource, MixEntry, ModelKind, Request, Source,
    WorkloadMix,
};
pub use stats::{LatencyRecorder, ModelStats, ServeStats};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DesignPoint;

    #[test]
    fn doc_example_pipeline_runs() {
        // The `no_run` crate-docs example, at test-friendly scale.
        let mut fleet =
            Fleet::new(PackageSpec::homogeneous(2, DesignPoint::WIENNA_C), RoutePolicy::LeastLoaded);
        let mix = WorkloadMix::single(ModelKind::TinyCnn, 20.0);
        let mut source = Source::poisson(mix, 5000.0, 42);
        let mut stats = ServeStats::new();
        fleet.run(&mut source, ms_to_cycles(5.0), &mut stats);
        assert!(stats.completed() > 0);
        assert!(stats.latency_ms(50.0) > 0.0);
        assert!(stats.latency_ms(99.0) >= stats.latency_ms(50.0));
        assert!(fleet.cache.hits > fleet.cache.misses, "cache should be hot");
    }
}
