//! Intra-chiplet dataflow mapping (substrate S3).
//!
//! Each WIENNA chiplet is a small fixed-function accelerator whose PE
//! array is spatially mapped according to the partitioning strategy
//! (paper Table 4):
//!
//! * **NVDLA-like** (used with KP-CP and NP-CP): weight-stationary, the PE
//!   array is spatially partitioned over `K x C` (filters x input
//!   channels) with an adder-tree reduction over the `C` slice.
//! * **Shidiannao-like** (used with YP-XP): output-stationary, the PE
//!   array is spatially partitioned over the output plane `Y' x X'`.
//!
//! Given a chiplet's sub-layer, the mapping determines how many passes the
//! array needs and hence the effective PE utilization and compute cycles
//! (1 MAC/PE/cycle, as in MAESTRO's peak model).

use crate::dataflow::Strategy;
use crate::workload::{Layer, OpKind};

/// The two chiplet microarchitectures of Table 4.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ChipletArch {
    /// Weight-stationary `K x C` spatial array (NVDLA [1] style).
    NvdlaLike,
    /// Output-stationary `Y x X` spatial array (Shidiannao [9] style).
    ShidiannaoLike,
}

impl ChipletArch {
    /// The paper pairs KP-CP / NP-CP with NVDLA-like chiplets and YP-XP
    /// with Shidiannao-like chiplets (Table 4).
    pub fn for_strategy(s: Strategy) -> ChipletArch {
        match s {
            Strategy::KpCp | Strategy::NpCp => ChipletArch::NvdlaLike,
            Strategy::YpXp => ChipletArch::ShidiannaoLike,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            ChipletArch::NvdlaLike => "NVDLA-like",
            ChipletArch::ShidiannaoLike => "Shidiannao-like",
        }
    }
}

/// How the 2-D PE array dimensions are chosen.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum MapPolicy {
    /// Pick the divisor pair of the PE count that maximizes utilization
    /// for the given sub-layer (a flexible-NoC chiplet, MAERI-style).
    Flexible,
    /// Fixed array aspect (e.g. NVDLA's native 8x8 MAC cell organisation);
    /// `dim0 x dim1` must equal the PE count.
    Fixed { dim0: u64, dim1: u64 },
}

/// Result of mapping a sub-layer onto one chiplet.
#[derive(Debug, Clone, PartialEq)]
pub struct IntraMapping {
    pub arch: ChipletArch,
    /// Spatial array shape actually used (`d0 x d1` PEs).
    pub d0: u64,
    pub d1: u64,
    /// Compute cycles for the chiplet's sub-layer at 1 MAC/PE/cycle.
    pub cycles: u64,
    /// Effective PE utilization in steady state (0, 1].
    pub utilization: f64,
    /// Minimum local (per-chiplet) buffer bytes for one working set:
    /// stationary tile + one streaming slice + output slice.
    pub local_buffer_bytes: u64,
}

/// Cycles for a spatial mapping of extents `(e0, e1)` over an array
/// `(d0, d1)`, times the `inner` sequential loop trip count.
fn spatial_cycles(e0: u64, e1: u64, d0: u64, d1: u64, inner: u64) -> u64 {
    e0.div_ceil(d0) * e1.div_ceil(d1) * inner
}

/// Map `sub` (a per-chiplet sub-layer) onto a chiplet with `pes` PEs.
pub fn map_layer(sub: &Layer, arch: ChipletArch, pes: u64, policy: MapPolicy, bytes_per_elem: u64) -> IntraMapping {
    assert!(pes >= 1);
    let macs = sub.macs();

    // Elementwise layers use the array as a flat SIMD lane regardless of
    // microarchitecture: one add per element.
    if sub.op == OpKind::ResidualAdd {
        let elems = sub.n * sub.c * sub.y * sub.x;
        let cycles = elems.div_ceil(pes).max(1);
        return IntraMapping {
            arch,
            d0: pes,
            d1: 1,
            cycles,
            utilization: macs as f64 / (cycles as f64 * pes as f64),
            local_buffer_bytes: 3 * pes * bytes_per_elem,
        };
    }

    // Spatial extents by microarchitecture.
    let (e0, e1) = match arch {
        ChipletArch::NvdlaLike => (sub.k, sub.c),
        ChipletArch::ShidiannaoLike => (sub.y_out().max(1), sub.x_out().max(1)),
    };
    // Sequential (temporal) loop trip count per spatial pass.
    let inner = match arch {
        ChipletArch::NvdlaLike => sub.n * sub.y_out().max(1) * sub.x_out().max(1) * sub.r * sub.s,
        ChipletArch::ShidiannaoLike => sub.n * sub.k * sub.c * sub.r * sub.s,
    };

    // Walk divisor pairs of the PE count without materializing them (this
    // runs on every layer evaluation; the hot path must not allocate).
    // Pairs are visited in the same `(d, p/d), (p/d, d)` order the old
    // candidate list used, and ties keep the first minimum.
    let (d0, d1, cycles) = match policy {
        MapPolicy::Fixed { dim0, dim1 } => {
            assert_eq!(dim0 * dim1, pes, "fixed array shape must use all PEs");
            (dim0, dim1, spatial_cycles(e0, e1, dim0, dim1, inner).max(1))
        }
        MapPolicy::Flexible => {
            let mut best = (1u64, pes, u64::MAX);
            let mut d = 1;
            while d * d <= pes {
                if pes % d == 0 {
                    let q = pes / d;
                    let c = spatial_cycles(e0, e1, d, q, inner).max(1);
                    if c < best.2 {
                        best = (d, q, c);
                    }
                    if d != q {
                        let c = spatial_cycles(e0, e1, q, d, inner).max(1);
                        if c < best.2 {
                            best = (q, d, c);
                        }
                    }
                }
                d += 1;
            }
            best
        }
    };

    // Local working set: stationary tile + streamed slice + output slice.
    let local = match arch {
        ChipletArch::NvdlaLike => {
            // Weight-stationary: d0*d1 weights resident per (r,s) position
            // plus an input row and an output row.
            (d0 * d1 * sub.r * sub.s + sub.c * sub.x + sub.k * sub.x_out().max(1)) * bytes_per_elem
        }
        ChipletArch::ShidiannaoLike => {
            // Output-stationary: d0*d1 partial sums resident plus the
            // input halo window and one filter.
            (d0 * d1 + sub.y * sub.x + sub.k * sub.c * sub.r * sub.s / sub.k.max(1)) * bytes_per_elem
        }
    };

    IntraMapping {
        arch,
        d0,
        d1,
        cycles,
        utilization: macs as f64 / (cycles as f64 * pes as f64),
        local_buffer_bytes: local,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::Layer;

    #[test]
    fn arch_pairing_follows_table4() {
        assert_eq!(ChipletArch::for_strategy(Strategy::KpCp), ChipletArch::NvdlaLike);
        assert_eq!(ChipletArch::for_strategy(Strategy::NpCp), ChipletArch::NvdlaLike);
        assert_eq!(ChipletArch::for_strategy(Strategy::YpXp), ChipletArch::ShidiannaoLike);
    }

    #[test]
    fn perfect_fit_is_full_utilization() {
        // K=8, C=8 on 64 PEs: exact 8x8 fit.
        let sub = Layer::conv("s", 1, 8, 8, 10, 10, 3, 3, 1);
        let m = map_layer(&sub, ChipletArch::NvdlaLike, 64, MapPolicy::Flexible, 1);
        assert!((m.utilization - 1.0).abs() < 1e-9, "util {}", m.utilization);
        assert_eq!(m.cycles, 1 * 8 * 8 * 9); // n*yo*xo*r*s
    }

    #[test]
    fn flexible_beats_fixed_on_skewed_layers() {
        // K=2, C=512: a fixed 8x8 array wastes 6/8 of its K rows.
        let sub = Layer::conv("s", 1, 2, 512, 9, 9, 3, 3, 1);
        let flex = map_layer(&sub, ChipletArch::NvdlaLike, 64, MapPolicy::Flexible, 1);
        let fixed = map_layer(&sub, ChipletArch::NvdlaLike, 64, MapPolicy::Fixed { dim0: 8, dim1: 8 }, 1);
        assert!(flex.cycles <= fixed.cycles);
        assert!(flex.utilization > 0.9, "flexible should find 2x32, util {}", flex.utilization);
        assert!(fixed.utilization < 0.3);
    }

    #[test]
    fn shidiannao_maps_output_plane() {
        let sub = Layer::conv("s", 1, 64, 64, 10, 10, 3, 3, 1); // 8x8 out
        let m = map_layer(&sub, ChipletArch::ShidiannaoLike, 64, MapPolicy::Flexible, 1);
        assert_eq!((m.d0, m.d1), (8, 8));
        assert!((m.utilization - 1.0).abs() < 1e-9);
    }

    #[test]
    fn residual_is_simd() {
        let sub = Layer::residual("r", 1, 4, 8, 8);
        let m = map_layer(&sub, ChipletArch::NvdlaLike, 64, MapPolicy::Flexible, 1);
        assert_eq!(m.cycles, (4 * 8 * 8u64).div_ceil(64));
    }

    #[test]
    fn cycles_times_pes_bounds_macs() {
        // Invariant: cycles * PEs >= MACs (cannot do more than 1 MAC/PE/cyc).
        for (k, c) in [(1u64, 1u64), (3, 7), (64, 64), (2, 512), (1000, 3)] {
            let sub = Layer::conv("s", 2, k, c, 12, 12, 3, 3, 1);
            for arch in [ChipletArch::NvdlaLike, ChipletArch::ShidiannaoLike] {
                let m = map_layer(&sub, arch, 64, MapPolicy::Flexible, 1);
                assert!(m.cycles * 64 >= sub.macs(), "{arch:?} k={k} c={c}");
                assert!(m.utilization <= 1.0 + 1e-9);
            }
        }
    }
}
