//! Mapping-space exploration (mRNA [28] / MAESTRO [16]-style): exhaustive
//! search over intra-chiplet spatial array shapes *and* temporal loop
//! orders for one chiplet's sub-layer.
//!
//! The main cost engine uses the closed-form `intra::map_layer`; this
//! explorer exists for the design-space studies the paper cites as the
//! surrounding literature — it enumerates candidate mappings, scores them
//! with the same 1 MAC/PE/cycle model plus a local-buffer constraint, and
//! reports the Pareto set (cycles vs buffer bytes).

use crate::dataflow::intra::{map_layer, ChipletArch, IntraMapping, MapPolicy};
use crate::workload::Layer;

/// Temporal loop orders considered for the innermost streaming dimension.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LoopOrder {
    /// Weight-stationary: outputs stream, weights resident.
    WeightStationary,
    /// Output-stationary: weights stream, partial sums resident.
    OutputStationary,
    /// Input-stationary: inputs resident, weights and outputs stream.
    InputStationary,
}

impl LoopOrder {
    pub const ALL: [LoopOrder; 3] = [LoopOrder::WeightStationary, LoopOrder::OutputStationary, LoopOrder::InputStationary];

    /// Stationary-tile bytes for a sub-layer under this order with a
    /// `d0 x d1` array (what must stay resident per pass).
    fn stationary_bytes(&self, sub: &Layer, d0: u64, d1: u64, bpe: u64) -> u64 {
        match self {
            LoopOrder::WeightStationary => d0 * d1 * sub.r * sub.s * bpe,
            LoopOrder::OutputStationary => d0 * d1 * 4, // f32 partial sums
            LoopOrder::InputStationary => sub.c.min(d1) * sub.y * sub.x * bpe / sub.c.max(1).min(d1).max(1),
        }
    }
}

/// One explored mapping candidate.
#[derive(Debug, Clone)]
pub struct MappingCandidate {
    pub arch: ChipletArch,
    pub order: LoopOrder,
    pub d0: u64,
    pub d1: u64,
    pub cycles: u64,
    pub utilization: f64,
    pub buffer_bytes: u64,
}

/// Exhaustively enumerate mappings of `sub` on a `pes`-PE chiplet.
pub fn enumerate(sub: &Layer, pes: u64, bpe: u64) -> Vec<MappingCandidate> {
    let mut out = Vec::new();
    let mut d = 1;
    while d <= pes {
        if pes % d == 0 {
            let (d0, d1) = (d, pes / d);
            for arch in [ChipletArch::NvdlaLike, ChipletArch::ShidiannaoLike] {
                let m: IntraMapping = map_layer(sub, arch, pes, MapPolicy::Fixed { dim0: d0, dim1: d1 }, bpe);
                for order in LoopOrder::ALL {
                    let stationary = order.stationary_bytes(sub, d0, d1, bpe);
                    // Streaming slices: one input row + one output row.
                    let stream = (sub.c * sub.x + sub.k * sub.x) * bpe;
                    out.push(MappingCandidate {
                        arch,
                        order,
                        d0,
                        d1,
                        cycles: m.cycles,
                        utilization: m.utilization,
                        buffer_bytes: stationary + stream,
                    });
                }
            }
        }
        d += 1;
    }
    out
}

/// The Pareto frontier of (cycles, buffer_bytes): no candidate dominates
/// another on both axes.
pub fn pareto(cands: &[MappingCandidate]) -> Vec<MappingCandidate> {
    let mut front: Vec<MappingCandidate> = Vec::new();
    for c in cands {
        if front.iter().any(|f| f.cycles <= c.cycles && f.buffer_bytes <= c.buffer_bytes && (f.cycles < c.cycles || f.buffer_bytes < c.buffer_bytes)) {
            continue;
        }
        front.retain(|f| !(c.cycles <= f.cycles && c.buffer_bytes <= f.buffer_bytes && (c.cycles < f.cycles || c.buffer_bytes < f.buffer_bytes)));
        front.push(c.clone());
    }
    front.sort_by_key(|c| c.cycles);
    front
}

/// Best mapping under a buffer budget (the constrained pick a real
/// chiplet would ship with).
pub fn best_under_budget(cands: &[MappingCandidate], budget_bytes: u64) -> Option<MappingCandidate> {
    cands
        .iter()
        .filter(|c| c.buffer_bytes <= budget_bytes)
        .min_by_key(|c| c.cycles)
        .cloned()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::Layer;

    fn sub() -> Layer {
        Layer::conv("s", 1, 8, 16, 12, 12, 3, 3, 1)
    }

    #[test]
    fn enumeration_covers_all_shapes_orders() {
        let cands = enumerate(&sub(), 64, 1);
        // 7 divisor splits x 2 archs x 3 orders.
        assert_eq!(cands.len(), 7 * 2 * 3);
    }

    #[test]
    fn pareto_is_nondominated_and_sorted() {
        let cands = enumerate(&sub(), 64, 1);
        let front = pareto(&cands);
        assert!(!front.is_empty());
        for a in &front {
            for b in &front {
                let dominates = a.cycles <= b.cycles && a.buffer_bytes <= b.buffer_bytes && (a.cycles < b.cycles || a.buffer_bytes < b.buffer_bytes);
                assert!(!dominates, "{a:?} dominates {b:?}");
            }
        }
        assert!(front.windows(2).all(|w| w[0].cycles <= w[1].cycles));
    }

    #[test]
    fn best_under_budget_respects_constraint() {
        let cands = enumerate(&sub(), 64, 1);
        let tight = best_under_budget(&cands, 600);
        if let Some(c) = &tight {
            assert!(c.buffer_bytes <= 600);
        }
        let loose = best_under_budget(&cands, u64::MAX).unwrap();
        if let Some(t) = tight {
            assert!(loose.cycles <= t.cycles);
        }
    }

    #[test]
    fn flexible_policy_matches_best_enumerated_shape() {
        // The closed-form mapper must find the same optimum cycles as the
        // exhaustive search over array shapes (same arch).
        let cands = enumerate(&sub(), 64, 1);
        let best_nvdla = cands
            .iter()
            .filter(|c| c.arch == ChipletArch::NvdlaLike)
            .map(|c| c.cycles)
            .min()
            .unwrap();
        let flex = map_layer(&sub(), ChipletArch::NvdlaLike, 64, MapPolicy::Flexible, 1);
        assert_eq!(flex.cycles, best_nvdla);
    }

    #[test]
    fn impossible_budget_returns_none() {
        let cands = enumerate(&sub(), 64, 1);
        assert!(best_under_budget(&cands, 1).is_none());
    }
}
