//! Algorithmic data-reuse analysis (MAESTRO's data-centric metrics [16]).
//!
//! For each tensor of a layer, the *algorithmic reuse* is how many MACs
//! touch each element — the upper bound any dataflow can exploit, and the
//! quantity partitioning strategies trade against each other (the paper's
//! §2: "DNNs exhibit plenty of data reuse ... exploited via custom memory
//! hierarchies"). The multicast factor of Fig 10 is exactly the fraction
//! of *spatial* (inter-chiplet) reuse a strategy turns into broadcast.

use crate::dataflow::{partition, Strategy, TensorKind};
use crate::workload::{Layer, OpKind};

/// Algorithmic (maximum) reuse per tensor element.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AlgorithmicReuse {
    /// MACs per input-activation element: `K · R · S / stride²`.
    pub input: f64,
    /// MACs per weight element: `N · Y' · X'`.
    pub weight: f64,
    /// MACs per output element (accumulation depth): `C · R · S`.
    pub output: f64,
}

/// Compute the algorithmic reuse of a layer.
pub fn algorithmic(layer: &Layer) -> AlgorithmicReuse {
    if layer.op == OpKind::ResidualAdd {
        return AlgorithmicReuse { input: 1.0, weight: 0.0, output: 1.0 };
    }
    let macs = layer.macs() as f64;
    AlgorithmicReuse {
        input: macs / layer.input_elems() as f64,
        weight: if layer.weight_elems() == 0 { 0.0 } else { macs / layer.weight_elems() as f64 },
        output: macs / layer.output_elems() as f64,
    }
}

/// How much of each tensor's reuse a strategy realizes *spatially*
/// (across chiplets, via multicast) on a package of `num_chiplets`.
#[derive(Debug, Clone)]
pub struct SpatialReuse {
    pub strategy: Strategy,
    /// Multicast fan-out achieved for the input tensor.
    pub input_spatial: f64,
    /// Multicast fan-out achieved for the weight tensor.
    pub weight_spatial: f64,
    /// Fraction of the layer's algorithmic input reuse exploited
    /// spatially (0..=1).
    pub input_fraction: f64,
    pub weight_fraction: f64,
}

/// Analyze the spatial reuse a strategy extracts.
pub fn spatial(layer: &Layer, strategy: Strategy, num_chiplets: u64) -> SpatialReuse {
    let plan = partition::partition(layer, strategy, num_chiplets, 1);
    let alg = algorithmic(layer);
    let mut input_spatial = 1.0;
    let mut weight_spatial = 1.0;
    for t in &plan.traffic {
        match t.tensor {
            TensorKind::Input => input_spatial = t.avg_dests,
            TensorKind::Weight => weight_spatial = t.avg_dests,
        }
    }
    SpatialReuse {
        strategy,
        input_spatial,
        weight_spatial,
        input_fraction: if alg.input > 0.0 { (input_spatial / alg.input).min(1.0) } else { 0.0 },
        weight_fraction: if alg.weight > 0.0 { (weight_spatial / alg.weight).min(1.0) } else { 0.0 },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{conv_padded, Layer};

    #[test]
    fn conv_reuse_formulas() {
        let l = Layer::conv("c", 1, 64, 32, 12, 12, 3, 3, 1);
        let r = algorithmic(&l);
        // input reuse = K*R*S scaled by the output/input plane ratio.
        let macs = l.macs() as f64;
        assert!((r.input - macs / l.input_elems() as f64).abs() < 1e-9);
        assert!((r.output - (32.0 * 9.0)).abs() < 1e-9); // C*R*S
        assert!((r.weight - (10.0 * 10.0)).abs() < 1e-9); // N*Yo*Xo
    }

    #[test]
    fn fc_weight_reuse_is_batch() {
        let l = Layer::fc("fc", 8, 100, 200);
        let r = algorithmic(&l);
        assert!((r.weight - 8.0).abs() < 1e-9);
        assert!((r.input - 100.0).abs() < 1e-9);
    }

    #[test]
    fn residual_has_no_reuse() {
        let r = algorithmic(&Layer::residual("r", 1, 8, 4, 4));
        assert_eq!(r.weight, 0.0);
        assert_eq!(r.input, 1.0);
    }

    #[test]
    fn kpcp_spatializes_input_reuse() {
        let l = conv_padded("c", 1, 512, 256, 14, 14, 3, 3, 1);
        let s = spatial(&l, Strategy::KpCp, 256);
        assert!(s.input_spatial > 100.0, "broadcast fan-out {}", s.input_spatial);
        assert!((s.weight_spatial - 1.0).abs() < 1e-9);
    }

    #[test]
    fn npcp_spatializes_weight_reuse() {
        let l = conv_padded("c", 64, 128, 64, 14, 14, 3, 3, 1);
        let s = spatial(&l, Strategy::NpCp, 256);
        assert!(s.weight_spatial > 10.0);
        assert!((s.input_spatial - 1.0).abs() < 1e-9);
    }

    #[test]
    fn spatial_reuse_never_exceeds_algorithmic() {
        for strat in Strategy::ALL {
            let l = conv_padded("c", 4, 64, 32, 28, 28, 3, 3, 1);
            let alg = algorithmic(&l);
            let s = spatial(&l, strat, 256);
            assert!(s.input_spatial <= alg.input.max(1.0) * 256.0);
            assert!(s.input_fraction <= 1.0 && s.weight_fraction <= 1.0);
        }
    }
}
