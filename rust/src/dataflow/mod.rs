//! Dataflow substrate (S2, S3): inter-chiplet tensor partitioning
//! strategies (Fig 2) and intra-chiplet dataflow mapping (NVDLA-like /
//! Shidiannao-like, Table 4).

pub mod intra;
pub mod partition;
pub mod reuse;
pub mod tiling;

pub use intra::{ChipletArch, IntraMapping, MapPolicy};
pub use partition::{PartitionPlan, Strategy, TensorKind, TrafficClass, TrafficVec};
