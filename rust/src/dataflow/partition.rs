//! Inter-chiplet tensor partitioning strategies (paper Fig 2, substrate S2).
//!
//! The paper implements three strategies that leverage parallelism across
//! three DNN dimensions:
//!
//! * **KP-CP** (filter partitioning, Fig 2a): output channels `K` are
//!   partitioned across chiplets; each chiplet's filters are *unicast* to
//!   it, while the input activation is *replicated* (broadcast) to every
//!   used chiplet. Intra-chiplet dataflow partitions `C` across PEs
//!   (NVDLA-like).
//! * **NP-CP** (batch partitioning, Fig 2b): the batch `N` is partitioned;
//!   per-batch inputs are unicast, filters are broadcast.
//! * **YP-XP** (activation partitioning, Fig 2c): the output activation
//!   plane `Y x X` is tiled across a 2-D grid of chiplets; filters are
//!   broadcast, input tiles (with `R - stride` halo rows/columns shared by
//!   neighbouring chiplets) are distributed with a small multicast factor.
//!
//! For each (layer, strategy, chiplet count) this module derives the
//! *partition plan*: how many chiplets are used, the sub-layer each chiplet
//! computes, and the distribution traffic broken into classes
//! (payload bytes from the SRAM, average destinations per byte).

use crate::workload::{Layer, OpKind};
use std::fmt;

/// The three inter-chiplet partitioning strategies of Fig 2.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Strategy {
    /// Filter (output-channel) partitioning across chiplets.
    KpCp,
    /// Batch partitioning across chiplets.
    NpCp,
    /// Output-activation (spatial) partitioning across chiplets.
    YpXp,
}

impl Strategy {
    pub const ALL: [Strategy; 3] = [Strategy::KpCp, Strategy::NpCp, Strategy::YpXp];

    pub fn label(&self) -> &'static str {
        match self {
            Strategy::KpCp => "KP-CP",
            Strategy::NpCp => "NP-CP",
            Strategy::YpXp => "YP-XP",
        }
    }
}

impl fmt::Display for Strategy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.label())
    }
}

/// Which tensor a traffic class carries.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TensorKind {
    Input,
    Weight,
}

/// One distribution traffic class: a set of transfers sharing payload type
/// and fan-out.
///
/// `bytes` counts *unique* payload bytes read from the global SRAM;
/// `avg_dests` is the average number of chiplets that must receive each
/// byte (1.0 for pure unicast, `used_chiplets` for a broadcast, fractional
/// for halo-overlapped spatial tiles). Total delivered bytes are therefore
/// `bytes * avg_dests`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TrafficClass {
    pub tensor: TensorKind,
    pub bytes: u64,
    pub avg_dests: f64,
    /// Whether this class is *preloaded* (must fully arrive before compute
    /// starts, e.g. stationary weights) or *streamed* (overlaps compute) —
    /// drives the Fig-6 phase timeline.
    pub streamed: bool,
}

impl TrafficClass {
    /// Bytes delivered across all destination chiplets.
    pub fn delivered_bytes(&self) -> f64 {
        self.bytes as f64 * self.avg_dests
    }
}

/// Fixed-capacity, inline list of [`TrafficClass`]es.
///
/// Every strategy produces at most two distribution classes (one weight,
/// one input), so the partitioner stores them inline instead of in a
/// `Vec` — building a [`PartitionPlan`] performs no heap allocation,
/// which matters in the cost engine's hot loop. Dereferences to a slice,
/// so call sites index and iterate it like a `Vec`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TrafficVec {
    len: u8,
    buf: [TrafficClass; 2],
}

const EMPTY_CLASS: TrafficClass =
    TrafficClass { tensor: TensorKind::Input, bytes: 0, avg_dests: 1.0, streamed: false };

impl TrafficVec {
    pub fn one(a: TrafficClass) -> Self {
        TrafficVec { len: 1, buf: [a, EMPTY_CLASS] }
    }

    pub fn two(a: TrafficClass, b: TrafficClass) -> Self {
        TrafficVec { len: 2, buf: [a, b] }
    }

    pub fn as_slice(&self) -> &[TrafficClass] {
        &self.buf[..self.len as usize]
    }
}

impl std::ops::Deref for TrafficVec {
    type Target = [TrafficClass];

    fn deref(&self) -> &[TrafficClass] {
        self.as_slice()
    }
}

impl<'a> IntoIterator for &'a TrafficVec {
    type Item = &'a TrafficClass;
    type IntoIter = std::slice::Iter<'a, TrafficClass>;

    fn into_iter(self) -> Self::IntoIter {
        self.as_slice().iter()
    }
}

/// Result of applying a [`Strategy`] to a layer on `num_chiplets` chiplets.
#[derive(Debug, Clone)]
pub struct PartitionPlan {
    pub strategy: Strategy,
    /// Chiplets that receive work (≤ `num_chiplets`).
    pub used_chiplets: u64,
    /// The sub-problem a single (worst-case) chiplet computes.
    pub sub_layer: Layer,
    /// Distribution traffic classes (SRAM → chiplets), stored inline.
    pub traffic: TrafficVec,
    /// Output bytes collected back over the wired NoP.
    pub collect_bytes: u64,
}

impl PartitionPlan {
    /// Average multicast factor of the distribution phase:
    /// `Σ received bytes / Σ sent bytes` (paper Fig 10).
    pub fn multicast_factor(&self) -> f64 {
        let sent: f64 = self.traffic.iter().map(|t| t.bytes as f64).sum();
        if sent == 0.0 {
            return 1.0;
        }
        let recv: f64 = self.traffic.iter().map(|t| t.delivered_bytes()).sum();
        recv / sent
    }

    /// Unique distribution payload in bytes.
    pub fn sent_bytes(&self) -> u64 {
        self.traffic.iter().map(|t| t.bytes).sum()
    }
}

/// Split `total` across at most `parts` workers; returns
/// `(workers_used, worst_case_share)`.
fn split(total: u64, parts: u64) -> (u64, u64) {
    let used = total.min(parts).max(1);
    (used, total.div_ceil(used))
}

/// Build the partition plan for `layer` under `strategy` on a package of
/// `num_chiplets` chiplets with `bytes_per_elem`-byte tensor elements.
pub fn partition(layer: &Layer, strategy: Strategy, num_chiplets: u64, bytes_per_elem: u64) -> PartitionPlan {
    assert!(num_chiplets >= 1, "need at least one chiplet");
    let bpe = bytes_per_elem;
    let in_bytes = layer.input_elems() * bpe;
    let w_bytes = layer.weight_elems() * bpe;
    let out_bytes = layer.output_elems() * bpe;

    // Residual adds carry no weights: every strategy degenerates to
    // partitioning the (pair of) input tensors; all traffic is unicast.
    if layer.op == OpKind::ResidualAdd {
        let (used, sub) = match strategy {
            Strategy::KpCp => {
                let (u, c) = split(layer.c, num_chiplets);
                (u, Layer { c, k: c, ..layer.clone() })
            }
            Strategy::NpCp => {
                let (u, n) = split(layer.n, num_chiplets);
                (u, Layer { n, ..layer.clone() })
            }
            Strategy::YpXp => {
                let side = (num_chiplets as f64).sqrt().floor() as u64;
                let py = layer.y.min(side.max(1));
                let px = layer.x.min(side.max(1));
                let sub = Layer { y: layer.y.div_ceil(py), x: layer.x.div_ceil(px), ..layer.clone() };
                (py * px, sub)
            }
        };
        return PartitionPlan {
            strategy,
            used_chiplets: used,
            sub_layer: sub,
            traffic: TrafficVec::one(TrafficClass { tensor: TensorKind::Input, bytes: in_bytes, avg_dests: 1.0, streamed: true }),
            collect_bytes: out_bytes,
        };
    }

    match strategy {
        // Fig 2(a): filters partitioned (unicast, preloaded), inputs
        // replicated (broadcast, streamed one by one — Fig 6 timeline).
        Strategy::KpCp => {
            let (used, k_sub) = split(layer.k, num_chiplets);
            let sub = Layer { k: k_sub, ..layer.clone() };
            PartitionPlan {
                strategy,
                used_chiplets: used,
                sub_layer: sub,
                traffic: TrafficVec::two(
                    TrafficClass { tensor: TensorKind::Weight, bytes: w_bytes, avg_dests: 1.0, streamed: false },
                    TrafficClass { tensor: TensorKind::Input, bytes: in_bytes, avg_dests: used as f64, streamed: true },
                ),
                collect_bytes: out_bytes,
            }
        }
        // Fig 2(b): batch partitioned (inputs unicast), filters replicated
        // (broadcast, preloaded — weight-stationary chiplets).
        Strategy::NpCp => {
            let (used, n_sub) = split(layer.n, num_chiplets);
            let sub = Layer { n: n_sub, ..layer.clone() };
            PartitionPlan {
                strategy,
                used_chiplets: used,
                sub_layer: sub,
                traffic: TrafficVec::two(
                    TrafficClass { tensor: TensorKind::Weight, bytes: w_bytes, avg_dests: used as f64, streamed: false },
                    TrafficClass { tensor: TensorKind::Input, bytes: in_bytes, avg_dests: 1.0, streamed: true },
                ),
                collect_bytes: out_bytes,
            }
        }
        // Fig 2(c): output plane tiled over a 2-D chiplet grid; filters
        // broadcast; input tiles unicast with halo overlap shared between
        // grid neighbours (fractional multicast).
        Strategy::YpXp => {
            let yo = layer.y_out().max(1);
            let xo = layer.x_out().max(1);
            let side = (num_chiplets as f64).sqrt().floor().max(1.0) as u64;
            // Favour a square grid, clipped by available parallelism.
            let py = yo.min(side);
            let px = xo.min(num_chiplets / py.max(1)).max(1);
            let used = py * px;
            let yo_sub = yo.div_ceil(py);
            let xo_sub = xo.div_ceil(px);
            // Input tile each chiplet needs (with halo).
            let (y_sub, x_sub) = match layer.op {
                OpKind::UpConv => (layer.y.div_ceil(py), layer.x.div_ceil(px)),
                _ => (
                    (yo_sub - 1) * layer.stride + layer.r,
                    (xo_sub - 1) * layer.stride + layer.s,
                ),
            };
            let sub = Layer { y: y_sub, x: x_sub, ..layer.clone() };
            // Delivered input bytes = Σ per-chiplet tiles; unique bytes =
            // the full input tensor. Their ratio is the halo multicast
            // factor (≥ 1).
            let delivered_in = (layer.n * layer.c * y_sub * x_sub * used) as f64 * bpe as f64;
            let avg_dests_in = if in_bytes > 0 { (delivered_in / in_bytes as f64).max(1.0) } else { 1.0 };
            PartitionPlan {
                strategy,
                used_chiplets: used,
                sub_layer: sub,
                traffic: TrafficVec::two(
                    TrafficClass { tensor: TensorKind::Weight, bytes: w_bytes, avg_dests: used as f64, streamed: false },
                    TrafficClass { tensor: TensorKind::Input, bytes: in_bytes, avg_dests: avg_dests_in, streamed: true },
                ),
                collect_bytes: out_bytes,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::Layer;

    fn conv() -> Layer {
        // Low-res-ish conv: K=C=512, 7x7 padded to 9x9.
        Layer::conv("c", 1, 512, 512, 9, 9, 3, 3, 1)
    }

    #[test]
    fn kpcp_partitions_filters() {
        let p = partition(&conv(), Strategy::KpCp, 256, 1);
        assert_eq!(p.used_chiplets, 256);
        assert_eq!(p.sub_layer.k, 2);
        // Weights unicast once, inputs broadcast to all used chiplets.
        let w = &p.traffic[0];
        assert_eq!(w.tensor, TensorKind::Weight);
        assert_eq!(w.bytes, 512 * 512 * 9);
        assert_eq!(w.avg_dests, 1.0);
        let i = &p.traffic[1];
        assert_eq!(i.avg_dests, 256.0);
        assert!(i.streamed && !w.streamed);
    }

    #[test]
    fn npcp_limited_by_batch() {
        let l = Layer { n: 16, ..conv() };
        let p = partition(&l, Strategy::NpCp, 256, 1);
        assert_eq!(p.used_chiplets, 16);
        assert_eq!(p.sub_layer.n, 1);
        // Weights broadcast to the 16 used chiplets only.
        assert_eq!(p.traffic[0].avg_dests, 16.0);
    }

    #[test]
    fn ypxp_grid_and_halo() {
        // High-res conv: 64ch, 58x58 padded input, 56x56 output.
        let l = Layer::conv("h", 1, 64, 64, 58, 58, 3, 3, 1);
        let p = partition(&l, Strategy::YpXp, 256, 1);
        assert_eq!(p.used_chiplets, 256); // 16x16 grid over 56x56.
        // Sub-tile: ceil(56/16)=4 output rows -> 6 input rows with halo.
        assert_eq!(p.sub_layer.y, (4 - 1) + 3);
        let i = &p.traffic[1];
        assert!(i.avg_dests > 1.0, "halo must create multicast > 1, got {}", i.avg_dests);
        assert!(i.avg_dests < 4.0, "halo multicast should be small, got {}", i.avg_dests);
        // Weights broadcast to all used chiplets.
        assert_eq!(p.traffic[0].avg_dests, 256.0);
    }

    #[test]
    fn multicast_factor_matches_hand_calc() {
        let p = partition(&conv(), Strategy::KpCp, 256, 1);
        let w = (512 * 512 * 9) as f64;
        let i = (512 * 9 * 9) as f64;
        let expect = (w + i * 256.0) / (w + i);
        assert!((p.multicast_factor() - expect).abs() < 1e-9);
    }

    #[test]
    fn residual_all_unicast() {
        let l = Layer::residual("r", 8, 256, 56, 56);
        for s in Strategy::ALL {
            let p = partition(&l, s, 256, 1);
            assert_eq!(p.multicast_factor(), 1.0, "{s}");
            assert_eq!(p.sent_bytes(), 2 * 8 * 256 * 56 * 56);
        }
    }

    #[test]
    fn fc_has_no_spatial_parallelism() {
        let l = Layer::fc("fc", 4, 1000, 2048);
        let p = partition(&l, Strategy::YpXp, 256, 1);
        // Output plane is 1x1: a single chiplet.
        assert_eq!(p.used_chiplets, 1);
        let p = partition(&l, Strategy::KpCp, 256, 1);
        assert_eq!(p.used_chiplets, 256);
    }

    #[test]
    fn conservation_delivered_ge_sent() {
        for s in Strategy::ALL {
            let p = partition(&conv(), s, 64, 2);
            for t in &p.traffic {
                assert!(t.delivered_bytes() >= t.bytes as f64 - 1e-9);
            }
        }
    }

    #[test]
    fn traffic_vec_slices_and_iterates() {
        let a = TrafficClass { tensor: TensorKind::Weight, bytes: 10, avg_dests: 1.0, streamed: false };
        let b = TrafficClass { tensor: TensorKind::Input, bytes: 20, avg_dests: 2.0, streamed: true };
        let one = TrafficVec::one(a);
        assert_eq!(one.len(), 1);
        assert_eq!(one[0].bytes, 10);
        let two = TrafficVec::two(a, b);
        assert_eq!(two.len(), 2);
        assert_eq!(two.iter().map(|t| t.bytes).sum::<u64>(), 30);
        let mut n = 0;
        for t in &two {
            assert!(t.bytes > 0);
            n += 1;
        }
        assert_eq!(n, 2);
    }

    #[test]
    fn single_chiplet_degenerates_to_unicast() {
        for s in Strategy::ALL {
            let p = partition(&conv(), s, 1, 1);
            assert_eq!(p.used_chiplets, 1);
            assert!((p.multicast_factor() - 1.0).abs() < 1e-9, "{s}");
        }
    }
}
