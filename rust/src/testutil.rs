//! Test and benchmark utilities (this build is fully offline, so the crate
//! ships its own tiny replacements for `tempfile`, `proptest`-style random
//! input generation, and `criterion`-style timing).

use std::path::{Path, PathBuf};
use std::time::Instant;

/// RAII temporary directory under the system temp dir.
pub struct TempDir {
    path: PathBuf,
}

impl TempDir {
    pub fn new(prefix: &str) -> Self {
        let nanos = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap()
            .as_nanos();
        let pid = std::process::id();
        let path = std::env::temp_dir().join(format!("{prefix}_{pid}_{nanos}"));
        std::fs::create_dir_all(&path).expect("create temp dir");
        TempDir { path }
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.path);
    }
}

/// SplitMix64: a tiny, deterministic RNG for property-style tests.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E3779B97F4A7C15) }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[lo, hi]` (inclusive).
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + self.next_u64() % (hi - lo + 1)
    }

    /// Uniform f32 in `[0, 1)`.
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Uniform f32 in `[-a, a)`.
    pub fn sym_f32(&mut self, a: f32) -> f32 {
        (self.next_f32() * 2.0 - 1.0) * a
    }

    /// Pick one element of a slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[(self.next_u64() % xs.len() as u64) as usize]
    }
}

/// Benchmark result of [`bench`].
#[derive(Debug, Clone, Copy)]
pub struct BenchStats {
    pub iters: u64,
    pub mean_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
}

impl BenchStats {
    pub fn mean_us(&self) -> f64 {
        self.mean_ns / 1e3
    }

    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }
}

/// Criterion-style micro-benchmark: warm up, then time `iters` runs of
/// `f`, batching the clock reads.
pub fn bench<T>(label: &str, iters: u64, mut f: impl FnMut() -> T) -> BenchStats {
    assert!(iters > 0);
    // Warm-up.
    for _ in 0..iters.min(3) {
        std::hint::black_box(f());
    }
    let mut min = f64::INFINITY;
    let mut max: f64 = 0.0;
    let mut total = 0.0f64;
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        let dt = t0.elapsed().as_secs_f64() * 1e9;
        total += dt;
        min = min.min(dt);
        max = max.max(dt);
    }
    let stats = BenchStats { iters, mean_ns: total / iters as f64, min_ns: min, max_ns: max };
    println!(
        "bench {label:<44} {:>12.2} us/iter  (min {:.2}, max {:.2}, n={})",
        stats.mean_us(),
        min / 1e3,
        max / 1e3,
        iters
    );
    stats
}

/// Relative-equality assertion helper (replaces `approx`).
#[macro_export]
macro_rules! assert_close {
    ($a:expr, $b:expr) => {
        $crate::assert_close!($a, $b, 1e-9)
    };
    ($a:expr, $b:expr, $eps:expr) => {{
        let (a, b) = ($a as f64, $b as f64);
        let scale = a.abs().max(b.abs()).max(1e-12);
        assert!(
            (a - b).abs() <= $eps * scale + $eps,
            "assert_close failed: {} vs {} (eps {})",
            a,
            b,
            $eps
        );
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tempdir_creates_and_cleans() {
        let p;
        {
            let d = TempDir::new("wienna_tu");
            p = d.path().to_path_buf();
            assert!(p.exists());
        }
        assert!(!p.exists());
    }

    #[test]
    fn rng_is_deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn rng_ranges() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let v = r.range_u64(3, 9);
            assert!((3..=9).contains(&v));
            let f = r.next_f32();
            assert!((0.0..1.0).contains(&f));
        }
    }

    #[test]
    fn close_macro() {
        assert_close!(1.0, 1.0 + 1e-12);
        assert_close!(1000.0, 1000.1, 1e-3);
    }

    #[test]
    fn bench_runs() {
        let s = bench("noop", 5, || 1 + 1);
        assert_eq!(s.iters, 5);
        assert!(s.mean_ns >= 0.0);
    }
}
