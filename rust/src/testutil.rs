//! Test and benchmark utilities (this build is fully offline, so the crate
//! ships its own tiny replacements for `tempfile`, `proptest`-style random
//! input generation, and `criterion`-style timing).

use std::path::{Path, PathBuf};
use std::time::Instant;

/// RAII temporary directory under the system temp dir.
pub struct TempDir {
    path: PathBuf,
}

impl TempDir {
    pub fn new(prefix: &str) -> Self {
        let nanos = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap()
            .as_nanos();
        let pid = std::process::id();
        let path = std::env::temp_dir().join(format!("{prefix}_{pid}_{nanos}"));
        std::fs::create_dir_all(&path).expect("create temp dir");
        TempDir { path }
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.path);
    }
}

/// SplitMix64: a tiny, deterministic RNG for property-style tests.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E3779B97F4A7C15) }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[lo, hi]` (inclusive).
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + self.next_u64() % (hi - lo + 1)
    }

    /// Uniform f32 in `[0, 1)`.
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Uniform f32 in `[-a, a)`.
    pub fn sym_f32(&mut self, a: f32) -> f32 {
        (self.next_f32() * 2.0 - 1.0) * a
    }

    /// Pick one element of a slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[(self.next_u64() % xs.len() as u64) as usize]
    }
}

/// Benchmark result of [`bench`].
#[derive(Debug, Clone, Copy)]
pub struct BenchStats {
    pub iters: u64,
    pub mean_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
}

impl BenchStats {
    pub fn mean_us(&self) -> f64 {
        self.mean_ns / 1e3
    }

    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }
}

/// Criterion-style micro-benchmark: warm up, then time `iters` runs of
/// `f`, batching the clock reads. Every result is also recorded in a
/// process-global registry so bench mains can dump a machine-readable
/// summary with [`write_bench_json`] (the CI perf job uploads it).
pub fn bench<T>(label: &str, iters: u64, mut f: impl FnMut() -> T) -> BenchStats {
    assert!(iters > 0);
    // Warm-up.
    for _ in 0..iters.min(3) {
        std::hint::black_box(f());
    }
    let mut min = f64::INFINITY;
    let mut max: f64 = 0.0;
    let mut total = 0.0f64;
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        let dt = t0.elapsed().as_secs_f64() * 1e9;
        total += dt;
        min = min.min(dt);
        max = max.max(dt);
    }
    let stats = BenchStats { iters, mean_ns: total / iters as f64, min_ns: min, max_ns: max };
    println!(
        "bench {label:<44} {:>12.2} us/iter  (min {:.2}, max {:.2}, n={})",
        stats.mean_us(),
        min / 1e3,
        max / 1e3,
        iters
    );
    bench_registry().lock().expect("bench registry").push((label.to_string(), stats));
    stats
}

/// Record a *metric* (not a timing) in the bench registry, so scenario
/// outputs — goodput, time-to-drain, tail amplification — land in the
/// bench JSON next to the timings and CI can scrape them by name. The
/// value is carried in the `mean_ms` field (min == max == mean,
/// iters == 1); name metrics so the unit is obvious (`..._ms`, `..._x`).
pub fn record_metric(label: &str, value: f64) {
    let ns = value * 1e6; // mean_ms() == value
    let stats = BenchStats { iters: 1, mean_ns: ns, min_ns: ns, max_ns: ns };
    println!("metric {label:<44} {value:>12.4}");
    bench_registry().lock().expect("bench registry").push((label.to_string(), stats));
}

fn bench_registry() -> &'static std::sync::Mutex<Vec<(String, BenchStats)>> {
    static REGISTRY: std::sync::OnceLock<std::sync::Mutex<Vec<(String, BenchStats)>>> =
        std::sync::OnceLock::new();
    REGISTRY.get_or_init(|| std::sync::Mutex::new(Vec::new()))
}

/// Minimal JSON string escaping (labels are code-controlled, but keep the
/// output well-formed regardless).
fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Write every [`bench`] result recorded so far to a JSON file — an array
/// of `{"name", "mean_ms", "iters"}` objects (plus min/max for context) —
/// and return its path. `$BENCH_JSON` overrides the path; otherwise
/// `default_name` lands in the working directory. Each bench main passes
/// its own default (`BENCH_perf.json`, `BENCH_serving.json`, …) so
/// back-to-back local bench runs never clobber each other's results.
pub fn write_bench_json(default_name: &str) -> std::io::Result<PathBuf> {
    let path =
        PathBuf::from(std::env::var("BENCH_JSON").unwrap_or_else(|_| default_name.to_string()));
    write_bench_json_to(&path)?;
    Ok(path)
}

/// [`write_bench_json`] to an explicit path (tests use this directly so
/// they never have to mutate the process environment).
pub fn write_bench_json_to(path: &Path) -> std::io::Result<()> {
    let list = bench_registry().lock().expect("bench registry");
    let mut s = String::from("[\n");
    for (i, (name, st)) in list.iter().enumerate() {
        s.push_str(&format!(
            "  {{\"name\": \"{}\", \"mean_ms\": {:.6}, \"iters\": {}, \"min_ms\": {:.6}, \"max_ms\": {:.6}}}{}\n",
            json_escape(name),
            st.mean_ms(),
            st.iters,
            st.min_ns / 1e6,
            st.max_ns / 1e6,
            if i + 1 < list.len() { "," } else { "" }
        ));
    }
    s.push_str("]\n");
    std::fs::write(path, s)
}

/// Summary of one [`fuzz_determinism`] sweep, so callers can assert the
/// harness actually exercised the interesting regimes.
#[derive(Debug, Clone, Copy, Default)]
pub struct FuzzSummary {
    pub trials: usize,
    /// Trials driven by a closed-loop source (client pool or trace).
    pub closed_loop_trials: usize,
    /// Trials with the epoch-barrier work-stealing pass enabled.
    pub steal_trials: usize,
    /// Trials running under a non-empty fault plan or MAC contention.
    pub chaos_trials: usize,
    /// Requests served or shed across all trials (at the 1-thread count).
    pub requests: u64,
    /// Trials replayed on the legacy scan engine and diffed byte-for-byte
    /// against the calendar engine's output (every trial).
    pub oracle_trials: usize,
}

/// Determinism fuzz harness for the sharded cluster engine: generate
/// `trials` randomized `ClusterConfig`s from `seed` — package/shard
/// counts, routing policy, queue caps, deadline shedding, preemption,
/// class populations, epoch widths, work stealing on/off, randomized
/// fault plans (kill / degrade / stall / spike windows) with MAC
/// contention, and all three
/// source families (Poisson, closed-loop client pool, client-trace
/// replay) — and assert for each that the emitted stats JSON, the
/// telemetry metrics JSON, and the Chrome trace export (every trial runs
/// with span recording on) are **byte-identical at 1, 2 and 4 worker
/// threads**, and that request conservation (`arrived == completed +
/// shed + failed`, globally and per class) holds after the drain.
/// Every trial also replays once on the legacy O(packages)-scan
/// scheduler and diffs all three exports byte-for-byte against the
/// calendar engine — the cross-scheduler oracle gate.
/// Source family, stealing, and chaos alternate
/// round-robin across trials so even a short sweep covers every regime;
/// everything else is drawn from the seeded RNG, so a failing seed
/// reproduces exactly.
///
/// Panics (with the trial's parameters in the message) on any violation;
/// returns a [`FuzzSummary`] of what was covered.
pub fn fuzz_determinism(seed: u64, trials: usize) -> FuzzSummary {
    use crate::cluster::{
        AdmissionConfig, ClassMix, ClassSpec, Cluster, ClusterConfig, SyncConfig, TrafficClass,
    };
    use crate::config::DesignPoint;
    use crate::fault::{ContentionConfig, FaultPlan};
    use crate::serve::{ms_to_cycles, MixEntry, ModelKind, PackageSpec, RoutePolicy, Source, WorkloadMix};
    use crate::workload::trace::synthetic_arrivals;

    let mut rng = Rng::new(seed);
    let mut summary = FuzzSummary::default();
    for trial in 0..trials {
        let mix = WorkloadMix::new(vec![
            MixEntry { kind: ModelKind::TinyCnn, weight: 3.0, slo_cycles: ms_to_cycles(20.0) },
            MixEntry { kind: ModelKind::Mlp, weight: 1.0, slo_cycles: ms_to_cycles(40.0) },
        ]);
        let packages = rng.range_u64(1, 5) as usize;
        let shards = rng.range_u64(1, 4) as usize;
        let steal = trial % 2 == 1;
        let queue_cap = match rng.range_u64(0, 3) {
            0 => None,
            1 => Some(0),
            n => Some((4 * n) as usize),
        };
        // 1–3 distinct classes with random weights, SLO scales (possibly
        // deadline-free) and shed policies.
        let mask = rng.range_u64(1, 7);
        let specs: Vec<ClassSpec> = TrafficClass::ALL
            .iter()
            .enumerate()
            .filter(|(bit, _)| mask & (1u64 << *bit) != 0)
            .map(|(_, &class)| ClassSpec {
                class,
                weight: 0.2 + rng.next_f32() as f64,
                slo_scale: if rng.range_u64(0, 3) == 0 {
                    f64::INFINITY
                } else {
                    1.0 + rng.next_f32() as f64 * 4.0
                },
                deadline_shed: rng.range_u64(0, 1) == 1,
            })
            .collect();
        // Every other trial runs chaotic: 0–3 randomized fault windows
        // (kill / degrade / stall / spike) plus, half the time, MAC
        // contention with a random background load. The fault spec goes
        // through the same `FaultPlan::parse` grammar the CLI uses so
        // the fuzzer also exercises the parser.
        let chaos = trial % 2 == 0;
        let mut fault_spec = String::new();
        let mut contention = ContentionConfig::default();
        if chaos {
            for _ in 0..rng.range_u64(0, 3) {
                let start = 0.2 + rng.next_f32() as f64 * 2.0;
                let end = start + 0.2 + rng.next_f32() as f64 * 2.0;
                let ev = match rng.range_u64(0, 3) {
                    0 => format!("kill:{}@{start:.3}..{end:.3}", rng.range_u64(0, packages as u64 - 1)),
                    1 => format!(
                        "degrade:{}:{:.2}@{start:.3}..{end:.3}",
                        rng.range_u64(0, packages as u64 - 1),
                        1.5 + rng.next_f32() as f64 * 2.0
                    ),
                    2 => format!("stall:{}@{start:.3}..{end:.3}", rng.range_u64(0, shards as u64 - 1)),
                    _ => format!("spike:{:.2}@{start:.3}..{end:.3}", rng.next_f32() as f64 * 0.5),
                };
                if !fault_spec.is_empty() {
                    fault_spec.push(';');
                }
                fault_spec.push_str(&ev);
            }
            // Contention on a coin flip — but always when the plan drew
            // zero events, so every chaos trial exercises *something*.
            if fault_spec.is_empty() || rng.range_u64(0, 1) == 1 {
                contention = ContentionConfig::with_background(rng.next_f32() as f64 * 0.5);
            }
        }
        let faults = FaultPlan::parse(&fault_spec).expect("fuzz-generated fault spec parses");
        let cfg = ClusterConfig {
            shards,
            threads: 1, // overridden per run below
            policy: *rng.pick(&RoutePolicy::ALL),
            classes: ClassMix::new(specs),
            admission: AdmissionConfig { queue_cap, shed_late: rng.range_u64(0, 1) == 1 },
            preemption: rng.range_u64(0, 1) == 1,
            sync: SyncConfig {
                epoch_cycles: ms_to_cycles(0.1 + rng.next_f32() as f64 * 1.4),
                steal,
                ..SyncConfig::default()
            },
            calibrated_eta: rng.range_u64(0, 1) == 1,
            telemetry: crate::telemetry::TelemetryConfig::enabled(),
            faults,
            contention,
            ..Default::default()
        };
        let horizon = ms_to_cycles(2.0 + rng.next_f32() as f64 * 4.0);
        let src_seed = rng.next_u64();
        let source = match trial % 3 {
            0 => Source::poisson(mix, 1000.0 + rng.next_f32() as f64 * 11_000.0, src_seed),
            1 => Source::closed_loop(
                mix,
                rng.range_u64(1, 8) as usize,
                0.05 + rng.next_f32() as f64 * 1.5,
                rng.range_u64(2, 8),
                src_seed,
            ),
            _ => {
                let counts: Vec<usize> =
                    (0..rng.range_u64(1, 6)).map(|_| rng.range_u64(1, 12) as usize).collect();
                let spacing = 0.1 + rng.next_f32() as f64 * 0.5;
                Source::client_trace(mix, &synthetic_arrivals(&counts, spacing, 0.5, src_seed), src_seed)
            }
        };
        let label = format!(
            "fuzz trial {trial} (seed {seed:#x}): {packages} pkg, {shards} shards, steal {steal}, \
             cap {queue_cap:?}, epoch {:.0} cyc, {}, faults \"{fault_spec}\", contention {}",
            cfg.sync.epoch_cycles,
            if source.is_open_loop() { "open-loop" } else { "closed-loop" },
            cfg.contention.enabled,
        );
        if !source.is_open_loop() {
            summary.closed_loop_trials += 1;
        }
        if steal {
            summary.steal_trials += 1;
        }
        if !cfg.faults.is_empty() || cfg.contention.enabled {
            summary.chaos_trials += 1;
        }

        let mut jsons = Vec::new();
        let mut metrics = Vec::new();
        let mut traces = Vec::new();
        for threads in [1usize, 2, 4] {
            let cluster = Cluster::new(
                PackageSpec::homogeneous(packages, DesignPoint::WIENNA_C),
                ClusterConfig { threads, ..cfg.clone() },
            );
            let mut src = source.clone();
            let stats = cluster.run(&mut src, horizon);
            assert_eq!(
                stats.serve.arrived(),
                stats.serve.completed() + stats.serve.shed() + stats.serve.failed(),
                "{label}: arrived != completed + shed + failed at {threads} threads"
            );
            let per_class: u64 =
                stats.per_class.values().map(|m| m.completed + m.shed + m.failed).sum();
            assert_eq!(per_class, stats.serve.arrived(), "{label}: per-class balance");
            if threads == 1 {
                summary.requests += stats.serve.arrived();
            }
            jsons.push(stats.to_json());
            // The memo counters are process-global (order-dependent under
            // parallel misses), so the harness diffs everything but them;
            // the CLI prewarms the memo before parallel runs instead.
            metrics.push(stats.metrics_json(None));
            traces.push(stats.chrome_trace());
        }
        assert_eq!(jsons[0], jsons[1], "{label}: 1-thread vs 2-thread stats JSON diverged");
        assert_eq!(jsons[0], jsons[2], "{label}: 1-thread vs 4-thread stats JSON diverged");
        assert_eq!(metrics[0], metrics[1], "{label}: 1 vs 2-thread metrics JSON diverged");
        assert_eq!(metrics[0], metrics[2], "{label}: 1 vs 4-thread metrics JSON diverged");
        assert_eq!(traces[0], traces[1], "{label}: 1 vs 2-thread chrome trace diverged");
        assert_eq!(traces[0], traces[2], "{label}: 1 vs 4-thread chrome trace diverged");

        // Oracle gate: the bucketed completion calendar must schedule
        // byte-for-byte like the legacy O(packages)-scan loop it
        // replaced — every trial (chaos included) replays once on the
        // legacy engine and diffs the full stats + telemetry output.
        let cluster = Cluster::new(
            PackageSpec::homogeneous(packages, DesignPoint::WIENNA_C),
            ClusterConfig {
                threads: 1,
                scheduler: crate::cluster::SchedulerKind::Legacy,
                ..cfg.clone()
            },
        );
        let mut src = source.clone();
        let legacy = cluster.run(&mut src, horizon);
        assert_eq!(jsons[0], legacy.to_json(), "{label}: calendar vs legacy-oracle stats diverged");
        assert_eq!(
            metrics[0],
            legacy.metrics_json(None),
            "{label}: calendar vs legacy-oracle metrics diverged"
        );
        assert_eq!(
            traces[0],
            legacy.chrome_trace(),
            "{label}: calendar vs legacy-oracle chrome trace diverged"
        );
        summary.oracle_trials += 1;
        summary.trials += 1;
    }
    summary
}

/// Relative-equality assertion helper (replaces `approx`).
#[macro_export]
macro_rules! assert_close {
    ($a:expr, $b:expr) => {
        $crate::assert_close!($a, $b, 1e-9)
    };
    ($a:expr, $b:expr, $eps:expr) => {{
        let (a, b) = ($a as f64, $b as f64);
        let scale = a.abs().max(b.abs()).max(1e-12);
        assert!(
            (a - b).abs() <= $eps * scale + $eps,
            "assert_close failed: {} vs {} (eps {})",
            a,
            b,
            $eps
        );
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tempdir_creates_and_cleans() {
        let p;
        {
            let d = TempDir::new("wienna_tu");
            p = d.path().to_path_buf();
            assert!(p.exists());
        }
        assert!(!p.exists());
    }

    #[test]
    fn rng_is_deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn rng_ranges() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let v = r.range_u64(3, 9);
            assert!((3..=9).contains(&v));
            let f = r.next_f32();
            assert!((0.0..1.0).contains(&f));
        }
    }

    #[test]
    fn close_macro() {
        assert_close!(1.0, 1.0 + 1e-12);
        assert_close!(1000.0, 1000.1, 1e-3);
    }

    #[test]
    fn bench_runs() {
        let s = bench("noop", 5, || 1 + 1);
        assert_eq!(s.iters, 5);
        assert!(s.mean_ns >= 0.0);
    }

    #[test]
    fn bench_json_is_wellformed_and_contains_results() {
        let d = TempDir::new("wienna_bench_json");
        let path = d.path().join("BENCH_perf.json");
        bench("json_probe", 3, || 2 + 2);
        write_bench_json_to(&path).expect("write json");
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.trim_start().starts_with('['));
        assert!(text.trim_end().ends_with(']'));
        assert!(text.contains("\"name\": \"json_probe\""));
        assert!(text.contains("\"iters\": 3"));
        assert!(text.contains("\"mean_ms\""));
        // No trailing comma before the closing bracket.
        assert!(!text.contains(",\n]"));
    }

    #[test]
    fn json_escape_handles_specials() {
        assert_eq!(json_escape("plain"), "plain");
        assert_eq!(json_escape("a\"b\\c"), "a\\\"b\\\\c");
        assert_eq!(json_escape("x\ny"), "x\\ny");
    }
}
