//! Artifact manifest: the contract between `python/compile/aot.py`
//! (build time) and the Rust runtime (run time).
//!
//! The manifest is a line-based format (this build environment is fully
//! offline and dependency-light, so no JSON library):
//!
//! ```text
//! # wienna artifact manifest
//! version 1
//! artifact <name> <file> <dtype> <in0;in1;...> <out>
//! ```
//!
//! where each shape is `64x64`-style. Example:
//!
//! ```text
//! artifact matmul64 matmul64.hlo.txt f32 64x64;64x64 64x64
//! ```

use crate::anyhow::{self, bail, Context, Result};
use std::path::{Path, PathBuf};

/// One AOT-lowered computation.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArtifactSpec {
    /// Stable name the coordinator dispatches by, e.g. `"matmul64"`.
    pub name: String,
    /// HLO text file, relative to the manifest directory.
    pub file: String,
    /// Input shapes, row-major.
    pub inputs: Vec<Vec<usize>>,
    /// Output shape (single tensor; lowered with `return_tuple=True` and
    /// unwrapped on the Rust side).
    pub output: Vec<usize>,
    /// Element dtype; only `"f32"` is used by the tiny e2e network.
    pub dtype: String,
}

impl ArtifactSpec {
    pub fn input_elems(&self, i: usize) -> usize {
        self.inputs[i].iter().product()
    }

    pub fn output_elems(&self) -> usize {
        self.output.iter().product()
    }
}

/// The parsed `artifacts/manifest.txt`.
#[derive(Debug, Clone)]
pub struct ArtifactManifest {
    /// Version tag so stale artifact dirs fail loudly.
    pub version: u32,
    pub artifacts: Vec<ArtifactSpec>,
    pub dir: PathBuf,
}

pub const MANIFEST_VERSION: u32 = 1;
pub const MANIFEST_FILE: &str = "manifest.txt";

fn parse_shape(s: &str) -> Result<Vec<usize>> {
    s.split('x')
        .map(|d| d.parse::<usize>().with_context(|| format!("bad shape dim '{d}' in '{s}'")))
        .collect()
}

/// Parse the manifest text (exposed for tests).
pub fn parse_manifest(text: &str) -> Result<(u32, Vec<ArtifactSpec>)> {
    let mut version: Option<u32> = None;
    let mut artifacts = Vec::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut parts = line.split_whitespace();
        match parts.next() {
            Some("version") => {
                let v = parts.next().context("version line missing value")?;
                version = Some(v.parse().with_context(|| format!("bad version '{v}'"))?);
            }
            Some("artifact") => {
                let name = parts.next().context("artifact line: missing name")?.to_string();
                let file = parts.next().context("artifact line: missing file")?.to_string();
                let dtype = parts.next().context("artifact line: missing dtype")?.to_string();
                let ins = parts.next().context("artifact line: missing input shapes")?;
                let out = parts.next().context("artifact line: missing output shape")?;
                if parts.next().is_some() {
                    bail!("line {}: trailing tokens", lineno + 1);
                }
                let inputs = ins.split(';').map(parse_shape).collect::<Result<Vec<_>>>()?;
                let output = parse_shape(out)?;
                artifacts.push(ArtifactSpec { name, file, inputs, output, dtype });
            }
            Some(tok) => bail!("line {}: unknown directive '{tok}'", lineno + 1),
            None => unreachable!(),
        }
    }
    let version = version.context("manifest missing 'version' line")?;
    Ok((version, artifacts))
}

impl ArtifactManifest {
    /// Load and validate `dir/manifest.txt`.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join(MANIFEST_FILE);
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?}; run `make artifacts` first"))?;
        let (version, artifacts) = parse_manifest(&text)?;
        anyhow::ensure!(
            version == MANIFEST_VERSION,
            "manifest version {version} != expected {MANIFEST_VERSION}; re-run `make artifacts`"
        );
        anyhow::ensure!(!artifacts.is_empty(), "manifest lists no artifacts");
        for a in &artifacts {
            let f = dir.join(&a.file);
            anyhow::ensure!(f.exists(), "artifact file missing: {f:?}");
            anyhow::ensure!(a.dtype == "f32", "unsupported dtype {} in {}", a.dtype, a.name);
            anyhow::ensure!(!a.inputs.is_empty(), "artifact {} has no inputs", a.name);
        }
        let mut names: Vec<&str> = artifacts.iter().map(|a| a.name.as_str()).collect();
        names.sort_unstable();
        names.dedup();
        anyhow::ensure!(names.len() == artifacts.len(), "duplicate artifact names in manifest");
        Ok(ArtifactManifest { version, artifacts, dir: dir.to_path_buf() })
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .iter()
            .find(|a| a.name == name)
            .with_context(|| format!("artifact '{name}' not in manifest"))
    }

    pub fn hlo_path(&self, spec: &ArtifactSpec) -> PathBuf {
        self.dir.join(&spec.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::TempDir;

    const GOOD: &str = "# comment\nversion 1\nartifact m m.hlo.txt f32 2x2;2x2 2x2\n";

    #[test]
    fn parses_valid_text() {
        let (v, a) = parse_manifest(GOOD).unwrap();
        assert_eq!(v, 1);
        assert_eq!(a.len(), 1);
        assert_eq!(a[0].inputs, vec![vec![2, 2], vec![2, 2]]);
        assert_eq!(a[0].output_elems(), 4);
    }

    #[test]
    fn rejects_malformed_lines() {
        assert!(parse_manifest("version 1\nartifact only-name\n").is_err());
        assert!(parse_manifest("artifact m f f32 2x2 2x2\n").is_err()); // no version
        assert!(parse_manifest("version 1\nbogus line\n").is_err());
        assert!(parse_manifest("version 1\nartifact m f f32 2xq 2x2\n").is_err());
        assert!(parse_manifest("version 1\nartifact m f f32 2x2 2x2 extra\n").is_err());
    }

    #[test]
    fn loads_valid_manifest_dir() {
        let d = TempDir::new("wienna_manifest");
        std::fs::write(d.path().join(MANIFEST_FILE), GOOD).unwrap();
        std::fs::write(d.path().join("m.hlo.txt"), "HloModule m").unwrap();
        let m = ArtifactManifest::load(d.path()).unwrap();
        assert_eq!(m.artifacts.len(), 1);
        assert!(m.get("m").is_ok());
        assert!(m.get("nope").is_err());
    }

    #[test]
    fn rejects_wrong_version_and_missing_file() {
        let d = TempDir::new("wienna_manifest_bad");
        std::fs::write(d.path().join(MANIFEST_FILE), "version 99\nartifact m m.hlo.txt f32 2x2 2x2\n").unwrap();
        std::fs::write(d.path().join("m.hlo.txt"), "x").unwrap();
        assert!(ArtifactManifest::load(d.path()).is_err());
        std::fs::write(d.path().join(MANIFEST_FILE), GOOD).unwrap();
        std::fs::remove_file(d.path().join("m.hlo.txt")).unwrap();
        assert!(ArtifactManifest::load(d.path()).is_err());
    }

    #[test]
    fn rejects_duplicate_names() {
        let d = TempDir::new("wienna_manifest_dup");
        let text = "version 1\nartifact m m.hlo.txt f32 2x2 2x2\nartifact m m.hlo.txt f32 2x2 2x2\n";
        std::fs::write(d.path().join(MANIFEST_FILE), text).unwrap();
        std::fs::write(d.path().join("m.hlo.txt"), "x").unwrap();
        assert!(ArtifactManifest::load(d.path()).is_err());
    }
}
