//! PJRT runtime (substrate S10): loads the AOT-compiled HLO artifacts
//! produced by `python/compile/aot.py` and executes them from the
//! coordinator's hot path. Python never runs at inference time — the
//! interchange format is HLO *text* (see DESIGN.md and aot_recipe notes):
//! jax ≥ 0.5 emits protos with 64-bit instruction ids that xla_extension
//! 0.5.1 rejects, while the text parser reassigns ids and round-trips
//! cleanly.

pub mod artifact;
pub mod client;

pub use artifact::{ArtifactManifest, ArtifactSpec};
pub use client::{ChipletEngine, ExecutableCache};
