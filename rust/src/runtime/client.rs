//! PJRT executable cache and chiplet compute engine.
//!
//! One `PjRtClient` (CPU) is created per process; each HLO artifact is
//! compiled exactly once and cached. The coordinator then executes tile
//! computations against the cache from its hot path — this is the "one
//! compiled executable per model variant" runtime of the architecture.

use super::artifact::{ArtifactManifest, ArtifactSpec};
use crate::anyhow::{self, Context, Result};
use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;

// Without the `xla-backend` feature the compile-only stub (`crate::xla`)
// stands in for the real bindings, so this module — and everything
// pjrt-gated above it — stays type-checked in the offline build.
#[cfg(not(feature = "xla-backend"))]
use crate::xla;

/// Compiled-executable cache over an artifact manifest.
pub struct ExecutableCache {
    client: xla::PjRtClient,
    manifest: ArtifactManifest,
    compiled: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
}

impl ExecutableCache {
    /// Create the PJRT CPU client and attach it to `artifacts_dir`.
    pub fn new(artifacts_dir: &Path) -> Result<Self> {
        let manifest = ArtifactManifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(ExecutableCache { client, manifest, compiled: Mutex::new(HashMap::new()) })
    }

    pub fn manifest(&self) -> &ArtifactManifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch the cached) executable for `name`.
    pub fn executable(&self, name: &str) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.compiled.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let spec = self.manifest.get(name)?;
        let path = self.manifest.hlo_path(spec);
        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
            .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).with_context(|| format!("compiling artifact '{name}'"))?;
        let exe = std::sync::Arc::new(exe);
        self.compiled.lock().unwrap().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Eagerly compile every artifact (start-of-run warm-up).
    pub fn warm_up(&self) -> Result<usize> {
        let names: Vec<String> = self.manifest.artifacts.iter().map(|a| a.name.clone()).collect();
        for n in &names {
            self.executable(n)?;
        }
        Ok(names.len())
    }

    /// Execute artifact `name` on f32 input buffers.
    ///
    /// Shapes are taken from the manifest; `inputs[i].len()` must match.
    pub fn execute_f32(&self, name: &str, inputs: &[&[f32]]) -> Result<Vec<f32>> {
        let spec = self.manifest.get(name)?.clone();
        anyhow::ensure!(inputs.len() == spec.inputs.len(), "artifact '{name}' wants {} inputs, got {}", spec.inputs.len(), inputs.len());
        let exe = self.executable(name)?;
        let mut literals = Vec::with_capacity(inputs.len());
        for (i, buf) in inputs.iter().enumerate() {
            anyhow::ensure!(
                buf.len() == spec.input_elems(i),
                "artifact '{name}' input {i}: want {} elems, got {}",
                spec.input_elems(i),
                buf.len()
            );
            let dims: Vec<i64> = spec.inputs[i].iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(buf).reshape(&dims).context("reshaping input literal")?;
            literals.push(lit);
        }
        let result = exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
        let out = result.to_tuple1().context("unwrapping result tuple")?;
        let values = out.to_vec::<f32>().context("reading f32 output")?;
        anyhow::ensure!(values.len() == spec.output_elems(), "artifact '{name}' output: want {} elems, got {}", spec.output_elems(), values.len());
        Ok(values)
    }

    /// Specs available, for introspection.
    pub fn specs(&self) -> &[ArtifactSpec] {
        &self.manifest.artifacts
    }
}

/// A chiplet-level compute engine: thin façade the coordinator uses to run
/// one chiplet's tile work. Today all chiplets share one CPU PJRT client;
/// the abstraction point is where per-chiplet devices would attach.
pub struct ChipletEngine {
    cache: std::sync::Arc<ExecutableCache>,
}

impl ChipletEngine {
    pub fn new(cache: std::sync::Arc<ExecutableCache>) -> Self {
        ChipletEngine { cache }
    }

    /// Run one GEMM tile `a[m,k] x b[k,n]` through the named artifact.
    pub fn run_tile(&self, artifact: &str, a: &[f32], b: &[f32]) -> Result<Vec<f32>> {
        self.cache.execute_f32(artifact, &[a, b])
    }

    pub fn cache(&self) -> &ExecutableCache {
        &self.cache
    }
}
