//! Layer-type classification (paper Table 1).
//!
//! The paper buckets layers into five types whose bandwidth/partitioning
//! behaviour differs (Figs 3, 7, 9, 10):
//!
//! | Type       | Description                                               |
//! |------------|-----------------------------------------------------------|
//! | High-res   | CONV2D with fewer channels than input-activation width    |
//! | Low-res    | CONV2D with more channels than input-activation width     |
//! | Residual   | Skip connections                                          |
//! | Fully-conn.| GEMM layer                                                |
//! | UpCONV     | CONV2D variant that increases activation resolution       |

use super::layer::{Layer, OpKind};
use std::fmt;

/// The five layer categories from Table 1.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum LayerType {
    HighRes,
    LowRes,
    Residual,
    FullyConnected,
    UpConv,
}

impl LayerType {
    /// All types in the order the paper's figures list them.
    pub const ALL: [LayerType; 5] = [
        LayerType::HighRes,
        LayerType::LowRes,
        LayerType::Residual,
        LayerType::FullyConnected,
        LayerType::UpConv,
    ];

    /// Short label used in figure axes.
    pub fn label(&self) -> &'static str {
        match self {
            LayerType::HighRes => "High-res",
            LayerType::LowRes => "Low-res",
            LayerType::Residual => "Residual",
            LayerType::FullyConnected => "FC",
            LayerType::UpConv => "Up-Conv",
        }
    }
}

impl fmt::Display for LayerType {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.label())
    }
}

/// Classify a layer per Table 1.
///
/// A CONV2D layer is *high-resolution* when its input-activation width
/// exceeds its channel count (`X > C`), i.e. parallelism is plentiful in
/// the spatial dims; *low-resolution* otherwise.
pub fn classify(layer: &Layer) -> LayerType {
    match layer.op {
        OpKind::FullyConnected => LayerType::FullyConnected,
        OpKind::ResidualAdd => LayerType::Residual,
        OpKind::UpConv => LayerType::UpConv,
        OpKind::Conv2D => {
            if layer.x > layer.c {
                LayerType::HighRes
            } else {
                LayerType::LowRes
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::layer::Layer;

    #[test]
    fn classify_all_kinds() {
        // 224-wide input, 3 channels: high resolution.
        assert_eq!(classify(&Layer::conv("a", 1, 64, 3, 224, 224, 7, 7, 2)), LayerType::HighRes);
        // 7-wide input, 512 channels: low resolution.
        assert_eq!(classify(&Layer::conv("b", 1, 512, 512, 7, 7, 3, 3, 1)), LayerType::LowRes);
        assert_eq!(classify(&Layer::fc("c", 1, 1000, 2048)), LayerType::FullyConnected);
        assert_eq!(classify(&Layer::residual("d", 1, 256, 56, 56)), LayerType::Residual);
        assert_eq!(classify(&Layer::upconv("e", 1, 256, 512, 28, 28, 2, 2, 2)), LayerType::UpConv);
    }

    #[test]
    fn boundary_equal_width_and_channels_is_low_res() {
        // X == C → "more channels than width" bucket (not strictly more,
        // but the paper's high-res definition requires input dim > channel
        // dim).
        assert_eq!(classify(&Layer::conv("b", 1, 64, 56, 56, 56, 3, 3, 1)), LayerType::LowRes);
    }
}
