//! Scaled-down CNN used by the end-to-end real-numerics example.
//!
//! The paper's evaluation networks run through the analytical cost model;
//! this small ResNet-style network additionally runs with *real numerics*
//! through the AOT-compiled JAX/Pallas compute path on a simulated
//! multi-chiplet package, proving the three layers compose. Its tile shapes
//! are the ones `python/compile/aot.py` lowers to HLO artifacts.

use super::{conv_padded, Layer, Model};

/// Tile-shape contract shared with `python/compile/aot.py`:
/// every conv in the tiny network reduces to GEMM tiles of
/// `[TILE_M, TILE_K] x [TILE_K, TILE_N]` after im2col.
pub const TILE_M: usize = 64;
pub const TILE_K: usize = 64;
pub const TILE_N: usize = 64;

/// Build the tiny end-to-end CNN.
///
/// Input is `batch x 16 x 32 x 32`. All convs are "same"-padded 3x3 or
/// 1x1 so that im2col dimensions stay multiples of the tile contract.
pub fn tiny_cnn(batch: u64) -> Model {
    let n = batch;
    let mut layers = Vec::new();
    layers.push(conv_padded("t_conv1", n, 32, 16, 32, 32, 3, 3, 1));
    layers.push(conv_padded("t_conv2", n, 32, 32, 32, 32, 3, 3, 1));
    layers.push(Layer::residual("t_add1", n, 32, 32, 32));
    layers.push(conv_padded("t_conv3", n, 64, 32, 32, 32, 3, 3, 2));
    layers.push(conv_padded("t_conv4", n, 64, 64, 16, 16, 3, 3, 1));
    layers.push(Layer::residual("t_add2", n, 64, 16, 16));
    layers.push(Layer::fc("t_fc", n, 64, 64 * 16 * 16));
    Model { name: format!("tiny_cnn_b{batch}"), layers }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_dims() {
        let m = tiny_cnn(1);
        assert_eq!(m.layers.len(), 7);
        assert_eq!(m.layers[3].y_out(), 16);
        assert!(m.total_macs() > 0);
    }
}
