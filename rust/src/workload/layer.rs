//! DNN layer descriptors (substrate S1).
//!
//! A layer is described by the seven classic convolution loop bounds plus
//! stride/upsample factors. Fully-connected layers are convolutions with
//! `Y = X = R = S = 1`; residual (skip-connection) adds are elementwise
//! layers; up-convolutions ("UpCONV" in the paper, Table 1) are transposed
//! convolutions that enlarge the activation by `upsample`.
//!
//! Layer names are reference-counted (`Arc<str>`) so that cloning a layer
//! — which the partitioner does on every cost evaluation to derive the
//! per-chiplet sub-layer — never touches the heap. The name-free geometry
//! lives in [`LayerShape`], the `Copy` key the cost engine's memo table
//! interns (`cost::memo`).

use std::sync::Arc;

/// Operator kind, mirroring the paper's Table 1 row "Description".
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OpKind {
    /// Standard 2-D convolution.
    Conv2D,
    /// GEMM layer (`Y=X=R=S=1`).
    FullyConnected,
    /// Elementwise addition of two activation tensors (skip connection).
    ResidualAdd,
    /// Transposed convolution that increases activation resolution.
    UpConv,
}

/// A single DNN layer with its full loop-nest bounds.
///
/// Dimension names follow the MAESTRO convention the paper uses:
/// `N` batch, `K` output channels, `C` input channels, `Y`/`X` input
/// activation height/width, `R`/`S` filter height/width.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Layer {
    /// Human-readable identifier, e.g. `"conv2_1_3x3"` (cheaply clonable).
    pub name: Arc<str>,
    pub op: OpKind,
    /// Batch size.
    pub n: u64,
    /// Output channels (filters).
    pub k: u64,
    /// Input channels.
    pub c: u64,
    /// Input activation height.
    pub y: u64,
    /// Input activation width.
    pub x: u64,
    /// Filter height.
    pub r: u64,
    /// Filter width.
    pub s: u64,
    /// Convolution stride (1 for FC/residual).
    pub stride: u64,
    /// Up-sampling factor for [`OpKind::UpConv`] (1 otherwise).
    pub upsample: u64,
}

impl Layer {
    /// Standard convolution layer constructor.
    #[allow(clippy::too_many_arguments)]
    pub fn conv(name: &str, n: u64, k: u64, c: u64, y: u64, x: u64, r: u64, s: u64, stride: u64) -> Self {
        Layer {
            name: Arc::from(name),
            op: OpKind::Conv2D,
            n,
            k,
            c,
            y,
            x,
            r,
            s,
            stride,
            upsample: 1,
        }
    }

    /// Fully-connected layer: `out = W[k,c] · in[c]` per batch element.
    pub fn fc(name: &str, n: u64, k: u64, c: u64) -> Self {
        Layer {
            name: Arc::from(name),
            op: OpKind::FullyConnected,
            n,
            k,
            c,
            y: 1,
            x: 1,
            r: 1,
            s: 1,
            stride: 1,
            upsample: 1,
        }
    }

    /// Residual (elementwise) addition over a `[n, c, y, x]` activation.
    pub fn residual(name: &str, n: u64, c: u64, y: u64, x: u64) -> Self {
        Layer {
            name: Arc::from(name),
            op: OpKind::ResidualAdd,
            n,
            k: c,
            c,
            y,
            x,
            r: 1,
            s: 1,
            stride: 1,
            upsample: 1,
        }
    }

    /// Up-convolution (transposed conv) with the given upsampling factor.
    #[allow(clippy::too_many_arguments)]
    pub fn upconv(name: &str, n: u64, k: u64, c: u64, y: u64, x: u64, r: u64, s: u64, upsample: u64) -> Self {
        Layer {
            name: Arc::from(name),
            op: OpKind::UpConv,
            n,
            k,
            c,
            y,
            x,
            r,
            s,
            stride: 1,
            upsample,
        }
    }

    /// Output activation height.
    pub fn y_out(&self) -> u64 {
        match self.op {
            OpKind::UpConv => self.y * self.upsample,
            _ => ((self.y.saturating_sub(self.r)) / self.stride) + 1,
        }
    }

    /// Output activation width.
    pub fn x_out(&self) -> u64 {
        match self.op {
            OpKind::UpConv => self.x * self.upsample,
            _ => ((self.x.saturating_sub(self.s)) / self.stride) + 1,
        }
    }

    /// Total multiply-accumulate operations in the layer.
    ///
    /// Residual adds are counted as one MAC per output element (one add on
    /// the adder of a PE), matching how an elementwise op occupies the
    /// array for one pass.
    pub fn macs(&self) -> u64 {
        match self.op {
            OpKind::ResidualAdd => self.n * self.c * self.y * self.x,
            _ => self.n * self.k * self.c * self.y_out() * self.x_out() * self.r * self.s,
        }
    }

    /// Input activation tensor volume in elements (`N·C·Y·X`).
    pub fn input_elems(&self) -> u64 {
        let base = self.n * self.c * self.y * self.x;
        match self.op {
            // Residual adds read two input tensors.
            OpKind::ResidualAdd => 2 * base,
            _ => base,
        }
    }

    /// Weight tensor volume in elements (`K·C·R·S`), zero for residual.
    pub fn weight_elems(&self) -> u64 {
        match self.op {
            OpKind::ResidualAdd => 0,
            _ => self.k * self.c * self.r * self.s,
        }
    }

    /// Output activation tensor volume in elements.
    pub fn output_elems(&self) -> u64 {
        self.n * self.k * self.y_out() * self.x_out()
    }

    /// `true` if the layer has a spatial (Y/X) extent larger than 1.
    pub fn is_spatial(&self) -> bool {
        self.y > 1 || self.x > 1
    }

    /// The name-free geometry of this layer — everything that determines
    /// its cost under a given strategy and system configuration.
    pub fn shape(&self) -> LayerShape {
        LayerShape {
            op: self.op,
            n: self.n,
            k: self.k,
            c: self.c,
            y: self.y,
            x: self.x,
            r: self.r,
            s: self.s,
            stride: self.stride,
            upsample: self.upsample,
        }
    }
}

/// The geometric identity of a [`Layer`]: its full loop-nest bounds minus
/// the human-readable name. Two layers with equal shapes have identical
/// cost under every strategy and system configuration, so this is the key
/// the crate-level cost memo table (`cost::memo`) interns — layers named
/// `conv2_1` and `conv2_2` with the same bounds share one cached cost.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct LayerShape {
    pub op: OpKind,
    pub n: u64,
    pub k: u64,
    pub c: u64,
    pub y: u64,
    pub x: u64,
    pub r: u64,
    pub s: u64,
    pub stride: u64,
    pub upsample: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_output_dims() {
        let l = Layer::conv("c", 1, 64, 3, 224, 224, 7, 7, 2);
        assert_eq!(l.y_out(), 109 + 0 / 2); // (224-7)/2+1 = 109
        assert_eq!(l.y_out(), 109);
        assert_eq!(l.x_out(), 109);
    }

    #[test]
    fn fc_is_1x1() {
        let l = Layer::fc("fc", 4, 1000, 2048);
        assert_eq!(l.y_out(), 1);
        assert_eq!(l.x_out(), 1);
        assert_eq!(l.macs(), 4 * 1000 * 2048);
    }

    #[test]
    fn upconv_scales_resolution() {
        let l = Layer::upconv("u", 1, 256, 512, 28, 28, 2, 2, 2);
        assert_eq!(l.y_out(), 56);
        assert_eq!(l.x_out(), 56);
    }

    #[test]
    fn residual_macs_equal_elements() {
        let l = Layer::residual("r", 1, 256, 56, 56);
        assert_eq!(l.macs(), 256 * 56 * 56);
        // Reads both addends.
        assert_eq!(l.input_elems(), 2 * 256 * 56 * 56);
        assert_eq!(l.weight_elems(), 0);
    }

    #[test]
    fn shape_ignores_name_only() {
        let a = Layer::conv("a", 1, 8, 4, 10, 10, 3, 3, 1);
        let b = Layer::conv("b", 1, 8, 4, 10, 10, 3, 3, 1);
        assert_ne!(a, b); // names differ
        assert_eq!(a.shape(), b.shape()); // geometry identical
        let c = Layer::conv("a", 1, 8, 4, 10, 10, 3, 3, 2);
        assert_ne!(a.shape(), c.shape());
    }

    #[test]
    fn layer_clone_shares_name_storage() {
        let a = Layer::conv("a", 1, 8, 4, 10, 10, 3, 3, 1);
        let b = a.clone();
        assert!(Arc::ptr_eq(&a.name, &b.name));
    }

    #[test]
    fn stride_one_conv_macs() {
        let l = Layer::conv("c", 1, 8, 4, 10, 10, 3, 3, 1);
        // y_out = x_out = 8
        assert_eq!(l.macs(), 8 * 4 * 8 * 8 * 3 * 3);
    }
}
