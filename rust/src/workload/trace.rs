//! Workload trace files: load arbitrary layer lists from a simple text
//! format so downstream users can evaluate their own networks without
//! recompiling.
//!
//! Format (one layer per line, `#` comments):
//!
//! ```text
//! model my_net
//! conv   <name> n k c y x r s stride
//! fc     <name> n k c
//! res    <name> n c y x
//! upconv <name> n k c y x r s up
//! ```
//!
//! `conv` takes *padded* input extents (as stored in [`Layer`]); use
//! `convp` for "SAME"-style auto-padding from unpadded extents.

use super::{conv_padded, Layer, Model};
use crate::anyhow::{bail, Context, Result};

/// Parse a workload trace from text.
pub fn parse(text: &str) -> Result<Model> {
    let mut name = "trace".to_string();
    let mut layers = Vec::new();
    for (i, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let tok: Vec<&str> = line.split_whitespace().collect();
        let ctx = || format!("trace line {}", i + 1);
        let num = |s: &str| -> Result<u64> { s.parse::<u64>().with_context(|| format!("bad number '{s}' on line {}", i + 1)) };
        match tok[0] {
            "model" => {
                if tok.len() != 2 {
                    bail!("{}: 'model' takes one name", ctx());
                }
                name = tok[1].to_string();
            }
            "conv" | "convp" => {
                if tok.len() != 10 {
                    bail!("{}: conv takes name + 8 numbers", ctx());
                }
                let v: Vec<u64> = tok[2..].iter().map(|s| num(s)).collect::<Result<_>>()?;
                let l = if tok[0] == "convp" {
                    conv_padded(tok[1], v[0], v[1], v[2], v[3], v[4], v[5], v[6], v[7])
                } else {
                    Layer::conv(tok[1], v[0], v[1], v[2], v[3], v[4], v[5], v[6], v[7])
                };
                layers.push(l);
            }
            "fc" => {
                if tok.len() != 5 {
                    bail!("{}: fc takes name + 3 numbers", ctx());
                }
                layers.push(Layer::fc(tok[1], num(tok[2])?, num(tok[3])?, num(tok[4])?));
            }
            "res" => {
                if tok.len() != 6 {
                    bail!("{}: res takes name + 4 numbers", ctx());
                }
                layers.push(Layer::residual(tok[1], num(tok[2])?, num(tok[3])?, num(tok[4])?, num(tok[5])?));
            }
            "upconv" => {
                if tok.len() != 10 {
                    bail!("{}: upconv takes name + 8 numbers", ctx());
                }
                let v: Vec<u64> = tok[2..].iter().map(|s| num(s)).collect::<Result<_>>()?;
                layers.push(Layer::upconv(tok[1], v[0], v[1], v[2], v[3], v[4], v[5], v[6], v[7]));
            }
            other => bail!("{}: unknown layer kind '{other}'", ctx()),
        }
    }
    if layers.is_empty() {
        bail!("trace defines no layers");
    }
    Ok(Model { name, layers })
}

/// Load a trace from a file.
pub fn load(path: &std::path::Path) -> Result<Model> {
    let text = std::fs::read_to_string(path).with_context(|| format!("reading trace {path:?}"))?;
    parse(&text)
}

/// Serialize a model back to trace text (round-trip support).
pub fn dump(model: &Model) -> String {
    use super::OpKind;
    let mut out = format!("model {}\n", model.name);
    for l in &model.layers {
        match l.op {
            OpKind::Conv2D => out.push_str(&format!(
                "conv {} {} {} {} {} {} {} {} {}\n",
                l.name, l.n, l.k, l.c, l.y, l.x, l.r, l.s, l.stride
            )),
            OpKind::FullyConnected => out.push_str(&format!("fc {} {} {} {}\n", l.name, l.n, l.k, l.c)),
            OpKind::ResidualAdd => out.push_str(&format!("res {} {} {} {} {}\n", l.name, l.n, l.c, l.y, l.x)),
            OpKind::UpConv => out.push_str(&format!(
                "upconv {} {} {} {} {} {} {} {} {}\n",
                l.name, l.n, l.k, l.c, l.y, l.x, l.r, l.s, l.upsample
            )),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "# test net\nmodel tiny\nconvp c1 1 8 3 16 16 3 3 1\nfc f1 1 10 128\nres r1 1 8 16 16\nupconv u1 1 4 8 8 8 2 2 2\n";

    #[test]
    fn parses_sample() {
        let m = parse(SAMPLE).unwrap();
        assert_eq!(m.name, "tiny");
        assert_eq!(m.layers.len(), 4);
        assert_eq!(m.layers[0].y_out(), 16); // convp SAME
        assert_eq!(m.layers[3].y_out(), 16); // upconv x2
    }

    #[test]
    fn round_trip() {
        let m = parse(SAMPLE).unwrap();
        let m2 = parse(&dump(&m)).unwrap();
        assert_eq!(m.layers, m2.layers);
        assert_eq!(m.name, m2.name);
    }

    #[test]
    fn round_trips_resnet50() {
        let m = crate::workload::resnet50::resnet50(4);
        let m2 = parse(&dump(&m)).unwrap();
        assert_eq!(m.layers, m2.layers);
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse("").is_err());
        assert!(parse("bogus x\n").is_err());
        assert!(parse("fc too few\n").is_err());
        assert!(parse("conv c 1 2 3\n").is_err());
        assert!(parse("fc f 1 x 3\n").is_err());
    }
}
