//! Workload trace files: load arbitrary layer lists from a simple text
//! format so downstream users can evaluate their own networks without
//! recompiling.
//!
//! Format (one layer per line, `#` comments):
//!
//! ```text
//! model my_net
//! conv   <name> n k c y x r s stride
//! fc     <name> n k c
//! res    <name> n c y x
//! upconv <name> n k c y x r s up
//! ```
//!
//! `conv` takes *padded* input extents (as stored in [`Layer`]); use
//! `convp` for "SAME"-style auto-padding from unpadded extents.
//!
//! The module also loads **client arrival traces** — recorded per-client
//! request-issue timestamps that `serve::Source::client_trace` replays in
//! place of the closed-loop source's fixed think time
//! ([`parse_arrivals`] / [`load_arrivals`]):
//!
//! ```text
//! # one line per client, timestamps in ms from run start, ascending
//! client <name> <t0> <t1> <t2> ...
//! ```

use super::{conv_padded, Layer, Model};
use crate::anyhow::{bail, Context, Result};

/// Parse a workload trace from text.
pub fn parse(text: &str) -> Result<Model> {
    let mut name = "trace".to_string();
    let mut layers = Vec::new();
    for (i, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let tok: Vec<&str> = line.split_whitespace().collect();
        let ctx = || format!("trace line {}", i + 1);
        let num = |s: &str| -> Result<u64> { s.parse::<u64>().with_context(|| format!("bad number '{s}' on line {}", i + 1)) };
        match tok[0] {
            "model" => {
                if tok.len() != 2 {
                    bail!("{}: 'model' takes one name", ctx());
                }
                name = tok[1].to_string();
            }
            "conv" | "convp" => {
                if tok.len() != 10 {
                    bail!("{}: conv takes name + 8 numbers", ctx());
                }
                let v: Vec<u64> = tok[2..].iter().map(|s| num(s)).collect::<Result<_>>()?;
                let l = if tok[0] == "convp" {
                    conv_padded(tok[1], v[0], v[1], v[2], v[3], v[4], v[5], v[6], v[7])
                } else {
                    Layer::conv(tok[1], v[0], v[1], v[2], v[3], v[4], v[5], v[6], v[7])
                };
                layers.push(l);
            }
            "fc" => {
                if tok.len() != 5 {
                    bail!("{}: fc takes name + 3 numbers", ctx());
                }
                layers.push(Layer::fc(tok[1], num(tok[2])?, num(tok[3])?, num(tok[4])?));
            }
            "res" => {
                if tok.len() != 6 {
                    bail!("{}: res takes name + 4 numbers", ctx());
                }
                layers.push(Layer::residual(tok[1], num(tok[2])?, num(tok[3])?, num(tok[4])?, num(tok[5])?));
            }
            "upconv" => {
                if tok.len() != 10 {
                    bail!("{}: upconv takes name + 8 numbers", ctx());
                }
                let v: Vec<u64> = tok[2..].iter().map(|s| num(s)).collect::<Result<_>>()?;
                layers.push(Layer::upconv(tok[1], v[0], v[1], v[2], v[3], v[4], v[5], v[6], v[7]));
            }
            other => bail!("{}: unknown layer kind '{other}'", ctx()),
        }
    }
    if layers.is_empty() {
        bail!("trace defines no layers");
    }
    Ok(Model { name, layers })
}

/// Load a trace from a file.
pub fn load(path: &std::path::Path) -> Result<Model> {
    let text = std::fs::read_to_string(path).with_context(|| format!("reading trace {path:?}"))?;
    parse(&text)
}

/// Parse a client arrival trace: one `client <name> <t_ms>...` line per
/// client, timestamps in milliseconds from run start, ascending within a
/// client. Returns one timestamp vector per client, in file order —
/// ready to feed `serve::Source::client_trace`.
pub fn parse_arrivals(text: &str) -> Result<Vec<Vec<f64>>> {
    let mut clients = Vec::new();
    for (i, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let tok: Vec<&str> = line.split_whitespace().collect();
        if tok[0] != "client" {
            bail!("arrival trace line {}: expected 'client', got '{}'", i + 1, tok[0]);
        }
        if tok.len() < 3 {
            bail!("arrival trace line {}: client takes a name + at least one timestamp", i + 1);
        }
        let mut times = Vec::with_capacity(tok.len() - 2);
        for s in &tok[2..] {
            let t: f64 = s
                .parse()
                .with_context(|| format!("arrival trace line {}: bad timestamp '{s}'", i + 1))?;
            if !t.is_finite() || t < 0.0 {
                bail!("arrival trace line {}: timestamp '{s}' must be finite and >= 0", i + 1);
            }
            if let Some(&prev) = times.last() {
                if t < prev {
                    bail!("arrival trace line {}: timestamps must be ascending ({t} after {prev})", i + 1);
                }
            }
            times.push(t);
        }
        clients.push(times);
    }
    if clients.is_empty() {
        bail!("arrival trace defines no clients");
    }
    Ok(clients)
}

/// Load a client arrival trace from a file.
pub fn load_arrivals(path: &std::path::Path) -> Result<Vec<Vec<f64>>> {
    let text =
        std::fs::read_to_string(path).with_context(|| format!("reading arrival trace {path:?}"))?;
    parse_arrivals(&text)
}

/// Synthesize a per-client arrival trace: client `i` issues
/// `requests_per_client[i]` requests at a jittered `spacing_ms` cadence
/// (each gap drawn uniformly from `spacing_ms * [1 - jitter, 1 + jitter]`,
/// first issue staggered inside one spacing). Deterministic in `seed`,
/// timestamps ascending per client — ready for
/// `serve::Source::client_trace` and for `workload::trace::parse_arrivals`
/// round-trips.
///
/// Skew is expressed through the counts vector: giving a few clients
/// (whose *indices* choose their cluster shard — closed-loop requests
/// stripe by client) most of the requests reproduces the hot-shard
/// pattern the cluster's work-stealing pass exists for; the
/// `cluster_scale` bench sweeps exactly that.
pub fn synthetic_arrivals(
    requests_per_client: &[usize],
    spacing_ms: f64,
    jitter: f64,
    seed: u64,
) -> Vec<Vec<f64>> {
    assert!(!requests_per_client.is_empty(), "need at least one client");
    assert!(requests_per_client.iter().all(|&n| n >= 1), "every client issues at least once");
    assert!(spacing_ms > 0.0 && spacing_ms.is_finite(), "spacing must be positive");
    assert!((0.0..=1.0).contains(&jitter), "jitter is a fraction of the spacing");
    let mut rng = crate::testutil::Rng::new(seed);
    requests_per_client
        .iter()
        .map(|&n| {
            let mut t = rng.next_f32() as f64 * spacing_ms;
            let mut times = Vec::with_capacity(n);
            for _ in 0..n {
                times.push(t);
                let u = rng.next_f32() as f64; // [0, 1)
                t += spacing_ms * (1.0 - jitter + 2.0 * jitter * u);
            }
            times
        })
        .collect()
}

/// Serialize a model back to trace text (round-trip support).
pub fn dump(model: &Model) -> String {
    use super::OpKind;
    let mut out = format!("model {}\n", model.name);
    for l in &model.layers {
        match l.op {
            OpKind::Conv2D => out.push_str(&format!(
                "conv {} {} {} {} {} {} {} {} {}\n",
                l.name, l.n, l.k, l.c, l.y, l.x, l.r, l.s, l.stride
            )),
            OpKind::FullyConnected => out.push_str(&format!("fc {} {} {} {}\n", l.name, l.n, l.k, l.c)),
            OpKind::ResidualAdd => out.push_str(&format!("res {} {} {} {} {}\n", l.name, l.n, l.c, l.y, l.x)),
            OpKind::UpConv => out.push_str(&format!(
                "upconv {} {} {} {} {} {} {} {} {}\n",
                l.name, l.n, l.k, l.c, l.y, l.x, l.r, l.s, l.upsample
            )),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "# test net\nmodel tiny\nconvp c1 1 8 3 16 16 3 3 1\nfc f1 1 10 128\nres r1 1 8 16 16\nupconv u1 1 4 8 8 8 2 2 2\n";

    #[test]
    fn parses_sample() {
        let m = parse(SAMPLE).unwrap();
        assert_eq!(m.name, "tiny");
        assert_eq!(m.layers.len(), 4);
        assert_eq!(m.layers[0].y_out(), 16); // convp SAME
        assert_eq!(m.layers[3].y_out(), 16); // upconv x2
    }

    #[test]
    fn round_trip() {
        let m = parse(SAMPLE).unwrap();
        let m2 = parse(&dump(&m)).unwrap();
        assert_eq!(m.layers, m2.layers);
        assert_eq!(m.name, m2.name);
    }

    #[test]
    fn round_trips_resnet50() {
        let m = crate::workload::resnet50::resnet50(4);
        let m2 = parse(&dump(&m)).unwrap();
        assert_eq!(m.layers, m2.layers);
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse("").is_err());
        assert!(parse("bogus x\n").is_err());
        assert!(parse("fc too few\n").is_err());
        assert!(parse("conv c 1 2 3\n").is_err());
        assert!(parse("fc f 1 x 3\n").is_err());
    }

    #[test]
    fn parses_arrival_traces() {
        let text = "# burst then lull\nclient a 0.5 1.0 9.5\nclient b 2.0 2.0 3.5 8.0\n";
        let clients = parse_arrivals(text).unwrap();
        assert_eq!(clients.len(), 2);
        assert_eq!(clients[0], vec![0.5, 1.0, 9.5]);
        assert_eq!(clients[1], vec![2.0, 2.0, 3.5, 8.0]); // equal stamps allowed
    }

    #[test]
    fn rejects_malformed_arrival_traces() {
        assert!(parse_arrivals("").is_err(), "no clients");
        assert!(parse_arrivals("server a 1.0\n").is_err(), "unknown keyword");
        assert!(parse_arrivals("client a\n").is_err(), "no timestamps");
        assert!(parse_arrivals("client a 1.0 x\n").is_err(), "bad number");
        assert!(parse_arrivals("client a 5.0 1.0\n").is_err(), "descending");
        assert!(parse_arrivals("client a -1.0\n").is_err(), "negative");
    }

    #[test]
    fn synthetic_arrivals_are_deterministic_ascending_and_sized() {
        let counts = [40usize, 1, 1, 7];
        let a = synthetic_arrivals(&counts, 0.25, 0.5, 11);
        let b = synthetic_arrivals(&counts, 0.25, 0.5, 11);
        assert_eq!(a.len(), counts.len());
        for (ts, &n) in a.iter().zip(counts.iter()) {
            assert_eq!(ts.len(), n);
            assert!(ts.windows(2).all(|w| w[0] <= w[1]), "ascending per client");
            assert!(ts.iter().all(|t| t.is_finite() && *t >= 0.0));
        }
        assert_eq!(a, b, "same seed, same trace");
        let c = synthetic_arrivals(&counts, 0.25, 0.5, 12);
        assert_ne!(a, c, "seed steers the jitter");
        // The skewed client dominates the issue volume but stays inside
        // the same time span order of magnitude as the cadence implies.
        let span = a[0].last().unwrap() - a[0][0];
        assert!(span > 0.25 * 39.0 * 0.4, "hot client spans its cadence, got {span}");
        // Zero jitter is an exact cadence.
        let exact = synthetic_arrivals(&[3], 1.0, 0.0, 5);
        assert!((exact[0][1] - exact[0][0] - 1.0).abs() < 1e-9);
        assert!((exact[0][2] - exact[0][1] - 1.0).abs() < 1e-9);
        // And the output feeds the closed-loop source directly.
        let mix = crate::serve::WorkloadMix::single(crate::serve::ModelKind::TinyCnn, 20.0);
        let mut src = crate::serve::Source::client_trace(mix, &a, 3);
        assert!(src.next_arrival_at().is_some());
        let _ = src.pop();
    }

    #[test]
    fn arrival_fixture_loads_and_drives_the_client_trace_source() {
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("rust/testdata/client_trace_small.txt");
        let clients = load_arrivals(&path).expect("fixture parses");
        assert!(clients.len() >= 4, "fixture has {} clients", clients.len());
        let total: usize = clients.iter().map(|c| c.len()).sum();
        let mix = crate::serve::WorkloadMix::single(crate::serve::ModelKind::TinyCnn, 20.0);
        let mut src = crate::serve::Source::client_trace(mix, &clients, 11);
        let mut emitted = 0;
        while src.next_arrival_at().is_some() {
            let r = src.pop();
            src.on_complete(r.arrival + 1.0, &r);
            emitted += 1;
        }
        assert_eq!(emitted, total as u64, "every recorded timestamp becomes one request");
    }
}
