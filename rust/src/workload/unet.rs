//! UNet [Ronneberger et al., MICCAI'15] layer table.
//!
//! The classic unpadded 572x572 segmentation network the paper uses as its
//! second workload: a 4-level contracting path, 1024-channel bottleneck,
//! and an expanding path of 2x2 up-convolutions followed by unpadded 3x3
//! convolutions, closed by a 1x1 classifier conv.

use super::{Layer, Model};

/// Build UNet with the given batch size.
///
/// All 3x3 convolutions are *unpadded* (`valid`), as in the original
/// architecture, so each conv shrinks the activation by 2 pixels; 2x2
/// max-pools (not modeled, zero MACs) halve resolution between encoder
/// levels; 2x2 up-convolutions double it on the way up. Decoder 3x3 convs
/// consume the channel-concatenated skip tensor (2x channels in).
pub fn unet(batch: u64) -> Model {
    let mut layers: Vec<Layer> = Vec::new();
    let n = batch;

    // (level, in_channels, out_channels, input resolution)
    // Encoder: two valid 3x3 convs per level.
    let mut res: u64 = 572;
    let mut in_ch: u64 = 1;
    let enc_widths = [64u64, 128, 256, 512];
    let mut skip_res: Vec<u64> = Vec::new();
    for (lvl, &w) in enc_widths.iter().enumerate() {
        layers.push(Layer::conv(&format!("enc{}_conv_a", lvl + 1), n, w, in_ch, res, res, 3, 3, 1));
        res -= 2;
        layers.push(Layer::conv(&format!("enc{}_conv_b", lvl + 1), n, w, w, res, res, 3, 3, 1));
        res -= 2;
        skip_res.push(res);
        in_ch = w;
        res /= 2; // 2x2 max-pool.
    }

    // Bottleneck at 1024 channels.
    layers.push(Layer::conv("bott_conv_a", n, 1024, 512, res, res, 3, 3, 1));
    res -= 2;
    layers.push(Layer::conv("bott_conv_b", n, 1024, 1024, res, res, 3, 3, 1));
    res -= 2;
    in_ch = 1024;

    // Decoder: up-conv then two valid 3x3 convs per level.
    for (i, &w) in enc_widths.iter().rev().enumerate() {
        let lvl = enc_widths.len() - i; // 4, 3, 2, 1
        layers.push(Layer::upconv(&format!("dec{lvl}_upconv"), n, w, in_ch, res, res, 2, 2, 2));
        res *= 2;
        // Skip tensor is center-cropped to `res`; concat doubles channels.
        layers.push(Layer::conv(&format!("dec{lvl}_conv_a"), n, w, 2 * w, res, res, 3, 3, 1));
        res -= 2;
        layers.push(Layer::conv(&format!("dec{lvl}_conv_b"), n, w, w, res, res, 3, 3, 1));
        res -= 2;
        in_ch = w;
    }

    // Final 1x1 conv to 2 classes.
    layers.push(Layer::conv("final_1x1", n, 2, 64, res, res, 1, 1, 1));

    Model { name: format!("unet_b{batch}"), layers }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{classify, LayerType, OpKind};

    #[test]
    fn layer_count_and_final_resolution() {
        let m = unet(1);
        // 8 encoder convs + 2 bottleneck + 4 * (upconv + 2 convs) + final.
        assert_eq!(m.layers.len(), 8 + 2 + 12 + 1);
        let last = m.layers.last().unwrap();
        // Classic UNet output is 388x388.
        assert_eq!(last.y_out(), 388);
        assert_eq!(last.k, 2);
    }

    #[test]
    fn resolutions_match_published_table() {
        let m = unet(1);
        let bott = m.layers.iter().find(|l| &*l.name == "bott_conv_b").unwrap();
        assert_eq!(bott.y, 30);
        assert_eq!(bott.y_out(), 28);
        let up4 = m.layers.iter().find(|l| &*l.name == "dec4_upconv").unwrap();
        assert_eq!(up4.y_out(), 56);
    }

    #[test]
    fn has_upconv_layers() {
        let m = unet(1);
        let ups = m.layers.iter().filter(|l| l.op == OpKind::UpConv).count();
        assert_eq!(ups, 4);
        assert!(m.layer_types().contains(&LayerType::UpConv));
    }

    #[test]
    fn encoder_is_high_res_deep_is_low_res() {
        let m = unet(1);
        assert_eq!(classify(&m.layers[0]), LayerType::HighRes);
        let bott = m.layers.iter().find(|l| &*l.name == "bott_conv_a").unwrap();
        assert_eq!(classify(bott), LayerType::LowRes);
    }

    #[test]
    fn total_macs_in_expected_range() {
        // Classic UNet at 572x572 with the full decoder works out to
        // ~167 GMACs; accept a generous band.
        let g = unet(1).total_macs() as f64 / 1e9;
        assert!(g > 120.0 && g < 220.0, "got {g} GMACs");
    }
}
