//! BERT-style transformer encoder workloads.
//!
//! The paper evaluates two CNNs (ResNet-50, UNet); the serving scenarios
//! of `wienna::serve` additionally mix in a matmul-dominated transformer
//! so the fleet sees both CNN and GEMM traffic. Every projection and
//! attention matmul is expressed through the existing [`Layer`] loop-nest
//! descriptors, so the Table-1 layer typing ([`crate::workload::classify`])
//! applies unchanged: projections / FFN / attention GEMMs classify as
//! `FullyConnected`, the skip connections as `Residual` — exactly the
//! KP-CP-friendly traffic mix the paper's Observation I predicts.
//!
//! Shapes follow the standard encoder block: per layer, Q/K/V and output
//! projections (`[hidden x hidden]` GEMMs over `batch*seq` rows), the
//! two attention matmuls (`QK^T` and `attn x V`, folded over
//! `batch * heads` score matrices), and the 4x feed-forward pair, with a
//! residual add after the attention and FFN sub-blocks.

use super::{Layer, Model};

/// Configuration of a BERT-style encoder stack.
#[derive(Debug, Clone, Copy)]
pub struct TransformerConfig {
    pub batch: u64,
    /// Sequence length (tokens per request).
    pub seq: u64,
    /// Model (hidden) dimension.
    pub hidden: u64,
    /// Attention heads; must divide `hidden`.
    pub heads: u64,
    /// Encoder blocks.
    pub blocks: u64,
    /// FFN expansion factor (4 in BERT).
    pub ffn_mult: u64,
}

impl TransformerConfig {
    /// BERT-base: 12 blocks, hidden 768, 12 heads, seq 128.
    pub fn bert_base(batch: u64) -> Self {
        TransformerConfig { batch, seq: 128, hidden: 768, heads: 12, blocks: 12, ffn_mult: 4 }
    }

    /// A small encoder for fast tests.
    pub fn tiny(batch: u64) -> Self {
        TransformerConfig { batch, seq: 16, hidden: 64, heads: 4, blocks: 2, ffn_mult: 4 }
    }

    pub fn head_dim(&self) -> u64 {
        self.hidden / self.heads
    }
}

/// Build the encoder stack for `cfg`.
///
/// Token dimensions are folded into the GEMM row dimension `N`
/// (`batch * seq` rows for projections, `batch * heads * seq` rows for
/// the per-head attention matmuls), which preserves exact MAC counts and
/// exact activation (input/output) volumes within the 7-loop CONV/GEMM
/// descriptor.
///
/// One deliberate approximation: a [`Layer`] carries a single weight
/// tensor, so the folded attention matmuls model their K (resp. V)
/// operand as one `seq x head_dim` stationary tensor shared by all
/// `batch * heads` score matrices — undercounting K/V distribution
/// traffic by that factor, exactly as if K/V stayed resident like
/// weights do. Expressing per-(batch, head) operands would need
/// `batch * heads` separate layers per matmul. MAC counts, Q-side
/// volumes and all non-attention layers are exact.
pub fn transformer(cfg: TransformerConfig) -> Model {
    assert!(cfg.hidden % cfg.heads == 0, "heads must divide hidden");
    assert!(cfg.batch >= 1 && cfg.seq >= 1 && cfg.blocks >= 1);
    let rows = cfg.batch * cfg.seq;
    let d = cfg.head_dim();
    let ffn = cfg.hidden * cfg.ffn_mult;
    let mut layers = Vec::new();
    for b in 0..cfg.blocks {
        let tag = |op: &str| format!("enc{b}_{op}");
        // Q, K, V projections: [rows x hidden] x [hidden x hidden].
        layers.push(Layer::fc(&tag("q_proj"), rows, cfg.hidden, cfg.hidden));
        layers.push(Layer::fc(&tag("k_proj"), rows, cfg.hidden, cfg.hidden));
        layers.push(Layer::fc(&tag("v_proj"), rows, cfg.hidden, cfg.hidden));
        // Attention scores QK^T: per (batch, head), [seq x d] x [d x seq].
        layers.push(Layer::fc(&tag("qk_scores"), cfg.batch * cfg.heads * cfg.seq, cfg.seq, d));
        // Attention-weighted values: per (batch, head), [seq x seq] x [seq x d].
        layers.push(Layer::fc(&tag("attn_v"), cfg.batch * cfg.heads * cfg.seq, d, cfg.seq));
        // Output projection and the attention skip connection.
        layers.push(Layer::fc(&tag("out_proj"), rows, cfg.hidden, cfg.hidden));
        layers.push(Layer::residual(&tag("attn_res"), cfg.batch, cfg.hidden, cfg.seq, 1));
        // Feed-forward pair and its skip connection.
        layers.push(Layer::fc(&tag("ffn_up"), rows, ffn, cfg.hidden));
        layers.push(Layer::fc(&tag("ffn_down"), rows, cfg.hidden, ffn));
        layers.push(Layer::residual(&tag("ffn_res"), cfg.batch, cfg.hidden, cfg.seq, 1));
    }
    // Pooler / classifier head on the [CLS] token.
    layers.push(Layer::fc("pooler", cfg.batch, cfg.hidden, cfg.hidden));
    Model {
        name: format!("bert_b{}_s{}_h{}x{}", cfg.batch, cfg.seq, cfg.hidden, cfg.blocks),
        layers,
    }
}

/// BERT-base encoder at the given batch size (seq 128).
pub fn bert_base(batch: u64) -> Model {
    transformer(TransformerConfig::bert_base(batch))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{classify, LayerType};

    #[test]
    fn block_structure_and_count() {
        let m = transformer(TransformerConfig::tiny(2));
        // 10 layers per block x 2 blocks + pooler.
        assert_eq!(m.layers.len(), 21);
        assert_eq!(&*m.layers[0].name, "enc0_q_proj");
        assert_eq!(&*m.layers[20].name, "pooler");
    }

    #[test]
    fn table1_typing_is_fc_plus_residual() {
        let m = bert_base(4);
        let types = m.layer_types();
        assert_eq!(types, vec![LayerType::Residual, LayerType::FullyConnected]);
        // 8 GEMMs + 2 residuals per block, 12 blocks, + pooler.
        assert_eq!(m.layers_of_type(LayerType::FullyConnected).len(), 8 * 12 + 1);
        assert_eq!(m.layers_of_type(LayerType::Residual).len(), 2 * 12);
    }

    #[test]
    fn attention_macs_match_closed_form() {
        let cfg = TransformerConfig::tiny(3);
        let m = transformer(cfg);
        let d = cfg.head_dim();
        // QK^T: batch * heads * seq^2 * d MACs.
        let qk = m.layers.iter().find(|l| &*l.name == "enc0_qk_scores").unwrap();
        assert_eq!(qk.macs(), cfg.batch * cfg.heads * cfg.seq * cfg.seq * d);
        // attn x V has the same MAC count by symmetry.
        let av = m.layers.iter().find(|l| &*l.name == "enc0_attn_v").unwrap();
        assert_eq!(av.macs(), qk.macs());
        // Projections: batch * seq * hidden^2.
        let q = m.layers.iter().find(|l| &*l.name == "enc0_q_proj").unwrap();
        assert_eq!(q.macs(), cfg.batch * cfg.seq * cfg.hidden * cfg.hidden);
    }

    #[test]
    fn total_macs_scale_linearly_with_batch() {
        let m1 = bert_base(1);
        let m4 = bert_base(4);
        assert_eq!(m4.total_macs(), 4 * m1.total_macs());
    }

    #[test]
    fn residual_volume_matches_token_embeddings() {
        let cfg = TransformerConfig::tiny(2);
        let m = transformer(cfg);
        let r = m.layers.iter().find(|l| &*l.name == "enc0_attn_res").unwrap();
        assert_eq!(r.macs(), cfg.batch * cfg.hidden * cfg.seq);
    }

    #[test]
    #[should_panic]
    fn heads_must_divide_hidden() {
        transformer(TransformerConfig { batch: 1, seq: 8, hidden: 65, heads: 4, blocks: 1, ffn_mult: 4 });
    }
}
