//! Workload substrate (S1): layer descriptors, Table-1 layer typing, and
//! the two evaluation networks from the paper (ResNet-50 and UNet), plus a
//! scaled-down CNN used by the end-to-end real-numerics example, MLP/RNN
//! generators, and a BERT-style transformer encoder for the serving mix.

pub mod layer;
pub mod mlp;
pub mod resnet50;
pub mod tiny;
pub mod trace;
pub mod transformer;
pub mod types;
pub mod unet;

pub use layer::{Layer, LayerShape, OpKind};
pub use types::{classify, LayerType};


/// A named DNN model: an ordered list of layers.
#[derive(Debug, Clone)]
pub struct Model {
    pub name: String,
    pub layers: Vec<Layer>,
}

impl Model {
    /// Total MAC count across all layers.
    pub fn total_macs(&self) -> u64 {
        self.layers.iter().map(|l| l.macs()).sum()
    }

    /// Layers of a given Table-1 type.
    pub fn layers_of_type(&self, t: LayerType) -> Vec<&Layer> {
        self.layers.iter().filter(|l| classify(l) == t).collect()
    }

    /// The distinct layer types present in this model, in Table-1 order.
    pub fn layer_types(&self) -> Vec<LayerType> {
        LayerType::ALL
            .iter()
            .copied()
            .filter(|t| self.layers.iter().any(|l| classify(l) == *t))
            .collect()
    }
}

/// Convolution with implicit "same"-style padding: the stored `y`/`x` are
/// the *padded* input extents so that `y_out = ceil(y_in / stride)`.
///
/// The cost model works on loop bounds only, so folding padding into the
/// input extent reproduces the correct output size and MAC count without a
/// separate padding field.
#[allow(clippy::too_many_arguments)]
pub fn conv_padded(name: &str, n: u64, k: u64, c: u64, y_in: u64, x_in: u64, r: u64, s: u64, stride: u64) -> Layer {
    let y_out = y_in.div_ceil(stride);
    let x_out = x_in.div_ceil(stride);
    let y = (y_out - 1) * stride + r;
    let x = (x_out - 1) * stride + s;
    Layer::conv(name, n, k, c, y, x, r, s, stride)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_padded_preserves_output_dims() {
        // 3x3 stride-1 "same" conv: 56 -> 56.
        let l = conv_padded("p", 1, 64, 64, 56, 56, 3, 3, 1);
        assert_eq!(l.y_out(), 56);
        assert_eq!(l.x_out(), 56);
        // 7x7 stride-2 "same" conv: 224 -> 112.
        let l = conv_padded("p", 1, 64, 3, 224, 224, 7, 7, 2);
        assert_eq!(l.y_out(), 112);
        assert_eq!(l.x_out(), 112);
    }

    #[test]
    fn model_helpers() {
        let m = Model {
            name: "m".into(),
            layers: vec![Layer::fc("fc", 1, 10, 20), Layer::residual("r", 1, 4, 8, 8)],
        };
        assert_eq!(m.total_macs(), 10 * 20 + 4 * 8 * 8);
        assert_eq!(m.layers_of_type(LayerType::FullyConnected).len(), 1);
        assert_eq!(m.layer_types(), vec![LayerType::Residual, LayerType::FullyConnected]);
    }
}
