//! MLP and GEMM-sequence workloads.
//!
//! Table 1 notes that fully-connected layers appear "in CNNs, MLPs, RNNs,
//! and so on"; these generators provide FC-dominated networks for the
//! strategy studies (KP-CP territory) beyond the paper's two CNNs.

use super::{Layer, Model};

/// A classic classifier MLP: `in -> hidden x depth -> out`.
pub fn mlp(batch: u64, input: u64, hidden: u64, depth: u64, out: u64) -> Model {
    assert!(depth >= 1);
    let mut layers = Vec::new();
    let mut prev = input;
    for i in 0..depth {
        layers.push(Layer::fc(&format!("fc{i}"), batch, hidden, prev));
        prev = hidden;
    }
    layers.push(Layer::fc("fc_out", batch, out, prev));
    Model { name: format!("mlp_b{batch}_h{hidden}x{depth}"), layers }
}

/// An unrolled RNN cell sequence: `steps` GEMMs of `[hidden x hidden]`
/// (the recurrent weight), modelling per-timestep inference traffic.
pub fn rnn_unrolled(batch: u64, hidden: u64, steps: u64) -> Model {
    let mut layers = Vec::new();
    for t in 0..steps {
        // Input and recurrent projections of one timestep.
        layers.push(Layer::fc(&format!("t{t}_ih"), batch, hidden, hidden));
        layers.push(Layer::fc(&format!("t{t}_hh"), batch, hidden, hidden));
        layers.push(Layer::residual(&format!("t{t}_add"), batch, hidden, 1, 1));
    }
    Model { name: format!("rnn_b{batch}_h{hidden}x{steps}"), layers }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{classify, LayerType};

    #[test]
    fn mlp_shapes_chain() {
        let m = mlp(8, 784, 1024, 3, 10);
        assert_eq!(m.layers.len(), 4);
        assert_eq!(m.layers[0].c, 784);
        assert_eq!(m.layers[3].k, 10);
        assert_eq!(m.layers[3].c, 1024);
        assert!(m.layers.iter().all(|l| classify(l) == LayerType::FullyConnected));
    }

    #[test]
    fn mlp_macs() {
        let m = mlp(1, 10, 20, 1, 5);
        assert_eq!(m.total_macs(), 10 * 20 + 20 * 5);
    }

    #[test]
    fn rnn_structure() {
        let m = rnn_unrolled(4, 256, 3);
        assert_eq!(m.layers.len(), 9);
        assert!(m.layer_types().contains(&LayerType::Residual));
    }
}
