//! ResNet-50 [He et al., CVPR'16] layer table.
//!
//! The full 50-layer network as evaluated in the paper (classification
//! workload): the stem convolution, four bottleneck stages (3/4/6/3
//! blocks), the projection shortcuts, the per-block residual additions,
//! and the final fully-connected classifier.

use super::{conv_padded, Layer, Model};

/// Configuration of one bottleneck stage.
struct Stage {
    /// Stage index (2..=5), used for layer names (`conv2_x` …).
    idx: usize,
    /// Number of bottleneck blocks.
    blocks: usize,
    /// Bottleneck width (the `1x1`/`3x3` channel count).
    width: u64,
    /// Input spatial resolution of the stage (pre-downsampling).
    res: u64,
    /// Input channels to the first block of the stage.
    in_ch: u64,
    /// Stride applied by the first block (spatial downsampling).
    stride: u64,
}

/// Build ResNet-50 with the given batch size.
///
/// Input is the standard `batch x 3 x 224 x 224` image tensor. Max-pool
/// layers are memory-reshape operations with no MACs and negligible
/// distribution traffic at the package level, so they are not modeled
/// (consistent with MAESTRO-style cost analysis).
pub fn resnet50(batch: u64) -> Model {
    let mut layers: Vec<Layer> = Vec::new();
    let n = batch;

    // Stem: 7x7/2, 64 filters, 224 -> 112 (then 3x3/2 max-pool -> 56).
    layers.push(conv_padded("conv1_7x7", n, 64, 3, 224, 224, 7, 7, 2));

    let stages = [
        Stage { idx: 2, blocks: 3, width: 64, res: 56, in_ch: 64, stride: 1 },
        Stage { idx: 3, blocks: 4, width: 128, res: 56, in_ch: 256, stride: 2 },
        Stage { idx: 4, blocks: 6, width: 256, res: 28, in_ch: 512, stride: 2 },
        Stage { idx: 5, blocks: 3, width: 512, res: 14, in_ch: 1024, stride: 2 },
    ];

    for st in &stages {
        let out_ch = st.width * 4;
        let out_res = st.res / st.stride;
        for b in 0..st.blocks {
            let first = b == 0;
            let block_in_ch = if first { st.in_ch } else { out_ch };
            let block_in_res = if first { st.res } else { out_res };
            let stride = if first { st.stride } else { 1 };
            let tag = |op: &str| format!("conv{}_{}_{}", st.idx, b + 1, op);

            // 1x1 reduce.
            layers.push(conv_padded(&tag("1x1a"), n, st.width, block_in_ch, block_in_res, block_in_res, 1, 1, stride));
            // 3x3.
            layers.push(conv_padded(&tag("3x3"), n, st.width, st.width, out_res, out_res, 3, 3, 1));
            // 1x1 expand.
            layers.push(conv_padded(&tag("1x1b"), n, out_ch, st.width, out_res, out_res, 1, 1, 1));
            // Projection shortcut on the first block of each stage.
            if first {
                layers.push(conv_padded(&tag("proj"), n, out_ch, block_in_ch, block_in_res, block_in_res, 1, 1, stride));
            }
            // Residual addition closing the block.
            layers.push(Layer::residual(&tag("add"), n, out_ch, out_res, out_res));
        }
    }

    // Global average pool is negligible; final classifier GEMM.
    layers.push(Layer::fc("fc1000", n, 1000, 2048));

    Model { name: format!("resnet50_b{batch}"), layers }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{classify, LayerType};

    #[test]
    fn layer_count() {
        let m = resnet50(1);
        // Convs: 1 stem + (3+4+6+3) blocks * 3 + 4 projections = 53.
        // Residual adds: 16. FC: 1. Total 70.
        let convs = m.layers.iter().filter(|l| l.op == crate::workload::OpKind::Conv2D).count();
        assert_eq!(convs, 53);
        let adds = m.layers.iter().filter(|l| l.op == crate::workload::OpKind::ResidualAdd).count();
        assert_eq!(adds, 16);
        assert_eq!(m.layers.len(), 70);
    }

    #[test]
    fn total_macs_close_to_published() {
        // ResNet-50 is ~3.8 GMACs per image at 224x224 (4.1e9 with
        // padding folded into input extents). Check the right ballpark.
        let m = resnet50(1);
        let g = m.total_macs() as f64 / 1e9;
        assert!(g > 3.0 && g < 4.6, "got {g} GMACs");
    }

    #[test]
    fn macs_scale_linearly_with_batch() {
        assert_eq!(resnet50(4).total_macs(), 4 * resnet50(1).total_macs());
    }

    #[test]
    fn has_expected_layer_types() {
        let m = resnet50(1);
        let types = m.layer_types();
        assert!(types.contains(&LayerType::HighRes));
        assert!(types.contains(&LayerType::LowRes));
        assert!(types.contains(&LayerType::Residual));
        assert!(types.contains(&LayerType::FullyConnected));
        assert!(!types.contains(&LayerType::UpConv));
        // The stem conv (3 channels, 224px) is high-res.
        assert_eq!(classify(&m.layers[0]), LayerType::HighRes);
    }

    #[test]
    fn stage_output_resolutions() {
        let m = resnet50(1);
        // Last conv of stage 5 runs at 7x7.
        let l = m.layers.iter().rev().find(|l| l.name.contains("conv5") && l.name.contains("1x1b")).unwrap();
        assert_eq!(l.y_out(), 7);
        assert_eq!(l.k, 2048);
    }
}
