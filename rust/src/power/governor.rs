//! The power-cap governor: a deterministic DVFS ladder under a fleet cap.
//!
//! The governor runs at dispatch time, inside the (deterministic)
//! discrete-event loop: before a batch starts, it projects the fleet's
//! instantaneous draw — the leakage floor of every powered package plus
//! the dynamic power of every in-flight batch — and walks the DVFS ladder
//! top-down for the fastest level whose added draw still fits under the
//! cap. The chosen level then *closes the loop*: it stretches the batch's
//! makespan by `1/freq` (so the package stays busy — and holds its power
//! share — longer) and scales its dynamic energy by the level's V² term,
//! which is exactly what later dispatch decisions observe. Throttling
//! therefore propagates through the simulation like real DVFS, not like
//! an after-the-fact discount.
//!
//! Everything is a pure function of simulation state, so a capped cluster
//! run remains bit-identical at any worker-thread count; with no cap the
//! governor always answers [`DvfsLevel::NOMINAL`] and the event loop's
//! arithmetic is untouched (`x * (1.0/1.0)` is IEEE-exact).

use super::meter::PowerModel;
use crate::config::CLOCK_HZ;
use crate::serve::BatchCost;

/// Voltage retention floor of the DVFS model: V(f) = V_FLOOR + (1-V_FLOOR)·f,
/// so dynamic energy/op scales by V(f)² (classic CV²f with V tracking f).
pub const V_FLOOR: f64 = 0.55;

/// One rung of the DVFS ladder.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DvfsLevel {
    /// Clock multiplier in (0, 1]: batch makespan stretches by 1/freq.
    pub freq_scale: f64,
    /// Dynamic energy/op multiplier (V² at the level's voltage).
    pub energy_scale: f64,
}

impl DvfsLevel {
    /// Full speed, full voltage — exactly scale 1.0 on both axes so an
    /// ungoverned run's floating-point arithmetic is bit-identical to a
    /// meter-less one.
    pub const NOMINAL: DvfsLevel = DvfsLevel { freq_scale: 1.0, energy_scale: 1.0 };

    /// The level at `freq_scale`, with voltage on the affine V(f) model.
    pub fn at(freq_scale: f64) -> DvfsLevel {
        assert!(freq_scale > 0.0 && freq_scale <= 1.0, "freq scale {freq_scale} out of (0, 1]");
        if freq_scale >= 1.0 {
            return DvfsLevel::NOMINAL;
        }
        let v = V_FLOOR + (1.0 - V_FLOOR) * freq_scale;
        DvfsLevel { freq_scale, energy_scale: v * v }
    }

    pub fn is_nominal(&self) -> bool {
        self.freq_scale >= 1.0
    }

    /// Dynamic *power* multiplier: energy/op × ops/s.
    pub fn power_scale(&self) -> f64 {
        self.energy_scale * self.freq_scale
    }
}

/// The ladder of available levels, fastest first (first rung is nominal).
#[derive(Debug, Clone)]
pub struct DvfsLadder {
    levels: Vec<DvfsLevel>,
}

impl Default for DvfsLadder {
    /// Five rungs from full speed down to 0.4×, spanning a ~4.7× dynamic
    /// power range (power scale 1.0 → 0.21).
    fn default() -> Self {
        DvfsLadder::new(&[1.0, 0.85, 0.7, 0.55, 0.4])
    }
}

impl DvfsLadder {
    /// Build from descending frequency scales; the first must be 1.0.
    pub fn new(freq_scales: &[f64]) -> Self {
        assert!(!freq_scales.is_empty(), "ladder needs at least one level");
        assert!(freq_scales[0] >= 1.0, "the top rung must be nominal");
        assert!(
            freq_scales.windows(2).all(|w| w[0] > w[1]),
            "ladder frequencies must strictly descend"
        );
        DvfsLadder { levels: freq_scales.iter().map(|&f| DvfsLevel::at(f)).collect() }
    }

    pub fn levels(&self) -> &[DvfsLevel] {
        &self.levels
    }

    /// The slowest rung — the floor when even it exceeds the budget.
    pub fn floor(&self) -> DvfsLevel {
        *self.levels.last().expect("ladder is never empty")
    }
}

/// Runtime power configuration of a fleet (or one cluster shard's slice
/// of it): the cap, the energy model behind the meter, and the ladder.
#[derive(Debug, Clone)]
pub struct PowerConfig {
    /// Fleet-level power cap in watts. `None` (the default) disables the
    /// governor entirely: every batch runs at [`DvfsLevel::NOMINAL`] and
    /// latency statistics are bit-identical to an unmetered run.
    pub cap_w: Option<f64>,
    pub model: PowerModel,
    pub ladder: DvfsLadder,
}

impl Default for PowerConfig {
    fn default() -> Self {
        PowerConfig { cap_w: None, model: PowerModel::default(), ladder: DvfsLadder::default() }
    }
}

impl PowerConfig {
    pub fn with_cap(cap_w: f64) -> Self {
        assert!(cap_w > 0.0, "power cap must be positive");
        PowerConfig { cap_w: Some(cap_w), ..Default::default() }
    }

    pub fn enabled(&self) -> bool {
        self.cap_w.is_some()
    }

    /// Static cap partition for a cluster shard owning `local` of `total`
    /// packages: shards simulate independently (that is what keeps the
    /// cluster thread-count-deterministic), so the fleet cap is split
    /// proportionally to the silicon each shard governs. Smarter dynamic
    /// partitioning is a ROADMAP follow-up.
    pub fn shard_cap(&self, local: usize, total: usize) -> Option<f64> {
        assert!(local <= total && total > 0);
        self.cap_w.map(|c| c * local as f64 / total as f64)
    }

    /// The governor decision for one dispatch: the fastest level whose
    /// projected draw fits under the `cap_w` watts this governor slice
    /// enforces (the fleet cap, or a shard's partitioned share — callers
    /// resolve the no-cap case to [`DvfsLevel::NOMINAL`] before calling).
    /// `leakage_floor_w` is the summed leakage of every package the cap
    /// governs (conservative: charged at the active rate) and
    /// `inflight_w` the dynamic draw of batches already running. Falls
    /// back to the ladder floor when nothing fits — refusing to dispatch
    /// could deadlock a backlogged queue, and the floor is the least
    /// power the hardware can run at.
    pub fn choose_level(
        &self,
        cap_w: f64,
        leakage_floor_w: f64,
        inflight_w: f64,
        cost: &BatchCost,
    ) -> DvfsLevel {
        let seconds = cost.latency / CLOCK_HZ;
        let nominal_mj = self.model.batch_dynamic(cost).total_mj();
        if seconds <= 0.0 || nominal_mj <= 0.0 {
            return DvfsLevel::NOMINAL;
        }
        let nominal_w = nominal_mj * 1e-3 / seconds;
        let budget = cap_w - leakage_floor_w - inflight_w;
        for level in self.ladder.levels() {
            if nominal_w * level.power_scale() <= budget {
                return *level;
            }
        }
        self.ladder.floor()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cost_with_power(total_pj: f64, latency: f64) -> BatchCost {
        // All dynamic energy in the distribution component (1:1 pJ).
        BatchCost {
            latency,
            dist_busy: 0.0,
            compute_busy: 0.0,
            collect_busy: 0.0,
            macs: 0.0,
            sram_bytes: 0.0,
            dist_energy_pj: total_pj,
            collect_byte_hops: 0.0,
        }
    }

    /// A batch whose nominal dynamic power is exactly `w` watts.
    fn batch_at_watts(w: f64) -> BatchCost {
        let latency = CLOCK_HZ; // 1 simulated second
        cost_with_power(w * 1e12, latency) // w J = w * 1e12 pJ over 1 s
    }

    #[test]
    fn ladder_is_monotone_and_nominal_topped() {
        let ladder = DvfsLadder::default();
        assert_eq!(ladder.levels()[0], DvfsLevel::NOMINAL);
        for w in ladder.levels().windows(2) {
            assert!(w[0].freq_scale > w[1].freq_scale);
            assert!(w[0].energy_scale > w[1].energy_scale);
            assert!(w[0].power_scale() > w[1].power_scale());
        }
        let floor = ladder.floor();
        assert!(floor.power_scale() < 0.25, "floor power scale {}", floor.power_scale());
        assert!(floor.energy_scale > 0.0 && floor.energy_scale < 1.0);
    }

    #[test]
    fn no_cap_disables_the_governor() {
        // The no-cap case is resolved by the callers (both
        // `governor_level` implementations) before `choose_level` runs.
        assert!(!PowerConfig::default().enabled());
        assert!(PowerConfig::with_cap(100.0).enabled());
    }

    #[test]
    fn ample_budget_runs_at_nominal() {
        let cfg = PowerConfig::with_cap(1000.0);
        let lvl = cfg.choose_level(1000.0, 50.0, 100.0, &batch_at_watts(100.0));
        assert_eq!(lvl, DvfsLevel::NOMINAL);
    }

    #[test]
    fn shrinking_budget_walks_down_the_ladder() {
        let cfg = PowerConfig::with_cap(100.0);
        let batch = batch_at_watts(90.0);
        // Remaining budget shrinks as in-flight draw grows: the level can
        // only move down the ladder, monotonically.
        let mut last = f64::INFINITY;
        for inflight in [0.0, 30.0, 60.0, 80.0, 95.0] {
            let lvl = cfg.choose_level(100.0, 0.0, inflight, &batch);
            assert!(lvl.freq_scale <= last, "ladder went up as budget shrank");
            last = lvl.freq_scale;
        }
        // 90 W nominal into a 5 W budget: nothing fits, floor applies.
        assert_eq!(cfg.choose_level(100.0, 0.0, 95.0, &batch), cfg.ladder.floor());
    }

    #[test]
    fn projection_respects_the_cap_when_feasible() {
        let cfg = PowerConfig::with_cap(60.0);
        let batch = batch_at_watts(55.0);
        let lvl = cfg.choose_level(60.0, 10.0, 20.0, &batch);
        // Budget is 30 W; the level chosen must project at most that.
        assert!(55.0 * lvl.power_scale() <= 30.0 + 1e-9);
        assert!(!lvl.is_nominal());
    }

    #[test]
    fn shard_caps_partition_proportionally() {
        let cfg = PowerConfig::with_cap(400.0);
        assert_eq!(cfg.shard_cap(4, 16), Some(100.0));
        assert_eq!(cfg.shard_cap(16, 16), Some(400.0));
        assert_eq!(PowerConfig::default().shard_cap(4, 16), None);
    }

    #[test]
    fn rebalanced_slices_conserve_the_fleet_cap_and_raise_survivors() {
        // The stranded-cap fix re-splits over *live* packages: a dead
        // shard's slice goes to zero, the freed watts raise every
        // survivor's slice, and the slices still sum to exactly the
        // fleet cap — the fleet never draws more than configured, and
        // survivors stop throttling below what the cap requires.
        let cfg = PowerConfig::with_cap(400.0);
        let before: Vec<f64> =
            (0..4).map(|_| cfg.shard_cap(4, 16).expect("cap set")).collect();
        // Shard 0's four packages die: 12 live packages remain.
        let live = [0usize, 4, 4, 4];
        let after: Vec<f64> =
            live.iter().map(|&l| cfg.shard_cap(l, 12).expect("cap set")).collect();
        assert_eq!(after[0], 0.0, "a dead shard holds no slice");
        for s in 1..4 {
            assert!(after[s] > before[s], "survivor slice must rise: {} vs {}", after[s], before[s]);
        }
        let total: f64 = after.iter().sum();
        assert!((total - 400.0).abs() < 1e-9, "slices sum to the fleet cap, got {total}");
        // A survivor's governor now picks a faster level for the same
        // batch than it could under the pre-kill slice.
        let batch = batch_at_watts(120.0);
        let throttled = cfg.choose_level(before[1], 10.0, 0.0, &batch);
        let raised = cfg.choose_level(after[1], 10.0, 0.0, &batch);
        assert!(raised.freq_scale > throttled.freq_scale, "survivor level must rise");
    }

    #[test]
    #[should_panic(expected = "strictly descend")]
    fn unsorted_ladders_are_rejected() {
        DvfsLadder::new(&[1.0, 0.5, 0.7]);
    }
}
