//! Fleet-level energy aggregation: the run's energy/power summary.
//!
//! [`FleetEnergy`] folds the per-package meters and the leakage integral
//! into one record. Both serving engines attach it to their stats —
//! `serve::Fleet::run` sets `ServeStats::energy`, and the cluster's
//! deterministic merge computes it from the merged (shard-major ordered)
//! package list, so the value is bit-identical at any worker-thread
//! count.

use super::meter::PowerModel;
use crate::config::CLOCK_HZ;
use crate::serve::Package;

/// One run's energy totals, by component (mJ).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct FleetEnergy {
    pub compute_mj: f64,
    pub sram_mj: f64,
    pub dist_mj: f64,
    pub collect_mj: f64,
    /// Leakage integral over the run: active leakage while a package
    /// served, idle (possibly power-gated) leakage otherwise.
    pub leakage_mj: f64,
    /// Batches the governor dispatched below the nominal DVFS level.
    pub throttled_batches: u64,
}

impl FleetEnergy {
    /// Aggregate the fleet's meters at the end of a run spanning
    /// `[0, end_cycle]`. Iterates `packages` in the given order and sums
    /// with plain `+=`, so a deterministic package order (the cluster's
    /// shard-major merge order) yields a bit-identical result.
    pub fn collect(packages: &[Package], end_cycle: f64, model: &PowerModel) -> FleetEnergy {
        let mut e = FleetEnergy::default();
        let end_s = (end_cycle / CLOCK_HZ).max(0.0);
        for p in packages {
            e.compute_mj += p.meter.compute_mj;
            e.sram_mj += p.meter.sram_mj;
            e.dist_mj += p.meter.dist_mj;
            e.collect_mj += p.meter.collect_mj;
            e.throttled_batches += p.meter.throttled_batches;
            // busy_cycles is already DVFS-stretched (wall time on the
            // simulated clock) and preemption-rolled-back.
            let busy_s = (p.busy_cycles / CLOCK_HZ).clamp(0.0, end_s);
            let idle_s = end_s - busy_s;
            e.leakage_mj += (model.active_leakage_w(&p.spec.sys) * busy_s
                + model.idle_leakage_w(&p.spec.sys) * idle_s)
                * 1e3;
        }
        e
    }

    /// Dynamic (switching) energy across all components.
    pub fn dynamic_mj(&self) -> f64 {
        self.compute_mj + self.sram_mj + self.dist_mj + self.collect_mj
    }

    pub fn total_mj(&self) -> f64 {
        self.dynamic_mj() + self.leakage_mj
    }

    /// Whole-run energy per completed request, in joules (`NaN` when
    /// nothing completed).
    pub fn energy_per_req_j(&self, completed: u64) -> f64 {
        if completed == 0 {
            f64::NAN
        } else {
            self.total_mj() * 1e-3 / completed as f64
        }
    }

    /// Mean power over the run, in watts (`NaN` for an empty run).
    pub fn avg_power_w(&self, end_cycle: f64) -> f64 {
        if end_cycle <= 0.0 {
            f64::NAN
        } else {
            self.total_mj() * 1e-3 / (end_cycle / CLOCK_HZ)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DesignPoint;
    use crate::serve::PackageSpec;

    fn fresh_packages(n: usize) -> Vec<Package> {
        PackageSpec::homogeneous(n, DesignPoint::WIENNA_C).into_iter().map(Package::new).collect()
    }

    #[test]
    fn idle_fleet_accrues_exactly_leakage_times_time() {
        // Satellite acceptance: an idle fleet's whole-run energy is the
        // idle-leakage integral and nothing else — computed with the very
        // same arithmetic, so the equality is exact.
        let model = PowerModel { power_gating: false, ..PowerModel::default() };
        let pkgs = fresh_packages(1);
        let end = CLOCK_HZ * 2.0; // 2 simulated seconds
        let e = FleetEnergy::collect(&pkgs, end, &model);
        assert_eq!(e.dynamic_mj(), 0.0, "no batches, no dynamic energy");
        assert_eq!(e.throttled_batches, 0);
        assert_eq!(e.leakage_mj, model.idle_leakage_w(&pkgs[0].spec.sys) * 2.0 * 1e3);
        // Without gating, idle leakage is the full active rate.
        assert_eq!(e.leakage_mj, model.active_leakage_w(&pkgs[0].spec.sys) * 2.0 * 1e3);
    }

    #[test]
    fn power_gating_cuts_idle_leakage() {
        let gated = PowerModel::default();
        let ungated = PowerModel { power_gating: false, ..PowerModel::default() };
        let pkgs = fresh_packages(4);
        let end = CLOCK_HZ;
        let e_gated = FleetEnergy::collect(&pkgs, end, &gated);
        let e_ungated = FleetEnergy::collect(&pkgs, end, &ungated);
        assert!(
            e_gated.leakage_mj < 0.5 * e_ungated.leakage_mj,
            "gating saved too little: {} vs {}",
            e_gated.leakage_mj,
            e_ungated.leakage_mj
        );
        assert!(e_gated.leakage_mj > 0.0, "the memory chiplet never gates away");
    }

    #[test]
    fn per_request_and_power_edges() {
        let e = FleetEnergy { leakage_mj: 500.0, ..Default::default() };
        assert!(e.energy_per_req_j(0).is_nan());
        assert!((e.energy_per_req_j(100) - 5e-3).abs() < 1e-15);
        assert!(e.avg_power_w(0.0).is_nan());
        // 500 mJ over 1 simulated second = 0.5 W.
        assert!((e.avg_power_w(CLOCK_HZ) - 0.5).abs() < 1e-12);
    }
}
