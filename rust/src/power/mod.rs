//! `wienna::power` — runtime energy telemetry, power capping, and the
//! energy axis of the design-space search (substrate S15).
//!
//! The paper's second headline claim — 38.2% lower energy than the
//! interposer NoP — had only a *static* counterpart in this crate
//! (`energy::{area,distribution,system}` price one isolated inference).
//! This module gives the discrete-event serving stack a *runtime* energy
//! story:
//!
//! * [`meter`] — the energy meter. Every dispatched batch is charged its
//!   dynamic energy, derived from the cost model's traffic phases
//!   (distribution pJ straight from the NoP models behind Fig 9, SRAM
//!   bytes, MACs, collection byte-hops) through the Table-3-consistent
//!   [`EnergyConstants`](crate::energy::EnergyConstants); a leakage term
//!   calibrated against the Table-3 power budget accrues over wall time,
//!   with optional **power gating** that sheds most of an idle chiplet's
//!   leakage. Telemetry lands in a per-package [`PackageMeter`].
//! * [`governor`] — the power-cap governor. A fleet-level cap in watts is
//!   enforced through a deterministic DVFS ladder: each dispatch picks
//!   the fastest frequency level whose projected draw (leakage floor +
//!   in-flight dynamic power + this batch) fits under the cap. The chosen
//!   level stretches the batch's makespan (cycles → time) *and* scales
//!   its dynamic energy (V² · f), so capping is a closed feedback loop —
//!   throttled batches run longer, hold their power share longer, and
//!   push later dispatches down the ladder — not post-hoc bookkeeping.
//!   With no cap configured every batch runs at [`DvfsLevel::NOMINAL`]
//!   and the serving simulation is bit-identical to the meter-less one.
//! * [`pareto`] — exhaustive non-dominated filtering, the multi-objective
//!   output of `search::autosize` (dollar cost × energy/request × p99
//!   instead of cheapest-only; `wienna search --pareto`).
//! * [`stats`] — fleet-level aggregation: [`FleetEnergy`] sums the
//!   per-package meters and the leakage integral, and feeds the energy
//!   fields of `serve::ServeStats` and the cluster stats JSON (which
//!   stays bit-identical at any worker-thread count — energy accumulates
//!   in deterministic shard-major order).
//!
//! ## Example
//!
//! ```no_run
//! use wienna::config::DesignPoint;
//! use wienna::power::PowerConfig;
//! use wienna::serve::{Fleet, ModelKind, PackageSpec, RoutePolicy, ServeStats, Source, WorkloadMix};
//!
//! let mut fleet = Fleet::new(
//!     PackageSpec::homogeneous(4, DesignPoint::WIENNA_C),
//!     RoutePolicy::EarliestDeadline,
//! );
//! fleet.power = PowerConfig::with_cap(250.0); // 250 W fleet cap
//! let mix = WorkloadMix::single(ModelKind::ResNet50, 25.0);
//! let mut source = Source::poisson(mix, 2000.0, 42);
//! let mut stats = ServeStats::new();
//! fleet.run(&mut source, wienna::serve::ms_to_cycles(100.0), &mut stats);
//! let e = stats.energy.expect("serve runs always meter energy");
//! println!(
//!     "{:.1} mJ total ({:.1} dynamic + {:.1} leakage) | {:.2} J/req | avg {:.1} W | {} throttled",
//!     e.total_mj(),
//!     e.dynamic_mj(),
//!     e.leakage_mj,
//!     e.energy_per_req_j(stats.completed()),
//!     e.avg_power_w(stats.end_cycle()),
//!     e.throttled_batches,
//! );
//! ```

pub mod governor;
pub mod meter;
pub mod pareto;
pub mod stats;

pub use governor::{DvfsLadder, DvfsLevel, PowerConfig};
pub use meter::{BatchEnergy, PackageMeter, PowerModel};
pub use pareto::{dominates, pareto_front};
pub use stats::FleetEnergy;
