//! The energy meter: per-batch dynamic energy from the cost model's
//! traffic phases, plus a Table-3-derived leakage model with power gating.
//!
//! Dynamic energy reuses exactly the machinery behind the paper's energy
//! results: the distribution pJ of a batch comes from the NoP models
//! (wireless multicast vs interposer mesh — the Fig-9 comparison), and
//! the strategy-invariant components (MACs, global-SRAM bytes, collection
//! byte-hops) are priced through the same 65-nm
//! [`EnergyConstants`](crate::energy::EnergyConstants) as
//! `energy::system`. Leakage is pinned to the Table-3 component budget
//! (`energy::area`): a fixed fraction of each component's active power
//! burns whenever the silicon is powered, and **power gating** sheds most
//! of an idle chiplet's share while the always-on memory chiplet (global
//! SRAM + TX) keeps leaking.

use crate::config::SystemConfig;
use crate::energy::area::{PE_POWER_MW, ROUTER_POWER_MW, SRAM_POWER_MW_PER_MIB};
use crate::energy::EnergyConstants;
use crate::serve::BatchCost;

/// Dynamic energy of one dispatched batch, by component (mJ).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct BatchEnergy {
    pub compute_mj: f64,
    pub sram_mj: f64,
    pub dist_mj: f64,
    pub collect_mj: f64,
}

impl BatchEnergy {
    pub fn total_mj(&self) -> f64 {
        self.compute_mj + self.sram_mj + self.dist_mj + self.collect_mj
    }

    /// Every component scaled by `k` (the DVFS ladder's V²·energy scale).
    pub fn scaled(&self, k: f64) -> BatchEnergy {
        BatchEnergy {
            compute_mj: self.compute_mj * k,
            sram_mj: self.sram_mj * k,
            dist_mj: self.dist_mj * k,
            collect_mj: self.collect_mj * k,
        }
    }
}

/// The runtime power model: dynamic per-op energies plus the leakage
/// calibration against the Table-3 power budget.
#[derive(Debug, Clone)]
pub struct PowerModel {
    /// 65-nm dynamic energy constants (shared with `energy::system`).
    pub constants: EnergyConstants,
    /// Leakage as a fraction of the Table-3 *active* power budget. 65-nm
    /// logic leaks well under 10% of its switching power; the default
    /// charges 8% of each component's Table-3 row.
    pub leakage_fraction: f64,
    /// Gate idle chiplets: a package with no batch in flight sheds
    /// `gating_efficiency` of its chiplet-side leakage (PE arrays +
    /// collection routers). The memory chiplet (global SRAM + TX) is
    /// always on — it holds live model weights.
    pub power_gating: bool,
    /// Share of chiplet leakage removed by gating (sleep transistors
    /// retain state but cannot cut the rail entirely).
    pub gating_efficiency: f64,
}

impl Default for PowerModel {
    fn default() -> Self {
        PowerModel {
            constants: EnergyConstants::default(),
            leakage_fraction: 0.08,
            power_gating: true,
            gating_efficiency: 0.95,
        }
    }
}

impl PowerModel {
    /// Dynamic energy of one batch from its memoized cost: MACs, SRAM
    /// traffic (every distributed byte read + every collected byte
    /// written), the NoP-model distribution energy, and collection
    /// byte-hops over the wired mesh — priced by the same
    /// [`TrafficTotals::price_mj`](crate::energy::TrafficTotals) formulas
    /// as the static `energy::system_energy` path. Unscaled — the caller
    /// applies the DVFS level's energy scale.
    pub fn batch_dynamic(&self, cost: &BatchCost) -> BatchEnergy {
        let t = crate::energy::TrafficTotals {
            macs: cost.macs,
            sram_bytes: cost.sram_bytes,
            dist_energy_pj: cost.dist_energy_pj,
            collect_byte_hops: cost.collect_byte_hops,
        };
        let [compute_mj, sram_mj, dist_mj, collect_mj] = t.price_mj(&self.constants);
        BatchEnergy { compute_mj, sram_mj, dist_mj, collect_mj }
    }

    /// Leakage of the gateable chiplet side (PE arrays + collection
    /// routers, Table-3 rows), in watts.
    pub fn chiplet_leakage_w(&self, sys: &SystemConfig) -> f64 {
        let per_chiplet_mw = PE_POWER_MW * sys.pes_per_chiplet as f64 + ROUTER_POWER_MW;
        per_chiplet_mw * sys.num_chiplets as f64 * self.leakage_fraction * 1e-3
    }

    /// Leakage of the always-on memory chiplet (global SRAM), in watts.
    pub fn always_on_leakage_w(&self, sys: &SystemConfig) -> f64 {
        let sram_mib = sys.global_sram_bytes as f64 / (1024.0 * 1024.0);
        SRAM_POWER_MW_PER_MIB * sram_mib * self.leakage_fraction * 1e-3
    }

    /// Whole-package leakage while a batch is in flight.
    pub fn active_leakage_w(&self, sys: &SystemConfig) -> f64 {
        self.always_on_leakage_w(sys) + self.chiplet_leakage_w(sys)
    }

    /// Whole-package leakage while idle: with power gating the chiplet
    /// side drops to its retention floor, without it idle == active.
    pub fn idle_leakage_w(&self, sys: &SystemConfig) -> f64 {
        let gated = if self.power_gating { 1.0 - self.gating_efficiency } else { 1.0 };
        self.always_on_leakage_w(sys) + self.chiplet_leakage_w(sys) * gated
    }
}

/// Per-package runtime energy telemetry. Lives on `serve::Package`; both
/// event loops (fleet and cluster shard) charge it through the package's
/// batch lifecycle, so the accounting is identical wherever the package
/// serves.
#[derive(Debug, Clone, Default)]
pub struct PackageMeter {
    pub compute_mj: f64,
    pub sram_mj: f64,
    pub dist_mj: f64,
    pub collect_mj: f64,
    /// Batches dispatched below the nominal DVFS level.
    pub throttled_batches: u64,
    /// Dynamic power draw of the in-flight batch (W); 0 while idle. The
    /// governor reads this to project fleet power at dispatch time.
    inflight_w: f64,
    /// The in-flight batch's (already level-scaled) energy, kept so a
    /// preemption can roll the un-run share back.
    cur: Option<BatchEnergy>,
}

impl PackageMeter {
    /// Total dynamic energy metered so far (mJ).
    pub fn dynamic_mj(&self) -> f64 {
        self.compute_mj + self.sram_mj + self.dist_mj + self.collect_mj
    }

    pub fn inflight_w(&self) -> f64 {
        self.inflight_w
    }

    /// Charge one dispatched batch: `energy` is the level-scaled dynamic
    /// energy, `cycles` the level-stretched makespan.
    pub(crate) fn begin(&mut self, energy: BatchEnergy, cycles: f64, throttled: bool) {
        self.compute_mj += energy.compute_mj;
        self.sram_mj += energy.sram_mj;
        self.dist_mj += energy.dist_mj;
        self.collect_mj += energy.collect_mj;
        if throttled {
            self.throttled_batches += 1;
        }
        self.inflight_w = if cycles > 0.0 {
            energy.total_mj() * 1e-3 / (cycles / crate::config::CLOCK_HZ)
        } else {
            0.0
        };
        self.cur = Some(energy);
    }

    /// The in-flight batch completed.
    pub(crate) fn finish(&mut self) {
        self.inflight_w = 0.0;
        self.cur = None;
    }

    /// The in-flight batch was preempted with `undone` of it un-run: the
    /// energy already burnt stays counted (preempted work is real wasted
    /// work), the un-run share is rolled back. Returns the mJ removed so
    /// per-class attribution can roll back the same amount.
    pub(crate) fn rollback(&mut self, undone: f64) -> f64 {
        let cur = self.cur.take().expect("in-flight batch has metered energy");
        self.compute_mj -= cur.compute_mj * undone;
        self.sram_mj -= cur.sram_mj * undone;
        self.dist_mj -= cur.dist_mj * undone;
        self.collect_mj -= cur.collect_mj * undone;
        self.inflight_w = 0.0;
        cur.total_mj() * undone
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cost(macs: f64, sram: f64, dist_pj: f64, hops: f64, latency: f64) -> BatchCost {
        BatchCost {
            latency,
            dist_busy: 0.0,
            compute_busy: 0.0,
            collect_busy: 0.0,
            macs,
            sram_bytes: sram,
            dist_energy_pj: dist_pj,
            collect_byte_hops: hops,
        }
    }

    #[test]
    fn batch_dynamic_prices_every_component() {
        let m = PowerModel::default();
        let e = m.batch_dynamic(&cost(1e9, 1e6, 5e6, 2e6, 1e6));
        assert!(e.compute_mj > 0.0 && e.sram_mj > 0.0 && e.dist_mj > 0.0 && e.collect_mj > 0.0);
        // MACs dominate this synthetic batch: 1e9 * 0.5 pJ = 0.5 mJ.
        assert!((e.compute_mj - 0.5).abs() < 1e-12);
        assert!((e.dist_mj - 5e-3).abs() < 1e-12);
        let s = e.scaled(0.5);
        assert!((s.total_mj() - e.total_mj() * 0.5).abs() < 1e-12);
    }

    #[test]
    fn leakage_tracks_table3_budget() {
        let m = PowerModel::default();
        let sys = SystemConfig::default();
        // Table-3 chiplet power: 256 x (90 mW PE array + 170 mW router)
        // ~ 66.6 W; SRAM 10 W. At 8% leakage: ~5.3 W + 0.8 W.
        let chip = m.chiplet_leakage_w(&sys);
        let mem = m.always_on_leakage_w(&sys);
        assert!(chip > 4.0 && chip < 7.0, "chiplet leakage {chip} W");
        assert!(mem > 0.5 && mem < 1.2, "SRAM leakage {mem} W");
        assert_eq!(m.active_leakage_w(&sys), chip + mem);
    }

    #[test]
    fn gating_sheds_chiplet_leakage_only() {
        let sys = SystemConfig::default();
        let on = PowerModel::default();
        let off = PowerModel { power_gating: false, ..PowerModel::default() };
        assert_eq!(off.idle_leakage_w(&sys), off.active_leakage_w(&sys));
        let idle = on.idle_leakage_w(&sys);
        let expected = on.always_on_leakage_w(&sys)
            + on.chiplet_leakage_w(&sys) * (1.0 - on.gating_efficiency);
        assert!((idle - expected).abs() < 1e-12);
        assert!(idle < on.active_leakage_w(&sys));
        // The always-on memory chiplet never gates away.
        assert!(idle > on.always_on_leakage_w(&sys) * 0.999);
    }

    #[test]
    fn meter_begin_finish_rollback() {
        let mut meter = PackageMeter::default();
        assert_eq!(meter.dynamic_mj(), 0.0);
        let e = BatchEnergy { compute_mj: 4.0, sram_mj: 2.0, dist_mj: 1.0, collect_mj: 1.0 };
        meter.begin(e, crate::config::CLOCK_HZ, false); // 1 simulated second
        assert!((meter.dynamic_mj() - 8.0).abs() < 1e-12);
        // 8 mJ over 1 s = 8 mW.
        assert!((meter.inflight_w() - 8e-3).abs() < 1e-15);
        meter.finish();
        assert_eq!(meter.inflight_w(), 0.0);

        // Preempt a second batch three quarters un-run: 25% of its energy
        // stays burnt.
        meter.begin(e, crate::config::CLOCK_HZ, true);
        assert_eq!(meter.throttled_batches, 1);
        let rolled = meter.rollback(0.75);
        assert!((rolled - 6.0).abs() < 1e-12);
        assert!((meter.dynamic_mj() - 10.0).abs() < 1e-12);
        assert_eq!(meter.inflight_w(), 0.0);
    }
}
