//! Exhaustive non-dominated (Pareto) filtering over small point sets.
//!
//! The auto-sizer's multi-objective output (`wienna search --pareto`)
//! scores every feasible fleet on (dollar cost, energy/request, p99) and
//! keeps the non-dominated subset. The sets involved are tiny (one sized
//! plan per surviving candidate — dozens, not millions), so the O(n²)
//! exhaustive check is both fastest in practice and trivially auditable:
//! the integration suite re-verifies the front against this very
//! definition.
//!
//! Orderings use `f64::total_cmp`, so a `NaN` coordinate (e.g. the p99 of
//! a probe that saw no traffic) sorts as *worse than everything* instead
//! of poisoning comparisons: a NaN-coordinate point can still be
//! dominated, but can only dominate a point that is NaN there too.

use std::cmp::Ordering;

/// `true` when `a` dominates `b`: no worse on every axis (minimizing),
/// strictly better on at least one.
pub fn dominates<const D: usize>(a: &[f64; D], b: &[f64; D]) -> bool {
    let mut strictly_better = false;
    for (x, y) in a.iter().zip(b.iter()) {
        match x.total_cmp(y) {
            Ordering::Greater => return false,
            Ordering::Less => strictly_better = true,
            Ordering::Equal => {}
        }
    }
    strictly_better
}

/// Indices (ascending) of the non-dominated members of `points`.
/// Duplicate points dominate nothing, so ties all stay on the front.
pub fn pareto_front<const D: usize>(points: &[[f64; D]]) -> Vec<usize> {
    (0..points.len())
        .filter(|&i| {
            !points.iter().enumerate().any(|(j, other)| j != i && dominates(other, &points[i]))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dominance_definition() {
        assert!(dominates(&[1.0, 2.0], &[1.0, 3.0]));
        assert!(dominates(&[0.5, 2.0], &[1.0, 3.0]));
        assert!(!dominates(&[1.0, 2.0], &[1.0, 2.0]), "equal points never dominate");
        assert!(!dominates(&[0.5, 4.0], &[1.0, 3.0]), "trade-offs never dominate");
        assert!(!dominates(&[2.0, 2.0], &[1.0, 3.0]));
    }

    #[test]
    fn nan_sorts_as_worst() {
        // A NaN coordinate loses that axis to any real value…
        assert!(dominates(&[1.0, 2.0], &[1.0, f64::NAN]));
        assert!(!dominates(&[1.0, f64::NAN], &[1.0, 2.0]));
        // …and two NaNs tie on it.
        assert!(dominates(&[1.0, f64::NAN], &[2.0, f64::NAN]));
    }

    #[test]
    fn front_of_a_known_set() {
        let pts = [
            [1.0, 10.0, 5.0], // on front (cheapest)
            [2.0, 4.0, 5.0],  // on front (energy trade)
            [2.0, 4.0, 6.0],  // dominated by [1]
            [3.0, 3.0, 1.0],  // on front (latency trade)
            [9.0, 9.0, 9.0],  // dominated by everything
        ];
        assert_eq!(pareto_front(&pts), vec![0, 1, 3]);
    }

    #[test]
    fn front_properties_hold_on_a_pseudorandom_cloud() {
        let mut rng = crate::testutil::Rng::new(7);
        let pts: Vec<[f64; 3]> = (0..60)
            .map(|_| [rng.next_f32() as f64, rng.next_f32() as f64, rng.next_f32() as f64])
            .collect();
        let front = pareto_front(&pts);
        assert!(!front.is_empty());
        // No front member is dominated by any point…
        for &i in &front {
            assert!(pts.iter().all(|p| !dominates(p, &pts[i])));
        }
        // …and every non-member is dominated by some front member
        // (dominance is transitive, so a maximal dominator is on the front).
        for (i, p) in pts.iter().enumerate() {
            if !front.contains(&i) {
                assert!(front.iter().any(|&f| dominates(&pts[f], p)), "point {i} escaped");
            }
        }
    }

    #[test]
    fn duplicates_all_stay_on_the_front() {
        let pts = [[1.0, 1.0], [1.0, 1.0], [2.0, 0.5]];
        assert_eq!(pareto_front(&pts), vec![0, 1, 2]);
    }

    #[test]
    fn empty_and_singleton() {
        let empty: [[f64; 2]; 0] = [];
        assert!(pareto_front(&empty).is_empty());
        assert_eq!(pareto_front(&[[3.0, 4.0]]), vec![0]);
    }
}
