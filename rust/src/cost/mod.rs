//! MAESTRO-like analytical cost model (substrate S4).
//!
//! Combines the partition plan (S2), intra-chiplet mapping (S3) and NoP
//! models (S5–S8) into per-layer latency, throughput, utilization and
//! distribution-energy estimates, following the paper's §5.1 methodology.

pub mod memo;
pub mod memory;
pub mod model;
pub mod par;
pub mod phase;
pub mod traffic;

pub use memo::MemoStats;
pub use memory::{HbmModel, StagingPlan};
pub use model::{
    best_strategy, evaluate_grid, evaluate_layer, evaluate_layer_uncached, evaluate_model,
    evaluate_model_par, CostEngine, DistFabric, EngineKey, LayerCost, ModelCost,
};
pub use phase::PhaseTimeline;
