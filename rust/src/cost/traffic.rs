//! Traffic-schedule generation: turn a partition plan's abstract traffic
//! classes into the concrete transfer lists consumed by the cycle-level
//! mesh simulator and by the coordinator's distribution scheduler.

use crate::dataflow::{PartitionPlan, TrafficClass};
use crate::nop::sim::{NodeId, Transfer};

/// Chunk size for streamed transfers (one "element row" per broadcast, as
/// in the Fig-6 walkthrough). Preloads use larger DMA-style chunks.
///
/// The transfer lists are *logical*: the cycle-level simulator packetizes
/// long transfers itself (`MeshSim::max_packet_bytes`), so expansion
/// coalesces chunks and caps the number of emitted transfers per class to
/// keep schedules O(chiplets), not O(bytes).
pub const STREAM_CHUNK_BYTES: u64 = 64;
pub const PRELOAD_CHUNK_BYTES: u64 = 4096;
/// Upper bound on transfers emitted per traffic class.
pub const MAX_TRANSFERS_PER_CLASS: usize = 512;

/// First `n` nodes of a `side`-wide mesh in row-major order — the layout
/// the coordinator assigns work in.
pub fn used_nodes(side: u32, n: u64) -> Vec<NodeId> {
    (0..n.min((side as u64) * (side as u64)))
        .map(|i| NodeId::new((i / side as u64) as u32, (i % side as u64) as u32))
        .collect()
}

/// Expand one traffic class into concrete mesh transfers.
///
/// * Unicast classes (`avg_dests == 1`) are round-robined across the used
///   chiplets in per-chiplet shares.
/// * Multicast/broadcast classes are chunked and sent to the whole used
///   set (fractional halo fan-outs are conservatively rounded up to the
///   nearest whole destination subset).
pub fn expand_class(class: &TrafficClass, used: &[NodeId]) -> Vec<Transfer> {
    assert!(!used.is_empty());
    let base_chunk = if class.streamed { STREAM_CHUNK_BYTES } else { PRELOAD_CHUNK_BYTES };
    let mut out = Vec::new();
    if class.avg_dests <= 1.0 + 1e-9 {
        // Partitioned tensor: each chiplet gets its share as one logical
        // transfer (the simulator packetizes).
        let share = class.bytes / used.len() as u64;
        let mut rem_extra = class.bytes - share * used.len() as u64;
        for &node in used {
            let mut bytes = share;
            if rem_extra > 0 {
                bytes += 1;
                rem_extra -= 1;
            }
            if bytes > 0 {
                out.push(Transfer::unicast(bytes, node));
            }
        }
    } else {
        // Replicated tensor: chunks go to a destination subset of size
        // ceil(avg_dests) chiplets (== all used chiplets for a broadcast).
        let fan = (class.avg_dests.ceil() as usize).min(used.len()).max(1);
        if fan == used.len() {
            // Full broadcast: every chunk has the identical destination
            // set, so one logical transfer suffices (the simulator
            // packetizes; the MAC layer slots it) — keeps schedules
            // O(chiplets), not O(bytes). See EXPERIMENTS.md §Perf.
            out.push(Transfer { bytes: class.bytes, dests: used.to_vec() });
            return out;
        }
        // Coalesce so at most MAX_TRANSFERS_PER_CLASS transfers emerge.
        let chunk = base_chunk.max(class.bytes.div_ceil(MAX_TRANSFERS_PER_CLASS as u64));
        let mut remaining = class.bytes;
        let mut offset = 0usize;
        while remaining > 0 {
            let c = remaining.min(chunk);
            remaining -= c;
            // Rotate the subset start so halo-style partial multicasts
            // spread over the grid rather than hammering one corner.
            let dests: Vec<NodeId> = (0..fan).map(|i| used[(offset + i) % used.len()]).collect();
            offset = (offset + fan) % used.len();
            out.push(Transfer { bytes: c, dests });
        }
    }
    out
}

/// Expand a whole partition plan into (preload, stream) transfer lists for
/// `side x side` mesh with `used` chiplets active.
pub fn expand_plan(plan: &PartitionPlan, side: u32) -> (Vec<Transfer>, Vec<Transfer>) {
    let used = used_nodes(side, plan.used_chiplets);
    let mut preload = Vec::new();
    let mut stream = Vec::new();
    for class in &plan.traffic {
        if class.bytes == 0 {
            continue;
        }
        let ts = expand_class(class, &used);
        if class.streamed {
            stream.extend(ts);
        } else {
            preload.extend(ts);
        }
    }
    (preload, stream)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::{partition, Strategy, TensorKind};
    use crate::workload::Layer;

    #[test]
    fn unicast_conserves_bytes() {
        let class = TrafficClass { tensor: TensorKind::Weight, bytes: 1000, avg_dests: 1.0, streamed: false };
        let used = used_nodes(4, 10);
        let ts = expand_class(&class, &used);
        let total: u64 = ts.iter().map(|t| t.bytes).sum();
        assert_eq!(total, 1000);
        // Every transfer is a unicast.
        assert!(ts.iter().all(|t| t.dests.len() == 1));
    }

    #[test]
    fn broadcast_conserves_bytes_and_fans_out() {
        let class = TrafficClass { tensor: TensorKind::Input, bytes: 300, avg_dests: 16.0, streamed: true };
        let used = used_nodes(4, 16);
        let ts = expand_class(&class, &used);
        let total: u64 = ts.iter().map(|t| t.bytes).sum();
        assert_eq!(total, 300);
        assert!(ts.iter().all(|t| t.dests.len() == 16));
        // Full broadcast coalesces to one logical transfer (the sim and
        // MAC layers packetize it).
        assert_eq!(ts.len(), 1);
    }

    #[test]
    fn halo_fanout_rounds_up() {
        let class = TrafficClass { tensor: TensorKind::Input, bytes: 128, avg_dests: 1.3, streamed: true };
        let used = used_nodes(4, 16);
        let ts = expand_class(&class, &used);
        assert!(ts.iter().all(|t| t.dests.len() == 2));
    }

    #[test]
    fn plan_expansion_covers_all_classes() {
        let l = Layer::conv("c", 1, 64, 32, 14, 14, 3, 3, 1);
        let plan = partition::partition(&l, Strategy::KpCp, 16, 1);
        let (pre, stream) = expand_plan(&plan, 4);
        let pre_bytes: u64 = pre.iter().map(|t| t.bytes).sum();
        let stream_bytes: u64 = stream.iter().map(|t| t.bytes).sum();
        assert_eq!(pre_bytes, l.weight_elems());
        assert_eq!(stream_bytes, l.input_elems());
    }
}
