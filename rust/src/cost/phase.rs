//! Fig-6 phase timeline: how distribution, compute and collection overlap.
//!
//! The paper's walkthrough (Fig 6) splits a layer into four phases:
//!
//! 1. `t_0`   — the *partitioned* tensor is unicast to each chiplet
//!              (preload; compute cannot start without it);
//! 2. `t_1`   — the *replicated* tensor is streamed (broadcast) element
//!              by element, overlapping compute;
//! 3. `t_2`   — chiplets compute, consuming the stream;
//! 4. `t_3`   — outputs are collected over the wired NoP; collection is
//!              off the critical path unless it outruns compute (§2:
//!              "collection can be hidden behind compute delay,
//!              distribution is in the critical path").


/// Cycle budget of one layer execution, broken into phases.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PhaseTimeline {
    /// Preload (non-overlapped distribution) cycles — Fig 6 `t_0`.
    pub preload: f64,
    /// Streamed distribution cycles — Fig 6 `t_1`.
    pub stream: f64,
    /// Compute cycles — Fig 6 `t_2`.
    pub compute: f64,
    /// Collection cycles — Fig 6 `t_3`.
    pub collect: f64,
    /// One-time NoP pipeline-fill latency.
    pub fill: f64,
}

impl PhaseTimeline {
    /// End-to-end latency of the layer.
    ///
    /// Preload serializes before the steady state; the steady state runs
    /// at the pace of the slowest of {input stream, compute, collection};
    /// the NoP fill latency is paid once.
    pub fn latency(&self) -> f64 {
        self.preload + self.stream.max(self.compute).max(self.collect) + self.fill
    }

    /// Which phase bounds the steady state.
    pub fn bottleneck(&self) -> Phase {
        if self.stream >= self.compute && self.stream >= self.collect {
            Phase::Distribution
        } else if self.compute >= self.collect {
            Phase::Compute
        } else {
            Phase::Collection
        }
    }
}

/// Steady-state bottleneck classification.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    Distribution,
    Compute,
    Collection,
}

impl Phase {
    pub fn label(&self) -> &'static str {
        match self {
            Phase::Distribution => "distribution-bound",
            Phase::Compute => "compute-bound",
            Phase::Collection => "collection-bound",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compute_bound_layer() {
        let t = PhaseTimeline { preload: 10.0, stream: 50.0, compute: 100.0, collect: 20.0, fill: 8.0 };
        assert_eq!(t.latency(), 10.0 + 100.0 + 8.0);
        assert_eq!(t.bottleneck(), Phase::Compute);
    }

    #[test]
    fn distribution_bound_layer() {
        let t = PhaseTimeline { preload: 0.0, stream: 500.0, compute: 100.0, collect: 20.0, fill: 1.0 };
        assert_eq!(t.latency(), 501.0);
        assert_eq!(t.bottleneck(), Phase::Distribution);
    }

    #[test]
    fn collection_can_bound_when_outputs_dominate() {
        let t = PhaseTimeline { preload: 0.0, stream: 10.0, compute: 10.0, collect: 90.0, fill: 0.0 };
        assert_eq!(t.bottleneck(), Phase::Collection);
        assert_eq!(t.latency(), 90.0);
    }

    #[test]
    fn latency_monotone_in_each_phase() {
        let base = PhaseTimeline { preload: 5.0, stream: 10.0, compute: 20.0, collect: 5.0, fill: 2.0 };
        for bump in [
            PhaseTimeline { preload: 6.0, ..base },
            PhaseTimeline { stream: 25.0, ..base },
            PhaseTimeline { compute: 30.0, ..base },
            PhaseTimeline { collect: 40.0, ..base },
            PhaseTimeline { fill: 3.0, ..base },
        ] {
            assert!(bump.latency() >= base.latency());
        }
    }
}
