//! Per-layer and per-model cost evaluation (the MAESTRO-like engine).

use crate::config::{DesignPoint, SystemConfig};
use crate::dataflow::{self, ChipletArch, MapPolicy, PartitionPlan, Strategy};
use crate::nop::{DistributionCost, MeshNop, NopKind, TrxDesignPoint, WirelessNop};
use crate::workload::{classify, Layer, LayerType, Model};
use crate::cost::phase::{Phase, PhaseTimeline};

/// Distribution fabric alternatives the engine can evaluate.
#[derive(Debug, Clone)]
pub enum DistFabric {
    Mesh(MeshNop),
    Wireless(WirelessNop),
    /// Idealized fabric used by the Fig-3 motivation study: unique bytes
    /// at a swept SRAM read bandwidth, free multicast, no hop latency.
    Ideal { bw: f64 },
}

impl DistFabric {
    pub fn distribution(&self, traffic: &[dataflow::TrafficClass]) -> DistributionCost {
        match self {
            DistFabric::Mesh(m) => m.distribution(traffic),
            DistFabric::Wireless(w) => w.distribution(traffic),
            DistFabric::Ideal { bw } => {
                let mut c = DistributionCost::default();
                for t in traffic {
                    let cycles = t.bytes as f64 / bw;
                    if t.streamed {
                        c.stream_cycles += cycles;
                    } else {
                        c.preload_cycles += cycles;
                    }
                }
                c
            }
        }
    }
}

/// Everything about a [`CostEngine`] that determines a layer's cost,
/// condensed into a hashable memo-table key (see `cost::memo`). Only
/// engines built by [`CostEngine::for_design_point`] carry one; the
/// ideal-fabric engines of the Fig-3 sweep are not memoized.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct EngineKey {
    pub dp: DesignPoint,
    pub num_chiplets: u64,
    pub pes_per_chiplet: u64,
    pub global_sram_bytes: u64,
    /// Collection bandwidth (bytes/cycle/link) as its IEEE-754 bit
    /// pattern, so the key stays `Eq + Hash`.
    pub collection_bw_bits: u64,
    pub bytes_per_elem: u64,
}

impl EngineKey {
    fn for_system(sys: &SystemConfig, dp: DesignPoint) -> Self {
        EngineKey {
            dp,
            num_chiplets: sys.num_chiplets,
            pes_per_chiplet: sys.pes_per_chiplet,
            global_sram_bytes: sys.global_sram_bytes,
            collection_bw_bits: sys.collection_bw_per_link.to_bits(),
            bytes_per_elem: sys.bytes_per_elem,
        }
    }
}

/// Fully-configured cost engine: package, NoP pair, mapping policy.
#[derive(Debug, Clone)]
pub struct CostEngine {
    pub sys: SystemConfig,
    pub dist: DistFabric,
    /// Wired mesh used for collection in *all* designs (paper §4).
    pub collect: MeshNop,
    pub map_policy: MapPolicy,
    /// Optional HBM→SRAM staging model. `None` (default) reproduces the
    /// paper's assumption that distribution is SRAM-fed; `Some` bounds
    /// the stream by the HBM refill rate when a layer's working set
    /// spills the global SRAM (see `cost::memory`, ablation bench).
    pub hbm: Option<crate::cost::memory::HbmModel>,
    /// Memo-table key; `Some` only for design-point engines whose whole
    /// configuration the key captures.
    memo_key: Option<EngineKey>,
    /// Fingerprint of every cost-relevant field at construction time.
    /// All engine fields are public (the ablation benches mutate `dist`,
    /// `map_policy` and `hbm` freely), so [`CostEngine::memo_key`]
    /// re-fingerprints on every call and silently falls back to uncached
    /// evaluation when anything changed — a mutated engine must never
    /// alias memo entries with its pristine design point.
    memo_fingerprint: u64,
}

impl CostEngine {
    /// Engine for one of the four Table-4 / Fig-7 design points.
    pub fn for_design_point(sys: &SystemConfig, dp: DesignPoint) -> Self {
        let aggressive = matches!(dp.aggr, crate::config::Aggressiveness::Aggressive);
        let collect = MeshNop::new(sys.num_chiplets, sys.collection_bw_per_link, aggressive);
        let dist = match dp.nop {
            NopKind::Interposer => DistFabric::Mesh(MeshNop::new(sys.num_chiplets, dp.distribution_bw(), aggressive)),
            NopKind::Wireless => {
                let trx = if aggressive { TrxDesignPoint::Aggressive } else { TrxDesignPoint::Conservative };
                DistFabric::Wireless(WirelessNop::new(dp.distribution_bw(), trx))
            }
        };
        let mut engine = CostEngine {
            sys: sys.clone(),
            dist,
            collect,
            map_policy: MapPolicy::Flexible,
            hbm: None,
            memo_key: None,
            memo_fingerprint: 0,
        };
        engine.memo_fingerprint = engine.config_fingerprint();
        engine.memo_key = Some(EngineKey::for_system(sys, dp));
        engine
    }

    /// Engine with an idealized distribution fabric at `bw` bytes/cycle
    /// (Fig-3 bandwidth sweep). Not memoized: the swept bandwidth is not
    /// part of the memo key.
    pub fn ideal(sys: &SystemConfig, bw: f64) -> Self {
        let collect = MeshNop::new(sys.num_chiplets, sys.collection_bw_per_link, true);
        CostEngine {
            sys: sys.clone(),
            dist: DistFabric::Ideal { bw },
            collect,
            map_policy: MapPolicy::Flexible,
            hbm: None,
            memo_key: None,
            memo_fingerprint: 0,
        }
    }

    /// Hash of every field that influences a layer's cost. Computed at
    /// construction and re-checked per lookup so post-construction
    /// mutations (ablation benches flip `tree_multicast`, `map_policy`,
    /// `hbm`, …) disable memoization instead of aliasing entries.
    fn config_fingerprint(&self) -> u64 {
        use std::hash::{Hash, Hasher};
        let mut h = std::collections::hash_map::DefaultHasher::new();
        self.sys.num_chiplets.hash(&mut h);
        self.sys.pes_per_chiplet.hash(&mut h);
        self.sys.global_sram_bytes.hash(&mut h);
        self.sys.collection_bw_per_link.to_bits().hash(&mut h);
        self.sys.bytes_per_elem.hash(&mut h);
        match self.map_policy {
            MapPolicy::Flexible => 0u8.hash(&mut h),
            MapPolicy::Fixed { dim0, dim1 } => {
                1u8.hash(&mut h);
                dim0.hash(&mut h);
                dim1.hash(&mut h);
            }
        }
        self.hbm.is_some().hash(&mut h);
        match &self.dist {
            DistFabric::Mesh(m) => {
                0u8.hash(&mut h);
                m.num_chiplets.hash(&mut h);
                m.link_bw.to_bits().hash(&mut h);
                m.hop_energy_pj.to_bits().hash(&mut h);
                m.tree_multicast.hash(&mut h);
            }
            DistFabric::Wireless(w) => {
                1u8.hash(&mut h);
                w.bw.to_bits().hash(&mut h);
                matches!(w.trx, TrxDesignPoint::Aggressive).hash(&mut h);
                w.ber.to_bits().hash(&mut h);
            }
            DistFabric::Ideal { bw } => {
                2u8.hash(&mut h);
                bw.to_bits().hash(&mut h);
            }
        }
        self.collect.num_chiplets.hash(&mut h);
        self.collect.link_bw.to_bits().hash(&mut h);
        self.collect.hop_energy_pj.to_bits().hash(&mut h);
        self.collect.tree_multicast.hash(&mut h);
        h.finish()
    }

    /// The memo-table key, when this engine's evaluations are memoizable:
    /// a design-point engine still in exactly the configuration it was
    /// constructed with. Engines customized after construction (fixed PE
    /// arrays, tree-multicast meshes, HBM ablations) evaluate uncached.
    pub fn memo_key(&self) -> Option<EngineKey> {
        match self.memo_key {
            Some(ek) if self.config_fingerprint() == self.memo_fingerprint => Some(ek),
            _ => None,
        }
    }
}

/// Cost of one layer under one strategy on one design point.
#[derive(Debug, Clone)]
pub struct LayerCost {
    pub layer_name: std::sync::Arc<str>,
    pub layer_type: LayerType,
    pub strategy: Strategy,
    pub used_chiplets: u64,
    /// Fig-6 phase timeline (cycles).
    pub timeline: PhaseTimeline,
    /// End-to-end layer latency in cycles.
    pub latency: f64,
    /// Total layer MACs.
    pub macs: u64,
    /// Achieved throughput in MACs/cycle.
    pub macs_per_cycle: f64,
    /// PE utilization within a used chiplet (steady state).
    pub pe_utilization: f64,
    /// Fraction of package chiplets receiving work.
    pub chiplet_utilization: f64,
    /// Distribution energy (SRAM → chiplets) in pJ.
    pub dist_energy_pj: f64,
    /// Average multicast factor of the distribution phase (Fig 10).
    pub multicast_factor: f64,
    /// Unique distribution payload bytes.
    pub dist_bytes: u64,
    /// Collected output bytes.
    pub collect_bytes: u64,
    /// Per-chiplet local buffer requirement (bytes).
    pub local_buffer_bytes: u64,
    /// HBM staging analysis (populated when the engine has an HBM model).
    pub staging: Option<crate::cost::memory::StagingPlan>,
}

impl LayerCost {
    pub fn bottleneck(&self) -> Phase {
        self.timeline.bottleneck()
    }
}

/// Evaluate one layer under `strategy`, consulting the crate-level memo
/// table (`cost::memo`) when the engine is memoizable: repeated
/// evaluations of the same layer *shape* on the same design point —
/// across models, serve-time batch probes, benches and threads — cost a
/// hash lookup instead of a partition + mapping + NoP walk.
pub fn evaluate_layer(engine: &CostEngine, layer: &Layer, strategy: Strategy) -> LayerCost {
    evaluate_layer_keyed(engine, layer, strategy, engine.memo_key())
}

/// [`evaluate_layer`] with the engine's memo key resolved by the caller.
/// Model-level loops fetch the key (and pay its mutation-detecting
/// fingerprint hash) once instead of per layer; an engine cannot change
/// configuration mid-call while shared borrows of it are live.
fn evaluate_layer_keyed(
    engine: &CostEngine,
    layer: &Layer,
    strategy: Strategy,
    key: Option<EngineKey>,
) -> LayerCost {
    if let Some(ek) = key {
        let sid = crate::cost::memo::intern(layer.shape());
        if let Some(mut hit) = crate::cost::memo::lookup(sid, strategy, ek) {
            // Same shape, possibly a different layer name.
            hit.layer_name = layer.name.clone();
            return hit;
        }
        let cost = evaluate_layer_uncached(engine, layer, strategy);
        crate::cost::memo::insert(sid, strategy, ek, cost.clone());
        return cost;
    }
    evaluate_layer_uncached(engine, layer, strategy)
}

/// Evaluate one layer under `strategy`, bypassing the memo table. The
/// memoized path produces bit-identical results (its entries come from
/// this function); tests use the pair to prove it.
pub fn evaluate_layer_uncached(engine: &CostEngine, layer: &Layer, strategy: Strategy) -> LayerCost {
    let sys = &engine.sys;
    let plan: PartitionPlan = dataflow::partition::partition(layer, strategy, sys.num_chiplets, sys.bytes_per_elem);
    let arch = ChipletArch::for_strategy(strategy);
    let mapping = dataflow::intra::map_layer(&plan.sub_layer, arch, sys.pes_per_chiplet, engine.map_policy, sys.bytes_per_elem);

    let dist = engine.dist.distribution(&plan.traffic);
    let collect_cycles = engine.collect.collection_cycles(plan.collect_bytes);

    // HBM→SRAM staging: when the working set spills the global SRAM the
    // distribution stream cannot outpace the refill rate.
    let staging = engine.hbm.as_ref().map(|h| h.stage(layer, sys.global_sram_bytes, sys.bytes_per_elem));
    let stream_floor = match (&engine.hbm, &staging) {
        (Some(h), Some(p)) => h.stream_bound_cycles(p, plan.sent_bytes()),
        _ => 0.0,
    };

    let timeline = PhaseTimeline {
        preload: dist.preload_cycles,
        stream: dist.stream_cycles.max(stream_floor),
        compute: mapping.cycles as f64,
        collect: collect_cycles,
        fill: dist.fill_latency,
    };
    let latency = timeline.latency();
    let macs = layer.macs();

    LayerCost {
        layer_name: layer.name.clone(),
        layer_type: classify(layer),
        strategy,
        used_chiplets: plan.used_chiplets,
        timeline,
        latency,
        macs,
        // Guard the degenerate zero-latency case (e.g. an empty layer on
        // an ideal fabric) rather than emitting NaN/inf throughput.
        macs_per_cycle: if latency > 0.0 { macs as f64 / latency } else { 0.0 },
        pe_utilization: mapping.utilization,
        chiplet_utilization: plan.used_chiplets as f64 / sys.num_chiplets as f64,
        dist_energy_pj: dist.energy_pj,
        multicast_factor: plan.multicast_factor(),
        dist_bytes: plan.sent_bytes(),
        collect_bytes: plan.collect_bytes,
        local_buffer_bytes: mapping.local_buffer_bytes,
        staging,
    }
}

/// Pick the strategy with the lowest end-to-end layer latency for
/// `layer` (the coordinator's adaptive mode re-uses this). For a single
/// layer minimum latency and maximum throughput coincide only when the
/// MAC count is fixed across strategies — which holds here — but the
/// selection criterion is, and always was, minimum `LayerCost::latency`.
pub fn best_strategy(engine: &CostEngine, layer: &Layer) -> (Strategy, LayerCost) {
    best_strategy_keyed(engine, layer, engine.memo_key())
}

fn best_strategy_keyed(engine: &CostEngine, layer: &Layer, key: Option<EngineKey>) -> (Strategy, LayerCost) {
    Strategy::ALL
        .iter()
        .map(|&s| (s, evaluate_layer_keyed(engine, layer, s, key)))
        .min_by(|a, b| a.1.latency.partial_cmp(&b.1.latency).unwrap())
        .unwrap()
}

/// Whole-model cost under a fixed strategy, or adaptively per layer when
/// `strategy` is `None` (the paper's adaptive partitioning).
#[derive(Debug, Clone)]
pub struct ModelCost {
    pub model_name: String,
    pub layers: Vec<LayerCost>,
    pub total_latency: f64,
    pub total_macs: u64,
    pub macs_per_cycle: f64,
    pub total_dist_energy_pj: f64,
}

pub fn evaluate_model(engine: &CostEngine, model: &Model, strategy: Option<Strategy>) -> ModelCost {
    let key = engine.memo_key();
    let layers: Vec<LayerCost> = model
        .layers
        .iter()
        .map(|l| match strategy {
            Some(s) => evaluate_layer_keyed(engine, l, s, key),
            None => best_strategy_keyed(engine, l, key).1,
        })
        .collect();
    summarize_model(model, layers)
}

/// `evaluate_model` with the per-layer evaluations spread over `threads`
/// worker threads (`cost::par`). Layer costs are independent, and the
/// memo table is shared and thread-safe, so the result is bit-identical
/// to the sequential evaluation — in the same layer order.
pub fn evaluate_model_par(engine: &CostEngine, model: &Model, strategy: Option<Strategy>, threads: usize) -> ModelCost {
    let key = engine.memo_key();
    let layers = crate::cost::par::par_map(model.layers.len(), threads, |i| {
        let l = &model.layers[i];
        match strategy {
            Some(s) => evaluate_layer_keyed(engine, l, s, key),
            None => best_strategy_keyed(engine, l, key).1,
        }
    });
    summarize_model(model, layers)
}

/// Evaluate a whole (design point × model) grid, farming the cells out to
/// `threads` workers. Returns costs in row-major order: all models under
/// `dps[0]`, then all models under `dps[1]`, … This is the Fig-7 / search
/// hot loop: with a warm memo each cell is pure table lookups.
pub fn evaluate_grid(
    sys: &SystemConfig,
    dps: &[DesignPoint],
    models: &[Model],
    strategy: Option<Strategy>,
    threads: usize,
) -> Vec<ModelCost> {
    let n = dps.len() * models.len();
    crate::cost::par::par_map(n, threads, |i| {
        let dp = dps[i / models.len()];
        let model = &models[i % models.len()];
        let engine = CostEngine::for_design_point(sys, dp);
        evaluate_model(&engine, model, strategy)
    })
}

fn summarize_model(model: &Model, layers: Vec<LayerCost>) -> ModelCost {
    let total_latency: f64 = layers.iter().map(|c| c.latency).sum();
    let total_macs: u64 = layers.iter().map(|c| c.macs).sum();
    let total_dist_energy_pj: f64 = layers.iter().map(|c| c.dist_energy_pj).sum();
    ModelCost {
        model_name: model.name.clone(),
        layers,
        total_latency,
        total_macs,
        macs_per_cycle: if total_latency > 0.0 { total_macs as f64 / total_latency } else { 0.0 },
        total_dist_energy_pj,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{resnet50, tiny, unet};

    fn sys() -> SystemConfig {
        SystemConfig::default()
    }

    #[test]
    fn throughput_never_exceeds_peak() {
        let e = CostEngine::for_design_point(&sys(), DesignPoint::WIENNA_A);
        let m = resnet50::resnet50(4);
        for l in &m.layers {
            for s in Strategy::ALL {
                let c = evaluate_layer(&e, l, s);
                assert!(
                    c.macs_per_cycle <= sys().total_pes() as f64 + 1e-6,
                    "{} {s}: {} MACs/cyc",
                    l.name,
                    c.macs_per_cycle
                );
            }
        }
    }

    #[test]
    fn wienna_beats_interposer_at_same_bandwidth() {
        // WIENNA-C and Interposer-A both distribute 16 B/cyc; the wireless
        // broadcast must win end-to-end (paper: 2.58x on ResNet50).
        let m = resnet50::resnet50(64);
        let ec = CostEngine::for_design_point(&sys(), DesignPoint::WIENNA_C);
        let ea = CostEngine::for_design_point(&sys(), DesignPoint::INTERPOSER_A);
        let w = evaluate_model(&ec, &m, None);
        let i = evaluate_model(&ea, &m, None);
        let ratio = w.macs_per_cycle / i.macs_per_cycle;
        assert!(ratio > 1.5, "expected >1.5x, got {ratio:.2}x");
        assert!(ratio < 8.0, "expected <8x, got {ratio:.2}x");
    }

    #[test]
    fn adaptive_at_least_as_good_as_any_fixed() {
        let m = unet::unet(16);
        let e = CostEngine::for_design_point(&sys(), DesignPoint::WIENNA_C);
        let adaptive = evaluate_model(&e, &m, None);
        for s in Strategy::ALL {
            let fixed = evaluate_model(&e, &m, Some(s));
            assert!(
                adaptive.total_latency <= fixed.total_latency + 1e-6,
                "adaptive worse than {s}"
            );
        }
    }

    #[test]
    fn ideal_fabric_saturates_with_bandwidth() {
        // Fig-3 mechanics: throughput grows with BW then saturates.
        let m = tiny::tiny_cnn(8);
        let lo = evaluate_model(&CostEngine::ideal(&sys(), 4.0), &m, Some(Strategy::KpCp));
        let hi = evaluate_model(&CostEngine::ideal(&sys(), 4096.0), &m, Some(Strategy::KpCp));
        let higher = evaluate_model(&CostEngine::ideal(&sys(), 8192.0), &m, Some(Strategy::KpCp));
        assert!(hi.macs_per_cycle > lo.macs_per_cycle);
        // Saturation: doubling an already-huge bandwidth barely helps.
        assert!((higher.macs_per_cycle - hi.macs_per_cycle) / hi.macs_per_cycle < 0.01);
    }

    #[test]
    fn energy_positive_and_wireless_cheaper_on_broadcast_heavy_layer() {
        // High-res conv: KP-CP broadcasts the (large) input.
        let l = crate::workload::conv_padded("hr", 1, 64, 64, 56, 56, 3, 3, 1);
        let ew = CostEngine::for_design_point(&sys(), DesignPoint::WIENNA_C);
        let ei = CostEngine::for_design_point(&sys(), DesignPoint::INTERPOSER_A);
        let cw = evaluate_layer(&ew, &l, Strategy::KpCp);
        let ci = evaluate_layer(&ei, &l, Strategy::KpCp);
        assert!(cw.dist_energy_pj > 0.0 && ci.dist_energy_pj > 0.0);
        assert!(cw.dist_energy_pj < ci.dist_energy_pj);
    }

    #[test]
    fn best_strategy_varies_by_layer_type() {
        // Observation I: high-res layers favor YP-XP, low-res/FC favor
        // KP-CP (under an ideal fabric with moderate bandwidth).
        let e = CostEngine::ideal(&sys(), 64.0);
        let hi = crate::workload::conv_padded("hr", 1, 64, 64, 112, 112, 3, 3, 1);
        let (s_hi, _) = best_strategy(&e, &hi);
        let fc = Layer::fc("fc", 1, 1000, 2048);
        let (s_fc, _) = best_strategy(&e, &fc);
        assert_eq!(s_hi, Strategy::YpXp, "high-res should favor YP-XP");
        assert_eq!(s_fc, Strategy::KpCp, "FC should favor KP-CP");
    }

    #[test]
    fn memoized_matches_uncached_and_adopts_names() {
        let e = CostEngine::for_design_point(&sys(), DesignPoint::WIENNA_C);
        let a = crate::workload::conv_padded("first", 4, 64, 64, 28, 28, 3, 3, 1);
        let b = crate::workload::conv_padded("second", 4, 64, 64, 28, 28, 3, 3, 1);
        for s in Strategy::ALL {
            let direct = evaluate_layer_uncached(&e, &a, s);
            let cached_a = evaluate_layer(&e, &a, s);
            let cached_b = evaluate_layer(&e, &b, s); // same shape, other name
            assert_eq!(direct.latency, cached_a.latency, "{s}");
            assert_eq!(direct.timeline, cached_a.timeline, "{s}");
            assert_eq!(cached_a.latency, cached_b.latency, "{s}");
            assert_eq!(&*cached_a.layer_name, "first");
            assert_eq!(&*cached_b.layer_name, "second");
        }
    }

    #[test]
    fn ideal_and_mutated_engines_are_not_memoized() {
        let ideal = CostEngine::ideal(&sys(), 64.0);
        assert!(ideal.memo_key().is_none());
        let mut hbm = CostEngine::for_design_point(&sys(), DesignPoint::WIENNA_C);
        assert!(hbm.memo_key().is_some());
        hbm.hbm = Some(crate::cost::memory::HbmModel::default());
        assert!(hbm.memo_key().is_none());
        let mut fixed = CostEngine::for_design_point(&sys(), DesignPoint::WIENNA_C);
        fixed.map_policy = MapPolicy::Fixed { dim0: 8, dim1: 8 };
        assert!(fixed.memo_key().is_none());
        // The A1 ablation flips the mesh's multicast capability on a
        // cloned engine — it must drop out of the memo, not alias it.
        let mut tree = CostEngine::for_design_point(&sys(), DesignPoint::INTERPOSER_A);
        assert!(tree.memo_key().is_some());
        if let DistFabric::Mesh(mesh) = &mut tree.dist {
            mesh.tree_multicast = true;
        }
        assert!(tree.memo_key().is_none());
    }

    #[test]
    fn parallel_model_eval_matches_sequential_exactly() {
        let e = CostEngine::for_design_point(&sys(), DesignPoint::WIENNA_A);
        let m = resnet50::resnet50(8);
        let seq = evaluate_model(&e, &m, None);
        for threads in [1, 2, 4] {
            let par = evaluate_model_par(&e, &m, None, threads);
            assert_eq!(seq.total_latency, par.total_latency, "{threads} threads");
            assert_eq!(seq.layers.len(), par.layers.len());
            for (a, b) in seq.layers.iter().zip(&par.layers) {
                assert_eq!(a.layer_name, b.layer_name);
                assert_eq!(a.latency, b.latency);
                assert_eq!(a.strategy, b.strategy);
            }
        }
    }

    #[test]
    fn grid_matches_per_design_evaluation() {
        let models = [tiny::tiny_cnn(4), unet::unet(2)];
        let grid = evaluate_grid(&sys(), &DesignPoint::ALL, &models, None, 2);
        assert_eq!(grid.len(), DesignPoint::ALL.len() * models.len());
        for (i, dp) in DesignPoint::ALL.iter().enumerate() {
            for (j, m) in models.iter().enumerate() {
                let direct = evaluate_model(&CostEngine::for_design_point(&sys(), *dp), m, None);
                let cell = &grid[i * models.len() + j];
                assert_eq!(cell.total_latency, direct.total_latency, "{} {}", dp.label(), m.name);
            }
        }
    }

    #[test]
    fn model_cost_sums_layers() {
        let m = tiny::tiny_cnn(2);
        let e = CostEngine::for_design_point(&sys(), DesignPoint::WIENNA_C);
        let mc = evaluate_model(&e, &m, Some(Strategy::KpCp));
        assert_eq!(mc.layers.len(), m.layers.len());
        let sum: f64 = mc.layers.iter().map(|l| l.latency).sum();
        assert!((sum - mc.total_latency).abs() < 1e-9);
        assert_eq!(mc.total_macs, m.total_macs());
    }
}
