//! Per-layer and per-model cost evaluation (the MAESTRO-like engine).

use crate::config::{DesignPoint, SystemConfig};
use crate::dataflow::{self, ChipletArch, MapPolicy, PartitionPlan, Strategy};
use crate::nop::{DistributionCost, MeshNop, NopKind, TrxDesignPoint, WirelessNop};
use crate::workload::{classify, Layer, LayerType, Model};
use crate::cost::phase::{Phase, PhaseTimeline};

/// Distribution fabric alternatives the engine can evaluate.
#[derive(Debug, Clone)]
pub enum DistFabric {
    Mesh(MeshNop),
    Wireless(WirelessNop),
    /// Idealized fabric used by the Fig-3 motivation study: unique bytes
    /// at a swept SRAM read bandwidth, free multicast, no hop latency.
    Ideal { bw: f64 },
}

impl DistFabric {
    pub fn distribution(&self, traffic: &[dataflow::TrafficClass]) -> DistributionCost {
        match self {
            DistFabric::Mesh(m) => m.distribution(traffic),
            DistFabric::Wireless(w) => w.distribution(traffic),
            DistFabric::Ideal { bw } => {
                let mut c = DistributionCost::default();
                for t in traffic {
                    let cycles = t.bytes as f64 / bw;
                    if t.streamed {
                        c.stream_cycles += cycles;
                    } else {
                        c.preload_cycles += cycles;
                    }
                }
                c
            }
        }
    }
}

/// Fully-configured cost engine: package, NoP pair, mapping policy.
#[derive(Debug, Clone)]
pub struct CostEngine {
    pub sys: SystemConfig,
    pub dist: DistFabric,
    /// Wired mesh used for collection in *all* designs (paper §4).
    pub collect: MeshNop,
    pub map_policy: MapPolicy,
    /// Optional HBM→SRAM staging model. `None` (default) reproduces the
    /// paper's assumption that distribution is SRAM-fed; `Some` bounds
    /// the stream by the HBM refill rate when a layer's working set
    /// spills the global SRAM (see `cost::memory`, ablation bench).
    pub hbm: Option<crate::cost::memory::HbmModel>,
}

impl CostEngine {
    /// Engine for one of the four Table-4 / Fig-7 design points.
    pub fn for_design_point(sys: &SystemConfig, dp: DesignPoint) -> Self {
        let aggressive = matches!(dp.aggr, crate::config::Aggressiveness::Aggressive);
        let collect = MeshNop::new(sys.num_chiplets, sys.collection_bw_per_link, aggressive);
        let dist = match dp.nop {
            NopKind::Interposer => DistFabric::Mesh(MeshNop::new(sys.num_chiplets, dp.distribution_bw(), aggressive)),
            NopKind::Wireless => {
                let trx = if aggressive { TrxDesignPoint::Aggressive } else { TrxDesignPoint::Conservative };
                DistFabric::Wireless(WirelessNop::new(dp.distribution_bw(), trx))
            }
        };
        CostEngine { sys: sys.clone(), dist, collect, map_policy: MapPolicy::Flexible, hbm: None }
    }

    /// Engine with an idealized distribution fabric at `bw` bytes/cycle
    /// (Fig-3 bandwidth sweep).
    pub fn ideal(sys: &SystemConfig, bw: f64) -> Self {
        let collect = MeshNop::new(sys.num_chiplets, sys.collection_bw_per_link, true);
        CostEngine { sys: sys.clone(), dist: DistFabric::Ideal { bw }, collect, map_policy: MapPolicy::Flexible, hbm: None }
    }
}

/// Cost of one layer under one strategy on one design point.
#[derive(Debug, Clone)]
pub struct LayerCost {
    pub layer_name: String,
    pub layer_type: LayerType,
    pub strategy: Strategy,
    pub used_chiplets: u64,
    /// Fig-6 phase timeline (cycles).
    pub timeline: PhaseTimeline,
    /// End-to-end layer latency in cycles.
    pub latency: f64,
    /// Total layer MACs.
    pub macs: u64,
    /// Achieved throughput in MACs/cycle.
    pub macs_per_cycle: f64,
    /// PE utilization within a used chiplet (steady state).
    pub pe_utilization: f64,
    /// Fraction of package chiplets receiving work.
    pub chiplet_utilization: f64,
    /// Distribution energy (SRAM → chiplets) in pJ.
    pub dist_energy_pj: f64,
    /// Average multicast factor of the distribution phase (Fig 10).
    pub multicast_factor: f64,
    /// Unique distribution payload bytes.
    pub dist_bytes: u64,
    /// Collected output bytes.
    pub collect_bytes: u64,
    /// Per-chiplet local buffer requirement (bytes).
    pub local_buffer_bytes: u64,
    /// HBM staging analysis (populated when the engine has an HBM model).
    pub staging: Option<crate::cost::memory::StagingPlan>,
}

impl LayerCost {
    pub fn bottleneck(&self) -> Phase {
        self.timeline.bottleneck()
    }
}

/// Evaluate one layer under `strategy`.
pub fn evaluate_layer(engine: &CostEngine, layer: &Layer, strategy: Strategy) -> LayerCost {
    let sys = &engine.sys;
    let plan: PartitionPlan = dataflow::partition::partition(layer, strategy, sys.num_chiplets, sys.bytes_per_elem);
    let arch = ChipletArch::for_strategy(strategy);
    let mapping = dataflow::intra::map_layer(&plan.sub_layer, arch, sys.pes_per_chiplet, engine.map_policy, sys.bytes_per_elem);

    let dist = engine.dist.distribution(&plan.traffic);
    let collect_cycles = engine.collect.collection_cycles(plan.collect_bytes);

    // HBM→SRAM staging: when the working set spills the global SRAM the
    // distribution stream cannot outpace the refill rate.
    let staging = engine.hbm.as_ref().map(|h| h.stage(layer, sys.global_sram_bytes, sys.bytes_per_elem));
    let stream_floor = match (&engine.hbm, &staging) {
        (Some(h), Some(p)) => h.stream_bound_cycles(p, plan.sent_bytes()),
        _ => 0.0,
    };

    let timeline = PhaseTimeline {
        preload: dist.preload_cycles,
        stream: dist.stream_cycles.max(stream_floor),
        compute: mapping.cycles as f64,
        collect: collect_cycles,
        fill: dist.fill_latency,
    };
    let latency = timeline.latency();
    let macs = layer.macs();

    LayerCost {
        layer_name: layer.name.clone(),
        layer_type: classify(layer),
        strategy,
        used_chiplets: plan.used_chiplets,
        timeline,
        latency,
        macs,
        macs_per_cycle: macs as f64 / latency,
        pe_utilization: mapping.utilization,
        chiplet_utilization: plan.used_chiplets as f64 / sys.num_chiplets as f64,
        dist_energy_pj: dist.energy_pj,
        multicast_factor: plan.multicast_factor(),
        dist_bytes: plan.sent_bytes(),
        collect_bytes: plan.collect_bytes,
        local_buffer_bytes: mapping.local_buffer_bytes,
        staging,
    }
}

/// Pick the strategy with the highest throughput for `layer` (the
/// coordinator's adaptive mode re-uses this).
pub fn best_strategy(engine: &CostEngine, layer: &Layer) -> (Strategy, LayerCost) {
    Strategy::ALL
        .iter()
        .map(|&s| (s, evaluate_layer(engine, layer, s)))
        .min_by(|a, b| a.1.latency.partial_cmp(&b.1.latency).unwrap())
        .unwrap()
}

/// Whole-model cost under a fixed strategy, or adaptively per layer when
/// `strategy` is `None` (the paper's adaptive partitioning).
#[derive(Debug, Clone)]
pub struct ModelCost {
    pub model_name: String,
    pub layers: Vec<LayerCost>,
    pub total_latency: f64,
    pub total_macs: u64,
    pub macs_per_cycle: f64,
    pub total_dist_energy_pj: f64,
}

pub fn evaluate_model(engine: &CostEngine, model: &Model, strategy: Option<Strategy>) -> ModelCost {
    let layers: Vec<LayerCost> = model
        .layers
        .iter()
        .map(|l| match strategy {
            Some(s) => evaluate_layer(engine, l, s),
            None => best_strategy(engine, l).1,
        })
        .collect();
    let total_latency: f64 = layers.iter().map(|c| c.latency).sum();
    let total_macs: u64 = layers.iter().map(|c| c.macs).sum();
    let total_dist_energy_pj: f64 = layers.iter().map(|c| c.dist_energy_pj).sum();
    ModelCost {
        model_name: model.name.clone(),
        layers,
        total_latency,
        total_macs,
        macs_per_cycle: total_macs as f64 / total_latency,
        total_dist_energy_pj,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{resnet50, tiny, unet};

    fn sys() -> SystemConfig {
        SystemConfig::default()
    }

    #[test]
    fn throughput_never_exceeds_peak() {
        let e = CostEngine::for_design_point(&sys(), DesignPoint::WIENNA_A);
        let m = resnet50::resnet50(4);
        for l in &m.layers {
            for s in Strategy::ALL {
                let c = evaluate_layer(&e, l, s);
                assert!(
                    c.macs_per_cycle <= sys().total_pes() as f64 + 1e-6,
                    "{} {s}: {} MACs/cyc",
                    l.name,
                    c.macs_per_cycle
                );
            }
        }
    }

    #[test]
    fn wienna_beats_interposer_at_same_bandwidth() {
        // WIENNA-C and Interposer-A both distribute 16 B/cyc; the wireless
        // broadcast must win end-to-end (paper: 2.58x on ResNet50).
        let m = resnet50::resnet50(64);
        let ec = CostEngine::for_design_point(&sys(), DesignPoint::WIENNA_C);
        let ea = CostEngine::for_design_point(&sys(), DesignPoint::INTERPOSER_A);
        let w = evaluate_model(&ec, &m, None);
        let i = evaluate_model(&ea, &m, None);
        let ratio = w.macs_per_cycle / i.macs_per_cycle;
        assert!(ratio > 1.5, "expected >1.5x, got {ratio:.2}x");
        assert!(ratio < 8.0, "expected <8x, got {ratio:.2}x");
    }

    #[test]
    fn adaptive_at_least_as_good_as_any_fixed() {
        let m = unet::unet(16);
        let e = CostEngine::for_design_point(&sys(), DesignPoint::WIENNA_C);
        let adaptive = evaluate_model(&e, &m, None);
        for s in Strategy::ALL {
            let fixed = evaluate_model(&e, &m, Some(s));
            assert!(
                adaptive.total_latency <= fixed.total_latency + 1e-6,
                "adaptive worse than {s}"
            );
        }
    }

    #[test]
    fn ideal_fabric_saturates_with_bandwidth() {
        // Fig-3 mechanics: throughput grows with BW then saturates.
        let m = tiny::tiny_cnn(8);
        let lo = evaluate_model(&CostEngine::ideal(&sys(), 4.0), &m, Some(Strategy::KpCp));
        let hi = evaluate_model(&CostEngine::ideal(&sys(), 4096.0), &m, Some(Strategy::KpCp));
        let higher = evaluate_model(&CostEngine::ideal(&sys(), 8192.0), &m, Some(Strategy::KpCp));
        assert!(hi.macs_per_cycle > lo.macs_per_cycle);
        // Saturation: doubling an already-huge bandwidth barely helps.
        assert!((higher.macs_per_cycle - hi.macs_per_cycle) / hi.macs_per_cycle < 0.01);
    }

    #[test]
    fn energy_positive_and_wireless_cheaper_on_broadcast_heavy_layer() {
        // High-res conv: KP-CP broadcasts the (large) input.
        let l = crate::workload::conv_padded("hr", 1, 64, 64, 56, 56, 3, 3, 1);
        let ew = CostEngine::for_design_point(&sys(), DesignPoint::WIENNA_C);
        let ei = CostEngine::for_design_point(&sys(), DesignPoint::INTERPOSER_A);
        let cw = evaluate_layer(&ew, &l, Strategy::KpCp);
        let ci = evaluate_layer(&ei, &l, Strategy::KpCp);
        assert!(cw.dist_energy_pj > 0.0 && ci.dist_energy_pj > 0.0);
        assert!(cw.dist_energy_pj < ci.dist_energy_pj);
    }

    #[test]
    fn best_strategy_varies_by_layer_type() {
        // Observation I: high-res layers favor YP-XP, low-res/FC favor
        // KP-CP (under an ideal fabric with moderate bandwidth).
        let e = CostEngine::ideal(&sys(), 64.0);
        let hi = crate::workload::conv_padded("hr", 1, 64, 64, 112, 112, 3, 3, 1);
        let (s_hi, _) = best_strategy(&e, &hi);
        let fc = Layer::fc("fc", 1, 1000, 2048);
        let (s_fc, _) = best_strategy(&e, &fc);
        assert_eq!(s_hi, Strategy::YpXp, "high-res should favor YP-XP");
        assert_eq!(s_fc, Strategy::KpCp, "FC should favor KP-CP");
    }

    #[test]
    fn model_cost_sums_layers() {
        let m = tiny::tiny_cnn(2);
        let e = CostEngine::for_design_point(&sys(), DesignPoint::WIENNA_C);
        let mc = evaluate_model(&e, &m, Some(Strategy::KpCp));
        assert_eq!(mc.layers.len(), m.layers.len());
        let sum: f64 = mc.layers.iter().map(|l| l.latency).sum();
        assert!((sum - mc.total_latency).abs() < 1e-9);
        assert_eq!(mc.total_macs, m.total_macs());
    }
}
