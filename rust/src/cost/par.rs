//! Zero-dependency scoped worker pool for embarrassingly parallel cost
//! evaluations.
//!
//! The build is offline (no `rayon`), so this is a minimal work-stealing
//! fan-out on `std::thread::scope`: workers pull indices from a shared
//! atomic cursor, which load-balances the wildly uneven per-item costs of
//! (layer, strategy) and (design point, model) evaluations. Results come
//! back in input order, so parallel callers are drop-in replacements for
//! their sequential counterparts (`evaluate_model_par`, `evaluate_grid`,
//! `search::autosize`).

use std::sync::atomic::{AtomicUsize, Ordering};

/// Worker count to use when the caller has no opinion: the machine's
/// available parallelism.
pub fn num_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Map `f` over `0..n` on up to `threads` scoped workers; results are
/// returned in index order. `threads <= 1` (or `n <= 1`) degrades to a
/// plain sequential loop with no thread spawned.
///
/// Panics in `f` propagate to the caller after all workers stop.
pub fn par_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 {
        return (0..n).map(f).collect();
    }
    let cursor = AtomicUsize::new(0);
    let parts: Vec<Vec<(usize, T)>> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..threads)
            .map(|_| {
                scope.spawn(|| {
                    let mut acc = Vec::new();
                    loop {
                        let i = cursor.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        acc.push((i, f(i)));
                    }
                    acc
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("cost::par worker panicked")).collect()
    });
    let mut out: Vec<Option<T>> = Vec::with_capacity(n);
    out.resize_with(n, || None);
    for part in parts {
        for (i, v) in part {
            debug_assert!(out[i].is_none(), "index {i} produced twice");
            out[i] = Some(v);
        }
    }
    out.into_iter().map(|o| o.expect("every index produced exactly once")).collect()
}

/// [`par_map`] over the items of a slice.
pub fn par_map_slice<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    par_map(items.len(), threads, |i| f(&items[i]))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order_and_covers_every_index() {
        for threads in [1, 2, 3, 8] {
            let out = par_map(100, threads, |i| i * i);
            assert_eq!(out.len(), 100);
            for (i, v) in out.iter().enumerate() {
                assert_eq!(*v, i * i, "{threads} threads");
            }
        }
    }

    #[test]
    fn empty_and_single_inputs() {
        assert!(par_map(0, 4, |i| i).is_empty());
        assert_eq!(par_map(1, 4, |i| i + 7), vec![7]);
    }

    #[test]
    fn slice_variant_borrows_items() {
        let items = vec!["a".to_string(), "bb".to_string(), "ccc".to_string()];
        let lens = par_map_slice(&items, 2, |s| s.len());
        assert_eq!(lens, vec![1, 2, 3]);
    }

    #[test]
    fn uneven_work_is_balanced() {
        // Nothing to assert beyond completion + order: the cursor-based
        // pull loop cannot deadlock and must terminate.
        let out = par_map(64, 4, |i| {
            let mut acc = 0u64;
            for k in 0..(i as u64 % 7) * 1000 {
                acc = acc.wrapping_add(k);
            }
            (i, acc)
        });
        assert_eq!(out.len(), 64);
        assert!(out.iter().enumerate().all(|(i, (j, _))| i == *j));
    }
}
