//! Memory-hierarchy substrate: HBM → global-SRAM staging (Fig 5's left
//! edge).
//!
//! The paper's package has an HBM stack feeding the 13 MiB global SRAM
//! chiplet, which in turn distributes to the chiplets. The evaluation
//! assumes distribution is the bottleneck, which holds while a layer's
//! working set fits the (double-buffered) SRAM; larger layers must be
//! staged from HBM in passes, and when the required staging rate exceeds
//! the HBM bandwidth the *memory* side becomes the critical path.
//!
//! This module makes that explicit so the cost engine can (a) bound the
//! distribution stream by the achievable SRAM refill rate and (b) report
//! which layers spill.

use crate::workload::Layer;

/// HBM interface model.
#[derive(Debug, Clone)]
pub struct HbmModel {
    /// Sustained HBM read bandwidth in bytes/cycle at the system clock.
    /// An HBM2 stack at ~256 GB/s and 500 MHz is ~512 B/cyc; we default
    /// conservatively to one pseudo-channel's worth.
    pub bw_bytes_per_cycle: f64,
    /// Access granularity in bytes (row-buffer burst).
    pub burst_bytes: u64,
    /// Energy per bit moved from HBM, in pJ (≈3.9 pJ/bit for HBM2).
    pub pj_per_bit: f64,
}

impl Default for HbmModel {
    fn default() -> Self {
        HbmModel { bw_bytes_per_cycle: 64.0, burst_bytes: 256, pj_per_bit: 3.9 }
    }
}

/// Staging analysis of one layer against the SRAM capacity.
#[derive(Debug, Clone, PartialEq)]
pub struct StagingPlan {
    /// Bytes that must transit HBM→SRAM for the layer (first touch of
    /// weights + inputs; outputs write back).
    pub staged_bytes: u64,
    /// Whether the full distribution working set is SRAM-resident.
    pub resident: bool,
    /// Number of staging passes through the (double-buffered) SRAM.
    pub passes: u64,
    /// Cycles the HBM needs to stage the layer.
    pub hbm_cycles: f64,
    /// HBM energy in pJ.
    pub hbm_energy_pj: f64,
}

impl HbmModel {
    /// Analyze `layer` against an SRAM of `sram_bytes`, double-buffered
    /// (half the capacity holds the active working set while the other
    /// half stages the next tile).
    pub fn stage(&self, layer: &Layer, sram_bytes: u64, bytes_per_elem: u64) -> StagingPlan {
        let ws = (layer.input_elems() + layer.weight_elems()) * bytes_per_elem;
        let out = layer.output_elems() * bytes_per_elem;
        let staged = ws + out; // inputs+weights read, outputs written back
        let usable = (sram_bytes / 2).max(1);
        let resident = ws <= usable;
        let passes = ws.div_ceil(usable).max(1);
        // Burst-align the HBM traffic.
        let bursts = staged.div_ceil(self.burst_bytes);
        let bytes_moved = bursts * self.burst_bytes;
        StagingPlan {
            staged_bytes: staged,
            resident,
            passes,
            hbm_cycles: bytes_moved as f64 / self.bw_bytes_per_cycle,
            hbm_energy_pj: bytes_moved as f64 * 8.0 * self.pj_per_bit,
        }
    }

    /// Effective distribution stream bound: the SRAM cannot distribute
    /// faster than HBM refills it once the working set spills.
    pub fn stream_bound_cycles(&self, plan: &StagingPlan, dist_bytes: u64) -> f64 {
        if plan.resident {
            0.0
        } else {
            // The distribution stream and the HBM refill proceed in
            // lockstep; the refill of the *distributed* bytes bounds it.
            dist_bytes as f64 / self.bw_bytes_per_cycle
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{conv_padded, Layer};

    #[test]
    fn small_layer_is_resident() {
        let hbm = HbmModel::default();
        let l = conv_padded("s", 1, 32, 16, 16, 16, 3, 3, 1);
        let p = hbm.stage(&l, 13 * 1024 * 1024, 1);
        assert!(p.resident);
        assert_eq!(p.passes, 1);
    }

    #[test]
    fn large_layer_spills_and_needs_passes() {
        let hbm = HbmModel::default();
        // conv1 of ResNet-50 at batch 64: ~10 MB of inputs.
        let l = conv_padded("conv1", 64, 64, 3, 224, 224, 7, 7, 2);
        let p = hbm.stage(&l, 13 * 1024 * 1024, 1);
        assert!(!p.resident);
        assert!(p.passes >= 2, "passes {}", p.passes);
        assert!(p.hbm_cycles > 0.0);
    }

    #[test]
    fn stream_bound_zero_when_resident() {
        let hbm = HbmModel::default();
        let l = Layer::fc("fc", 1, 100, 100);
        let p = hbm.stage(&l, 13 * 1024 * 1024, 1);
        assert_eq!(hbm.stream_bound_cycles(&p, 10_000), 0.0);
    }

    #[test]
    fn stream_bound_kicks_in_on_spill() {
        let hbm = HbmModel::default();
        let l = conv_padded("big", 64, 64, 3, 224, 224, 7, 7, 2);
        let p = hbm.stage(&l, 13 * 1024 * 1024, 1);
        let bound = hbm.stream_bound_cycles(&p, 1_000_000);
        assert!((bound - 1_000_000.0 / 64.0).abs() < 1e-9);
    }

    #[test]
    fn burst_alignment_rounds_up() {
        let hbm = HbmModel::default();
        let l = Layer::fc("fc", 1, 3, 3); // tiny: 9 weights + 3 in + 3 out
        let p = hbm.stage(&l, 1 << 20, 1);
        // One 256-byte burst minimum.
        assert!(p.hbm_cycles >= 256.0 / hbm.bw_bytes_per_cycle);
    }

    #[test]
    fn energy_proportional_to_bytes() {
        let hbm = HbmModel::default();
        let small = hbm.stage(&Layer::fc("a", 1, 64, 64), 1 << 20, 1);
        let large = hbm.stage(&Layer::fc("b", 1, 640, 640), 1 << 20, 1);
        assert!(large.hbm_energy_pj > small.hbm_energy_pj * 10.0);
    }
}
