//! Crate-level layer-cost memo table.
//!
//! Layer shapes repeat everywhere: within a model (ResNet's residual
//! stages), across batch sizes probed by the serve-time batcher, across
//! the design-point grid of the Fig-7 sweep, and massively across the
//! `search::autosize` design-space exploration. The memo table caches one
//! [`LayerCost`] per `(shape, strategy, engine)` so all of those callers
//! — `evaluate_model`, the serve `CostCache`, the benches, and every
//! worker thread of `cost::par` — share each cold evaluation.
//!
//! Shapes are interned to a dense [`ShapeId`] first, so the (much hotter)
//! memo lookup hashes a 4-byte id plus the small engine key instead of
//! ten `u64` loop bounds.
//!
//! The table is process-global, append-only and thread-safe (`RwLock`
//! around a `HashMap`; reads dominate). Entries are deterministic pure
//! functions of their key, so a racing double-insert is harmless — both
//! writers computed bit-identical values.

use crate::cost::model::{EngineKey, LayerCost};
use crate::dataflow::Strategy;
use crate::workload::LayerShape;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{OnceLock, RwLock};

/// Dense id of an interned [`LayerShape`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ShapeId(u32);

fn interner() -> &'static RwLock<HashMap<LayerShape, u32>> {
    static INTERNER: OnceLock<RwLock<HashMap<LayerShape, u32>>> = OnceLock::new();
    INTERNER.get_or_init(|| RwLock::new(HashMap::new()))
}

/// Intern `shape`, returning its stable dense id. Idempotent; the id
/// space only grows (a few hundred distinct shapes even across a large
/// design-space search).
pub fn intern(shape: LayerShape) -> ShapeId {
    let lock = interner();
    if let Some(&id) = lock.read().expect("interner lock").get(&shape) {
        return ShapeId(id);
    }
    let mut map = lock.write().expect("interner lock");
    let next = map.len() as u32;
    ShapeId(*map.entry(shape).or_insert(next))
}

/// Number of distinct shapes interned so far.
pub fn interned_shapes() -> usize {
    interner().read().expect("interner lock").len()
}

type MemoKey = (ShapeId, Strategy, EngineKey);

fn table() -> &'static RwLock<HashMap<MemoKey, LayerCost>> {
    static TABLE: OnceLock<RwLock<HashMap<MemoKey, LayerCost>>> = OnceLock::new();
    TABLE.get_or_init(|| RwLock::new(HashMap::new()))
}

static HITS: AtomicU64 = AtomicU64::new(0);
static MISSES: AtomicU64 = AtomicU64::new(0);

/// Fetch the memoized cost of `(shape, strategy, engine)`, if present.
pub fn lookup(shape: ShapeId, strategy: Strategy, engine: EngineKey) -> Option<LayerCost> {
    let hit = table().read().expect("memo lock").get(&(shape, strategy, engine)).cloned();
    match hit {
        Some(c) => {
            HITS.fetch_add(1, Ordering::Relaxed);
            Some(c)
        }
        None => {
            MISSES.fetch_add(1, Ordering::Relaxed);
            None
        }
    }
}

/// Record the cost of `(shape, strategy, engine)`. Last writer wins;
/// racing writers computed identical values (see module docs).
pub fn insert(shape: ShapeId, strategy: Strategy, engine: EngineKey, cost: LayerCost) {
    table().write().expect("memo lock").insert((shape, strategy, engine), cost);
}

/// Snapshot of the memo table's accounting.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MemoStats {
    pub hits: u64,
    pub misses: u64,
    pub entries: usize,
}

impl MemoStats {
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

pub fn stats() -> MemoStats {
    MemoStats {
        hits: HITS.load(Ordering::Relaxed),
        misses: MISSES.load(Ordering::Relaxed),
        entries: table().read().expect("memo lock").len(),
    }
}

/// Drop every cached cost and reset the hit/miss counters (the interner
/// keeps its ids — they stay valid). Benches call this to time cold
/// evaluations honestly.
pub fn clear() {
    table().write().expect("memo lock").clear();
    HITS.store(0, Ordering::Relaxed);
    MISSES.store(0, Ordering::Relaxed);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::Layer;

    #[test]
    fn intern_is_idempotent_and_distinguishes_shapes() {
        let a = Layer::conv("a", 1, 8, 8, 12, 12, 3, 3, 1).shape();
        let b = Layer::conv("b", 1, 8, 8, 12, 12, 3, 3, 1).shape();
        let c = Layer::fc("c", 1, 8, 8).shape();
        assert_eq!(intern(a), intern(b));
        assert_ne!(intern(a), intern(c));
        assert_eq!(intern(a), intern(a));
    }

    #[test]
    fn stats_track_hits_and_misses() {
        // Other tests share the process-global table, so assert deltas on
        // a key no other test uses.
        let shape = Layer::conv("memo_stats_probe", 3, 7, 11, 13, 13, 3, 3, 1).shape();
        let sid = intern(shape);
        let ek = crate::cost::CostEngine::for_design_point(
            &crate::config::SystemConfig { num_chiplets: 4, pes_per_chiplet: 16, ..Default::default() },
            crate::config::DesignPoint::WIENNA_C,
        )
        .memo_key()
        .expect("design-point engines are memoizable");
        let before = stats();
        assert!(lookup(sid, Strategy::KpCp, ek).is_none());
        let engine = crate::cost::CostEngine::for_design_point(
            &crate::config::SystemConfig { num_chiplets: 4, pes_per_chiplet: 16, ..Default::default() },
            crate::config::DesignPoint::WIENNA_C,
        );
        let layer = Layer::conv("memo_stats_probe", 3, 7, 11, 13, 13, 3, 3, 1);
        let cost = crate::cost::evaluate_layer_uncached(&engine, &layer, Strategy::KpCp);
        insert(sid, Strategy::KpCp, ek, cost.clone());
        let hit = lookup(sid, Strategy::KpCp, ek).expect("inserted");
        assert_eq!(hit.latency, cost.latency);
        let after = stats();
        assert!(after.misses >= before.misses + 1);
        assert!(after.hits >= before.hits + 1);
        assert!(after.entries >= 1);
    }
}
