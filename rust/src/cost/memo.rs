//! Crate-level layer-cost memo table.
//!
//! Layer shapes repeat everywhere: within a model (ResNet's residual
//! stages), across batch sizes probed by the serve-time batcher, across
//! the design-point grid of the Fig-7 sweep, and massively across the
//! `search::autosize` design-space exploration. The memo table caches one
//! [`LayerCost`] per `(shape, strategy, engine)` so all of those callers
//! — `evaluate_model`, the serve `CostCache`, the benches, and every
//! worker thread of `cost::par` — share each cold evaluation.
//!
//! Shapes are interned to a dense [`ShapeId`] first, so the (much hotter)
//! memo lookup hashes a 4-byte id plus the small engine key instead of
//! ten `u64` loop bounds.
//!
//! The table is process-global and thread-safe (`RwLock` around a
//! `HashMap`; reads dominate). Entries are deterministic pure functions
//! of their key, so a racing double-insert is harmless — both writers
//! computed bit-identical values — and *eviction never changes results*,
//! only whether a value is recomputed.
//!
//! Growth is bounded two ways (ROADMAP item — long-lived serving
//! simulations must not grow the memo without limit):
//!
//! * a **size-capped LRU**: inserts past [`capacity`] evict the
//!   least-recently-used slice of the table (recency is tracked with a
//!   relaxed atomic tick, so reads stay read-locked);
//! * a **per-run scope guard**: [`run_scope`] returns an RAII guard that,
//!   on drop, removes every entry inserted after its creation — long-
//!   lived processes that run many simulations (the `cluster_scale`
//!   bench, embedding hosts) wrap each run in one so no run's working
//!   set outlives it. (One-shot CLI invocations don't need a guard; the
//!   table dies with the process.)

use crate::cost::model::{EngineKey, LayerCost};
use crate::dataflow::Strategy;
use crate::workload::LayerShape;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{OnceLock, RwLock};

/// Dense id of an interned [`LayerShape`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ShapeId(u32);

fn interner() -> &'static RwLock<HashMap<LayerShape, u32>> {
    static INTERNER: OnceLock<RwLock<HashMap<LayerShape, u32>>> = OnceLock::new();
    INTERNER.get_or_init(|| RwLock::new(HashMap::new()))
}

/// Intern `shape`, returning its stable dense id. Idempotent; the id
/// space only grows (a few hundred distinct shapes even across a large
/// design-space search).
pub fn intern(shape: LayerShape) -> ShapeId {
    let lock = interner();
    if let Some(&id) = lock.read().expect("interner lock").get(&shape) {
        return ShapeId(id);
    }
    let mut map = lock.write().expect("interner lock");
    let next = map.len() as u32;
    ShapeId(*map.entry(shape).or_insert(next))
}

/// Number of distinct shapes interned so far.
pub fn interned_shapes() -> usize {
    interner().read().expect("interner lock").len()
}

type MemoKey = (ShapeId, Strategy, EngineKey);

/// One cached cost plus the bookkeeping the LRU and scope guard need.
#[derive(Debug)]
struct Entry {
    cost: LayerCost,
    /// Recency stamp: the insert tick, refreshed on every lookup hit with
    /// a relaxed *load* of the current tick (not a fetch-add — the hit
    /// path is the crate's hottest and must not gain a second contended
    /// RMW). Ticks only advance on inserts/scopes, so recency is
    /// epoch-granular: "last touched since which insert" — an NRU
    /// approximation, which is all eviction needs.
    last_used: AtomicU64,
    /// Tick at insert time — `RunScope` drops entries younger than its
    /// creation tick.
    inserted_at: u64,
}

fn table() -> &'static RwLock<HashMap<MemoKey, Entry>> {
    static TABLE: OnceLock<RwLock<HashMap<MemoKey, Entry>>> = OnceLock::new();
    TABLE.get_or_init(|| RwLock::new(HashMap::new()))
}

static HITS: AtomicU64 = AtomicU64::new(0);
static MISSES: AtomicU64 = AtomicU64::new(0);
static EVICTIONS: AtomicU64 = AtomicU64::new(0);
/// Logical clock for recency and scope stamps (starts at 1 so tick 0
/// means "before any memo activity").
static TICK: AtomicU64 = AtomicU64::new(1);

/// Default entry cap. A `LayerCost` is a few hundred bytes, so the
/// default bounds the table to tens of MB — far above what the 256-point
/// search touches (a few thousand entries), so eviction only engages on
/// genuinely unbounded workloads (long cluster runs over churning engine
/// configs).
pub const DEFAULT_CAPACITY: usize = 131_072;

static CAPACITY: AtomicUsize = AtomicUsize::new(DEFAULT_CAPACITY);

fn next_tick() -> u64 {
    TICK.fetch_add(1, Ordering::Relaxed)
}

/// Current entry cap of the table.
pub fn capacity() -> usize {
    CAPACITY.load(Ordering::Relaxed)
}

/// Set the entry cap (`>= 1`). Shrinking below the current size takes
/// effect on the next insert; values are recomputed on demand, so any
/// cap is safe.
pub fn set_capacity(cap: usize) {
    assert!(cap >= 1, "memo capacity must be >= 1");
    CAPACITY.store(cap, Ordering::Relaxed);
}

/// Fetch the memoized cost of `(shape, strategy, engine)`, if present.
pub fn lookup(shape: ShapeId, strategy: Strategy, engine: EngineKey) -> Option<LayerCost> {
    let guard = table().read().expect("memo lock");
    match guard.get(&(shape, strategy, engine)) {
        Some(e) => {
            e.last_used.store(TICK.load(Ordering::Relaxed), Ordering::Relaxed);
            let cost = e.cost.clone();
            drop(guard);
            HITS.fetch_add(1, Ordering::Relaxed);
            Some(cost)
        }
        None => {
            drop(guard);
            MISSES.fetch_add(1, Ordering::Relaxed);
            None
        }
    }
}

/// Record the cost of `(shape, strategy, engine)`. Last writer wins;
/// racing writers computed identical values (see module docs). Inserts
/// that would push the table past [`capacity`] first evict the
/// least-recently-used ~1/8 of entries (batched so the O(n) recency scan
/// amortizes across many inserts).
pub fn insert(shape: ShapeId, strategy: Strategy, engine: EngineKey, cost: LayerCost) {
    let mut map = table().write().expect("memo lock");
    let key = (shape, strategy, engine);
    let cap = capacity();
    if map.len() >= cap && !map.contains_key(&key) {
        // Evict at least enough that the table is within cap after this
        // insert (covers a freshly shrunk cap), in batches of ~cap/8 so
        // the O(n) recency scan amortizes across many inserts.
        let needed = map.len() + 1 - cap;
        let evict = needed.max(cap / 8).min(map.len());
        let mut by_age: Vec<(MemoKey, u64)> =
            map.iter().map(|(k, e)| (*k, e.last_used.load(Ordering::Relaxed))).collect();
        // O(n) selection, not a full sort — this all happens under the
        // table's write lock, which stalls every concurrent evaluation,
        // and only membership in the oldest-`evict` set matters.
        by_age.select_nth_unstable_by_key(evict - 1, |&(_, used)| used);
        for (k, _) in by_age.into_iter().take(evict) {
            map.remove(&k);
        }
        EVICTIONS.fetch_add(evict as u64, Ordering::Relaxed);
    }
    let t = next_tick();
    map.insert(key, Entry { cost, last_used: AtomicU64::new(t), inserted_at: t });
}

/// RAII guard from [`run_scope`]: dropping it removes every memo entry
/// inserted after its creation.
#[derive(Debug)]
pub struct RunScope {
    start_tick: u64,
}

/// Scope the memo to one run: entries inserted while the returned guard
/// is alive are dropped when it goes out of scope, so a long-lived
/// process (a bench loop, an embedding host) can run many simulations
/// without accumulating every run's working set. Scopes nest — an inner
/// guard only removes what was inserted after *it* was created. The
/// hit/miss/eviction counters are process-lifetime and unaffected.
pub fn run_scope() -> RunScope {
    RunScope { start_tick: next_tick() }
}

impl Drop for RunScope {
    fn drop(&mut self) {
        table()
            .write()
            .expect("memo lock")
            .retain(|_, e| e.inserted_at < self.start_tick);
    }
}

/// Snapshot of the memo table's accounting.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MemoStats {
    pub hits: u64,
    pub misses: u64,
    pub entries: usize,
    /// Entries removed by the LRU policy (not by `clear`/scope guards).
    pub evictions: u64,
    /// Entry cap in force when the snapshot was taken.
    pub capacity: usize,
}

impl MemoStats {
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

pub fn stats() -> MemoStats {
    MemoStats {
        hits: HITS.load(Ordering::Relaxed),
        misses: MISSES.load(Ordering::Relaxed),
        entries: table().read().expect("memo lock").len(),
        evictions: EVICTIONS.load(Ordering::Relaxed),
        capacity: capacity(),
    }
}

/// Drop every cached cost and reset the hit/miss/eviction counters (the
/// interner keeps its ids — they stay valid). Benches call this to time
/// cold evaluations honestly.
pub fn clear() {
    table().write().expect("memo lock").clear();
    HITS.store(0, Ordering::Relaxed);
    MISSES.store(0, Ordering::Relaxed);
    EVICTIONS.store(0, Ordering::Relaxed);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::Layer;

    /// The capacity- and scope-touching tests mutate process-global state,
    /// so they serialize against each other (tests in other modules only
    /// ever lookup/insert, which stays correct — if noisier — at any
    /// capacity).
    fn test_lock() -> std::sync::MutexGuard<'static, ()> {
        static LOCK: OnceLock<std::sync::Mutex<()>> = OnceLock::new();
        LOCK.get_or_init(|| std::sync::Mutex::new(()))
            .lock()
            .unwrap_or_else(|e| e.into_inner())
    }

    fn probe_engine() -> crate::cost::CostEngine {
        crate::cost::CostEngine::for_design_point(
            &crate::config::SystemConfig { num_chiplets: 4, pes_per_chiplet: 16, ..Default::default() },
            crate::config::DesignPoint::WIENNA_C,
        )
    }

    /// Distinct probe shapes (varying channel count) that no other test
    /// evaluates, plus one computed cost to reuse as the stored value.
    fn probe_entries(n: u64) -> (Vec<ShapeId>, EngineKey, LayerCost) {
        let engine = probe_engine();
        let ek = engine.memo_key().expect("design-point engines are memoizable");
        let layer = Layer::conv("memo_lru_probe", 2, 5, 3, 9, 9, 3, 3, 1);
        let cost = crate::cost::evaluate_layer_uncached(&engine, &layer, Strategy::KpCp);
        let ids = (0..n)
            .map(|i| intern(Layer::conv("memo_lru_probe", 2, 5, 3 + i, 9, 9, 3, 3, 1).shape()))
            .collect();
        (ids, ek, cost)
    }

    #[test]
    fn intern_is_idempotent_and_distinguishes_shapes() {
        let a = Layer::conv("a", 1, 8, 8, 12, 12, 3, 3, 1).shape();
        let b = Layer::conv("b", 1, 8, 8, 12, 12, 3, 3, 1).shape();
        let c = Layer::fc("c", 1, 8, 8).shape();
        assert_eq!(intern(a), intern(b));
        assert_ne!(intern(a), intern(c));
        assert_eq!(intern(a), intern(a));
    }

    #[test]
    fn stats_track_hits_and_misses() {
        // Other tests share the process-global table, so assert deltas on
        // a key no other test uses.
        let _g = test_lock();
        let shape = Layer::conv("memo_stats_probe", 3, 7, 11, 13, 13, 3, 3, 1).shape();
        let sid = intern(shape);
        let ek = crate::cost::CostEngine::for_design_point(
            &crate::config::SystemConfig { num_chiplets: 4, pes_per_chiplet: 16, ..Default::default() },
            crate::config::DesignPoint::WIENNA_C,
        )
        .memo_key()
        .expect("design-point engines are memoizable");
        let before = stats();
        assert!(lookup(sid, Strategy::KpCp, ek).is_none());
        let engine = crate::cost::CostEngine::for_design_point(
            &crate::config::SystemConfig { num_chiplets: 4, pes_per_chiplet: 16, ..Default::default() },
            crate::config::DesignPoint::WIENNA_C,
        );
        let layer = Layer::conv("memo_stats_probe", 3, 7, 11, 13, 13, 3, 3, 1);
        let cost = crate::cost::evaluate_layer_uncached(&engine, &layer, Strategy::KpCp);
        insert(sid, Strategy::KpCp, ek, cost.clone());
        let hit = lookup(sid, Strategy::KpCp, ek).expect("inserted");
        assert_eq!(hit.latency, cost.latency);
        let after = stats();
        assert!(after.misses >= before.misses + 1);
        assert!(after.hits >= before.hits + 1);
        assert!(after.entries >= 1);
    }

    #[test]
    fn lru_cap_evicts_coldest_entry_first() {
        let _g = test_lock();
        let old_cap = capacity();
        let (ids, ek, cost) = probe_entries(5);
        // Quiesce the table so tick order below is fully ours. Tests in
        // *other* modules share the process-global table and may insert
        // concurrently; the `quiet` probe below detects that and skips
        // the order-sensitive assertions (the capacity invariant and the
        // eviction counter stay asserted unconditionally).
        clear();
        set_capacity(4);
        for &id in &ids[..4] {
            insert(id, Strategy::KpCp, ek, cost.clone());
        }
        // Refresh entry 0 so entry 1 becomes the coldest.
        assert!(lookup(ids[0], Strategy::KpCp, ek).is_some());
        let before = stats();
        let quiet = before.entries == 4 && before.evictions == 0;
        insert(ids[4], Strategy::KpCp, ek, cost.clone());
        let after = stats();
        assert!(after.entries <= 4, "cap 4 enforced, saw {} entries", after.entries);
        assert!(after.evictions > before.evictions, "insert past cap must evict");
        if quiet && after.evictions == 1 {
            assert!(lookup(ids[1], Strategy::KpCp, ek).is_none(), "coldest entry must go first");
            assert!(lookup(ids[0], Strategy::KpCp, ek).is_some(), "refreshed entry was evicted");
            assert!(lookup(ids[4], Strategy::KpCp, ek).is_some(), "newest entry was evicted");
        }
        set_capacity(old_cap);
    }

    #[test]
    fn run_scope_drops_only_entries_inserted_inside_it() {
        let _g = test_lock();
        let (ids, ek, cost) = probe_entries(3);
        clear();
        insert(ids[0], Strategy::KpCp, ek, cost.clone());
        {
            let _scope = run_scope();
            insert(ids[1], Strategy::KpCp, ek, cost.clone());
            insert(ids[2], Strategy::KpCp, ek, cost.clone());
            assert!(lookup(ids[1], Strategy::KpCp, ek).is_some());
        }
        assert!(lookup(ids[0], Strategy::KpCp, ek).is_some(), "pre-scope entry must survive");
        assert!(lookup(ids[1], Strategy::KpCp, ek).is_none(), "scoped entry must be dropped");
        assert!(lookup(ids[2], Strategy::KpCp, ek).is_none(), "scoped entry must be dropped");
    }

    #[test]
    fn capacity_is_settable_and_reported() {
        let _g = test_lock();
        let old = capacity();
        set_capacity(777);
        assert_eq!(capacity(), 777);
        assert_eq!(stats().capacity, 777);
        set_capacity(old);
        assert_eq!(capacity(), old);
    }
}
