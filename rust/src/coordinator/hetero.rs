//! Heterogeneous chiplet arrays (paper §4: "WIENNA makes no assumptions
//! about the chiplet architecture and can thus accommodate heterogeneous
//! combinations of chiplets with different architectures and networks").
//!
//! This module implements that claim: a package whose chiplets differ in
//! PE count (e.g. a mix of big NVDLA-like tiles and small Shidiannao-like
//! tiles), with a work-partitioner that splits the partitioned dimension
//! *proportionally to compute capability* instead of uniformly, and a
//! load-balance analysis showing when heterogeneity helps (layers whose
//! parallelism does not divide evenly) and what a naive uniform split
//! loses.

use crate::dataflow::{ChipletArch, MapPolicy, Strategy};
use crate::workload::Layer;

/// One chiplet class in a heterogeneous package.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ChipletClass {
    pub name: String,
    pub count: u64,
    pub pes: u64,
    pub arch: ChipletArch,
}

/// A heterogeneous package description.
#[derive(Debug, Clone)]
pub struct HeteroPackage {
    pub classes: Vec<ChipletClass>,
}

impl HeteroPackage {
    /// A homogeneous package, for comparison.
    pub fn homogeneous(count: u64, pes: u64, arch: ChipletArch) -> Self {
        HeteroPackage { classes: vec![ChipletClass { name: "uniform".into(), count, pes, arch }] }
    }

    pub fn total_chiplets(&self) -> u64 {
        self.classes.iter().map(|c| c.count).sum()
    }

    pub fn total_pes(&self) -> u64 {
        self.classes.iter().map(|c| c.count * c.pes).sum()
    }
}

/// Work assignment for one chiplet class.
#[derive(Debug, Clone)]
pub struct ClassAssignment {
    pub class: ChipletClass,
    /// Units of the partitioned dimension given to each chiplet of this
    /// class (worst case).
    pub units_per_chiplet: u64,
    /// Compute cycles for this class's worst chiplet.
    pub cycles: u64,
}

/// Result of partitioning a layer across a heterogeneous package.
#[derive(Debug, Clone)]
pub struct HeteroPlan {
    pub assignments: Vec<ClassAssignment>,
    /// Makespan = max over classes (the slowest chiplet gates the layer).
    pub makespan: u64,
    /// Load imbalance: makespan / ideal (1.0 = perfectly balanced).
    pub imbalance: f64,
}

/// Units of the partitioned dimension for `strategy`.
fn partitioned_units(layer: &Layer, strategy: Strategy) -> u64 {
    match strategy {
        Strategy::KpCp => layer.k,
        Strategy::NpCp => layer.n,
        Strategy::YpXp => layer.y_out().max(1) * layer.x_out().max(1),
    }
}

/// Per-unit sub-layer for cycle estimation: the layer with the
/// partitioned dimension set to `units`.
fn sub_layer(layer: &Layer, strategy: Strategy, units: u64) -> Layer {
    match strategy {
        Strategy::KpCp => Layer { k: units, ..layer.clone() },
        Strategy::NpCp => Layer { n: units, ..layer.clone() },
        Strategy::YpXp => {
            // Interpret `units` as output rows (column dim kept whole).
            let rows = units.div_ceil(layer.x_out().max(1)).max(1);
            let y = (rows - 1) * layer.stride + layer.r;
            Layer { y, ..layer.clone() }
        }
    }
}

/// Partition `layer` across `pkg` proportionally to per-chiplet compute.
///
/// Each class receives a share of the partitioned dimension proportional
/// to `count x pes`, rounded to whole units; remainders go to the most
/// capable class.
pub fn partition_hetero(layer: &Layer, strategy: Strategy, pkg: &HeteroPackage, bytes_per_elem: u64) -> HeteroPlan {
    let units = partitioned_units(layer, strategy);
    let total_cap: u64 = pkg.total_pes();
    assert!(total_cap > 0);

    // Proportional shares (floor), remainder to the biggest class.
    let mut shares: Vec<u64> = pkg
        .classes
        .iter()
        .map(|c| units * c.count * c.pes / total_cap)
        .collect();
    let assigned: u64 = shares.iter().sum();
    let biggest = pkg
        .classes
        .iter()
        .enumerate()
        .max_by_key(|(_, c)| c.pes)
        .map(|(i, _)| i)
        .unwrap();
    shares[biggest] += units - assigned;

    let mut assignments = Vec::new();
    let mut makespan = 0u64;
    for (c, &share) in pkg.classes.iter().zip(shares.iter()) {
        let per_chiplet = share.div_ceil(c.count.max(1));
        let cycles = if per_chiplet == 0 {
            0
        } else {
            let sub = sub_layer(layer, strategy, per_chiplet);
            crate::dataflow::intra::map_layer(&sub, c.arch, c.pes, MapPolicy::Flexible, bytes_per_elem).cycles
        };
        makespan = makespan.max(cycles);
        assignments.push(ClassAssignment { class: c.clone(), units_per_chiplet: per_chiplet, cycles });
    }

    // Ideal: all MACs spread over all PEs at 1 MAC/PE/cycle.
    let ideal = layer.macs() as f64 / total_cap as f64;
    HeteroPlan { assignments, makespan, imbalance: makespan as f64 / ideal.max(1.0) }
}

/// Naive uniform split (every chiplet gets the same unit count) for
/// comparison — what a heterogeneity-unaware coordinator would do.
pub fn partition_uniform(layer: &Layer, strategy: Strategy, pkg: &HeteroPackage, bytes_per_elem: u64) -> HeteroPlan {
    let units = partitioned_units(layer, strategy);
    let n = pkg.total_chiplets();
    let per_chiplet = units.div_ceil(n.max(1)).max(1);
    let mut assignments = Vec::new();
    let mut makespan = 0u64;
    for c in &pkg.classes {
        let sub = sub_layer(layer, strategy, per_chiplet);
        let cycles = crate::dataflow::intra::map_layer(&sub, c.arch, c.pes, MapPolicy::Flexible, bytes_per_elem).cycles;
        makespan = makespan.max(cycles);
        assignments.push(ClassAssignment { class: c.clone(), units_per_chiplet: per_chiplet, cycles });
    }
    let ideal = layer.macs() as f64 / pkg.total_pes() as f64;
    HeteroPlan { assignments, makespan, imbalance: makespan as f64 / ideal.max(1.0) }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::conv_padded;

    fn mixed() -> HeteroPackage {
        HeteroPackage {
            classes: vec![
                ChipletClass { name: "big".into(), count: 32, pes: 256, arch: ChipletArch::NvdlaLike },
                ChipletClass { name: "small".into(), count: 128, pes: 64, arch: ChipletArch::NvdlaLike },
            ],
        }
    }

    #[test]
    fn totals() {
        let p = mixed();
        assert_eq!(p.total_chiplets(), 160);
        assert_eq!(p.total_pes(), 32 * 256 + 128 * 64);
    }

    #[test]
    fn proportional_beats_uniform_on_mixed_package() {
        let l = conv_padded("c", 8, 512, 256, 14, 14, 3, 3, 1);
        let pkg = mixed();
        let prop = partition_hetero(&l, Strategy::KpCp, &pkg, 1);
        let unif = partition_uniform(&l, Strategy::KpCp, &pkg, 1);
        assert!(
            prop.makespan <= unif.makespan,
            "proportional {} vs uniform {}",
            prop.makespan,
            unif.makespan
        );
    }

    #[test]
    fn homogeneous_matches_either_split() {
        let l = conv_padded("c", 4, 256, 128, 14, 14, 3, 3, 1);
        let pkg = HeteroPackage::homogeneous(256, 64, ChipletArch::NvdlaLike);
        let prop = partition_hetero(&l, Strategy::KpCp, &pkg, 1);
        let unif = partition_uniform(&l, Strategy::KpCp, &pkg, 1);
        assert_eq!(prop.makespan, unif.makespan);
    }

    #[test]
    fn all_units_assigned() {
        let l = conv_padded("c", 8, 500, 64, 28, 28, 3, 3, 1);
        let pkg = mixed();
        let plan = partition_hetero(&l, Strategy::KpCp, &pkg, 1);
        let covered: u64 = plan
            .assignments
            .iter()
            .map(|a| a.units_per_chiplet * a.class.count)
            .sum();
        assert!(covered >= 500, "covered {covered}");
    }

    #[test]
    fn imbalance_at_least_one() {
        let l = conv_padded("c", 2, 64, 64, 28, 28, 3, 3, 1);
        for strat in Strategy::ALL {
            let plan = partition_hetero(&l, strat, &mixed(), 1);
            assert!(plan.imbalance >= 0.99, "{strat}: {}", plan.imbalance);
        }
    }

    #[test]
    fn ypxp_hetero_split() {
        let l = conv_padded("c", 1, 64, 64, 56, 56, 3, 3, 1);
        let pkg = HeteroPackage {
            classes: vec![
                ChipletClass { name: "big".into(), count: 16, pes: 256, arch: ChipletArch::ShidiannaoLike },
                ChipletClass { name: "small".into(), count: 64, pes: 64, arch: ChipletArch::ShidiannaoLike },
            ],
        };
        let plan = partition_hetero(&l, Strategy::YpXp, &pkg, 1);
        assert!(plan.makespan > 0);
        // The big class must take more rows per chiplet than the small.
        assert!(plan.assignments[0].units_per_chiplet >= plan.assignments[1].units_per_chiplet);
    }
}
