//! Collection-phase helpers: replaying a layer's schedule through the
//! cycle-level mesh simulator (validation) and computing collection
//! schedules for the wired plane.

use crate::coordinator::scheduler::LayerSchedule;
use crate::nop::sim::{MeshSim, SimReport};

/// Replay a layer's distribution schedule through the cycle-level mesh
/// simulator at `link_bw` bytes/cycle. Used by tests and by the
//  `sim-validate` CLI subcommand to bound the analytical model's error.
pub fn simulate_distribution(schedule: &LayerSchedule, side: u32, link_bw: f64) -> SimReport {
    let sim = MeshSim::new(side, link_bw);
    let mut all = schedule.preload.clone();
    all.extend(schedule.stream.iter().cloned());
    sim.run_distribution(&all)
}

/// Simulate the collection phase: every used chiplet returns its share of
/// the layer's output bytes.
pub fn simulate_collection(schedule: &LayerSchedule, side: u32, link_bw: f64) -> SimReport {
    let sim = MeshSim::new(side, link_bw);
    let per_chiplet = schedule.plan.collect_bytes / schedule.plan.used_chiplets.max(1);
    sim.run_collection(per_chiplet)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DesignPoint, SystemConfig};
    use crate::coordinator::{Coordinator, StrategyPolicy};
    use crate::dataflow::Strategy;
    use crate::workload::conv_padded;

    #[test]
    fn sim_tracks_analytic_distribution_time() {
        // On a 4x4 package the cycle-level simulator and the analytical
        // mesh model must agree within a modest factor (fill effects).
        let sys = SystemConfig { num_chiplets: 16, pes_per_chiplet: 64, ..Default::default() };
        let c = Coordinator::new(sys, DesignPoint::INTERPOSER_A, StrategyPolicy::Fixed(Strategy::KpCp));
        let l = conv_padded("c", 1, 32, 16, 16, 16, 3, 3, 1);
        let s = c.schedule_layer(&l);
        let sim = simulate_distribution(&s, 4, DesignPoint::INTERPOSER_A.distribution_bw());
        let analytic = s.selection.cost.timeline.preload + s.selection.cost.timeline.stream;
        let ratio = sim.makespan / analytic;
        assert!(ratio > 0.5 && ratio < 2.0, "sim {} vs analytic {analytic} (ratio {ratio})", sim.makespan);
    }

    #[test]
    fn collection_sim_runs() {
        let sys = SystemConfig { num_chiplets: 16, pes_per_chiplet: 64, ..Default::default() };
        let c = Coordinator::new(sys, DesignPoint::WIENNA_C, StrategyPolicy::Adaptive);
        let l = conv_padded("c", 1, 32, 16, 16, 16, 3, 3, 1);
        let s = c.schedule_layer(&l);
        let r = simulate_collection(&s, 4, 8.0);
        assert!(r.makespan > 0.0);
        assert!(r.byte_hops > 0.0);
    }
}
