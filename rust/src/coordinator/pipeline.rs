//! Inter-layer pipelining: overlapping the next layer's preload with the
//! current layer's compute (an extension of the paper's Fig-6 timeline
//! across layer boundaries).
//!
//! The Fig-6 walkthrough treats each layer as preload → stream/compute →
//! collect. Because WIENNA's distribution plane is idle while chiplets
//! crunch a compute-bound layer, the coordinator can push layer `k+1`'s
//! *partitioned* tensor (its preload class) during layer `k`'s steady
//! state — classic double buffering, bounded by the chiplets' local
//! buffer capacity. This module computes the pipelined makespan and the
//! resulting speedup over the sequential schedule; the `ablation_pipeline`
//! bench quantifies it per workload.

use crate::cost::LayerCost;

/// Result of pipelining a layer sequence.
#[derive(Debug, Clone, PartialEq)]
pub struct PipelineResult {
    /// Sequential makespan (sum of per-layer latencies).
    pub sequential_cycles: f64,
    /// Pipelined makespan with next-layer preload overlap.
    pub pipelined_cycles: f64,
    /// Number of layer transitions where the preload fully hid.
    pub fully_hidden: usize,
    /// Layers whose preload could not overlap (local buffers too small
    /// to hold both the live working set and the staged preload).
    pub buffer_blocked: usize,
}

impl PipelineResult {
    pub fn speedup(&self) -> f64 {
        self.sequential_cycles / self.pipelined_cycles
    }
}

/// Compute the pipelined makespan.
///
/// `local_buffer_bytes` is the per-chiplet buffer budget; layer `k+1`'s
/// preload may overlap layer `k` only if the sum of both layers' working
/// sets fits (double buffering), otherwise the transition falls back to
/// the sequential schedule.
pub fn pipeline_makespan(costs: &[LayerCost], local_buffer_bytes: u64) -> PipelineResult {
    let sequential: f64 = costs.iter().map(|c| c.latency).sum();
    if costs.is_empty() {
        return PipelineResult { sequential_cycles: 0.0, pipelined_cycles: 0.0, fully_hidden: 0, buffer_blocked: 0 };
    }

    let mut total = 0.0;
    let mut hidden = 0usize;
    let mut blocked = 0usize;
    // First layer pays its full preload.
    total += costs[0].timeline.preload;
    for k in 0..costs.len() {
        let t = &costs[k].timeline;
        let steady = t.stream.max(t.compute).max(t.collect) + t.fill;
        total += steady;
        if k + 1 < costs.len() {
            let next = &costs[k + 1];
            let fits = costs[k].local_buffer_bytes + next.local_buffer_bytes <= local_buffer_bytes;
            if fits {
                // Next preload rides the idle distribution plane during
                // our steady state; only the excess spills into the
                // critical path.
                let overlap_capacity = if t.stream >= steady { 0.0 } else { steady - t.stream };
                let spill = (next.timeline.preload - overlap_capacity).max(0.0);
                if spill == 0.0 {
                    hidden += 1;
                }
                total += spill;
            } else {
                blocked += 1;
                total += next.timeline.preload;
            }
        }
    }
    PipelineResult { sequential_cycles: sequential, pipelined_cycles: total, fully_hidden: hidden, buffer_blocked: blocked }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DesignPoint, SystemConfig};
    use crate::cost::{evaluate_model, CostEngine};
    use crate::workload::resnet50::resnet50;

    fn costs() -> Vec<LayerCost> {
        let e = CostEngine::for_design_point(&SystemConfig::default(), DesignPoint::WIENNA_C);
        evaluate_model(&e, &resnet50(16), None).layers
    }

    #[test]
    fn pipelined_never_slower_with_big_buffers() {
        let cs = costs();
        let r = pipeline_makespan(&cs, u64::MAX);
        assert!(r.pipelined_cycles <= r.sequential_cycles + 1e-6);
        assert!(r.speedup() >= 1.0);
        assert_eq!(r.buffer_blocked, 0);
    }

    #[test]
    fn tiny_buffers_degrade_to_sequential() {
        let cs = costs();
        let r = pipeline_makespan(&cs, 0);
        assert!((r.pipelined_cycles - r.sequential_cycles).abs() < 1e-6);
        assert_eq!(r.buffer_blocked, cs.len() - 1);
    }

    #[test]
    fn speedup_monotone_in_buffer_size() {
        let cs = costs();
        let small = pipeline_makespan(&cs, 16 * 1024);
        let large = pipeline_makespan(&cs, 16 * 1024 * 1024);
        assert!(large.pipelined_cycles <= small.pipelined_cycles + 1e-6);
    }

    #[test]
    fn empty_sequence() {
        let r = pipeline_makespan(&[], 1024);
        assert_eq!(r.pipelined_cycles, 0.0);
        assert_eq!(r.speedup().is_nan(), true);
    }

    #[test]
    fn compute_bound_layers_hide_preloads() {
        // Synthetic: all steady states much longer than preloads.
        let e = CostEngine::for_design_point(&SystemConfig::default(), DesignPoint::WIENNA_A);
        let m = resnet50(64);
        let cs = evaluate_model(&e, &m, None).layers;
        let r = pipeline_makespan(&cs, u64::MAX);
        assert!(r.fully_hidden > 0, "expected some hidden preloads");
    }
}
