//! Per-layer partitioning-strategy selection.
//!
//! The paper's headline scheduling result (§5.2) is that *adaptive*
//! partitioning — picking the best strategy per layer, enabled by the
//! wireless NoP's run-time reconfigurability — beats any fixed strategy
//! (+4.7% on ResNet50, +9.1% on UNet over all-KP-CP).

use crate::cost::{best_strategy, evaluate_layer, CostEngine, LayerCost};
use crate::dataflow::Strategy;
use crate::workload::Layer;

/// How the coordinator chooses a strategy for each layer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StrategyPolicy {
    /// One strategy for the whole network.
    Fixed(Strategy),
    /// Evaluate all three strategies per layer and keep the fastest
    /// (latency-optimal under the active design point's cost model).
    Adaptive,
}

impl StrategyPolicy {
    pub fn label(&self) -> String {
        match self {
            StrategyPolicy::Fixed(s) => s.label().to_string(),
            StrategyPolicy::Adaptive => "Adaptive".to_string(),
        }
    }
}

/// Outcome of strategy selection for one layer.
#[derive(Debug, Clone)]
pub struct StrategySelection {
    pub strategy: Strategy,
    pub cost: LayerCost,
    /// Costs of the strategies that were considered and rejected
    /// (empty under a fixed policy) — kept for ablation reporting.
    pub rejected: Vec<LayerCost>,
}

/// Select a strategy for `layer` under `policy`.
pub fn select(engine: &CostEngine, layer: &Layer, policy: StrategyPolicy) -> StrategySelection {
    match policy {
        StrategyPolicy::Fixed(s) => {
            StrategySelection { strategy: s, cost: evaluate_layer(engine, layer, s), rejected: Vec::new() }
        }
        StrategyPolicy::Adaptive => {
            let (s, cost) = best_strategy(engine, layer);
            let rejected = Strategy::ALL
                .iter()
                .filter(|&&x| x != s)
                .map(|&x| evaluate_layer(engine, layer, x))
                .collect();
            StrategySelection { strategy: s, cost, rejected }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DesignPoint, SystemConfig};
    use crate::workload::conv_padded;

    #[test]
    fn adaptive_never_loses_to_its_candidates() {
        let e = CostEngine::for_design_point(&SystemConfig::default(), DesignPoint::WIENNA_C);
        let l = conv_padded("c", 4, 128, 64, 28, 28, 3, 3, 1);
        let sel = select(&e, &l, StrategyPolicy::Adaptive);
        for r in &sel.rejected {
            assert!(sel.cost.latency <= r.latency + 1e-9);
        }
        assert_eq!(sel.rejected.len(), 2);
    }

    #[test]
    fn fixed_policy_is_obeyed() {
        let e = CostEngine::for_design_point(&SystemConfig::default(), DesignPoint::WIENNA_C);
        let l = conv_padded("c", 4, 128, 64, 28, 28, 3, 3, 1);
        for s in Strategy::ALL {
            let sel = select(&e, &l, StrategyPolicy::Fixed(s));
            assert_eq!(sel.strategy, s);
            assert!(sel.rejected.is_empty());
        }
    }
}
