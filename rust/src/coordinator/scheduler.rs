//! The coordinator proper: walks a model layer by layer, selects
//! strategies, builds distribution schedules, and accounts the run.

use crate::config::{DesignPoint, SystemConfig, CLOCK_HZ};
use crate::coordinator::adaptive::{select, StrategyPolicy, StrategySelection};
use crate::cost::traffic::expand_plan;
use crate::cost::CostEngine;
use crate::dataflow::{partition, PartitionPlan};
use crate::nop::sim::Transfer;
use crate::workload::Model;

/// Everything the coordinator decided for one layer.
#[derive(Debug, Clone)]
pub struct LayerSchedule {
    pub selection: StrategySelection,
    pub plan: PartitionPlan,
    /// Concrete preload transfers (partitioned tensor, Fig-6 `t_0`).
    pub preload: Vec<Transfer>,
    /// Concrete streamed transfers (replicated tensor, Fig-6 `t_1`).
    pub stream: Vec<Transfer>,
}

impl LayerSchedule {
    /// Schedule invariant: unique bytes in the transfer lists equal the
    /// plan's traffic payload.
    pub fn scheduled_bytes(&self) -> u64 {
        self.preload.iter().map(|t| t.bytes).sum::<u64>() + self.stream.iter().map(|t| t.bytes).sum::<u64>()
    }
}

/// Aggregate statistics of a coordinated run.
#[derive(Debug, Clone)]
pub struct RunSummary {
    pub model_name: String,
    pub design_point: String,
    pub policy: String,
    pub total_latency_cycles: f64,
    pub total_macs: u64,
    pub macs_per_cycle: f64,
    /// Wall-clock at the Table-4 clock (500 MHz).
    pub latency_ms: f64,
    pub dist_energy_mj: f64,
    /// Per-layer-type strategy histogram (adaptive mode introspection).
    pub strategy_histogram: Vec<(String, String, usize)>,
}

/// The WIENNA package coordinator.
pub struct Coordinator {
    pub sys: SystemConfig,
    pub design_point: DesignPoint,
    pub engine: CostEngine,
    pub policy: StrategyPolicy,
}

impl Coordinator {
    pub fn new(sys: SystemConfig, design_point: DesignPoint, policy: StrategyPolicy) -> Self {
        let engine = CostEngine::for_design_point(&sys, design_point);
        Coordinator { sys, design_point, engine, policy }
    }

    /// Build the full schedule for one layer.
    pub fn schedule_layer(&self, layer: &crate::workload::Layer) -> LayerSchedule {
        let selection = select(&self.engine, layer, self.policy);
        let plan = partition::partition(layer, selection.strategy, self.sys.num_chiplets, self.sys.bytes_per_elem);
        let (preload, stream) = expand_plan(&plan, self.sys.mesh_side() as u32);
        LayerSchedule { selection, plan, preload, stream }
    }

    /// Schedule the whole model and summarize.
    pub fn run_model(&self, model: &Model) -> (Vec<LayerSchedule>, RunSummary) {
        let schedules: Vec<LayerSchedule> = model.layers.iter().map(|l| self.schedule_layer(l)).collect();
        let total_latency: f64 = schedules.iter().map(|s| s.selection.cost.latency).sum();
        let total_macs: u64 = schedules.iter().map(|s| s.selection.cost.macs).sum();
        let energy_pj: f64 = schedules.iter().map(|s| s.selection.cost.dist_energy_pj).sum();

        // Histogram: (layer type, strategy) -> count.
        let mut hist: std::collections::BTreeMap<(String, String), usize> = Default::default();
        for s in &schedules {
            *hist
                .entry((s.selection.cost.layer_type.label().to_string(), s.selection.strategy.label().to_string()))
                .or_insert(0) += 1;
        }

        let summary = RunSummary {
            model_name: model.name.clone(),
            design_point: self.design_point.label(),
            policy: self.policy.label(),
            total_latency_cycles: total_latency,
            total_macs,
            macs_per_cycle: total_macs as f64 / total_latency,
            latency_ms: total_latency / CLOCK_HZ * 1e3,
            dist_energy_mj: energy_pj * 1e-9,
            strategy_histogram: hist.into_iter().map(|((t, s), c)| (t, s, c)).collect(),
        };
        (schedules, summary)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::Strategy;
    use crate::workload::{resnet50, tiny};

    fn coord(policy: StrategyPolicy) -> Coordinator {
        Coordinator::new(SystemConfig::default(), DesignPoint::WIENNA_C, policy)
    }

    #[test]
    fn schedule_conserves_bytes() {
        let c = coord(StrategyPolicy::Adaptive);
        let m = tiny::tiny_cnn(4);
        for l in &m.layers {
            let s = c.schedule_layer(l);
            assert_eq!(s.scheduled_bytes(), s.plan.sent_bytes(), "layer {}", l.name);
        }
    }

    #[test]
    fn run_summary_aggregates() {
        let c = coord(StrategyPolicy::Fixed(Strategy::KpCp));
        let m = tiny::tiny_cnn(4);
        let (schedules, sum) = c.run_model(&m);
        assert_eq!(schedules.len(), m.layers.len());
        assert_eq!(sum.total_macs, m.total_macs());
        assert!(sum.macs_per_cycle > 0.0);
        assert!(sum.latency_ms > 0.0);
    }

    #[test]
    fn adaptive_histogram_uses_multiple_strategies_on_resnet() {
        let c = coord(StrategyPolicy::Adaptive);
        let (_, sum) = c.run_model(&resnet50::resnet50(64));
        let strategies: std::collections::HashSet<&String> =
            sum.strategy_histogram.iter().map(|(_, s, _)| s).collect();
        assert!(
            strategies.len() >= 2,
            "adaptive should mix strategies on ResNet50, got {strategies:?}"
        );
    }
}
