//! WIENNA coordinator (substrate S11) — the system layer of the paper's
//! contribution.
//!
//! The coordinator owns the package: for every layer of a DNN it
//! (1) selects the partitioning strategy (fixed or adaptive, §5.2),
//! (2) derives the partition plan and the concrete distribution schedule
//! (unicasts for the partitioned tensor, broadcasts for the replicated
//! one — the Fig-6 timeline), (3) accounts cycles and energy through the
//! cost model and NoP models, and (4) — in execution mode — dispatches
//! the chiplets' tile computations onto the PJRT runtime and collects the
//! outputs, producing real numerics end to end.

pub mod adaptive;
pub mod collective;
#[cfg(feature = "pjrt")]
pub mod exec;
pub mod hetero;
pub mod pipeline;
pub mod scheduler;

pub use adaptive::{StrategyPolicy, StrategySelection};
#[cfg(feature = "pjrt")]
pub use exec::{InferenceReport, PackageExecutor};
pub use scheduler::{Coordinator, LayerSchedule, RunSummary};
