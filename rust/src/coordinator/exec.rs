//! Package executor: runs a model with *real numerics* through the AOT
//! compute path, under the coordinator's schedules.
//!
//! Convolutions are lowered to im2col + tiled GEMM — exactly the shape the
//! L1 Pallas kernel implements (NVDLA-style weight-stationary tiles; see
//! DESIGN.md §Hardware-Adaptation). Every `TILE x TILE` GEMM tile is
//! dispatched to a (simulated) chiplet according to the layer's partition
//! strategy and executed on the PJRT runtime; residual additions run
//! through the elementwise artifact. A naive Rust convolution provides an
//! independent oracle for the end-to-end numerics.

use crate::coordinator::scheduler::{Coordinator, LayerSchedule};
use crate::runtime::ExecutableCache;
use crate::workload::{Layer, Model, OpKind};
use crate::dataflow::Strategy;
use crate::anyhow::{self, Context, Result};
use std::sync::Arc;

/// Tile edge shared with `python/compile/aot.py` (`tiny::TILE_M` etc.).
pub const TILE: usize = 64;
/// Elementwise artifact chunk (must match aot.py's `ADD_CHUNK`).
pub const ADD_CHUNK: usize = 4096;
/// Artifact names from the manifest.
pub const MATMUL_ARTIFACT: &str = "matmul64";
pub const ADD_ARTIFACT: &str = "add4096";

/// A dense activation tensor in `[N, C, Y, X]` layout.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub n: usize,
    pub c: usize,
    pub y: usize,
    pub x: usize,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(n: usize, c: usize, y: usize, x: usize) -> Self {
        Tensor { n, c, y, x, data: vec![0.0; n * c * y * x] }
    }

    pub fn from_fn(n: usize, c: usize, y: usize, x: usize, f: impl Fn(usize, usize, usize, usize) -> f32) -> Self {
        let mut t = Tensor::zeros(n, c, y, x);
        for ni in 0..n {
            for ci in 0..c {
                for yi in 0..y {
                    for xi in 0..x {
                        let idx = ((ni * c + ci) * y + yi) * x + xi;
                        t.data[idx] = f(ni, ci, yi, xi);
                    }
                }
            }
        }
        t
    }

    #[inline]
    pub fn at(&self, n: usize, c: usize, y: usize, x: usize) -> f32 {
        self.data[((n * self.c + c) * self.y + y) * self.x + x]
    }

    pub fn elems(&self) -> usize {
        self.data.len()
    }
}

/// Conv weights in `[K, C, R, S]` layout.
#[derive(Debug, Clone)]
pub struct Weights {
    pub k: usize,
    pub c: usize,
    pub r: usize,
    pub s: usize,
    pub data: Vec<f32>,
}

impl Weights {
    pub fn from_fn(k: usize, c: usize, r: usize, s: usize, f: impl Fn(usize) -> f32) -> Self {
        let len = k * c * r * s;
        Weights { k, c, r, s, data: (0..len).map(f).collect() }
    }

    #[inline]
    pub fn at(&self, k: usize, c: usize, r: usize, s: usize) -> f32 {
        self.data[((k * self.c + c) * self.r + r) * self.s + s]
    }
}

/// Per-layer execution statistics.
#[derive(Debug, Clone)]
pub struct LayerExecStats {
    pub layer_name: String,
    pub strategy: String,
    pub tiles_dispatched: usize,
    pub chiplets_used: u64,
    pub model_cycles: f64,
    pub wall_us: f64,
}

/// End-to-end inference report.
#[derive(Debug, Clone)]
pub struct InferenceReport {
    pub model_name: String,
    pub layers: Vec<LayerExecStats>,
    pub total_model_cycles: f64,
    pub total_wall_ms: f64,
    /// Max |xla - naive| over the final output.
    pub max_abs_err: f32,
    pub output_len: usize,
}

/// Runs a model's numerics through the PJRT artifacts under the
/// coordinator's per-layer schedules.
pub struct PackageExecutor {
    pub coordinator: Coordinator,
    cache: Arc<ExecutableCache>,
    /// Round-robin cursor emulating per-chiplet dispatch.
    tile_log: Vec<(usize, u64)>, // (tiles, chiplet)
}

impl PackageExecutor {
    pub fn new(coordinator: Coordinator, cache: Arc<ExecutableCache>) -> Self {
        PackageExecutor { coordinator, cache, tile_log: Vec::new() }
    }

    /// GEMM `a[m,kd] x b[kd,n]` via TILE³ artifact dispatches.
    ///
    /// `assign` maps a `(row_tile, col_tile)` to the chiplet that computes
    /// it (partition-strategy dependent); returns the output buffer and
    /// the number of tiles dispatched.
    fn gemm_tiled(
        &self,
        a: &[f32],
        b: &[f32],
        m: usize,
        kd: usize,
        n: usize,
        assign: impl Fn(usize, usize) -> u64,
    ) -> Result<(Vec<f32>, usize)> {
        let mt = m.div_ceil(TILE);
        let kt = kd.div_ceil(TILE);
        let nt = n.div_ceil(TILE);
        let mut out = vec![0.0f32; m * n];
        let mut a_tile = vec![0.0f32; TILE * TILE];
        let mut b_tile = vec![0.0f32; TILE * TILE];
        let mut tiles = 0usize;
        for mi in 0..mt {
            for ni in 0..nt {
                let chiplet = assign(mi, ni);
                let mut acc = vec![0.0f32; TILE * TILE];
                for ki in 0..kt {
                    // Pack (zero-padded) tiles row-wise: interior rows are
                    // a single memcpy, edges are zero-filled then patched
                    // (EXPERIMENTS.md §Perf — the elementwise pack with
                    // per-element bounds checks was the executor's second
                    // hottest loop).
                    pack_tile(a, m, kd, mi, ki, &mut a_tile);
                    pack_tile(b, kd, n, ki, ni, &mut b_tile);
                    let prod = self.cache.execute_f32(MATMUL_ARTIFACT, &[&a_tile, &b_tile])?;
                    for (o, p) in acc.iter_mut().zip(prod.iter()) {
                        *o += p;
                    }
                    tiles += 1;
                }
                // Scatter the accumulated tile into the output.
                for r in 0..TILE {
                    let or = mi * TILE + r;
                    if or >= m {
                        break;
                    }
                    for c in 0..TILE {
                        let oc = ni * TILE + c;
                        if oc < n {
                            out[or * n + oc] = acc[r * TILE + c];
                        }
                    }
                }
                let _ = chiplet;
            }
        }
        Ok((out, tiles))
    }

    /// im2col patch matrix `[(n,yo,xo) x (c,r,s)]` with symmetric
    /// zero-padding derived from the layer's padded extents.
    fn im2col(layer: &Layer, input: &Tensor) -> (Vec<f32>, usize, usize) {
        let yo = layer.y_out() as usize;
        let xo = layer.x_out() as usize;
        let (r, s, stride) = (layer.r as usize, layer.s as usize, layer.stride as usize);
        let m = input.n * yo * xo;
        let kd = input.c * r * s;
        let pad_y = (layer.y as usize).saturating_sub(input.y);
        let pad_x = (layer.x as usize).saturating_sub(input.x);
        let (py0, px0) = (pad_y / 2, pad_x / 2);
        let mut patches = vec![0.0f32; m * kd];
        for n in 0..input.n {
            for oy in 0..yo {
                for ox in 0..xo {
                    let row = (n * yo + oy) * xo + ox;
                    for c in 0..input.c {
                        for rr in 0..r {
                            for ss in 0..s {
                                let iy = (oy * stride + rr) as isize - py0 as isize;
                                let ix = (ox * stride + ss) as isize - px0 as isize;
                                let col = (c * r + rr) * s + ss;
                                if iy >= 0 && (iy as usize) < input.y && ix >= 0 && (ix as usize) < input.x {
                                    patches[row * kd + col] = input.at(n, c, iy as usize, ix as usize);
                                }
                            }
                        }
                    }
                }
            }
        }
        (patches, m, kd)
    }

    /// Direct output-stationary conv through the Shidiannao-style
    /// artifact (`conv3x3_c{C}k{K}y{Y}`), the YP-XP compute path. Returns
    /// `None` when no artifact covers this shape.
    fn conv3x3_direct(&self, layer: &Layer, input: &Tensor, weights: &Weights) -> Result<Option<(Tensor, usize)>> {
        let same_conv = layer.op == OpKind::Conv2D
            && layer.r == 3
            && layer.s == 3
            && layer.stride == 1
            && layer.y_out() as usize == input.y;
        if !same_conv {
            return Ok(None);
        }
        let name = format!("conv3x3_c{}k{}y{}", input.c, weights.k, input.y);
        if self.cache.manifest().get(&name).is_err() {
            return Ok(None);
        }
        let yo = input.y;
        let xo = input.x;
        let mut out = Tensor::zeros(input.n, weights.k, yo, xo);
        let plane = input.c * input.y * input.x;
        let oplane = weights.k * yo * xo;
        let mut calls = 0usize;
        for n in 0..input.n {
            let x = &input.data[n * plane..(n + 1) * plane];
            let o = self.cache.execute_f32(&name, &[x, &weights.data])?;
            out.data[n * oplane..(n + 1) * oplane].copy_from_slice(&o);
            calls += 1;
        }
        Ok(Some((out, calls)))
    }

    /// Execute one convolution (or FC, which is a 1x1 conv) layer.
    pub fn conv_layer(&mut self, layer: &Layer, input: &Tensor, weights: &Weights) -> Result<(Tensor, LayerExecStats)> {
        let t0 = std::time::Instant::now();
        let schedule: LayerSchedule = self.coordinator.schedule_layer(layer);
        let used = schedule.plan.used_chiplets;
        let strategy = schedule.selection.strategy;

        // YP-XP layers run on Shidiannao-style chiplets (Table 4): use the
        // output-stationary direct-conv artifact when one matches.
        if strategy == Strategy::YpXp {
            if let Some((out, calls)) = self.conv3x3_direct(layer, input, weights)? {
                let stats = LayerExecStats {
                    layer_name: layer.name.to_string(),
                    strategy: format!("{}*", strategy.label()), // '*' = direct-conv path
                    tiles_dispatched: calls,
                    chiplets_used: used,
                    model_cycles: schedule.selection.cost.latency,
                    wall_us: t0.elapsed().as_secs_f64() * 1e6,
                };
                self.tile_log.push((calls, used));
                return Ok((out, stats));
            }
        }

        let (patches, m, kd) = Self::im2col(layer, input);
        let k_out = weights.k;
        // Weight matrix [kd x k_out] (transposed filter bank).
        let mut wmat = vec![0.0f32; kd * k_out];
        for k in 0..k_out {
            for c in 0..weights.c {
                for r in 0..weights.r {
                    for s in 0..weights.s {
                        let row = (c * weights.r + r) * weights.s + s;
                        wmat[row * k_out + k] = weights.at(k, c, r, s);
                    }
                }
            }
        }

        // Tile-to-chiplet assignment mirrors the partition strategy:
        // KP-CP owns output-channel tiles, NP-CP / YP-XP own row
        // (batch/spatial) tiles.
        let assign = move |mi: usize, ni: usize| -> u64 {
            match strategy {
                Strategy::KpCp => (ni as u64) % used,
                Strategy::NpCp | Strategy::YpXp => (mi as u64) % used,
            }
        };
        let (out_flat, tiles) = self.gemm_tiled(&patches, &wmat, m, kd, k_out, assign)?;

        // Rearrange [m x k_out] -> [N, K, Yo, Xo].
        let yo = layer.y_out() as usize;
        let xo = layer.x_out() as usize;
        let mut out = Tensor::zeros(input.n, k_out, yo, xo);
        for n in 0..input.n {
            for oy in 0..yo {
                for ox in 0..xo {
                    let row = (n * yo + oy) * xo + ox;
                    for k in 0..k_out {
                        out.data[((n * k_out + k) * yo + oy) * xo + ox] = out_flat[row * k_out + k];
                    }
                }
            }
        }
        let stats = LayerExecStats {
            layer_name: layer.name.to_string(),
            strategy: strategy.label().to_string(),
            tiles_dispatched: tiles,
            chiplets_used: used,
            model_cycles: schedule.selection.cost.latency,
            wall_us: t0.elapsed().as_secs_f64() * 1e6,
        };
        self.tile_log.push((tiles, used));
        Ok((out, stats))
    }

    /// Execute a residual addition through the elementwise artifact.
    pub fn residual_layer(&mut self, layer: &Layer, a: &Tensor, b: &Tensor) -> Result<(Tensor, LayerExecStats)> {
        anyhow::ensure!(a.data.len() == b.data.len(), "residual operand shape mismatch");
        let t0 = std::time::Instant::now();
        let schedule = self.coordinator.schedule_layer(layer);
        let mut out = a.clone();
        let mut chunks = 0usize;
        let mut xa = vec![0.0f32; ADD_CHUNK];
        let mut xb = vec![0.0f32; ADD_CHUNK];
        let mut off = 0usize;
        while off < a.data.len() {
            let len = ADD_CHUNK.min(a.data.len() - off);
            xa[..len].copy_from_slice(&a.data[off..off + len]);
            xb[..len].copy_from_slice(&b.data[off..off + len]);
            xa[len..].fill(0.0);
            xb[len..].fill(0.0);
            let sum = self.cache.execute_f32(ADD_ARTIFACT, &[&xa, &xb])?;
            out.data[off..off + len].copy_from_slice(&sum[..len]);
            off += len;
            chunks += 1;
        }
        let stats = LayerExecStats {
            layer_name: layer.name.to_string(),
            strategy: schedule.selection.strategy.label().to_string(),
            tiles_dispatched: chunks,
            chiplets_used: schedule.plan.used_chiplets,
            model_cycles: schedule.selection.cost.latency,
            wall_us: t0.elapsed().as_secs_f64() * 1e6,
        };
        Ok((out, stats))
    }

    /// Run the whole model on `input`, generating deterministic weights
    /// per layer, and verify against the naive Rust oracle.
    pub fn run_model(&mut self, model: &Model, input: &Tensor) -> Result<InferenceReport> {
        let t0 = std::time::Instant::now();
        let mut stats = Vec::new();
        let mut cur = input.clone();
        let mut residual_src: Option<Tensor> = None;
        let mut ref_cur = input.clone();
        let mut ref_residual: Option<Tensor> = None;

        for layer in &model.layers {
            match layer.op {
                OpKind::ResidualAdd => {
                    let a = residual_src.take().context("no residual source saved")?;
                    let (out, st) = self.residual_layer(layer, &cur, &a)?;
                    stats.push(st);
                    cur = out;
                    let ra = ref_residual.take().unwrap();
                    for (o, x) in ref_cur.data.iter_mut().zip(ra.data.iter()) {
                        *o += x;
                    }
                }
                OpKind::Conv2D | OpKind::FullyConnected => {
                    // Save the residual source *before* channel-changing
                    // convs that open a block (convention: layers named
                    // `*conv1`/`*conv3` in tiny_cnn start blocks).
                    if layer.name.ends_with("conv1") || layer.name.ends_with("conv3") {
                        // block opens after this layer computes
                    }
                    let (k, c) = (layer.k as usize, layer.c as usize);
                    let (r, s) = (layer.r as usize, layer.s as usize);
                    let w = deterministic_weights(&layer.name, k, c, r, s);
                    let (inp, ref_inp) = if layer.op == OpKind::FullyConnected {
                        // Flatten to [N, C, 1, 1].
                        (flatten(&cur), flatten(&ref_cur))
                    } else {
                        (cur.clone(), ref_cur.clone())
                    };
                    let (out, st) = self.conv_layer(layer, &inp, &w)?;
                    stats.push(st);
                    cur = out;
                    ref_cur = naive_conv(layer, &ref_inp, &w);
                    // The layer after a block-opening conv consumes its
                    // output as the residual source.
                    if layer.name.ends_with("conv1") || layer.name.ends_with("conv3") {
                        residual_src = Some(cur.clone());
                        ref_residual = Some(ref_cur.clone());
                    }
                }
                OpKind::UpConv => anyhow::bail!("UpConv not supported by the tiny e2e path"),
            }
        }

        let max_abs_err = cur
            .data
            .iter()
            .zip(ref_cur.data.iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        Ok(InferenceReport {
            model_name: model.name.clone(),
            total_model_cycles: stats.iter().map(|s| s.model_cycles).sum(),
            total_wall_ms: t0.elapsed().as_secs_f64() * 1e3,
            layers: stats,
            max_abs_err,
            output_len: cur.data.len(),
        })
    }
}

/// Pack the `(ti, tj)` TILE x TILE block of the `rows x cols` row-major
/// matrix `src` into `dst`, zero-padding beyond the matrix edge.
#[inline]
fn pack_tile(src: &[f32], rows: usize, cols: usize, ti: usize, tj: usize, dst: &mut [f32]) {
    let r0 = ti * TILE;
    let c0 = tj * TILE;
    let nrows = TILE.min(rows.saturating_sub(r0));
    let ncols = TILE.min(cols.saturating_sub(c0));
    if nrows < TILE || ncols < TILE {
        dst.fill(0.0);
    }
    for r in 0..nrows {
        let s = (r0 + r) * cols + c0;
        dst[r * TILE..r * TILE + ncols].copy_from_slice(&src[s..s + ncols]);
    }
}

/// Deterministic pseudo-random weights: reproducible across Rust and any
/// re-run without an RNG dependency on the hot path.
pub fn deterministic_weights(name: &str, k: usize, c: usize, r: usize, s: usize) -> Weights {
    let seed: u32 = name.bytes().fold(0x811c9dc5u32, |h, b| (h ^ b as u32).wrapping_mul(0x01000193));
    Weights::from_fn(k, c, r, s, |i| {
        let h = (seed ^ (i as u32).wrapping_mul(0x9e3779b9)).wrapping_mul(0x85ebca6b);
        // Map to [-0.05, 0.05) — keeps deep activations in f32 range.
        ((h >> 8) as f32 / (1u32 << 24) as f32 - 0.5) * 0.1
    })
}

/// Flatten `[N, C, Y, X]` to `[N, C*Y*X, 1, 1]` for FC layers.
pub fn flatten(t: &Tensor) -> Tensor {
    Tensor { n: t.n, c: t.c * t.y * t.x, y: 1, x: 1, data: t.data.clone() }
}

/// Naive direct convolution oracle (padding derived like `im2col`).
pub fn naive_conv(layer: &Layer, input: &Tensor, w: &Weights) -> Tensor {
    let yo = layer.y_out() as usize;
    let xo = layer.x_out() as usize;
    let stride = layer.stride as usize;
    let pad_y = (layer.y as usize).saturating_sub(input.y);
    let pad_x = (layer.x as usize).saturating_sub(input.x);
    let (py0, px0) = (pad_y / 2, pad_x / 2);
    let mut out = Tensor::zeros(input.n, w.k, yo, xo);
    for n in 0..input.n {
        for k in 0..w.k {
            for oy in 0..yo {
                for ox in 0..xo {
                    let mut acc = 0.0f32;
                    for c in 0..input.c {
                        for r in 0..w.r {
                            for s in 0..w.s {
                                let iy = (oy * stride + r) as isize - py0 as isize;
                                let ix = (ox * stride + s) as isize - px0 as isize;
                                if iy >= 0 && (iy as usize) < input.y && ix >= 0 && (ix as usize) < input.x {
                                    acc += input.at(n, c, iy as usize, ix as usize) * w.at(k, c, r, s);
                                }
                            }
                        }
                    }
                    out.data[((n * w.k + k) * yo + oy) * xo + ox] = acc;
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::conv_padded;

    #[test]
    fn im2col_matches_naive_conv_via_cpu_gemm() {
        // Validate the im2col + GEMM lowering against the naive oracle
        // with a pure-Rust GEMM (no artifacts needed).
        let layer = conv_padded("t", 1, 4, 3, 8, 8, 3, 3, 1);
        let input = Tensor::from_fn(1, 3, 8, 8, |_, c, y, x| (c * 64 + y * 8 + x) as f32 * 0.01);
        let w = deterministic_weights("t", 4, 3, 3, 3);
        let (patches, m, kd) = PackageExecutor::im2col(&layer, &input);
        let mut wmat = vec![0.0f32; kd * 4];
        for k in 0..4 {
            for c in 0..3 {
                for r in 0..3 {
                    for s in 0..3 {
                        wmat[((c * 3 + r) * 3 + s) * 4 + k] = w.at(k, c, r, s);
                    }
                }
            }
        }
        // Plain GEMM.
        let mut out_flat = vec![0.0f32; m * 4];
        for i in 0..m {
            for j in 0..4 {
                let mut acc = 0.0;
                for p in 0..kd {
                    acc += patches[i * kd + p] * wmat[p * 4 + j];
                }
                out_flat[i * 4 + j] = acc;
            }
        }
        let oracle = naive_conv(&layer, &input, &w);
        let yo = layer.y_out() as usize;
        let xo = layer.x_out() as usize;
        for oy in 0..yo {
            for ox in 0..xo {
                for k in 0..4 {
                    let a = out_flat[(oy * xo + ox) * 4 + k];
                    let b = oracle.at(0, k, oy, ox);
                    assert!((a - b).abs() < 1e-4, "mismatch at k={k} oy={oy} ox={ox}: {a} vs {b}");
                }
            }
        }
    }

    #[test]
    fn strided_conv_with_asymmetric_padding() {
        let layer = conv_padded("t", 1, 2, 2, 8, 8, 3, 3, 2);
        let input = Tensor::from_fn(1, 2, 8, 8, |_, c, y, x| ((c + y + x) % 5) as f32);
        let w = deterministic_weights("t2", 2, 2, 3, 3);
        let out = naive_conv(&layer, &input, &w);
        assert_eq!((out.y, out.x), (4, 4));
    }

    #[test]
    fn deterministic_weights_are_stable_and_bounded() {
        let a = deterministic_weights("layer", 4, 4, 3, 3);
        let b = deterministic_weights("layer", 4, 4, 3, 3);
        assert_eq!(a.data, b.data);
        assert!(a.data.iter().all(|v| v.abs() <= 0.05));
        let c = deterministic_weights("other", 4, 4, 3, 3);
        assert_ne!(a.data, c.data);
    }

    #[test]
    fn flatten_preserves_data() {
        let t = Tensor::from_fn(2, 3, 4, 4, |n, c, y, x| (n + c + y + x) as f32);
        let f = flatten(&t);
        assert_eq!(f.c, 48);
        assert_eq!(f.data, t.data);
    }
}
