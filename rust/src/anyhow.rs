//! Minimal, dependency-free replacement for the `anyhow` error crate.
//!
//! The build environment is fully offline (see `testutil`, which likewise
//! replaces `tempfile`/`proptest`/`criterion`), so the crate ships its own
//! drop-in subset of the `anyhow` API surface it actually uses:
//!
//! * [`Error`] — a context-chained, message-only error value;
//! * [`Result`] — `Result<T, Error>` with the usual default type param;
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`;
//! * the `anyhow!`, `bail!` and `ensure!` macros, re-exported here so both
//!   `use crate::anyhow::{bail, ...}` and qualified `anyhow::bail!(..)`
//!   call sites keep working.
//!
//! Like `anyhow::Error`, [`Error`] deliberately does **not** implement
//! `std::error::Error`; that is what makes the blanket
//! `From<E: std::error::Error>` conversion (and thus `?` on any standard
//! error) coherent.

use std::fmt;

/// A message-chained error. The chain is stored innermost (root cause)
/// first; `Display` shows the outermost message, `{:#}` the whole chain
/// separated by `": "`, and `Debug` an `anyhow`-style "Caused by" block.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap the error with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.push(context.to_string());
        self
    }

    /// The innermost (root cause) message.
    pub fn root_cause(&self) -> &str {
        &self.chain[0]
    }

    /// Context messages, outermost first (the order `{:#}` prints them).
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().rev().map(|s| s.as_str())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            for (i, msg) in self.chain.iter().rev().enumerate() {
                if i > 0 {
                    f.write_str(": ")?;
                }
                f.write_str(msg)?;
            }
            Ok(())
        } else {
            f.write_str(self.chain.last().expect("error chain is never empty"))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.chain.last().expect("error chain is never empty"))?;
        if self.chain.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for (i, msg) in self.chain.iter().rev().skip(1).enumerate() {
                write!(f, "\n    {i}: {msg}")?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.insert(0, s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to `Result` and `Option` values, as in `anyhow`.
pub trait Context<T> {
    /// Wrap the error (or `None`) with a context message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;

    /// Wrap the error (or `None`) with a lazily evaluated context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E> Context<T> for std::result::Result<T, E>
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for std::result::Result<T, Error> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// `anyhow!`-style error constructor from a format string.
#[macro_export]
macro_rules! __wienna_anyhow {
    ($($arg:tt)*) => {
        $crate::anyhow::Error::msg(format!($($arg)*))
    };
}

/// Early-return with a formatted error.
#[macro_export]
macro_rules! __wienna_bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow::Error::msg(format!($($arg)*)))
    };
}

/// Early-return with a formatted error when a condition does not hold.
#[macro_export]
macro_rules! __wienna_ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow::Error::msg(format!(
                "condition failed: `{}`",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow::Error::msg(format!($($arg)*)));
        }
    };
}

pub use crate::{__wienna_anyhow as anyhow, __wienna_bail as bail, __wienna_ensure as ensure};

#[cfg(test)]
mod tests {
    use super::*;
    // Qualified `anyhow::...` call sites (as `main.rs` and the examples
    // use) resolve through this module import.
    use crate::anyhow;

    fn parse_number(s: &str) -> Result<u64> {
        let n: u64 = s.parse().with_context(|| format!("bad number '{s}'"))?;
        ensure!(n < 100, "number {n} out of range");
        Ok(n)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        assert_eq!(parse_number("42").unwrap(), 42);
        let e = parse_number("nope").unwrap_err();
        assert_eq!(e.to_string(), "bad number 'nope'");
        assert!(format!("{e:#}").starts_with("bad number 'nope': "));
    }

    #[test]
    fn ensure_and_bail() {
        let e = parse_number("500").unwrap_err();
        assert_eq!(e.to_string(), "number 500 out of range");

        fn fail() -> Result<()> {
            bail!("kind {}", "bad");
        }
        assert_eq!(fail().unwrap_err().to_string(), "kind bad");
    }

    #[test]
    fn context_chains_render() {
        let e = Error::msg("root").context("middle").context("outer");
        assert_eq!(e.to_string(), "outer");
        assert_eq!(format!("{e:#}"), "outer: middle: root");
        assert_eq!(e.root_cause(), "root");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by:"));
        assert!(dbg.contains("1: root"));
    }

    #[test]
    fn option_context() {
        let v: Option<u64> = None;
        assert_eq!(v.context("missing").unwrap_err().to_string(), "missing");
        assert_eq!(Some(7u64).context("missing").unwrap(), 7);
    }

    #[test]
    fn qualified_macro_paths_work() {
        fn f() -> anyhow::Result<u64> {
            anyhow::ensure!(1 + 1 == 2);
            Err(anyhow::anyhow!("boom {}", 1))
        }
        assert_eq!(f().unwrap_err().to_string(), "boom 1");
    }
}
