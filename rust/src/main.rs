//! `wienna` — CLI for the WIENNA 2.5D accelerator reproduction.
//!
//! Usage:
//! ```text
//! wienna simulate  [--workload resnet50|unet|tiny] [--design interposer-c|interposer-a|wienna-c|wienna-a]
//!                  [--strategy kp-cp|np-cp|yp-xp|adaptive] [--batch N] [--chiplets N] [--verbose]
//! wienna sweep     [--workload ...] [--batch N]
//! wienna serve     [--mix cnn|mixed|resnet50|bert] [--design ...] [--packages N]
//!                  [--policy rr|ll|edf] [--load F] [--duration-ms MS] [--slo-ms MS]
//!                  [--client-trace FILE] [--trace-out FILE] [--metrics-out FILE]
//! wienna cluster   [--packages N] [--shards N] [--threads N] [--mix ...] [--policy ...]
//!                  [--load F | --rps R | --closed-loop N | --client-trace FILE]
//!                  [--steal] [--epoch-cycles N] [--adaptive-epochs] [--scheduler calendar|legacy]
//!                  [--queue-cap N|none] [--no-shed-late]
//!                  [--no-preempt] [--faults SPEC] [--contention F] [--bounded-stats]
//!                  [--quantile-error EPS] [--stats-json FILE] [--trace-out FILE]
//!                  [--metrics-out FILE(.jsonl streams)|tcp://HOST:PORT|-]
//! wienna report    <metrics.json|.jsonl|stats.json> [--trace FILE] [--top N]   (artifact analyzer)
//! wienna report    --diff A B [--tolerance F] [--phase-tolerance F] [--occupancy-tolerance F]
//! wienna watch     <tcp://HOST:PORT|FILE.jsonl|-> [--top N] [--raw] [--no-clear] [--once]
//! wienna e2e       [--artifacts DIR] [--batch N] [--chiplets N] [--strategy ...]
//! wienna sim-validate [--chiplets N]
//! wienna breakdown [--chiplets N] [--wireless-bw B]
//! ```
//!
//! (The CLI is hand-rolled: the build environment is offline and `clap`
//! is not in the vendored crate set.)

use std::collections::HashMap;
use std::io::Write as _;
use wienna::anyhow;
use wienna::config::{DesignPoint, SystemConfig};
use wienna::coordinator::collective::simulate_distribution;
use wienna::coordinator::{Coordinator, StrategyPolicy};
use wienna::cost::{evaluate_model, CostEngine};
use wienna::dataflow::Strategy;
use wienna::energy::AreaPowerBreakdown;
use wienna::report::Table;
use wienna::serve::{
    ms_to_cycles, Fleet, MixEntry, ModelKind, PackageSpec, RoutePolicy, ServeStats, Source,
    WorkloadMix,
};
use wienna::workload::{resnet50::resnet50, tiny::tiny_cnn, unet::unet, Model};

const USAGE: &str = "usage: wienna <simulate|sweep|serve|cluster|search|e2e|sim-validate|breakdown|report|watch> [--flag value ...]
  simulate      cost-model run of a workload on one design point
  sweep         Fig-8-style cluster-size sweep (fixed 16384 PEs)
  serve         request-serving simulation on a package fleet
  cluster       sharded multi-tenant serving simulation (priority classes + admission control)
  search        auto-size the cheapest fleet meeting an SLO at a load
  e2e           real-numerics inference through the PJRT artifacts (needs --features pjrt)
  sim-validate  analytical mesh model vs cycle-level simulator
  breakdown     Table-3 area/power breakdown
  report        condensed Fig-7/Fig-9 evaluation of one workload, or — with a positional
                path — offline analysis of an emitted metrics artifact:
                report <metrics.json|.jsonl> [--trace FILE] [--top N]
                report --diff A B [--tolerance F] [--phase-tolerance F] [--occupancy-tolerance F]
                compares two artifacts — metrics or --stats-json dumps, mixed freely —
                and exits nonzero on a regression past tolerance
  watch         live text dashboard over a wienna-metrics-stream-v1 stream:
                watch <tcp://HOST:PORT|FILE.jsonl|-> [--top N] [--raw] [--no-clear] [--once]
                (tcp:// listens and keeps serving run after run; --once exits after the
                first stream, --raw implies it; start watch first, then the run with
                --metrics-out tcp://...)
common flags: --workload resnet50|unet|tiny|mlp|rnn|bert|<file>.trace
              --design interposer-c|interposer-a|wienna-c|wienna-a
              --strategy kp-cp|np-cp|yp-xp|adaptive  --batch N  --chiplets N  --verbose
              --artifacts DIR  --wireless-bw B
serve flags:  --mix cnn|mixed|resnet50|bert  --packages N  --policy rr|ll|edf
              --load F (fraction of fleet capacity)  --duration-ms MS  --slo-ms MS  --seed N
              --power-cap-w W (fleet power cap; DVFS governor)  --no-power-gating
              --client-trace FILE (closed-loop replay of recorded per-client timestamps;
              the trace sets the load and the run drains it fully — ignores --load/--duration-ms)
              --trace-out FILE (Chrome trace-event JSON; load in Perfetto or chrome://tracing)
              --metrics-out FILE (metrics-registry JSON: latency/queue-wait/batch histograms,
              cycle attribution, layer-memo counters)
              --bounded-stats (histogram-backed percentiles, no per-request latency vectors)
              --quantile-error EPS (bounded-stats percentile resolution: relative error <= EPS,
              default 0.01)
cluster flags: --packages N  --shards N  --threads N  --design ...  --policy rr|ll|edf  --mix ...
              --slo-ms MS  --load F (x capacity) | --rps R (absolute)  --duration-ms MS  --seed N
              --queue-cap N|none  --no-shed-late  --no-preempt  --stats-json FILE  --verbose
              --power-cap-w W (statically partitioned across shards)  --no-power-gating
              --calibrated-eta (fold in-class batching gains into the deadline-shed estimate)
              --closed-loop N (N closed-loop clients instead of the Poisson source; drains fully,
              ignores --load/--rps/--duration-ms)  --think-ms MS  --requests-per-client N
              --client-trace FILE (closed-loop replay of recorded per-client timestamps)
              --steal (epoch-barrier cross-shard work stealing; also enables failover re-routing
              of a dead shard's queue to survivors under --faults)
              --epoch-cycles N (sync window width; feedback + stealing cross shards at its edges)
              --adaptive-epochs (size each window to the earliest cross-shard event instead of
              a fixed width: fewer barriers at low load, same per-thread determinism)
              --scheduler calendar|legacy (per-shard event engine; default calendar — the
              bucketed completion calendar; legacy is the O(packages)-scan oracle)
              --faults SPEC (seeded chaos plan, ';'-separated, times in ms, '..END' optional:
              kill:PKG@T[..T2]  degrade:PKG:FACTOR@T[..T2]  stall:SHARD@T[..T2]  spike:LOAD@T[..T2];
              deterministic — stats stay byte-identical at any --threads)
              --contention F (shared-medium MAC background load in [0,1): stretches the dist phase
              via token-queueing delay; sheds best-effort when the medium saturates)
              --trace-out FILE (Chrome trace-event JSON of the merged span log; Perfetto-loadable)
              --metrics-out FILE (metrics-registry JSON incl. per-epoch gauges, per-package MAC
              occupancy and SLO burn-rate events; byte-identical at any --threads; a .jsonl
              suffix streams wienna-metrics-stream-v1 lines incrementally at each epoch barrier;
              tcp://HOST:PORT exports the same lines live over a non-blocking socket — pair with
              `wienna watch tcp://...`, started first; '-' streams to stdout ahead of the report)
              --bounded-stats (O(sketch buckets+epochs) telemetry: percentiles come off
              mergeable quantile sketches — relative error <= --quantile-error — and the
              per-request latency vectors are never grown)
              --quantile-error EPS (bounded-stats sketch resolution, in (0,1); default 0.01;
              per-shard sketches merge deterministically at each epoch barrier)
search flags: --slo MS  --load RPS (absolute)  --mix cnn|mixed|resnet50|bert
              --duration-ms MS (per probe)  --max-width N  --threads N  --seed N
              --class-slos I,B,E (per-class p99 targets in ms, 'inf' allowed; sizes on the
              cluster engine against the SLO vector)  --no-prune (exhaustive)  --verbose
              --pareto (emit the cost x energy/request x p99 non-dominated front)";

/// Parsed flags: `--key value` pairs plus bare `--switch`es.
struct Flags(HashMap<String, String>);

impl Flags {
    fn parse(args: &[String]) -> anyhow::Result<Self> {
        let mut m = HashMap::new();
        let mut i = 0;
        while i < args.len() {
            let a = &args[i];
            let key = a
                .strip_prefix("--")
                .ok_or_else(|| anyhow::anyhow!("unexpected argument '{a}'\n{USAGE}"))?;
            if key == "verbose"
                || key == "no-prune"
                || key == "no-shed-late"
                || key == "no-preempt"
                || key == "no-power-gating"
                || key == "calibrated-eta"
                || key == "pareto"
                || key == "steal"
                || key == "bounded-stats"
                || key == "adaptive-epochs"
            {
                m.insert(key.to_string(), "true".to_string());
                i += 1;
            } else {
                let v = args.get(i + 1).ok_or_else(|| anyhow::anyhow!("--{key} needs a value"))?;
                m.insert(key.to_string(), v.clone());
                i += 2;
            }
        }
        Ok(Flags(m))
    }

    fn str(&self, key: &str, default: &str) -> String {
        self.0.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    fn u64(&self, key: &str, default: u64) -> anyhow::Result<u64> {
        match self.0.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow::anyhow!("--{key}: bad number '{v}'")),
        }
    }

    fn f64(&self, key: &str, default: f64) -> anyhow::Result<f64> {
        match self.0.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow::anyhow!("--{key}: bad number '{v}'")),
        }
    }

    fn flag(&self, key: &str) -> bool {
        self.0.contains_key(key)
    }
}

fn parse_workload(s: &str, batch: u64) -> anyhow::Result<Model> {
    Ok(match s {
        "resnet50" => resnet50(batch),
        "unet" => unet(batch),
        "tiny" => tiny_cnn(batch),
        "mlp" => wienna::workload::mlp::mlp(batch, 784, 4096, 4, 1000),
        "rnn" => wienna::workload::mlp::rnn_unrolled(batch, 1024, 16),
        "bert" => wienna::workload::transformer::bert_base(batch),
        path if path.ends_with(".trace") => wienna::workload::trace::load(std::path::Path::new(path))?,
        _ => anyhow::bail!("unknown workload '{s}' (resnet50|unet|tiny|mlp|rnn|bert|<file>.trace)"),
    })
}

fn parse_design(s: &str) -> anyhow::Result<DesignPoint> {
    Ok(match s {
        "interposer-c" => DesignPoint::INTERPOSER_C,
        "interposer-a" => DesignPoint::INTERPOSER_A,
        "wienna-c" => DesignPoint::WIENNA_C,
        "wienna-a" => DesignPoint::WIENNA_A,
        _ => anyhow::bail!("unknown design point '{s}'"),
    })
}

fn parse_policy(s: &str) -> anyhow::Result<StrategyPolicy> {
    Ok(match s {
        "kp-cp" => StrategyPolicy::Fixed(Strategy::KpCp),
        "np-cp" => StrategyPolicy::Fixed(Strategy::NpCp),
        "yp-xp" => StrategyPolicy::Fixed(Strategy::YpXp),
        "adaptive" => StrategyPolicy::Adaptive,
        _ => anyhow::bail!("unknown strategy '{s}'"),
    })
}

fn cmd_simulate(f: &Flags) -> anyhow::Result<()> {
    let sys = SystemConfig { num_chiplets: f.u64("chiplets", 256)?, ..Default::default() };
    let model = parse_workload(&f.str("workload", "resnet50"), f.u64("batch", 64)?)?;
    let coord = Coordinator::new(sys, parse_design(&f.str("design", "wienna-c"))?, parse_policy(&f.str("strategy", "adaptive"))?);
    let (schedules, sum) = coord.run_model(&model);
    if f.flag("verbose") {
        let mut t = Table::new(
            &format!("{} on {} ({})", model.name, sum.design_point, sum.policy),
            &["layer", "type", "strategy", "chiplets", "latency(cyc)", "MACs/cyc", "bottleneck"],
        );
        for s in &schedules {
            let c = &s.selection.cost;
            t.row(vec![
                c.layer_name.to_string(),
                c.layer_type.label().into(),
                c.strategy.label().into(),
                c.used_chiplets.to_string(),
                format!("{:.0}", c.latency),
                format!("{:.0}", c.macs_per_cycle),
                c.bottleneck().label().into(),
            ]);
        }
        print!("{}", t.render());
    }
    println!(
        "{} | {} | {} | {:.0} MACs/cyc | {:.3} ms | {:.3} mJ dist-energy",
        sum.model_name, sum.design_point, sum.policy, sum.macs_per_cycle, sum.latency_ms, sum.dist_energy_mj
    );
    Ok(())
}

fn cmd_sweep(f: &Flags) -> anyhow::Result<()> {
    let model = parse_workload(&f.str("workload", "resnet50"), f.u64("batch", 64)?)?;
    let mut t = Table::new(&format!("Fig-8 style sweep: {}", model.name), &["chiplets", "PEs/chiplet", "KP-CP", "NP-CP", "YP-XP"]);
    for nc in [32u64, 64, 128, 256, 512, 1024] {
        let sys = SystemConfig::with_chiplets(nc);
        let e = CostEngine::for_design_point(&sys, DesignPoint::WIENNA_C);
        let row: Vec<String> = Strategy::ALL
            .iter()
            .map(|&s| format!("{:.0}", evaluate_model(&e, &model, Some(s)).macs_per_cycle))
            .collect();
        t.row(vec![nc.to_string(), sys.pes_per_chiplet.to_string(), row[0].clone(), row[1].clone(), row[2].clone()]);
    }
    print!("{}", t.render());
    Ok(())
}

#[cfg(feature = "pjrt")]
fn cmd_e2e(f: &Flags) -> anyhow::Result<()> {
    use wienna::coordinator::exec::Tensor;
    use wienna::coordinator::PackageExecutor;
    use wienna::runtime::ExecutableCache;

    let sys = SystemConfig { num_chiplets: f.u64("chiplets", 16)?, ..Default::default() };
    let batch = f.u64("batch", 1)?;
    let artifacts = f.str("artifacts", "artifacts");
    let cache = std::sync::Arc::new(ExecutableCache::new(std::path::Path::new(&artifacts))?);
    println!("platform: {} | artifacts: {}", cache.platform(), cache.specs().len());
    cache.warm_up()?;
    let coord = Coordinator::new(sys, DesignPoint::WIENNA_C, parse_policy(&f.str("strategy", "adaptive"))?);
    let mut exec = PackageExecutor::new(coord, cache);
    let model = tiny_cnn(batch);
    let input = Tensor::from_fn(batch as usize, 16, 32, 32, |n, c, y, x| {
        ((n * 7 + c * 5 + y * 3 + x) % 17) as f32 * 0.05 - 0.4
    });
    let report = exec.run_model(&model, &input)?;
    for l in &report.layers {
        println!(
            "  {:<12} {:<6} tiles={:<4} chiplets={:<3} model-cycles={:<10.0} wall={:.0}us",
            l.layer_name, l.strategy, l.tiles_dispatched, l.chiplets_used, l.model_cycles, l.wall_us
        );
    }
    println!(
        "e2e: {} | max|err| = {:.3e} | {} outputs | {:.1} ms wall | {:.0} model cycles",
        report.model_name, report.max_abs_err, report.output_len, report.total_wall_ms, report.total_model_cycles
    );
    anyhow::ensure!(report.max_abs_err < 1e-3, "numerics mismatch vs oracle");
    println!("NUMERICS OK (XLA path == naive oracle)");
    Ok(())
}

fn parse_route(s: &str) -> anyhow::Result<RoutePolicy> {
    Ok(match s {
        "rr" | "round-robin" => RoutePolicy::RoundRobin,
        "ll" | "least-loaded" => RoutePolicy::LeastLoaded,
        "edf" | "earliest-deadline" => RoutePolicy::EarliestDeadline,
        _ => anyhow::bail!("unknown routing policy '{s}' (rr|ll|edf)"),
    })
}

fn parse_mix(s: &str, slo_ms: f64) -> anyhow::Result<WorkloadMix> {
    let e = |kind, weight, slo: f64| MixEntry { kind, weight, slo_cycles: ms_to_cycles(slo) };
    Ok(match s {
        "resnet50" => WorkloadMix::single(ModelKind::ResNet50, slo_ms),
        "bert" => WorkloadMix::single(ModelKind::BertBase, slo_ms),
        "cnn" => WorkloadMix::new(vec![
            e(ModelKind::ResNet50, 2.0, slo_ms),
            e(ModelKind::UNet, 1.0, 2.0 * slo_ms),
        ]),
        "mixed" => WorkloadMix::new(vec![
            e(ModelKind::ResNet50, 2.0, slo_ms),
            e(ModelKind::UNet, 1.0, 2.0 * slo_ms),
            e(ModelKind::BertBase, 1.0, slo_ms),
        ]),
        _ => anyhow::bail!("unknown mix '{s}' (cnn|mixed|resnet50|bert)"),
    })
}

/// Shared `--power-cap-w` / `--no-power-gating` parsing for serve and
/// cluster.
fn parse_power(f: &Flags) -> anyhow::Result<wienna::power::PowerConfig> {
    let mut power = wienna::power::PowerConfig::default();
    if let Some(w) = f.0.get("power-cap-w") {
        let w: f64 = w.parse().map_err(|_| anyhow::anyhow!("--power-cap-w: bad number '{w}'"))?;
        anyhow::ensure!(w > 0.0, "--power-cap-w must be positive (watts)");
        power.cap_w = Some(w);
    }
    if f.flag("no-power-gating") {
        power.model.power_gating = false;
    }
    Ok(power)
}

/// Pin non-finite derived stats (zero-completion runs have NaN
/// percentiles) to 0 in human-readable output — the same zero-guard the
/// JSON emitters apply.
fn z(v: f64) -> f64 {
    if v.is_finite() {
        v
    } else {
        0.0
    }
}

/// One-line energy telemetry summary shared by serve and cluster.
fn energy_line(e: &wienna::power::FleetEnergy, completed: u64, end_cycle: f64) -> String {
    format!(
        "energy {:.1} mJ (dynamic {:.1} + leakage {:.1}) | {:.2} mJ/req | avg power {:.1} W | throttled {} batches",
        e.total_mj(),
        e.dynamic_mj(),
        e.leakage_mj,
        e.energy_per_req_j(completed) * 1e3,
        e.avg_power_w(end_cycle),
        e.throttled_batches,
    )
}

fn cmd_serve(f: &Flags) -> anyhow::Result<()> {
    let packages = f.u64("packages", 4)? as usize;
    let dp = parse_design(&f.str("design", "wienna-c"))?;
    let policy = parse_route(&f.str("policy", "edf"))?;
    let load = f.f64("load", 0.8)?;
    let duration_ms = f.f64("duration-ms", 100.0)?;
    let slo_ms = f.f64("slo-ms", 25.0)?;
    anyhow::ensure!(packages >= 1, "--packages must be >= 1");
    anyhow::ensure!(load > 0.0, "--load must be positive");
    anyhow::ensure!(duration_ms > 0.0, "--duration-ms must be positive");
    anyhow::ensure!(slo_ms > 0.0, "--slo-ms must be positive");
    let mix = parse_mix(&f.str("mix", "cnn"), slo_ms)?;

    let telemetry_on = f.0.contains_key("trace-out") || f.0.contains_key("metrics-out");
    let mut fleet =
        Fleet::new(PackageSpec::homogeneous(packages, dp), policy).with_power(parse_power(f)?);
    if telemetry_on {
        fleet.recorder = wienna::telemetry::Recorder::new(true);
    }
    let capacity = fleet.estimate_capacity_rps(&mix, 8);
    // A recorded client trace replaces the Poisson source: closed-loop
    // replay of per-client issue timestamps (the trace sets the load, so
    // --load is ignored and the run ends when the trace drains).
    let (mut source, horizon, offered) = match f.0.get("client-trace") {
        Some(path) => {
            if f.0.contains_key("load") || f.0.contains_key("duration-ms") {
                eprintln!(
                    "note: --load/--duration-ms are ignored with --client-trace — the recorded \
                     trace sets the load and the run ends when it drains"
                );
            }
            let clients = wienna::workload::trace::load_arrivals(std::path::Path::new(path))?;
            let recorded: usize = clients.iter().map(|c| c.len()).sum();
            let offered =
                format!("replaying {} clients / {recorded} recorded requests from {path}", clients.len());
            (Source::client_trace(mix, &clients, f.u64("seed", 42)?), f64::INFINITY, offered)
        }
        None => {
            let rate = capacity * load;
            let offered = format!("offered {rate:.0} req/s ({load:.2}x)");
            (Source::poisson(mix, rate, f.u64("seed", 42)?), ms_to_cycles(duration_ms), offered)
        }
    };
    let quantile_error =
        f.f64("quantile-error", wienna::telemetry::DEFAULT_QUANTILE_ERROR)?;
    anyhow::ensure!(
        quantile_error > 0.0 && quantile_error < 1.0,
        "--quantile-error must be in (0, 1)"
    );
    let mut stats = if f.flag("bounded-stats") {
        ServeStats::bounded_with(quantile_error)
    } else {
        ServeStats::new()
    };
    let end = fleet.run(&mut source, horizon, &mut stats);

    println!(
        "fleet: {packages} x {} | policy {} | est. capacity {capacity:.0} req/s | {offered}",
        dp.label(),
        policy.label()
    );
    println!(
        "served {} requests in {:.1} ms simulated | p50 {:.2} ms  p95 {:.2} ms  p99 {:.2} ms",
        stats.completed(),
        wienna::serve::cycles_to_ms(end),
        z(stats.latency_ms(50.0)),
        z(stats.latency_ms(95.0)),
        z(stats.latency_ms(99.0)),
    );
    println!(
        "throughput {:.0} req/s | goodput {:.0} req/s | SLO violations {:.1}% | mean batch {:.2} (max {})",
        z(stats.throughput_rps()),
        z(stats.goodput_rps()),
        z(stats.violation_rate()) * 100.0,
        z(stats.mean_batch()),
        stats.max_batch(),
    );
    if let Some(e) = &stats.energy {
        println!("{}", energy_line(e, stats.completed(), end));
    }
    if f.flag("verbose") {
        let mut t = Table::new(
            "per-package accounting",
            &["package", "completed", "batches", "mean batch", "busy %", "dist-plane %", "compute %"],
        );
        for p in &fleet.packages {
            t.row(vec![
                p.spec.name.clone(),
                p.requests_completed.to_string(),
                p.batches_dispatched.to_string(),
                format!("{:.2}", p.mean_batch()),
                format!("{:.1}", p.utilization(end) * 100.0),
                format!("{:.1}", p.dist_plane_utilization(end) * 100.0),
                format!("{:.1}", p.compute_utilization(end) * 100.0),
            ]);
        }
        print!("{}", t.render());
        println!("cost cache: {} entries, {} hits, {} misses", fleet.cache.len(), fleet.cache.hits, fleet.cache.misses);
    }
    if telemetry_on {
        let mut tele = wienna::telemetry::Telemetry {
            log: fleet.recorder.take_log(),
            ..Default::default()
        };
        tele.finish();
        if let Some(path) = f.0.get("trace-out") {
            std::fs::write(path, wienna::telemetry::chrome_trace(&tele))
                .map_err(|e| anyhow::anyhow!("writing {path}: {e}"))?;
            println!("chrome trace -> {path} (load in Perfetto or chrome://tracing)");
        }
        if let Some(path) = f.0.get("metrics-out") {
            let memo = wienna::cost::memo::stats();
            // Bounded-stats runs carry the fleet latency sketch at full
            // resolution so `wienna report` answers the same quantiles
            // the stats line printed.
            let mut sketches: Vec<wienna::telemetry::NamedSketch> = Vec::new();
            if let Some(sk) = stats.latency_sketch() {
                sketches.push(("latency_ms".to_string(), sk));
            }
            let json = wienna::telemetry::metrics_json_with(
                &tele,
                &stats.attr,
                None,
                Some(memo),
                &sketches,
            );
            std::fs::write(path, json).map_err(|e| anyhow::anyhow!("writing {path}: {e}"))?;
            println!(
                "metrics json -> {path} | layer memo: {} hits / {} misses / {} evictions ({} entries, cap {})",
                memo.hits, memo.misses, memo.evictions, memo.entries, memo.capacity
            );
        }
    }
    Ok(())
}

/// Backlog cap for the live tcp metrics export: ~4 MiB of queued lines
/// before the non-blocking sink starts dropping oldest-first.
const TCP_STREAM_BACKLOG_BYTES: usize = 4 << 20;

/// Where `--metrics-out` stream lines go: a file (`.jsonl`), stdout
/// (`-`), or a live non-blocking socket (`tcp://HOST:PORT`).
enum StreamSink {
    File(std::fs::File),
    Stdout(std::io::Stdout),
    Tcp(wienna::telemetry::NonBlockingLineSink<std::net::TcpStream>),
}

impl std::io::Write for StreamSink {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            StreamSink::File(f) => f.write(buf),
            StreamSink::Stdout(s) => s.write(buf),
            StreamSink::Tcp(t) => t.write(buf),
        }
    }

    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            StreamSink::File(f) => f.flush(),
            StreamSink::Stdout(s) => s.flush(),
            StreamSink::Tcp(t) => t.flush(),
        }
    }
}

fn cmd_cluster(f: &Flags) -> anyhow::Result<()> {
    use wienna::cluster::{AdmissionConfig, Cluster, ClusterConfig, SyncConfig};

    let packages = f.u64("packages", 16)? as usize;
    let shards = f.u64("shards", 4)? as usize;
    let dp = parse_design(&f.str("design", "wienna-c"))?;
    let policy = parse_route(&f.str("policy", "edf"))?;
    let load = f.f64("load", 0.8)?;
    let duration_ms = f.f64("duration-ms", 100.0)?;
    let slo_ms = f.f64("slo-ms", 25.0)?;
    anyhow::ensure!(packages >= 1, "--packages must be >= 1");
    anyhow::ensure!(shards >= 1, "--shards must be >= 1");
    anyhow::ensure!(load > 0.0, "--load must be positive");
    anyhow::ensure!(duration_ms > 0.0, "--duration-ms must be positive");
    anyhow::ensure!(slo_ms > 0.0, "--slo-ms must be positive");
    // Default the CLI cap to the library default so the two can't drift.
    let default_cap =
        AdmissionConfig::default().queue_cap.map_or("none".to_string(), |c| c.to_string());
    let queue_cap = match f.str("queue-cap", &default_cap).as_str() {
        "none" => None,
        v => Some(v.parse::<usize>().map_err(|_| anyhow::anyhow!("--queue-cap: bad value '{v}' (number or 'none')"))?),
    };
    let mix = parse_mix(&f.str("mix", "mixed"), slo_ms)?;
    let mix_kinds: Vec<ModelKind> = mix.entries.iter().map(|e| e.kind).collect();
    let bounded = f.flag("bounded-stats");
    let quantile_error =
        f.f64("quantile-error", wienna::telemetry::DEFAULT_QUANTILE_ERROR)?;
    anyhow::ensure!(
        quantile_error > 0.0 && quantile_error < 1.0,
        "--quantile-error must be in (0, 1)"
    );
    let trace_on = f.0.contains_key("trace-out");
    // --bounded-stats arms the registry even without an export path: the
    // histograms ARE the percentile source in that mode.
    let telemetry_on = trace_on || f.0.contains_key("metrics-out") || bounded;

    let mut sync = SyncConfig {
        steal: f.flag("steal"),
        adaptive: f.flag("adaptive-epochs"),
        ..Default::default()
    };
    if let Some(e) = f.0.get("epoch-cycles") {
        sync.epoch_cycles =
            e.parse().map_err(|_| anyhow::anyhow!("--epoch-cycles: bad number '{e}'"))?;
        anyhow::ensure!(
            sync.epoch_cycles > 0.0 && sync.epoch_cycles.is_finite(),
            "--epoch-cycles must be positive and finite"
        );
    }
    let scheduler = match f.str("scheduler", "calendar").as_str() {
        "calendar" => wienna::cluster::SchedulerKind::Calendar,
        "legacy" => wienna::cluster::SchedulerKind::Legacy,
        other => anyhow::bail!("--scheduler: unknown engine '{other}' (calendar|legacy)"),
    };
    let mut cfg = ClusterConfig {
        shards,
        policy,
        preemption: !f.flag("no-preempt"),
        admission: AdmissionConfig { queue_cap, shed_late: !f.flag("no-shed-late") },
        sync,
        scheduler,
        power: parse_power(f)?,
        calibrated_eta: f.flag("calibrated-eta"),
        telemetry: wienna::telemetry::TelemetryConfig {
            enabled: telemetry_on,
            // Spans are the one O(requests) surface: on for --trace-out,
            // otherwise only in the exact (un-bounded) mode, where
            // Telemetry::finish feeds the histograms from them.
            spans: trace_on || (telemetry_on && !bounded),
            bounded,
            quantile_error,
            ..Default::default()
        },
        ..Default::default()
    };
    if let Some(t) = f.0.get("threads") {
        cfg.threads = t.parse().map_err(|_| anyhow::anyhow!("--threads: bad number '{t}'"))?;
    }
    if let Some(spec) = f.0.get("faults") {
        cfg.faults = wienna::fault::FaultPlan::parse(spec)?;
    }
    if let Some(bg) = f.0.get("contention") {
        let bg: f64 =
            bg.parse().map_err(|_| anyhow::anyhow!("--contention: bad number '{bg}'"))?;
        anyhow::ensure!(
            (0.0..1.0).contains(&bg),
            "--contention must be a background load in [0, 1)"
        );
        cfg.contention = wienna::fault::ContentionConfig::with_background(bg);
    }
    let chaos_on = !cfg.faults.is_empty() || cfg.contention.enabled;
    let threads = cfg.threads;
    let seed = f.u64("seed", 42)?;

    let specs = PackageSpec::homogeneous(packages, dp);
    // Source: a recorded client trace or a synthetic closed-loop client
    // pool replace the open-loop Poisson process; both set their own load
    // and the run ends when they drain.
    let (mut source, horizon, offered) = if let Some(path) = f.0.get("client-trace") {
        if f.0.contains_key("load") || f.0.contains_key("rps") || f.0.contains_key("duration-ms") {
            eprintln!(
                "note: --load/--rps/--duration-ms are ignored with --client-trace — the recorded \
                 trace sets the load and the run ends when it drains"
            );
        }
        let clients = wienna::workload::trace::load_arrivals(std::path::Path::new(path))?;
        let recorded: usize = clients.iter().map(|c| c.len()).sum();
        let offered =
            format!("replaying {} clients / {recorded} recorded requests from {path}", clients.len());
        (Source::client_trace(mix, &clients, seed), f64::INFINITY, offered)
    } else if let Some(c) = f.0.get("closed-loop") {
        if f.0.contains_key("load") || f.0.contains_key("rps") || f.0.contains_key("duration-ms") {
            eprintln!(
                "note: --load/--rps/--duration-ms are ignored with --closed-loop — client \
                 pushback sets the load and the run ends when every client finishes"
            );
        }
        let clients: usize =
            c.parse().map_err(|_| anyhow::anyhow!("--closed-loop: bad client count '{c}'"))?;
        anyhow::ensure!(clients >= 1, "--closed-loop needs at least one client");
        let think_ms = f.f64("think-ms", 2.0)?;
        anyhow::ensure!(think_ms >= 0.0, "--think-ms must be >= 0");
        let per_client = f.u64("requests-per-client", 64)?;
        anyhow::ensure!(per_client >= 1, "--requests-per-client must be >= 1");
        let offered =
            format!("closed loop: {clients} clients x {per_client} requests, think {think_ms} ms");
        (Source::closed_loop(mix, clients, think_ms, per_client, seed), f64::INFINITY, offered)
    } else {
        // Offered rate: absolute --rps, or --load as a fraction of the
        // fleet's estimated capacity.
        let rate = match f.0.get("rps") {
            Some(r) => r.parse::<f64>().map_err(|_| anyhow::anyhow!("--rps: bad number '{r}'"))?,
            None => Fleet::new(specs.clone(), policy).estimate_capacity_rps(&mix, 8) * load,
        };
        anyhow::ensure!(rate > 0.0, "offered rate must be positive");
        let offered = format!("offered {rate:.0} req/s for {duration_ms:.0} ms");
        (Source::poisson(mix, rate, seed), ms_to_cycles(duration_ms), offered)
    };

    if f.0.contains_key("metrics-out") {
        // The global layer memo is the one piece of state shards share
        // across threads: sweep its (model, batch) grid single-threaded
        // up front so the parallel run only ever hits, keeping the
        // exported hit/miss counters byte-identical at any --threads.
        wienna::telemetry::prewarm_cost_model(&specs, &mix_kinds, &cfg.batcher);
    }
    let cluster = Cluster::new(specs, cfg);
    // --metrics-out selects its sink by shape: a .jsonl suffix streams
    // wienna-metrics-stream-v1 lines to a file at each epoch barrier, a
    // tcp://HOST:PORT target exports the same lines live over a
    // non-blocking socket (a `wienna watch` listener, started first),
    // '-' streams to stdout, and anything else buffers the run and
    // writes the wienna-metrics-v1 JSON at the end.
    let metrics_path = f.0.get("metrics-out").cloned();
    let streaming = metrics_path
        .as_deref()
        .is_some_and(|p| p.ends_with(".jsonl") || p.starts_with("tcp://") || p == "-");
    let mut stream_dropped: Option<u64> = None;
    let t0 = std::time::Instant::now();
    let stats = if streaming {
        let path = metrics_path.as_deref().expect("streaming implies a path");
        let mut sink = if let Some(addr) = path.strip_prefix("tcp://") {
            let conn = std::net::TcpStream::connect(addr)
                .map_err(|e| anyhow::anyhow!("connecting to {path} (is `wienna watch {path}` listening?): {e}"))?;
            // Nagle off so each epoch line leaves promptly; non-blocking
            // so a stalled consumer can never stall the epoch barrier
            // (the bounded sink drops oldest lines instead).
            let _ = conn.set_nodelay(true);
            conn.set_nonblocking(true)
                .map_err(|e| anyhow::anyhow!("setting {path} non-blocking: {e}"))?;
            StreamSink::Tcp(wienna::telemetry::NonBlockingLineSink::new(
                conn,
                TCP_STREAM_BACKLOG_BYTES,
            ))
        } else if path == "-" {
            StreamSink::Stdout(std::io::stdout())
        } else {
            StreamSink::File(
                std::fs::File::create(path)
                    .map_err(|e| anyhow::anyhow!("creating {path}: {e}"))?,
            )
        };
        let mut w = wienna::telemetry::MetricsStreamWriter::new(&mut sink);
        let stats = cluster.run_streaming(&mut source, horizon, &mut w);
        w.write_summary(&stats.metrics_json_summary(Some(wienna::cost::memo::stats())));
        w.finish().map_err(|e| anyhow::anyhow!("writing {path}: {e}"))?;
        if let StreamSink::Tcp(t) = sink {
            // Post-run grace drain; whatever the consumer still hasn't
            // taken after the deadline is dropped and reported below.
            let (_, dropped) = t.finish(std::time::Duration::from_secs(5));
            stream_dropped = Some(dropped);
        }
        stats
    } else {
        cluster.run(&mut source, horizon)
    };
    let wall = t0.elapsed().as_secs_f64();

    println!(
        "cluster: {packages} x {} in {} shards ({} threads) | policy {} | {offered}",
        dp.label(),
        cluster.shards(),
        threads,
        policy.label()
    );
    println!(
        "arrived {} | completed {} | shed {} (queue-full {}, deadline {}, overload {}) | preemptions {} | steals {} over {} epochs | {:.1} ms wall",
        stats.serve.arrived(),
        stats.serve.completed(),
        stats.serve.shed(),
        stats.shed_queue_full,
        stats.shed_deadline,
        stats.shed_overload,
        stats.preemptions,
        stats.steals,
        stats.epochs,
        wall * 1e3,
    );
    println!(
        "p50 {:.2} ms  p95 {:.2} ms  p99 {:.2} ms | goodput {:.0} req/s | violations {:.1}% | mean batch {:.2}",
        z(stats.serve.latency_ms(50.0)),
        z(stats.serve.latency_ms(95.0)),
        z(stats.serve.latency_ms(99.0)),
        z(stats.serve.goodput_rps()),
        z(stats.serve.violation_rate()) * 100.0,
        z(stats.serve.mean_batch()),
    );
    if telemetry_on {
        let (raised, active) = stats.slo_alert_counts();
        println!(
            "slo burn-rate alerts: {raised} raised, {active} still active{}",
            if stats.is_bounded() {
                " | bounded stats: sketch percentiles (relative error <= --quantile-error)"
            } else {
                ""
            }
        );
    }
    if chaos_on {
        println!(
            "chaos: failed {} | retries {} | reroutes {} | tail amplification {:.2}x | failover goodput {:.0} req/s | dead-shard drain {:.2} ms",
            stats.serve.failed(),
            stats.retries(),
            stats.reroutes(),
            stats.tail_amplification(),
            stats.failover_goodput_rps(),
            stats.dead_shard_drain_ms(),
        );
    }
    println!("{}", energy_line(&stats.energy, stats.serve.completed(), stats.serve.end_cycle()));
    let mut t = Table::new(
        "per-class SLO accounting",
        &["class", "arrived", "completed", "shed", "failed", "slo met", "violated", "p50 ms", "p99 ms", "energy mJ"],
    );
    for (class, m) in &stats.per_class {
        t.row(vec![
            class.label().to_string(),
            m.arrived.to_string(),
            m.completed.to_string(),
            m.shed.to_string(),
            m.failed.to_string(),
            m.slo_met.to_string(),
            m.slo_violated.to_string(),
            format!("{:.2}", z(stats.class_latency_ms(*class, 50.0))),
            format!("{:.2}", z(stats.class_latency_ms(*class, 99.0))),
            format!("{:.1}", stats.class_energy_mj[class.index()]),
        ]);
    }
    print!("{}", t.render());
    if f.flag("verbose") {
        let end = stats.serve.end_cycle();
        let mut t = Table::new(
            "per-package accounting (shard-major order)",
            &["package", "completed", "batches", "mean batch", "busy %", "dist-plane %"],
        );
        for p in &stats.packages {
            t.row(vec![
                p.spec.name.clone(),
                p.requests_completed.to_string(),
                p.batches_dispatched.to_string(),
                format!("{:.2}", p.mean_batch()),
                format!("{:.1}", p.utilization(end) * 100.0),
                format!("{:.1}", p.dist_plane_utilization(end) * 100.0),
            ]);
        }
        print!("{}", t.render());
        let memo = wienna::cost::memo::stats();
        println!(
            "shard cost caches: {} hits / {} misses | layer memo: {} entries (cap {}), {:.1}% hit rate, {} evictions",
            stats.cache_hits,
            stats.cache_misses,
            memo.entries,
            memo.capacity,
            memo.hit_rate() * 100.0,
            memo.evictions
        );
    }
    if let Some(path) = f.0.get("stats-json") {
        std::fs::write(path, stats.to_json())
            .map_err(|e| anyhow::anyhow!("writing {path}: {e}"))?;
        println!("stats json -> {path}");
    }
    if let Some(path) = f.0.get("trace-out") {
        std::fs::write(path, stats.chrome_trace())
            .map_err(|e| anyhow::anyhow!("writing {path}: {e}"))?;
        println!("chrome trace -> {path} (load in Perfetto or chrome://tracing)");
    }
    if let Some(path) = f.0.get("metrics-out") {
        let memo = wienna::cost::memo::stats();
        if !streaming {
            std::fs::write(path, stats.metrics_json(Some(memo)))
                .map_err(|e| anyhow::anyhow!("writing {path}: {e}"))?;
        }
        let desc = if path.starts_with("tcp://") {
            "stream (wienna-metrics-stream-v1, live tcp)"
        } else if path == "-" {
            "stream (wienna-metrics-stream-v1, stdout)"
        } else if streaming {
            "stream (wienna-metrics-stream-v1)"
        } else {
            "json"
        };
        println!(
            "metrics {desc} -> {path} | layer memo: {} hits / {} misses / {} evictions ({} entries, cap {})",
            memo.hits,
            memo.misses,
            memo.evictions,
            memo.entries,
            memo.capacity
        );
        if let Some(dropped) = stream_dropped {
            println!(
                "metrics stream: {dropped} lines dropped{}",
                if dropped > 0 { " (slow or disconnected consumer)" } else { "" }
            );
        }
    }
    Ok(())
}

fn cmd_search(f: &Flags) -> anyhow::Result<()> {
    use wienna::search::{autosize, AutosizeConfig, CostModel, MultiClassSlo, SearchSpace};

    let slo_ms = f.f64("slo", 25.0)?;
    let load_rps = f.f64("load", 3000.0)?;
    anyhow::ensure!(slo_ms > 0.0, "--slo must be positive (milliseconds)");
    anyhow::ensure!(load_rps > 0.0, "--load must be positive (requests/second)");
    let mix = parse_mix(&f.str("mix", "cnn"), slo_ms)?;

    let mut cfg = AutosizeConfig::new(slo_ms, load_rps, mix);
    cfg.horizon_ms = f.f64("duration-ms", 40.0)?;
    cfg.seed = f.u64("seed", 42)?;
    if let Some(t) = f.0.get("threads") {
        cfg.threads = t.parse().map_err(|_| anyhow::anyhow!("--threads: bad number '{t}'"))?;
    }
    cfg.prune = !f.flag("no-prune");
    // --class-slos I,B,E switches to the multi-class mode: probes run on
    // the sharded cluster engine and every class must meet its target.
    if let Some(spec) = f.0.get("class-slos") {
        let parts: Vec<&str> = spec.split(',').collect();
        anyhow::ensure!(
            parts.len() == 3,
            "--class-slos takes three comma-separated p99 targets in ms (interactive,batch,best-effort; 'inf' allowed)"
        );
        let ms = |s: &str| -> anyhow::Result<f64> {
            if s == "inf" {
                Ok(f64::INFINITY)
            } else {
                s.parse::<f64>().map_err(|_| anyhow::anyhow!("--class-slos: bad target '{s}'"))
            }
        };
        cfg.class_slos = Some(MultiClassSlo::with_targets(ms(parts[0])?, ms(parts[1])?, ms(parts[2])?));
    }
    let mut space = SearchSpace::default();
    space.max_width = f.u64("max-width", 32)?;
    let costs = CostModel::default();

    let t0 = std::time::Instant::now();
    let result = autosize(&cfg, &space, &costs);
    let elapsed = t0.elapsed().as_secs_f64();

    println!(
        "searched {} package design points in {elapsed:.2} s ({} pruned analytically, {} serve probes, {} threads)",
        result.explored, result.pruned, result.simulated_runs, cfg.threads
    );
    let memo = wienna::cost::memo::stats();
    println!(
        "cost memo: {} entries | {:.1}% hit rate ({} hits / {} misses)",
        memo.entries,
        memo.hit_rate() * 100.0,
        memo.hits,
        memo.misses
    );
    match &result.best {
        None => println!(
            "no fleet of <= {} packages meets p99 <= {slo_ms} ms at {load_rps:.0} req/s",
            space.max_width
        ),
        Some(best) => {
            println!(
                "cheapest fleet: {} x{} | cost {:.0} | p99 {:.2} ms (SLO {slo_ms} ms) | {:.2} mJ/req | goodput {:.0} req/s | violations {:.2}%",
                best.point.label(),
                best.width,
                best.fleet_cost,
                best.p99_ms,
                best.energy_per_req_j * 1e3,
                best.goodput_rps,
                best.violation_rate * 100.0
            );
            if f.flag("pareto") {
                let mut t = Table::new(
                    "cost x energy x latency Pareto front (non-dominated fleets, cheapest first)",
                    &["package", "width", "cost", "mJ/req", "p99 ms", "goodput req/s"],
                );
                for p in &result.pareto {
                    t.row(vec![
                        p.point.label(),
                        p.width.to_string(),
                        format!("{:.0}", p.fleet_cost),
                        format!("{:.2}", p.energy_per_req_j * 1e3),
                        format!("{:.2}", p.p99_ms),
                        format!("{:.0}", p.goodput_rps),
                    ]);
                }
                print!("{}", t.render());
                println!(
                    "front: {} of {} feasible fleets are non-dominated (cheapest-only answer is a member)",
                    result.pareto.len(),
                    result.plans.len()
                );
            }
            if !best.class_p99_ms.is_empty() {
                let per_class: Vec<String> = best
                    .class_p99_ms
                    .iter()
                    .map(|(c, p)| format!("{} p99 {:.2} ms", c.label(), p))
                    .collect();
                println!("per-class: {}", per_class.join(" | "));
            }
            if f.flag("verbose") {
                let mut t = Table::new(
                    "feasible fleets, cheapest first",
                    &["package", "width", "cost", "p99 ms", "goodput req/s", "viol %"],
                );
                for p in result.plans.iter().take(12) {
                    t.row(vec![
                        p.point.label(),
                        p.width.to_string(),
                        format!("{:.0}", p.fleet_cost),
                        format!("{:.2}", p.p99_ms),
                        format!("{:.0}", p.goodput_rps),
                        format!("{:.2}", p.violation_rate * 100.0),
                    ]);
                }
                print!("{}", t.render());
            }
        }
    }
    Ok(())
}

fn cmd_sim_validate(f: &Flags) -> anyhow::Result<()> {
    let chiplets = f.u64("chiplets", 64)?;
    let sys = SystemConfig { num_chiplets: chiplets, ..Default::default() };
    let side = sys.mesh_side() as u32;
    let coord = Coordinator::new(sys, DesignPoint::INTERPOSER_A, StrategyPolicy::Adaptive);
    let model = resnet50(8);
    let mut t = Table::new(
        &format!("analytical vs cycle-level mesh ({chiplets} chiplets)"),
        &["layer", "analytic(cyc)", "sim(cyc)", "ratio"],
    );
    for l in model.layers.iter().take(12) {
        let s = coord.schedule_layer(l);
        let analytic = s.selection.cost.timeline.preload + s.selection.cost.timeline.stream;
        let sim = simulate_distribution(&s, side, DesignPoint::INTERPOSER_A.distribution_bw());
        t.row(vec![
            l.name.to_string(),
            format!("{analytic:.0}"),
            format!("{:.0}", sim.makespan),
            format!("{:.2}", sim.makespan / analytic),
        ]);
    }
    print!("{}", t.render());
    Ok(())
}

fn cmd_breakdown(f: &Flags) -> anyhow::Result<()> {
    let sys = SystemConfig { num_chiplets: f.u64("chiplets", 256)?, ..Default::default() };
    let b = AreaPowerBreakdown::for_system(&sys, f.f64("wireless-bw", 16.0)?, 1e-9);
    let mut t = Table::new("Table 3: WIENNA area and power breakdown", &["component", "area (mm2)", "power (mW)"]);
    for c in &b.components {
        t.row(vec![c.name.clone(), format!("{:.1}", c.area_mm2), format!("{:.0}", c.power_mw)]);
    }
    t.row(vec!["Total".into(), format!("{:.1}", b.total_area_mm2()), format!("{:.0}", b.total_power_mw())]);
    print!("{}", t.render());
    println!(
        "RX fraction of chiplet: area {:.1}% power {:.1}%",
        b.rx_area_fraction_of_chiplet() * 100.0,
        b.rx_power_fraction_of_chiplet() * 100.0
    );
    Ok(())
}

fn cmd_report(f: &Flags) -> anyhow::Result<()> {
    let sys = SystemConfig { num_chiplets: f.u64("chiplets", 256)?, ..Default::default() };
    let model = parse_workload(&f.str("workload", "resnet50"), f.u64("batch", 64)?)?;
    println!("{}: {} layers, {:.2} GMACs", model.name, model.layers.len(), model.total_macs() as f64 / 1e9);

    let mut t = Table::new("throughput (adaptive)", &["design", "MACs/cycle", "vs Interposer-C"]);
    let mut th = Vec::new();
    for dp in DesignPoint::ALL {
        let e = CostEngine::for_design_point(&sys, dp);
        th.push(evaluate_model(&e, &model, None).macs_per_cycle);
    }
    for (i, dp) in DesignPoint::ALL.iter().enumerate() {
        t.row(vec![dp.label(), format!("{:.0}", th[i]), format!("{:.2}x", th[i] / th[0])]);
    }
    print!("{}", t.render());

    let cmp = wienna::energy::model_distribution_energy(&sys, &model, None);
    println!(
        "distribution energy: interposer {:.2} mJ vs WIENNA {:.2} mJ ({:.1}% reduction)",
        cmp.interposer_pj * 1e-9,
        cmp.wienna_pj * 1e-9,
        cmp.reduction() * 100.0
    );

    // Whole-system energy on WIENNA-C (compute + SRAM + NoPs + idle).
    let ew = CostEngine::for_design_point(&sys, DesignPoint::WIENNA_C);
    let cost = evaluate_model(&ew, &model, None);
    let se = wienna::energy::system_energy(&cost, sys.avg_mesh_hops(), &wienna::energy::EnergyConstants::default());
    println!(
        "whole-system (WIENNA-C): {:.1} mJ total (compute {:.1}, SRAM {:.1}, dist {:.1}, collect {:.1}, idle {:.1}) | {:.0} GMAC/s/W",
        se.total_mj(),
        se.compute_mj,
        se.sram_mj,
        se.distribution_mj,
        se.collection_mj,
        se.idle_mj,
        se.gmacs_per_watt(cost.total_macs, cost.total_latency)
    );

    // Strategy histogram under WIENNA-C.
    let coord = Coordinator::new(sys, DesignPoint::WIENNA_C, StrategyPolicy::Adaptive);
    let (_, sum) = coord.run_model(&model);
    let mut h = Table::new("adaptive strategy histogram", &["layer type", "strategy", "layers"]);
    for (ty, s, n) in &sum.strategy_histogram {
        h.row(vec![ty.clone(), s.clone(), n.to_string()]);
    }
    print!("{}", h.render());
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else {
        eprintln!("{USAGE}");
        std::process::exit(2);
    };
    // `wienna report --diff A B`: the regression gate between two
    // metrics artifacts; `wienna watch SRC`: the live stream dashboard.
    // Both take positionals, so they dispatch before Flags::parse.
    if cmd == "report" && args.get(1).map(String::as_str) == Some("--diff") {
        return wienna::report::diff::run(&args[2..]);
    }
    if cmd == "watch" {
        return wienna::report::watch::run(&args[1..]);
    }
    // `wienna report <artifact>`: the positional form analyzes an emitted
    // metrics artifact (buffered JSON or JSONL stream); the flags-only
    // form below keeps the paper evaluation. Dispatched before
    // Flags::parse, which rejects positional arguments.
    if cmd == "report" && args.get(1).is_some_and(|a| !a.starts_with("--")) {
        return wienna::report::artifact::run(&args[1..]);
    }
    let flags = Flags::parse(&args[1..])?;
    match cmd.as_str() {
        "simulate" => cmd_simulate(&flags),
        "sweep" => cmd_sweep(&flags),
        "serve" => cmd_serve(&flags),
        "cluster" => cmd_cluster(&flags),
        "search" => cmd_search(&flags),
        #[cfg(feature = "pjrt")]
        "e2e" => cmd_e2e(&flags),
        #[cfg(not(feature = "pjrt"))]
        "e2e" => anyhow::bail!("this binary was built without the 'pjrt' feature; rebuild with `cargo build --features pjrt`"),
        "sim-validate" => cmd_sim_validate(&flags),
        "breakdown" => cmd_breakdown(&flags),
        "report" => cmd_report(&flags),
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(())
        }
        other => anyhow::bail!("unknown command '{other}'\n{USAGE}"),
    }
}
